"""Analysis engine: file walk, suppressions, fingerprints, baseline.

The engine is deliberately numpy/jax-free — parsing is stdlib ``ast``,
the baseline is stdlib ``json`` — so the pass runs on any runner,
including a bare CI image before dependency install.

Suppressions
------------
``# repro: ignore[EXA002]`` on a line suppresses those rule ids on that
line; a comment-only line suppresses them on the next line.  Multiple
ids separated by commas.  Suppressed findings never reach the report
(they are counted, for the summary line).

Baseline
--------
Grandfathered findings live in a checked-in JSON file keyed by content
fingerprints: ``sha256(rule : path : stripped-source-line : occurrence)``
— stable under line-number drift, invalidated the moment the offending
line's text changes.  Baselined findings are reported but do not fail
the run; baseline entries that no longer match anything are flagged as
stale so the file shrinks as code is fixed.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
from pathlib import Path, PurePosixPath
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

_SUPPRESS_RE = re.compile(r"#\s*repro:\s*ignore\[([A-Za-z0-9_,\s-]+)\]")
PARSE_ERROR_RULE = "ANA001"  # reserved id: unparseable source file


# ---------------------------------------------------------------------------
# data model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Finding:
  """One rule violation at one source location."""
  rule: str
  path: str               # posix, relative to the scan root
  line: int               # 1-based
  col: int                # 0-based
  message: str
  fingerprint: str = ""   # filled by the engine (content-addressed)
  baselined: bool = False

  def location(self) -> str:
    return f"{self.path}:{self.line}:{self.col + 1}"


class Module:
  """One parsed source file plus its suppression map."""

  def __init__(self, path: Path, rel: str, source: str):
    self.path = path
    self.rel = rel
    self.source = source
    self.lines = source.splitlines()
    self.tree: Optional[ast.AST] = None
    self.parse_error: Optional[SyntaxError] = None
    try:
      self.tree = ast.parse(source)
    except SyntaxError as e:  # surfaced as an ANA001 finding
      self.parse_error = e
    self._suppressions = self._parse_suppressions()

  def _parse_suppressions(self) -> Dict[int, Set[str]]:
    sup: Dict[int, Set[str]] = {}
    for i, text in enumerate(self.lines, start=1):
      m = _SUPPRESS_RE.search(text)
      if not m:
        continue
      ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
      before = text[:m.start()].strip()
      target = i if before else i + 1  # comment-only line guards the next
      sup.setdefault(target, set()).update(ids)
    return sup

  def suppressed(self, line: int, rule: str) -> bool:
    return rule in self._suppressions.get(line, ())

  def line_text(self, line: int) -> str:
    if 1 <= line <= len(self.lines):
      return self.lines[line - 1].strip()
    return ""


@dataclasses.dataclass
class Context:
  """Everything the rules can see: the scanned modules plus the test
  sources (for cross-file contracts like "has an interpret-mode test")."""
  root: Path
  modules: List[Module]
  tests: Dict[str, str]   # test filename -> source text ({} if no dir)
  tests_dir: Optional[Path] = None

  def module(self, rel: str) -> Optional[Module]:
    for m in self.modules:
      if m.rel == rel:
        return m
    return None

  def has_file(self, rel: str) -> bool:
    return (self.root / PurePosixPath(rel)).is_file()


@dataclasses.dataclass
class Report:
  """Scan outcome after suppression + baseline application."""
  findings: List[Finding]          # everything not inline-suppressed
  inline_suppressed: int
  stale_baseline: List[dict]       # baseline entries matching nothing

  @property
  def new(self) -> List[Finding]:
    return [f for f in self.findings if not f.baselined]

  @property
  def baselined(self) -> List[Finding]:
    return [f for f in self.findings if f.baselined]

  @property
  def ok(self) -> bool:
    return not self.new


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

class Baseline:
  """Checked-in grandfathered findings (see module docstring)."""

  VERSION = 1

  def __init__(self, entries: Optional[List[dict]] = None):
    self.entries = list(entries or [])

  @classmethod
  def load(cls, path: Path) -> "Baseline":
    data = json.loads(path.read_text())
    if data.get("version") != cls.VERSION:
      raise ValueError(f"unsupported baseline version {data.get('version')}"
                       f" in {path} (expected {cls.VERSION})")
    return cls(data.get("entries", []))

  @classmethod
  def from_findings(cls, findings: Sequence[Finding],
                    justification: str = "TODO: justify or fix"
                    ) -> "Baseline":
    return cls([{
        "fingerprint": f.fingerprint, "rule": f.rule, "path": f.path,
        "line": f.line, "message": f.message,
        "justification": justification,
    } for f in findings])

  def save(self, path: Path) -> None:
    path.write_text(json.dumps(
        {"version": self.VERSION, "entries": self.entries},
        indent=2, sort_keys=True) + "\n")

  def fingerprints(self) -> Set[str]:
    return {e["fingerprint"] for e in self.entries}


# ---------------------------------------------------------------------------
# fingerprints
# ---------------------------------------------------------------------------

def _assign_fingerprints(findings: List[Finding],
                         modules: Dict[str, Module]) -> None:
  """Content-addressed ids: (rule, path, stripped line text, occurrence
  index among identical triples) — stable when unrelated lines shift."""
  seen: Dict[Tuple[str, str, str], int] = {}
  for f in sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule)):
    mod = modules.get(f.path)
    text = mod.line_text(f.line) if mod else ""
    key = (f.rule, f.path, text)
    occ = seen.get(key, 0)
    seen[key] = occ + 1
    raw = f"{f.rule}:{f.path}:{text}:{occ}"
    f.fingerprint = hashlib.sha256(raw.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# walking + scanning
# ---------------------------------------------------------------------------

def _iter_py_files(path: Path) -> Iterable[Path]:
  if path.is_file():
    yield path
    return
  for p in sorted(path.rglob("*.py")):
    if "__pycache__" not in p.parts:
      yield p


def _load_modules(paths: Sequence[Path]) -> Tuple[Path, List[Module]]:
  """Parse every .py under ``paths``; rel paths are taken against the
  first path (the scan root) so rule scopes like ``core/`` resolve."""
  root = paths[0] if paths[0].is_dir() else paths[0].parent
  modules = []
  for base in paths:
    for p in _iter_py_files(base):
      try:
        rel = p.relative_to(root).as_posix()
      except ValueError:
        rel = p.name
      modules.append(Module(p, rel, p.read_text()))
  return root, modules


def find_tests_dir(root: Path) -> Optional[Path]:
  """Auto-detect the repo's tests/ next to the scan root (walk up a few
  levels looking for a ``tests`` directory beside a ``pytest.ini`` or
  ``.git``)."""
  cur = root.resolve()
  for _ in range(5):
    cand = cur / "tests"
    if cand.is_dir() and any((cur / m).exists()
                             for m in ("pytest.ini", "setup.py",
                                       "pyproject.toml", ".git")):
      return cand
    if cur.parent == cur:
      break
    cur = cur.parent
  return None


def scan_paths(paths: Sequence[Path], tests_dir: Optional[Path] = None,
               baseline: Optional[Baseline] = None,
               rules: Optional[Iterable[str]] = None) -> Report:
  """Run every registered rule over ``paths``; apply suppressions and the
  baseline; return the :class:`Report`.

  ``tests_dir=None`` auto-detects (pass a non-existent path to disable).
  ``rules`` optionally restricts to a subset of rule ids.
  """
  from repro.analysis import rules as _rules  # noqa: F401 (registers packs)
  from repro.analysis.registry import RULES, iter_rules

  paths = [Path(p) for p in paths]
  root, modules = _load_modules(paths)
  if tests_dir is None:
    tests_dir = find_tests_dir(root)
  tests: Dict[str, str] = {}
  if tests_dir is not None and tests_dir.is_dir():
    tests = {p.name: p.read_text() for p in sorted(tests_dir.glob("*.py"))}
  ctx = Context(root=root, modules=modules, tests=tests, tests_dir=tests_dir)

  selected = list(iter_rules()) if rules is None \
      else [RULES[r] for r in rules]
  raw: List[Finding] = []
  for mod in modules:
    if mod.parse_error is not None:
      e = mod.parse_error
      raw.append(Finding(PARSE_ERROR_RULE, mod.rel, e.lineno or 1,
                         (e.offset or 1) - 1, f"syntax error: {e.msg}"))
      continue
    for rule in selected:
      raw.extend(rule.check_module(mod, ctx))
  for rule in selected:
    raw.extend(rule.check_tree(ctx))

  mod_by_rel = {m.rel: m for m in modules}
  kept: List[Finding] = []
  suppressed = 0
  for f in raw:
    mod = mod_by_rel.get(f.path)
    if mod is not None and mod.suppressed(f.line, f.rule):
      suppressed += 1
    else:
      kept.append(f)
  kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
  _assign_fingerprints(kept, mod_by_rel)

  stale: List[dict] = []
  if baseline is not None:
    fps = {f.fingerprint for f in kept}
    for f in kept:
      if f.fingerprint in baseline.fingerprints():
        f.baselined = True
    stale = [e for e in baseline.entries if e["fingerprint"] not in fps]
  return Report(findings=kept, inline_suppressed=suppressed,
                stale_baseline=stale)


# ---------------------------------------------------------------------------
# shared AST helpers (used by the rule packs)
# ---------------------------------------------------------------------------

def attr_chain(node: ast.AST) -> Tuple[str, ...]:
  """Dotted-name parts of a Name/Attribute chain, outermost first:
  ``np.random.RandomState`` -> ("np", "random", "RandomState");
  non-chains (calls, subscripts...) terminate with "?"."""
  parts: List[str] = []
  while isinstance(node, ast.Attribute):
    parts.append(node.attr)
    node = node.value
  if isinstance(node, ast.Name):
    parts.append(node.id)
  else:
    parts.append("?")
  return tuple(reversed(parts))


def walk_functions(tree: ast.AST):
  """Yield every (possibly nested) function definition node."""
  for node in ast.walk(tree):
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
      yield node


def func_params(fn) -> Set[str]:
  a = fn.args
  names = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
  if a.vararg:
    names.append(a.vararg.arg)
  if a.kwarg:
    names.append(a.kwarg.arg)
  return set(names)
