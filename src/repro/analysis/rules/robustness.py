"""Robustness pack (ROB*): failures must surface, not vanish.

The resilience layer (``explore/resilience.py``) gives every failure a
typed path: retryable errors re-execute through ``RetryPolicy``, rung
exhaustion demotes down the device->host ladder, and anything terminal
is journaled and re-raised as ``ChunkError`` with the failing chunk's
global index.  That accounting only works if exceptions actually reach
it — a bare ``except:`` or a handler that silently discards the error
hides faults from the retry/demotion counters and turns a diagnosable
chunk failure into a wrong-answer sweep.  These rules keep the
exploration stack's handlers honest.
"""
from __future__ import annotations

import ast

from repro.analysis import config
from repro.analysis.engine import Finding
from repro.analysis.registry import Rule, register


def _in_robustness_scope(rel: str) -> bool:
  return rel.startswith(config.ROBUSTNESS_DIRS)


def _swallows(handler: ast.ExceptHandler) -> bool:
  """True when the handler body discards the exception without acting.

  A body counts as swallowing when every statement is ``pass``, ``...``,
  or a bare constant (docstring-style) — no re-raise, no logging, no
  fallback value, no state update.
  """
  for stmt in handler.body:
    if isinstance(stmt, ast.Pass):
      continue
    if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
      continue
    return False
  return True


@register
class BareExcept(Rule):
  id = "ROB001"
  pack = "robustness"
  summary = ("bare except / silently swallowed exception in the "
             "exploration stack")

  def check_module(self, mod, ctx):
    if not _in_robustness_scope(mod.rel):
      return
    for node in ast.walk(mod.tree):
      if not isinstance(node, ast.ExceptHandler):
        continue
      if node.type is None:
        yield Finding(
            self.id, mod.rel, node.lineno, node.col_offset,
            "bare 'except:' catches SystemExit/KeyboardInterrupt and "
            "hides the failure from the resilience layer's retry/"
            "demotion accounting; catch a concrete exception type and "
            "let everything else propagate to ChunkError")
      elif _swallows(node):
        yield Finding(
            self.id, mod.rel, node.lineno, node.col_offset,
            "exception handler discards the error without acting "
            "(body is only pass/...); re-raise, degrade to a fallback "
            "rung, or return an explicit sentinel so the failure stays "
            "visible to retry/demotion accounting")


def _has_timeout(call: ast.Call) -> bool:
  return any(kw.arg == "timeout" for kw in call.keywords)


@register
class UnboundedJoin(Rule):
  id = "ROB002"
  pack = "robustness"
  summary = ("unbounded thread/executor join or wait in the exploration "
             "stack")

  def check_module(self, mod, ctx):
    """Flags waits that can block forever in ``explore/``:

    * zero-argument ``.join()`` — a hung worker (the exact failure the
      resilience watchdog exists for) wedges the caller with it; pass a
      timeout and handle the still-alive case,
    * zero-argument ``.wait()`` — an ``Event``/``Condition`` wait with
      no timeout never re-checks cancellation or deadlines,
    * ``wait(futures)`` (the ``concurrent.futures`` form) without a
      ``timeout=``/second positional — one lost future stalls the whole
      dispatch loop.

    String/path ``.join(parts)`` calls carry an argument, so only the
    thread-shaped zero-argument form is flagged.
    """
    if not _in_robustness_scope(mod.rel):
      return
    for node in ast.walk(mod.tree):
      if not isinstance(node, ast.Call):
        continue
      fn = node.func
      if isinstance(fn, ast.Attribute) and fn.attr in ("join", "wait") \
          and not node.args and not _has_timeout(node):
        yield Finding(
            self.id, mod.rel, node.lineno, node.col_offset,
            f"zero-argument .{fn.attr}() blocks forever if the other "
            "side hangs — the resilience layer's watchdog/cancellation "
            "never gets a chance; pass a timeout and re-check "
            "deadline/cancel state in a loop")
      elif (isinstance(fn, ast.Name) and fn.id == "wait"
            and len(node.args) < 2 and not _has_timeout(node)):
        yield Finding(
            self.id, mod.rel, node.lineno, node.col_offset,
            "concurrent.futures.wait without timeout= stalls the "
            "dispatch loop on a single lost future; use "
            "timeout=POOL_WAIT_SECONDS in a re-arming loop")


@register
class DirectDeviceEnumeration(Rule):
  id = "ROB003"
  pack = "robustness"
  summary = ("direct jax.devices()/jax.local_devices() outside the fleet "
             "module")

  def check_module(self, mod, ctx):
    """Flags ``jax.devices()`` / ``jax.local_devices()`` anywhere but
    ``explore/fleet.py`` (tree-wide, not just ``explore/``).  Direct
    enumeration hands code a device the fleet layer may have quarantined
    — a lost or silently-corrupting device looks exactly like a healthy
    one to ``jax.devices()``.  Go through
    ``repro.explore.fleet.visible_devices()`` (or a ``DevicePool``) so
    placement stays health-aware.
    """
    if mod.rel == config.DEVICE_ENUM_MODULE:
      return
    for node in ast.walk(mod.tree):
      if not isinstance(node, ast.Call):
        continue
      fn = node.func
      if (isinstance(fn, ast.Attribute)
          and fn.attr in config.DEVICE_ENUM_CALLS
          and isinstance(fn.value, ast.Name) and fn.value.id == "jax"):
        yield Finding(
            self.id, mod.rel, node.lineno, node.col_offset,
            f"direct jax.{fn.attr}() bypasses the fleet health registry "
            "(quarantined/lost devices look healthy); use "
            "repro.explore.fleet.visible_devices() or a DevicePool")
