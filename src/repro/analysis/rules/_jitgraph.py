"""Which functions in a module trace under jax?

Roots are found syntactically:

  * decorated with ``@jax.jit`` / ``@jit`` / ``@partial(jax.jit, ...)``;
  * passed (possibly via ``functools.partial``) as the first argument to
    a ``jit(...)`` / ``pallas_call(...)`` / ``shard_map(...)`` call
    anywhere in the module;
  * nested functions *returned by* a builder named in
    ``config.JIT_ROOT_BUILDERS`` (the backend jits those returned
    callables cross-module, which no local syntax shows).

Reachability then propagates intra-module through plain ``Name`` calls
(fixpoint).  Cross-module propagation is deliberately out of scope — the
exactness pack's ``xp``-parameter convention covers the generic formula
modules instead (see docs/analysis.md, "limits").
"""
from __future__ import annotations

import ast
from typing import Dict, Set

from repro.analysis import config
from repro.analysis.engine import Module

_JIT_WRAPPERS = frozenset({"jit", "pallas_call", "shard_map"})


def _is_jit_name(node: ast.AST) -> bool:
  """Does this expression denote jit/pallas_call/shard_map?"""
  if isinstance(node, ast.Name):
    return node.id in _JIT_WRAPPERS
  if isinstance(node, ast.Attribute):
    return node.attr in _JIT_WRAPPERS
  return False


def _first_func_arg(call: ast.Call) -> str:
  """Name of the function handed to a jit-like wrapper (unwrapping one
  level of functools.partial), or '' when it is not a plain name."""
  if not call.args:
    return ""
  arg = call.args[0]
  if isinstance(arg, ast.Call) and attr_last(arg.func) == "partial" \
      and arg.args and isinstance(arg.args[0], ast.Name):
    return arg.args[0].id
  if isinstance(arg, ast.Name):
    return arg.id
  return ""


def attr_last(node: ast.AST) -> str:
  if isinstance(node, ast.Attribute):
    return node.attr
  if isinstance(node, ast.Name):
    return node.id
  return ""


def _decorated_as_jit(fn) -> bool:
  for dec in fn.decorator_list:
    if _is_jit_name(dec):
      return True
    if isinstance(dec, ast.Call):
      if _is_jit_name(dec.func):
        return True
      if attr_last(dec.func) == "partial" and dec.args \
          and _is_jit_name(dec.args[0]):
        return True
  return False


def jit_reached_functions(mod: Module) -> Set[ast.AST]:
  """The set of FunctionDef nodes in ``mod`` that trace under jax."""
  tree = mod.tree
  if tree is None:
    return set()
  by_name: Dict[str, list] = {}
  for node in ast.walk(tree):
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
      by_name.setdefault(node.name, []).append(node)

  reached: Set[ast.AST] = set()

  def mark(name: str) -> None:
    for fn in by_name.get(name, ()):
      reached.add(fn)

  for node in ast.walk(tree):
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
        and _decorated_as_jit(node):
      reached.add(node)
    if isinstance(node, ast.Call) and _is_jit_name(node.func):
      name = _first_func_arg(node)
      if name:
        mark(name)

  builders = config.JIT_ROOT_BUILDERS.get(mod.rel, frozenset())
  for node in ast.walk(tree):
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
        and node.name in builders:
      returned = {r.value.id for r in ast.walk(node)
                  if isinstance(r, ast.Return)
                  and isinstance(r.value, ast.Name)}
      for inner in ast.walk(node):
        if isinstance(inner, (ast.FunctionDef, ast.AsyncFunctionDef)) \
            and inner.name in returned:
          reached.add(inner)

  # propagate through intra-module Name calls to fixpoint
  changed = True
  while changed:
    changed = False
    for fn in list(reached):
      for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
          for callee in by_name.get(node.func.id, ()):
            if callee not in reached:
              reached.add(callee)
              changed = True
  return reached


def enclosing_function(mod: Module, target: ast.AST):
  """The innermost function def whose body contains ``target``."""
  best = None
  for fn in ast.walk(mod.tree):
    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
      if fn is target:
        continue
      if any(n is target for n in ast.walk(fn)):
        if best is None or any(n is fn for n in ast.walk(best)):
          best = fn
  return best
