"""Contract-structure pack (CON*): the shapes the stack's guarantees
hang off of.

Every Pallas kernel package carries a numpy reference (``ref.py``), a
jitted public wrapper (``ops.py``) and an interpret-mode test comparing
the two — that triangle IS the kernel correctness story.  Every
streaming reducer implements the fold/result merge surface the
chunk-order-invariance proofs quantify over, and any ``device_spec`` it
offers must speak one of the spec types ``explore.device.build_plan``
can compile.  These rules keep new kernels/reducers from shipping
without their contract half.
"""
from __future__ import annotations

import ast

from repro.analysis import config
from repro.analysis.engine import Finding, attr_chain
from repro.analysis.registry import Rule, register


def _kernel_packages(ctx):
  for mod in ctx.modules:
    m = config.KERNEL_PATH_RE.search(mod.rel)
    if m:
      yield mod, m.group(1)


@register
class KernelSiblings(Rule):
  id = "CON001"
  pack = "contract"
  summary = "kernel.py without its ref.py + ops.py siblings"

  def check_tree(self, ctx):
    for mod, name in _kernel_packages(ctx):
      pkg = mod.rel.rsplit("/", 1)[0]
      missing = [s for s in config.KERNEL_SIBLINGS
                 if not ctx.has_file(f"{pkg}/{s}")]
      if missing:
        yield Finding(
            self.id, mod.rel, 1, 0,
            f"kernel package '{name}' is missing {', '.join(missing)}: "
            "every kernel ships a numpy reference (ref.py) and a jitted "
            "public wrapper (ops.py) alongside kernel.py")


@register
class KernelInterpretTest(Rule):
  id = "CON002"
  pack = "contract"
  summary = "kernel package with no interpret-mode test referencing it"

  def check_tree(self, ctx):
    if ctx.tests_dir is None:
      return  # no tests tree in view: nothing to assert against
    for mod, name in _kernel_packages(ctx):
      covered = any(name in src and "interpret" in src
                    for src in ctx.tests.values())
      if not covered:
        yield Finding(
            self.id, mod.rel, 1, 0,
            f"no test under {ctx.tests_dir} references kernel "
            f"'{name}' together with interpret mode — add an "
            "interpret=True comparison against its ref.py oracle "
            "(see tests/test_kernels.py)")


def _reducer_classes(mod):
  for node in ast.walk(mod.tree):
    if isinstance(node, ast.ClassDef) and any(
        isinstance(b, ast.Name) and b.id == config.REDUCER_BASE
        for b in node.bases):
      yield node


def _methods(cls):
  return {n.name: n for n in cls.body
          if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


@register
class ReducerSurface(Rule):
  id = "CON003"
  pack = "contract"
  summary = ("streaming reducer missing the fold/result merge surface "
             "the chunk-order-invariance guarantees quantify over")

  def check_module(self, mod, ctx):
    if mod.rel != config.STREAMING_MODULE:
      return
    for cls in _reducer_classes(mod):
      methods = _methods(cls)
      missing = [m for m in config.REDUCER_REQUIRED_METHODS
                 if m not in methods]
      if missing:
        yield Finding(
            self.id, mod.rel, cls.lineno, cls.col_offset,
            f"Reducer subclass '{cls.name}' does not define "
            f"{', '.join(missing)}: every accumulator must consume "
            "chunks (fold) and emit its merge (result) so any chunk "
            "partition folds to the same answer")


@register
class DeviceSpecShape(Rule):
  id = "CON004"
  pack = "contract"
  summary = ("device_spec() returning something explore.device.build_plan "
             "cannot compile")

  def check_module(self, mod, ctx):
    if mod.rel != config.STREAMING_MODULE:
      return
    for cls in _reducer_classes(mod):
      spec_fn = _methods(cls).get("device_spec")
      if spec_fn is None:
        continue  # base default (None) => plain per-chunk fallback
      known = {n.id for n in ast.walk(spec_fn)
               if isinstance(n, ast.Name)} & config.DEVICE_SPEC_TYPES
      returns_none_only = all(
          r.value is None or (isinstance(r.value, ast.Constant)
                              and r.value.value is None)
          for r in ast.walk(spec_fn) if isinstance(r, ast.Return))
      if not known and not returns_none_only:
        yield Finding(
            self.id, mod.rel, spec_fn.lineno, spec_fn.col_offset,
            f"'{cls.name}.device_spec' must return one of "
            f"{sorted(config.DEVICE_SPEC_TYPES)} (what "
            "explore.device.build_plan compiles into the fused program) "
            "or None to opt out of fusion")


@register
class SearchSeedRouting(Rule):
  id = "CON005"
  pack = "contract"
  summary = ("guided-search RNG not seeded by a direct derive_seed call "
             "(same-seed bit-identity of optimize() hangs on labelled "
             "per-generation streams)")

  def check_module(self, mod, ctx):
    if mod.rel != config.SEARCH_MODULE:
      return
    for node in ast.walk(mod.tree):
      if not isinstance(node, ast.Call):
        continue
      chain = attr_chain(node.func)
      if chain[-1] not in config.SEED_SINKS:
        continue
      args = list(node.args) + [kw.value for kw in node.keywords]
      derived = any(
          isinstance(a, ast.Call)
          and attr_chain(a.func)[-1] == config.SEED_DERIVER
          for a in args)
      if not derived:
        yield Finding(
            self.id, mod.rel, node.lineno, node.col_offset,
            f"search proposal operators must seed '{chain[-1]}' with a "
            f"direct {config.SEED_DERIVER}(...) call (stricter than "
            "DET005: no pre-derived variables, no raw seeds) so every "
            "random stream is a labelled per-generation derivation and "
            "same-seed optimize() reruns stay bit-identical")
