"""Jit-purity pack (JIT*): traced functions must be pure device programs.

A function reached by ``jax.jit`` / ``pallas_call`` / ``shard_map``
executes as a traced program: Python side effects run once at trace time
(then silently never again), host numpy calls either fail on tracers or
constant-fold surprising values, and ``.item()``-style coercions force a
blocking device sync inside what is supposed to be an async pipeline.
Reachability is computed per module (see :mod:`._jitgraph`).
"""
from __future__ import annotations

import ast

from repro.analysis import config
from repro.analysis.engine import Finding, attr_chain
from repro.analysis.registry import Rule, register
from repro.analysis.rules._jitgraph import jit_reached_functions


def _reached_nodes(mod):
  nodes = {}
  for fn in jit_reached_functions(mod):
    for n in ast.walk(fn):
      nodes.setdefault(id(n), (n, fn))
  return nodes


@register
class PrintInJit(Rule):
  id = "JIT001"
  pack = "jit-purity"
  summary = "print() inside a traced function (runs at trace time only)"

  def check_module(self, mod, ctx):
    for node, fn in _reached_nodes(mod).values():
      if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
          and node.func.id == "print":
        yield Finding(self.id, mod.rel, node.lineno, node.col_offset,
                      f"print() in traced function '{fn.name}' executes "
                      "once at trace time and never per call — use "
                      "jax.debug.print for traced values, or log on the "
                      "host side")


@register
class GlobalStateInJit(Rule):
  id = "JIT002"
  pack = "jit-purity"
  summary = "global/nonlocal mutation inside a traced function"

  def check_module(self, mod, ctx):
    for node, fn in _reached_nodes(mod).values():
      if isinstance(node, (ast.Global, ast.Nonlocal)):
        kind = "global" if isinstance(node, ast.Global) else "nonlocal"
        yield Finding(self.id, mod.rel, node.lineno, node.col_offset,
                      f"{kind} statement in traced function '{fn.name}': "
                      "mutation happens at trace time only; thread state "
                      "through arguments/returns instead")


@register
class HostNumpyInJit(Rule):
  id = "JIT003"
  pack = "jit-purity"
  summary = "host numpy call inside a traced function"

  def check_module(self, mod, ctx):
    for node, fn in _reached_nodes(mod).values():
      if isinstance(node, ast.Call):
        chain = attr_chain(node.func)
        if chain[0] in ("np", "numpy") and len(chain) >= 2 \
            and chain[1] != "random":  # np.random is DET001's beat
          yield Finding(
              self.id, mod.rel, node.lineno, node.col_offset,
              f"host {'.'.join(chain)}(...) in traced function "
              f"'{fn.name}' — it fails on tracers or constant-folds at "
              "trace time; use jnp, or justify (trace-constant "
              "computation) with a suppression")


@register
class HostCoercionInJit(Rule):
  id = "JIT004"
  pack = "jit-purity"
  summary = (".item()/.tolist()/device_get host coercion inside a traced "
             "function")

  def check_module(self, mod, ctx):
    for node, fn in _reached_nodes(mod).values():
      if not isinstance(node, ast.Call):
        continue
      chain = attr_chain(node.func)
      if (chain[-1] in config.HOST_COERCION_METHODS
          and isinstance(node.func, ast.Attribute)) \
          or chain[-1] in config.HOST_COERCION_CALLS:
        yield Finding(
            self.id, mod.rel, node.lineno, node.col_offset,
            f"host coercion .{chain[-1]}(...) in traced function "
            f"'{fn.name}' fails on tracers (concretization error) and "
            "forces a device sync — keep values on device until the "
            "caller resolves them")
