"""Determinism pack (DET*): sweeps must be replayable from their seeds.

Every number the exploration stack produces is either a pure function of
a config table or derived from an explicitly seeded RNG; the streaming
engine's chunk-order-invariance proofs assume it.  These rules catch the
ways that silently stops being true.
"""
from __future__ import annotations

import ast

from repro.analysis import config
from repro.analysis.engine import Finding, attr_chain
from repro.analysis.registry import Rule, register


def _in_determinism_scope(rel: str) -> bool:
  return rel.startswith(config.DETERMINISM_DIRS)


def _np_random_call(node: ast.Call):
  """('np'|'numpy', fn) when the call is np.random.<fn>(...), else None."""
  chain = attr_chain(node.func)
  if len(chain) == 3 and chain[0] in ("np", "numpy") \
      and chain[1] == "random":
    return chain[2]
  return None


@register
class GlobalNumpyRandom(Rule):
  id = "DET001"
  pack = "determinism"
  summary = ("call into numpy's hidden module-global RNG "
             "(np.random.<fn>) instead of a seeded RandomState/Generator")

  def check_module(self, mod, ctx):
    for node in ast.walk(mod.tree):
      if isinstance(node, ast.Call):
        fn = _np_random_call(node)
        if fn is not None and fn not in config.SEEDED_RNG_FACTORIES:
          yield Finding(self.id, mod.rel, node.lineno, node.col_offset,
                        f"np.random.{fn}(...) draws from the process-global "
                        "RNG; construct a seeded np.random.RandomState / "
                        "default_rng and draw from it")


@register
class UnseededRngFactory(Rule):
  id = "DET002"
  pack = "determinism"
  summary = "RNG factory constructed without a seed (entropy from the OS)"

  def check_module(self, mod, ctx):
    for node in ast.walk(mod.tree):
      if isinstance(node, ast.Call):
        fn = _np_random_call(node)
        if fn in ("RandomState", "default_rng") and not node.args \
            and not node.keywords:
          yield Finding(self.id, mod.rel, node.lineno, node.col_offset,
                        f"np.random.{fn}() without a seed pulls OS entropy; "
                        "pass an explicit seed (see "
                        "repro.core.seeding.derive_seed)")


@register
class WallClock(Rule):
  id = "DET003"
  pack = "determinism"
  summary = ("wall-clock read (time.time / datetime.now) in deterministic "
             "numeric code")

  def check_module(self, mod, ctx):
    if not _in_determinism_scope(mod.rel):
      return
    for node in ast.walk(mod.tree):
      if isinstance(node, ast.Call):
        chain = attr_chain(node.func)
        if len(chain) >= 2 and chain[-2:] in config.WALL_CLOCK_CALLS:
          yield Finding(self.id, mod.rel, node.lineno, node.col_offset,
                        f"wall-clock read {'.'.join(chain)}(...) in "
                        f"{mod.rel}: results must be a function of seeds "
                        "and configs only (monotonic perf counters for "
                        "throughput metadata are fine)")


@register
class SetOrderIteration(Rule):
  id = "DET004"
  pack = "determinism"
  summary = ("iteration over a set drives numeric work in hash order "
             "(string hashing is per-process randomized)")

  def _set_valued(self, node: ast.AST) -> bool:
    if isinstance(node, ast.Set):
      return True
    return isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
        and node.func.id in ("set", "frozenset")

  def check_module(self, mod, ctx):
    if not _in_determinism_scope(mod.rel):
      return
    iters = []
    for node in ast.walk(mod.tree):
      if isinstance(node, ast.For):
        iters.append(node.iter)
      elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                             ast.GeneratorExp)):
        iters.extend(gen.iter for gen in node.generators)
    for it in iters:
      if self._set_valued(it):
        yield Finding(self.id, mod.rel, it.lineno, it.col_offset,
                      "iterating a set: order is hash-dependent "
                      "(PYTHONHASHSEED) — wrap in sorted(...) or iterate "
                      "a list/tuple")


@register
class AdHocSeedArithmetic(Rule):
  id = "DET005"
  pack = "determinism"
  summary = ("arithmetic seed derivation at an RNG constructor "
             "(collision/overflow-prone) instead of derive_seed")

  def check_module(self, mod, ctx):
    for node in ast.walk(mod.tree):
      if not isinstance(node, ast.Call):
        continue
      chain = attr_chain(node.func)
      if chain[-1] not in config.SEED_SINKS:
        continue
      # jax.random.key / PRNGKey or np.random.* only — not arbitrary
      # user functions that happen to share a sink name
      if chain[-1] in ("PRNGKey", "key") and len(chain) >= 2 \
          and chain[-2] != "random":
        continue
      for arg in node.args:
        if isinstance(arg, ast.BinOp):
          yield Finding(
              self.id, mod.rel, arg.lineno, arg.col_offset,
              f"ad-hoc seed arithmetic feeding {'.'.join(chain)}: linear "
              "seed maps collide (seed*k+i meets seed'*k+i') and overflow "
              "platform int bounds — derive child seeds with "
              "repro.core.seeding.derive_seed(label, *components)")
