"""Rule packs — importing this module registers every rule."""
from repro.analysis.rules import (contract, determinism, exactness,  # noqa: F401
                                  jit_purity, robustness)
