"""Exactness pack (EXA*): the parity_max_rel_err == 0.0 contract.

The exact device path is bit-identical to numpy because the parity-
critical modules restrict themselves to IEEE-exact ops (+-*/, sqrt,
ceil, comparisons) and host-precompute everything else
(:func:`repro.core.oracle.batch_inputs`).  These rules fence that
discipline: a float32 cast, an XLA transcendental, or a reassociated
reduction in those modules is a silent 1-ulp (or worse) parity break.

EXA002/EXA003 scope to *array-context* functions — those taking an
``xp``/``jnp`` array-module parameter or reached by a jit root — since
host-only helpers (e.g. the scalar reference oracle) ARE the libm
reference the contract compares against.
"""
from __future__ import annotations

import ast
from typing import Set

from repro.analysis import config
from repro.analysis.engine import Finding, attr_chain, func_params
from repro.analysis.registry import Rule, register
from repro.analysis.rules._jitgraph import jit_reached_functions


def _array_context_nodes(mod) -> Set[ast.AST]:
  """All AST nodes inside functions that may trace under jax."""
  fns = set(jit_reached_functions(mod))
  for fn in ast.walk(mod.tree):
    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
        and func_params(fn) & config.ARRAY_MODULE_PARAMS:
      fns.add(fn)
  nodes: Set[ast.AST] = set()
  for fn in fns:
    nodes.update(ast.walk(fn))
  return nodes


@register
class Float32Cast(Rule):
  id = "EXA001"
  pack = "exactness"
  summary = "float32 cast/dtype in a parity-critical module (exact = x64)"

  def check_module(self, mod, ctx):
    if mod.rel not in config.PARITY_CRITICAL:
      return
    for node in ast.walk(mod.tree):
      hit = None
      if isinstance(node, ast.Attribute) and node.attr == "float32" \
          and attr_chain(node)[0] in ("np", "numpy", "jnp", "jax"):
        hit = node
      elif isinstance(node, ast.Constant) and node.value == "float32":
        hit = node
      if hit is not None:
        yield Finding(self.id, mod.rel, hit.lineno, hit.col_offset,
                      "float32 in a parity-critical module: the exact "
                      "contract is float64 end to end (the float32 demo "
                      "mode lives behind precision='float32' in the "
                      "backend, not here)")


@register
class DivergentTranscendental(Rule):
  id = "EXA002"
  pack = "exactness"
  summary = ("XLA-divergent transcendental (log/exp/pow/...) via xp/jnp "
             "in a traceable function of a parity-critical module")

  def check_module(self, mod, ctx):
    if mod.rel not in config.PARITY_CRITICAL:
      return
    ctx_nodes = _array_context_nodes(mod)
    for node in ast.walk(mod.tree):
      if node not in ctx_nodes:
        continue
      if isinstance(node, ast.Call):
        chain = attr_chain(node.func)
        if len(chain) == 2 and chain[0] in config.ARRAY_MODULE_PARAMS \
            and chain[1] in config.DIVERGENT_OPS:
          yield Finding(
              self.id, mod.rel, node.lineno, node.col_offset,
              f"{'.'.join(chain)}(...) may differ from numpy by 1 ulp "
              "under XLA — host-precompute it into the inputs bundle "
              "(oracle.batch_inputs) or justify with a suppression")
      # non-integer literal exponent => pow lowering on the array path
      if isinstance(node, ast.BinOp) \
          and isinstance(node.op, ast.Pow) \
          and isinstance(node.right, ast.Constant) \
          and isinstance(node.right.value, float) \
          and not float(node.right.value).is_integer():
        yield Finding(
            self.id, mod.rel, node.lineno, node.col_offset,
            f"`** {node.right.value}` lowers to a pow call on the array "
            "path, which XLA computes differently from numpy — "
            "host-precompute (oracle.batch_inputs) or justify with a "
            "suppression")


@register
class ReassociatingReduction(Rule):
  id = "EXA003"
  pack = "exactness"
  summary = ("reduction/contraction with reassociable accumulation order "
             "in a traceable function of a parity-critical module")

  def check_module(self, mod, ctx):
    if mod.rel not in config.PARITY_CRITICAL:
      return
    ctx_nodes = _array_context_nodes(mod)
    for node in ast.walk(mod.tree):
      if node not in ctx_nodes or not isinstance(node, ast.Call):
        continue
      chain = attr_chain(node.func)
      if len(chain) == 2 and chain[0] in config.ARRAY_MODULE_PARAMS \
          and chain[1] in config.REASSOCIATING_CALLS:
        name = ".".join(chain)
      elif len(chain) >= 2 and chain[-1] in config.REASSOCIATING_METHODS \
          and isinstance(node.func, ast.Attribute):
        name = f"<expr>.{chain[-1]}"
      else:
        continue
      yield Finding(
          self.id, mod.rel, node.lineno, node.col_offset,
          f"{name}(...) lets XLA reassociate the accumulation — "
          "bit-identity needs a fixed-order fold (or a justified "
          "suppression when the result is integer-exact / outside the "
          "parity contract)")


@register
class DivergentOpWithoutRef(Rule):
  id = "EXA004"
  pack = "exactness"
  summary = ("kernel uses XLA-divergent ops but ships no ref.py numpy "
             "oracle to pin its semantics")

  def check_module(self, mod, ctx):
    m = config.KERNEL_PATH_RE.search(mod.rel)
    if not m:
      return
    uses = []
    for node in ast.walk(mod.tree):
      if isinstance(node, ast.Call):
        chain = attr_chain(node.func)
        if len(chain) >= 2 and chain[-1] in config.DIVERGENT_OPS \
            and chain[0] in ("jnp", "jax", "lax"):
          uses.append((node, ".".join(chain)))
    if not uses:
      return
    ref = mod.rel.rsplit("/", 1)[0] + "/ref.py"
    if not ctx.has_file(ref):
      node, name = uses[0]
      yield Finding(
          self.id, mod.rel, node.lineno, node.col_offset,
          f"kernel calls {name}(...) (XLA-divergent) but has no sibling "
          "ref.py — every kernel's numerics must be pinned by a numpy "
          "reference the interpret-mode tests compare against")
