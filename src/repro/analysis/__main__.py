"""CLI: ``python -m repro.analysis [paths...] [options]``.

Exit codes: 0 = clean (modulo baseline), 1 = new findings (or stale
baseline entries under --strict-baseline), 2 = usage/IO error.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.engine import Baseline, scan_paths
from repro.analysis.formats import FORMATTERS, summary_line
from repro.analysis.registry import iter_rules

DEFAULT_BASELINE = "analysis_baseline.json"


def _build_parser() -> argparse.ArgumentParser:
  p = argparse.ArgumentParser(
      prog="python -m repro.analysis",
      description="Determinism & exactness static analysis "
                  "(rule catalog: docs/analysis.md)")
  p.add_argument("paths", nargs="*", default=None,
                 help="files/directories to scan (default: src/repro, "
                      "falling back to the package directory)")
  p.add_argument("--format", choices=sorted(FORMATTERS),
                 default="text", help="report format (default: text)")
  p.add_argument("--output", metavar="FILE",
                 help="write the report to FILE instead of stdout "
                      "(a text summary still goes to stderr)")
  p.add_argument("--baseline", metavar="FILE",
                 help=f"baseline JSON (default: ./{DEFAULT_BASELINE} "
                      "when present; 'none' disables)")
  p.add_argument("--write-baseline", action="store_true",
                 help="write all current findings to the baseline file "
                      "and exit 0 (then edit in the justifications)")
  p.add_argument("--strict-baseline", action="store_true",
                 help="also fail when the baseline has stale entries")
  p.add_argument("--tests-dir", metavar="DIR",
                 help="tests directory for the contract rules "
                      "(default: auto-detect; 'none' disables)")
  p.add_argument("--rules", metavar="IDS",
                 help="comma-separated rule ids to run (default: all)")
  p.add_argument("--list-rules", action="store_true",
                 help="print the rule catalog and exit")
  return p


def _default_paths() -> list:
  if Path("src/repro").is_dir():
    return [Path("src/repro")]
  return [Path(__file__).resolve().parents[1]]  # the repro package


def main(argv=None) -> int:
  args = _build_parser().parse_args(argv)

  if args.list_rules:
    for rule in iter_rules():
      print(f"{rule.id}  [{rule.pack}]  {rule.summary}")
    return 0

  paths = [Path(p) for p in args.paths] if args.paths else _default_paths()
  for p in paths:
    if not p.exists():
      print(f"error: no such path: {p}", file=sys.stderr)
      return 2

  baseline_path = None
  if args.baseline != "none":
    baseline_path = Path(args.baseline) if args.baseline \
        else (Path(DEFAULT_BASELINE)
              if Path(DEFAULT_BASELINE).is_file() else None)
  baseline = None
  if baseline_path is not None and baseline_path.is_file():
    try:
      baseline = Baseline.load(baseline_path)
    except (ValueError, OSError) as e:
      print(f"error: cannot load baseline {baseline_path}: {e}",
            file=sys.stderr)
      return 2

  tests_dir = None
  if args.tests_dir == "none":
    tests_dir = Path("/nonexistent")
  elif args.tests_dir:
    tests_dir = Path(args.tests_dir)

  rules = [r.strip() for r in args.rules.split(",")] if args.rules else None
  try:
    report = scan_paths(paths, tests_dir=tests_dir, baseline=baseline,
                        rules=rules)
  except KeyError as e:
    print(f"error: unknown rule id {e}", file=sys.stderr)
    return 2

  if args.write_baseline:
    out = baseline_path or Path(DEFAULT_BASELINE)
    Baseline.from_findings(report.findings).save(out)
    print(f"wrote {len(report.findings)} entries to {out} — edit in the "
          "justifications; the goal is an empty baseline", file=sys.stderr)
    return 0

  rendered = FORMATTERS[args.format](report)
  if args.output:
    Path(args.output).write_text(rendered)
    print(summary_line(report), file=sys.stderr)
  else:
    sys.stdout.write(rendered)
    if args.format != "text":
      print(summary_line(report), file=sys.stderr)

  if report.new:
    return 1
  if args.strict_baseline and report.stale_baseline:
    return 1
  return 0


if __name__ == "__main__":
  sys.exit(main())
