"""Report serializers: text (human), json (tooling), sarif (CI upload)."""
from __future__ import annotations

import json
from typing import List

from repro.analysis.engine import Report
from repro.analysis.registry import RULES

_SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                 "master/Schemata/sarif-schema-2.1.0.json")


def summary_line(report: Report) -> str:
  n = len(report.findings)
  parts = [f"{n} finding{'s' if n != 1 else ''}",
           f"{len(report.new)} new",
           f"{len(report.baselined)} baselined",
           f"{report.inline_suppressed} inline-suppressed"]
  if report.stale_baseline:
    parts.append(f"{len(report.stale_baseline)} stale baseline entries")
  return "repro.analysis: " + ", ".join(parts)


def to_text(report: Report) -> str:
  out: List[str] = []
  for f in report.findings:
    tag = " [baseline]" if f.baselined else ""
    out.append(f"{f.location()} {f.rule}{tag} {f.message}")
  for e in report.stale_baseline:
    out.append(f"{e['path']}:{e['line']}: stale baseline entry "
               f"{e['rule']} ({e['fingerprint']}) matches nothing — "
               "remove it from the baseline file")
  out.append(summary_line(report))
  return "\n".join(out) + "\n"


def to_json(report: Report) -> str:
  return json.dumps({
      "findings": [{
          "rule": f.rule, "path": f.path, "line": f.line, "col": f.col,
          "message": f.message, "fingerprint": f.fingerprint,
          "baselined": f.baselined,
      } for f in report.findings],
      "stale_baseline": report.stale_baseline,
      "counts": {
          "total": len(report.findings),
          "new": len(report.new),
          "baselined": len(report.baselined),
          "inline_suppressed": report.inline_suppressed,
      },
      "ok": report.ok,
  }, indent=2) + "\n"


def to_sarif(report: Report) -> str:
  rules = [{
      "id": rid,
      "shortDescription": {"text": rule.summary},
      "properties": {"pack": rule.pack},
  } for rid, rule in sorted(RULES.items())]
  results = []
  for f in report.findings:
    res = {
        "ruleId": f.rule,
        "level": "error",
        "message": {"text": f.message},
        "locations": [{
            "physicalLocation": {
                "artifactLocation": {"uri": f.path},
                "region": {"startLine": f.line,
                           "startColumn": f.col + 1},
            },
        }],
        "fingerprints": {"reproAnalysis/v1": f.fingerprint},
    }
    if f.baselined:
      res["suppressions"] = [{"kind": "external",
                              "justification": "checked-in baseline"}]
    results.append(res)
  doc = {
      "$schema": _SARIF_SCHEMA,
      "version": "2.1.0",
      "runs": [{
          "tool": {"driver": {
              "name": "repro.analysis",
              "informationUri": "docs/analysis.md",
              "rules": rules,
          }},
          "results": results,
      }],
  }
  return json.dumps(doc, indent=2) + "\n"


FORMATTERS = {"text": to_text, "json": to_json, "sarif": to_sarif}
