"""repro.analysis: determinism & exactness static analysis.

The exploration stack's headline guarantee — every fast path (vectorized,
streaming, device-fused) is *bit-identical* to the scalar numpy oracle —
is a contract no runtime test can police exhaustively: one stray
``np.random`` call, an unseeded RNG, a float32 literal in an exact x64
formula, or a host ``np.`` call inside a jitted program silently breaks
``parity_max_rel_err == 0.0`` for some sweep nobody benchmarks.  This
package is the AST-level backstop: a rule registry with per-rule codes,
inline suppressions (``# repro: ignore[RULE-ID]``), a checked-in baseline
for grandfathered findings, and a CLI::

    python -m repro.analysis [paths...] [--format text|json|sarif]
                             [--baseline analysis_baseline.json]

Rule packs (see :mod:`repro.analysis.rules` and docs/analysis.md):

  DET*  determinism   — global/unseeded RNG, wall-clock reads, set-order
                        iteration, ad-hoc seed arithmetic
  EXA*  exactness     — float32 casts, divergent transcendentals, and
                        reassociating reductions in the parity-critical
                        modules (core/oracle.py, core/dataflow.py,
                        explore/device.py); divergent jnp ops in kernels
                        without a ref.py oracle
  JIT*  jit-purity    — print / global state / host numpy / host
                        coercions inside functions reached by jax.jit,
                        pallas_call or shard_map
  CON*  contract      — kernel packages must ship kernel.py + ref.py +
                        ops.py + an interpret-mode test; streaming
                        reducers must implement the fold/result/
                        device_spec surface explore.device.build_plan
                        expects

The engine is pure stdlib (ast + json): it never imports numpy or jax,
so it runs in any environment, including bare CI runners.
"""
from repro.analysis.engine import (Baseline, Finding, Module, Report,
                                   scan_paths)
from repro.analysis.registry import RULES, Rule, register

__all__ = ["Baseline", "Finding", "Module", "Report", "scan_paths",
           "RULES", "Rule", "register"]
