"""Repo-specific scoping for the rule packs.

Paths are posix-style, relative to the scan root (scanning ``src/repro``
makes the oracle ``core/oracle.py`` — the fixture trees the tests build
mirror that layout, so scopes apply there unchanged).
"""
from __future__ import annotations

import re

# -- determinism pack --------------------------------------------------------

# Directories whose numerics must be run-to-run deterministic: the oracle
# formulas, the exploration engine, the Pallas kernels and the synthetic
# data pipelines.  (launch/, serve/, train/ may legitimately read clocks.)
DETERMINISM_DIRS = ("core/", "explore/", "kernels/", "data/")

# np.random factories that carry explicit seed state (everything else on
# np.random is the hidden module-global generator).
SEEDED_RNG_FACTORIES = frozenset({
    "RandomState", "default_rng", "Generator", "SeedSequence",
    "PCG64", "PCG64DXSM", "Philox", "MT19937", "SFC64",
})

# Wall-clock reads (date/time-of-day).  Monotonic benchmarking clocks
# (perf_counter / monotonic) are deliberately NOT listed: throughput
# metadata is allowed, nondeterministic *inputs* are not.
WALL_CLOCK_CALLS = frozenset({
    ("time", "time"), ("time", "time_ns"),
    ("datetime", "now"), ("datetime", "utcnow"), ("datetime", "today"),
    ("date", "today"),
})

# Seed-consuming constructors whose arguments must come from
# repro.core.seeding.derive_seed rather than ad-hoc arithmetic.
SEED_SINKS = frozenset({"RandomState", "default_rng", "SeedSequence",
                        "PRNGKey", "key"})
SEED_DERIVER = "derive_seed"

# -- exactness pack ----------------------------------------------------------

# Modules under the parity_max_rel_err == 0.0 contract: the batch oracle
# formulas, the dataflow model, and the fused device programs.
PARITY_CRITICAL = frozenset({
    "core/oracle.py", "core/dataflow.py", "explore/device.py",
})

# Array-module names the generic formulas are written against.  A
# function taking one of these as a parameter may trace under jax, where
# transcendentals and reassociating reductions diverge from numpy.
ARRAY_MODULE_PARAMS = frozenset({"xp", "jnp"})

# Ops where XLA's result is not guaranteed bit-identical to libm/numpy
# (typically 1 ulp): these must be host-precomputed on the exact path
# (see repro.core.oracle.batch_inputs) or carry a justified suppression.
DIVERGENT_OPS = frozenset({
    "log", "log2", "log10", "log1p", "exp", "exp2", "expm1",
    "power", "pow", "float_power", "tanh", "sinh", "cosh",
    "sin", "cos", "tan", "arcsin", "arccos", "arctan", "arctan2",
    "erf", "erfc", "cbrt", "sigmoid", "softmax", "logsumexp",
})

# Reductions/contractions whose accumulation order XLA may reassociate.
REASSOCIATING_CALLS = frozenset({
    "einsum", "tensordot", "matmul", "dot", "vdot", "inner", "prod",
})
REASSOCIATING_METHODS = frozenset({"sum", "mean", "dot", "prod"})

# -- jit-purity pack ---------------------------------------------------------

# Known jit-root *builders*: functions whose returned nested callables the
# backend wraps in jax.jit (repro/explore/backend.py).  The syntactic
# detector cannot see that cross-module hand-off, so they are named here;
# add new builders when a module grows one.
JIT_ROOT_BUILDERS = {
    "explore/device.py": frozenset({"make_eval_fn", "make_joint_fn"}),
}

# Host coercions that force a device sync / transfer inside traced code.
HOST_COERCION_METHODS = frozenset({"item", "tolist", "block_until_ready"})
HOST_COERCION_CALLS = frozenset({"device_get"})

# -- robustness pack ---------------------------------------------------------

# Directories under the fault-tolerance contract: every exception either
# reaches the resilience layer's retry/demotion accounting or is
# re-raised as a typed ChunkError — never silently swallowed (ROB001).
ROBUSTNESS_DIRS = ("explore/",)

# The one sanctioned device-enumeration call site (ROB003): every other
# module must reach devices through repro.explore.fleet, so the fleet's
# health registry / quarantine cannot be bypassed.  Scanned tree-wide.
DEVICE_ENUM_MODULE = "explore/fleet.py"
DEVICE_ENUM_CALLS = frozenset({"devices", "local_devices"})

# -- contract pack -----------------------------------------------------------

KERNEL_PATH_RE = re.compile(r"(?:^|/)kernels/([A-Za-z0-9_]+)/kernel\.py$")
KERNEL_SIBLINGS = ("ref.py", "ops.py")
STREAMING_MODULE = "explore/streaming.py"
# The guided-search optimizer: every RNG its proposal operators construct
# must be seeded by a *direct* derive_seed(...) call (CON005) — stricter
# than DET005 (which only rejects ad-hoc seed arithmetic), because the
# search bit-identity contract hangs on labelled per-generation streams.
SEARCH_MODULE = "explore/search.py"
REDUCER_BASE = "Reducer"
REDUCER_REQUIRED_METHODS = ("fold", "result")
DEVICE_SPEC_TYPES = frozenset({"ParetoSpec", "TopKSpec", "StatsSpec",
                               "HistSpec"})
