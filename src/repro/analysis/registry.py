"""Rule registry: every rule is a singleton with a stable id and pack.

A rule sees one module at a time (:meth:`Rule.check_module`) and, after
the walk, the whole tree (:meth:`Rule.check_tree`) for cross-file
contracts (kernel siblings, test references).  Rules yield raw findings;
the engine owns suppression, baselining and fingerprints.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Iterable, Iterator, Type

if TYPE_CHECKING:  # pragma: no cover - typing only
  from repro.analysis.engine import Context, Finding, Module


class Rule:
  """One checkable invariant.  Subclasses set the class attributes and
  override one (or both) of the check hooks."""

  id: str = ""            # e.g. "DET001"
  pack: str = ""          # "determinism" | "exactness" | "jit-purity" | ...
  summary: str = ""       # one-line catalog entry (docs/analysis.md)

  def check_module(self, mod: "Module", ctx: "Context"
                   ) -> Iterable["Finding"]:
    return ()

  def check_tree(self, ctx: "Context") -> Iterable["Finding"]:
    return ()


RULES: Dict[str, Rule] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
  """Class decorator: instantiate and index the rule by id."""
  inst = cls()
  if not inst.id or not inst.pack:
    raise ValueError(f"rule {cls.__name__} must set id and pack")
  if inst.id in RULES:
    raise ValueError(f"duplicate rule id {inst.id}")
  RULES[inst.id] = inst
  return cls


def iter_rules() -> Iterator[Rule]:
  # The packs register themselves on import; pull them in here so direct
  # catalog queries (--list-rules) see the same set scan_paths does.
  import repro.analysis.rules  # noqa: F401  (registration side effect)
  for rid in sorted(RULES):
    yield RULES[rid]
