"""Optimizers in pure JAX: AdamW (with optional int8 block-quantized
moments) and SGD + Nesterov momentum (the paper's CIFAR recipe).

Quantized optimizer state is QUIDAM's precision axis applied to the
distributed-memory roofline: block-wise int8 m/v (bitsandbytes-style,
block 256, per-block absmax scales) cut optimizer HBM by ~3.5x — the
difference between jamba-1.5-large fitting a single pod or not (see
EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any

QUANT_BLOCK = 256


# ---------------------------------------------------------------------------
# block-wise int8 state codec
# ---------------------------------------------------------------------------

def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
  """f32 -> (int8 codes, per-block scales), blocked along the LAST axis.

  Shape-preserving blocking (codes keep the tensor's shape, padded on the
  last dim) so the int8 state inherits the parameter's sharding spec
  exactly — with flat-blocked state the SPMD partitioner must re-gather
  full f32 moments at every update (measured: 6.1 TB of depth-0
  all-gathers on jamba-1.5-large; see EXPERIMENTS.md §Perf)."""
  last = x.shape[-1] if x.ndim else 1
  pad = (-last) % QUANT_BLOCK
  xp = jnp.pad(x.reshape(*x.shape[:-1], last),
               [(0, 0)] * (x.ndim - 1) + [(0, pad)])
  xb = xp.reshape(*x.shape[:-1], -1, QUANT_BLOCK)
  scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1, keepdims=True),
                      1e-12) / 127.0
  codes = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
  return codes.reshape(*x.shape[:-1], last + pad), scale[..., 0]


def _dq8(codes: jax.Array, scale: jax.Array, shape) -> jax.Array:
  last = shape[-1] if shape else 1
  xb = codes.reshape(*codes.shape[:-1], -1, QUANT_BLOCK).astype(jnp.float32)
  x = (xb * scale[..., None]).reshape(*codes.shape[:-1], -1)
  return x[..., :last].reshape(shape)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamWConfig:
  lr: float = 3e-4
  b1: float = 0.9
  b2: float = 0.95
  eps: float = 1e-8
  weight_decay: float = 0.1
  grad_clip: float = 1.0
  quantize_state: bool = False   # int8 block-wise m/v
  schedule: str = "cosine"       # cosine | constant | paper_cifar
  warmup_steps: int = 100
  total_steps: int = 10_000


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
  s = step.astype(jnp.float32)
  warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
  if cfg.schedule == "constant":
    return cfg.lr * warm
  if cfg.schedule == "cosine":
    t = jnp.clip((s - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
  raise ValueError(cfg.schedule)


def adamw_init(cfg: AdamWConfig, params: Params) -> Dict:
  def zeros_like_state(p):
    if cfg.quantize_state:
      codes, scale = _q8(jnp.zeros_like(p, jnp.float32))
      return {"codes": codes, "scale": scale}
    return jnp.zeros_like(p, jnp.float32)

  return {
      "step": jnp.zeros((), jnp.int32),
      "m": jax.tree_util.tree_map(zeros_like_state, params),
      "v": jax.tree_util.tree_map(zeros_like_state, params),
  }


def global_norm(tree) -> jax.Array:
  leaves = jax.tree_util.tree_leaves(tree)
  return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                      for l in leaves))


def adamw_update(cfg: AdamWConfig, params: Params, grads: Params,
                 state: Dict) -> Tuple[Params, Dict, Dict]:
  step = state["step"] + 1
  lr = lr_at(cfg, step)
  gnorm = global_norm(grads)
  scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
      if cfg.grad_clip else 1.0
  bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
  bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

  def upd(p, g, m, v):
    g = g.astype(jnp.float32) * scale
    if cfg.quantize_state:
      m_f = _dq8(m["codes"], m["scale"], p.shape)
      v_f = _dq8(v["codes"], v["scale"], p.shape)
    else:
      m_f, v_f = m, v
    m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
    v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
    mh = m_f / bc1
    vh = v_f / bc2
    delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
        p.astype(jnp.float32)
    p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
    if cfg.quantize_state:
      mc, ms = _q8(m_f)
      vc, vs = _q8(v_f)
      return p_new, {"codes": mc, "scale": ms}, {"codes": vc, "scale": vs}
    return p_new, m_f, v_f

  flat_p, tdef = jax.tree_util.tree_flatten(params)
  flat_g = tdef.flatten_up_to(grads)
  flat_m = tdef.flatten_up_to(state["m"])
  flat_v = tdef.flatten_up_to(state["v"])
  out = [upd(p, g, m, v) for p, g, m, v in
         zip(flat_p, flat_g, flat_m, flat_v)]
  new_p = tdef.unflatten([o[0] for o in out])
  new_m = tdef.unflatten([o[1] for o in out])
  new_v = tdef.unflatten([o[2] for o in out])
  metrics = {"lr": lr, "grad_norm": gnorm}
  return new_p, {"step": step, "m": new_m, "v": new_v}, metrics


# ---------------------------------------------------------------------------
# SGD + Nesterov (paper Sec. 4.3 CIFAR recipe)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SGDConfig:
  """The paper's recipe: momentum 0.9 Nesterov, wd 5e-4, lr 0.1 dropped 5x
  at epochs 60/120/160 over 200 epochs."""
  lr: float = 0.1
  momentum: float = 0.9
  nesterov: bool = True
  weight_decay: float = 5e-4
  drops: Tuple[int, ...] = (60, 120, 160)
  drop_factor: float = 0.2
  steps_per_epoch: int = 100


def sgd_init(params: Params) -> Dict:
  return {"step": jnp.zeros((), jnp.int32),
          "mom": jax.tree_util.tree_map(
              lambda p: jnp.zeros_like(p, jnp.float32), params)}


def sgd_lr_at(cfg: SGDConfig, step: jax.Array) -> jax.Array:
  epoch = step // max(cfg.steps_per_epoch, 1)
  lr = jnp.asarray(cfg.lr, jnp.float32)
  for d in cfg.drops:
    lr = jnp.where(epoch >= d, lr * cfg.drop_factor, lr)
  return lr


def sgd_update(cfg: SGDConfig, params: Params, grads: Params,
               state: Dict) -> Tuple[Params, Dict, Dict]:
  step = state["step"] + 1
  lr = sgd_lr_at(cfg, step)

  def upd(p, g, mom):
    g = g.astype(jnp.float32) + cfg.weight_decay * p.astype(jnp.float32)
    mom = cfg.momentum * mom + g
    d = g + cfg.momentum * mom if cfg.nesterov else mom
    return (p.astype(jnp.float32) - lr * d).astype(p.dtype), mom

  flat_p, tdef = jax.tree_util.tree_flatten(params)
  flat_g = tdef.flatten_up_to(grads)
  flat_m = tdef.flatten_up_to(state["mom"])
  out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
  return (tdef.unflatten([o[0] for o in out]),
          {"step": step, "mom": tdef.unflatten([o[1] for o in out])},
          {"lr": lr})
