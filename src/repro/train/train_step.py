"""Train step assembly: QAT fake-quant hooks, microbatched gradient
accumulation, AdamW update — everything inside one jit so XLA overlaps the
backward collectives with compute.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.quant.policy import QuantPolicy, fake_quant_params
from repro.train import optimizer as opt_lib

Params = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
  optimizer: opt_lib.AdamWConfig = opt_lib.AdamWConfig()
  microbatches: int = 1          # gradient accumulation within the step
  remat: bool = True
  quant: QuantPolicy = QuantPolicy()
  # bf16 matmul weights (f32 Adam moments keep the accuracy); halves the
  # FSDP all-gather bytes and the parameter HBM footprint (§Perf iter 2)
  param_dtype: str = "float32"   # float32 | bfloat16


def make_train_state(model: Model, tcfg: TrainConfig, key) -> Dict:
  params = model.init(key)
  if tcfg.param_dtype == "bfloat16":
    params = jax.tree_util.tree_map(
        lambda p: p.astype(jnp.bfloat16)
        if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)
  return {"params": params,
          "opt": opt_lib.adamw_init(tcfg.optimizer, params)}


def _split_microbatches(batch: Dict, n: int) -> Dict:
  def split(x):
    b = x.shape[0]
    assert b % n == 0, (b, n)
    return x.reshape(n, b // n, *x.shape[1:])
  return jax.tree_util.tree_map(split, batch)


def loss_fn(model: Model, tcfg: TrainConfig, params: Params,
            batch: Dict) -> Tuple[jax.Array, Dict]:
  q_params = fake_quant_params(params, tcfg.quant)
  return model.train_loss(q_params, batch, remat=tcfg.remat)


def train_step(model: Model, tcfg: TrainConfig, state: Dict,
               batch: Dict) -> Tuple[Dict, Dict]:
  """One optimizer step (with optional microbatch accumulation)."""
  params = state["params"]
  grad_fn = jax.value_and_grad(
      functools.partial(loss_fn, model, tcfg), has_aux=True)

  if tcfg.microbatches <= 1:
    (loss, metrics), grads = grad_fn(params, batch)
  else:
    mb = _split_microbatches(batch, tcfg.microbatches)

    def acc_step(carry, microbatch):
      g_acc, loss_acc = carry
      (loss, _), g = grad_fn(params, microbatch)
      g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
      return (g_acc, loss_acc + loss), None

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (grads, loss_sum), _ = jax.lax.scan(acc_step, (zeros, 0.0), mb)
    inv = 1.0 / tcfg.microbatches
    grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
    loss = loss_sum * inv
    metrics = {}

  new_params, new_opt, opt_metrics = opt_lib.adamw_update(
      tcfg.optimizer, params, grads, state["opt"])
  metrics = {**metrics, **opt_metrics, "loss": loss}
  return {"params": new_params, "opt": new_opt}, metrics


def jit_train_step(model: Model, tcfg: TrainConfig,
                   donate: bool = True) -> Callable:
  step = functools.partial(train_step, model, tcfg)
  return jax.jit(step, donate_argnums=(0,) if donate else ())
