"""Trainer loop: data pipeline + jitted step + checkpointing + telemetry.

Wires the fault-tolerance substrate together: every step is timed into the
StragglerMonitor, checkpoints are atomic + pruned, the data cursor is
checkpointed so restarts are exactly resumable, and a retry wrapper guards
against transient step failures.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import DataCursor
from repro.models.model import Model
from repro.train import checkpoint as ckpt_lib
from repro.train import train_step as ts_lib
from repro.train.fault_tolerance import StragglerMonitor, retrying


@dataclasses.dataclass
class TrainerConfig:
  total_steps: int = 100
  log_every: int = 10
  ckpt_every: int = 50
  ckpt_dir: str = "/tmp/repro_ckpt"
  keep_ckpts: int = 3
  host_name: str = "host0"
  max_step_retries: int = 1


class Trainer:
  def __init__(self, model: Model, tcfg: ts_lib.TrainConfig,
               trainer_cfg: TrainerConfig,
               batches: Iterator[Dict[str, np.ndarray]],
               cursor: Optional[DataCursor] = None,
               key: Optional[jax.Array] = None):
    self.model = model
    self.tcfg = tcfg
    self.cfg = trainer_cfg
    self.batches = batches
    self.cursor = cursor or DataCursor()
    self.monitor = StragglerMonitor()
    self.history: List[Dict[str, float]] = []
    key = key if key is not None else jax.random.PRNGKey(0)
    self.state = ts_lib.make_train_state(model, tcfg, key)
    self._step_fn = retrying(ts_lib.jit_train_step(model, tcfg),
                             max_retries=trainer_cfg.max_step_retries)
    self.step = 0

  # -- checkpoint integration --------------------------------------------
  def maybe_restore(self) -> bool:
    steps = ckpt_lib.list_checkpoints(self.cfg.ckpt_dir)
    if not steps:
      return False
    step, state, extra = ckpt_lib.restore_checkpoint(self.cfg.ckpt_dir)
    self.state = jax.tree_util.tree_map(jnp.asarray, state)
    self.step = step
    self.cursor.step = extra.get("data_step", step)
    return True

  def save(self):
    ckpt_lib.save_checkpoint(
        self.cfg.ckpt_dir, self.step, self.state,
        extra={"data_step": self.cursor.step}, keep=self.cfg.keep_ckpts)

  # -- the loop ------------------------------------------------------------
  def run(self, steps: Optional[int] = None) -> List[Dict[str, float]]:
    steps = steps if steps is not None else self.cfg.total_steps
    for _ in range(steps):
      batch = next(self.batches)
      batch = {k: jnp.asarray(v) for k, v in batch.items()}
      t0 = time.perf_counter()
      self.state, metrics = self._step_fn(self.state, batch)
      loss = float(metrics["loss"])
      dt = time.perf_counter() - t0
      self.monitor.record(self.cfg.host_name, dt)
      self.step += 1
      rec = {"step": self.step, "loss": loss, "sec": dt,
             "lr": float(metrics.get("lr", 0.0))}
      self.history.append(rec)
      if self.step % self.cfg.log_every == 0:
        print(f"step {self.step:5d} loss {loss:.4f} "
              f"({dt*1e3:.0f} ms)", flush=True)
      if self.cfg.ckpt_every and self.step % self.cfg.ckpt_every == 0:
        self.save()
    return self.history
