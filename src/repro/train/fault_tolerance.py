"""Fault tolerance for 1000+ node runs.

Three mechanisms:

1. `StragglerMonitor` — per-host step-time telemetry with EWMA + robust
   z-score detection; the policy hook decides (log / exclude-host /
   checkpoint-and-rescale).  At pod scale this feeds the cluster manager;
   here it is driven by the trainer loop and fully unit-tested.

2. `ElasticMeshPlanner` — given a degraded healthy-device count, picks the
   best (data, model) re-factorization (keeps TP degree if possible,
   shrinks DP; global batch held by raising grad-accumulation), producing
   a plan the launcher uses to re-mesh and reshard from the latest
   checkpoint (restore_checkpoint already reshards to arbitrary meshes).

3. `retrying` — wraps the jitted step so transient device errors trigger
   bounded retries, then a checkpoint-restore escalation.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# straggler detection
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HostStats:
  ewma: float = 0.0
  var: float = 0.0
  count: int = 0


class StragglerMonitor:
  """EWMA-based straggler detection over per-host step durations."""

  def __init__(self, alpha: float = 0.2, z_threshold: float = 3.0,
               min_samples: int = 5):
    self.alpha = alpha
    self.z = z_threshold
    self.min_samples = min_samples
    self.hosts: Dict[str, HostStats] = {}

  def record(self, host: str, step_seconds: float) -> None:
    st = self.hosts.setdefault(host, HostStats())
    if st.count == 0:
      st.ewma = step_seconds
    delta = step_seconds - st.ewma
    st.ewma += self.alpha * delta
    st.var = (1 - self.alpha) * (st.var + self.alpha * delta * delta)
    st.count += 1

  def fleet_median(self) -> float:
    vals = sorted(s.ewma for s in self.hosts.values() if s.count)
    return vals[len(vals) // 2] if vals else 0.0

  def stragglers(self) -> List[str]:
    """Hosts whose EWMA step time exceeds fleet median by z * fleet std."""
    med = self.fleet_median()
    if med <= 0:
      return []
    devs = [abs(s.ewma - med) for s in self.hosts.values()
            if s.count >= self.min_samples]
    if not devs:
      return []
    mad = sorted(devs)[len(devs) // 2] or 1e-9
    out = []
    for h, s in self.hosts.items():
      if s.count >= self.min_samples and (s.ewma - med) / (1.4826 * mad) \
          > self.z:
        out.append(h)
    return sorted(out)


# ---------------------------------------------------------------------------
# elastic re-meshing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshPlan:
  data: int
  model: int
  pods: int
  microbatch_scale: int   # grad-accum multiplier to keep the global batch

  @property
  def devices(self) -> int:
    return self.pods * self.data * self.model


class ElasticMeshPlanner:
  """Re-factorize the mesh after failures.

  Policy: keep the TP ("model") degree — param shardings stay valid and
  TP degree is capacity-critical — shrink DP to the largest size that fits
  the healthy-device count, and scale gradient accumulation so the global
  batch is unchanged.
  """

  def __init__(self, model_parallel: int, global_batch: int,
               batch_per_dp: int):
    self.model_parallel = model_parallel
    self.global_batch = global_batch
    self.batch_per_dp = batch_per_dp

  def plan(self, healthy_devices: int,
           pods: int = 1) -> Optional[MeshPlan]:
    per_pod = healthy_devices // pods
    dp = per_pod // self.model_parallel
    if dp < 1:
      return None
    # DP must divide the per-step batch; shrink to a divisor
    while dp > 1 and (self.global_batch % (dp * pods)) != 0:
      dp -= 1
    orig_dp = self.global_batch // self.batch_per_dp
    scale = max(1, int(math.ceil(orig_dp / (dp * pods))))
    return MeshPlan(data=dp, model=self.model_parallel, pods=pods,
                    microbatch_scale=scale)


# ---------------------------------------------------------------------------
# retry wrapper
# ---------------------------------------------------------------------------

class StepFailure(RuntimeError):
  pass


def retrying(step_fn: Callable, max_retries: int = 2,
             on_failure: Optional[Callable[[int, Exception], None]] = None,
             retry_exceptions: Tuple = (RuntimeError,),
             sleep: Callable[[float], None] = time.sleep,
             base_delay: float = 0.01, backoff: float = 2.0) -> Callable:
  """Wrap a step function with bounded retries on transient errors.

  The single retry primitive for both trainer steps and sweep chunks
  (:mod:`repro.explore.resilience` builds its ``RetryPolicy`` on it).
  ``sleep`` is injectable so unit tests never wall-wait; the delay before
  retry ``attempt`` is ``base_delay * backoff**attempt``, and no sleep
  happens after the final attempt (there is nothing left to wait for).
  """

  def wrapped(*args, **kwargs):
    last: Optional[Exception] = None
    for attempt in range(max_retries + 1):
      try:
        return step_fn(*args, **kwargs)
      except retry_exceptions as e:  # pragma: no cover - exercised in tests
        last = e
        if on_failure:
          on_failure(attempt, e)
        if attempt < max_retries:
          sleep(base_delay * (backoff ** attempt))
    raise StepFailure(
        f"step failed after {max_retries + 1} attempts") from last

  return wrapped
