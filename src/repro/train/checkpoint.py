"""Checkpointing: atomic, resumable, keep-last-k, async-capable.

Format: one .npz per checkpoint holding every leaf (flattened paths) +
a JSON manifest (step, rng, data cursor, tree structure). Writes go to a
temp file + os.replace for atomicity (a crash mid-write never corrupts
the latest checkpoint — the fault-tolerance contract).
"""
from __future__ import annotations

import json
import os
import re
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


def _flatten(tree, path=()):
  if isinstance(tree, dict):
    out = {}
    for k, v in tree.items():
      out.update(_flatten(v, path + (str(k),)))
    return out
  return {"/".join(path): tree}


def _unflatten(flat: Dict[str, Any]):
  root: Dict[str, Any] = {}
  for path, leaf in flat.items():
    parts = path.split("/")
    node = root
    for p in parts[:-1]:
      node = node.setdefault(p, {})
    node[parts[-1]] = leaf
  return root


def save_checkpoint(ckpt_dir: str, step: int, state: Params,
                    extra: Optional[Dict] = None, keep: int = 3,
                    background: bool = False) -> str:
  """Atomically write checkpoint `step`; prune to the newest `keep`."""
  os.makedirs(ckpt_dir, exist_ok=True)
  flat = _flatten(state)
  host = {k: np.asarray(v) for k, v in flat.items()}

  def write():
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
      np.savez(f, **host)
    os.replace(tmp, path)
    manifest = {"step": step, "extra": extra or {},
                "leaves": sorted(host.keys())}
    mpath = os.path.join(ckpt_dir, f"ckpt_{step:08d}.json")
    with open(mpath + ".tmp", "w") as f:
      json.dump(manifest, f)
    os.replace(mpath + ".tmp", mpath)
    _prune(ckpt_dir, keep)

  if background:
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
  write()
  return os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")


def _prune(ckpt_dir: str, keep: int):
  steps = list_checkpoints(ckpt_dir)
  for s in steps[:-keep] if keep else []:
    for ext in (".npz", ".json"):
      p = os.path.join(ckpt_dir, f"ckpt_{s:08d}{ext}")
      if os.path.exists(p):
        os.remove(p)


def list_checkpoints(ckpt_dir: str) -> List[int]:
  if not os.path.isdir(ckpt_dir):
    return []
  out = []
  for name in os.listdir(ckpt_dir):
    m = re.match(r"ckpt_(\d+)\.npz$", name)
    if m:
      # only count checkpoints whose manifest exists (fully committed)
      if os.path.exists(os.path.join(ckpt_dir,
                                     f"ckpt_{int(m.group(1)):08d}.json")):
        out.append(int(m.group(1)))
  return sorted(out)


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None,
                       shardings: Optional[Params] = None
                       ) -> Tuple[int, Params, Dict]:
  """Restore the latest (or given) checkpoint; optionally device_put with
  the provided sharding tree (elastic restarts reshard here)."""
  steps = list_checkpoints(ckpt_dir)
  if not steps:
    raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
  step = step if step is not None else steps[-1]
  with np.load(os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")) as data:
    flat = {k: data[k] for k in data.files}
  with open(os.path.join(ckpt_dir, f"ckpt_{step:08d}.json")) as f:
    manifest = json.load(f)
  state = _unflatten(flat)
  if shardings is not None:
    flat_sh = _flatten(shardings)
    state = _unflatten({
        k: jax.device_put(v, flat_sh[k]) if k in flat_sh else jnp.asarray(v)
        for k, v in flat.items()})
  return step, state, manifest.get("extra", {})
