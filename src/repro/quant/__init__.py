"""Framework-level quantization policies (QAT + deploy codecs)."""
from repro.quant.policy import QuantPolicy, fake_quant_params, pack_params
