"""Framework-level quantization policy: QUIDAM's PE-type axis applied to
any model in the zoo.

QAT path: `fake_quant_params` rewrites weight leaves with straight-through
fake quantization matching a PE type (FP32 / INT16 / INT8 / INT4 /
LightPE-1 / LightPE-2) — model code is untouched; the policy operates on
the parameter pytree by path pattern.

Deploy path: `pack_params` converts matmul weights to the packed HBM
codecs consumed by kernels/pow2_matmul and kernels/int8_matmul.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant

Params = Any

# Param-path patterns considered "matmul weights" (quantizable). Norms,
# biases, embeddings-by-default, scalars stay full precision.
_DEFAULT_PATTERNS = (
    r".*/(wq|wkv|wo|wi|wg|wr|wk|wv|cm_wk|cm_wv|cm_wr|in_proj|out_proj|"
    r"x_proj|dt_proj)$",
)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
  pe_type: str = "FP32"            # no-op default
  quantize_embeddings: bool = False
  act_quant: bool = False          # 8/16-bit activation fake-quant
  patterns: Tuple[str, ...] = _DEFAULT_PATTERNS

  @property
  def enabled(self) -> bool:
    return self.pe_type != "FP32"


def _matches(path: str, policy: QuantPolicy) -> bool:
  for pat in policy.patterns:
    if re.match(pat, path):
      return True
  if policy.quantize_embeddings and path.endswith("embed"):
    return True
  return False


def _walk(params, fn, path=()):
  if isinstance(params, dict):
    return {k: _walk(v, fn, path + (str(k),)) for k, v in params.items()}
  return fn("/".join(path), params)


def fake_quant_params(params: Params, policy: QuantPolicy) -> Params:
  """QAT: replace weight leaves with fake-quantized versions (STE grads)."""
  if not policy.enabled:
    return params

  def maybe_q(path, leaf):
    if leaf.ndim < 2 or not _matches(path, policy):
      return leaf
    # stacked block leaves: (layers, ..., d_in, d_out) -> channel axis -1
    return quant.fake_quant_for_pe(leaf, policy.pe_type, channel_axis=-1)

  return _walk(params, maybe_q)


def deploy_bytes_per_param(pe_type: str) -> float:
  """HBM bytes per weight under each deploy codec."""
  return {"FP32": 4.0, "INT16": 2.0, "INT8": 1.0, "INT4": 0.5,
          "LightPE-1": 0.5, "LightPE-2": 1.0}[pe_type]


def pack_params(params: Params, policy: QuantPolicy) -> Params:
  """Deploy: convert matmul weights to packed codecs (serving path).

  LightPE-1/INT4 -> packed nibbles; LightPE-2/INT8 -> uint8/int8 codes.
  Returns a tree where quantized leaves become {"codes", "scale", "fmt"}.
  """
  if not policy.enabled:
    return params

  def pack(path, leaf):
    if leaf.ndim < 2 or not _matches(path, policy):
      return leaf
    w2 = leaf.reshape(-1, leaf.shape[-1]) if leaf.ndim > 2 else leaf
    if policy.pe_type in ("LightPE-1", "LightPE-2"):
      k = 1 if policy.pe_type == "LightPE-1" else 2
      q = quant.pow2_quantize(w2, k=k, channel_axis=1)
      codes = quant.pack_nibbles(q.codes) if k == 1 else q.codes
      return {"codes": codes, "scale": q.scale, "fmt": f"pow2_{k}",
              "shape": leaf.shape}
    bits = {"INT16": 16, "INT8": 8, "INT4": 4}[policy.pe_type]
    q = quant.int_quantize(w2, bits=bits, channel_axis=1)
    codes = quant.pack_int4(q.codes) if bits == 4 else q.codes
    return {"codes": codes, "scale": q.scale, "fmt": f"int{bits}",
            "shape": leaf.shape}

  return _walk(params, pack)
