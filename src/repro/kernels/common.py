"""Shared helpers for the Pallas TPU kernels.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with ``interpret=True`` against pure-jnp oracles
(``ref.py`` next to each kernel).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# MXU-aligned default tile sizes (multiples of 128 on the matmul dims).
BM, BN, BK = 128, 128, 128


@functools.cache
def default_interpret() -> bool:
  """Interpret Pallas kernels unless running on a real TPU."""
  return jax.default_backend() != "tpu"


def pad_to(x: jax.Array, axis: int, multiple: int,
           value: float = 0.0) -> Tuple[jax.Array, int]:
  """Pad `axis` up to a multiple; returns (padded, original_size)."""
  size = x.shape[axis]
  target = -(-size // multiple) * multiple
  if target == size:
    return x, size
  pads = [(0, 0)] * x.ndim
  pads[axis] = (0, target - size)
  return jnp.pad(x, pads, constant_values=value), size


def cdiv(a: int, b: int) -> int:
  return -(-a // b)
