"""Jitted public wrapper for the W8A8 int8 matmul kernel."""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels import common
from repro.kernels.int8_matmul.kernel import int8_matmul_pallas
from repro.kernels.int8_matmul.ref import int8_matmul_ref


@dataclasses.dataclass(frozen=True)
class Int8Weights:
  codes: jax.Array   # int8 (K, N)
  scale: jax.Array   # f32 (N,) per output channel
  k: int
  n: int

  def tree_flatten(self):
    return (self.codes, self.scale), (self.k, self.n)

  @classmethod
  def tree_unflatten(cls, aux, leaves):
    return cls(leaves[0], leaves[1], *aux)

  @property
  def hbm_bytes(self) -> int:
    return self.codes.size + 4 * self.scale.size


jax.tree_util.register_pytree_node(
    Int8Weights, Int8Weights.tree_flatten, Int8Weights.tree_unflatten)


def quantize_weights(w: jax.Array) -> Int8Weights:
  q = quant.int_quantize(w, bits=8, channel_axis=1)
  return Int8Weights(q.codes, q.scale.reshape(-1), w.shape[0], w.shape[1])


def quantize_activations(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
  """Dynamic per-row symmetric int8 activation quantization."""
  absmax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-12)
  scale = absmax / 127.0
  codes = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
  return codes, scale.reshape(*x.shape[:-1])


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_matmul(x: jax.Array, weights: Int8Weights,
                interpret: Optional[bool] = None) -> jax.Array:
  """(..., K) f32/bf16 @ int8 (K, N): dynamic act quant + Pallas kernel."""
  if interpret is None:
    interpret = common.default_interpret()
  lead = x.shape[:-1]
  x2 = x.reshape(-1, x.shape[-1])
  xq, xs = quantize_activations(x2)
  xq, m0 = common.pad_to(xq, 0, common.BM)
  xq, _ = common.pad_to(xq, 1, common.BK)
  xs, _ = common.pad_to(xs.reshape(-1), 0, common.BM)
  wq, _ = common.pad_to(weights.codes, 0, common.BK)
  wq, _ = common.pad_to(wq, 1, common.BN)
  ws, _ = common.pad_to(weights.scale, 0, common.BN)
  out = int8_matmul_pallas(xq, wq, xs, ws, interpret=interpret)
  return out[:m0, :weights.n].reshape(*lead, weights.n)


def int8_matmul_reference(x: jax.Array, weights: Int8Weights) -> jax.Array:
  lead = x.shape[:-1]
  x2 = x.reshape(-1, x.shape[-1])
  xq, xs = quantize_activations(x2)
  out = int8_matmul_ref(xq, weights.codes, xs.reshape(-1), weights.scale)
  return out.reshape(*lead, weights.n)
