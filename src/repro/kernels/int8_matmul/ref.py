"""Pure-jnp oracle for the W8A8 int8 matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_matmul_ref(x: jax.Array, w: jax.Array, x_scale: jax.Array,
                    w_scale: jax.Array) -> jax.Array:
  acc = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.int32)
  return acc.astype(jnp.float32) * x_scale.reshape(-1, 1) \
      * w_scale.reshape(1, -1)
