"""Pallas TPU kernel: W8A8 int8 matmul with int32 accumulation.

QUIDAM's INT8/INT16 PE types map to TPU as quantized GEMMs: int8 weights
AND int8 activations in HBM/VMEM, int32 accumulation (the MXU supports
int8 x int8 -> int32 natively), dequantized in the epilogue with
per-row activation scales x per-column weight scales.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import BK, BM, BN


def _int8_matmul_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *,
                        n_k_steps: int):
  """Grid (M/BM, N/BN, K/BK); int32 accumulator scratch in VMEM."""
  kstep = pl.program_id(2)

  @pl.when(kstep == 0)
  def _init():
    acc_ref[...] = jnp.zeros_like(acc_ref)

  acc_ref[...] += jax.lax.dot_general(
      x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
      preferred_element_type=jnp.int32)

  @pl.when(kstep == n_k_steps - 1)
  def _finalize():
    xs = xs_ref[...].astype(jnp.float32)   # (bm, 1) per-row act scale
    ws = ws_ref[...].astype(jnp.float32)   # (1, bn) per-col weight scale
    o_ref[...] = acc_ref[...].astype(jnp.float32) * xs * ws


def int8_matmul_pallas(x: jax.Array, w: jax.Array, x_scale: jax.Array,
                       w_scale: jax.Array, interpret: bool = True,
                       bm: int = BM, bn: int = BN, bk: int = BK) -> jax.Array:
  """int8 (M,K) @ int8 (K,N) -> f32 (M,N), scales applied in the epilogue."""
  m, kdim = x.shape
  _, n = w.shape
  assert m % bm == 0 and n % bn == 0 and kdim % bk == 0
  n_k_steps = kdim // bk
  kern = functools.partial(_int8_matmul_kernel, n_k_steps=n_k_steps)
  from jax.experimental.pallas import tpu as pltpu
  return pl.pallas_call(
      kern,
      grid=(m // bm, n // bn, n_k_steps),
      in_specs=[
          pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
          pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
          pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
          pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
      ],
      out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
      out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
      scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
      interpret=interpret,
  )(x, w, x_scale.reshape(-1, 1), w_scale.reshape(1, -1))
