from repro.kernels.pareto_front.ops import (block_prefilter_mask,
                                            dominance_counts,
                                            pareto_front_mask)

__all__ = ["dominance_counts", "pareto_front_mask", "block_prefilter_mask"]
