"""Pure-jnp oracle for the Pareto dominance-count kernel.

All objectives are MINIMIZED.  Point ``j`` dominates point ``i`` iff
``obj[j] <= obj[i]`` on every axis and ``obj[j] < obj[i]`` on at least
one — the exact predicate of ``repro.explore.frame.pareto_mask`` (ties /
duplicates dominate nobody, so duplicated front points all survive).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dominance_counts_ref(obj: jax.Array) -> jax.Array:
  """(N, D) objectives -> (N,) int32: how many points dominate each row."""
  le = jnp.all(obj[None, :, :] <= obj[:, None, :], axis=-1)  # [i, j]: j<=i
  lt = jnp.any(obj[None, :, :] < obj[:, None, :], axis=-1)   # [i, j]: j<i
  return (le & lt).sum(axis=1).astype(jnp.int32)


def pareto_mask_ref(obj: jax.Array) -> jax.Array:
  """(N,) bool: rows no other row dominates (the exact front)."""
  return dominance_counts_ref(obj) == 0


def block_dominance_counts_ref(obj: jax.Array, block: int) -> jax.Array:
  """Per-block dominance counts: dominators are only sought within each
  row's own ``block``-sized slab (N must divide evenly; ops.py pads).
  ``counts == 0`` is the block-decomposed front *superset*: every global
  front point survives its own block."""
  n, d = obj.shape
  blocks = obj.reshape(n // block, block, d)
  return jax.vmap(dominance_counts_ref)(blocks).reshape(-1)
