"""Pallas TPU kernel: pairwise Pareto dominance counts.

The streaming sweep engine's device-resident reducer needs, per evaluated
chunk, the set of non-dominated candidates so only O(survivors) rows ever
cross the device boundary (repro.explore.device).  The primitive behind
both its prefilter and its exact candidate merge is a pairwise dominance
count: for each point, how many others dominate it (0 == on the front).

Objectives are carried **feature-major** — ``(D, N)`` with the point axis
last — so the point axis lands on the 128-wide lane dimension of the VPU
tiles (D is 2-4: a (N, D) layout would waste the whole lane dimension).
The kernel walks a 2-D grid of (BI, BJ) tile pairs; each step loads one
``(D, BI)`` "row" tile and one ``(D, BJ)`` "col" tile, evaluates the
dominance predicate with a static loop over D (bool (BI, BJ) masks, no
3-D broadcast), and accumulates counts into the (1, BI) output tile over
the j axis of the grid.

Comparisons run in the input dtype: dominance is an *exact* predicate, so
callers must pass objectives at the precision they need (the x64 streaming
path hands f64; downcasting could merge distinct values and eliminate a
true front point).

``_block`` mode restricts dominators to each point's own tile — the
block-decomposed front prefilter of ``repro.explore.frame._pareto_mask_nd``
(every global front point survives its own block), one grid step per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# tile sizes: lanes are 128 wide; 256 keeps the (BI, BJ) bool mask small
# while amortizing grid overhead
BI = 256
BJ = 256


def _dominance_tile(x, y, d: int):
  """counts[i] over one tile pair: x (d, bi) rows, y (d, bj) columns.
  dominates[i, j] == all_d(y[d, j] <= x[d, i]) & any_d(y[d, j] < x[d, i])."""
  le = None
  lt = None
  for k in range(d):
    xi = x[k][:, None]   # (bi, 1)
    yj = y[k][None, :]   # (1, bj)
    le_k = yj <= xi
    lt_k = yj < xi
    le = le_k if le is None else le & le_k
    lt = lt_k if lt is None else lt | lt_k
  return (le & lt).sum(axis=1, dtype=jnp.int32)


def _pairwise_kernel(x_ref, y_ref, o_ref, *, d: int, n_j_steps: int):
  """Grid (N/BI, N/BJ): accumulate dominator counts over the j axis."""
  jstep = pl.program_id(1)

  @pl.when(jstep == 0)
  def _init():
    o_ref[...] = jnp.zeros_like(o_ref)

  counts = _dominance_tile(x_ref[...], y_ref[...], d)
  o_ref[...] += counts[None, :]
  del n_j_steps


def _block_kernel(x_ref, o_ref, *, d: int):
  """Grid (N/BI,): dominators sought within each point's own tile only."""
  x = x_ref[...]
  o_ref[...] = _dominance_tile(x, x, d)[None, :]


def dominance_counts_pallas(obj_t: jax.Array, interpret: bool = True,
                            bi: int = BI, bj: int = BJ) -> jax.Array:
  """obj_t (D, N) feature-major objectives -> (N,) int32 global dominance
  counts.  N must be pre-padded to a multiple of lcm(bi, bj) with +inf
  points (ops.py handles padding; +inf rows dominate nothing)."""
  d, n = obj_t.shape
  assert n % bi == 0 and n % bj == 0, (n, bi, bj)
  kern = functools.partial(_pairwise_kernel, d=d, n_j_steps=n // bj)
  out = pl.pallas_call(
      kern,
      grid=(n // bi, n // bj),
      in_specs=[
          pl.BlockSpec((d, bi), lambda i, j: (0, i)),
          pl.BlockSpec((d, bj), lambda i, j: (0, j)),
      ],
      out_specs=pl.BlockSpec((1, bi), lambda i, j: (0, i)),
      out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
      interpret=interpret,
  )(obj_t, obj_t)
  return out[0]


def block_dominance_counts_pallas(obj_t: jax.Array, interpret: bool = True,
                                  block: int = BI) -> jax.Array:
  """obj_t (D, N) -> (N,) int32 within-block dominance counts (the
  prefilter mode: one tile pair per grid step, never O(N^2))."""
  d, n = obj_t.shape
  assert n % block == 0, (n, block)
  kern = functools.partial(_block_kernel, d=d)
  out = pl.pallas_call(
      kern,
      grid=(n // block,),
      in_specs=[pl.BlockSpec((d, block), lambda i: (0, i))],
      out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
      out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
      interpret=interpret,
  )(obj_t)
  return out[0]
