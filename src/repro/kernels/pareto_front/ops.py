"""Public wrappers for the Pareto dominance kernels.

Two interchangeable backends behind one API:

  * a pure-jnp port of the block-decomposed N-D front machinery of
    ``repro.explore.frame._pareto_mask_nd`` (vmapped per-block dominance,
    no Python-level elimination loop) — what the fused device reducer
    runs on CPU/GPU backends;
  * the Pallas TPU kernel (``kernel.py``), exercised in interpret mode on
    CPU by the tier-1 tests and compiled on real TPU backends.

All objectives are MINIMIZED; callers negate maximize columns first (the
convention of ``repro.explore.frame.pareto_mask``).  Comparisons run in
the input dtype — pass f64 when the caller needs exact f64 dominance.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.pareto_front import kernel as _kernel
from repro.kernels.pareto_front.ref import dominance_counts_ref


def _pad_feature_major(obj: jax.Array, multiple: int) -> jax.Array:
  """(N, D) -> (D, N_padded) with +inf pad points (dominate nothing and
  are dominated by every real point, so real counts are unchanged)."""
  n = obj.shape[0]
  pad = (-n) % multiple
  obj_t = obj.T
  if pad:
    obj_t = jnp.concatenate(
        [obj_t, jnp.full((obj.shape[1], pad), jnp.inf, obj.dtype)], axis=1)
  return obj_t


def dominance_counts(obj: jax.Array, interpret: Optional[bool] = None,
                     use_pallas: bool = True) -> jax.Array:
  """(N, D) -> (N,) int32 global dominance counts (0 == on the front)."""
  if interpret is None:
    interpret = common.default_interpret()
  n = obj.shape[0]
  if not use_pallas:
    return dominance_counts_ref(obj)
  obj_t = _pad_feature_major(obj, max(_kernel.BI, _kernel.BJ))
  return _kernel.dominance_counts_pallas(obj_t, interpret=interpret)[:n]


def pareto_front_mask(obj: jax.Array, interpret: Optional[bool] = None,
                      use_pallas: bool = True) -> jax.Array:
  """(N,) bool exact non-dominated mask via pairwise dominance counts.

  O(N^2) compares: meant for candidate sets that already passed
  :func:`block_prefilter_mask`, not raw million-row sweeps.
  """
  return dominance_counts(obj, interpret=interpret,
                          use_pallas=use_pallas) == 0


def _block_survivor_mask_jnp(obj: jax.Array, block: int) -> jax.Array:
  """vmapped within-block non-dominated mask ((N,) bool; N % block == 0).

  The jax port of ``_pareto_mask_nd``'s block decomposition: the static
  loop over D keeps the compare masks 2-D ((block, block) bools), and
  vmap over blocks replaces the Python block loop.
  """
  n, d = obj.shape
  o = obj.reshape(n // block, block, d)

  def blk(b):
    le = None
    lt = None
    for k in range(d):
      col = b[:, k]
      le_k = col[None, :] <= col[:, None]
      lt_k = col[None, :] < col[:, None]
      le = le_k if le is None else le & le_k
      lt = lt_k if lt is None else lt | lt_k
    return ~(le & lt).any(axis=1)

  return jax.vmap(blk)(o).reshape(-1)


def block_prefilter_mask(obj: jax.Array, block: int = 128,
                         interpret: Optional[bool] = None,
                         use_pallas: bool = False) -> jax.Array:
  """(N,) bool block-decomposed front *superset* mask.

  Every global front point is non-dominated within its own block, and
  every dominated point is dominated by some front point (transitivity),
  so the union of per-block fronts is an exact superset of the global
  front — the same argument ``_pareto_mask_nd`` and the streaming
  ParetoAccumulator rest on.  Cost is O(N * block), never O(N^2).
  """
  n = obj.shape[0]
  if n == 0:
    return jnp.zeros(0, bool)
  if use_pallas:
    if interpret is None:
      interpret = common.default_interpret()
    obj_t = _pad_feature_major(obj, block)
    counts = _kernel.block_dominance_counts_pallas(obj_t, block=block,
                                                   interpret=interpret)
    return counts[:n] == 0
  pad = (-n) % block
  if pad:
    obj = jnp.concatenate(
        [obj, jnp.full((pad, obj.shape[1]), jnp.inf, obj.dtype)])
  return _block_survivor_mask_jnp(obj, block)[:n]
