"""Pallas TPU kernels for QUIDAM's quantization-aware compute paths.

Each kernel lives in its own subpackage with:
  kernel.py  pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py     jitted public wrapper (padding, packing, dispatch)
  ref.py     pure-jnp oracle used by the interpret-mode test sweeps
"""
