"""Pallas TPU kernel: chunked WKV6 (RWKV-6) recurrence.

The sequential per-token recurrence (see ref.py) is O(T) serial steps; on
TPU the chunked matmul form processes C tokens per step, turning the
recurrence into MXU-friendly (C, D) x (D, D) matmuls plus a stable
pairwise-decay score tensor:

  la_t   = cumsum(log w)                       (within-chunk log-decay)
  o_t    = (r_t * exp(la_{t-1})) @ S_in                           [state]
         + sum_{j<t} (sum_d r_t k_j exp(la_{t-1} - la_j)) v_j     [intra]
         + (r_t . (u * k_t)) v_t                                  [bonus]
  S_out  = exp(la_last) * S_in (rows) + (k_j * exp(la_last - la_j))^T V

All exponents are differences of a monotone cumsum (<= 0), so every exp()
is in (0, 1] — numerically stable for arbitrary chunk lengths, unlike the
naive k / cumprod(w) form which underflows.

Grid: (B * H, T / C); the chunk axis is sequential, the running state lives
in a VMEM scratch (D x D f32) and is emitted as a second output on the last
chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sout_ref,
                 s_ref, *, n_chunks: int):
  cstep = pl.program_id(1)

  @pl.when(cstep == 0)
  def _init():
    s_ref[...] = s0_ref[0].astype(jnp.float32)

  r = r_ref[0].astype(jnp.float32)   # (C, D)
  k = k_ref[0].astype(jnp.float32)
  v = v_ref[0].astype(jnp.float32)
  w = w_ref[0].astype(jnp.float32)
  u = u_ref[0].astype(jnp.float32)   # (D,)
  s = s_ref[...]                     # (D, D)

  logw = jnp.log(jnp.maximum(w, 1e-30))
  la = jnp.cumsum(logw, axis=0)             # inclusive  (C, D)
  la_prev = la - logw                       # exclusive

  # state term: (r * exp(la_prev)) @ S
  rq = r * jnp.exp(la_prev)
  o = jnp.dot(rq, s, preferred_element_type=jnp.float32)

  # intra-chunk pairwise term, strictly causal
  cdim = r.shape[0]
  decay = jnp.exp(la_prev[:, None, :] - la[None, :, :])   # (C, C, D), <= 1
  scores = jnp.einsum("td,jd,tjd->tj", r, k, decay)
  mask = (jax.lax.broadcasted_iota(jnp.int32, (cdim, cdim), 0)
          > jax.lax.broadcasted_iota(jnp.int32, (cdim, cdim), 1))
  scores = jnp.where(mask, scores, 0.0)
  o += jnp.dot(scores, v, preferred_element_type=jnp.float32)

  # current-token bonus
  rd = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)  # (C, 1)
  o += rd * v
  o_ref[0] = o

  # state update
  la_last = la[-1]
  kd = k * jnp.exp(la_last[None, :] - la)                  # (C, D)
  s_ref[...] = jnp.exp(la_last)[:, None] * s + jnp.dot(
      kd.T, v, preferred_element_type=jnp.float32)

  @pl.when(cstep == n_chunks - 1)
  def _emit_state():
    sout_ref[0] = s_ref[...]


def wkv6_pallas(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                u: jax.Array, s0: jax.Array, interpret: bool = True,
                chunk: int = DEFAULT_CHUNK):
  """r/k/v/w (BH, T, D), u (BH, D), s0 (BH, D, D) -> (o, s_final)."""
  bh, t, d = r.shape
  assert t % chunk == 0, (t, chunk)
  n_chunks = t // chunk
  kern = functools.partial(_wkv6_kernel, n_chunks=n_chunks)
  return pl.pallas_call(
      kern,
      grid=(bh, n_chunks),
      in_specs=[
          pl.BlockSpec((1, chunk, d), lambda i, c: (i, c, 0)),
          pl.BlockSpec((1, chunk, d), lambda i, c: (i, c, 0)),
          pl.BlockSpec((1, chunk, d), lambda i, c: (i, c, 0)),
          pl.BlockSpec((1, chunk, d), lambda i, c: (i, c, 0)),
          pl.BlockSpec((1, d), lambda i, c: (i, 0)),
          pl.BlockSpec((1, d, d), lambda i, c: (i, 0, 0)),
      ],
      out_specs=[
          pl.BlockSpec((1, chunk, d), lambda i, c: (i, c, 0)),
          pl.BlockSpec((1, d, d), lambda i, c: (i, 0, 0)),
      ],
      out_shape=[
          jax.ShapeDtypeStruct((bh, t, d), jnp.float32),
          jax.ShapeDtypeStruct((bh, d, d), jnp.float32),
      ],
      scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
      interpret=interpret,
  )(r, k, v, w, u, s0)
