"""Jitted public wrapper for the chunked WKV6 kernel."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.rwkv6_scan.kernel import DEFAULT_CHUNK, wkv6_pallas
from repro.kernels.rwkv6_scan.ref import wkv6_ref


@functools.partial(jax.jit, static_argnames=("interpret", "chunk"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, s0: Optional[jax.Array] = None,
         interpret: Optional[bool] = None,
         chunk: int = DEFAULT_CHUNK) -> Tuple[jax.Array, jax.Array]:
  """WKV6 over (B, H, T, D) inputs; u (H, D); returns (out, final state).

  Pads T to the chunk size with identity tokens (w=1, k=v=0) which leave the
  state untouched.
  """
  if interpret is None:
    interpret = common.default_interpret()
  b, h, t, d = r.shape
  if s0 is None:
    s0 = jnp.zeros((b, h, d, d), jnp.float32)

  def flat(x):
    return x.reshape(b * h, t, d)

  rf, kf, vf, wf = flat(r), flat(k), flat(v), flat(w)
  pad = (-t) % chunk
  if pad:
    zeros = jnp.zeros((b * h, pad, d), rf.dtype)
    rf = jnp.concatenate([rf, zeros], axis=1)
    kf = jnp.concatenate([kf, zeros], axis=1)
    vf = jnp.concatenate([vf, zeros], axis=1)
    wf = jnp.concatenate([wf, jnp.ones((b * h, pad, d), wf.dtype)], axis=1)
  uf = jnp.broadcast_to(u[None, :, :], (b, h, d)).reshape(b * h, d)
  o, s_final = wkv6_pallas(rf, kf, vf, wf, uf,
                           s0.reshape(b * h, d, d),
                           interpret=interpret, chunk=chunk)
  return (o[:, :t, :].reshape(b, h, t, d),
          s_final.reshape(b, h, d, d))


def wkv6_reference(r, k, v, w, u, s0=None):
  b, h, t, d = r.shape
  if s0 is None:
    s0 = jnp.zeros((b, h, d, d), jnp.float32)
  return wkv6_ref(r, k, v, w, u, s0)


def wkv6_decode_step(rt, kt, vt, wt, u, state):
  """Single-token decode update (B, H, D) x state (B, H, D, D)."""
  at = kt[..., :, None] * vt[..., None, :]
  s_plus = state + u[None, :, :, None] * at
  ot = jnp.einsum("bhd,bhde->bhe", rt, s_plus)
  state = wt[..., :, None] * state + at
  return ot, state
