"""Pure-jnp oracle for the WKV6 recurrence (RWKV-6 "Finch").

Per head (state S in R^{D x D}, D = head dim; r, k, v, w per token):

    o_t = r_t @ (S_{t-1} + diag(u * k_t ... ) ...)    concretely:
    a_t = k_t^T v_t                      (outer product, D x D)
    o_t = r_t @ (S_{t-1} + diag(u) a_t)
    S_t = diag(w_t) S_{t-1} + a_t

with data-dependent per-channel decay w_t in (0, 1) and a learned per-head
"bonus" u for the current token.  This sequential scan is the correctness
oracle; the Pallas kernel computes the chunked matmul form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, s0: jax.Array) -> tuple[jax.Array, jax.Array]:
  """r/k/v/w: (B, H, T, D); u: (H, D); s0: (B, H, D, D) initial state.

  Returns (out (B, H, T, D), final state (B, H, D, D)).
  State convention: S[d_k, d_v]; o_t = sum_dk r[dk] * S_plus[dk, dv].
  """
  b, h, t, d = r.shape

  def step(S, inp):
    rt, kt, vt, wt = inp                      # (B, H, D) each
    at = kt[..., :, None] * vt[..., None, :]  # (B, H, D, D)
    s_plus = S + u[None, :, :, None] * at
    ot = jnp.einsum("bhd,bhde->bhe", rt, s_plus)
    S = wt[..., :, None] * S + at
    return S, ot

  xs = (jnp.moveaxis(r, 2, 0), jnp.moveaxis(k, 2, 0),
        jnp.moveaxis(v, 2, 0), jnp.moveaxis(w, 2, 0))
  s_final, outs = jax.lax.scan(step, s0.astype(jnp.float32), xs)
  return jnp.moveaxis(outs, 0, 2), s_final
