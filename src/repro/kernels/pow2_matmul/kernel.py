"""Pallas TPU kernel: matmul against sum-of-powers-of-two (LightPE) weights.

The paper's LightPE replaces the ASIC multiplier with shifters (Eq. 1).  On
TPU there is no per-lane shifter array — the MXU systolic array is the
compute unit — so the TPU-native adaptation keeps the *storage* win and
feeds the MXU:

  HBM:   packed exponent codes  (4 bit/weight for k=1, 8 bit for k=2)
         + one fp32 scale per output channel
  VMEM:  decode codes -> EXACT bf16/f32 values (+/- 2^-m [+ 2^-m'])
  MXU:   jnp.dot(x_tile, decoded_tile)

The matmul is tiled (BM, BK) x (BK, BN) with accumulation over the K grid
axis; weight bytes moved from HBM drop 4-8x vs bf16, which is the roofline
lever for the memory-bound decode shapes (see EXPERIMENTS.md §Perf).

Code formats (repro.core.quant):
  k=1: uint8 nibble pairs, little-nibble-first, value bits [s m m m]
  k=2: uint8, value bits [. s m1 m1 m1 m2 m2 m2]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import BK, BM, BN


def _decode_lp1_nibbles(packed: jax.Array) -> jax.Array:
  """(bk, bn//2) uint8 -> (bk, bn) f32 of +/- 2^-m (exact)."""
  lo = (packed & 0xF).astype(jnp.int32)
  hi = ((packed >> 4) & 0xF).astype(jnp.int32)
  both = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
  sign = jnp.where((both & 8) != 0, -1.0, 1.0)
  m = (both & 7).astype(jnp.float32)
  return sign * jnp.exp2(-m)


def _decode_lp2_bytes(codes: jax.Array) -> jax.Array:
  """(bk, bn) uint8 -> (bk, bn) f32 of +/- (2^-m1 + 2^-m2) (exact)."""
  c = codes.astype(jnp.int32)
  sign = jnp.where((c & 64) != 0, -1.0, 1.0)
  m1 = ((c >> 3) & 7).astype(jnp.float32)
  m2 = (c & 7).astype(jnp.float32)
  return sign * (jnp.exp2(-m1) + jnp.exp2(-m2))


def _pow2_matmul_kernel(x_ref, w_ref, scale_ref, o_ref, *, k_terms: int,
                        n_k_steps: int):
  """Grid (M/BM, N/BN, K/BK); accumulates over the K axis in f32."""
  kstep = pl.program_id(2)

  @pl.when(kstep == 0)
  def _init():
    o_ref[...] = jnp.zeros_like(o_ref)

  x = x_ref[...].astype(jnp.float32)
  if k_terms == 1:
    w = _decode_lp1_nibbles(w_ref[...])
  else:
    w = _decode_lp2_bytes(w_ref[...])
  acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
  o_ref[...] += acc

  @pl.when(kstep == n_k_steps - 1)
  def _finalize():
    o_ref[...] *= scale_ref[...].astype(jnp.float32)


def pow2_matmul_pallas(x: jax.Array, codes: jax.Array, scale: jax.Array,
                       k_terms: int, interpret: bool = True,
                       bm: int = BM, bn: int = BN, bk: int = BK) -> jax.Array:
  """x (M, K) @ decode(codes) (K, N) * scale (N,) -> (M, N) float32.

  codes: uint8, (K, N//2) for k_terms=1 (packed nibbles), (K, N) for k=2.
  Shapes must be pre-padded to tile multiples (ops.py handles padding).
  """
  m, kdim = x.shape
  n = scale.shape[0]
  assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (m, n, kdim)
  n_k_steps = kdim // bk
  code_cols = bn // 2 if k_terms == 1 else bn

  kern = functools.partial(_pow2_matmul_kernel, k_terms=k_terms,
                           n_k_steps=n_k_steps)
  return pl.pallas_call(
      kern,
      grid=(m // bm, n // bn, n_k_steps),
      in_specs=[
          pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
          pl.BlockSpec((bk, code_cols), lambda i, j, k: (k, j)),
          pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
      ],
      out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
      out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
      interpret=interpret,
  )(x, codes, scale.reshape(1, -1))
