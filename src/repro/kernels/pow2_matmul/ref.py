"""Pure-jnp oracle for the pow2 (LightPE) matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import pow2_decode_codes, unpack_nibbles


def decode_weights(codes: jax.Array, scale: jax.Array,
                   k_terms: int) -> jax.Array:
  """codes (packed for k=1) + per-output-channel scale -> f32 (K, N)."""
  if k_terms == 1:
    codes = unpack_nibbles(codes)
  vals = pow2_decode_codes(codes, k_terms)
  return vals * scale.reshape(1, -1)


def pow2_matmul_ref(x: jax.Array, codes: jax.Array, scale: jax.Array,
                    k_terms: int) -> jax.Array:
  w = decode_weights(codes, scale, k_terms)
  return jnp.dot(x.astype(jnp.float32), w)
