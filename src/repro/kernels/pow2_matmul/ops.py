"""Jitted public wrapper for the pow2 (LightPE) matmul kernel.

Handles quantization-to-codes, padding to MXU tiles, kernel dispatch, and
unpadding.  ``quantize_weights`` is the offline packing step (what a
checkpoint-conversion tool runs); ``pow2_matmul`` is the serving-time op.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels import common
from repro.kernels.pow2_matmul.kernel import pow2_matmul_pallas
from repro.kernels.pow2_matmul.ref import pow2_matmul_ref


@dataclasses.dataclass(frozen=True)
class Pow2Weights:
  """Packed LightPE weights: HBM-resident codes + per-channel scales."""
  codes: jax.Array   # uint8 (K, N//2) for k=1, (K, N) for k=2
  scale: jax.Array   # f32 (N,)
  k_terms: int
  k: int
  n: int

  def tree_flatten(self):
    return (self.codes, self.scale), (self.k_terms, self.k, self.n)

  @classmethod
  def tree_unflatten(cls, aux, leaves):
    return cls(leaves[0], leaves[1], *aux)

  @property
  def hbm_bytes(self) -> int:
    return self.codes.size + 4 * self.scale.size


jax.tree_util.register_pytree_node(
    Pow2Weights, Pow2Weights.tree_flatten, Pow2Weights.tree_unflatten)


def quantize_weights(w: jax.Array, k_terms: int = 1) -> Pow2Weights:
  """Quantize a dense (K, N) weight matrix to packed LightPE codes."""
  kdim, n = w.shape
  q = quant.pow2_quantize(w, k=k_terms, channel_axis=1)  # per-output-channel
  codes = q.codes
  if k_terms == 1:
    assert n % 2 == 0, "LightPE-1 packing needs even N"
    codes = quant.pack_nibbles(codes)
  return Pow2Weights(codes=codes, scale=q.scale.reshape(-1),
                     k_terms=k_terms, k=kdim, n=n)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pow2_matmul(x: jax.Array, weights: Pow2Weights,
                interpret: Optional[bool] = None) -> jax.Array:
  """(..., K) @ LightPE(K, N) -> (..., N) float32 via the Pallas kernel."""
  if interpret is None:
    interpret = common.default_interpret()
  lead = x.shape[:-1]
  x2 = x.reshape(-1, x.shape[-1])
  x2, m0 = common.pad_to(x2, 0, common.BM)
  x2, k0 = common.pad_to(x2, 1, common.BK)
  codes, _ = common.pad_to(weights.codes, 0, common.BK)
  pack = 2 if weights.k_terms == 1 else 1
  codes, _ = common.pad_to(codes, 1, common.BN // pack)
  scale, _ = common.pad_to(weights.scale, 0, common.BN)
  out = pow2_matmul_pallas(x2, codes, scale, weights.k_terms,
                           interpret=interpret)
  return out[:m0, :weights.n].reshape(*lead, weights.n)


def pow2_matmul_reference(x: jax.Array, weights: Pow2Weights) -> jax.Array:
  """Oracle path (unpadded, pure jnp)."""
  lead = x.shape[:-1]
  out = pow2_matmul_ref(x.reshape(-1, x.shape[-1]), weights.codes,
                        weights.scale, weights.k_terms)
  return out.reshape(*lead, weights.n)
