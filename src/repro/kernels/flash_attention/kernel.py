"""Pallas TPU kernel: causal / sliding-window flash attention (prefill).

Online-softmax attention tiled (BQ x BK) with running (m, l, acc) in VMEM
scratch; the kv-block axis is the minor (sequential) grid dim.  Blocks
fully outside the causal / sliding-window band are skipped with pl.when
(no MXU work), so causal attention does ~half the FLOPs and SWA touches
only the diagonal band — the same schedule the pure-JAX training path
uses, here as the TPU compute kernel for serving prefill.

Layout: q/k/v (BH, S, D) with GQA pre-expanded by ops.py; grid
(BH, S/BQ, S/BK).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_k_steps: int, bq: int, bk: int, sm_scale: float,
                  causal: bool, window: int, seq_len: int):
  qi = pl.program_id(1)
  ki = pl.program_id(2)

  @pl.when(ki == 0)
  def _init():
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)

  # band check: is any (q, k) pair in this block pair live?
  q_lo = qi * bq
  k_lo = ki * bk
  live = True
  if causal:
    live = jnp.asarray(k_lo <= q_lo + bq - 1)
  if window:
    live = jnp.logical_and(live, k_lo + bk - 1 > q_lo - window)

  @pl.when(live)
  def _attend():
    q = q_ref[0].astype(jnp.float32) * sm_scale      # (BQ, D)
    k = k_ref[0].astype(jnp.float32)                 # (BK, D)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (BQ, BK)
    qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_len
    if causal:
      mask = jnp.logical_and(mask, qpos >= kpos)
    if window:
      mask = jnp.logical_and(mask, kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

  @pl.when(ki == n_k_steps - 1)
  def _finalize():
    o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           sm_scale: float, causal: bool = True,
                           window: int = 0, seq_len: int = None,
                           interpret: bool = True,
                           bq: int = DEFAULT_BQ,
                           bk: int = DEFAULT_BK) -> jax.Array:
  """q/k/v (BH, S, D), S % bq == S % bk == 0 -> (BH, S, D) f32."""
  bh, s, d = q.shape
  assert s % bq == 0 and s % bk == 0, (s, bq, bk)
  if seq_len is None:
    seq_len = s
  kern = functools.partial(
      _flash_kernel, n_k_steps=s // bk, bq=bq, bk=bk, sm_scale=sm_scale,
      causal=causal, window=window, seq_len=seq_len)
  return pl.pallas_call(
      kern,
      grid=(bh, s // bq, s // bk),
      in_specs=[
          pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
          pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
          pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
      ],
      out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
      out_shape=jax.ShapeDtypeStruct((bh, s, d), jnp.float32),
      scratch_shapes=[
          pltpu.VMEM((bq, 1), jnp.float32),
          pltpu.VMEM((bq, 1), jnp.float32),
          pltpu.VMEM((bq, d), jnp.float32),
      ],
      interpret=interpret,
  )(q, k, v)
