"""Jitted public wrapper for the prefill flash-attention kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.flash_attention.kernel import (DEFAULT_BK, DEFAULT_BQ,
                                                  flash_attention_pallas)
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "interpret",
                                    "bq", "bk"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    interpret: Optional[bool] = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK) -> jax.Array:
  """GQA attention (B, S, H, D) x (B, S, Hkv, D) -> (B, S, H, D) f32."""
  if interpret is None:
    interpret = common.default_interpret()
  b, s, h, d = q.shape
  hkv = k.shape[2]
  g = h // hkv
  if g > 1:
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
  sm_scale = 1.0 / (d ** 0.5)

  def flat(x):  # (B, S, H, D) -> (B*H, S, D)
    return jnp.moveaxis(x, 2, 1).reshape(b * h, s, d)

  qf, kf, vf = flat(q), flat(k), flat(v)
  blk = min(bq, bk)
  qf, s0 = common.pad_to(qf, 1, blk)
  kf, _ = common.pad_to(kf, 1, blk)
  vf, _ = common.pad_to(vf, 1, blk)
  bq2 = min(bq, qf.shape[1])
  bk2 = min(bk, kf.shape[1])
  out = flash_attention_pallas(qf, kf, vf, sm_scale, causal=causal,
                               window=window, seq_len=s0,
                               interpret=interpret, bq=bq2, bk=bk2)
  out = out[:, :s0].reshape(b, h, s0, d)
  return jnp.moveaxis(out, 1, 2)


def flash_attention_reference(q, k, v, causal=True, window=0):
  b, s, h, d = q.shape
  hkv = k.shape[2]
  g = h // hkv
  if g > 1:
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)

  def flat(x):
    return jnp.moveaxis(x, 2, 1).reshape(b * h, s, d)

  out = flash_attention_ref(flat(q), flat(k), flat(v), 1.0 / (d ** 0.5),
                            causal=causal, window=window)
  return jnp.moveaxis(out.reshape(b, h, s, d), 1, 2)
