"""Pure-jnp oracle for the prefill flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        sm_scale: float, causal: bool = True,
                        window: int = 0,
                        seq_len: int = None) -> jax.Array:
  """q/k/v (BH, S, D) -> (BH, S, D); dense masked softmax attention."""
  bh, s, d = q.shape
  if seq_len is None:
    seq_len = s
  scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                      k.astype(jnp.float32)) * sm_scale
  qpos = jnp.arange(s)[:, None]
  kpos = jnp.arange(s)[None, :]
  mask = kpos < seq_len
  if causal:
    mask = mask & (qpos >= kpos)
  if window:
    mask = mask & (kpos > qpos - window)
  scores = jnp.where(mask[None], scores, -1e30)
  p = jax.nn.softmax(scores, axis=-1)
  return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
