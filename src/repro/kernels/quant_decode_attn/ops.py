"""Jitted public wrapper for quantized-KV decode attention.

Accepts GQA-shaped decode inputs (B, H, D) + an int8 cache
(B, H_kv, S, D) with per-(position, head) scales, handles padding of the
sequence axis to the kernel block and head grouping.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.quant_decode_attn.kernel import (DEFAULT_BS,
                                                    quant_decode_attn_pallas)
from repro.kernels.quant_decode_attn.ref import quant_decode_attn_ref


def quantize_kv(k: jax.Array, v: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
  """(B, Hkv, S, D) f32 -> int8 codes + per-(b, h, s) scales."""
  def q(x):
    absmax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-12)  # (B,Hkv,S)
    scale = absmax / 127.0
    codes = jnp.clip(jnp.round(x / scale[..., None]), -128, 127)
    return codes.astype(jnp.int8), scale
  kc, ks = q(k)
  vc, vs = q(v)
  return kc, ks, vc, vs


@functools.partial(jax.jit, static_argnames=("interpret", "bs"))
def quant_decode_attn(q: jax.Array, k_codes: jax.Array, k_scale: jax.Array,
                      v_codes: jax.Array, v_scale: jax.Array,
                      length: jax.Array, interpret: Optional[bool] = None,
                      bs: int = DEFAULT_BS) -> jax.Array:
  """q (B, H, D) x int8 cache (B, Hkv, S, D) -> (B, H, D) f32.

  length: (B,) int32 current fill per sequence.
  """
  if interpret is None:
    interpret = common.default_interpret()
  b, h, d = q.shape
  _, hkv, s, _ = k_codes.shape
  assert h % hkv == 0
  g = h // hkv
  sm_scale = 1.0 / (d ** 0.5)

  qg = q.reshape(b * hkv, g, d)
  kc = k_codes.reshape(b * hkv, s, d)
  vc = v_codes.reshape(b * hkv, s, d)
  ks = k_scale.reshape(b * hkv, s)
  vs = v_scale.reshape(b * hkv, s)
  lens = jnp.repeat(length.astype(jnp.int32), hkv)

  kc, s0 = common.pad_to(kc, 1, bs)
  vc, _ = common.pad_to(vc, 1, bs)
  ks, _ = common.pad_to(ks, 1, bs)
  vs, _ = common.pad_to(vs, 1, bs)
  out = quant_decode_attn_pallas(qg, kc, ks, vc, vs, lens, sm_scale,
                                 interpret=interpret, bs=bs)
  return out.reshape(b, h, d)


def quant_decode_attn_reference(q: jax.Array, k_codes: jax.Array,
                                k_scale: jax.Array, v_codes: jax.Array,
                                v_scale: jax.Array,
                                length: jax.Array) -> jax.Array:
  b, h, d = q.shape
  _, hkv, s, _ = k_codes.shape
  g = h // hkv
  out = quant_decode_attn_ref(
      q.reshape(b * hkv, g, d), k_codes.reshape(b * hkv, s, d),
      k_scale.reshape(b * hkv, s), v_codes.reshape(b * hkv, s, d),
      v_scale.reshape(b * hkv, s), jnp.repeat(length.astype(jnp.int32), hkv),
      1.0 / (d ** 0.5))
  return out.reshape(b, h, d)
