"""Pallas TPU kernel: single-token decode attention over an int8 KV cache.

Decode attention is memory-bound: every step streams the whole KV cache
from HBM.  QUIDAM's precision axis applied here = store K/V as int8 codes
with one f32 scale per (position, kv-head); the kernel dequantizes tiles in
VMEM and runs an online-softmax flash-decoding pass over sequence blocks.

Layout (per kv-head group, GQA):
  q        (G, D)        f32/bf16 — the G = H / H_kv query heads of a group
  k_codes  (S, D) int8 + k_scale (S,)
  v_codes  (S, D) int8 + v_scale (S,)
  out      (G, D) f32

Grid: (B * H_kv, S / BS) — the sequence axis is the minor (sequential) grid
dim; running max / denominator / accumulator live in VMEM scratch and are
finalized on the last block.  `length` masks positions >= the real cache
fill (padded shapes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BS = 256  # sequence block


def _decode_attn_kernel(len_ref, q_ref, kc_ref, ks_ref, vc_ref, vs_ref,
                        o_ref, m_ref, l_ref, acc_ref, *,
                        n_s_steps: int, bs: int, sm_scale: float):
  sstep = pl.program_id(1)

  @pl.when(sstep == 0)
  def _init():
    m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)

  q = q_ref[0].astype(jnp.float32)                        # (G, D)
  k = kc_ref[0].astype(jnp.float32) * ks_ref[0]           # (BS, D)
  v = vc_ref[0].astype(jnp.float32) * vs_ref[0]           # (BS, D)

  s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale  # (G,BS)
  # mask beyond the true cache length
  pos = sstep * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
  s = jnp.where(pos < len_ref[0], s, -jnp.inf)

  m_prev = m_ref[...]                                      # (G, 1)
  m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
  # all-masked blocks keep m = -inf; guard the exp against NaN
  m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
  p = jnp.exp(s - m_safe)
  p = jnp.where(jnp.isfinite(s), p, 0.0)
  alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
  l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
  acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
      p, v, preferred_element_type=jnp.float32)
  m_ref[...] = m_new

  @pl.when(sstep == n_s_steps - 1)
  def _finalize():
    o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def quant_decode_attn_pallas(q: jax.Array, k_codes: jax.Array,
                             k_scale: jax.Array, v_codes: jax.Array,
                             v_scale: jax.Array, length: jax.Array,
                             sm_scale: float, interpret: bool = True,
                             bs: int = DEFAULT_BS) -> jax.Array:
  """q (BH, G, D) x int8 KV (BH, S, D) + scales (BH, S) -> (BH, G, D).

  BH = batch * kv_heads (one grid row per kv-head group); S % bs == 0.
  length: int32 (BH,) true fill of each cache row.
  """
  bh, g, d = q.shape
  s_len = k_codes.shape[1]
  assert s_len % bs == 0, (s_len, bs)
  n_s_steps = s_len // bs
  kern = functools.partial(_decode_attn_kernel, n_s_steps=n_s_steps, bs=bs,
                           sm_scale=sm_scale)
  return pl.pallas_call(
      kern,
      grid=(bh, n_s_steps),
      in_specs=[
          pl.BlockSpec((1,), lambda i, s: (i,)),
          pl.BlockSpec((1, g, d), lambda i, s: (i, 0, 0)),
          pl.BlockSpec((1, bs, d), lambda i, s: (i, s, 0)),
          pl.BlockSpec((1, bs, 1), lambda i, s: (i, s, 0)),
          pl.BlockSpec((1, bs, d), lambda i, s: (i, s, 0)),
          pl.BlockSpec((1, bs, 1), lambda i, s: (i, s, 0)),
      ],
      out_specs=pl.BlockSpec((1, g, d), lambda i, s: (i, 0, 0)),
      out_shape=jax.ShapeDtypeStruct((bh, g, d), jnp.float32),
      scratch_shapes=[
          pltpu.VMEM((g, 1), jnp.float32),
          pltpu.VMEM((g, 1), jnp.float32),
          pltpu.VMEM((g, d), jnp.float32),
      ],
      interpret=interpret,
  )(length, q, k_codes, k_scale[..., None], v_codes, v_scale[..., None])
