"""Pure-jnp oracle for the quantized-KV decode attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_decode_attn_ref(q: jax.Array, k_codes: jax.Array,
                          k_scale: jax.Array, v_codes: jax.Array,
                          v_scale: jax.Array, length: jax.Array,
                          sm_scale: float) -> jax.Array:
  """q (BH, G, D), int8 KV (BH, S, D), scales (BH, S), length (BH,)."""
  k = k_codes.astype(jnp.float32) * k_scale[..., None]
  v = v_codes.astype(jnp.float32) * v_scale[..., None]
  s = jnp.einsum("bgd,bsd->bgs", q.astype(jnp.float32), k) * sm_scale
  pos = jnp.arange(k.shape[1])[None, None, :]
  s = jnp.where(pos < length[:, None, None], s, -jnp.inf)
  p = jax.nn.softmax(s, axis=-1)
  return jnp.einsum("bgs,bsd->bgd", p, v)
