"""Content-addressed, crash-safe result store + delta-sweeps.

QUIDAM's pre-characterized PPA models make a design point cheap to
evaluate, but an exploration *service* re-answers the same questions:
the same sweep re-submitted by another client, or a sweep over a space
that differs from a finished one by a handful of new axis values.  This
module amortizes both:

  store   :class:`ResultStore` — finished sweeps (reducer snapshots +
          run counters) keyed by the same content-addressed
          :func:`~repro.explore.resilience.sweep_key` fingerprints PR
          8's journal uses, minus the chunking parameters (reductions
          are chunk-order invariant, so chunk_size/workers are not part
          of a *result's* identity).  Entries are written atomic
          tempfile + rename with an embedded sha256 self-checksum;
          corrupt or truncated entries are detected on load,
          quarantined, and transparently recomputed.
  delta   when a full-grid sweep's :class:`DesignSpace` differs from a
          stored one by one edited axis (an in-order value
          supersequence, see :meth:`DesignSpace.axis_delta`), only the
          new subgrid is evaluated and folded into the cached
          accumulators.  Soundness: reducers are chunk-order invariant,
          and the cached survivors are re-addressed with
          :meth:`DesignSpace.grid_rank` — canonical value-determined
          ranks whose old->new remap is strictly monotone, so every
          selection and tie-break matches a from-scratch sweep and the
          merged fronts are bit-identical (property-tested in
          ``tests/test_service.py``).

Entry points: :func:`cached_stream_explore` /
:func:`cached_stream_co_explore` (standalone drivers, also reachable via
``ExplorationSession.explore(..., stream=True, store=...)``), and the
:class:`~repro.explore.service.ExplorationService`, which consults the
store at admission time.
"""
from __future__ import annotations

import contextlib
import hashlib
import os
import pickle
import threading
import time
from typing import Dict, List, Optional, Tuple

try:
  import fcntl
except ImportError:  # non-posix: single-process use keeps working
  fcntl = None

import numpy as np

from repro.explore.resilience import (ResiliencePolicy, SweepJournal,
                                      arch_accs_fingerprint,
                                      reducers_fingerprint,
                                      space_fingerprint, sweep_key)
from repro.explore.space import DesignSpace
from repro.explore.streaming import (Reducer, StreamResult,
                                     default_co_reducers,
                                     default_explore_reducers,
                                     default_workers, explore_tasks,
                                     run_stream, stream_co_explore,
                                     stream_explore)

STORE_VERSION = 1

# entry layout: magic | sha256 hexdigest of payload | newline | payload
_MAGIC = b"RSTO1\n"
_SHA_LEN = 64


class ResultStore:
  """Durable cache of finished sweeps, plus the in-progress journal.

  One binary file per result key under ``dir_path``; each file embeds a
  sha256 self-checksum over its pickled payload, is written atomically
  (tempfile + fsync + ``os.replace``), and is verified on every load —
  a mismatch (truncation, bit rot, a concurrent writer's partial state)
  moves the file into ``quarantine/`` and reports a miss, so the caller
  recomputes instead of trusting bad bytes.  A :class:`SweepJournal`
  under ``journal/`` carries in-progress checkpoints, and a small
  append-log index of manifests makes finished sweeps discoverable for
  delta-sweep base matching.
  """

  INDEX_KEY = "index"

  def __init__(self, dir_path):
    self.dir = str(dir_path)
    os.makedirs(self.dir, exist_ok=True)
    self.quarantine_dir = os.path.join(self.dir, "quarantine")
    self._journal = SweepJournal(os.path.join(self.dir, "journal"))
    self.lock_path = os.path.join(self.dir, "manifest.lock")
    self.n_hits = 0
    self.n_misses = 0
    self.n_quarantined = 0
    self._lock = threading.Lock()

  @property
  def journal(self) -> SweepJournal:
    """The in-progress checkpoint journal co-located with the store."""
    return self._journal

  def path(self, key: str) -> str:
    return os.path.join(self.dir, f"result-{key[:32]}.bin")

  def put(self, key: str, state: Dict[str, object]) -> None:
    payload = pickle.dumps(
        {"version": STORE_VERSION, "key": key, "state": state},
        protocol=pickle.HIGHEST_PROTOCOL)
    digest = hashlib.sha256(payload).hexdigest().encode("ascii")
    path = self.path(key)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
      f.write(_MAGIC + digest + b"\n" + payload)
      f.flush()
      os.fsync(f.fileno())
    os.replace(tmp, path)

  def get(self, key: str) -> Optional[Dict[str, object]]:
    path = self.path(key)
    try:
      with open(path, "rb") as f:
        data = f.read()
    except FileNotFoundError:
      with self._lock:
        self.n_misses += 1
      return None
    state = self._verify(key, data)
    with self._lock:
      if state is None:
        self.n_quarantined += 1
        self.n_misses += 1
      else:
        self.n_hits += 1
    if state is None:
      self._quarantine(path)
    return state

  def _verify(self, key: str, data: bytes) -> Optional[Dict[str, object]]:
    header = len(_MAGIC) + _SHA_LEN + 1
    if len(data) < header or not data.startswith(_MAGIC):
      return None
    digest = data[len(_MAGIC):len(_MAGIC) + _SHA_LEN]
    payload = data[header:]
    if hashlib.sha256(payload).hexdigest().encode("ascii") != digest:
      return None
    try:
      rec = pickle.loads(payload)
    except Exception:
      return None
    if rec.get("version") != STORE_VERSION or rec.get("key") != key:
      return None
    return rec.get("state")

  def _quarantine(self, path: str) -> None:
    os.makedirs(self.quarantine_dir, exist_ok=True)
    base = os.path.basename(path)
    for i in range(10_000):
      dst = os.path.join(self.quarantine_dir, f"{base}.{i}")
      if not os.path.exists(dst):
        try:
          os.replace(path, dst)
        except FileNotFoundError:
          return  # a concurrent loader quarantined it first
        return

  def __contains__(self, key: str) -> bool:
    return os.path.exists(self.path(key))

  def stats(self) -> Dict[str, int]:
    with self._lock:
      return {"n_hits": self.n_hits, "n_misses": self.n_misses,
              "n_quarantined": self.n_quarantined}

  # -- manifest index (delta-sweep base discovery) --------------------------
  #
  # The index is one shared append log — the only store file multiple
  # *processes* mutate concurrently (results themselves are
  # content-addressed: concurrent writers of the same key write identical
  # bytes, and os.replace keeps each file internally consistent).  An
  # fcntl advisory lock serializes index access across processes (and,
  # because each acquisition opens its own file description, across
  # threads).  Reads take the lock too: ``replay`` truncates trailing
  # garbage *in place*, which must never race a concurrent append.

  @contextlib.contextmanager
  def _manifest_lock(self):
    if fcntl is None:
      yield
      return
    with open(self.lock_path, "a+b") as f:
      fcntl.flock(f.fileno(), fcntl.LOCK_EX)
      try:
        yield
      finally:
        fcntl.flock(f.fileno(), fcntl.LOCK_UN)

  def put_final(self, key: str, state: Dict[str, object],
                manifest: Optional[Dict[str, object]] = None) -> None:
    """Store a finished sweep and (optionally) index its manifest so
    later sweeps over edited spaces can find it as a delta base."""
    self.put(key, state)
    if manifest is not None:
      entry = dict(manifest)
      entry["key"] = key
      with self._manifest_lock():
        self._journal.append(self.INDEX_KEY, entry)

  def manifests(self) -> List[Dict[str, object]]:
    """Indexed manifests, newest last, deduplicated by key (last wins).
    The index is an append log — a kill mid-append costs at most the
    entry being written; the entries (and the store files) survive."""
    seen: Dict[str, Dict[str, object]] = {}
    with self._manifest_lock():
      entries = self._journal.replay(self.INDEX_KEY)
    for entry in entries:
      seen[entry["key"]] = entry
    return list(seen.values())

  def compact_manifests(self) -> int:
    """Rewrite the manifest index keeping only the latest entry per key
    (the append log otherwise grows one frame per re-recorded sweep
    forever).  Runs under the manifest lock; the rewrite is atomic, so a
    kill mid-compaction leaves the previous index intact.  Returns the
    number of superseded entries dropped."""
    with self._manifest_lock():
      entries = self._journal.replay(self.INDEX_KEY)
      seen: Dict[str, Dict[str, object]] = {}
      for entry in entries:
        seen[entry["key"]] = entry
      dropped = len(entries) - len(seen)
      if dropped:
        self._journal.rewrite(self.INDEX_KEY, list(seen.values()))
    return dropped


# ---------------------------------------------------------------------------
# result keys (chunking-free: a *result's* identity, not a checkpoint's)
# ---------------------------------------------------------------------------

def explore_result_key(space: DesignSpace, reducers: Dict[str, Reducer], *,
                       network: str, n_per_type: int, seed: int,
                       method: str) -> str:
  """Finished-result key of a plain sweep.  ``chunk_size``/``workers``
  are excluded (chunk-order-invariant reducers make them irrelevant to
  the result); full-grid enumerations normalize ``n_per_type`` to the
  grid size and drop the seed (grid sampling never consumes it), so any
  request that enumerates the same point set hits the same entry."""
  params: Dict[str, object] = {"network": network, "method": method}
  if method == "grid":
    params["n_per_type"] = int(min(n_per_type, space.per_type_grid_size()))
  else:
    params["n_per_type"] = int(n_per_type)
    params["seed"] = int(seed)
  return sweep_key("explore-final", space_fingerprint(space),
                   reducers_fingerprint(reducers), params)


def co_explore_result_key(space: DesignSpace, reducers: Dict[str, Reducer],
                          arch_accs, *, n_hw_per_type: int, seed: int,
                          image_size: int, method: str) -> str:
  """Finished-result key of a co-exploration (chunking excluded)."""
  archs = tuple(arch for arch, _ in arch_accs)
  accs = np.asarray([float(acc) for _, acc in arch_accs], np.float64)
  return sweep_key("co-explore-final", space_fingerprint(space),
                   reducers_fingerprint(reducers),
                   {"n_hw_per_type": int(n_hw_per_type), "seed": int(seed),
                    "image_size": int(image_size), "method": method,
                    "archs": arch_accs_fingerprint(archs, accs)})


def _space_manifest(space: DesignSpace) -> Dict[str, object]:
  return {"pe_types": list(space.pe_types),
          "axes": {a.name: list(a.values) for a in space.axes},
          "n_constraints": len(space.constraints)}


def _explore_manifest(space: DesignSpace, network: str, method: str,
                      reducers_fp: str, full_grid: bool) -> Dict[str, object]:
  return {"kind": "explore", "network": network, "method": method,
          "reducers_fp": reducers_fp, "full_grid": bool(full_grid),
          "space": _space_manifest(space)}


def find_delta_base(store: ResultStore, space: DesignSpace, *, network: str,
                    reducers_fp: str
                    ) -> Optional[Tuple[str, str, Tuple[float, ...]]]:
  """Newest indexed full-grid sweep that ``space`` extends by one axis
  edit, as ``(base_key, axis_name, added_values)`` — or None."""
  for entry in reversed(store.manifests()):
    if (entry.get("kind") != "explore" or not entry.get("full_grid")
        or entry.get("network") != network
        or entry.get("reducers_fp") != reducers_fp
        or entry.get("method") != "grid"):
      continue
    m = entry.get("space", {})
    if (tuple(m.get("pe_types", ())) != space.pe_types
        or m.get("n_constraints") != len(space.constraints)):
      continue
    axes = {name: tuple(vals) for name, vals in m.get("axes", {}).items()}
    delta = space.axis_delta(axes)
    if delta is not None and entry["key"] in store:
      return entry["key"], delta[0], delta[1]
  return None


# ---------------------------------------------------------------------------
# cached drivers
# ---------------------------------------------------------------------------

def _snapshot_state(reducers: Dict[str, Reducer],
                    res: StreamResult) -> Dict[str, object]:
  return {"reducers": {n: r.snapshot() for n, r in reducers.items()},
          "n_rows": int(res.n_rows),
          "n_chunks": int(res.meta.get("n_chunks", 0))}


def _cached_result(reducers: Dict[str, Reducer], state: Dict[str, object],
                   seconds: float) -> StreamResult:
  n_chunks = float(state.get("n_chunks", 0))
  n_rows = int(state.get("n_rows", 0))
  return StreamResult(
      results={n: r.result() for n, r in reducers.items()},
      n_rows=n_rows, seconds=seconds,
      meta={"seconds": seconds, "workers": 0.0, "n_chunks": n_chunks,
            "rows_transferred": 0.0,
            "rows_per_sec": n_rows / max(seconds, 1e-12),
            "n_retries": 0.0, "n_demotions": 0.0,
            "n_resumed_chunks": n_chunks, "n_overflows": 0.0,
            "store_hit": 1.0})


def _restore_delta_base(store: ResultStore, base_key: str,
                        reducers: Dict[str, Reducer],
                        space: DesignSpace) -> Optional[Dict[str, object]]:
  """Restore a delta base into ``reducers`` and re-address its survivors
  with the edited space's canonical grid ranks.  None (and reducers
  untouched — the caller falls back to a full sweep) when the entry is
  gone/corrupt or its frames cannot be re-ranked."""
  state = store.get(base_key)
  if state is None:
    return None
  snaps = state.get("reducers", {})
  if set(snaps) != set(reducers):
    return None
  fresh = {n: r.snapshot() for n, r in reducers.items()}
  try:
    for name, r in reducers.items():
      r.restore(snaps[name])
    ranker = lambda frame: space.grid_rank(frame.table)  # noqa: E731
    for r in reducers.values():
      r.remap_indices(ranker)
  except Exception:
    for name, r in reducers.items():
      r.restore(fresh[name])
    return None
  return state


def cached_stream_explore(backend, space: DesignSpace, layers,
                          network: str = "net", n_per_type: int = 200,
                          seed: int = 17, method: str = "random",
                          reducers: Optional[Dict[str, Reducer]] = None,
                          chunk_size: int = 65536,
                          workers: Optional[int] = None,
                          policy: Optional[ResiliencePolicy] = None,
                          checkpoint_every: int = 1,
                          store=None, delta: bool = True,
                          pool=None) -> StreamResult:
  """:func:`~repro.explore.streaming.stream_explore` through the store:
  an identical finished sweep is a store hit (no evaluation at all); a
  full-grid sweep one axis-edit away from a stored one runs as a
  delta-sweep over just the new subgrid; anything else runs from
  scratch (journaled under the store's journal, so kills resume).  All
  three paths yield bit-identical reductions; ``meta`` carries
  ``store_hit`` / ``delta_sweep`` so callers can see which ran."""
  if store is None:
    raise ValueError("cached_stream_explore requires store=")
  if not isinstance(store, ResultStore):
    store = ResultStore(store)
  if reducers is None:
    reducers = default_explore_reducers()
  rfp = reducers_fingerprint(reducers)
  rkey = explore_result_key(space, reducers, network=network,
                            n_per_type=n_per_type, seed=seed, method=method)
  t0 = time.perf_counter()
  state = store.get(rkey)
  if state is not None:
    for name, r in reducers.items():
      r.restore(state["reducers"][name])
    return _cached_result(reducers, state, time.perf_counter() - t0)

  full_grid = (method == "grid"
               and int(n_per_type) >= space.per_type_grid_size())
  base = None
  if delta and full_grid:
    base = find_delta_base(store, space, network=network, reducers_fp=rfp)
  if base is not None:
    base_key, axis, added = base
    base_state = _restore_delta_base(store, base_key, reducers, space)
    if base_state is not None:
      sub = space.with_axes(**{axis: added})
      delta_key = sweep_key("explore-delta", space_fingerprint(space), rfp,
                            {"base": base_key, "network": network})
      tasks = explore_tasks(
          backend, sub, layers, network, sub.per_type_grid_size(), 0,
          "grid", chunk_size, reducers,
          row_ids=lambda chunk, offset: space.grid_rank(chunk))
      res = run_stream(tasks, reducers,
                       workers=default_workers(backend) if workers is None
                       else workers,
                       policy=policy, resume_from=store.journal,
                       journal_key=delta_key,
                       checkpoint_every=checkpoint_every, pool=pool)
      res.meta["delta_sweep"] = 1.0
      res.meta["n_delta_rows"] = float(res.n_rows)
      res.n_rows += int(base_state.get("n_rows", 0))
      store.put_final(rkey, _snapshot_state(reducers, res),
                      _explore_manifest(space, network, method, rfp,
                                        full_grid))
      return res

  res = stream_explore(backend, space, layers, network,
                       n_per_type=n_per_type, seed=seed, method=method,
                       reducers=reducers, chunk_size=chunk_size,
                       workers=workers, policy=policy,
                       resume_from=store.journal,
                       checkpoint_every=checkpoint_every, pool=pool)
  store.put_final(rkey, _snapshot_state(reducers, res),
                  _explore_manifest(space, network, method, rfp, full_grid))
  return res


def cached_stream_co_explore(backend, space: DesignSpace, arch_accs,
                             n_hw_per_type: int = 20, seed: int = 3,
                             image_size: int = 32, method: str = "random",
                             reducers: Optional[Dict[str, Reducer]] = None,
                             chunk_size: int = 65536,
                             workers: Optional[int] = None,
                             policy: Optional[ResiliencePolicy] = None,
                             checkpoint_every: int = 1,
                             store=None, pool=None) -> StreamResult:
  """:func:`~repro.explore.streaming.stream_co_explore` through the
  store: hit on an identical finished co-exploration, otherwise run
  (journaled) and record.  No delta path — the joint sweep's identity
  includes the architecture set, so axis-edit deltas rarely apply."""
  if store is None:
    raise ValueError("cached_stream_co_explore requires store=")
  if not isinstance(store, ResultStore):
    store = ResultStore(store)
  if reducers is None:
    reducers = default_co_reducers()
  rkey = co_explore_result_key(space, reducers, arch_accs,
                               n_hw_per_type=n_hw_per_type, seed=seed,
                               image_size=image_size, method=method)
  t0 = time.perf_counter()
  state = store.get(rkey)
  if state is not None:
    for name, r in reducers.items():
      r.restore(state["reducers"][name])
    return _cached_result(reducers, state, time.perf_counter() - t0)
  res = stream_co_explore(backend, space, arch_accs,
                          n_hw_per_type=n_hw_per_type, seed=seed,
                          image_size=image_size, method=method,
                          reducers=reducers, chunk_size=chunk_size,
                          workers=workers, policy=policy,
                          resume_from=store.journal,
                          checkpoint_every=checkpoint_every, pool=pool)
  store.put_final(rkey, _snapshot_state(reducers, res))
  return res
