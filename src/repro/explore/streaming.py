"""Streaming sweep engine: constant-memory, parallel exploration with
online Pareto / top-k / stats reduction.

QUIDAM's pre-characterized models make evaluating a design point cheap
(Sec. 4.1), so the binding constraint on sweep size becomes *memory*: the
one-shot paths materialize the full ConfigTable/JointTable plus a full
ResultFrame of every evaluated point, even though the paper only ever
consumes fronts, top-k lists, and distribution stats.  This module fuses
sampling -> evaluation -> reduction into a bounded-memory pipeline:

  chunks      lazy sampling (``DesignSpace.iter_tables``) or lazy
              JointTable block slices (``JointTable.block_slices``) —
              the full sweep never exists as one array
  evaluation  each chunk goes through the backend's ``evaluate_table`` /
              ``co_evaluate_table`` exactly as the one-shot path would,
              optionally on a thread pool (the numpy formulas release
              the GIL; the jax ``jit=True`` path keeps one submitting
              thread — each chunk already spans all devices via
              shard_map)
  reduction   online accumulators fold ``(chunk frame, global row ids)``
              blocks and keep only the survivors

Every accumulator is **chunk-order invariant** and emits survivors in
global row order, so streaming results are bit-identical (numpy path) to
the one-shot frame's ``pareto``/``top_k`` on the same sweep — for any
chunk size, any partition, any fold order (enforced by
``tests/test_streaming.py`` property tests).

  ParetoAccumulator     block-decomposed front merge: per-chunk
                        ``pareto_mask``, then front-vs-front elimination
                        (every dominated point is dominated by a front
                        point, so merging fronts is exact)
  TopKAccumulator       argpartition-based k-best under one column, ties
                        broken by global row id (== the one-shot stable
                        sort)
  StatsAccumulator      streaming count/mean/std/min/max (Chan's
                        parallel-Welford merge)
  HistogramAccumulator  fixed-range bin counts + approximate quantiles
  CollectAccumulator    keeps everything (the ``vectorized="auto"``
                        above-threshold path: parallel chunk evaluation,
                        full frame out)

Entry points: ``ExplorationSession.explore(..., stream=True,
reducers=...)`` / ``co_explore(..., stream=True)``, or the
``stream_explore`` / ``stream_co_explore`` drivers below.
"""
from __future__ import annotations

import copy
import dataclasses
import os
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import (Callable, Dict, Iterable, Iterator, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.explore.frame import (_MAXIMIZE_COLUMNS, ResultFrame, pareto_mask,
                                 stable_topk_indices)
from repro.explore.resilience import (ChunkError, ChunkTask, ResiliencePolicy,
                                      Rung, SweepJournal,
                                      arch_accs_fingerprint,
                                      reducers_fingerprint, space_fingerprint,
                                      sweep_key)
from repro.explore.space import DesignSpace

# explore/co_explore(vectorized="auto") switch to the parallel streaming
# engine (CollectAccumulator: identical full frame out) at this many rows
STREAM_AUTO_MIN_ROWS = 1_000_000

# a chunk producer — the engine's unit of work.  Tasks return either the
# evaluated (frame, global row ids) pair directly, or an asynchronous
# handle with .resolve() (the device path's PendingFrame / PendingFused)
Task = Callable[[], object]


# how many device chunks a single submitting thread keeps in flight: the
# engine materializes + dispatches chunk n+ahead while the device still
# runs chunk n (jax async dispatch), so host sampling/hashing overlaps
# device execution — the double-buffering that replaced the old
# "jit backends get one fully-serial worker" special case
DISPATCH_AHEAD = 2


def default_workers(backend=None) -> int:
  """Thread-pool width: one per core up to 8 for the numpy formulas
  (they release the GIL); 1 for a ``jit=True`` backend — its chunks are
  dispatched asynchronously with a ``DISPATCH_AHEAD`` in-flight window
  (and span every visible device via shard_map), so the single
  submitting thread still overlaps host and device work."""
  if backend is not None and getattr(backend, "jit", False):
    return 1
  return max(1, min(8, os.cpu_count() or 1))


def _empty_frame() -> ResultFrame:
  z = np.zeros(0)
  return ResultFrame(z, z, z, np.zeros(0, dtype="<U1"))


# ---------------------------------------------------------------------------
# reducers
# ---------------------------------------------------------------------------

class Reducer:
  """Online reduction over evaluated chunks.

  ``fold(frame, indices)`` consumes one chunk (``indices`` are the
  chunk's global row ids in the equivalent one-shot frame);
  ``result()`` emits the reduction.  Implementations must be
  chunk-order invariant: folding any partition of the sweep in any
  order yields the same result.

  Device-fusable reducers additionally implement ``device_spec()``
  (what the fused device program must compute per chunk, see
  :mod:`repro.explore.device`) and ``fold_payload(payload)`` (consume
  that program's per-chunk output).  The host accumulator state stays
  the cross-chunk merge either way — a fused chunk folds exactly like a
  host chunk whose rows were pre-thinned to an exact superset of the
  survivors, which is why the bit-identity guarantees carry over.
  """

  def fold(self, frame: ResultFrame, indices: np.ndarray) -> None:
    raise NotImplementedError

  def result(self):
    raise NotImplementedError

  def device_spec(self):
    """The fused-device request, or None when this reducer needs full
    chunks (the engine then falls back to plain per-chunk evaluation)."""
    return None

  def fold_payload(self, payload) -> None:
    """Consume one fused-chunk payload.  The default handles the
    ``("rows", frame, indices)`` form every row-keeping reducer uses."""
    kind, frame, indices = payload
    if kind != "rows":
      raise ValueError(f"{type(self).__name__} cannot fold {kind!r}")
    self.fold(frame, indices)

  def snapshot(self) -> Dict[str, object]:
    """Journal-serializable copy of the accumulator state (see
    :class:`repro.explore.resilience.SweepJournal`).  The default deep
    copies ``__dict__`` wholesale — accumulator state is numpy arrays,
    scalars, frames and lists, all picklable and all isolated from
    later in-place folds by the copy.  Override for reducers holding
    live handles."""
    return {"cls": type(self).__name__,
            "state": copy.deepcopy(self.__dict__)}

  def restore(self, snap: Dict[str, object]) -> None:
    """Adopt a :meth:`snapshot`; folding the not-yet-journaled chunks on
    top is bit-identical to an uninterrupted run (chunk-order
    invariance quantifies over *every* partition, including the
    before/after-restore one)."""
    if snap.get("cls") != type(self).__name__:
      raise ValueError(f"snapshot of {snap.get('cls')!r} cannot restore "
                       f"a {type(self).__name__}")
    self.__dict__.update(copy.deepcopy(snap["state"]))

  def fingerprint(self) -> str:
    """Content key for the journal's reducer-plan component: two
    reducers with equal fingerprints accept each other's snapshots."""
    return type(self).__name__

  def remap_indices(self, ranker: Callable[[ResultFrame], np.ndarray]) -> None:
    """Rewrite the retained survivors' global row ids via ``ranker``
    (a frame -> int64 ids function).  Delta-sweeps (see
    :mod:`repro.explore.store`) restore a cached accumulator whose ids
    were assigned under the *base* space's enumeration and re-address
    them in the edited space before folding the new subgrid; as long as
    the remap is strictly monotone over the old points, every selection
    and tie-break is unchanged.  Default: no retained ids, nothing to
    do (stats/histogram state is id-free)."""


class ParetoAccumulator(Reducer):
  """Online non-dominated front over the given columns.

  Per chunk: local ``pareto_mask``, then a front-vs-front merge with the
  running front (exact — any point dominated by a non-front point is
  also dominated by a front point, so eliminating within the union of
  fronts loses nothing).  ``result()`` is a survivors-only ResultFrame
  in global row order: bit-identical rows to
  ``frame.select(frame.pareto(cols))`` on the one-shot path.
  """

  def __init__(self, cols: Sequence[str] = ("perf_per_area", "energy_mj"),
               maximize: Optional[Sequence[str]] = None):
    self.cols = tuple(cols)
    self._mx = _MAXIMIZE_COLUMNS if maximize is None else frozenset(maximize)
    self._obj: Optional[np.ndarray] = None
    self._idx = np.zeros(0, np.int64)
    self._frame: Optional[ResultFrame] = None

  def _objectives(self, frame: ResultFrame) -> np.ndarray:
    return np.stack([-frame.column(c) if c in self._mx else frame.column(c)
                     for c in self.cols], axis=1).astype(np.float64)

  def fold(self, frame: ResultFrame, indices: np.ndarray) -> None:
    if not len(frame):
      return
    obj = self._objectives(frame)
    keep = np.flatnonzero(pareto_mask(obj))
    cand_obj = obj[keep]
    cand_idx = np.asarray(indices, np.int64)[keep]
    cand_frame = frame.select(keep)
    if self._frame is not None:
      cand_obj = np.concatenate([self._obj, cand_obj])
      cand_idx = np.concatenate([self._idx, cand_idx])
      cand_frame = ResultFrame.concat([self._frame, cand_frame])
    sel = np.flatnonzero(pareto_mask(cand_obj))
    self._obj = cand_obj[sel]
    self._idx = cand_idx[sel]
    self._frame = cand_frame.select(sel)

  @property
  def indices(self) -> np.ndarray:
    """Global row ids of the current front, ascending."""
    return np.sort(self._idx)

  def device_spec(self):
    from repro.explore.device import ParetoSpec
    return ParetoSpec(self.cols,
                      tuple(c for c in self.cols if c in self._mx))

  def remap_indices(self, ranker) -> None:
    if self._frame is not None and len(self._frame):
      self._idx = np.asarray(ranker(self._frame), np.int64)

  def fingerprint(self) -> str:
    mx = ",".join(sorted(c for c in self.cols if c in self._mx))
    return f"Pareto(cols={','.join(self.cols)};mx={mx})"

  def result(self) -> ResultFrame:
    if self._frame is None:
      return _empty_frame()
    return self._frame.select(np.argsort(self._idx, kind="stable"))


class TopKAccumulator(Reducer):
  """Online k-best rows under one column (argpartition-based, ties broken
  by global row id).  ``result()`` is a best-first ResultFrame,
  bit-identical to the one-shot ``frame.top_k(k, by)``."""

  def __init__(self, k: int, by: str = "perf_per_area",
               maximize: Optional[bool] = None):
    if k <= 0:
      raise ValueError(f"k must be positive, got {k}")
    self.k = int(k)
    self.by = by
    self.maximize = by in _MAXIMIZE_COLUMNS if maximize is None else maximize
    self._key = np.zeros(0, np.float64)
    self._idx = np.zeros(0, np.int64)
    self._frame: Optional[ResultFrame] = None

  def fold(self, frame: ResultFrame, indices: np.ndarray) -> None:
    if not len(frame):
      return
    vals = np.asarray(frame.column(self.by), np.float64)
    key = -vals if self.maximize else vals
    idx = np.asarray(indices, np.int64)
    loc = stable_topk_indices(key, self.k, tie=idx)
    cand_key = np.concatenate([self._key, key[loc]])
    cand_idx = np.concatenate([self._idx, idx[loc]])
    sub = frame.select(loc)
    cand_frame = sub if self._frame is None \
        else ResultFrame.concat([self._frame, sub])
    sel = stable_topk_indices(cand_key, self.k, tie=cand_idx)
    self._key = cand_key[sel]
    self._idx = cand_idx[sel]
    self._frame = cand_frame.select(sel)

  @property
  def indices(self) -> np.ndarray:
    """Global row ids of the current k-best, best-first."""
    return self._idx.copy()

  def device_spec(self):
    from repro.explore.device import TopKSpec
    return TopKSpec(self.by, self.k, self.maximize)

  def remap_indices(self, ranker) -> None:
    if self._frame is not None and len(self._frame):
      self._idx = np.asarray(ranker(self._frame), np.int64)

  def fingerprint(self) -> str:
    return f"TopK(k={self.k};by={self.by};mx={self.maximize})"

  def result(self) -> ResultFrame:
    # state is already (key, global id)-ordered best-first
    return self._frame if self._frame is not None else _empty_frame()


class StatsAccumulator(Reducer):
  """Streaming count/mean/std/min/max of one column (Chan's parallel
  Welford merge — exact min/max/count, float-associativity-level mean and
  std).  Quantiles need the data: see HistogramAccumulator."""

  def __init__(self, col: str):
    self.col = col
    self.n = 0
    self._mean = 0.0
    self._m2 = 0.0
    self._min = np.inf
    self._max = -np.inf

  def fold(self, frame: ResultFrame, indices: np.ndarray) -> None:
    v = np.asarray(frame.column(self.col), np.float64)
    if not v.size:
      return
    mean_b = float(v.mean())
    # a single row has zero spread by definition; computing (v - mean)**2
    # would turn a non-finite value into a NaN M2 partial (inf - inf)
    m2_b = 0.0 if v.size == 1 else float(((v - mean_b) ** 2).sum())
    self._merge(v.size, mean_b, m2_b, float(v.min()), float(v.max()))

  def _merge(self, n_b: int, mean_b: float, m2_b: float, min_b: float,
             max_b: float) -> None:
    """Chan's parallel merge of one (count, mean, M2, min, max) partial —
    shared by host chunks and fused device partials."""
    if not self.n:
      # adopt the first partial directly: bit-identical to the merge
      # formula for finite means (delta*n_b/total collapses to mean_b
      # exactly), and NaN-free when mean_b is +-inf (the general formula
      # multiplies delta**2 by n == 0 -> inf * 0 -> NaN)
      self.n = n_b
      self._mean = mean_b
      self._m2 += m2_b
      self._min = min(self._min, min_b)
      self._max = max(self._max, max_b)
      return
    delta = mean_b - self._mean
    total = self.n + n_b
    self._m2 += m2_b + delta * delta * self.n * n_b / total
    self._mean += delta * n_b / total
    self.n = total
    self._min = min(self._min, min_b)
    self._max = max(self._max, max_b)

  def device_spec(self):
    from repro.explore.device import StatsSpec
    return StatsSpec(self.col)

  def fingerprint(self) -> str:
    return f"Stats(col={self.col})"

  def fold_payload(self, payload) -> None:
    kind, data = payload[0], payload[1]
    if kind != "stats":
      return super().fold_payload(payload)
    if data["n"]:
      self._merge(data["n"], data["mean"], data["m2"], data["min"],
                  data["max"])

  def result(self) -> Dict[str, float]:
    if not self.n:
      return {k: float("nan")
              for k in ("count", "mean", "std", "min", "max")}
    return {"count": float(self.n), "mean": self._mean,
            "std": float(np.sqrt(self._m2 / self.n)),
            "min": self._min, "max": self._max}


class HistogramAccumulator(Reducer):
  """Streaming fixed-range histogram of one column.

  The bin range must be declared up front (streaming cannot rescale);
  values outside ``(lo, hi)`` are clipped into the edge bins.
  ``result()`` returns ``{"counts", "edges"}``; :meth:`quantile` linearly
  interpolates within bins (approximate — error bounded by bin width).
  """

  def __init__(self, col: str, lo: float, hi: float, bins: int = 64):
    if not hi > lo:
      raise ValueError(f"need hi > lo, got ({lo}, {hi})")
    if bins <= 0:
      raise ValueError(f"bins must be positive, got {bins}")
    self.col = col
    self.edges = np.linspace(float(lo), float(hi), int(bins) + 1)
    self.counts = np.zeros(int(bins), np.int64)

  def fold(self, frame: ResultFrame, indices: np.ndarray) -> None:
    v = np.asarray(frame.column(self.col), np.float64)
    if not v.size:
      return
    v = np.clip(v, self.edges[0], self.edges[-1])
    self.counts += np.histogram(v, bins=self.edges)[0]

  def device_spec(self):
    from repro.explore.device import HistSpec
    return HistSpec(self.col, float(self.edges[0]), float(self.edges[-1]),
                    len(self.counts))

  def fingerprint(self) -> str:
    return (f"Hist(col={self.col};lo={self.edges[0]!r};"
            f"hi={self.edges[-1]!r};bins={len(self.counts)})")

  def fold_payload(self, payload) -> None:
    kind, data = payload[0], payload[1]
    if kind != "hist":
      return super().fold_payload(payload)
    self.counts += np.asarray(data, np.int64)

  def quantile(self, q: float) -> float:
    """Approximate q-quantile from the bin counts (linear within bins)."""
    total = int(self.counts.sum())
    if not total:
      return float("nan")
    target = np.clip(q, 0.0, 1.0) * total
    cum = np.cumsum(self.counts)
    b = int(np.searchsorted(cum, target, side="left"))
    b = min(b, len(self.counts) - 1)
    below = cum[b] - self.counts[b]
    frac = (target - below) / max(self.counts[b], 1)
    return float(self.edges[b]
                 + np.clip(frac, 0.0, 1.0) * (self.edges[b + 1]
                                              - self.edges[b]))

  def result(self) -> Dict[str, np.ndarray]:
    return {"counts": self.counts.copy(), "edges": self.edges.copy()}


class CollectAccumulator(Reducer):
  """Keeps every chunk and reassembles the full frame in global row
  order — NOT constant-memory.  This is how ``vectorized="auto"`` runs
  big sweeps through the parallel engine while preserving the one-shot
  return type bit-exactly."""

  def __init__(self):
    self._frames = []
    self._idx = []

  def fold(self, frame: ResultFrame, indices: np.ndarray) -> None:
    if not len(frame):
      return
    self._frames.append(frame)
    self._idx.append(np.asarray(indices, np.int64))

  def remap_indices(self, ranker) -> None:
    self._idx = [np.asarray(ranker(f), np.int64) for f in self._frames]

  def result(self) -> ResultFrame:
    if not self._frames:
      return _empty_frame()
    big = self._frames[0] if len(self._frames) == 1 \
        else ResultFrame.concat(self._frames)
    idx = np.concatenate(self._idx)
    return big.select(np.argsort(idx, kind="stable"))


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StreamResult:
  """Outcome of a streaming sweep: one entry per reducer (by name) plus
  run stats.  ``res["pareto"]`` etc. index into ``results``."""
  results: Dict[str, object]
  n_rows: int
  seconds: float
  meta: Dict[str, float]

  def __getitem__(self, name: str):
    return self.results[name]


def new_counters() -> Dict[str, int]:
  """A fresh run-stats dict in the shape the journal checkpoints."""
  return {"n_rows": 0, "n_chunks": 0, "n_transferred": 0,
          "n_overflows": 0, "n_retries": 0, "n_demotions": 0}


def fold_chunk(reducers: Dict[str, Reducer], counters: Dict[str, int],
               result) -> None:
  """Resolve (if pending) and fold one completed chunk into every
  reducer, updating ``counters``.  Shared by :func:`run_stream` and the
  exploration service's session scheduler so both fold identically."""
  if hasattr(result, "resolve"):
    result = result.resolve()
  counters["n_chunks"] += 1
  payloads = getattr(result, "payloads", None)
  if payloads is not None:  # a device FusedChunk (duck-typed: keeps
    counters["n_rows"] += result.n_rows  # numpy path device-import-free
    counters["n_transferred"] += result.n_transferred
    counters["n_overflows"] += getattr(result, "n_overflows", 0)
    for name, payload in payloads.items():
      reducers[name].fold_payload(payload)
    return
  frame, indices = result
  counters["n_rows"] += len(frame)
  counters["n_transferred"] += len(frame)
  for r in reducers.values():
    r.fold(frame, indices)


# ROB002: every wait in explore/ must carry a bounded timeout (the
# watchdog idiom) — the pool waits below re-arm in a loop, so a slow
# chunk never wedges the submitting thread invisibly
POOL_WAIT_SECONDS = 60.0


def run_stream(tasks: Iterable[Task], reducers: Dict[str, Reducer],
               workers: int = 1, dispatch_ahead: int = DISPATCH_AHEAD,
               policy: Optional[ResiliencePolicy] = None,
               resume_from=None, journal_key: str = "",
               checkpoint_every: int = 1, pool=None) -> StreamResult:
  """Drain ``tasks`` (each producing one evaluated chunk), folding every
  reducer as chunks complete.

  A task may return the plain ``(frame, indices)`` tuple, or an
  asynchronous handle — anything with a ``resolve()`` method, i.e. the
  device path's :class:`~repro.explore.device.PendingFrame` /
  :class:`~repro.explore.device.PendingFused`.  Handles are kept in a
  bounded ``dispatch_ahead`` window before resolution, so a single
  submitting thread materializes + dispatches upcoming chunks while the
  device still executes earlier ones (jax async dispatch).

  ``workers > 1`` evaluates chunks on a thread pool with a bounded
  in-flight window (2x workers), so peak memory stays O(window x chunk);
  folds happen on the submitting thread only.  Completion order is
  nondeterministic — reducers are chunk-order invariant, so results are
  not.

  Failure semantics (see :mod:`repro.explore.resilience` and
  docs/explore.md "Failure semantics & resume"):

  * ``policy`` — a :class:`ResiliencePolicy` executing each
    :class:`ChunkTask` through retry + the degradation ladder; its
    retry/demotion totals land in ``meta``.
  * a fatally failing chunk cancels all not-yet-started work and raises
    :class:`ChunkError` carrying the chunk's *global index* (the
    previous behavior lost both the index and the in-flight window).
  * ``resume_from`` — a :class:`SweepJournal` (or its directory path).
    Reducer snapshots plus the set of folded chunk indices are recorded
    under ``journal_key`` every ``checkpoint_every`` folds *and* on the
    way out of a fatal error; on entry, a matching record restores the
    reducers and already-folded chunks are skipped before dispatch.
    Chunk-order invariance makes the resumed final reductions
    bit-identical to an uninterrupted run.
  * ``pool`` — a :class:`repro.explore.fleet.DevicePool`; the sweep is
    handed to :func:`repro.explore.fleet.run_fleet`, which shards chunks
    across the pool's devices with health tracking, straggler
    speculation, elastic resharding and the silent-corruption sentinel.
    Chunk-partition bit-identity keeps the fronts identical to this
    single-device path.
  """
  if pool is not None:
    from repro.explore.fleet import run_fleet
    return run_fleet(tasks, reducers, pool, policy=policy,
                     dispatch_ahead=dispatch_ahead, resume_from=resume_from,
                     journal_key=journal_key,
                     checkpoint_every=checkpoint_every)
  workers = max(1, int(workers))
  t0 = time.perf_counter()
  journal = None
  done_chunks: set = set()
  counters = new_counters()
  n_resumed = 0
  if resume_from is not None:
    journal = resume_from if isinstance(resume_from, SweepJournal) \
        else SweepJournal(resume_from)
    state = journal.load_state(journal_key)
    if state is not None:
      done_chunks = set(state["done"])
      for name, r in reducers.items():
        r.restore(state["reducers"][name])
      counters.update(state["counters"])
      n_resumed = len(done_chunks)
  base_retries = counters["n_retries"]
  base_demotions = counters["n_demotions"]
  since_ckpt = 0

  def totals() -> Tuple[int, int]:
    extra_r = policy.n_retries if policy is not None else 0
    extra_d = policy.n_demotions if policy is not None else 0
    return base_retries + extra_r, base_demotions + extra_d

  def checkpoint(force: bool = False) -> None:
    nonlocal since_ckpt
    if journal is None:
      return
    since_ckpt += 1
    if not force and since_ckpt < max(int(checkpoint_every), 1):
      return
    counters["n_retries"], counters["n_demotions"] = totals()
    journal.record(journal_key, {
        "done": set(done_chunks),
        "reducers": {name: r.snapshot() for name, r in reducers.items()},
        "counters": dict(counters)})
    since_ckpt = 0

  def execute(task):
    if policy is not None:
      return policy.execute(task)
    return task()

  def fail(index, exc):
    """Flush the journal, then surface the failing chunk's global
    index (a bare re-raise would lose it)."""
    checkpoint(force=True)
    if isinstance(exc, ChunkError):
      raise exc
    raise ChunkError(index, f"{type(exc).__name__}: {exc}") from exc

  def finish(index, result) -> None:
    try:
      fold_chunk(reducers, counters, result)
    except Exception as e:
      fail(index, e)
    done_chunks.add(index)
    checkpoint()

  def indexed(ts) -> Iterator[Tuple[int, Task]]:
    """(global chunk index, task) pairs, skipping already-folded chunks
    before they are materialized or dispatched."""
    for i, t in enumerate(ts):
      index = getattr(t, "index", i)
      if index in done_chunks:
        continue
      yield index, t

  if workers == 1:
    window: "deque" = deque()
    for index, task in indexed(tasks):
      try:
        res = execute(task)
      except Exception as e:
        fail(index, e)
      if hasattr(res, "resolve"):
        window.append((index, res))
        if len(window) > max(int(dispatch_ahead), 0):
          finish(*window.popleft())
      else:
        finish(index, res)
    while window:
      finish(*window.popleft())
  else:
    with ThreadPoolExecutor(max_workers=workers) as pool:
      pending: Dict = {}  # future -> global chunk index

      def drain(ready) -> None:
        for fut in ready:
          index = pending.pop(fut)
          try:
            res = fut.result()
          except Exception as e:
            fail(index, e)
          finish(index, res)

      try:
        for index, task in indexed(tasks):
          pending[pool.submit(execute, task)] = index
          while len(pending) >= 2 * workers:
            ready, _ = wait(set(pending), timeout=POOL_WAIT_SECONDS,
                            return_when=FIRST_COMPLETED)
            drain(ready)
        while pending:
          ready, _ = wait(set(pending), timeout=POOL_WAIT_SECONDS,
                          return_when=FIRST_COMPLETED)
          drain(ready)
      except Exception:
        # fatal: drop queued chunks so the pool shuts down promptly
        # instead of grinding through the whole in-flight window
        for fut in pending:
          fut.cancel()
        raise
  checkpoint(force=True)
  seconds = time.perf_counter() - t0
  n_retries, n_demotions = totals()
  meta = {"seconds": seconds, "workers": float(workers),
          "n_chunks": float(counters["n_chunks"]),
          "rows_transferred": float(counters["n_transferred"]),
          "rows_per_sec": counters["n_rows"] / max(seconds, 1e-12),
          "n_retries": float(n_retries),
          "n_demotions": float(n_demotions),
          "n_resumed_chunks": float(n_resumed),
          "n_overflows": float(counters["n_overflows"])}
  if policy is not None:
    meta["n_leaked_watchdogs"] = float(policy.watchdogs.n_live())
    if policy.breaker is not None:
      meta.update(policy.breaker.meta())
  return StreamResult(
      results={name: r.result() for name, r in reducers.items()},
      n_rows=counters["n_rows"], seconds=seconds, meta=meta)


# ---------------------------------------------------------------------------
# drivers: plain DSE + joint co-exploration
# ---------------------------------------------------------------------------

def default_explore_reducers() -> Dict[str, Reducer]:
  """The paper's default plain-sweep reduction plan."""
  return {"pareto": ParetoAccumulator()}


def default_co_reducers() -> Dict[str, Reducer]:
  """The paper's default 3-objective joint-front reduction plan."""
  return {"pareto": ParetoAccumulator(("top1_err", "energy_mj",
                                       "area_mm2"))}


def explore_sweep_key(space: DesignSpace, reducers: Dict[str, Reducer], *,
                      n_per_type: int, seed: int, method: str,
                      chunk_size: int, network: str) -> str:
  """The content-addressed journal key of a plain streamed sweep."""
  return sweep_key("explore", space_fingerprint(space),
                   reducers_fingerprint(reducers),
                   {"n_per_type": n_per_type, "seed": seed,
                    "method": method, "chunk_size": chunk_size,
                    "network": network})


def co_explore_sweep_key(space: DesignSpace, reducers: Dict[str, Reducer],
                         arch_accs, *, n_hw_per_type: int, seed: int,
                         image_size: int, method: str,
                         chunk_size: int) -> str:
  """The content-addressed journal key of a streamed co-exploration."""
  archs = tuple(arch for arch, _ in arch_accs)
  accs = np.asarray([float(acc) for _, acc in arch_accs], np.float64)
  return sweep_key("co-explore", space_fingerprint(space),
                   reducers_fingerprint(reducers),
                   {"n_hw_per_type": n_hw_per_type, "seed": seed,
                    "image_size": image_size, "method": method,
                    "chunk_size": chunk_size,
                    "archs": arch_accs_fingerprint(archs, accs)})


def explore_tasks(backend, space: DesignSpace, layers, network: str,
                  n_per_type: int, seed: int, method: str, chunk_size: int,
                  reducers: Dict[str, Reducer],
                  row_ids: Optional[Callable[[object, int], np.ndarray]]
                  = None) -> Iterator[ChunkTask]:
  """The ladder-carrying chunk tasks of a plain streamed sweep.

  Extracted from :func:`stream_explore` so the exploration service (and
  the delta-sweep driver in :mod:`repro.explore.store`) consume the
  exact same task generators and ladders as the standalone driver.
  ``row_ids`` overrides the global row-id assignment — default is the
  one-shot sample order ``arange(offset, offset+len)``; delta-sweeps
  pass the parent space's canonical grid ranks instead.
  """
  if not hasattr(backend, "evaluate_table"):
    raise ValueError(f"backend {backend.name!r} has no evaluate_table; "
                     "streaming requires the columnar path")
  plan = None
  device_mode = getattr(backend, "jit", False) \
      and hasattr(backend, "fused_eval_pending")
  if device_mode:
    from repro.explore.device import build_plan
    plan = build_plan(reducers, joint=False)
  # the terminal numpy rung: bypasses jit even on a device backend
  host_eval = getattr(backend, "host_evaluate_table", None)
  if host_eval is None:
    host_eval = backend.evaluate_table

  def make_task(chunk, idx, ci) -> ChunkTask:
    rungs = []
    if plan is not None:
      rungs.append(Rung(
          "fused-device",
          lambda: backend.fused_eval_pending(chunk, layers, network, plan,
                                             idx),
          layer="device"))
    if device_mode:
      rungs.append(Rung(
          "device",
          lambda: backend.eval_pending(chunk, layers, network, idx),
          layer="device"))
    rungs.append(Rung("numpy",
                      lambda: (host_eval(chunk, layers, network), idx),
                      layer="backend"))
    return ChunkTask(index=ci, rungs=tuple(rungs))

  def gen() -> Iterator[ChunkTask]:
    offset = 0
    for ci, chunk in enumerate(
        space.iter_tables(n_per_type, seed=seed, method=method,
                          chunk_size=chunk_size)):
      if row_ids is None:
        idx = np.arange(offset, offset + len(chunk), dtype=np.int64)
      else:
        idx = np.asarray(row_ids(chunk, offset), np.int64)
      offset += len(chunk)
      yield make_task(chunk, idx, ci)

  return gen()


def co_explore_tasks(backend, space: DesignSpace, arch_accs,
                     n_hw_per_type: int, seed: int, image_size: int,
                     method: str, chunk_size: int,
                     reducers: Dict[str, Reducer]) -> Iterator[ChunkTask]:
  """The ladder-carrying chunk tasks of a streamed co-exploration —
  extracted from :func:`stream_co_explore` for the same service/driver
  sharing as :func:`explore_tasks`."""
  from repro.core.dataflow import LayerStack  # deferred: keep header lean
  from repro.core.supernet import arch_to_layers  # deferred: pulls jax
  if not hasattr(backend, "co_evaluate_table"):
    raise ValueError(f"backend {backend.name!r} has no co_evaluate_table; "
                     "streaming requires the joint columnar path")
  archs = tuple(arch for arch, _ in arch_accs)
  accs = np.asarray([float(acc) for _, acc in arch_accs], np.float64)
  stack = LayerStack.from_layer_lists(
      [arch_to_layers(a, image_size=image_size) for a in archs])
  plan = None
  device_mode = getattr(backend, "jit", False) \
      and hasattr(backend, "fused_co_eval_pending")
  dedup = None
  if device_mode:
    from repro.explore.device import build_plan
    plan = build_plan(reducers, joint=True)
    # one global distinct-layer factorization: every block slices the
    # same unique rows, so one compiled program serves the whole sweep
    unique_cols, slot_ids = stack.dedup_slots()
    dedup = lambda a_sl: (unique_cols, slot_ids[a_sl])  # noqa: E731
  # the terminal numpy rung: bypasses jit even on a device backend
  host_co = getattr(backend, "host_co_evaluate_table", None)
  if host_co is None:
    host_co = backend.co_evaluate_table

  def make_task(hw_sub, sub_stack, a_sl, idx, ci) -> ChunkTask:
    a_lo = a_sl.start
    rungs = []
    if plan is not None:
      rungs.append(Rung(
          "fused-device",
          lambda: backend.fused_co_eval_pending(
              hw_sub, sub_stack, "coexplore", plan, idx, a_lo, accs[a_sl],
              archs, dedup=dedup(a_sl)),
          layer="device"))
    if device_mode:
      rungs.append(Rung(
          "device",
          lambda: backend.co_eval_pending(
              hw_sub, sub_stack, "coexplore", idx, a_lo, accs[a_sl], archs,
              dedup=dedup(a_sl)),
          layer="device"))

    def run():
      f = host_co(hw_sub, sub_stack, network="coexplore")
      f.extra["arch_id"] = f.extra["arch_id"] + a_lo
      f.extra["top1"] = accs[f.extra["arch_id"]]
      f.arch_lookup = archs
      return f, idx
    rungs.append(Rung("numpy", run, layer="backend"))
    return ChunkTask(index=ci, rungs=tuple(rungs))

  def gen() -> Iterator[ChunkTask]:
    offset = 0
    ci = 0
    for ti, pe_type in enumerate(space.pe_types):
      hw = space.sample_type_table(pe_type, n_hw_per_type,
                                   seed=seed + 17 * ti, method=method)
      joint = hw.cross(stack.n_archs)
      for a_sl, h_sl in joint.block_slices(chunk_size):
        idx = offset + joint.block_indices(a_sl, h_sl)
        yield make_task(hw.select(h_sl),
                        stack.slice_archs(a_sl.start, a_sl.stop),
                        a_sl, idx, ci)
        ci += 1
      offset += len(joint)

  return gen()


def stream_explore(backend, space: DesignSpace, layers, network: str = "net",
                   n_per_type: int = 200, seed: int = 17,
                   method: str = "random",
                   reducers: Optional[Dict[str, Reducer]] = None,
                   chunk_size: int = 65536,
                   workers: Optional[int] = None,
                   policy: Optional[ResiliencePolicy] = None,
                   resume_from=None,
                   checkpoint_every: int = 1, pool=None) -> StreamResult:
  """Sample -> evaluate -> reduce a plain HW sweep in bounded memory.

  Chunks come from ``space.iter_tables`` (bit-identical concatenation to
  ``sample_table``), evaluate through ``backend.evaluate_table``, and
  fold into ``reducers`` (default: one ParetoAccumulator on the paper's
  (perf_per_area, energy) axes).  Global row ids follow the one-shot
  sample order, so survivors match the one-shot frame row for row.

  On a ``jit=True`` backend chunks dispatch asynchronously; when every
  reducer is device-fusable the evaluate+reduce pipeline additionally
  fuses into one jitted program per chunk (see
  :mod:`repro.explore.device`), so only O(survivors) floats come back
  per chunk instead of full metric arrays.

  Each chunk carries the full fallback ladder ``fused-device ->
  unfused-device -> numpy`` (whichever rungs the backend supports); a
  ``policy`` walks it on failures, and ``resume_from`` journals /
  restores the sweep under a content-addressed key derived from the
  space, oracle version, reducer plan, and the sampling parameters —
  the backend itself is *not* part of the key (parity makes checkpoints
  portable across the numpy and device paths).
  """
  if reducers is None:
    reducers = default_explore_reducers()
  tasks = explore_tasks(backend, space, layers, network, n_per_type, seed,
                        method, chunk_size, reducers)
  key = ""
  if resume_from is not None:
    key = explore_sweep_key(space, reducers, n_per_type=n_per_type,
                            seed=seed, method=method, chunk_size=chunk_size,
                            network=network)
  return run_stream(tasks, reducers,
                    workers=default_workers(backend) if workers is None
                    else workers,
                    policy=policy, resume_from=resume_from,
                    journal_key=key, checkpoint_every=checkpoint_every,
                    pool=pool)


def stream_co_explore(backend, space: DesignSpace, arch_accs,
                      n_hw_per_type: int = 20, seed: int = 3,
                      image_size: int = 32, method: str = "random",
                      reducers: Optional[Dict[str, Reducer]] = None,
                      chunk_size: int = 65536,
                      workers: Optional[int] = None,
                      policy: Optional[ResiliencePolicy] = None,
                      resume_from=None,
                      checkpoint_every: int = 1, pool=None) -> StreamResult:
  """Joint HW x NN co-exploration in bounded memory: the arch x HW cross
  product is visited as ``JointTable.block_slices`` blocks (HW sampled
  once per PE type — the small input side; the 100M-pair product never
  materializes), each block evaluated via ``backend.co_evaluate_table``
  on an arch-sliced LayerStack.  Chunk frames carry the same ``top1`` /
  ``arch_id`` / ``arch_lookup`` columns as the one-shot joint frame, and
  global row ids replicate its (pe_type, arch, hw) order exactly.
  Default reducers: a ParetoAccumulator on the paper's 3-objective
  (top1_err, energy_mj, area_mm2) joint front.
  """
  if reducers is None:
    reducers = default_co_reducers()
  tasks = co_explore_tasks(backend, space, arch_accs, n_hw_per_type, seed,
                           image_size, method, chunk_size, reducers)
  key = ""
  if resume_from is not None:
    key = co_explore_sweep_key(space, reducers, arch_accs,
                               n_hw_per_type=n_hw_per_type, seed=seed,
                               image_size=image_size, method=method,
                               chunk_size=chunk_size)
  return run_stream(tasks, reducers,
                    workers=default_workers(backend) if workers is None
                    else workers,
                    policy=policy, resume_from=resume_from,
                    journal_key=key, checkpoint_every=checkpoint_every,
                    pool=pool)
