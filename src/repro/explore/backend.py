"""Pluggable evaluation backends: how a design point gets its PPA numbers.

Three implementations of the :class:`EvaluationBackend` protocol:

  OracleBackend        slow, exact — full per-design characterization via
                       the synthesis stand-in (``repro.core.oracle``),
                       one Python call per design point
  VectorOracleBackend  the same oracle, array-at-a-time — consumes a
                       :class:`~repro.core.table.ConfigTable` in
                       bounded-memory chunks via the ``*_batch`` formulas;
                       bit-identical to OracleBackend on the numpy path,
                       ~2 orders of magnitude faster, with an optional
                       ``jax.jit`` / ``shard_map`` device path
  PolynomialBackend    fast — QUIDAM's fit-once / evaluate-many polynomial
                       models (``repro.core.ppa``), with in-process fit
                       memoization and ``save``/``load`` to ``.npz`` so
                       sessions and benchmarks never refit; accepts config
                       lists or ConfigTables (the table path predicts
                       without building per-point objects)

All compose the global buffer the same way: the polynomial targets cover
the PE-array subsystem only (the paper's 4-feature vector cannot see GBS),
so the buffer adds on as a pre-characterized SRAM macro via
:func:`gbuf_overheads` (memoized, scalar) / :func:`gbuf_overheads_table`
(vectorized).
"""
from __future__ import annotations

import functools
import hashlib
import os
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import oracle
from repro.core import ppa as ppa_lib
from repro.core.dataflow import AcceleratorConfig, ConvLayer, LayerStack
from repro.core.pe import PAPER_PE_TYPES
from repro.core.table import ConfigTable
from repro.explore.frame import ResultFrame

Configs = Union[Sequence[AcceleratorConfig], ConfigTable]

try:  # Protocol is typing-only; keep runtime deps minimal
  from typing import Protocol
except ImportError:  # pragma: no cover - py<3.8
  Protocol = object  # type: ignore[assignment]


class EvaluationBackend(Protocol):
  """Anything that turns (configs, workload) into a ResultFrame.

  ``cfgs`` may be a sequence of per-point dataclasses or a columnar
  :class:`ConfigTable`.  Backends that implement the optional
  ``evaluate_table(table, layers, network)`` method (and advertise
  ``prefers_table = True``) get handed ConfigTables directly by
  :class:`~repro.explore.ExplorationSession`, keeping million-point
  sweeps columnar end to end.
  """
  name: str

  def evaluate(self, cfgs: Configs, layers: Sequence[ConvLayer],
               network: str = "net") -> ResultFrame:
    ...


# ---------------------------------------------------------------------------
# shared global-buffer composition (the one memoized helper)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=65536)
def _gbuf_cached(cfg: AcceleratorConfig) -> Tuple[float, float]:
  return oracle.gbuf_power_mw(cfg), oracle.gbuf_area_mm2(cfg)


def gbuf_overheads(cfgs: Configs) -> Tuple[np.ndarray, np.ndarray]:
  """(power_mw, area_mm2) of the global-buffer SRAM macro per config,
  memoized per unique config across all backends and callers.  ConfigTable
  inputs take the vectorized (unmemoized — it is cheaper than the cache
  lookup loop) path."""
  if isinstance(cfgs, ConfigTable):
    return gbuf_overheads_table(cfgs)
  pwr = np.empty(len(cfgs))
  area = np.empty(len(cfgs))
  for i, c in enumerate(cfgs):
    pwr[i], area[i] = _gbuf_cached(c)
  return pwr, area


def gbuf_overheads_table(table: ConfigTable, xp=np
                         ) -> Tuple[np.ndarray, np.ndarray]:
  """Vectorized :func:`gbuf_overheads` over a ConfigTable."""
  inputs = oracle.batch_inputs(table)
  return (oracle.gbuf_power_mw_batch(table, xp=xp, inputs=inputs),
          oracle.gbuf_area_mm2_batch(table, xp=xp, inputs=inputs))


# ---------------------------------------------------------------------------
# oracle backends (exact): scalar loop + vectorized chunked sibling
# ---------------------------------------------------------------------------

class OracleBackend:
  """Full characterization per design — the synthesis stand-in."""
  name = "oracle"

  def evaluate(self, cfgs: Configs, layers: Sequence[ConvLayer],
               network: str = "net") -> ResultFrame:
    cfgs = list(cfgs)
    lat = np.empty(len(cfgs))
    pwr = np.empty(len(cfgs))
    area = np.empty(len(cfgs))
    for i, cfg in enumerate(cfgs):
      ch = oracle.characterize(cfg, layers)
      lat[i], pwr[i], area[i] = ch.latency_s, ch.power_mw, ch.area_mm2
    return ResultFrame(lat, pwr, area,
                       np.asarray([c.pe_type for c in cfgs]),
                       tuple(cfgs), network)


class _LRUCache:
  """Tiny LRU for compiled executables: long-lived sessions sweeping many
  networks must not accumulate one jitted program per layer tuple.
  Lock-guarded: streaming pool workers may share one backend."""

  def __init__(self, maxsize: int):
    import threading
    from collections import OrderedDict
    self.maxsize = int(maxsize)
    self._d: "OrderedDict" = OrderedDict()
    self._lock = threading.Lock()

  def __len__(self) -> int:
    return len(self._d)

  def get(self, key):
    with self._lock:
      if key not in self._d:
        return None
      self._d.move_to_end(key)
      return self._d[key]

  def put(self, key, value) -> None:
    with self._lock:
      self._d[key] = value
      self._d.move_to_end(key)
      while len(self._d) > self.maxsize:
        self._d.popitem(last=False)


class VectorOracleBackend:
  """The synthesis stand-in, array-at-a-time over ConfigTables.

  Evaluates design points in bounded-memory chunks of ``chunk_size`` rows
  through the vectorized oracle/dataflow formulas.  On the default numpy
  path results are bit-identical to :class:`OracleBackend`.

  ``jit=True`` runs the per-chunk formulas under ``jax.jit`` as a
  first-class exact backend: the default ``precision="x64"`` traces with
  float64 enabled and host-precomputed transcendental columns (see
  :func:`repro.core.oracle.batch_inputs`), so device results are
  **bit-identical** to the numpy path; ``precision="float32"`` keeps the
  old approximate fast mode.  Joint sweeps compile the distinct-layer
  factorization with the stack as a traced input, so one executable
  serves every arch block of a streaming sweep.  When several devices
  are visible, chunk rows shard across them via ``shard_map``.

  The streaming engine additionally uses the ``*_pending`` entry points:
  chunks dispatch asynchronously (jax futures) and resolve later, and
  with a :class:`repro.explore.device.DevicePlan` the whole
  evaluate+reduce pipeline is fused on device so only O(survivors)
  floats come back per chunk.
  """
  name = "vector-oracle"
  prefers_table = True

  # compiled-program cache bound (stack/layers enter as traced inputs, so
  # entries are per (path, plan, precision), not per sweep content)
  JIT_CACHE_SIZE = 8

  def __init__(self, chunk_size: int = 65536, jit: bool = False,
               precision: str = "x64"):
    if chunk_size <= 0:
      raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    if precision not in ("x64", "float32"):
      raise ValueError(f"precision must be 'x64' or 'float32', "
                       f"got {precision!r}")
    self.chunk_size = chunk_size
    self.jit = jit
    self.precision = precision
    self._jit_cache = _LRUCache(self.JIT_CACHE_SIZE)
    import threading
    self._tls = threading.local()
    if jit and precision == "x64":
      # must precede this process's first XLA compilation (see device.py)
      from repro.explore.device import ensure_exact_cpu_codegen
      ensure_exact_cpu_codegen()

  def _scratch(self) -> Dict:
    """Per-worker-thread reusable feature-temporary buffers (numpy path
    only: the jit path hands jax freshly allocated arrays, which may be
    transferred asynchronously)."""
    d = getattr(self._tls, "scratch", None)
    if d is None:
      d = {}
      self._tls.scratch = d
    return d

  def _eval_chunk(self, chunk: ConfigTable, layers: Sequence[ConvLayer]):
    """numpy chunk evaluation, reusing this worker's scratch buffers."""
    inputs = oracle.batch_inputs(chunk, scratch=self._scratch())
    ch = oracle.characterize_batch(None, layers, inputs=inputs)
    return ch.latency_s, ch.power_mw, ch.area_mm2

  def _co_eval_chunk(self, chunk: ConfigTable, stack: LayerStack):
    """numpy joint chunk evaluation with scratch reuse."""
    inputs = oracle.batch_inputs(chunk, scratch=self._scratch())
    ch = oracle.characterize_joint(None, stack, inputs=inputs)
    return ch.latency_s, ch.power_mw, ch.area_mm2

  def evaluate(self, cfgs: Configs, layers: Sequence[ConvLayer],
               network: str = "net") -> ResultFrame:
    """Config lists are converted to a table; the frame keeps whichever
    design-point representation came in."""
    if isinstance(cfgs, ConfigTable):
      return self.evaluate_table(cfgs, layers, network)
    cfgs = list(cfgs)
    frame = self.evaluate_table(ConfigTable.from_configs(cfgs), layers,
                                network)
    frame.cfgs = tuple(cfgs)
    return frame

  def evaluate_table(self, table: ConfigTable, layers: Sequence[ConvLayer],
                     network: str = "net") -> ResultFrame:
    n = len(table)
    lat = np.empty(n)
    pwr = np.empty(n)
    area = np.empty(n)
    lo = 0
    for chunk in table.chunks(self.chunk_size):
      if self.jit:
        l, p, a = self._eval_chunk_jax(chunk, tuple(layers))
      else:
        l, p, a = self._eval_chunk(chunk, layers)
      hi = lo + len(chunk)
      lat[lo:hi], pwr[lo:hi], area[lo:hi] = l, p, a
      lo = hi
    return ResultFrame(lat, pwr, area, table.pe_type_strings(), (),
                       network, table=table)

  def co_evaluate_table(self, hw: ConfigTable, stack: LayerStack,
                        network: str = "coexplore") -> ResultFrame:
    """Joint HW x NN sweep: every stack architecture against every HW row.

    Evaluates ``characterize_joint`` over bounded-memory HW chunks (the
    working set is ``n_archs x hw_chunk`` where
    ``hw_chunk = chunk_size // n_archs``); clock/power/area are computed
    once per HW row, latency/energy once per pair.  Returns an arch-major
    joint frame (row ``a * n_hw + h``) carrying a lazy
    :class:`~repro.core.table.JointTable` plus an ``arch_id`` extra
    column — the caller (session) attaches ``top1`` and ``arch_lookup``.
    Bit-identical (numpy path) to the scalar per-(arch, hw) loop.
    """
    n_hw, n_archs = len(hw), stack.n_archs
    lat = np.empty((n_archs, n_hw))
    pwr = np.empty(n_hw)
    area = np.empty(n_hw)
    hw_chunk = max(1, self.chunk_size // max(n_archs, 1))
    dedup = stack.dedup_slots() if self.jit else None
    lo = 0
    for chunk in hw.chunks(hw_chunk):
      if self.jit:
        l, p, a = self._co_eval_chunk_jax(chunk, stack, dedup)
      else:
        l, p, a = self._co_eval_chunk(chunk, stack)
      hi = lo + len(chunk)
      lat[:, lo:hi], pwr[lo:hi], area[lo:hi] = l, p, a
      lo = hi
    joint = hw.cross(n_archs)
    return ResultFrame(
        lat.reshape(-1), np.tile(pwr, n_archs), np.tile(area, n_archs),
        joint.pe_type_strings(), (), network, table=joint,
        extra={"arch_id": joint.arch_ids()})

  # -- host fallback rungs --------------------------------------------------
  # The degradation ladder's terminal rung (repro.explore.resilience):
  # same formulas, numpy only — never touches jax even when ``jit=True``,
  # so a compile/OOM/transfer failure cannot recur here.  Bit-identical
  # to the device path by the exact-codegen parity contract.

  def host_evaluate_table(self, table: ConfigTable,
                          layers: Sequence[ConvLayer],
                          network: str = "net") -> ResultFrame:
    n = len(table)
    lat = np.empty(n)
    pwr = np.empty(n)
    area = np.empty(n)
    lo = 0
    for chunk in table.chunks(self.chunk_size):
      l, p, a = self._eval_chunk(chunk, layers)
      hi = lo + len(chunk)
      lat[lo:hi], pwr[lo:hi], area[lo:hi] = l, p, a
      lo = hi
    return ResultFrame(lat, pwr, area, table.pe_type_strings(), (),
                       network, table=table)

  def host_co_evaluate_table(self, hw: ConfigTable, stack: LayerStack,
                             network: str = "coexplore") -> ResultFrame:
    n_hw, n_archs = len(hw), stack.n_archs
    lat = np.empty((n_archs, n_hw))
    pwr = np.empty(n_hw)
    area = np.empty(n_hw)
    hw_chunk = max(1, self.chunk_size // max(n_archs, 1))
    lo = 0
    for chunk in hw.chunks(hw_chunk):
      l, p, a = self._co_eval_chunk(chunk, stack)
      hi = lo + len(chunk)
      lat[:, lo:hi], pwr[lo:hi], area[lo:hi] = l, p, a
      lo = hi
    joint = hw.cross(n_archs)
    return ResultFrame(
        lat.reshape(-1), np.tile(pwr, n_archs), np.tile(area, n_archs),
        joint.pe_type_strings(), (), network, table=joint,
        extra={"arch_id": joint.arch_ids()})

  # -- optional device path -------------------------------------------------
  # Joint programs take the sweep content (inputs bundle, dedup'd stack
  # arrays) as arguments — one LRU entry per (path kind, plan, precision),
  # jax handles shape specialization.  Plain-sweep programs still bake
  # the layer tuple into the trace (layer features are scalars there, and
  # one sweep evaluates one network), so their entries are per layer
  # tuple and sessions sweeping many networks recompile under LRU
  # eviction.

  def _x64(self):
    """Precision context: trace/run with float64 for the exact path."""
    if self.precision == "x64":
      from jax.experimental import enable_x64
      return enable_x64()
    import contextlib
    return contextlib.nullcontext()

  def _cached_fn(self, key, build):
    fn = self._jit_cache.get(key)
    if fn is None:
      if self.precision == "x64":
        from repro.explore.device import warn_if_inexact_codegen
        warn_if_inexact_codegen()
      fn = build()
      self._jit_cache.put(key, fn)
    return fn

  @staticmethod
  def _jit(fn):
    import jax
    kwargs = {}
    if jax.default_backend() != "cpu":
      # chunk input buffers are single-use: let XLA reuse their memory
      kwargs["donate_argnums"] = (0,)
    return jax.jit(fn, **kwargs)

  @staticmethod
  def _shard_rows(fn, joint: bool):
    """Shard the HW-row axis of a full (lat, pwr, area) program across
    visible devices (identity for a single device).  Fused programs run
    unsharded — their reductions are chunk-global; multi-device overlap
    comes from the dispatch-ahead window instead."""
    import jax
    import jax.numpy as jnp
    from repro.explore.fleet import visible_devices
    devices = visible_devices()
    if len(devices) <= 1:
      return fn
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.asarray(devices), ("batch",))
    out_specs = (P(None, "batch"), P("batch"), P("batch")) if joint \
        else (P("batch"), P("batch"), P("batch"))

    def rowwise(inputs, *rest):
      return fn(inputs, *rest)

    def padded(inputs, *rest):
      n = next(iter(inputs.values())).shape[0]
      pad = (-n) % len(devices)
      in_specs = (P("batch"),) + tuple(P() for _ in rest)
      sharded = shard_map(rowwise, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
      if pad:
        inputs = {k: jnp.concatenate([jnp.asarray(v),
                                      jnp.asarray(v[-1:]).repeat(pad, 0)])
                  for k, v in inputs.items()}
      l, p, a = sharded(inputs, *rest)
      if joint:
        return l[:, :n], p[:n], a[:n]
      return l[:n], p[:n], a[:n]

    return padded

  @staticmethod
  def _pinned():
    """The fleet layer's thread-local device pin (None: default
    placement) — see :func:`repro.explore.fleet.pin`."""
    from repro.explore import fleet
    return fleet.pinned_device()

  @staticmethod
  def _place(inputs, dev):
    """Commit a chunk's input arrays to the pinned device so the jitted
    program executes there.  Must run inside the ``_x64`` context —
    ``device_put`` canonicalizes dtypes, and float64 inputs would be
    silently downcast outside it."""
    if dev is None:
      return inputs
    import jax
    return jax.device_put(inputs, dev)

  def _eval_fn(self, layers: Tuple[ConvLayer, ...], plan=None,
               pinned: bool = False):
    from repro.explore import device as device_lib
    pinned = bool(pinned) and plan is None  # fused programs never shard

    def build():
      fn = device_lib.make_eval_fn(layers, plan)
      if plan is None and not pinned:
        fn = self._shard_rows(fn, joint=False)
      return self._jit(fn)

    return self._cached_fn(("eval", layers, plan, self.precision, pinned),
                           build)

  def _joint_fn(self, plan=None, pinned: bool = False):
    from repro.explore import device as device_lib
    pinned = bool(pinned) and plan is None  # fused programs never shard

    def build():
      fn = device_lib.make_joint_fn(plan)
      if plan is None and not pinned:
        fn = self._shard_rows(fn, joint=True)
      return self._jit(fn)

    return self._cached_fn(("joint", plan, self.precision, pinned), build)

  def _eval_chunk_jax(self, chunk: ConfigTable,
                      layers: Tuple[ConvLayer, ...]):
    import jax
    inputs = oracle.batch_inputs(chunk)  # variations need host uint64
    with self._x64():
      l, p, a = self._eval_fn(layers)(inputs)
    return (np.asarray(jax.device_get(l), np.float64),
            np.asarray(jax.device_get(p), np.float64),
            np.asarray(jax.device_get(a), np.float64))

  def _co_eval_chunk_jax(self, chunk: ConfigTable, stack: LayerStack,
                         dedup=None):
    import jax
    inputs = oracle.batch_inputs(chunk)
    unique_cols, slot_ids = stack.dedup_slots() if dedup is None else dedup
    with self._x64():
      # accs is only consumed by fused plans; an empty array keeps the
      # arg pytree shard_map-friendly (None has no pytree leaves)
      l, p, a = self._joint_fn()(inputs, unique_cols, slot_ids,
                                 stack.valid, np.zeros(0))
    return (np.asarray(jax.device_get(l), np.float64),
            np.asarray(jax.device_get(p), np.float64),
            np.asarray(jax.device_get(a), np.float64))

  # -- streaming entry points: async dispatch + optional fused reduction ----

  def eval_pending(self, table: ConfigTable, layers: Sequence[ConvLayer],
                   network: str, idx: np.ndarray):
    """Dispatch one streaming chunk; the returned PendingFrame resolves
    to the same (frame, idx) the numpy task path produces."""
    import jax
    from repro.explore import device as device_lib
    layers = tuple(layers)
    inputs = oracle.batch_inputs(table)
    dev = self._pinned()
    with self._x64():
      inputs = self._place(inputs, dev)
      out = self._eval_fn(layers, pinned=dev is not None)(inputs)

    def finalize():
      l, p, a = (np.asarray(jax.device_get(o), np.float64) for o in out)
      return ResultFrame(l, p, a, table.pe_type_strings(), (), network,
                         table=table), idx

    return device_lib.PendingFrame(finalize, buffers=out)

  def co_eval_pending(self, hw: ConfigTable, stack: LayerStack, network: str,
                      idx: np.ndarray, arch_lo: int, accs: np.ndarray,
                      arch_lookup: Tuple[object, ...], dedup=None):
    """Joint twin of :meth:`eval_pending` (arch columns attached on
    resolve, matching the host streaming task)."""
    import jax
    from repro.explore import device as device_lib
    inputs = oracle.batch_inputs(hw)
    unique_cols, slot_ids = stack.dedup_slots() if dedup is None else dedup
    dev = self._pinned()
    with self._x64():
      inputs = self._place(inputs, dev)
      out = self._joint_fn(pinned=dev is not None)(
          inputs, unique_cols, slot_ids, stack.valid, np.zeros(0))

    def finalize():
      lat, pwr, area = (np.asarray(jax.device_get(o), np.float64)
                        for o in out)
      return device_lib.joint_chunk_frame(
          lat, pwr, area, hw, network, arch_lo, accs, arch_lookup), idx

    return device_lib.PendingFrame(finalize, buffers=out)

  def fused_eval_pending(self, table: ConfigTable,
                         layers: Sequence[ConvLayer], network: str,
                         plan, idx: np.ndarray):
    """Dispatch one fused evaluate+reduce chunk (see
    :mod:`repro.explore.device`); resolves to per-reducer payloads with
    O(survivors) device->host transfer."""
    from repro.explore import device as device_lib
    layers = tuple(layers)
    inputs = oracle.batch_inputs(table)
    dev = self._pinned()
    with self._x64():
      inputs = self._place(inputs, dev)
      outputs = self._eval_fn(layers, plan)(inputs)
    return device_lib.PendingFused(outputs, plan, table, idx, network)

  def fused_co_eval_pending(self, hw: ConfigTable, stack: LayerStack,
                            network: str, plan, idx: np.ndarray,
                            arch_lo: int, accs: np.ndarray,
                            arch_lookup: Tuple[object, ...], dedup=None):
    """Joint twin of :meth:`fused_eval_pending`."""
    from repro.explore import device as device_lib
    inputs = oracle.batch_inputs(hw)
    unique_cols, slot_ids = stack.dedup_slots() if dedup is None else dedup
    accs = np.asarray(accs, np.float64)
    dev = self._pinned()
    with self._x64():
      inputs = self._place(inputs, dev)
      outputs = self._joint_fn(plan)(inputs, unique_cols, slot_ids,
                                     stack.valid, accs)
    return device_lib.PendingFused(outputs, plan, hw, idx, network,
                                   n_hw=len(hw), arch_lo=arch_lo, accs=accs,
                                   arch_lookup=arch_lookup)


# ---------------------------------------------------------------------------
# polynomial backend (fast, fit-once)
# ---------------------------------------------------------------------------

def _layers_fingerprint(layers: Optional[Sequence[ConvLayer]]) -> str:
  if layers is None:
    return "default-workloads"
  blob = repr(tuple((l.name, l.features()) for l in layers))
  return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _fit_key(pe_types: Tuple[str, ...], degree: int, n_train: int,
             seed: int, layers: Optional[Sequence[ConvLayer]]
             ) -> Tuple[str, ...]:
  # oracle.ORACLE_VERSION is part of the fingerprint: a cache fitted
  # against older oracle outputs must refit, not silently load
  return (",".join(pe_types), str(degree), str(n_train), str(seed),
          _layers_fingerprint(layers), f"oracle-v{oracle.ORACLE_VERSION}")


# in-process fit-once cache: identical fit requests share one model bundle
_FIT_CACHE: Dict[Tuple[str, ...], Dict[str, ppa_lib.PPAModels]] = {}

_MODEL_FIELDS = ("exponents", "col_scale", "coef")
_MODEL_SCALARS = ("degree", "y_scale", "log_target")
_TARGETS = ("power", "area", "latency")
_FORMAT_VERSION = 1


class PolynomialBackend:
  """QUIDAM's 3-4-orders-of-magnitude fast path over the PPA models."""
  name = "polynomial"

  def __init__(self, models: Dict[str, ppa_lib.PPAModels],
               loaded_from: Optional[str] = None):
    self.models = dict(models)
    self.loaded_from = loaded_from

  @property
  def pe_types(self) -> Tuple[str, ...]:
    return tuple(self.models)

  # -- fitting --------------------------------------------------------------

  @classmethod
  def fit(cls, pe_types: Sequence[str] = PAPER_PE_TYPES, degree: int = 5,
          n_train: int = 240, layers: Optional[Sequence[ConvLayer]] = None,
          seed: int = 0) -> "PolynomialBackend":
    """Characterize + fit once per PE type (seed offset i per type, like
    the legacy explorer); identical requests reuse the in-process cache."""
    pe_types = tuple(pe_types)
    key = _fit_key(pe_types, degree, n_train, seed, layers)
    if key not in _FIT_CACHE:
      _FIT_CACHE[key] = {
          t: ppa_lib.fit_ppa_models(t, degree=degree, n_train=n_train,
                                    layers=layers, seed=seed + i)
          for i, t in enumerate(pe_types)}
    return cls(_FIT_CACHE[key], loaded_from=None)

  @classmethod
  def fit_or_load(cls, path: str, pe_types: Sequence[str] = PAPER_PE_TYPES,
                  degree: int = 5, n_train: int = 240,
                  layers: Optional[Sequence[ConvLayer]] = None,
                  seed: int = 0) -> "PolynomialBackend":
    """Load fitted models from `path` when its fit fingerprint matches;
    otherwise fit fresh and save (benchmarks never refit across runs)."""
    want = "|".join(_fit_key(tuple(pe_types), degree, n_train, seed, layers))
    if os.path.exists(path):
      try:
        with np.load(path) as data:
          if str(data["meta/fit_key"]) == want:
            return cls._from_npz(data, path)
      # corrupt/stale/foreign cache file -> refit and overwrite below
      except Exception:  # repro: ignore[ROB001]
        pass
    backend = cls.fit(pe_types, degree, n_train, layers, seed)
    backend.save(path, fit_key=want)
    return backend

  # -- persistence ----------------------------------------------------------

  def save(self, path: str, fit_key: str = "") -> None:
    """Serialize every PolyModel exactly (float64 .npz: predictions after
    `load` are bit-identical)."""
    arrays: Dict[str, np.ndarray] = {
        "meta/version": np.asarray(_FORMAT_VERSION),
        "meta/pe_types": np.asarray(list(self.models)),
        "meta/fit_key": np.asarray(fit_key),
    }
    for t, bundle in self.models.items():
      arrays[f"{t}/degree"] = np.asarray(bundle.degree)
      for target in _TARGETS:
        model: ppa_lib.PolyModel = getattr(bundle, target)
        base = f"{t}/{target}"
        arrays[f"{base}/exponents"] = model.exponents
        arrays[f"{base}/col_scale"] = model.col_scale
        arrays[f"{base}/coef"] = model.coef
        arrays[f"{base}/degree"] = np.asarray(model.degree)
        arrays[f"{base}/y_scale"] = np.asarray(model.y_scale)
        arrays[f"{base}/log_target"] = np.asarray(model.log_target)
    d = os.path.dirname(path)
    if d:
      os.makedirs(d, exist_ok=True)
    np.savez(path, **arrays)

  @classmethod
  def load(cls, path: str) -> "PolynomialBackend":
    with np.load(path) as data:
      return cls._from_npz(data, path)

  @classmethod
  def _from_npz(cls, data, path: str) -> "PolynomialBackend":
    version = int(data["meta/version"])
    if version != _FORMAT_VERSION:
      raise ValueError(f"{path}: unsupported model-bundle version {version}")
    models = {}
    for t in data["meta/pe_types"]:
      t = str(t)
      parts = {}
      for target in _TARGETS:
        base = f"{t}/{target}"
        parts[target] = ppa_lib.PolyModel(
            degree=int(data[f"{base}/degree"]),
            exponents=data[f"{base}/exponents"],
            col_scale=data[f"{base}/col_scale"],
            coef=data[f"{base}/coef"],
            y_scale=float(data[f"{base}/y_scale"]),
            log_target=bool(data[f"{base}/log_target"]))
      models[t] = ppa_lib.PPAModels(pe_type=t, degree=int(data[f"{t}/degree"]),
                                    **parts)
    return cls(models, loaded_from=path)

  # -- evaluation -----------------------------------------------------------

  def evaluate(self, cfgs: Configs, layers: Sequence[ConvLayer],
               network: str = "net") -> ResultFrame:
    """Batched prediction, grouped by PE type (one model set per type).
    ConfigTables take the fully columnar path."""
    if isinstance(cfgs, ConfigTable):
      return self.evaluate_table(cfgs, layers, network)
    cfgs = list(cfgs)
    by_type: Dict[str, List[int]] = {}
    for i, c in enumerate(cfgs):
      by_type.setdefault(c.pe_type, []).append(i)
    missing = set(by_type) - set(self.models)
    if missing:
      raise KeyError(f"backend has no models for PE types {sorted(missing)}; "
                     f"fitted types: {sorted(self.models)}")
    lat = np.zeros(len(cfgs))
    pwr = np.zeros(len(cfgs))
    area = np.zeros(len(cfgs))
    for pe_type, idxs in by_type.items():
      sub = [cfgs[i] for i in idxs]
      m = self.models[pe_type]
      lat[idxs] = np.maximum(m.predict_network_latency_s(sub, layers), 1e-9)
      gb_p, gb_a = gbuf_overheads(sub)
      pwr[idxs] = np.maximum(m.predict_power_mw(sub), 1e-3) + gb_p
      area[idxs] = np.maximum(m.predict_area_mm2(sub), 1e-6) + gb_a
    return ResultFrame(lat, pwr, area,
                       np.asarray([c.pe_type for c in cfgs]),
                       tuple(cfgs), network)

  def evaluate_table(self, table: ConfigTable, layers: Sequence[ConvLayer],
                     network: str = "net",
                     chunk_size: int = 32768) -> ResultFrame:
    """Columnar prediction over a ConfigTable, per-PE-type model sets, in
    bounded-memory chunks (the latency feature matrix is rows x layers
    wide — chunking caps it at ``chunk_size * len(layers)`` rows)."""
    missing = {t for t, idx in table.groups_by_type()} - set(self.models)
    if missing:
      raise KeyError(f"backend has no models for PE types {sorted(missing)}; "
                     f"fitted types: {sorted(self.models)}")
    n = len(table)
    lat = np.zeros(n)
    pwr = np.zeros(n)
    area = np.zeros(n)
    for pe_type, idxs in table.groups_by_type():
      m = self.models[pe_type]
      for lo in range(0, idxs.size, chunk_size):
        sel = idxs[lo:lo + chunk_size]
        sub = table.select(sel)
        lat[sel] = np.maximum(
            m.predict_network_latency_s(sub, layers), 1e-9)
        gb_p, gb_a = gbuf_overheads_table(sub)
        pwr[sel] = np.maximum(m.predict_power_mw(sub), 1e-3) + gb_p
        area[sel] = np.maximum(m.predict_area_mm2(sub), 1e-6) + gb_a
    return ResultFrame(lat, pwr, area, table.pe_type_strings(), (),
                       network, table=table)

  def co_evaluate_table(self, hw: ConfigTable, stack: LayerStack,
                        network: str = "coexplore",
                        chunk_size: int = 32768) -> ResultFrame:
    """Joint HW x NN sweep through the fitted models.

    Power/area (+ the memoized global-buffer macro) are predicted once
    per HW row; latency is predicted per (arch, HW) pair from the stack's
    precomputed feature tensors — no per-pair Python objects, and the
    per-arch predictions are bit-identical to
    ``predict_network_latency_s(sub, arch_layers)`` on the scalar loop.
    Returns the same arch-major joint frame as
    :meth:`VectorOracleBackend.co_evaluate_table`.
    """
    missing = {t for t, idx in hw.groups_by_type()} - set(self.models)
    if missing:
      raise KeyError(f"backend has no models for PE types {sorted(missing)}; "
                     f"fitted types: {sorted(self.models)}")
    n_hw, n_archs = len(hw), stack.n_archs
    lat = np.zeros((n_archs, n_hw))
    pwr = np.zeros(n_hw)
    area = np.zeros(n_hw)
    feats = stack.features()
    n_layers = stack.n_layers()
    hw_chunk = max(1, chunk_size // max(stack.max_layers, 1))
    for pe_type, idxs in hw.groups_by_type():
      m = self.models[pe_type]
      for lo in range(0, idxs.size, hw_chunk):
        sel = idxs[lo:lo + hw_chunk]
        sub = hw.select(sel)
        gb_p, gb_a = gbuf_overheads_table(sub)
        pwr[sel] = np.maximum(m.predict_power_mw(sub), 1e-3) + gb_p
        area[sel] = np.maximum(m.predict_area_mm2(sub), 1e-6) + gb_a
        hw_feats = sub.latency_hw_features()
        for a in range(n_archs):
          lf = feats[a, :int(n_layers[a])]
          lat[a, sel] = np.maximum(
              m.predict_network_latency_feats(hw_feats, lf), 1e-9)
    joint = hw.cross(n_archs)
    return ResultFrame(
        lat.reshape(-1), np.tile(pwr, n_archs), np.tile(area, n_archs),
        joint.pe_type_strings(), (), network, table=joint,
        extra={"arch_id": joint.arch_ids()})
