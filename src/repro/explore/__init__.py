"""repro.explore — the unified design-space exploration API.

This package is the single public entry point for QUIDAM-style
fit-once / evaluate-many DSE and HW x NN co-exploration:

  DesignSpace          declarative space spec: axes (from HW_RANGES), PE
                       types, constraints; grid/random/stratified sampling
                       with deterministic seeds                 [space]
  EvaluationBackend    protocol turning (configs, workload) -> results
    OracleBackend      slow, exact per-design characterization
    PolynomialBackend  fast polynomial PPA models; fit-once cached,
                       save/load to .npz                        [backend]
  ResultFrame          columnar (struct-of-arrays) results with vectorized
                       .pareto(), .normalize(), .stats(), .top_k() [frame]
  ExplorationSession   facade driving plain DSE and co-exploration over
                       the same backend + space                 [session]

Quickstart::

    from repro.explore import (DesignSpace, ExplorationSession,
                               PolynomialBackend)
    from repro.core.workloads import get_network

    layers = get_network("resnet20")
    backend = PolynomialBackend.fit(layers=layers)   # or .fit_or_load(path)
    frame = ExplorationSession(backend).explore(layers, "resnet20")
    ppa_n, energy_n = frame.normalize(ref="best-int16")
    best = frame.top_k(1, by="perf_per_area")

The legacy ``repro.core.dse`` / ``repro.core.coexplore`` modules remain as
thin compatibility shims over this package.
"""
from repro.explore.backend import (EvaluationBackend, OracleBackend,
                                   PolynomialBackend, gbuf_overheads)
from repro.explore.frame import (DesignPoint, Normalized, ResultFrame,
                                 pareto_mask, summary_stats)
from repro.explore.session import ExplorationSession
from repro.explore.space import AXIS_ORDER, Axis, DesignSpace

__all__ = [
    "AXIS_ORDER", "Axis", "DesignPoint", "DesignSpace", "EvaluationBackend",
    "ExplorationSession", "Normalized", "OracleBackend", "PolynomialBackend",
    "ResultFrame", "gbuf_overheads", "pareto_mask", "summary_stats",
]
