"""repro.explore — the unified design-space exploration API.

This package is the single public entry point for QUIDAM-style
fit-once / evaluate-many DSE and HW x NN co-exploration:

  DesignSpace          declarative space spec: axes (from HW_RANGES), PE
                       types, constraints; grid/random/stratified sampling
                       with deterministic seeds; list or ConfigTable
                       materialization                          [space]
  ConfigTable          struct-of-arrays design points — the input-side
                       twin of ResultFrame (re-export of
                       repro.core.table)                        [table]
  JointTable           lazy archs x ConfigTable cross product for HW x NN
                       co-exploration (``table.cross(n_archs)``); pairs
                       exist only as integer index arithmetic   [table]
  LayerStack           padded (n_archs, max_layers) layer-feature tensors
                       feeding the joint batch dataflow model
                       (re-export of repro.core.dataflow)     [dataflow]
  EvaluationBackend    protocol turning (configs, workload) -> results
    OracleBackend      slow, exact per-design characterization
    VectorOracleBackend  the same oracle vectorized over ConfigTables in
                       bounded-memory chunks (optional jax.jit path)
    PolynomialBackend  fast polynomial PPA models; fit-once cached,
                       save/load to .npz; list or table inputs  [backend]
  ResultFrame          columnar (struct-of-arrays) results with vectorized
                       .pareto(), .normalize(), .stats(), .top_k() [frame]
  ExplorationSession   facade driving plain DSE and co-exploration over
                       the same backend + space                 [session]
  guided search        NSGA-II-style multi-objective optimizer over the
                       evaluate pipeline — one generation == one chunk
                       (device-resident on a jit backend), surrogate
                       screening by expected hypervolume gain, fronts
                       folded through ParetoAccumulator:
                       ``session.optimize(...)``; front-quality helpers
                       ``hypervolume``/``nondominated_ranks``/
                       ``crowding_distance``                     [search]
  streaming engine     constant-memory, parallel sweeps with online
                       reduction: ParetoAccumulator, TopKAccumulator,
                       StatsAccumulator, HistogramAccumulator fold lazy
                       chunks (``DesignSpace.iter_tables`` /
                       ``JointTable.block_slices``) into survivors-only
                       results — ``session.explore(stream=True,
                       reducers=...)`` / ``co_explore(stream=True)``
                                                              [streaming]
  device programs      the ``VectorOracleBackend(jit=True)`` streaming
                       path: exact x64 evaluation bit-identical to numpy,
                       fused on-device pareto/top-k/stats reduction with
                       O(survivors) transfer, async dispatch-ahead
                       (imported lazily — see note below)        [device]
  resilience           fault-tolerant sweeps: chunk retry (RetryPolicy),
                       graceful device->host degradation + watchdog
                       (ResiliencePolicy), journaled checkpoint/resume
                       (SweepJournal + ``resume_from=``), deterministic
                       fault injection (FaultPlan) — results stay
                       bit-identical through all of it        [resilience]
  fleet execution      elastic device-fleet sweeps: one shared DevicePool
                       health registry (per-device EWMA latency +
                       circuit breakers), straggler speculation, elastic
                       resharding on device loss, and a silent-data-
                       corruption sentinel built on the exact-parity
                       contract — ``run_stream(..., pool=DevicePool())``
                       or ``stream_explore(..., pool=...)``       [fleet]
  exploration service  concurrent sessions over one shared executor:
                       admission control + typed backpressure, per-request
                       deadlines and cooperative cancellation, a shared
                       device circuit breaker, fair round-robin
                       interleaving (ExplorationService)         [service]
  result store         content-addressed crash-safe cache of finished
                       sweeps (atomic writes, sha256 self-checksums,
                       quarantine) + delta-sweeps re-evaluating only an
                       edited axis' new subgrid (ResultStore,
                       cached_stream_explore)                     [store]

Quickstart::

    from repro.explore import (DesignSpace, ExplorationSession,
                               PolynomialBackend, VectorOracleBackend)
    from repro.core.workloads import get_network

    layers = get_network("resnet20")
    backend = PolynomialBackend.fit(layers=layers)   # or .fit_or_load(path)
    frame = ExplorationSession(backend).explore(layers, "resnet20")
    ppa_n, energy_n = frame.normalize(ref="best-int16")
    best = frame.top_k(1, by="perf_per_area")

    # exact-oracle sweep over 1M design points, fully vectorized:
    session = ExplorationSession(VectorOracleBackend(chunk_size=65536))
    big = session.explore(layers, "resnet20", n_per_type=250_000)

    # joint HW x NN co-exploration, also vectorized (arch features stack
    # once; HW x arch pairs never become Python objects):
    joint = session.co_explore(arch_accs, n_hw_per_type=250)  # auto=joint
    front3 = joint.pareto(("top1_err", "energy_mj", "area_mm2"))

The legacy ``repro.core.dse`` / ``repro.core.coexplore`` modules remain as
thin compatibility shims over this package.  See ``docs/explore.md`` for
the full guide and ``docs/architecture.md`` for the paper-to-code map.
"""
from repro.core.dataflow import LayerStack
from repro.core.table import ConfigTable, JointTable
from repro.explore.backend import (EvaluationBackend, OracleBackend,
                                   PolynomialBackend, VectorOracleBackend,
                                   gbuf_overheads, gbuf_overheads_table)
# NOTE: repro.explore.device is intentionally NOT imported here — its
# import sets process-global XLA exactness flags (no FMA contraction, no
# algebraic simplifier), which mixed jax workloads may not want.  It
# loads automatically when a VectorOracleBackend(jit=True) is built or a
# streaming sweep hits the device path; import it explicitly (before any
# jax compilation) when you need the flags earlier.
from repro.explore.fleet import (DevicePool, device_topology, run_fleet,
                                 visible_devices)
from repro.explore.frame import (DesignPoint, Normalized, ResultFrame,
                                 pareto_mask, stable_topk_indices,
                                 summary_stats)
from repro.explore.resilience import (ChunkError, ChunkTask, Fault,
                                      FaultInjected, FaultPlan, InjectedHang,
                                      ResiliencePolicy, RetryPolicy, Rung,
                                      SweepJournal, SweepKilled, sweep_key)
from repro.explore.resilience import CircuitBreaker
from repro.explore.search import (crowding_distance, guided_search,
                                  hypervolume, nondominated_ranks,
                                  objective_matrix)
from repro.explore.service import (AdmissionRejected, BudgetExhausted,
                                   Deadline, DeadlineExceeded,
                                   ExplorationService, SessionCancelled,
                                   SessionHandle)
from repro.explore.session import ExplorationSession
from repro.explore.space import (AXIS_ORDER, Axis, DesignSpace,
                                 VectorConstraint, vector_constraint)
from repro.explore.streaming import (STREAM_AUTO_MIN_ROWS,
                                     CollectAccumulator,
                                     HistogramAccumulator, ParetoAccumulator,
                                     Reducer, StatsAccumulator, StreamResult,
                                     TopKAccumulator, stream_co_explore,
                                     stream_explore)
from repro.explore.store import (ResultStore, cached_stream_co_explore,
                                 cached_stream_explore)

__all__ = [
    "AXIS_ORDER", "AdmissionRejected", "Axis", "BudgetExhausted",
    "ChunkError", "ChunkTask", "CircuitBreaker", "CollectAccumulator",
    "ConfigTable", "Deadline", "DeadlineExceeded", "DesignPoint",
    "DesignSpace", "DevicePool", "EvaluationBackend", "ExplorationService",
    "ExplorationSession", "Fault", "FaultInjected", "FaultPlan",
    "HistogramAccumulator", "InjectedHang", "JointTable", "LayerStack",
    "Normalized", "OracleBackend", "ParetoAccumulator", "PolynomialBackend",
    "Reducer", "ResiliencePolicy", "ResultFrame", "ResultStore",
    "RetryPolicy", "Rung", "STREAM_AUTO_MIN_ROWS", "SessionCancelled",
    "SessionHandle", "StatsAccumulator", "StreamResult", "SweepJournal",
    "SweepKilled", "TopKAccumulator", "VectorConstraint",
    "VectorOracleBackend", "cached_stream_co_explore",
    "cached_stream_explore", "crowding_distance", "device_topology",
    "gbuf_overheads", "gbuf_overheads_table", "guided_search",
    "hypervolume", "nondominated_ranks", "objective_matrix", "pareto_mask",
    "run_fleet", "stable_topk_indices", "stream_co_explore",
    "stream_explore", "summary_stats", "sweep_key", "vector_constraint",
    "visible_devices",
]
