"""repro.explore — the unified design-space exploration API.

This package is the single public entry point for QUIDAM-style
fit-once / evaluate-many DSE and HW x NN co-exploration:

  DesignSpace          declarative space spec: axes (from HW_RANGES), PE
                       types, constraints; grid/random/stratified sampling
                       with deterministic seeds; list or ConfigTable
                       materialization                          [space]
  ConfigTable          struct-of-arrays design points — the input-side
                       twin of ResultFrame (re-export of
                       repro.core.table)                        [table]
  EvaluationBackend    protocol turning (configs, workload) -> results
    OracleBackend      slow, exact per-design characterization
    VectorOracleBackend  the same oracle vectorized over ConfigTables in
                       bounded-memory chunks (optional jax.jit path)
    PolynomialBackend  fast polynomial PPA models; fit-once cached,
                       save/load to .npz; list or table inputs  [backend]
  ResultFrame          columnar (struct-of-arrays) results with vectorized
                       .pareto(), .normalize(), .stats(), .top_k() [frame]
  ExplorationSession   facade driving plain DSE and co-exploration over
                       the same backend + space                 [session]

Quickstart::

    from repro.explore import (DesignSpace, ExplorationSession,
                               PolynomialBackend, VectorOracleBackend)
    from repro.core.workloads import get_network

    layers = get_network("resnet20")
    backend = PolynomialBackend.fit(layers=layers)   # or .fit_or_load(path)
    frame = ExplorationSession(backend).explore(layers, "resnet20")
    ppa_n, energy_n = frame.normalize(ref="best-int16")
    best = frame.top_k(1, by="perf_per_area")

    # exact-oracle sweep over 1M design points, fully vectorized:
    session = ExplorationSession(VectorOracleBackend(chunk_size=65536))
    big = session.explore(layers, "resnet20", n_per_type=250_000)

The legacy ``repro.core.dse`` / ``repro.core.coexplore`` modules remain as
thin compatibility shims over this package.  See ``docs/explore.md`` for
the full guide and ``docs/architecture.md`` for the paper-to-code map.
"""
from repro.core.table import ConfigTable
from repro.explore.backend import (EvaluationBackend, OracleBackend,
                                   PolynomialBackend, VectorOracleBackend,
                                   gbuf_overheads, gbuf_overheads_table)
from repro.explore.frame import (DesignPoint, Normalized, ResultFrame,
                                 pareto_mask, summary_stats)
from repro.explore.session import ExplorationSession
from repro.explore.space import (AXIS_ORDER, Axis, DesignSpace,
                                 VectorConstraint, vector_constraint)

__all__ = [
    "AXIS_ORDER", "Axis", "ConfigTable", "DesignPoint", "DesignSpace",
    "EvaluationBackend", "ExplorationSession", "Normalized", "OracleBackend",
    "PolynomialBackend", "ResultFrame", "VectorConstraint",
    "VectorOracleBackend", "gbuf_overheads", "gbuf_overheads_table",
    "pareto_mask", "summary_stats", "vector_constraint",
]
