"""ExplorationSession: the one facade over plain DSE and HW x NN
co-exploration.

A session binds an :class:`EvaluationBackend` (how points are scored) to a
:class:`DesignSpace` (which points exist) and drives both exploration
flavours over the same machinery:

  explore(...)      sample hardware configs, evaluate one workload
                    -> ResultFrame (timings in frame.meta)
  co_explore(...)   pair sampled hardware with supernet-evaluated NN
                    architectures -> ResultFrame with top1/arch columns

``explore`` picks between two sampling materializations: the legacy
per-point config list, and the columnar :class:`ConfigTable` path for
backends that prefer it (``prefers_table = True``, e.g.
:class:`~repro.explore.VectorOracleBackend`) — million-point sweeps then
stay struct-of-arrays from sampling through evaluation to the frame.

Both methods also route into the streaming engine
(:mod:`repro.explore.streaming`): explicitly with ``stream=True`` +
``reducers`` (constant memory, survivors-only :class:`StreamResult`
out), or implicitly when ``vectorized="auto"`` sees a sweep of
``STREAM_AUTO_MIN_ROWS``+ rows on a table-capable backend — the engine
then evaluates chunks on a thread pool and reassembles the identical
full frame (parallel throughput, one-shot semantics).

On a ``VectorOracleBackend(jit=True)`` the streaming engine goes
device-resident: exact x64 evaluation under ``jax.jit`` (bit-identical
to the numpy path), asynchronous dispatch-ahead chunk scheduling, and —
when every reducer is device-fusable — fused on-device reduction so
only O(survivors) floats come back per chunk
(:mod:`repro.explore.device`).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.dataflow import AcceleratorConfig, ConvLayer
from repro.explore.backend import EvaluationBackend, OracleBackend
from repro.explore.frame import ResultFrame
from repro.explore.space import DesignSpace
from repro.explore.streaming import (STREAM_AUTO_MIN_ROWS,
                                     CollectAccumulator, Reducer,
                                     StreamResult, stream_co_explore,
                                     stream_explore)


class ExplorationSession:
  """Fit-once / evaluate-many driver over a backend + space pair."""

  def __init__(self, backend: EvaluationBackend,
               space: Optional[DesignSpace] = None):
    self.backend = backend
    if space is None:
      pe_types = getattr(backend, "pe_types", None)
      space = DesignSpace(pe_types=pe_types) if pe_types else DesignSpace()
    self.space = space

  def evaluate(self, cfgs: Sequence[AcceleratorConfig],
               layers: Sequence[ConvLayer],
               network: str = "net") -> ResultFrame:
    """Score explicit configs through the session's backend."""
    return self.backend.evaluate(cfgs, layers, network)

  def explore(self, layers: Sequence[ConvLayer], network: str,
              n_per_type: int = 200, seed: int = 17,
              method: str = "random", measure_oracle: int = 0,
              vectorized: Union[bool, str] = "auto", stream: bool = False,
              reducers: Optional[Dict[str, Reducer]] = None,
              chunk_size: int = 65536, workers: Optional[int] = None,
              policy=None, resume_from=None, checkpoint_every: int = 1,
              store=None, pool=None) -> Union[ResultFrame, StreamResult]:
    """Sample the space, evaluate `network`; optionally time the oracle on
    the first `measure_oracle` configs for the paper's speedup claim.

    vectorized: "auto" (default) samples a columnar ConfigTable when the
    backend advertises ``prefers_table``; True forces the table path for
    any backend with ``evaluate_table``; False keeps the legacy per-point
    config list (bit-compatible with the pre-table sampler sequences).

    stream=True runs the constant-memory streaming engine instead and
    returns a :class:`StreamResult` of reducer outputs (default: one
    ParetoAccumulator) — survivors bit-identical to the one-shot frame's
    ``pareto``/``top_k`` on the numpy path.  With ``vectorized="auto"``
    and no explicit ``stream``, sweeps of ``STREAM_AUTO_MIN_ROWS``+ rows
    still go through the engine with a CollectAccumulator: parallel
    chunked evaluation, identical full ResultFrame out (meta carries
    ``streamed``/``workers``).

    frame.meta carries: eval_seconds, eval_us_per_design, and (when
    measured) oracle_seconds_per_design + speedup.

    ``policy`` / ``resume_from`` / ``checkpoint_every`` (stream=True
    only) enable chunk retry + graceful degradation and journaled
    resume — see :mod:`repro.explore.resilience`.
    """
    if reducers is not None and not stream:
      raise ValueError("reducers only apply to the streaming engine; "
                       "pass stream=True")
    if (policy is not None or resume_from is not None
        or pool is not None) and not stream:
      raise ValueError("policy/resume_from/pool apply to the streaming "
                       "engine; pass stream=True")
    if store is not None and not stream:
      raise ValueError("store applies to the streaming engine; "
                       "pass stream=True")
    if stream:
      if measure_oracle:
        raise ValueError("measure_oracle is a one-shot feature; "
                         "pass stream=False")
      if store is not None:
        from repro.explore.store import cached_stream_explore
        return cached_stream_explore(self.backend, self.space, layers,
                                     network, n_per_type=n_per_type,
                                     seed=seed, method=method,
                                     reducers=reducers,
                                     chunk_size=chunk_size, workers=workers,
                                     policy=policy,
                                     checkpoint_every=checkpoint_every,
                                     store=store, pool=pool)
      return stream_explore(self.backend, self.space, layers, network,
                            n_per_type=n_per_type, seed=seed, method=method,
                            reducers=reducers, chunk_size=chunk_size,
                            workers=workers, policy=policy,
                            resume_from=resume_from,
                            checkpoint_every=checkpoint_every, pool=pool)
    if vectorized == "auto":
      use_table = bool(getattr(self.backend, "prefers_table", False))
    else:
      use_table = bool(vectorized)
    if use_table and not hasattr(self.backend, "evaluate_table"):
      raise ValueError(f"backend {self.backend.name!r} has no "
                       "evaluate_table; pass vectorized=False")
    if (use_table and vectorized == "auto" and not measure_oracle
        and n_per_type * len(self.space.pe_types) >= STREAM_AUTO_MIN_ROWS):
      return self._explore_streamed_frame(layers, network, n_per_type, seed,
                                          method, chunk_size, workers)
    if use_table:
      cfgs = self.space.sample_table(n_per_type, seed=seed, method=method)
    else:
      cfgs = self.space.sample(n_per_type, seed=seed, method=method)
    t0 = time.perf_counter()
    frame = self.backend.evaluate(cfgs, layers, network)
    t_eval = time.perf_counter() - t0
    n = max(len(frame), 1)
    frame.meta["eval_seconds"] = t_eval
    frame.meta["eval_us_per_design"] = t_eval / n * 1e6
    if measure_oracle:
      k = min(measure_oracle, len(cfgs))
      sample = cfgs.select(slice(0, k)).to_configs() \
          if use_table else cfgs[:k]
      t1 = time.perf_counter()
      OracleBackend().evaluate(sample, layers, network)
      per_design = (time.perf_counter() - t1) / max(k, 1)
      frame.meta["oracle_seconds_per_design"] = per_design
      frame.meta["speedup"] = per_design / max(t_eval / n, 1e-12)
    return frame

  @staticmethod
  def _collected_frame(res: StreamResult) -> ResultFrame:
    """Unwrap a CollectAccumulator run: the identical full frame, tagged
    with how it was produced."""
    frame = res["frame"]
    frame.meta["streamed"] = 1.0
    frame.meta["workers"] = res.meta["workers"]
    return frame

  def _explore_streamed_frame(self, layers, network, n_per_type, seed,
                              method, chunk_size, workers) -> ResultFrame:
    """The auto above-threshold path: parallel chunked evaluation through
    the engine, identical full frame out (CollectAccumulator)."""
    res = stream_explore(self.backend, self.space, layers, network,
                         n_per_type=n_per_type, seed=seed, method=method,
                         reducers={"frame": CollectAccumulator()},
                         chunk_size=chunk_size, workers=workers)
    frame = self._collected_frame(res)
    frame.meta["eval_seconds"] = res.seconds
    frame.meta["eval_us_per_design"] = res.seconds / max(len(frame), 1) * 1e6
    return frame

  def optimize(self, layers: Optional[Sequence[ConvLayer]] = None,
               network: str = "search", *,
               arch_accs: Optional[Sequence[Tuple[object, float]]] = None,
               objectives: Optional[Sequence[str]] = None,
               maximize: Optional[Sequence[str]] = None,
               population: int = 32, generations: int = 12, seed: int = 17,
               image_size: int = 32, surrogate: bool = False,
               surrogate_pool: int = 4, crossover_rate: float = 0.9,
               mutation_rate: Optional[float] = None,
               reducers: Optional[Dict[str, Reducer]] = None,
               policy=None, resume_from=None, checkpoint_every: int = 1
               ) -> StreamResult:
    """Guided multi-objective search (:mod:`repro.explore.search`) instead
    of enumeration: an NSGA-II-style optimizer whose generations evaluate
    as single chunks through this session's backend, fronts folding
    through the chunk-order-invariant ParetoAccumulator — the same
    :class:`StreamResult` the streaming engine returns, same-seed reruns
    bit-identical.

    Two modes, like :meth:`explore` / :meth:`co_explore`:

      * HW-only (pass ``layers``): searches the DesignSpace for one
        workload; default objectives ``("perf_per_area", "energy_mj")``
        (the paper's front axes).  On a ``VectorOracleBackend(jit=True)``
        each generation is one device-resident ``eval_pending`` dispatch
        (exact x64: the search trajectory is bit-identical to numpy).
      * joint (pass ``arch_accs``): the architecture choice becomes one
        more integer gene, and each generation evaluates grouped by
        architecture through ``evaluate_table``; default objectives
        ``("top1_err", "energy_mj", "area_mm2")`` (the Fig. 12 front).
        Requires a non-jit backend — per-arch layer programs would
        thrash the bounded jit cache, so this path refuses rather than
        silently recompiling every generation.

    ``surrogate=True`` adds online polynomial screening (QAPPA-style
    models refit on all evaluated points each generation) — proposals
    are pre-ranked by expected hypervolume gain before spending budget.
    ``meta`` carries evaluations / generations / hypervolume.
    """
    from repro.explore import search as _search  # local: keep header lean
    if (layers is None) == (arch_accs is None):
      raise ValueError("pass exactly one of layers= (HW-only search) or "
                       "arch_accs= (joint search)")
    if arch_accs is None:
      if objectives is None:
        objectives = ("perf_per_area", "energy_mj")
      use_device = bool(getattr(self.backend, "jit", False)) \
          and hasattr(self.backend, "eval_pending")
      use_table = hasattr(self.backend, "evaluate_table")
      layer_key = tuple(layers)

      def evaluate(table, idx, arch):
        if use_device:
          return self.backend.eval_pending(table, layer_key, network, idx)
        if use_table:
          return self.backend.evaluate_table(table, layers, network), idx
        return self.backend.evaluate(table.to_configs(), layers, network), idx

      return _search.guided_search(
          self.space, evaluate, objectives, maximize=maximize,
          population=population, generations=generations, seed=seed,
          surrogate=surrogate, surrogate_pool=surrogate_pool,
          crossover_rate=crossover_rate, mutation_rate=mutation_rate,
          reducers=reducers, policy=policy, resume_from=resume_from,
          checkpoint_every=checkpoint_every)

    from repro.core.supernet import arch_to_layers  # deferred: pulls jax
    if objectives is None:
      objectives = ("top1_err", "energy_mj", "area_mm2")
    if getattr(self.backend, "jit", False):
      raise ValueError(
          "joint optimize() needs a non-jit backend: each generation "
          "evaluates per-architecture layer lists, which would thrash "
          "the bounded jit program cache; use VectorOracleBackend() or "
          "PolynomialBackend")
    use_table = hasattr(self.backend, "evaluate_table")
    archs = [arch for arch, _ in arch_accs]
    accs = np.asarray([float(acc) for _, acc in arch_accs], np.float64)
    arch_layers = [arch_to_layers(arch, image_size=image_size)
                   for arch in archs]

    def evaluate(table, idx, arch):
      # group rows by architecture gene (one evaluate_table per distinct
      # arch in the generation), then reassemble in genome row order
      parts: List[ResultFrame] = []
      rows: List[np.ndarray] = []
      for aid in np.unique(arch):
        sel = np.flatnonzero(arch == aid)
        sub = table.select(sel)
        if use_table:
          f = self.backend.evaluate_table(sub, arch_layers[aid], network)
        else:
          f = self.backend.evaluate(sub.to_configs(), arch_layers[aid],
                                    network)
        f.extra["top1"] = np.full(len(f), accs[aid])
        f.extra["arch_id"] = np.full(len(f), aid, np.int64)
        f.arch_lookup = tuple(archs)
        parts.append(f)
        rows.append(sel)
      frame = ResultFrame.concat(parts)
      perm = np.concatenate(rows)
      inv = np.empty_like(perm)
      inv[perm] = np.arange(perm.shape[0])
      return frame.select(inv), idx

    def features(table, arch):
      # the arch gene enters the surrogate as its accuracy (the quantity
      # the top1_err objective actually depends on), not as a raw id
      base = _search.default_features(table, None)
      return np.concatenate([base, accs[arch][:, None]], axis=1)

    return _search.guided_search(
        self.space, evaluate, objectives, maximize=maximize,
        population=population, generations=generations, seed=seed,
        surrogate=surrogate, surrogate_pool=surrogate_pool,
        features=features, crossover_rate=crossover_rate,
        mutation_rate=mutation_rate, n_archs=len(archs),
        reducers=reducers, policy=policy, resume_from=resume_from,
        checkpoint_every=checkpoint_every)

  def co_explore(self, arch_accs: Sequence[Tuple[object, float]],
                 n_hw_per_type: int = 20, seed: int = 3,
                 image_size: int = 32, method: str = "random",
                 vectorized: Union[bool, str] = "auto", stream: bool = False,
                 reducers: Optional[Dict[str, Reducer]] = None,
                 chunk_size: int = 65536, workers: Optional[int] = None,
                 policy=None, resume_from=None, checkpoint_every: int = 1,
                 store=None, pool=None) -> Union[ResultFrame, StreamResult]:
    """Sampled HW x supernet-evaluated archs -> joint frame (Fig. 12).

    Rows carry a ``top1`` float column and an integer ``arch_id`` column
    resolving through ``frame.arch_lookup`` (one entry per architecture,
    in ``arch_accs`` order); energy / area anchors come from
    frame.reference_index("energy"/"area"), and the 3-objective joint
    front is ``frame.pareto(("top1_err", "energy_mj", "area_mm2"))``.

    vectorized: "auto" (default) takes the joint-table path when the
    backend advertises ``prefers_table`` and implements
    ``co_evaluate_table`` — the whole archs x HW cross product evaluates
    array-at-a-time (arch layer features stacked once, HW sampled as
    ConfigTables), with power/area computed once per HW row instead of
    once per pair.  True forces that path for any backend implementing
    ``co_evaluate_table`` (e.g. PolynomialBackend); False keeps the
    legacy nested arch x HW loop of scalar ``backend.evaluate`` calls.
    Both paths emit rows in the same (pe_type, arch, hw) order; note
    ``method="random"`` samples different (each deterministic) HW
    sequences per path, exactly like :meth:`explore` — use
    ``grid``/``stratified`` when comparing paths point for point.

    stream=True runs the constant-memory streaming engine over lazy
    JointTable blocks and returns a :class:`StreamResult` (default
    reducer: the 3-objective joint-front ParetoAccumulator).  Like
    :meth:`explore`, ``vectorized="auto"`` sends
    ``STREAM_AUTO_MIN_ROWS``+-pair sweeps through the engine with a
    CollectAccumulator — parallel evaluation, identical joint frame out.
    """
    from repro.core.dataflow import LayerStack  # local: keep header lean
    if reducers is not None and not stream:
      raise ValueError("reducers only apply to the streaming engine; "
                       "pass stream=True")
    if (policy is not None or resume_from is not None
        or pool is not None) and not stream:
      raise ValueError("policy/resume_from/pool apply to the streaming "
                       "engine; pass stream=True")
    if store is not None and not stream:
      raise ValueError("store applies to the streaming engine; "
                       "pass stream=True")
    if stream:
      if not hasattr(self.backend, "co_evaluate_table"):
        raise ValueError(f"backend {self.backend.name!r} has no "
                         "co_evaluate_table; streaming needs the joint path")
      if store is not None:
        from repro.explore.store import cached_stream_co_explore
        return cached_stream_co_explore(self.backend, self.space, arch_accs,
                                        n_hw_per_type=n_hw_per_type,
                                        seed=seed, image_size=image_size,
                                        method=method, reducers=reducers,
                                        chunk_size=chunk_size,
                                        workers=workers, policy=policy,
                                        checkpoint_every=checkpoint_every,
                                        store=store, pool=pool)
      return stream_co_explore(self.backend, self.space, arch_accs,
                               n_hw_per_type=n_hw_per_type, seed=seed,
                               image_size=image_size, method=method,
                               reducers=reducers, chunk_size=chunk_size,
                               workers=workers, policy=policy,
                               resume_from=resume_from,
                               checkpoint_every=checkpoint_every, pool=pool)
    from repro.core.supernet import arch_to_layers  # deferred: pulls jax
    if vectorized == "auto":
      use_joint = bool(getattr(self.backend, "prefers_table", False)) \
          and hasattr(self.backend, "co_evaluate_table")
    else:
      use_joint = bool(vectorized)
    if use_joint and not hasattr(self.backend, "co_evaluate_table"):
      raise ValueError(f"backend {self.backend.name!r} has no "
                       "co_evaluate_table; pass vectorized=False")
    n_pairs_est = len(arch_accs) * n_hw_per_type * len(self.space.pe_types)
    if (use_joint and vectorized == "auto"
        and n_pairs_est >= STREAM_AUTO_MIN_ROWS):
      res = stream_co_explore(self.backend, self.space, arch_accs,
                              n_hw_per_type=n_hw_per_type, seed=seed,
                              image_size=image_size, method=method,
                              reducers={"frame": CollectAccumulator()},
                              chunk_size=chunk_size, workers=workers)
      return self._collected_frame(res)
    archs = [arch for arch, _ in arch_accs]
    accs = np.asarray([float(acc) for _, acc in arch_accs], np.float64)
    arch_layers = [arch_to_layers(arch, image_size=image_size)
                   for arch in archs]
    frames: List[ResultFrame] = []
    if use_joint:
      stack = LayerStack.from_layer_lists(arch_layers)
      for ti, pe_type in enumerate(self.space.pe_types):
        hw = self.space.sample_type_table(pe_type, n_hw_per_type,
                                          seed=seed + 17 * ti, method=method)
        f = self.backend.co_evaluate_table(hw, stack, network="coexplore")
        f.extra["top1"] = accs[f.extra["arch_id"]]
        f.arch_lookup = tuple(archs)
        frames.append(f)
      return ResultFrame.concat(frames)
    for ti, pe_type in enumerate(self.space.pe_types):
      cfgs = self.space.sample_type(pe_type, n_hw_per_type,
                                    seed=seed + 17 * ti, method=method)
      for aid, layers in enumerate(arch_layers):
        f = self.backend.evaluate(cfgs, layers, network="coexplore")
        f.extra["top1"] = np.full(len(f), accs[aid])
        f.extra["arch_id"] = np.full(len(f), aid, np.int64)
        f.arch_lookup = tuple(archs)
        frames.append(f)
    return ResultFrame.concat(frames)
