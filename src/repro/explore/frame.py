"""Columnar exploration results: struct-of-arrays over design points.

A :class:`ResultFrame` holds latency / power / area / pe_type as parallel
numpy arrays (plus arbitrary extra columns such as ``top1`` for
co-exploration), so million-point sweeps stay vectorized end to end.  It
subsumes the old free functions of ``repro.core.dse``:

  ============================  =================================
  old (repro.core.dse)          new (ResultFrame)
  ============================  =================================
  pareto_front(obj)             frame.pareto(...) / pareto_mask(obj)
  best_int16_reference(points)  frame.reference_index(metric)
  normalized_metrics(points)    frame.normalize(ref="best-int16")
  distribution_stats(values)    frame.stats(col) / summary_stats(v)
  ============================  =================================

``pareto_mask`` is vectorized (sort-based sweep in 2-D, non-dominated-
sorted elimination otherwise): no O(n^2) Python loop, so 100k-point
fronts are cheap.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.dataflow import AcceleratorConfig
from repro.core.table import ConfigTable, JointTable

BASE_COLUMNS = ("latency_s", "power_mw", "area_mm2")

# numeric columns derivable from the base metrics alone (plus, on joint
# frames, the top1/top1_err pair derived from the arch accuracies) — the
# contract the fused device programs mirror op for op so survivor values
# stay bit-identical (see repro.explore.device.DEVICE_COLUMNS)
DERIVED_COLUMNS = ("perf", "perf_per_area", "energy_mj")

# derived columns where "bigger is better" (auto-negated inside pareto())
_MAXIMIZE_COLUMNS = frozenset({"perf", "perf_per_area", "top1"})

# normalization-anchor aliases: metric name -> (column, maximize)
_REF_ALIASES = {
    "perf_per_area": ("perf_per_area", True),
    "perf": ("perf", True),
    "energy": ("energy_mj", False),
    "energy_mj": ("energy_mj", False),
    "area": ("area_mm2", False),
    "area_mm2": ("area_mm2", False),
    "latency": ("latency_s", False),
    "latency_s": ("latency_s", False),
}


@dataclasses.dataclass
class DesignPoint:
  """One evaluated (hardware config, network) pair (row view of a frame)."""
  cfg: AcceleratorConfig
  network: str
  latency_s: float
  power_mw: float
  area_mm2: float

  @property
  def perf(self) -> float:
    return 1.0 / max(self.latency_s, 1e-12)

  @property
  def perf_per_area(self) -> float:
    return self.perf / max(self.area_mm2, 1e-12)

  @property
  def energy_mj(self) -> float:
    return self.power_mw * self.latency_s  # mW * s = mJ


# ---------------------------------------------------------------------------
# Pareto machinery (vectorized)
# ---------------------------------------------------------------------------

def _pareto_mask_2d(obj: np.ndarray) -> np.ndarray:
  """Exact 2-D front via one lexsort + prefix minima, O(n log n)."""
  n = obj.shape[0]
  order = np.lexsort((obj[:, 1], obj[:, 0]))  # by x asc, then y asc
  xs, ys = obj[order, 0], obj[order, 1]
  new_x = np.empty(n, np.bool_)
  new_x[0] = True
  new_x[1:] = xs[1:] != xs[:-1]
  group_first = np.flatnonzero(new_x)
  group_id = np.cumsum(new_x) - 1
  # min y over all strictly-smaller-x points (dominates if <= our y) and
  # min y within our own x group (dominates if < our y)
  prefix_min = np.minimum.accumulate(ys)
  before = np.full(group_first.shape, np.inf)
  before[1:] = prefix_min[group_first[1:] - 1]
  keep = (ys < before[group_id]) & (ys == ys[group_first][group_id])
  mask = np.empty(n, np.bool_)
  mask[order] = keep
  return mask


def _pareto_elim_nd(obj: np.ndarray) -> np.ndarray:
  """General-dimension front by elimination: visit candidates in ascending
  objective-sum order — the smallest-sum survivor is provably
  non-dominated — then kill its dominated set vectorized, *compacting*
  the survivor arrays each step.  The Python loop runs front_size times
  over an ever-shrinking alive set (not n times over the full array)."""
  n = obj.shape[0]
  order = np.argsort(obj.sum(axis=1), kind="stable")
  o = obj[order]
  pos = np.arange(n)
  front = np.zeros(n, np.bool_)
  while pos.size:
    head = pos[0]
    front[order[head]] = True
    rest = pos[1:]
    sub = o[rest]
    x = o[head]
    dominated = np.all(sub >= x, axis=1) & np.any(sub > x, axis=1)
    pos = rest[~dominated]
  return front


# block size for the divide-and-conquer N-D front (crossover tuned on the
# 1M x 3 BENCH_coexplore front; correctness is block-size independent)
_ND_BLOCK = 4096


def _pareto_mask_nd(obj: np.ndarray) -> np.ndarray:
  """Block-decomposed general-dimension front.

  Per-block elimination first (every global-front point survives its own
  block; every dominated point is dominated by some front point, which
  survives *its* block), then recursive elimination over the surviving
  candidates only.  Full-array passes touch at most ``_ND_BLOCK``-row
  blocks, so million-point fronts cost block sweeps + a small candidate
  merge instead of O(front_size) million-row passes.  This is the same
  front-vs-front merge kernel ParetoAccumulator folds streaming chunks
  with (see repro.explore.streaming).
  """
  n = obj.shape[0]
  if n <= _ND_BLOCK:
    return _pareto_elim_nd(obj)
  cand = np.concatenate([
      lo + np.flatnonzero(_pareto_elim_nd(obj[lo:lo + _ND_BLOCK]))
      for lo in range(0, n, _ND_BLOCK)])
  if cand.size == n:  # degenerate: every block all-front; no progress
    return _pareto_elim_nd(obj)
  mask = np.zeros(n, np.bool_)
  mask[cand[_pareto_mask_nd(obj[cand])]] = True
  return mask


def stable_topk_indices(key: np.ndarray, k: int,
                        tie: Optional[np.ndarray] = None) -> np.ndarray:
  """Indices of the k smallest ``key`` values in stable-sort order
  (ascending key, ties by ascending ``tie`` — default the index itself),
  via argpartition + sort-of-k: O(n + k log k) instead of a full argsort.

  Exactly equivalent to ``np.argsort(key, kind="stable")[:k]`` (with
  ``tie=None``); the streaming TopKAccumulator passes global row ids as
  ``tie`` so folds over shuffled chunk partitions stay bit-identical to
  the one-shot path.
  """
  key = np.asarray(key)
  n = key.shape[0]
  k = max(int(k), 0)
  if k == 0:
    return np.zeros(0, np.int64)
  tie_of = np.arange(n) if tie is None else np.asarray(tie)
  if k >= n:
    sel = np.arange(n)
    return sel[np.lexsort((tie_of, key))]
  part = np.argpartition(key, k - 1)[:k]
  if np.isnan(key[part]).any():  # NaN partitions unreliably; full sort
    return np.lexsort((tie_of, key))[:k]
  thresh = key[part].max()
  strict = np.flatnonzero(key < thresh)
  ties = np.flatnonzero(key == thresh)
  need = k - strict.size
  # boundary ties resolve exactly like the stable sort: smallest tie wins
  ties = ties[np.argsort(tie_of[ties], kind="stable")[:need]]
  sel = np.concatenate([strict, ties])
  return sel[np.lexsort((tie_of[sel], key[sel]))]


def pareto_mask(objectives: np.ndarray) -> np.ndarray:
  """Boolean mask of non-dominated rows; all objectives are MINIMIZED."""
  obj = np.asarray(objectives, np.float64)
  if obj.ndim != 2:
    raise ValueError(f"objectives must be 2-D, got shape {obj.shape}")
  if obj.shape[0] == 0:
    return np.zeros(0, np.bool_)
  if obj.shape[1] == 1:
    return obj[:, 0] == obj[:, 0].min()
  if obj.shape[1] == 2:
    return _pareto_mask_2d(obj)
  return _pareto_mask_nd(obj)


def summary_stats(values: np.ndarray) -> Dict[str, float]:
  """Fig. 9 violin summary: min / q1 / median / q3 / max / mean.

  Empty input (e.g. a ``frame.stats(col, mask)`` whose mask selects zero
  rows) returns NaN for every statistic instead of the opaque ``np.min``
  ValueError."""
  v = np.asarray(values, np.float64)
  if v.size == 0:
    return {k: float("nan")
            for k in ("min", "q1", "median", "q3", "max", "mean")}
  return {
      "min": float(v.min()), "q1": float(np.percentile(v, 25)),
      "median": float(np.median(v)), "q3": float(np.percentile(v, 75)),
      "max": float(v.max()), "mean": float(v.mean()),
  }


@dataclasses.dataclass
class Normalized:
  """Metrics normalized against a reference design (paper's best-INT16)."""
  perf_per_area: np.ndarray
  energy: np.ndarray
  ref_index: Optional[int] = None

  def __iter__(self) -> Iterator[np.ndarray]:  # (ppa, energy) unpacking
    return iter((self.perf_per_area, self.energy))


# ---------------------------------------------------------------------------
# the frame
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class ResultFrame:
  """Struct-of-arrays over evaluated design points.

  Design points can ride along either as a tuple of per-point ``cfgs``
  dataclasses (the scalar path) or as a columnar :class:`ConfigTable` /
  :class:`JointTable` (the vectorized paths, where million-point sweeps
  never build per-point objects); :meth:`config_at` reads from whichever
  is present.

  Co-exploration frames carry architectures as an integer ``arch_id``
  extra column plus the shared ``arch_lookup`` tuple (one entry per
  distinct architecture) — never as an object-dtype column, which would
  defeat vectorized stats/pareto and make ``concat`` allocation-heavy.
  :meth:`arch_at` maps a row back to its architecture object.
  """
  latency_s: np.ndarray
  power_mw: np.ndarray
  area_mm2: np.ndarray
  pe_type: np.ndarray
  cfgs: Tuple[AcceleratorConfig, ...] = ()
  network: str = "net"
  extra: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
  meta: Dict[str, float] = dataclasses.field(default_factory=dict)
  table: Optional[Union[ConfigTable, JointTable]] = None
  arch_lookup: Tuple[object, ...] = ()

  def __post_init__(self):
    self.latency_s = np.asarray(self.latency_s, np.float64)
    self.power_mw = np.asarray(self.power_mw, np.float64)
    self.area_mm2 = np.asarray(self.area_mm2, np.float64)
    self.pe_type = np.asarray(self.pe_type)
    self.cfgs = tuple(self.cfgs)
    self.arch_lookup = tuple(self.arch_lookup)
    n = len(self.latency_s)
    for name, arr in (("power_mw", self.power_mw),
                      ("area_mm2", self.area_mm2),
                      ("pe_type", self.pe_type)):
      if len(arr) != n:
        raise ValueError(f"column {name!r} has {len(arr)} rows, expected {n}")
    if self.cfgs and len(self.cfgs) != n:
      raise ValueError(f"{len(self.cfgs)} cfgs for {n} rows")
    if self.table is not None and len(self.table) != n:
      raise ValueError(f"{len(self.table)}-row table for {n} rows")
    if self.arch_lookup:
      ids = self.extra.get("arch_id")
      if ids is None:
        raise ValueError("arch_lookup given without an 'arch_id' column")
      self.extra["arch_id"] = ids = np.asarray(ids, np.int64)
      if ids.size and (ids.min() < 0 or ids.max() >= len(self.arch_lookup)):
        raise ValueError("arch_id out of range for arch_lookup")

  def __len__(self) -> int:
    return int(self.latency_s.shape[0])

  # -- columns -------------------------------------------------------------

  @property
  def perf(self) -> np.ndarray:
    return 1.0 / np.maximum(self.latency_s, 1e-12)

  @property
  def perf_per_area(self) -> np.ndarray:
    return self.perf / np.maximum(self.area_mm2, 1e-12)

  @property
  def energy_mj(self) -> np.ndarray:
    return self.power_mw * self.latency_s  # mW * s = mJ

  def column(self, name: str) -> np.ndarray:
    if name in BASE_COLUMNS or name in DERIVED_COLUMNS:
      return getattr(self, name)
    if name == "pe_type":
      return self.pe_type
    if name == "top1_err":
      return 1.0 - self.extra["top1"]
    if name in self.extra:
      return self.extra[name]
    raise KeyError(f"unknown column {name!r}; have base={BASE_COLUMNS}, "
                   f"derived=(perf, perf_per_area, energy_mj, top1_err), "
                   f"extra={tuple(self.extra)}")

  def by_type(self, pe_type: str) -> np.ndarray:
    return self.pe_type == pe_type

  # -- construction / conversion -------------------------------------------

  @classmethod
  def from_points(cls, points: Sequence[DesignPoint],
                  network: Optional[str] = None) -> "ResultFrame":
    pts = list(points)
    return cls(
        latency_s=np.asarray([p.latency_s for p in pts], np.float64),
        power_mw=np.asarray([p.power_mw for p in pts], np.float64),
        area_mm2=np.asarray([p.area_mm2 for p in pts], np.float64),
        pe_type=np.asarray([p.cfg.pe_type for p in pts]),
        cfgs=tuple(p.cfg for p in pts),
        network=network if network is not None
        else (pts[0].network if pts else "net"))

  def config_at(self, i: int) -> AcceleratorConfig:
    """The i-th design point, from ``cfgs`` or the columnar ``table``."""
    if self.cfgs:
      return self.cfgs[i]
    if self.table is not None:
      return self.table.config_at(i)
    raise ValueError("frame carries neither cfgs nor a ConfigTable")

  def arch_at(self, i: int) -> object:
    """The i-th row's architecture object (``arch_lookup[arch_id[i]]``)."""
    if not self.arch_lookup:
      raise ValueError("frame carries no arch_lookup (not a co-exploration "
                       "frame)")
    return self.arch_lookup[int(self.extra["arch_id"][i])]

  def to_points(self) -> List[DesignPoint]:
    if not self.cfgs and self.table is not None:
      cfgs = self.table.to_configs()
    else:
      cfgs = self.cfgs
    return [DesignPoint(cfg, self.network, float(l), float(p), float(a))
            for cfg, l, p, a in zip(cfgs, self.latency_s,
                                    self.power_mw, self.area_mm2)]

  def select(self, index: Union[np.ndarray, Sequence[int]]) -> "ResultFrame":
    """Sub-frame by boolean mask or integer index array."""
    idx = np.asarray(index)
    if idx.dtype == np.bool_:
      idx = np.flatnonzero(idx)
    cfgs = tuple(self.cfgs[i] for i in idx) if self.cfgs else ()
    return ResultFrame(
        self.latency_s[idx], self.power_mw[idx], self.area_mm2[idx],
        self.pe_type[idx], cfgs, self.network,
        {k: v[idx] for k, v in self.extra.items()}, dict(self.meta),
        self.table.select(idx) if self.table is not None else None,
        self.arch_lookup)

  @staticmethod
  def _merge_arch_lookups(frames: Sequence["ResultFrame"]
                          ) -> Tuple[Tuple[object, ...], Optional[np.ndarray]]:
    """Union the frames' arch lookups; returns (merged lookup, remapped
    arch_id column or None when ids can pass through unchanged)."""
    lookups = [f.arch_lookup for f in frames]
    if not any(lookups):
      return (), None
    if any(not lu and len(f) for lu, f in zip(lookups, frames)):
      raise ValueError("cannot concat coded-arch frames with frames that "
                       "have arch_id but no arch_lookup")
    first = next(lu for lu in lookups if lu)
    if all(lu == first or not len(f) for lu, f in zip(lookups, frames)):
      return first, None  # identical lookups: ids are already aligned
    merged: List[object] = []
    index: Dict[object, int] = {}
    parts: List[np.ndarray] = []
    for f in frames:
      remap = np.empty(len(f.arch_lookup), np.int64)
      for j, arch in enumerate(f.arch_lookup):
        if arch not in index:
          index[arch] = len(merged)
          merged.append(arch)
        remap[j] = index[arch]
      parts.append(remap[np.asarray(f.extra["arch_id"], np.int64)]
                   if len(f) else np.zeros(0, np.int64))
    return tuple(merged), np.concatenate(parts)

  @classmethod
  def concat(cls, frames: Sequence["ResultFrame"]) -> "ResultFrame":
    frames = list(frames)
    if not frames:
      raise ValueError("cannot concat zero frames")
    keys = set(frames[0].extra)
    if any(set(f.extra) != keys for f in frames):
      raise ValueError("frames have mismatched extra columns")
    cfgs = sum((f.cfgs for f in frames), ()) \
        if all(f.cfgs or not len(f) for f in frames) else ()
    # JointTables flatten to plain ConfigTables across a concat (numpy
    # tiling; still no per-point Python objects)
    tables = [f.table.materialize() if isinstance(f.table, JointTable)
              else f.table for f in frames]
    if all(t is not None for t in tables):
      table = ConfigTable.concat(tables)
    elif not cfgs and all(t is not None or f.cfgs or not len(f)
                          for t, f in zip(tables, frames)):
      # mixed representations: lift the cfgs-only frames into tables so
      # design points survive the concat (tables are the cheap direction)
      table = ConfigTable.concat([
          t if t is not None else ConfigTable.from_configs(f.cfgs)
          for t, f in zip(tables, frames)])
    else:
      table = None
    extra = {k: np.concatenate([f.extra[k] for f in frames]) for k in keys}
    arch_lookup, remapped = cls._merge_arch_lookups(frames)
    if remapped is not None:
      extra["arch_id"] = remapped
    return cls(
        np.concatenate([f.latency_s for f in frames]),
        np.concatenate([f.power_mw for f in frames]),
        np.concatenate([f.area_mm2 for f in frames]),
        np.concatenate([f.pe_type for f in frames]),
        cfgs,
        frames[0].network,
        extra,
        table=table,
        arch_lookup=arch_lookup)

  # -- analysis ------------------------------------------------------------

  def pareto(self, cols: Sequence[str] = ("perf_per_area", "energy_mj"),
             maximize: Optional[Sequence[str]] = None) -> np.ndarray:
    """Non-dominated mask over the given columns.  Columns in `maximize`
    (default: perf/perf_per_area/top1) are negated; the rest minimized."""
    mx = _MAXIMIZE_COLUMNS if maximize is None else frozenset(maximize)
    obj = np.stack([-self.column(c) if c in mx else self.column(c)
                    for c in cols], axis=1)
    return pareto_mask(obj)

  def reference_index(self, metric: str = "perf_per_area",
                      pe_type: Optional[str] = "INT16") -> int:
    """Row index of the paper's normalization anchor: the best design under
    `metric` among `pe_type` rows (None = whole frame)."""
    if metric not in _REF_ALIASES:
      raise ValueError(f"unknown reference metric {metric!r}; "
                       f"one of {sorted(_REF_ALIASES)}")
    col, maximize = _REF_ALIASES[metric]
    if pe_type is None:
      rows = np.arange(len(self))
    else:
      rows = np.flatnonzero(self.pe_type == pe_type)
      if rows.size == 0:
        raise ValueError(
            f"design space contains no {pe_type} points to normalize by")
    vals = self.column(col)[rows]
    local = int(np.argmax(vals)) if maximize else int(np.argmin(vals))
    return int(rows[local])

  def normalize(self, ref: Union[str, int, Tuple[float, float]]
                = "best-int16") -> Normalized:
    """(normalized perf/area, normalized energy).

    ref: "best-int16" (paper default: best-perf/area INT16 design), a row
    index, or an explicit (perf_per_area_ref, energy_mj_ref) pair.
    """
    ref_index: Optional[int] = None
    if isinstance(ref, str):
      if ref != "best-int16":
        raise ValueError(f"unknown normalization reference {ref!r}")
      ref_index = self.reference_index("perf_per_area", "INT16")
    elif isinstance(ref, (int, np.integer)):
      ref_index = int(ref)
    if ref_index is not None:
      ppa_ref = float(self.perf_per_area[ref_index])
      en_ref = float(self.energy_mj[ref_index])
    else:
      ppa_ref, en_ref = float(ref[0]), float(ref[1])
    return Normalized(self.perf_per_area / ppa_ref,
                      self.energy_mj / en_ref, ref_index)

  def stats(self, col: str, mask: Optional[np.ndarray] = None
            ) -> Dict[str, float]:
    vals = self.column(col)
    if mask is not None:
      vals = vals[mask]
    return summary_stats(vals)

  def top_k(self, k: int, by: str = "perf_per_area",
            maximize: Optional[bool] = None) -> "ResultFrame":
    """Sub-frame of the k best rows under one column (best-first order)."""
    if maximize is None:
      maximize = by in _MAXIMIZE_COLUMNS
    vals = self.column(by)
    return self.select(stable_topk_indices(-vals if maximize else vals, k))
