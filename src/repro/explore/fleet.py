"""Elastic device-fleet execution for streaming sweeps.

The streaming engine (repro.explore.streaming) keeps one submitting
thread and a small in-flight window; on a multi-device host that window
all lands on the default device.  This module shards streaming chunks
across *all* visible devices — each chunk is pinned to one device and
runs the same fused evaluate+reduce program there; the host merge is
unchanged.  Chunk-partition bit-identity (every reducer is chunk-order
invariant, every chunk a pure function of ``(space, chunk_index,
seed)``) makes any sharding, resharding, re-execution, or re-ordering
sound: the final fronts are bit-identical to a solo single-device run.

A fleet fails in ways one device never does, so the execution layer is
built around a health registry and three mitigations:

  DevicePool   per-device health: EWMA chunk latencies (via
               :class:`repro.train.fault_tolerance.StragglerMonitor` —
               the trainer's monitor generalized to exploration),
               consecutive-failure counts, and a per-device
               :class:`~repro.explore.resilience.CircuitBreaker` so one
               sick device is quarantined instead of tripping the whole
               rung.  Quarantined (or lost) devices rejoin through the
               breaker's half-open probe.
  stragglers   the slowest in-flight shard is speculatively re-dispatched
               to an idle healthy device; the first bit-identical result
               wins and the loser is discarded (``n_speculative``).
  elasticity   on device loss or quarantine the pool shrinks, orphaned
               chunks re-enter the queue and are resharded onto the
               surviving devices (``n_resharded``).
  SDC sentinel silent data corruption produces no exception — the only
               detector is recomputation.  With ``sdc_check_every > 0``
               device results are buffered per device (deferred fold);
               every check window a seeded sample chunk is re-evaluated
               on the terminal numpy rung and compared value-for-value.
               The parity contract makes device x64 results bit-identical
               to numpy, so ANY mismatch is corruption, not roundoff:
               the device is quarantined and its buffered chunks replay
               on healthy devices (``n_corruption_checks`` /
               ``n_corruptions_detected``).

Device *placement* rides on a thread-local pin: :func:`pin` marks the
submitting thread's target device and the backend's pending entry points
(`repro.explore.backend`) commit each chunk's inputs there with
``jax.device_put`` — jax then executes the jitted program on the
committed device, and its output buffers expose ``is_ready()`` for the
non-blocking readiness polling the straggler logic needs.

:func:`visible_devices` is the ONE sanctioned device enumeration in the
tree — analysis rule ROB003 bans direct ``jax.devices()`` /
``jax.local_devices()`` calls everywhere else so all device access goes
through the health-tracked pool.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from collections import deque
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.seeding import derive_seed
from repro.explore.resilience import (ChunkError, ChunkTask, CircuitBreaker,
                                      ResiliencePolicy, SweepJournal,
                                      SweepKilled)
from repro.train.fault_tolerance import StragglerMonitor


# ---------------------------------------------------------------------------
# sanctioned device enumeration (ROB003)
# ---------------------------------------------------------------------------

def visible_devices() -> Tuple[object, ...]:
  """All addressable jax devices.  This is the single sanctioned call
  site of ``jax.devices()`` in the tree (analysis rule ROB003): every
  other module reaches devices through here or a :class:`DevicePool`,
  so health tracking and quarantine cannot be bypassed."""
  import jax
  return tuple(jax.devices())


def device_topology() -> Dict[str, object]:
  """Provenance-stamp description of the fleet (platform, count, kinds).
  Import-safe: degrades to an empty topology when jax is unavailable."""
  try:
    devs = visible_devices()
  except Exception:
    return {"platform": "none", "n_devices": 0, "device_kinds": []}
  kinds = sorted({str(getattr(d, "device_kind", "unknown")) for d in devs})
  platform = str(getattr(devs[0], "platform", "unknown")) if devs else "none"
  return {"platform": platform, "n_devices": len(devs),
          "device_kinds": kinds}


# ---------------------------------------------------------------------------
# thread-local device pinning
# ---------------------------------------------------------------------------

_TLS = threading.local()


def pinned_device():
  """The device the current thread's dispatches are pinned to (or None:
  default placement)."""
  return getattr(_TLS, "device", None)


@contextlib.contextmanager
def pin(device):
  """Pin this thread's backend dispatches to ``device``: the pending
  entry points commit chunk inputs there (``jax.device_put``), so the
  jitted program executes on that device.  Pins nest; the previous pin
  is restored on exit."""
  prev = getattr(_TLS, "device", None)
  _TLS.device = device
  try:
    yield device
  finally:
    _TLS.device = prev


# ---------------------------------------------------------------------------
# the health registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DeviceHealth:
  """Mutable per-device record inside a :class:`DevicePool`."""
  device: object
  breaker: CircuitBreaker
  n_chunks: int = 0            # completed chunks
  n_failures: int = 0          # consecutive failures (resets on success)
  n_dispatched: int = 0
  outstanding: int = 0         # checked-out, not yet checked-in
  n_losses: int = 0            # injected/observed device-lost events

  @property
  def ewma_key(self) -> str:
    return str(id(self))


class DevicePool:
  """Health registry + admission control for a device fleet.

  One pool is shared by every consumer multiplexed over the fleet
  (:func:`run_fleet` sweeps, exploration-service sessions), so the
  quarantine decision reflects the *device*, not any single session's
  luck — the per-device generalization of PR 9's shared
  :class:`~repro.explore.resilience.CircuitBreaker`.

  ``checkout()`` admits a dispatch on the healthiest available device
  (fewest outstanding shards, breaker willing); ``checkin()`` releases
  it; ``record_latency`` / ``record_success`` / ``record_failure`` feed
  the health state.  ``quarantine()`` force-opens a device's breaker
  (device loss, SDC divergence) — the device rejoins later through the
  breaker's ordinary half-open probe, so recovery needs no extra
  machinery.  Thread-safe.

  ``sdc_check_every`` arms the silent-corruption sentinel in
  :func:`run_fleet`: N > 0 defers folds and re-checks one seeded chunk
  per N buffered results per device; 0 disables buffering entirely (the
  zero-overhead healthy path).
  """

  def __init__(self, devices: Optional[Iterable[object]] = None, *,
               ewma_alpha: float = 0.25, speculation_factor: float = 4.0,
               sdc_check_every: int = 0, seed: int = 0,
               breaker_threshold: int = 3, breaker_cooldown: int = 8,
               breaker_jitter: int = 2):
    devs = tuple(visible_devices() if devices is None else devices)
    if not devs:
      raise ValueError("DevicePool needs at least one device")
    if speculation_factor <= 1.0:
      raise ValueError(
          f"speculation_factor must exceed 1.0, got {speculation_factor}")
    if sdc_check_every < 0:
      raise ValueError(
          f"sdc_check_every must be >= 0, got {sdc_check_every}")
    self.seed = int(seed)
    self.speculation_factor = float(speculation_factor)
    self.sdc_check_every = int(sdc_check_every)
    self._monitor = StragglerMonitor(alpha=float(ewma_alpha))
    self._health: List[DeviceHealth] = [
        DeviceHealth(d, CircuitBreaker(
            threshold=breaker_threshold, cooldown=breaker_cooldown,
            jitter=breaker_jitter,
            seed=derive_seed("fleet-device", seed, i)))
        for i, d in enumerate(devs)]
    self._lock = threading.Lock()
    # fleet-wide mitigation counters (shared by every consumer)
    self.n_speculative = 0
    self.n_resharded = 0
    self.n_corruption_checks = 0
    self.n_corruptions_detected = 0

  # -- topology -------------------------------------------------------------

  @property
  def n_devices(self) -> int:
    return len(self._health)

  def device(self, i: int):
    return self._health[i].device

  def devices(self) -> Tuple[object, ...]:
    return tuple(h.device for h in self._health)

  # -- admission ------------------------------------------------------------

  def checkout(self, require_idle: bool = False,
               exclude: Tuple[int, ...] = ()) -> Optional[int]:
    """Admit one dispatch: returns the index of the healthiest available
    device (fewest outstanding shards; its breaker consulted exactly
    once), or None when every device refuses — callers then fall back to
    the terminal host rung.  ``require_idle`` restricts to devices with
    nothing in flight (speculation targets)."""
    with self._lock:
      order = sorted(range(len(self._health)),
                     key=lambda i: (self._health[i].outstanding, i))
      for i in order:
        h = self._health[i]
        if i in exclude or (require_idle and h.outstanding):
          continue
        if h.breaker.allow_device():
          h.outstanding += 1
          h.n_dispatched += 1
          return i
    return None

  def checkin(self, i: int) -> None:
    with self._lock:
      self._health[i].outstanding = max(0, self._health[i].outstanding - 1)

  # -- health feed ----------------------------------------------------------

  def record_latency(self, i: int, seconds: float) -> None:
    with self._lock:
      h = self._health[i]
      h.n_chunks += 1
      self._monitor.record(h.ewma_key, float(seconds))

  def record_success(self, i: int) -> None:
    h = self._health[i]
    with self._lock:
      h.n_failures = 0
    h.breaker.record_success()

  def record_failure(self, i: int) -> None:
    h = self._health[i]
    with self._lock:
      h.n_failures += 1
    h.breaker.record_failure()

  def quarantine(self, i: int) -> None:
    """Force-open a device's breaker (loss / corruption); it rejoins via
    the ordinary half-open probe after the seeded cooldown."""
    self._health[i].breaker.trip()

  def lose_device(self, i: int) -> None:
    """A device vanished mid-sweep: quarantine it and count the loss.
    (If it comes back, the half-open probe readmits it.)"""
    with self._lock:
      self._health[i].n_losses += 1
    self.quarantine(i)

  # -- fleet statistics -----------------------------------------------------

  def ewma(self, i: int) -> Optional[float]:
    st = self._monitor.hosts.get(self._health[i].ewma_key)
    return float(st.ewma) if st is not None and st.count else None

  def fleet_latency(self) -> Optional[float]:
    """Fleet-median EWMA chunk latency — the straggler reference point
    (a shard is speculated past ``speculation_factor`` x this)."""
    with self._lock:
      med = self._monitor.fleet_median()
    return float(med) if med > 0.0 else None

  def note_speculation(self, n: int = 1) -> None:
    with self._lock:
      self.n_speculative += int(n)

  def note_reshard(self, n: int = 1) -> None:
    with self._lock:
      self.n_resharded += int(n)

  def note_corruption_check(self, n: int = 1) -> None:
    with self._lock:
      self.n_corruption_checks += int(n)

  def note_corruption(self, n: int = 1) -> None:
    with self._lock:
      self.n_corruptions_detected += int(n)

  def counters(self) -> Dict[str, int]:
    """Snapshot of the fleet mitigation counters (cumulative over the
    pool's lifetime; runs diff two snapshots for per-run meta)."""
    with self._lock:
      return {"n_speculative": self.n_speculative,
              "n_resharded": self.n_resharded,
              "n_corruption_checks": self.n_corruption_checks,
              "n_corruptions_detected": self.n_corruptions_detected,
              "n_device_losses": sum(h.n_losses for h in self._health)}

  def meta(self) -> Dict[str, object]:
    """Snapshot for ``StreamResult.meta`` merging: counters plus the
    per-device breaker states and health stats."""
    out: Dict[str, object] = {k: float(v) for k, v in self.counters().items()}
    states = [h.breaker.state for h in self._health]
    out["fleet_devices"] = float(self.n_devices)
    out["fleet_device_states"] = states
    out["n_quarantined_devices"] = float(
        sum(1 for s in states if s != "closed"))
    out["fleet_device_chunks"] = [float(h.n_chunks) for h in self._health]
    out["fleet_device_ewma_s"] = [
        e if e is not None else -1.0
        for e in (self.ewma(i) for i in range(self.n_devices))]
    return out


# ---------------------------------------------------------------------------
# fleet execution
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Shard:
  """One in-flight dispatch: a chunk pinned to one pool device."""
  index: int
  task: ChunkTask
  dev: Optional[int]           # pool device index; None = host fallback
  handle: object               # pending handle or immediate result
  t0: float
  immediate: bool              # result needs no resolve()
  slow: bool = False           # injected-slow fault fired at dispatch
  corrupt: bool = False        # injected-corrupt fault fired at dispatch
  speculated: bool = False     # a twin has been launched
  twin: Optional["_Shard"] = None


def _handle_ready(shard: _Shard) -> bool:
  if shard.immediate:
    return True
  fn = getattr(shard.handle, "is_ready", None)
  if fn is None:
    return False
  try:
    return bool(fn())
  except Exception:
    return False


def _corrupt_result(result):
  """Deterministic stand-in for silent device corruption: bump every
  transferred survivor's latency by one ulp (and histogram counts /
  stats means by one quantum).  No exception, values still plausible —
  exactly the failure mode only recomputation can catch."""
  payloads = getattr(result, "payloads", None)
  if payloads is None:
    frame, _ = result
    frame.latency_s = np.nextafter(frame.latency_s, np.inf)
    return result
  for payload in payloads.values():
    kind = payload[0]
    if kind == "rows":
      payload[1].latency_s = np.nextafter(payload[1].latency_s, np.inf)
    elif kind == "hist":
      counts = np.asarray(payload[1])
      if counts.size:
        counts[0] += 1
    elif kind == "stats":
      payload[1]["mean"] = np.nextafter(payload[1].get("mean", 0.0), np.inf)
  return result


def _frame_rows_match(ref_frame, ref_idx: np.ndarray, frame,
                      ids: np.ndarray) -> bool:
  """Do the transferred survivor rows (values at global ids) match the
  reference numpy evaluation bit for bit?"""
  ref_idx = np.asarray(ref_idx, np.int64)
  ids = np.asarray(ids, np.int64)
  if not ids.size:
    return True
  order = np.argsort(ref_idx, kind="stable")
  pos = np.clip(np.searchsorted(ref_idx[order], ids), 0, ref_idx.size - 1)
  pos = order[pos]
  if not np.array_equal(ref_idx[pos], ids):
    return False
  return all(np.array_equal(np.asarray(frame.column(c), np.float64),
                            np.asarray(ref_frame.column(c), np.float64)[pos])
             for c in ("latency_s", "power_mw", "area_mm2"))


def _results_match(result, reference) -> bool:
  """Compare a device chunk result against the terminal numpy rung's
  evaluation of the same chunk.  Row-carrying payloads (pareto / top-k
  survivors, full frames) are compared value-for-value — exact by the
  parity contract, so any mismatch is corruption.  Stats partials are
  merge-order-dependent (ulp-level, see EXA003) and histogram payloads
  carry no row ids; both are skipped — every default reduction plan
  ships row payloads, which carry all transferred values."""
  ref_frame, ref_idx = reference
  payloads = getattr(result, "payloads", None)
  if payloads is None:
    frame, ids = result
    return _frame_rows_match(ref_frame, ref_idx, frame, ids)
  for payload in payloads.values():
    if payload[0] == "rows":
      if not _frame_rows_match(ref_frame, ref_idx, payload[1], payload[2]):
        return False
  return True


def run_fleet(tasks: Iterable[ChunkTask], reducers: Dict[str, object],
              pool: DevicePool, *,
              policy: Optional[ResiliencePolicy] = None,
              dispatch_ahead: Optional[int] = None,
              resume_from=None, journal_key: str = "",
              checkpoint_every: int = 1):
  """Drain ``tasks`` across the pool's devices, folding every reducer as
  chunks complete — the fleet analogue of
  :func:`repro.explore.streaming.run_stream` (same journaling, same
  failure semantics, same ``StreamResult`` shape) with health-aware
  sharding, straggler speculation, elastic resharding, and the SDC
  sentinel layered on top.  Bit-identity: reducers are chunk-order
  invariant and every re-execution is a pure recomputation, so the final
  fronts match a solo single-device run exactly.
  """
  # deferred: streaming imports fleet lazily too (pool= routing)
  from repro.explore.streaming import (DISPATCH_AHEAD, StreamResult,
                                       fold_chunk, new_counters)
  if dispatch_ahead is None:
    dispatch_ahead = DISPATCH_AHEAD
  t0 = time.perf_counter()
  plan = policy.fault_plan if policy is not None else None
  journal = None
  done_chunks: set = set()
  counters = new_counters()
  n_resumed = 0
  if resume_from is not None:
    journal = resume_from if isinstance(resume_from, SweepJournal) \
        else SweepJournal(resume_from)
    state = journal.load_state(journal_key)
    if state is not None:
      done_chunks = set(state["done"])
      for name, r in reducers.items():
        r.restore(state["reducers"][name])
      counters.update(state["counters"])
      n_resumed = len(done_chunks)
  base_retries = counters["n_retries"]
  base_demotions = counters["n_demotions"]
  base_fleet = pool.counters()
  since_ckpt = 0

  def totals() -> Tuple[int, int]:
    extra_r = policy.n_retries if policy is not None else 0
    extra_d = policy.n_demotions if policy is not None else 0
    return base_retries + extra_r, base_demotions + extra_d

  def checkpoint(force: bool = False) -> None:
    nonlocal since_ckpt
    if journal is None:
      return
    since_ckpt += 1
    if not force and since_ckpt < max(int(checkpoint_every), 1):
      return
    counters["n_retries"], counters["n_demotions"] = totals()
    journal.record(journal_key, {
        "done": set(done_chunks),
        "reducers": {name: r.snapshot() for name, r in reducers.items()},
        "counters": dict(counters)})
    since_ckpt = 0

  def fail(index, exc):
    checkpoint(force=True)
    if isinstance(exc, ChunkError):
      raise exc
    raise ChunkError(index, f"{type(exc).__name__}: {exc}") from exc

  def execute(task):
    if policy is not None:
      return policy.execute(task)
    return task()

  def run_terminal(task: ChunkTask):
    """The chunk on its terminal (numpy) rung — the all-devices-refused
    fallback and the SDC sentinel's reference evaluation."""
    if policy is not None:
      out = policy.execute_from(task, len(task.rungs) - 1)
    else:
      out = task.rungs[-1].fn()
    if hasattr(out, "resolve"):
      out = out.resolve()
    return out

  def finish_fold(index, result) -> None:
    try:
      fold_chunk(reducers, counters, result)
    except Exception as e:
      fail(index, e)
    done_chunks.add(index)
    checkpoint()

  def indexed(ts) -> Iterator[Tuple[int, ChunkTask]]:
    for i, t in enumerate(ts):
      index = getattr(t, "index", i)
      if index in done_chunks:
        continue
      yield index, t

  source = indexed(tasks)
  queue: "deque" = deque()        # requeued (orphaned / replayed) chunks
  inflight: List[_Shard] = []
  # dev index -> [(chunk index, task, resolved result)] awaiting the
  # sentinel's validation before folding (sdc_check_every > 0 only)
  buffers: Dict[int, List[Tuple[int, ChunkTask, object]]] = {}
  sdc_rng = np.random.RandomState(derive_seed("fleet-sdc", pool.seed))
  window_cap = max(1, pool.n_devices) * max(int(dispatch_ahead), 1)

  def next_item() -> Optional[Tuple[int, ChunkTask]]:
    if queue:
      return queue.popleft()
    return next(source, None)

  def dispatch(index: int, task: ChunkTask) -> None:
    has_device_rung = any(r.layer == "device"
                          for r in getattr(task, "rungs", ()))
    dev = pool.checkout() if has_device_rung else None
    slow = corrupt = False
    if dev is not None and plan is not None:
      kind = plan.check_fleet(dev, index)
      if kind == "device-lost":
        # the device vanished at this chunk boundary: quarantine it,
        # orphan its in-flight shards, reshard everything onto the rest
        pool.checkin(dev)
        pool.lose_device(dev)
        requeued = 1  # the chunk we were about to dispatch
        for s in [s for s in inflight if s.dev == dev]:
          inflight.remove(s)
          pool.checkin(dev)
          if s.twin is not None:
            # a twin on another device carries the chunk — don't
            # requeue, or the chunk would fold twice
            s.twin.twin = None
            continue
          queue.appendleft((s.index, s.task))
          requeued += 1
        buf = buffers.pop(dev, [])
        for i, t, _ in reversed(buf):
          queue.appendleft((i, t))
        pool.note_reshard(requeued + len(buf))
        queue.appendleft((index, task))
        return
      slow = kind == "slow"
      corrupt = kind == "corrupt"
    start = time.perf_counter()
    try:
      if dev is not None:
        with pin(pool.device(dev)):
          out = execute(task)
      elif has_device_rung:
        # every device quarantined: the terminal numpy rung is the safe
        # harbor (bit-identical by the parity contract)
        out = run_terminal(task)
      else:
        out = execute(task)
    except SweepKilled:
      checkpoint(force=True)
      raise
    except Exception as e:
      if dev is not None:
        pool.checkin(dev)
        pool.record_failure(dev)
      fail(index, e)
    inflight.append(_Shard(index, task, dev, out, start,
                           immediate=not hasattr(out, "resolve"),
                           slow=slow, corrupt=corrupt))

  def try_speculate() -> None:
    """Twin the slowest straggler onto an idle healthy device.  A shard
    counts as a straggler when its injected-slow fault fired, or when it
    is unready past ``speculation_factor`` x the fleet-median EWMA
    latency.  First bit-identical result wins; the loser is discarded."""
    fleet_lat = pool.fleet_latency()
    now = time.perf_counter()
    for shard in inflight:
      if shard.speculated or shard.twin is not None or shard.dev is None:
        continue
      straggling = shard.slow
      if not straggling:
        if fleet_lat is None or _handle_ready(shard):
          continue
        straggling = (now - shard.t0) > pool.speculation_factor * fleet_lat
      if not straggling:
        continue
      alt = pool.checkout(require_idle=True, exclude=(shard.dev,))
      if alt is None:
        continue
      shard.speculated = True
      try:
        with pin(pool.device(alt)):
          out = execute(shard.task)
      except SweepKilled:
        checkpoint(force=True)
        raise
      except Exception:
        # the speculation failed, the original is still in flight —
        # mitigation must never make things worse
        pool.checkin(alt)
        pool.record_failure(alt)
        continue
      twin = _Shard(shard.index, shard.task, alt, out, now,
                    immediate=not hasattr(out, "resolve"),
                    corrupt=shard.corrupt)
      twin.twin = shard
      shard.twin = twin
      inflight.append(twin)
      pool.note_speculation()
      return

  def validate(dev: int, force: bool = False) -> None:
    """The SDC sentinel: once a device has ``sdc_check_every`` buffered
    results (or at final flush), re-evaluate one seeded sample chunk on
    the numpy rung and compare.  Match folds the whole buffer;
    divergence quarantines the device and replays its chunks."""
    buf = buffers.get(dev)
    if not buf:
      return
    if not force and len(buf) < pool.sdc_check_every:
      return
    pick = int(sdc_rng.randint(len(buf)))
    index, task, result = buf[pick]
    pool.note_corruption_check()
    reference = run_terminal(task)
    if _results_match(result, reference):
      for i, _, r in buf:
        finish_fold(i, r)
      buf.clear()
      return
    pool.note_corruption()
    pool.quarantine(dev)
    pool.note_reshard(len(buf))
    for i, t, _ in reversed(buf):
      queue.appendleft((i, t))
    buf.clear()

  def finish(shard: _Shard) -> None:
    inflight.remove(shard)
    twin = shard.twin
    if twin is not None:
      # keep-first: the twin's (bit-identical) result is abandoned;
      # jax drains the orphaned dispatch harmlessly
      if twin in inflight:
        inflight.remove(twin)
      if twin.dev is not None:
        pool.checkin(twin.dev)
      shard.twin = twin.twin = None
    try:
      result = shard.handle if shard.immediate else shard.handle.resolve()
    except SweepKilled:
      if shard.dev is not None:
        pool.checkin(shard.dev)
      checkpoint(force=True)
      raise
    except Exception as e:
      if shard.dev is not None:
        pool.checkin(shard.dev)
        pool.record_failure(shard.dev)
      fail(shard.index, e)
    if shard.dev is None:
      finish_fold(shard.index, result)
      return
    pool.checkin(shard.dev)
    pool.record_latency(shard.dev, time.perf_counter() - shard.t0)
    pool.record_success(shard.dev)
    if shard.corrupt:
      result = _corrupt_result(result)
    if pool.sdc_check_every > 0:
      buffers.setdefault(shard.dev, []).append(
          (shard.index, shard.task, result))
      validate(shard.dev)
    else:
      finish_fold(shard.index, result)

  while True:
    while len(inflight) < window_cap:
      item = next_item()       # requeued chunks first, then the source
      if item is None:
        break
      dispatch(*item)
    if inflight:
      try_speculate()
      shard = next((s for s in inflight if _handle_ready(s)), None)
      finish(shard if shard is not None else inflight[0])
      continue
    if queue:
      continue                 # device-lost replays still pending
    if any(buffers.values()):
      for dev in list(buffers):
        validate(dev, force=True)
      continue  # a failed validation requeues chunks
    break

  checkpoint(force=True)
  seconds = time.perf_counter() - t0
  n_retries, n_demotions = totals()
  fleet_now = pool.counters()
  meta = {"seconds": seconds, "workers": 1.0,
          "n_chunks": float(counters["n_chunks"]),
          "rows_transferred": float(counters["n_transferred"]),
          "rows_per_sec": counters["n_rows"] / max(seconds, 1e-12),
          "n_retries": float(n_retries),
          "n_demotions": float(n_demotions),
          "n_resumed_chunks": float(n_resumed),
          "n_overflows": float(counters["n_overflows"])}
  # per-run deltas of the (pool-lifetime) mitigation counters
  meta.update({k: float(fleet_now[k] - base_fleet[k]) for k in fleet_now})
  pool_meta = pool.meta()
  for k in ("fleet_devices", "fleet_device_states",
            "n_quarantined_devices", "fleet_device_chunks",
            "fleet_device_ewma_s"):
    meta[k] = pool_meta[k]
  if policy is not None:
    meta["n_leaked_watchdogs"] = float(policy.watchdogs.n_live())
    if policy.breaker is not None:
      meta.update(policy.breaker.meta())
  return StreamResult(
      results={name: r.result() for name, r in reducers.items()},
      n_rows=counters["n_rows"], seconds=seconds, meta=meta)
