"""Declarative design-space specification + deterministic sampling.

A :class:`DesignSpace` is the hardware half of QUIDAM's input space
(Fig. 2) as data: one :class:`Axis` per hardware knob (defaults from
``repro.core.ppa.HW_RANGES``, Sec. 3.3), a set of PE types, and optional
constraint predicates.  Sampling is deterministic in the seed and comes in
three flavours:

  random      independent uniform choice per axis (the paper's sampler;
              bit-identical to the legacy ``ppa.sample_configs`` sequence
              for the default axes)
  grid        evenly-strided slice of the full cartesian product
  stratified  per-axis latin-hypercube: every axis value covered evenly,
              axes decorrelated by independent seeded permutations
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.dataflow import AcceleratorConfig
from repro.core.pe import PAPER_PE_TYPES
from repro.core.ppa import HW_RANGES

# canonical axis order == AcceleratorConfig field order == the RNG call
# order of the legacy sampler (determinism contract, do not reorder)
AXIS_ORDER = ("pe_rows", "pe_cols", "sp_if", "sp_fw", "sp_ps", "gbuf_kb",
              "bandwidth_gbps")

Constraint = Callable[[AcceleratorConfig], bool]


@dataclasses.dataclass(frozen=True)
class Axis:
  """One discrete hardware knob: a name and its allowed values."""
  name: str
  values: Tuple[float, ...]

  def __post_init__(self):
    if self.name not in AXIS_ORDER:
      raise ValueError(f"unknown axis {self.name!r}; one of {AXIS_ORDER}")
    if not self.values:
      raise ValueError(f"axis {self.name!r} has no values")


class DesignSpace:
  """The declarative spec every exploration entry point consumes."""

  def __init__(self, pe_types: Sequence[str] = PAPER_PE_TYPES,
               axes: Optional[Mapping[str, Sequence[float]]] = None,
               constraints: Sequence[Constraint] = ()):
    self.pe_types = tuple(pe_types)
    overrides = dict(axes or {})
    unknown = set(overrides) - set(AXIS_ORDER)
    if unknown:
      raise ValueError(f"unknown axes {sorted(unknown)}; one of {AXIS_ORDER}")
    self.axes: Tuple[Axis, ...] = tuple(
        Axis(name, tuple(overrides.get(name, HW_RANGES[name])))
        for name in AXIS_ORDER)
    self.constraints = tuple(constraints)

  # -- introspection -------------------------------------------------------

  def axis(self, name: str) -> Axis:
    for a in self.axes:
      if a.name == name:
        return a
    raise KeyError(name)

  def size(self) -> int:
    """Cardinality of the unconstrained space (all PE types)."""
    per_type = math.prod(len(a.values) for a in self.axes)
    return per_type * len(self.pe_types)

  def __repr__(self) -> str:
    dims = "x".join(str(len(a.values)) for a in self.axes)
    return (f"DesignSpace({len(self.pe_types)} PE types x {dims} grid, "
            f"{len(self.constraints)} constraints, size={self.size():,})")

  # -- construction helpers ------------------------------------------------

  def _make(self, pe_type: str, values: Dict[str, float]) -> AcceleratorConfig:
    kw = {name: (float(v) if name == "bandwidth_gbps" else int(v))
          for name, v in values.items()}
    return AcceleratorConfig(pe_type=pe_type, **kw)

  def _passes(self, cfg: AcceleratorConfig) -> bool:
    return all(c(cfg) for c in self.constraints)

  # -- sampling ------------------------------------------------------------

  def sample_type(self, pe_type: str, n: int, seed: int = 0,
                  method: str = "random") -> List[AcceleratorConfig]:
    """n deterministic configs of one PE type (may return fewer than n for
    grid/stratified when constraints filter points)."""
    if pe_type not in self.pe_types:
      raise ValueError(f"{pe_type!r} not in this space's {self.pe_types}")
    if method == "random":
      return self._sample_random(pe_type, n, seed)
    if method == "grid":
      return self._sample_grid(pe_type, n)
    if method == "stratified":
      return self._sample_stratified(pe_type, n, seed)
    raise ValueError(f"unknown sampling method {method!r}; "
                     "one of ('random', 'grid', 'stratified')")

  def sample(self, n_per_type: int, seed: int = 0, method: str = "random"
             ) -> List[AcceleratorConfig]:
    """n_per_type configs for every PE type (legacy per-type seed offsets
    of 100*i, so default-space results match the old explorer exactly)."""
    out: List[AcceleratorConfig] = []
    for i, t in enumerate(self.pe_types):
      out.extend(self.sample_type(t, n_per_type, seed=seed + 100 * i,
                                  method=method))
    return out

  def _sample_random(self, pe_type: str, n: int, seed: int
                     ) -> List[AcceleratorConfig]:
    rng = np.random.RandomState(seed)
    out: List[AcceleratorConfig] = []
    tries = 0
    max_tries = max(1000 * n, 1000)
    while len(out) < n:
      if tries >= max_tries:
        raise ValueError(
            f"constraints rejected {tries} straight samples; the "
            f"constrained space is (nearly) empty for {pe_type}")
      cfg = self._make(pe_type,
                       {a.name: rng.choice(a.values) for a in self.axes})
      tries += 1
      if self._passes(cfg):
        out.append(cfg)
    return out

  def _sample_grid(self, pe_type: str, n: int) -> List[AcceleratorConfig]:
    sizes = [len(a.values) for a in self.axes]
    total = math.prod(sizes)
    if n >= total:
      flat = np.arange(total, dtype=np.int64)
    else:
      flat = np.unique(np.linspace(0, total - 1, n).astype(np.int64))
    out = []
    for idx in flat:
      values = {}
      for a, size in zip(reversed(self.axes), reversed(sizes)):
        values[a.name] = a.values[int(idx % size)]
        idx //= size
      cfg = self._make(pe_type, values)
      if self._passes(cfg):
        out.append(cfg)
    return out

  def _sample_stratified(self, pe_type: str, n: int, seed: int
                         ) -> List[AcceleratorConfig]:
    rng = np.random.RandomState(seed)
    cols: Dict[str, np.ndarray] = {}
    for a in self.axes:  # AXIS_ORDER: fixed RNG consumption order
      bins = (np.arange(n) * len(a.values)) // n  # even per-value coverage
      cols[a.name] = np.asarray(a.values)[bins][rng.permutation(n)]
    out = []
    for i in range(n):
      cfg = self._make(pe_type, {name: cols[name][i] for name in cols})
      if self._passes(cfg):
        out.append(cfg)
    return out
