"""Declarative design-space specification + deterministic sampling.

A :class:`DesignSpace` is the hardware half of QUIDAM's input space
(Fig. 2) as data: one :class:`Axis` per hardware knob (defaults from
``repro.core.ppa.HW_RANGES``, Sec. 3.3), a set of PE types, and optional
constraint predicates.  Sampling is deterministic in the seed and comes in
three flavours:

  random      independent uniform choice per axis (the paper's sampler;
              bit-identical to the legacy ``ppa.sample_configs`` sequence
              for the default axes)
  grid        evenly-strided slice of the full cartesian product
  stratified  per-axis latin-hypercube: every axis value covered evenly,
              axes decorrelated by independent seeded permutations

Every flavour also has a columnar twin (:meth:`DesignSpace.sample_table` /
:meth:`DesignSpace.sample_type_table`) that materializes a
:class:`~repro.core.table.ConfigTable` directly — million-point sweeps
never instantiate per-point dataclasses.  ``grid`` and ``stratified``
tables enumerate the exact same design-point sequence as their list twins;
``random`` tables draw column-major (one RNG call per axis) and therefore
have their own deterministic sequence.  Constraints apply to tables too:
plain per-config predicates are evaluated row-by-row (slow, correct),
while :func:`vector_constraint`-wrapped predicates filter whole columns.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.dataflow import AcceleratorConfig
from repro.core.pe import PAPER_PE_TYPES
from repro.core.ppa import HW_RANGES
from repro.core.table import ConfigTable

# canonical axis order == AcceleratorConfig field order == the RNG call
# order of the legacy sampler (determinism contract, do not reorder)
AXIS_ORDER = ("pe_rows", "pe_cols", "sp_if", "sp_fw", "sp_ps", "gbuf_kb",
              "bandwidth_gbps")

Constraint = Callable[[AcceleratorConfig], bool]


class VectorConstraint:
  """A constraint usable on both paths: a per-config predicate plus a
  columnar mask over a :class:`ConfigTable`.

  Built via :func:`vector_constraint`; plain callables remain valid
  constraints but force row-by-row evaluation when sampling tables.
  """

  def __init__(self, scalar: Constraint,
               mask: Callable[[ConfigTable], np.ndarray]):
    self._scalar = scalar
    self.mask = mask

  def __call__(self, cfg: AcceleratorConfig) -> bool:
    return bool(self._scalar(cfg))


def vector_constraint(scalar: Constraint,
                      mask: Callable[[ConfigTable], np.ndarray]
                      ) -> VectorConstraint:
  """Pair a scalar predicate with its vectorized table mask, e.g.::

      vector_constraint(lambda c: c.n_pe <= 256,
                        lambda t: t.n_pe <= 256)
  """
  return VectorConstraint(scalar, mask)


@dataclasses.dataclass(frozen=True)
class Axis:
  """One discrete hardware knob: a name and its allowed values."""
  name: str
  values: Tuple[float, ...]

  def __post_init__(self):
    if self.name not in AXIS_ORDER:
      raise ValueError(f"unknown axis {self.name!r}; one of {AXIS_ORDER}")
    if not self.values:
      raise ValueError(f"axis {self.name!r} has no values")


class DesignSpace:
  """The declarative spec every exploration entry point consumes."""

  def __init__(self, pe_types: Sequence[str] = PAPER_PE_TYPES,
               axes: Optional[Mapping[str, Sequence[float]]] = None,
               constraints: Sequence[Constraint] = ()):
    self.pe_types = tuple(pe_types)
    overrides = dict(axes or {})
    unknown = set(overrides) - set(AXIS_ORDER)
    if unknown:
      raise ValueError(f"unknown axes {sorted(unknown)}; one of {AXIS_ORDER}")
    self.axes: Tuple[Axis, ...] = tuple(
        Axis(name, tuple(overrides.get(name, HW_RANGES[name])))
        for name in AXIS_ORDER)
    self.constraints = tuple(constraints)

  # -- introspection -------------------------------------------------------

  def axis(self, name: str) -> Axis:
    for a in self.axes:
      if a.name == name:
        return a
    raise KeyError(name)

  def size(self) -> int:
    """Cardinality of the unconstrained space (all PE types)."""
    per_type = math.prod(len(a.values) for a in self.axes)
    return per_type * len(self.pe_types)

  def __repr__(self) -> str:
    dims = "x".join(str(len(a.values)) for a in self.axes)
    return (f"DesignSpace({len(self.pe_types)} PE types x {dims} grid, "
            f"{len(self.constraints)} constraints, size={self.size():,})")

  # -- construction helpers ------------------------------------------------

  def _make(self, pe_type: str, values: Dict[str, float]) -> AcceleratorConfig:
    kw = {name: (float(v) if name == "bandwidth_gbps" else int(v))
          for name, v in values.items()}
    return AcceleratorConfig(pe_type=pe_type, **kw)

  def _passes(self, cfg: AcceleratorConfig) -> bool:
    return all(c(cfg) for c in self.constraints)

  # -- sampling ------------------------------------------------------------

  def sample_type(self, pe_type: str, n: int, seed: int = 0,
                  method: str = "random") -> List[AcceleratorConfig]:
    """n deterministic configs of one PE type (may return fewer than n for
    grid/stratified when constraints filter points)."""
    if pe_type not in self.pe_types:
      raise ValueError(f"{pe_type!r} not in this space's {self.pe_types}")
    if method == "random":
      return self._sample_random(pe_type, n, seed)
    if method == "grid":
      return self._sample_grid(pe_type, n)
    if method == "stratified":
      return self._sample_stratified(pe_type, n, seed)
    raise ValueError(f"unknown sampling method {method!r}; "
                     "one of ('random', 'grid', 'stratified')")

  def sample(self, n_per_type: int, seed: int = 0, method: str = "random"
             ) -> List[AcceleratorConfig]:
    """n_per_type configs for every PE type (legacy per-type seed offsets
    of 100*i, so default-space results match the old explorer exactly)."""
    out: List[AcceleratorConfig] = []
    for i, t in enumerate(self.pe_types):
      out.extend(self.sample_type(t, n_per_type, seed=seed + 100 * i,
                                  method=method))
    return out

  def _sample_random(self, pe_type: str, n: int, seed: int
                     ) -> List[AcceleratorConfig]:
    rng = np.random.RandomState(seed)
    out: List[AcceleratorConfig] = []
    tries = 0
    max_tries = max(1000 * n, 1000)
    while len(out) < n:
      if tries >= max_tries:
        raise ValueError(
            f"constraints rejected {tries} straight samples; the "
            f"constrained space is (nearly) empty for {pe_type}")
      cfg = self._make(pe_type,
                       {a.name: rng.choice(a.values) for a in self.axes})
      tries += 1
      if self._passes(cfg):
        out.append(cfg)
    return out

  def _sample_grid(self, pe_type: str, n: int) -> List[AcceleratorConfig]:
    sizes = [len(a.values) for a in self.axes]
    total = math.prod(sizes)
    if n >= total:
      flat = np.arange(total, dtype=np.int64)
    else:
      flat = np.unique(np.linspace(0, total - 1, n).astype(np.int64))
    out = []
    for idx in flat:
      values = {}
      for a, size in zip(reversed(self.axes), reversed(sizes)):
        values[a.name] = a.values[int(idx % size)]
        idx //= size
      cfg = self._make(pe_type, values)
      if self._passes(cfg):
        out.append(cfg)
    return out

  def _sample_stratified(self, pe_type: str, n: int, seed: int
                         ) -> List[AcceleratorConfig]:
    rng = np.random.RandomState(seed)
    cols: Dict[str, np.ndarray] = {}
    for a in self.axes:  # AXIS_ORDER: fixed RNG consumption order
      bins = (np.arange(n) * len(a.values)) // n  # even per-value coverage
      cols[a.name] = np.asarray(a.values)[bins][rng.permutation(n)]
    out = []
    for i in range(n):
      cfg = self._make(pe_type, {name: cols[name][i] for name in cols})
      if self._passes(cfg):
        out.append(cfg)
    return out

  # -- columnar sampling (no per-point dataclasses) --------------------------

  def _table_mask(self, table: ConfigTable) -> np.ndarray:
    """Constraint mask over a candidate table.  VectorConstraints filter
    whole columns; plain predicates fall back to row-by-row dataclasses."""
    mask = np.ones(len(table), np.bool_)
    for c in self.constraints:
      if hasattr(c, "mask"):
        mask &= np.asarray(c.mask(table), np.bool_)
      else:
        idx = np.flatnonzero(mask)
        scalar = np.asarray([bool(c(table.config_at(int(i)))) for i in idx])
        mask[idx] &= scalar
    return mask

  def _make_table(self, pe_type: str, cols: Dict[str, np.ndarray]
                  ) -> ConfigTable:
    n = len(cols[AXIS_ORDER[0]])
    cast = {name: (np.asarray(v, np.float64) if name == "bandwidth_gbps"
                   else np.asarray(v).astype(np.int64))
            for name, v in cols.items()}
    return ConfigTable.full(pe_type, n, cast)

  def sample_type_table(self, pe_type: str, n: int, seed: int = 0,
                        method: str = "random") -> ConfigTable:
    """Columnar twin of :meth:`sample_type`: n deterministic design points
    of one PE type as a ConfigTable (fewer when constraints filter
    grid/stratified points)."""
    if pe_type not in self.pe_types:
      raise ValueError(f"{pe_type!r} not in this space's {self.pe_types}")
    if method == "random":
      return self._sample_random_table(pe_type, n, seed)
    if method == "grid":
      return self._sample_grid_table(pe_type, n)
    if method == "stratified":
      return self._sample_stratified_table(pe_type, n, seed)
    raise ValueError(f"unknown sampling method {method!r}; "
                     "one of ('random', 'grid', 'stratified')")

  def sample_table(self, n_per_type: int, seed: int = 0,
                   method: str = "random") -> ConfigTable:
    """Columnar twin of :meth:`sample` (same per-type seed offsets)."""
    return ConfigTable.concat([
        self.sample_type_table(t, n_per_type, seed=seed + 100 * i,
                               method=method)
        for i, t in enumerate(self.pe_types)])

  def _sample_random_table(self, pe_type: str, n: int, seed: int
                           ) -> ConfigTable:
    rng = np.random.RandomState(seed)
    if n <= 0:
      return self._make_table(
          pe_type, {a.name: np.asarray(a.values)[:0] for a in self.axes})
    kept: List[ConfigTable] = []
    have = 0
    drawn = 0
    max_draws = max(1000 * n, 1000)
    while have < n:
      batch = min(max(n - have, 1024), max_draws - drawn)
      if batch <= 0:
        raise ValueError(
            f"constraints rejected all but {have}/{n} of {drawn} draws; the "
            f"constrained space is (nearly) empty for {pe_type}")
      # column-major draws: one rng.choice per axis, in AXIS_ORDER
      cols = {a.name: np.asarray(a.values)[
          rng.randint(0, len(a.values), size=batch)] for a in self.axes}
      drawn += batch
      cand = self._make_table(pe_type, cols)
      mask = self._table_mask(cand)
      if mask.all() and not kept:
        kept, have = [cand], len(cand)
      else:
        sub = cand.select(mask)
        kept.append(sub)
        have += len(sub)
    table = kept[0] if len(kept) == 1 else ConfigTable.concat(kept)
    return table.select(slice(0, n))

  def _sample_grid_table(self, pe_type: str, n: int) -> ConfigTable:
    """Same evenly-strided flat indices (and therefore the exact same
    design-point sequence) as :meth:`_sample_grid`, unraveled columnwise."""
    sizes = [len(a.values) for a in self.axes]
    total = math.prod(sizes)
    if n >= total:
      flat = np.arange(total, dtype=np.int64)
    else:
      flat = np.unique(np.linspace(0, total - 1, n).astype(np.int64))
    idx = flat.copy()
    cols: Dict[str, np.ndarray] = {}
    for a, size in zip(reversed(self.axes), reversed(sizes)):
      cols[a.name] = np.asarray(a.values)[idx % size]
      idx //= size
    table = self._make_table(pe_type, cols)
    return table.select(self._table_mask(table))

  def _sample_stratified_table(self, pe_type: str, n: int, seed: int
                               ) -> ConfigTable:
    """Identical column construction + RNG consumption to
    :meth:`_sample_stratified`, so both paths yield the same sequence."""
    rng = np.random.RandomState(seed)
    cols: Dict[str, np.ndarray] = {}
    for a in self.axes:  # AXIS_ORDER: fixed RNG consumption order
      bins = (np.arange(n) * len(a.values)) // n
      cols[a.name] = np.asarray(a.values)[bins][rng.permutation(n)]
    table = self._make_table(pe_type, cols)
    return table.select(self._table_mask(table))
