"""Declarative design-space specification + deterministic sampling.

A :class:`DesignSpace` is the hardware half of QUIDAM's input space
(Fig. 2) as data: one :class:`Axis` per hardware knob (defaults from
``repro.core.ppa.HW_RANGES``, Sec. 3.3), a set of PE types, and optional
constraint predicates.  Sampling is deterministic in the seed and comes in
three flavours:

  random      independent uniform choice per axis (the paper's sampler;
              bit-identical to the legacy ``ppa.sample_configs`` sequence
              for the default axes)
  grid        evenly-strided slice of the full cartesian product
  stratified  per-axis latin-hypercube: every axis value covered evenly,
              axes decorrelated by independent seeded permutations

Every flavour also has a columnar twin (:meth:`DesignSpace.sample_table` /
:meth:`DesignSpace.sample_type_table`) that materializes a
:class:`~repro.core.table.ConfigTable` directly — million-point sweeps
never instantiate per-point dataclasses.  ``grid`` and ``stratified``
tables enumerate the exact same design-point sequence as their list twins;
``random`` tables draw column-major (one independent seeded RNG stream
per axis) and therefore have their own deterministic sequence.
Constraints apply to tables too: plain per-config predicates are
evaluated row-by-row (slow, correct), while
:func:`vector_constraint`-wrapped predicates filter whole columns.

On top of the one-shot twins sits the *lazy* flavour the streaming sweep
engine (:mod:`repro.explore.streaming`) consumes:
:meth:`DesignSpace.iter_type_tables` / :meth:`DesignSpace.iter_tables`
yield bounded-size ConfigTable chunks whose concatenation is bit-identical
to the corresponding ``sample_*_table`` call — for any chunk size — so a
100M-point sweep never materializes its full table.  ``random`` chunks are
truly constant-memory (the per-axis RNG streams are drawn incrementally;
legacy ``RandomState`` bounded ints are generated element-sequentially, so
chunked draws concatenate exactly); ``grid`` chunks are computed from
index arithmetic; ``stratified`` needs its per-axis permutations up front
and therefore holds O(n) *index* arrays (still no full value table).
"""
from __future__ import annotations

import dataclasses
import math
from typing import (Callable, Dict, Iterator, List, Mapping, Optional,
                    Sequence, Tuple)

import numpy as np

from repro.core.dataflow import AcceleratorConfig
from repro.core.pe import PAPER_PE_TYPES
from repro.core.ppa import HW_RANGES
from repro.core.table import ConfigTable

# canonical axis order == AcceleratorConfig field order == the RNG call
# order of the legacy sampler (determinism contract, do not reorder)
AXIS_ORDER = ("pe_rows", "pe_cols", "sp_if", "sp_fw", "sp_ps", "gbuf_kb",
              "bandwidth_gbps")

Constraint = Callable[[AcceleratorConfig], bool]


class VectorConstraint:
  """A constraint usable on both paths: a per-config predicate plus a
  columnar mask over a :class:`ConfigTable`.

  Built via :func:`vector_constraint`; plain callables remain valid
  constraints but force row-by-row evaluation when sampling tables.
  """

  def __init__(self, scalar: Constraint,
               mask: Callable[[ConfigTable], np.ndarray]):
    self._scalar = scalar
    self.mask = mask

  def __call__(self, cfg: AcceleratorConfig) -> bool:
    return bool(self._scalar(cfg))


def vector_constraint(scalar: Constraint,
                      mask: Callable[[ConfigTable], np.ndarray]
                      ) -> VectorConstraint:
  """Pair a scalar predicate with its vectorized table mask, e.g.::

      vector_constraint(lambda c: c.n_pe <= 256,
                        lambda t: t.n_pe <= 256)
  """
  return VectorConstraint(scalar, mask)


@dataclasses.dataclass(frozen=True)
class Axis:
  """One discrete hardware knob: a name and its allowed values."""
  name: str
  values: Tuple[float, ...]

  def __post_init__(self):
    if self.name not in AXIS_ORDER:
      raise ValueError(f"unknown axis {self.name!r}; one of {AXIS_ORDER}")
    if not self.values:
      raise ValueError(f"axis {self.name!r} has no values")


class DesignSpace:
  """The declarative spec every exploration entry point consumes."""

  def __init__(self, pe_types: Sequence[str] = PAPER_PE_TYPES,
               axes: Optional[Mapping[str, Sequence[float]]] = None,
               constraints: Sequence[Constraint] = ()):
    self.pe_types = tuple(pe_types)
    overrides = dict(axes or {})
    unknown = set(overrides) - set(AXIS_ORDER)
    if unknown:
      raise ValueError(f"unknown axes {sorted(unknown)}; one of {AXIS_ORDER}")
    self.axes: Tuple[Axis, ...] = tuple(
        Axis(name, tuple(overrides.get(name, HW_RANGES[name])))
        for name in AXIS_ORDER)
    self.constraints = tuple(constraints)

  # -- introspection -------------------------------------------------------

  def axis(self, name: str) -> Axis:
    for a in self.axes:
      if a.name == name:
        return a
    raise KeyError(name)

  def size(self) -> int:
    """Cardinality of the unconstrained space (all PE types)."""
    per_type = math.prod(len(a.values) for a in self.axes)
    return per_type * len(self.pe_types)

  def per_type_grid_size(self) -> int:
    """Cardinality of one PE type's unconstrained axis grid."""
    return math.prod(len(a.values) for a in self.axes)

  def __repr__(self) -> str:
    dims = "x".join(str(len(a.values)) for a in self.axes)
    return (f"DesignSpace({len(self.pe_types)} PE types x {dims} grid, "
            f"{len(self.constraints)} constraints, size={self.size():,})")

  # -- subgrid diffing (delta-sweep support, see repro.explore.store) --------

  def with_axes(self, **overrides) -> "DesignSpace":
    """A copy of this space with the given axes' value tuples replaced
    (PE types and constraints carried over)."""
    axes = {a.name: a.values for a in self.axes}
    axes.update({name: tuple(vals) for name, vals in overrides.items()})
    return DesignSpace(self.pe_types, axes, self.constraints)

  def axis_delta(self, base) -> Optional[Tuple[str, Tuple[float, ...]]]:
    """The single-axis edit turning ``base`` into this space, if any.

    Returns ``(axis_name, added_values)`` when exactly one axis differs
    and the base axis' values appear in this axis' values in the same
    relative order (an in-order supersequence).  That order condition is
    what makes the :meth:`grid_rank` remap of base points strictly
    monotone — the soundness requirement for merging a cached sweep into
    an edited space (selections and tie-breaks are order-determined).
    ``base`` may be another DesignSpace or a ``{axis: values}`` mapping
    (a stored manifest; PE-type/constraint compatibility is then the
    caller's check).  None when the spaces are identical, differ on more
    than one axis, drop values, or break the order condition.
    """
    if isinstance(base, DesignSpace):
      if (self.pe_types != base.pe_types
          or len(self.constraints) != len(base.constraints)):
        return None
      base_axes = {a.name: a.values for a in base.axes}
    else:
      base_axes = {name: tuple(vals) for name, vals in dict(base).items()}
      if set(base_axes) != {a.name for a in self.axes}:
        return None
    diff: Optional[Tuple[str, Tuple[float, ...]]] = None
    for a in self.axes:
      bv = base_axes[a.name]
      if tuple(a.values) == bv:
        continue
      if diff is not None:
        return None  # more than one axis edited
      it = iter(a.values)
      if not all(any(v == w for w in it) for v in bv):
        return None  # a base value was dropped or reordered
      base_set = set(bv)
      added = tuple(v for v in a.values if v not in base_set)
      if len(added) + len(bv) != len(a.values):
        return None  # duplicated values
      diff = (a.name, added)
    return diff

  def grid_rank(self, table: ConfigTable) -> np.ndarray:
    """Canonical global row ids: each row's mixed-radix rank in this
    space's full-grid enumeration (PE-type-major, axes in AXIS_ORDER
    with the last axis fastest — exactly the ``method="grid"`` visit
    order).  Unlike the engine's compacted ``arange`` ids, these ranks
    are a pure function of the row's *values*, so points keep an
    order-isomorphic addressing when an axis gains values: delta-sweeps
    re-rank cached survivors here before folding the new subgrid."""
    try:
      code_to_type = np.asarray(
          [self.pe_types.index(nm) for nm in table.pe_type_names], np.int64)
    except ValueError:
      raise ValueError("table contains PE types outside this space")
    rank = code_to_type[np.asarray(table.pe_code, np.int64)]
    for a in self.axes:
      vals = np.asarray(a.values)
      col = np.asarray(getattr(table, a.name))
      order = np.argsort(vals, kind="stable")
      pos = np.clip(np.searchsorted(vals[order], col), 0, len(vals) - 1)
      ai = order[pos]
      if not np.array_equal(vals[ai], col.astype(vals.dtype)):
        raise ValueError(f"axis {a.name!r}: table values outside this space")
      rank = rank * len(vals) + ai
    return rank.astype(np.int64)

  # -- construction helpers ------------------------------------------------

  def _make(self, pe_type: str, values: Dict[str, float]) -> AcceleratorConfig:
    kw = {name: (float(v) if name == "bandwidth_gbps" else int(v))
          for name, v in values.items()}
    return AcceleratorConfig(pe_type=pe_type, **kw)

  def _passes(self, cfg: AcceleratorConfig) -> bool:
    return all(c(cfg) for c in self.constraints)

  # -- sampling ------------------------------------------------------------

  def sample_type(self, pe_type: str, n: int, seed: int = 0,
                  method: str = "random") -> List[AcceleratorConfig]:
    """n deterministic configs of one PE type (may return fewer than n for
    grid/stratified when constraints filter points)."""
    if pe_type not in self.pe_types:
      raise ValueError(f"{pe_type!r} not in this space's {self.pe_types}")
    if method == "random":
      return self._sample_random(pe_type, n, seed)
    if method == "grid":
      return self._sample_grid(pe_type, n)
    if method == "stratified":
      return self._sample_stratified(pe_type, n, seed)
    raise ValueError(f"unknown sampling method {method!r}; "
                     "one of ('random', 'grid', 'stratified')")

  def sample(self, n_per_type: int, seed: int = 0, method: str = "random"
             ) -> List[AcceleratorConfig]:
    """n_per_type configs for every PE type (legacy per-type seed offsets
    of 100*i, so default-space results match the old explorer exactly)."""
    out: List[AcceleratorConfig] = []
    for i, t in enumerate(self.pe_types):
      out.extend(self.sample_type(t, n_per_type, seed=seed + 100 * i,
                                  method=method))
    return out

  def _sample_random(self, pe_type: str, n: int, seed: int
                     ) -> List[AcceleratorConfig]:
    rng = np.random.RandomState(seed)
    out: List[AcceleratorConfig] = []
    tries = 0
    max_tries = max(1000 * n, 1000)
    while len(out) < n:
      if tries >= max_tries:
        raise ValueError(
            f"constraints rejected {tries} straight samples; the "
            f"constrained space is (nearly) empty for {pe_type}")
      cfg = self._make(pe_type,
                       {a.name: rng.choice(a.values) for a in self.axes})
      tries += 1
      if self._passes(cfg):
        out.append(cfg)
    return out

  def _sample_grid(self, pe_type: str, n: int) -> List[AcceleratorConfig]:
    sizes = [len(a.values) for a in self.axes]
    total = math.prod(sizes)
    if n >= total:
      flat = np.arange(total, dtype=np.int64)
    else:
      flat = np.unique(np.linspace(0, total - 1, n).astype(np.int64))
    out = []
    for idx in flat:
      values = {}
      for a, size in zip(reversed(self.axes), reversed(sizes)):
        values[a.name] = a.values[int(idx % size)]
        idx //= size
      cfg = self._make(pe_type, values)
      if self._passes(cfg):
        out.append(cfg)
    return out

  def _sample_stratified(self, pe_type: str, n: int, seed: int
                         ) -> List[AcceleratorConfig]:
    rng = np.random.RandomState(seed)
    cols: Dict[str, np.ndarray] = {}
    for a in self.axes:  # AXIS_ORDER: fixed RNG consumption order
      bins = (np.arange(n) * len(a.values)) // n  # even per-value coverage
      cols[a.name] = np.asarray(a.values)[bins][rng.permutation(n)]
    out = []
    for i in range(n):
      cfg = self._make(pe_type, {name: cols[name][i] for name in cols})
      if self._passes(cfg):
        out.append(cfg)
    return out

  # -- columnar sampling (no per-point dataclasses) --------------------------

  def _table_mask(self, table: ConfigTable) -> np.ndarray:
    """Constraint mask over a candidate table.  VectorConstraints filter
    whole columns; plain predicates fall back to row-by-row dataclasses."""
    mask = np.ones(len(table), np.bool_)
    for c in self.constraints:
      if hasattr(c, "mask"):
        mask &= np.asarray(c.mask(table), np.bool_)
      else:
        idx = np.flatnonzero(mask)
        scalar = np.asarray([bool(c(table.config_at(int(i)))) for i in idx])
        mask[idx] &= scalar
    return mask

  def table_mask(self, table: ConfigTable) -> np.ndarray:
    """Public constraint mask over a candidate table — the guided-search
    variation operators (:mod:`repro.explore.search`) re-validate every
    mutated/crossed-over population through this before spending
    evaluation budget."""
    return self._table_mask(table)

  def _make_table(self, pe_type: str, cols: Dict[str, np.ndarray]
                  ) -> ConfigTable:
    n = len(cols[AXIS_ORDER[0]])
    cast = {name: (np.asarray(v, np.float64) if name == "bandwidth_gbps"
                   else np.asarray(v).astype(np.int64))
            for name, v in cols.items()}
    return ConfigTable.full(pe_type, n, cast)

  def sample_type_table(self, pe_type: str, n: int, seed: int = 0,
                        method: str = "random") -> ConfigTable:
    """Columnar twin of :meth:`sample_type`: n deterministic design points
    of one PE type as a ConfigTable (fewer when constraints filter
    grid/stratified points)."""
    if pe_type not in self.pe_types:
      raise ValueError(f"{pe_type!r} not in this space's {self.pe_types}")
    if method == "random":
      return self._sample_random_table(pe_type, n, seed)
    if method == "grid":
      return self._sample_grid_table(pe_type, n)
    if method == "stratified":
      return self._sample_stratified_table(pe_type, n, seed)
    raise ValueError(f"unknown sampling method {method!r}; "
                     "one of ('random', 'grid', 'stratified')")

  def sample_table(self, n_per_type: int, seed: int = 0,
                   method: str = "random") -> ConfigTable:
    """Columnar twin of :meth:`sample` (same per-type seed offsets)."""
    return ConfigTable.concat([
        self.sample_type_table(t, n_per_type, seed=seed + 100 * i,
                               method=method)
        for i, t in enumerate(self.pe_types)])

  def _empty_table(self, pe_type: str) -> ConfigTable:
    return self._make_table(
        pe_type, {a.name: np.asarray(a.values)[:0] for a in self.axes})

  def _axis_rngs(self, seed: int) -> List[np.random.RandomState]:
    """One independent RandomState per axis, derived from (seed, axis
    index).  Per-axis streams are the determinism contract that makes
    chunked random sampling bit-identical to one-shot sampling: legacy
    RandomState bounded ints are drawn element-sequentially, so the i-th
    value of axis ``a`` is the same for every draw batching."""
    return [np.random.RandomState(
        np.asarray([seed % (2 ** 32), 0x9E3779B9 ^ ai], np.uint32))
            for ai in range(len(self.axes))]

  def _sample_random_table(self, pe_type: str, n: int, seed: int
                           ) -> ConfigTable:
    parts = list(self._iter_random_table(pe_type, n, seed,
                                         chunk_size=max(n, 1024)))
    return ConfigTable.concat(parts) if parts else self._empty_table(pe_type)

  def _iter_random_table(self, pe_type: str, n: int, seed: int,
                         chunk_size: int) -> Iterator[ConfigTable]:
    """Candidate stream: fixed per-axis RNG sequences, filtered row-local
    by constraints, truncated to the first n passing rows.  The kept
    prefix is independent of ``chunk_size`` by construction."""
    if n <= 0:
      return
    rngs = self._axis_rngs(seed)
    have = 0
    drawn = 0
    max_draws = max(1000 * n, 1000)
    while have < n:
      batch = min(chunk_size, max_draws - drawn)
      if batch <= 0:
        raise ValueError(
            f"constraints rejected all but {have}/{n} of {drawn} draws; the "
            f"constrained space is (nearly) empty for {pe_type}")
      cols = {a.name: np.asarray(a.values)[
          rng.randint(0, len(a.values), size=batch)]
          for a, rng in zip(self.axes, rngs)}
      drawn += batch
      cand = self._make_table(pe_type, cols)
      mask = self._table_mask(cand)
      kept = cand if mask.all() else cand.select(mask)
      if len(kept) > n - have:
        kept = kept.select(slice(0, n - have))
      have += len(kept)
      if len(kept):
        yield kept

  # -- lazy chunked sampling (the streaming engine's input side) -------------

  def iter_type_tables(self, pe_type: str, n: int, seed: int = 0,
                       method: str = "random", chunk_size: int = 65536
                       ) -> Iterator[ConfigTable]:
    """Lazy twin of :meth:`sample_type_table`: yields ConfigTable chunks
    of <= chunk_size rows whose concatenation is bit-identical to the
    one-shot table, for any chunk size — the full table is never
    materialized (``stratified`` holds O(n) per-axis index arrays; see
    the module docstring)."""
    if pe_type not in self.pe_types:
      raise ValueError(f"{pe_type!r} not in this space's {self.pe_types}")
    if chunk_size <= 0:
      raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    if method == "random":
      return self._iter_random_table(pe_type, n, seed, chunk_size)
    if method == "grid":
      return self._iter_grid_table(pe_type, n, chunk_size)
    if method == "stratified":
      return self._iter_stratified_table(pe_type, n, seed, chunk_size)
    raise ValueError(f"unknown sampling method {method!r}; "
                     "one of ('random', 'grid', 'stratified')")

  def iter_tables(self, n_per_type: int, seed: int = 0,
                  method: str = "random", chunk_size: int = 65536
                  ) -> Iterator[ConfigTable]:
    """Lazy twin of :meth:`sample_table` (same per-type seed offsets);
    chunks arrive per PE type, in type order."""
    for i, t in enumerate(self.pe_types):
      yield from self.iter_type_tables(t, n_per_type, seed=seed + 100 * i,
                                       method=method, chunk_size=chunk_size)

  def _grid_flat_indices(self, n: int, total: int, lo: int, hi: int,
                         prev_last: int) -> np.ndarray:
    """Flat grid indices for linspace positions [lo, hi), deduplicated
    against truncation collisions exactly like the one-shot
    ``np.unique(np.linspace(...))`` (values are monotone, so global
    unique == drop-adjacent-equal with ``prev_last`` carried across
    chunk boundaries)."""
    if n >= total:
      return np.arange(lo, hi, dtype=np.int64)
    pos = np.arange(lo, hi, dtype=np.int64)
    if n == 1:
      flat = np.zeros(pos.shape, np.int64)
    else:
      # mirror np.linspace(0, total-1, n): arange * step, endpoint pinned
      flat = (pos * ((total - 1) / (n - 1))).astype(np.int64)
      flat[pos == n - 1] = total - 1
    keep = np.empty(flat.shape, np.bool_)
    if flat.size:
      keep[0] = flat[0] != prev_last
      keep[1:] = flat[1:] != flat[:-1]
    return flat[keep]

  def _iter_grid_table(self, pe_type: str, n: int, chunk_size: int
                       ) -> Iterator[ConfigTable]:
    sizes = [len(a.values) for a in self.axes]
    total = math.prod(sizes)
    n_pos = total if n >= total else max(n, 0)
    prev_last = -1
    for lo in range(0, n_pos, chunk_size):
      flat = self._grid_flat_indices(n, total, lo,
                                     min(lo + chunk_size, n_pos), prev_last)
      if not flat.size:
        continue
      prev_last = int(flat[-1])
      idx = flat.copy()
      cols: Dict[str, np.ndarray] = {}
      for a, size in zip(reversed(self.axes), reversed(sizes)):
        cols[a.name] = np.asarray(a.values)[idx % size]
        idx //= size
      table = self._make_table(pe_type, cols)
      table = table.select(self._table_mask(table))
      if len(table):
        yield table

  def _iter_stratified_table(self, pe_type: str, n: int, seed: int,
                             chunk_size: int) -> Iterator[ConfigTable]:
    rng = np.random.RandomState(seed)
    # per-axis *index* arrays only (uint16: axis cardinalities are tiny) —
    # values gather per chunk, so the retained state is ~2 bytes/row/axis,
    # not the full float64/int64 value table.  values[bins][perm] ==
    # values[bins[perm]], keeping the one-shot RNG consumption + sequence.
    idx_cols: Dict[str, np.ndarray] = {}
    for a in self.axes:  # AXIS_ORDER: fixed RNG consumption order
      bins = (np.arange(n) * len(a.values)) // n
      idx_cols[a.name] = bins[rng.permutation(n)].astype(np.uint16)
    for lo in range(0, n, chunk_size):
      sl = slice(lo, lo + chunk_size)
      table = self._make_table(
          pe_type, {a.name: np.asarray(a.values)[idx_cols[a.name][sl]]
                    for a in self.axes})
      table = table.select(self._table_mask(table))
      if len(table):
        yield table

  def _sample_grid_table(self, pe_type: str, n: int) -> ConfigTable:
    """Same evenly-strided flat indices (and therefore the exact same
    design-point sequence) as :meth:`_sample_grid`, unraveled columnwise
    (single-chunk drain of :meth:`_iter_grid_table`)."""
    parts = list(self._iter_grid_table(pe_type, n, chunk_size=max(n, 1)))
    return ConfigTable.concat(parts) if parts else self._empty_table(pe_type)

  def _sample_stratified_table(self, pe_type: str, n: int, seed: int
                               ) -> ConfigTable:
    """Identical column construction + RNG consumption to
    :meth:`_sample_stratified`, so both paths yield the same sequence
    (single-chunk drain of :meth:`_iter_stratified_table`)."""
    parts = list(self._iter_stratified_table(pe_type, n, seed,
                                             chunk_size=max(n, 1)))
    return ConfigTable.concat(parts) if parts else self._empty_table(pe_type)
