"""Hardened exploration service: concurrent sessions over one shared
device executor, with production-grade failure behavior at every
boundary.

QUIDAM's cheap pre-characterized evaluations (Sec. 4.1) invite many
overlapping consumers — interactive sweeps, co-explorations, guided
searches — but the device executor is one shared resource.  The
:class:`ExplorationService` multiplexes them with the fixed-slot
scheduler shape of :class:`repro.serve.engine.ServeEngine` (session
slots instead of decode slots): a bounded submission queue feeds a small
set of active sessions, and each scheduler pass gives every active
session one unit of work — dispatch one chunk into its bounded
``dispatch_ahead`` window or resolve its oldest pending chunk — so
sessions interleave fairly through the same async-dispatch machinery
``run_stream`` uses.

Failure behavior, layer by layer (see docs/explore.md "Exploration
service & result store"):

  admission   a full queue raises a typed :class:`AdmissionRejected` at
              submit time (backpressure, not buffering); per-session
              ``chunk_budget`` bounds how much executor time one request
              can consume, failing over to a typed
              :class:`BudgetExhausted` with progress journaled.
  deadlines   a per-request :class:`Deadline` (monotonic, injectable
              clock) is threaded into the
              :class:`~repro.explore.resilience.ResiliencePolicy`
              resolve-time watchdog as ``min(base, remaining)``; an
              expired or cancelled session abandons its in-flight
              chunks (the abandoned device work drains harmlessly, as
              with any watchdogged resolution) without poisoning
              neighboring sessions, and its journal keeps the finished
              chunks for a later resume.
  breaker     one :class:`~repro.explore.resilience.CircuitBreaker` is
              shared by all sessions: persistent device-rung failures
              open it and new chunks route straight to the terminal
              numpy rung (bit-identical by the parity contract) for a
              seeded cooldown, then half-open probes; transitions land
              in every session's ``StreamResult.meta``.
  store       with a :class:`~repro.explore.store.ResultStore`
              attached, finished sweeps are served from the store
              (``store_hit``), one-axis-edited full-grid sweeps run as
              delta-sweeps over just the new subgrid, and in-progress
              sessions checkpoint into the store's append-log journal —
              a kill (:class:`~repro.explore.resilience.SweepKilled`)
              aborts the whole service the way a process death would,
              and resubmitting the same work replays from the store.

Everything rests on the same structural facts as ``run_stream``: chunks
are pure functions of their index and reducers are chunk-order
invariant, so any interleaving, demotion, breaker reroute, resume, or
delta merge yields bit-identical reductions (chaos-tested in
``tests/test_service.py``).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.explore.resilience import (ChunkError, ChunkTask, CircuitBreaker,
                                      FaultPlan, ResiliencePolicy,
                                      RetryPolicy, Rung, SweepJournal,
                                      SweepKilled, reducers_fingerprint,
                                      space_fingerprint, sweep_key)
from repro.explore.space import DesignSpace
from repro.explore.store import (ResultStore, _explore_manifest,
                                 _restore_delta_base, _snapshot_state,
                                 co_explore_result_key, explore_result_key,
                                 find_delta_base)
from repro.explore.streaming import (DISPATCH_AHEAD, Reducer, StreamResult,
                                     co_explore_sweep_key, co_explore_tasks,
                                     default_co_reducers,
                                     default_explore_reducers,
                                     explore_sweep_key, explore_tasks,
                                     fold_chunk, new_counters)

# how long SessionHandle.result / service joins wait per condition poll —
# every wait in this module is bounded (the ROB002 idiom)
_POLL_SECONDS = 0.05
_JOIN_SECONDS = 5.0


class AdmissionRejected(RuntimeError):
  """The submission queue is full — typed backpressure, not buffering."""

  def __init__(self, queued: int, max_queued: int):
    self.queued = int(queued)
    self.max_queued = int(max_queued)
    super().__init__(f"submission queue full ({queued}/{max_queued}); "
                     "retry after a session completes")


class BudgetExhausted(RuntimeError):
  """A session spent its per-request chunk budget.  Progress up to the
  budget is journaled — resubmitting with a larger budget resumes."""

  def __init__(self, session: int, budget: int):
    self.session = int(session)
    self.budget = int(budget)
    super().__init__(f"session {session} exhausted its {budget}-chunk "
                     "budget (progress journaled; resubmit to resume)")


class DeadlineExceeded(RuntimeError):
  """A session's wall-clock deadline expired.  In-flight chunks are
  abandoned, finished chunks are journaled for resume."""

  def __init__(self, session: int, deadline: "Deadline"):
    self.session = int(session)
    super().__init__(f"session {session} exceeded its "
                     f"{deadline.seconds}s deadline "
                     "(progress journaled; resubmit to resume)")


class SessionCancelled(RuntimeError):
  """The client cancelled the session; progress is journaled."""

  def __init__(self, session: int):
    self.session = int(session)
    super().__init__(f"session {session} cancelled "
                     "(progress journaled; resubmit to resume)")


class Deadline:
  """A monotonic wall-clock budget, started at construction.

  The clock is injectable so tests (and the serve engine's
  deterministic harnesses) can expire deadlines without wall-waiting;
  the default is ``time.monotonic`` — deliberately not ``time.time``,
  which NTP can step backwards.  Shared by the exploration service and
  :class:`repro.serve.engine.ServeEngine` request eviction.
  """

  def __init__(self, seconds: float,
               clock: Callable[[], float] = time.monotonic):
    self.seconds = float(seconds)
    self.clock = clock
    self._t0 = clock()

  def remaining(self) -> float:
    return self.seconds - (self.clock() - self._t0)

  def expired(self) -> bool:
    return self.remaining() <= 0.0

  def __repr__(self) -> str:
    return f"Deadline({self.seconds}s, {self.remaining():.3f}s left)"


# session lifecycle: queued -> running -> one terminal state
SESSION_STATES = ("queued", "running", "done", "failed", "cancelled",
                  "expired")


class SessionHandle:
  """The client's view of a submitted session."""

  def __init__(self, session: "_Session"):
    self._s = session

  @property
  def session_id(self) -> int:
    return self._s.sid

  @property
  def kind(self) -> str:
    return self._s.kind

  @property
  def status(self) -> str:
    return self._s.state

  def cancel(self) -> None:
    """Request cooperative cancellation; the scheduler journals progress
    and abandons in-flight work at its next pass over the session."""
    self._s.cancel_requested = True

  def result(self, timeout: Optional[float] = 60.0) -> StreamResult:
    """The session's StreamResult; raises the session's typed error for
    failed/expired/cancelled sessions, TimeoutError if the session is
    still live after ``timeout`` (bounded — never an unbounded wait)."""
    s = self._s
    t0 = time.monotonic()
    with s.cond:
      while s.state in ("queued", "running"):
        if timeout is not None and time.monotonic() - t0 >= timeout:
          raise TimeoutError(
              f"session {s.sid} still {s.state} after {timeout}s; "
              "drain() the service or start() its scheduler thread")
        s.cond.wait(_POLL_SECONDS)
    if s.error is not None:
      raise s.error
    return s.result


class _Session:
  """Scheduler-internal state shared by sweep and search sessions."""

  def __init__(self, sid: int, kind: str, policy: ResiliencePolicy,
               deadline: Optional[Deadline], chunk_budget: Optional[int],
               journal: Optional[SweepJournal], journal_key: str):
    self.sid = sid
    self.kind = kind
    self.policy = policy
    self.deadline = deadline
    self.chunk_budget = chunk_budget
    self.journal = journal
    self.journal_key = journal_key
    self.state = "queued"
    self.cancel_requested = False
    self.error: Optional[BaseException] = None
    self.result: Optional[StreamResult] = None
    self.cond = threading.Condition()
    self.t0: Optional[float] = None
    self.n_dispatched = 0  # fresh chunks this run (budget unit)
    self.meta_extra: Dict[str, float] = {}

  def finalize(self, state: str, error: Optional[BaseException] = None,
               result: Optional[StreamResult] = None) -> None:
    with self.cond:
      self.state = state
      self.error = error
      self.result = result
      self.cond.notify_all()


class _SweepSession(_Session):
  """An explore/co-explore sweep interleaved chunk-by-chunk."""

  def __init__(self, sid: int, kind: str, policy: ResiliencePolicy,
               deadline: Optional[Deadline], chunk_budget: Optional[int],
               journal: Optional[SweepJournal], journal_key: str,
               reducers: Dict[str, Reducer], tasks,
               dispatch_ahead: int, checkpoint_every: int,
               result_key: str = "", manifest=None):
    super().__init__(sid, kind, policy, deadline, chunk_budget, journal,
                     journal_key)
    self.reducers = reducers
    self.task_iter = iter(tasks)
    self.next_task: Optional[ChunkTask] = None
    self.exhausted = False
    self.window: deque = deque()
    self.dispatch_ahead = max(int(dispatch_ahead), 0)
    self.checkpoint_every = max(int(checkpoint_every), 1)
    self.result_key = result_key
    self.manifest = manifest
    self.counters = new_counters()
    self.done_chunks: set = set()
    self.n_resumed = 0
    self._since_ckpt = 0
    self._base_retries = 0
    self._base_demotions = 0

  def adopt_checkpoint(self, state: Dict[str, object]) -> None:
    self.done_chunks = set(state["done"])
    for name, r in self.reducers.items():
      r.restore(state["reducers"][name])
    self.counters.update(state["counters"])
    self.n_resumed = len(self.done_chunks)
    self._base_retries = self.counters["n_retries"]
    self._base_demotions = self.counters["n_demotions"]

  def totals(self) -> Tuple[int, int]:
    return (self._base_retries + self.policy.n_retries,
            self._base_demotions + self.policy.n_demotions)

  def checkpoint(self, force: bool = False) -> None:
    if self.journal is None:
      return
    self._since_ckpt += 1
    if not force and self._since_ckpt < self.checkpoint_every:
      return
    r, d = self.totals()
    self.counters["n_retries"], self.counters["n_demotions"] = r, d
    self.journal.append(self.journal_key, {
        "done": set(self.done_chunks),
        "reducers": {n: r_.snapshot() for n, r_ in self.reducers.items()},
        "counters": dict(self.counters)})
    self._since_ckpt = 0

  def pull_task(self) -> Optional[ChunkTask]:
    """Next not-yet-folded task, or None when the sweep is exhausted."""
    if self.next_task is not None:
      task, self.next_task = self.next_task, None
      return task
    while not self.exhausted:
      task = next(self.task_iter, None)
      if task is None:
        self.exhausted = True
        return None
      if task.index not in self.done_chunks:
        return task
    return None


class _EvalRequest:
  """One blocking evaluate handoff from a search thread to the
  scheduler (the shared-executor proxy)."""

  __slots__ = ("table", "layers", "network", "event", "box")

  def __init__(self, table, layers, network):
    self.table = table
    self.layers = layers
    self.network = network
    self.event = threading.Event()
    self.box: Optional[Tuple[str, object]] = None


class _ProxyBackend:
  """The backend a service-hosted search sees: every ``evaluate_table``
  becomes a blocking handoff through the service's shared executor, so
  search evaluations interleave with sweep chunks under the same
  retry/fault/breaker policy and the same fairness pass."""

  name = "service-proxy"
  jit = False
  prefers_table = True

  def __init__(self, session: "_SearchSession"):
    self._session = session

  def evaluate_table(self, table, layers, network="net"):
    return self._session.call_through(table, layers, network)


class _SearchSession(_Session):
  """A guided search running on its own thread, its evaluations proxied
  through the scheduler; deadline/cancel/budget surface as typed errors
  raised *inside* the search (cooperative cancellation)."""

  def __init__(self, sid: int, policy: ResiliencePolicy,
               deadline: Optional[Deadline], chunk_budget: Optional[int],
               journal: Optional[SweepJournal], run_search):
    super().__init__(sid, "search", policy, deadline, chunk_budget,
                     journal, "")
    self._run_search = run_search  # (proxy backend) -> StreamResult
    self.requests: deque = deque()
    self.thread: Optional[threading.Thread] = None
    self.thread_done = threading.Event()
    self.thread_result: Optional[Tuple[str, object]] = None
    self.flag: Optional[Tuple[str, BaseException]] = None

  def start_thread(self) -> None:
    proxy = _ProxyBackend(self)

    def target():
      try:
        self.thread_result = ("ok", self._run_search(proxy))
      except BaseException as e:
        self.thread_result = ("err", e)
      finally:
        self.thread_done.set()

    self.thread = threading.Thread(
        target=target, daemon=True, name=f"search-session-{self.sid}")
    self.thread.start()

  def call_through(self, table, layers, network):
    """Search-thread side of the handoff: enqueue and poll (bounded
    waits), surfacing cancellation/deadline as typed errors so the
    search unwinds cooperatively with its generations journaled."""
    req = _EvalRequest(table, layers, network)
    self.requests.append(req)
    while not req.event.wait(_POLL_SECONDS):
      if self.flag is not None:
        raise self.flag[1]
    tag, val = req.box
    if tag == "err":
      raise val
    return val


class ExplorationService:
  """Concurrent exploration sessions over one shared executor.

  ``slots`` bounds how many sessions interleave at once (the
  ``ServeEngine`` fixed-slot shape); ``max_queued`` bounds the
  submission queue behind them — a submit beyond that raises
  :class:`AdmissionRejected`.  ``drain()`` runs the scheduler on the
  calling thread until all work finishes (deterministic — what the
  chaos tests drive); ``start()``/``stop()`` run it on a background
  thread instead.  See the module docstring for the failure model.
  """

  def __init__(self, backend, *, slots: int = 2, max_queued: int = 8,
               store: Optional[Union[ResultStore, str]] = None,
               retry: Optional[RetryPolicy] = None,
               fault_plan: Optional[FaultPlan] = None,
               breaker: Optional[CircuitBreaker] = None,
               resolve_timeout: Optional[float] = None,
               dispatch_ahead: int = DISPATCH_AHEAD,
               checkpoint_every: int = 1, pool=None):
    if slots < 1:
      raise ValueError(f"slots must be >= 1, got {slots}")
    if max_queued < 0:
      raise ValueError(f"max_queued must be >= 0, got {max_queued}")
    self.backend = backend
    # one DevicePool shared by every session: quarantine decisions
    # reflect the device, not any single session's luck
    self.pool = pool
    self.store = (ResultStore(store)
                  if store is not None and not isinstance(store, ResultStore)
                  else store)
    self.retry = retry
    self.fault_plan = fault_plan
    self.breaker = breaker
    self.resolve_timeout = resolve_timeout
    self.dispatch_ahead = dispatch_ahead
    self.checkpoint_every = checkpoint_every
    self.slots: List[Optional[_Session]] = [None] * int(slots)
    self.queue: deque = deque()
    self.max_queued = int(max_queued)
    self.stats = {"n_admitted": 0, "n_rejected": 0, "n_completed": 0,
                  "n_failed": 0, "n_store_hits": 0, "n_delta_sweeps": 0}
    self._uid = 0
    self._lock = threading.RLock()
    self._thread: Optional[threading.Thread] = None
    self._stop = threading.Event()

  # -- policy / deadline plumbing -------------------------------------------

  def _as_deadline(self, deadline) -> Optional[Deadline]:
    if deadline is None or isinstance(deadline, Deadline):
      return deadline
    return Deadline(float(deadline))

  def _session_policy(self, deadline: Optional[Deadline]
                      ) -> ResiliencePolicy:
    base = self.resolve_timeout
    if deadline is None:
      resolve = base
    else:
      def resolve() -> float:
        rem = max(deadline.remaining(), 0.0)
        return rem if base is None else min(base, rem)
    return ResiliencePolicy(retry=self.retry, fault_plan=self.fault_plan,
                            resolve_timeout=resolve, breaker=self.breaker)

  # -- admission ------------------------------------------------------------

  def _admit_or_reject(self) -> None:
    self._admit()  # free slots absorb the queue before capacity is judged
    if len(self.queue) >= self.max_queued:
      self.stats["n_rejected"] += 1
      raise AdmissionRejected(len(self.queue), self.max_queued)

  def _next_sid(self) -> int:
    self._uid += 1
    return self._uid

  def _enqueue(self, session: _Session) -> SessionHandle:
    self.queue.append(session)
    self.stats["n_admitted"] += 1
    return SessionHandle(session)

  def _store_hit_session(self, kind: str, reducers: Dict[str, Reducer],
                         state: Dict[str, object]) -> SessionHandle:
    """A finished sweep served straight from the store: the session is
    born terminal, no executor time at all."""
    from repro.explore.store import _cached_result
    t0 = time.perf_counter()
    for name, r in reducers.items():
      r.restore(state["reducers"][name])
    res = _cached_result(reducers, state, time.perf_counter() - t0)
    s = _Session(self._next_sid(), kind, ResiliencePolicy(retry=self.retry),
                 None, None, None, "")
    res.meta["session"] = float(s.sid)
    s.finalize("done", result=res)
    self.stats["n_admitted"] += 1
    self.stats["n_store_hits"] += 1
    self.stats["n_completed"] += 1
    return SessionHandle(s)

  # -- submission: plain sweep ----------------------------------------------

  def submit_explore(self, space: DesignSpace, layers, network: str = "net",
                     *, n_per_type: int = 200, seed: int = 17,
                     method: str = "random",
                     reducers: Optional[Dict[str, Reducer]] = None,
                     chunk_size: int = 65536, deadline=None,
                     chunk_budget: Optional[int] = None) -> SessionHandle:
    """Submit a plain streamed sweep.  With a store attached: an
    identical finished sweep returns as a store hit, a one-axis-edited
    full-grid sweep runs as a delta-sweep, and progress journals under
    the store for kill-resume."""
    with self._lock:
      deadline = self._as_deadline(deadline)
      if reducers is None:
        reducers = default_explore_reducers()
      rfp = reducers_fingerprint(reducers)
      result_key = ""
      manifest = None
      full_grid = (method == "grid"
                   and int(n_per_type) >= space.per_type_grid_size())
      if self.store is not None:
        result_key = explore_result_key(space, reducers, network=network,
                                        n_per_type=n_per_type, seed=seed,
                                        method=method)
        state = self.store.get(result_key)
        if state is not None:
          return self._store_hit_session("explore", reducers, state)
        manifest = _explore_manifest(space, network, method, rfp, full_grid)
      self._admit_or_reject()

      journal = self.store.journal if self.store is not None else None
      meta_extra: Dict[str, float] = {}
      tasks = None
      journal_key = ""
      if self.store is not None and full_grid:
        base = find_delta_base(self.store, space, network=network,
                               reducers_fp=rfp)
        if base is not None:
          base_key, axis, added = base
          base_state = _restore_delta_base(self.store, base_key, reducers,
                                           space)
          if base_state is not None:
            sub = space.with_axes(**{axis: added})
            journal_key = sweep_key("explore-delta",
                                    space_fingerprint(space), rfp,
                                    {"base": base_key, "network": network})
            tasks = explore_tasks(
                self.backend, sub, layers, network,
                sub.per_type_grid_size(), 0, "grid", chunk_size, reducers,
                row_ids=lambda chunk, offset: space.grid_rank(chunk))
            meta_extra = {"delta_sweep": 1.0,
                          "n_base_rows":
                              float(base_state.get("n_rows", 0))}
            self.stats["n_delta_sweeps"] += 1
      if tasks is None:
        journal_key = explore_sweep_key(
            space, reducers, n_per_type=n_per_type, seed=seed,
            method=method, chunk_size=chunk_size, network=network)
        tasks = explore_tasks(self.backend, space, layers, network,
                              n_per_type, seed, method, chunk_size,
                              reducers)
      s = _SweepSession(self._next_sid(), "explore",
                        self._session_policy(deadline), deadline,
                        chunk_budget, journal, journal_key, reducers, tasks,
                        self.dispatch_ahead, self.checkpoint_every,
                        result_key=result_key, manifest=manifest)
      s.meta_extra = meta_extra
      if journal is not None:
        ckpt = journal.load_state(journal_key)
        if ckpt is not None:
          s.adopt_checkpoint(ckpt)
      return self._enqueue(s)

  # -- submission: co-exploration -------------------------------------------

  def submit_co_explore(self, space: DesignSpace, arch_accs, *,
                        n_hw_per_type: int = 20, seed: int = 3,
                        image_size: int = 32, method: str = "random",
                        reducers: Optional[Dict[str, Reducer]] = None,
                        chunk_size: int = 65536, deadline=None,
                        chunk_budget: Optional[int] = None) -> SessionHandle:
    """Submit a streamed joint co-exploration (store hit + journaled
    resume with a store attached; no delta path — the joint identity
    includes the architecture set)."""
    with self._lock:
      deadline = self._as_deadline(deadline)
      if reducers is None:
        reducers = default_co_reducers()
      result_key = ""
      if self.store is not None:
        result_key = co_explore_result_key(
            space, reducers, arch_accs, n_hw_per_type=n_hw_per_type,
            seed=seed, image_size=image_size, method=method)
        state = self.store.get(result_key)
        if state is not None:
          return self._store_hit_session("co-explore", reducers, state)
      self._admit_or_reject()
      journal = self.store.journal if self.store is not None else None
      journal_key = co_explore_sweep_key(
          space, reducers, arch_accs, n_hw_per_type=n_hw_per_type,
          seed=seed, image_size=image_size, method=method,
          chunk_size=chunk_size)
      tasks = co_explore_tasks(self.backend, space, arch_accs,
                               n_hw_per_type, seed, image_size, method,
                               chunk_size, reducers)
      s = _SweepSession(self._next_sid(), "co-explore",
                        self._session_policy(deadline), deadline,
                        chunk_budget, journal, journal_key, reducers, tasks,
                        self.dispatch_ahead, self.checkpoint_every,
                        result_key=result_key)
      if journal is not None:
        ckpt = journal.load_state(journal_key)
        if ckpt is not None:
          s.adopt_checkpoint(ckpt)
      return self._enqueue(s)

  # -- submission: guided search --------------------------------------------

  def submit_search(self, space: DesignSpace, layers=None, *,
                    arch_accs=None, network: str = "search",
                    objectives=None, maximize=None, population: int = 32,
                    generations: int = 12, seed: int = 17,
                    image_size: int = 32, surrogate: bool = False,
                    reducers: Optional[Dict[str, Reducer]] = None,
                    deadline=None,
                    chunk_budget: Optional[int] = None) -> SessionHandle:
    """Submit a guided search (HW-only via ``layers=`` or joint via
    ``arch_accs=``).  The search runs on its own thread but every
    generation's evaluation is handed through the service's shared
    executor — one more session in the fairness pass, under the same
    retry/fault/breaker policy.  Its generations journal under the
    store (guided_search's own checkpointing), so kills resume."""
    with self._lock:
      deadline = self._as_deadline(deadline)
      self._admit_or_reject()
      resume_from = self.store.journal if self.store is not None else None
      ckpt_every = self.checkpoint_every

      def run_search(proxy) -> StreamResult:
        from repro.explore.session import ExplorationSession
        sess = ExplorationSession(proxy, space)
        return sess.optimize(
            layers=layers, network=network, arch_accs=arch_accs,
            objectives=objectives, maximize=maximize,
            population=population, generations=generations, seed=seed,
            image_size=image_size, surrogate=surrogate, reducers=reducers,
            resume_from=resume_from, checkpoint_every=ckpt_every)

      s = _SearchSession(self._next_sid(), self._session_policy(deadline),
                         deadline, chunk_budget,
                         resume_from, run_search)
      return self._enqueue(s)

  # -- the scheduler --------------------------------------------------------

  def _admit(self) -> None:
    for i, s in enumerate(self.slots):
      if s is None and self.queue:
        nxt = self.queue.popleft()
        nxt.state = "running"
        nxt.t0 = time.perf_counter()
        self.slots[i] = nxt
        if isinstance(nxt, _SearchSession):
          nxt.start_thread()

  def _kill_everything(self, exc: SweepKilled) -> None:
    """A SweepKilled is a process death: journal every active session's
    progress, fail every session (queued included) so no handle hangs,
    and unblock any search threads."""
    for s in list(self.slots) + list(self.queue):
      if s is None:
        continue
      if isinstance(s, _SweepSession):
        try:
          s.checkpoint(force=True)
        except Exception:
          # best-effort on the way down, but never silent
          self.stats["n_checkpoint_errors"] = \
              self.stats.get("n_checkpoint_errors", 0) + 1
      if isinstance(s, _SearchSession):
        s.flag = ("failed", exc)
      if s.state in ("queued", "running"):
        s.finalize("failed", error=exc)
    self.slots = [None] * len(self.slots)
    self.queue.clear()

  def _tick(self) -> bool:
    """One fair pass: every active session gets one unit of work.
    Returns True while any session is active or queued."""
    self._admit()
    progressed = False
    for i, s in enumerate(self.slots):
      if s is None:
        continue
      try:
        progressed = self._step(s) or progressed
      except SweepKilled as e:
        self._kill_everything(e)
        raise
      if s.state != "running":
        self.slots[i] = None
        if s.state == "done":
          self.stats["n_completed"] += 1
        else:
          self.stats["n_failed"] += 1
    return any(s is not None for s in self.slots) or bool(self.queue)

  def _step(self, s: _Session) -> bool:
    if isinstance(s, _SearchSession):
      return self._step_search(s)
    return self._step_sweep(s)

  # -- sweep stepping -------------------------------------------------------

  def _abandon_window(self, s: _SweepSession) -> None:
    # in-flight device work is simply dropped — like a watchdogged
    # resolution, the abandoned dispatches drain harmlessly; checked-out
    # pool devices must still be released
    if self.pool is not None:
      for _, _, dev, _ in s.window:
        if dev is not None:
          self.pool.checkin(dev)
    s.window.clear()

  def _step_sweep(self, s: _SweepSession) -> bool:
    if s.cancel_requested:
      s.checkpoint(force=True)
      self._abandon_window(s)
      s.finalize("cancelled", error=SessionCancelled(s.sid))
      return True
    if s.deadline is not None and s.deadline.expired():
      s.checkpoint(force=True)
      self._abandon_window(s)
      s.finalize("expired", error=DeadlineExceeded(s.sid, s.deadline))
      return True
    # resolve first when the window is full
    if len(s.window) > s.dispatch_ahead:
      return self._finish_oldest(s)
    task = s.pull_task()
    if task is None:
      if s.window:
        return self._finish_oldest(s)
      self._complete_sweep(s)
      return True
    if s.chunk_budget is not None and s.n_dispatched >= s.chunk_budget:
      s.next_task = task  # not consumed: a resume re-pulls it
      s.checkpoint(force=True)
      self._abandon_window(s)
      s.finalize("failed", error=BudgetExhausted(s.sid, s.chunk_budget))
      return True
    dev = None
    if self.pool is not None and \
        any(r.layer == "device" for r in getattr(task, "rungs", ())):
      dev = self.pool.checkout()
    t_dispatch = time.perf_counter()
    try:
      if dev is not None:
        from repro.explore import fleet
        with fleet.pin(self.pool.device(dev)):
          out = s.policy.execute(task)
      else:
        out = s.policy.execute(task)
    except SweepKilled:
      if dev is not None:
        self.pool.checkin(dev)
      s.checkpoint(force=True)
      raise
    except Exception as e:
      if dev is not None:
        self.pool.checkin(dev)
        self.pool.record_failure(dev)
      self._fail_sweep(s, task.index, e)
      return True
    s.n_dispatched += 1
    if hasattr(out, "resolve"):
      s.window.append((task.index, out, dev, t_dispatch))
    else:
      if dev is not None:
        self._release(dev, t_dispatch, ok=True)
      self._fold(s, task.index, out)
    return True

  def _release(self, dev: int, t_dispatch: float, ok: bool) -> None:
    """Return a checked-out pool device, feeding the health registry."""
    self.pool.checkin(dev)
    if ok:
      self.pool.record_latency(dev, time.perf_counter() - t_dispatch)
      self.pool.record_success(dev)
    else:
      self.pool.record_failure(dev)

  def _finish_oldest(self, s: _SweepSession) -> bool:
    index, pending, dev, t_dispatch = s.window.popleft()
    try:
      self._fold(s, index, pending)
    except SweepKilled:
      if dev is not None:
        self.pool.checkin(dev)
      s.checkpoint(force=True)
      raise
    if dev is not None:
      self._release(dev, t_dispatch, ok=s.state != "failed")
    return True

  def _fold(self, s: _SweepSession, index: int, result) -> None:
    try:
      fold_chunk(s.reducers, s.counters, result)
    except SweepKilled:
      raise
    except Exception as e:
      self._fail_sweep(s, index, e)
      return
    s.done_chunks.add(index)
    s.checkpoint()

  def _fail_sweep(self, s: _SweepSession, index: int,
                  exc: Exception) -> None:
    s.checkpoint(force=True)
    self._abandon_window(s)
    err = exc if isinstance(exc, ChunkError) \
        else ChunkError(index, f"{type(exc).__name__}: {exc}")
    err.__cause__ = exc
    s.finalize("failed", error=err)

  def _complete_sweep(self, s: _SweepSession) -> None:
    s.checkpoint(force=True)
    seconds = time.perf_counter() - (s.t0 or time.perf_counter())
    n_retries, n_demotions = s.totals()
    meta = {"seconds": seconds, "workers": 1.0,
            "n_chunks": float(s.counters["n_chunks"]),
            "rows_transferred": float(s.counters["n_transferred"]),
            "rows_per_sec": s.counters["n_rows"] / max(seconds, 1e-12),
            "n_retries": float(n_retries),
            "n_demotions": float(n_demotions),
            "n_resumed_chunks": float(s.n_resumed),
            "n_overflows": float(s.counters["n_overflows"]),
            "session": float(s.sid),
            "service_slots": float(len(self.slots))}
    meta["n_leaked_watchdogs"] = float(s.policy.watchdogs.n_live())
    meta.update(s.meta_extra)
    if self.breaker is not None:
      meta.update(self.breaker.meta())
    if self.pool is not None:
      meta.update(self.pool.meta())
    res = StreamResult(
        results={n: r.result() for n, r in s.reducers.items()},
        n_rows=s.counters["n_rows"], seconds=seconds, meta=meta)
    if "n_base_rows" in s.meta_extra:
      res.meta["n_delta_rows"] = float(res.n_rows)
      res.n_rows += int(s.meta_extra["n_base_rows"])
    if self.store is not None and s.result_key:
      self.store.put_final(s.result_key,
                           _snapshot_state(s.reducers, res), s.manifest)
    s.finalize("done", result=res)

  # -- search stepping ------------------------------------------------------

  def _step_search(self, s: _SearchSession) -> bool:
    if s.flag is None and s.cancel_requested:
      s.flag = ("cancelled", SessionCancelled(s.sid))
    if s.flag is None and s.deadline is not None and s.deadline.expired():
      s.flag = ("expired", DeadlineExceeded(s.sid, s.deadline))
    if s.requests:
      req = s.requests.popleft()
      if s.flag is not None:
        req.box = ("err", s.flag[1])
        req.event.set()
        return True
      if s.chunk_budget is not None and s.n_dispatched >= s.chunk_budget:
        s.flag = ("failed", BudgetExhausted(s.sid, s.chunk_budget))
        req.box = ("err", s.flag[1])
        req.event.set()
        return True
      task = ChunkTask(index=s.n_dispatched, rungs=(Rung(
          "numpy",
          lambda: self.backend.evaluate_table(req.table, req.layers,
                                              req.network),
          layer="backend"),))
      s.n_dispatched += 1
      try:
        out = s.policy.execute(task)
      except SweepKilled as e:
        req.box = ("err", e)
        req.event.set()
        raise
      except Exception as e:
        req.box = ("err", e)
      else:
        req.box = ("ok", out)
      req.event.set()
      return True
    if s.thread_done.is_set():
      s.thread.join(_JOIN_SECONDS)  # bounded: the thread already signalled
      tag, val = s.thread_result
      if tag == "ok":
        res: StreamResult = val
        res.meta["session"] = float(s.sid)
        res.meta["n_retries"] = res.meta.get("n_retries", 0.0) \
            + float(s.policy.n_retries)
        res.meta["n_demotions"] = res.meta.get("n_demotions", 0.0) \
            + float(s.policy.n_demotions)
        if self.breaker is not None:
          res.meta.update(self.breaker.meta())
        s.finalize("done", result=res)
      else:
        state, err = s.flag if s.flag is not None else ("failed", val)
        # the search surfaces proxy errors wrapped in ChunkError — the
        # typed service error is the one the client should see
        s.finalize(state, error=err if s.flag is not None else val)
      return True
    return False  # thread busy between evaluations: nothing to do

  # -- driving --------------------------------------------------------------

  def drain(self) -> int:
    """Run the scheduler on the calling thread until every session has
    reached a terminal state; returns how many sessions completed
    successfully during the drain.  Deterministic for a fixed submission
    order (the chaos-test mode).  :class:`SweepKilled` propagates after
    all progress is journaled — the process-death simulation."""
    with self._lock:
      before = self.stats["n_completed"]
      while True:
        busy = self._tick()
        if not busy:
          break
        # a pass with live sessions but no progress means every active
        # session is a search thread computing between evaluations —
        # yield briefly instead of spinning
        if not any(isinstance(s, _SweepSession) for s in self.slots
                   if s is not None):
          time.sleep(0.001)
      return self.stats["n_completed"] - before

  def start(self) -> None:
    """Run the scheduler on a background daemon thread."""
    with self._lock:
      if self._thread is not None and self._thread.is_alive():
        return
      self._stop.clear()

      def loop():
        while not self._stop.is_set():
          with self._lock:
            try:
              busy = self._tick()
            except SweepKilled:
              return  # everything already failed + journaled
          if not busy:
            self._stop.wait(_POLL_SECONDS)

      self._thread = threading.Thread(target=loop, daemon=True,
                                      name="exploration-service")
      self._thread.start()

  def stop(self, timeout: float = _JOIN_SECONDS) -> None:
    """Stop the background scheduler (bounded join — ROB002)."""
    self._stop.set()
    t = self._thread
    if t is not None:
      t.join(timeout)

  def service_meta(self) -> Dict[str, object]:
    """Service-level observability: admission/completion counters plus
    breaker and store state."""
    meta: Dict[str, object] = dict(self.stats)
    meta["n_queued"] = len(self.queue)
    meta["n_active"] = sum(1 for s in self.slots if s is not None)
    meta["slots"] = len(self.slots)
    if self.pool is not None:
      meta.update(self.pool.meta())
    if self.breaker is not None:
      meta.update(self.breaker.meta())
    if self.store is not None:
      meta.update({f"store_{k}": v for k, v in self.store.stats().items()})
    return meta
