"""Fault tolerance for production-scale exploration: retry, degradation,
checkpoint/resume, and deterministic fault injection.

QUIDAM's pre-characterized PPA models only pay off if a sweep actually
*finishes* — a 10M-pair streamed co-exploration or a long guided-search
run must survive the transient failures any long-lived service sees:
flaky jit compiles, device OOMs, hung dispatches, worker exceptions,
whole-process kills.  Everything here leans on one structural fact: a
chunk is a pure function of ``(space, chunk_index, seed)``, so
re-evaluating it — on any rung of the ladder, in any later process — is
bit-identical.  That turns fault tolerance into bookkeeping:

  retry        :class:`RetryPolicy` — seeded, bounded exponential
               backoff around each rung dispatch, built on the single
               retry primitive :func:`repro.train.fault_tolerance.
               retrying` (injectable ``sleep`` — tests never wall-wait)
  degradation  :class:`ResiliencePolicy` — per-chunk fallback ladder
               ``fused-device -> unfused-device -> numpy`` (each rung a
               :class:`Rung` inside a :class:`ChunkTask`); exhausted
               retries or a watchdogged/hung resolution demote to the
               next rung, and the numpy rung has no device failure
               modes left.  Every demotion is counted and surfaced in
               ``StreamResult.meta``.
  resume       reducer ``snapshot()/restore()`` state serialized by a
               :class:`SweepJournal` — a content-addressed checkpoint
               store keyed by (design-space hash, oracle version,
               reducer plan, sweep params).  ``run_stream`` /
               ``stream_explore`` / ``stream_co_explore`` /
               ``guided_search`` accept ``resume_from=`` and skip
               chunks already folded; chunk-order invariance of the
               reducers makes the resumed final fronts bit-identical to
               an uninterrupted run.
  injection    :class:`FaultPlan` — seeded schedules of raise / hang /
               kill-at-chunk-k faults installable at the task, device,
               and backend layers; the tests and the resilience
               benchmark drive every path above through it
               deterministically.

The journal is deliberately backend-agnostic: the exact-codegen parity
contract (``parity_max_rel_err == 0.0``) means a sweep checkpointed from
the device path can resume on the numpy path and vice versa.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import struct
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import oracle
from repro.core.seeding import derive_seed
from repro.train.fault_tolerance import StepFailure, retrying


# ---------------------------------------------------------------------------
# failure taxonomy
# ---------------------------------------------------------------------------

class FaultInjected(RuntimeError):
  """A :class:`FaultPlan`-injected transient fault.  Subclasses
  RuntimeError so the default retry policy treats it exactly like a real
  transient device error."""


class SweepKilled(Exception):
  """A :class:`FaultPlan`-injected process death.  Deliberately NOT a
  RuntimeError: no retry policy or ladder rung may absorb it — it must
  abort the run the way a real kill would, leaving only the journal."""


class ChunkTimeout(RuntimeError):
  """A pending chunk resolution exceeded the watchdog timeout."""


class InjectedHang(ChunkTimeout):
  """Deterministic stand-in for a hung resolution: raised at the
  resolve point *instead of* blocking, so tests exercise the demotion
  path without consuming the watchdog's wall-clock budget."""


class ChunkError(RuntimeError):
  """A chunk failed fatally.  Carries the chunk's global index so a
  caller (or operator) knows exactly where the sweep stopped."""

  def __init__(self, chunk_index: int, message: str = ""):
    self.chunk_index = int(chunk_index)
    detail = f": {message}" if message else ""
    super().__init__(f"chunk {self.chunk_index} failed{detail}")


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------

FAULT_KINDS = ("raise", "hang", "kill", "slow", "corrupt", "device-lost")
FAULT_LAYERS = ("task", "device", "backend", "fleet")

# fleet-layer faults fire at shard granularity inside explore.fleet's
# dispatch loop (not in the per-chunk ladder): a slow shard triggers
# speculation, a corrupt shard exercises the SDC sentinel, a lost device
# exercises elastic resharding
FLEET_FAULT_KINDS = ("slow", "corrupt", "device-lost")

# wildcard chunk for fleet faults: fires at ANY chunk dispatched on the
# targeted device (until ``times`` is spent) — how a persistently sick
# device is modeled
ANY_CHUNK = -1


@dataclasses.dataclass(frozen=True)
class Fault:
  """One scheduled fault: ``kind`` fires at chunk ``chunk`` when the
  ladder touches ``layer``, at most ``times`` times (a transient with
  ``times <= max_retries`` is healed by retry alone; a larger budget
  forces a demotion).  Fleet-layer faults additionally carry the
  targeted pool ``device`` index (None: any device) and may use the
  ``ANY_CHUNK`` wildcard."""
  kind: str
  chunk: int
  layer: str = "task"
  times: int = 1
  device: Optional[int] = None

  def __post_init__(self):
    if self.kind not in FAULT_KINDS:
      raise ValueError(f"unknown fault kind {self.kind!r}")
    if self.layer not in FAULT_LAYERS:
      raise ValueError(f"unknown fault layer {self.layer!r}")
    if self.times <= 0:
      raise ValueError(f"times must be positive, got {self.times}")
    if (self.kind in FLEET_FAULT_KINDS) != (self.layer == "fleet"):
      raise ValueError(f"fault kind {self.kind!r} and layer {self.layer!r} "
                       "mismatch: slow/corrupt/device-lost are fleet-layer "
                       "faults (and only those are)")
    if self.layer != "fleet":
      if self.device is not None:
        raise ValueError("device targeting is fleet-layer only")
      if self.chunk < 0:
        raise ValueError("the ANY_CHUNK wildcard is fleet-layer only")


class FaultPlan:
  """A deterministic schedule of injected faults.

  Installed on a :class:`ResiliencePolicy`; the policy consults the plan
  at each rung dispatch (``check``) and each pending resolution
  (``check_resolve``).  Thread-safe — the streaming engine dispatches
  chunks from a pool — and exactly reproducible: the same plan against
  the same sweep fires the same faults at the same chunks.
  """

  def __init__(self, faults: Iterable[Fault] = ()):
    self.faults: Tuple[Fault, ...] = tuple(faults)
    self._remaining = [f.times for f in self.faults]
    self.n_fired = 0
    self._lock = threading.Lock()

  @classmethod
  def seeded(cls, seed: int, n_chunks: int, p_raise: float = 0.25,
             p_hang: float = 0.0, p_kill: float = 0.0,
             layer: str = "device", times: int = 1) -> "FaultPlan":
    """Random-but-reproducible schedule: per chunk, independent draws
    decide whether a raise / hang / kill fault is planted (hangs always
    target the device layer — that is where resolutions block)."""
    rng = np.random.RandomState(derive_seed("fault-plan", seed))
    faults: List[Fault] = []
    for chunk in range(int(n_chunks)):
      u = rng.random_sample(3)
      if u[0] < p_raise:
        faults.append(Fault("raise", chunk, layer, times))
      if u[1] < p_hang:
        faults.append(Fault("hang", chunk, "device", times))
      if u[2] < p_kill:
        faults.append(Fault("kill", chunk, layer, times))
    return cls(faults)

  def _fire(self, layer: str, chunk: int,
            kinds: Tuple[str, ...]) -> Optional[str]:
    with self._lock:
      for i, f in enumerate(self.faults):
        if (f.chunk == chunk and f.layer == layer and f.kind in kinds
            and self._remaining[i] > 0):
          self._remaining[i] -= 1
          self.n_fired += 1
          return f.kind
    return None

  def check(self, layer: str, chunk: int) -> None:
    """Dispatch-point hook: raises the scheduled fault, if any."""
    kind = self._fire(layer, chunk, ("kill", "raise"))
    if kind == "kill":
      raise SweepKilled(f"injected kill at {layer} layer, chunk {chunk}")
    if kind == "raise":
      raise FaultInjected(f"injected fault at {layer} layer, chunk {chunk}")

  def check_resolve(self, layer: str, chunk: int) -> None:
    """Resolution-point hook: a scheduled hang raises
    :class:`InjectedHang` instead of blocking."""
    if self._fire(layer, chunk, ("hang",)):
      raise InjectedHang(f"injected hang at {layer} layer, chunk {chunk}")

  def check_fleet(self, device: int, chunk: int) -> Optional[str]:
    """Shard-dispatch hook for the fleet layer: returns the fired fault
    kind (``slow`` / ``corrupt`` / ``device-lost``) when a fleet fault
    targets this (device, chunk) pair — device None and the
    ``ANY_CHUNK`` wildcard match anything — else None.  The fleet
    executor acts on the kind; nothing is raised here."""
    with self._lock:
      for i, f in enumerate(self.faults):
        if f.layer != "fleet" or self._remaining[i] <= 0:
          continue
        if f.chunk not in (chunk, ANY_CHUNK):
          continue
        if f.device is not None and f.device != int(device):
          continue
        self._remaining[i] -= 1
        self.n_fired += 1
        return f.kind
    return None

  @classmethod
  def seeded_fleet(cls, seed: int, n_chunks: int, n_devices: int,
                   p_slow: float = 0.0, p_corrupt: float = 0.0,
                   p_lost: float = 0.0, times: int = 1) -> "FaultPlan":
    """Random-but-reproducible fleet chaos: at every chunk boundary,
    independent draws decide whether a seeded random device is slowed,
    corrupted, or lost at that chunk."""
    rng = np.random.RandomState(derive_seed("fleet-fault-plan", seed))
    faults: List[Fault] = []
    for chunk in range(int(n_chunks)):
      u = rng.random_sample(3)
      dev = int(rng.randint(max(1, int(n_devices))))
      if u[0] < p_slow:
        faults.append(Fault("slow", chunk, "fleet", times, device=dev))
      if u[1] < p_corrupt:
        faults.append(Fault("corrupt", chunk, "fleet", times, device=dev))
      if u[2] < p_lost:
        faults.append(Fault("device-lost", chunk, "fleet", times,
                            device=dev))
    return cls(faults)


# ---------------------------------------------------------------------------
# retry policy (thin, injectable wrapper over train.fault_tolerance)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RetryPolicy:
  """Bounded exponential-backoff retry for one rung dispatch.

  Delegates to :func:`repro.train.fault_tolerance.retrying` — the same
  primitive that guards trainer steps — so there is exactly one retry
  semantics in the stack.  ``sleep`` is injectable; tests pass a no-op
  and never wall-wait."""
  max_retries: int = 2
  base_delay: float = 0.01
  backoff: float = 2.0
  sleep: Callable[[float], None] = time.sleep
  retry_exceptions: Tuple = (RuntimeError,)

  def call(self, fn: Callable[[], object],
           on_retry: Optional[Callable[[int, Exception], None]] = None):
    """Run ``fn`` with retries; raises
    :class:`~repro.train.fault_tolerance.StepFailure` on exhaustion.
    ``on_retry(attempt, exc)`` fires only for failures that will
    actually be retried, so it counts re-executions exactly."""
    def note(attempt: int, exc: Exception) -> None:
      if on_retry is not None and attempt < self.max_retries:
        on_retry(attempt, exc)
    return retrying(fn, max_retries=self.max_retries, on_failure=note,
                    retry_exceptions=self.retry_exceptions,
                    sleep=self.sleep, base_delay=self.base_delay,
                    backoff=self.backoff)()


# ---------------------------------------------------------------------------
# the degradation ladder
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Rung:
  """One way to evaluate a chunk.  ``fn`` returns either the plain
  ``(frame, indices)`` pair or a pending handle with ``resolve()``;
  ``layer`` is the :class:`FaultPlan` layer this rung dispatches
  through."""
  name: str
  fn: Callable[[], object]
  layer: str = "backend"


@dataclasses.dataclass(frozen=True)
class ChunkTask:
  """A chunk plus its fallback ladder, best rung first.  Calling the
  task directly (no policy installed) runs the best rung only — the
  zero-overhead healthy path the engine used before resilience."""
  index: int
  rungs: Tuple[Rung, ...]

  def __call__(self):
    return self.rungs[0].fn()


BREAKER_STATES = ("closed", "open", "half-open")


class CircuitBreaker:
  """Device-rung circuit breaker for the degradation ladder.

  The per-chunk ladder already heals individual device failures by
  demotion, but when the device rung is *persistently* sick (a wedged
  runtime, a driver in a crash loop) every chunk still pays the full
  retry + watchdog budget before falling back.  The breaker converts
  that into a fleet-level decision: after ``threshold`` consecutive
  device-rung failures it **opens** and new chunks skip the device rungs
  entirely (straight to the terminal numpy rung — bit-identical by the
  parity contract).  After a seeded cooldown — ``cooldown`` chunks plus
  a deterministic jitter drawn from ``seed`` so concurrent services
  don't re-probe in lockstep — it goes **half-open** and lets exactly
  one probe chunk try the device rung; success closes the breaker,
  failure re-opens it.  Every transition is recorded (and surfaced in
  ``StreamResult.meta``) as ``(event_count, from_state, to_state)``.

  Thread-safe; one breaker is shared by all sessions multiplexed over a
  device executor so the open/closed decision reflects the device, not
  any single session's luck.
  """

  def __init__(self, threshold: int = 3, cooldown: int = 8,
               jitter: int = 2, seed: int = 0):
    if threshold < 1:
      raise ValueError(f"threshold must be >= 1, got {threshold}")
    if cooldown < 1:
      raise ValueError(f"cooldown must be >= 1, got {cooldown}")
    if jitter < 0:
      raise ValueError(f"jitter must be >= 0, got {jitter}")
    self.threshold = int(threshold)
    self.cooldown = int(cooldown)
    self.jitter = int(jitter)
    self._rng = np.random.RandomState(derive_seed("circuit-breaker", seed))
    self.state = "closed"
    self.n_opens = 0
    self.n_short_circuits = 0
    self.n_probes = 0
    self.transitions: List[Tuple[int, str, str]] = []
    self._failures = 0
    self._cooldown_left = 0
    self._probing = False
    self._events = 0
    self._lock = threading.Lock()

  def _to(self, state: str) -> None:
    self.transitions.append((self._events, self.state, state))
    self.state = state

  def _arm_cooldown(self) -> None:
    extra = int(self._rng.randint(0, self.jitter + 1)) if self.jitter else 0
    self._cooldown_left = self.cooldown + extra

  def allow_device(self) -> bool:
    """Consulted once per chunk ladder that has device rungs: may this
    chunk dispatch on the device?  While open, each refusal counts down
    the cooldown; when it reaches zero the breaker turns half-open and
    admits a single probe."""
    with self._lock:
      self._events += 1
      if self.state == "closed":
        return True
      if self.state == "open":
        self._cooldown_left -= 1
        if self._cooldown_left > 0:
          self.n_short_circuits += 1
          return False
        self._to("half-open")
        self._probing = False
      # half-open: one probe in flight at a time
      if self._probing:
        self.n_short_circuits += 1
        return False
      self._probing = True
      self.n_probes += 1
      return True

  def record_failure(self) -> None:
    """A device-rung dispatch or resolution failed (demotion/timeout)."""
    with self._lock:
      self._events += 1
      if self.state == "half-open":
        self._probing = False
        self._to("open")
        self.n_opens += 1
        self._arm_cooldown()
      elif self.state == "closed":
        self._failures += 1
        if self._failures >= self.threshold:
          self._to("open")
          self.n_opens += 1
          self._arm_cooldown()

  def trip(self) -> None:
    """Force the breaker open immediately — the fleet layer's verdicts
    (device lost, SDC divergence) are not "consecutive failures" to be
    counted but standing evidence; the device still rejoins through the
    ordinary half-open probe after the seeded cooldown."""
    with self._lock:
      self._events += 1
      self._failures = 0
      self._probing = False
      if self.state != "open":
        self._to("open")
        self.n_opens += 1
      self._arm_cooldown()

  def record_success(self) -> None:
    """A device-rung chunk completed (dispatch + resolution)."""
    with self._lock:
      self._events += 1
      if self.state == "half-open":
        self._probing = False
        self._failures = 0
        self._to("closed")
      elif self.state == "closed":
        self._failures = 0

  def meta(self) -> Dict[str, object]:
    """Snapshot for ``StreamResult.meta`` merging."""
    with self._lock:
      return {
          "breaker_state": self.state,
          "n_breaker_opens": float(self.n_opens),
          "n_breaker_short_circuits": float(self.n_short_circuits),
          "n_breaker_probes": float(self.n_probes),
          "breaker_transitions": list(self.transitions),
      }


class WatchdogRegistry:
  """Bookkeeping for the watchdog helper threads of
  :meth:`ResiliencePolicy._timed_resolve`.

  A watchdogged resolution that outlives its bounded join used to be
  abandoned: the daemon thread kept running with no reference anywhere —
  invisible to shutdown, impossible to count, a genuine leak under a
  long-lived service that demotes often.  The registry keeps every live
  watchdog referenced, reaps the ones that have since finished, and
  reports the still-running remainder as ``n_leaked_watchdogs`` in
  ``StreamResult.meta`` (0 on every healthy run — asserted in tests).
  Thread-safe."""

  def __init__(self):
    self._threads: List[threading.Thread] = []
    self._lock = threading.Lock()
    self.n_spawned = 0
    self.n_reaped = 0

  def _reap_locked(self) -> None:
    live = [t for t in self._threads if t.is_alive()]
    self.n_reaped += len(self._threads) - len(live)
    self._threads = live

  def track(self, t: threading.Thread) -> None:
    with self._lock:
      self.n_spawned += 1
      self._threads.append(t)
      self._reap_locked()

  def n_live(self) -> int:
    """Reap finished watchdogs, then count the still-running ones."""
    with self._lock:
      self._reap_locked()
      return len(self._threads)

  def drain(self, timeout: float = 0.1) -> int:
    """Bounded-join every live watchdog (service shutdown); returns how
    many are still running afterwards."""
    with self._lock:
      threads = list(self._threads)
    for t in threads:
      t.join(timeout)
    return self.n_live()


class ResiliencePolicy:
  """Executes :class:`ChunkTask` ladders with retry, demotion, and an
  optional resolution watchdog.

  Per rung: dispatch under :class:`RetryPolicy`; if retries exhaust (or
  a pending resolution later fails/hangs), demote to the next rung —
  the terminal numpy rung has no device failure modes, so a sweep
  completes unless the host itself is gone.  Demotion preserves
  bit-identity: whichever rung computes a chunk, the exact-codegen
  parity contract makes the folded rows identical.  ``n_retries`` /
  ``n_demotions`` are totalled here and surfaced in
  ``StreamResult.meta``.  :class:`SweepKilled` is never absorbed.
  """

  def __init__(self, retry: Optional[RetryPolicy] = None,
               fault_plan: Optional[FaultPlan] = None,
               resolve_timeout: Union[None, float,
                                      Callable[[], Optional[float]]] = None,
               breaker: Optional[CircuitBreaker] = None):
    self.retry = RetryPolicy() if retry is None else retry
    self.fault_plan = fault_plan
    # either a fixed budget or a callable evaluated at each resolve —
    # the service layer passes ``lambda: min(base, deadline.remaining())``
    # so per-request deadlines reach the watchdog without new plumbing
    self.resolve_timeout = resolve_timeout
    self.breaker = breaker
    self.watchdogs = WatchdogRegistry()
    self.n_retries = 0
    self.n_demotions = 0
    self.demotions: List[Tuple[int, str, str]] = []  # (chunk, rung, why)
    self._lock = threading.Lock()

  # -- accounting -----------------------------------------------------------

  def _note_retry(self) -> None:
    with self._lock:
      self.n_retries += 1

  def _note_demotion(self, chunk: int, rung: str, why: str) -> None:
    with self._lock:
      self.n_demotions += 1
      self.demotions.append((chunk, rung, why))

  # -- execution ------------------------------------------------------------

  def execute(self, task):
    """Run a task through its ladder.  Plain callables (no ladder) pass
    straight through so legacy task iterables keep working."""
    if not isinstance(task, ChunkTask):
      return task()
    return self._run_ladder(task, 0)

  def execute_from(self, task, start: int):
    """Run a task's ladder from rung ``start`` onward.  The fleet layer
    uses this to route chunks straight to the terminal numpy rung when
    every device is quarantined (and for the SDC sentinel's reference
    evaluation) — the breaker is not consulted, matching demotion
    semantics."""
    if not isinstance(task, ChunkTask):
      return task()
    return self._run_ladder(task, max(0, min(int(start),
                                             len(task.rungs) - 1)))

  def _attempt(self, task: ChunkTask, rung: Rung) -> Callable[[], object]:
    def attempt():
      if self.fault_plan is not None:
        self.fault_plan.check("task", task.index)
        if rung.layer != "task":
          self.fault_plan.check(rung.layer, task.index)
      return rung.fn()
    return attempt

  def _run_ladder(self, task: ChunkTask, start: int):
    last: Optional[Exception] = None
    skip_device = False
    if (self.breaker is not None and start == 0
        and any(r.layer == "device" for r in task.rungs)):
      skip_device = not self.breaker.allow_device()
    for r in range(start, len(task.rungs)):
      rung = task.rungs[r]
      if skip_device and rung.layer == "device" and r + 1 < len(task.rungs):
        continue  # breaker open: route straight past the device rungs
      try:
        out = self.retry.call(self._attempt(task, rung),
                              on_retry=lambda a, e: self._note_retry())
      except StepFailure as e:
        if rung.layer == "device" and self.breaker is not None:
          self.breaker.record_failure()
        if r + 1 < len(task.rungs):
          self._note_demotion(task.index, rung.name, "dispatch")
          last = e
          continue
        raise
      if hasattr(out, "resolve") and r + 1 < len(task.rungs):
        return _GuardedPending(self, task, r, out)
      if rung.layer == "device" and self.breaker is not None:
        self.breaker.record_success()
      return out
    raise StepFailure(f"chunk {task.index}: every ladder rung "
                      "exhausted") from last  # pragma: no cover

  def _timed_resolve(self, handle):
    """Resolve a pending handle under the watchdog: the resolution runs
    on a daemon helper thread and a bounded join decides whether it hung
    (the abandoned thread keeps draining the device queue harmlessly —
    its result is discarded and the chunk recomputed on a lower rung)."""
    timeout = (self.resolve_timeout() if callable(self.resolve_timeout)
               else self.resolve_timeout)
    if timeout is None:
      return handle.resolve()
    if timeout <= 0.0:
      # deadline already spent: abandon without starting a helper thread
      raise ChunkTimeout("resolution budget exhausted before resolve")
    box: List[Tuple[str, object]] = []

    def run():
      try:
        box.append(("ok", handle.resolve()))
      except BaseException as e:  # relayed to the watchdog thread below
        box.append(("err", e))

    t = threading.Thread(target=run, daemon=True)
    t.start()
    t.join(timeout)
    if not box:
      # the helper is still running: keep it referenced (and countable)
      # instead of abandoning it — see WatchdogRegistry
      self.watchdogs.track(t)
      raise ChunkTimeout(
          f"resolution exceeded the {timeout}s watchdog")
    tag, val = box[0]
    if tag == "err":
      raise val
    return val


class _GuardedPending:
  """Wraps a device pending handle issued by a non-terminal rung: the
  resolution goes through the fault plan and the watchdog, and any
  transient failure demotes to the remaining rungs synchronously."""

  def __init__(self, policy: ResiliencePolicy, task: ChunkTask,
               rung_pos: int, handle):
    self._policy = policy
    self._task = task
    self._pos = rung_pos
    self._handle = handle

  def is_ready(self) -> bool:
    """Non-blocking readiness (fleet straggler polling): delegates to
    the wrapped handle; handles without readiness report False."""
    fn = getattr(self._handle, "is_ready", None)
    if fn is None:
      return False
    try:
      return bool(fn())
    except Exception:
      return False

  def resolve(self):
    policy, task = self._policy, self._task
    rung = task.rungs[self._pos]
    demotable = (ChunkTimeout, StepFailure) + policy.retry.retry_exceptions
    try:
      if policy.fault_plan is not None:
        policy.fault_plan.check_resolve(rung.layer, task.index)
      val = policy._timed_resolve(self._handle)
    except SweepKilled:
      raise
    except demotable:
      # hung or failed resolution: recompute on the remaining rungs —
      # the chunk is a pure function of its index, so whichever rung
      # finishes it, the folded rows are bit-identical
      if rung.layer == "device" and policy.breaker is not None:
        policy.breaker.record_failure()
      policy._note_demotion(task.index, rung.name, "resolve")
      out = policy._run_ladder(task, self._pos + 1)
      if hasattr(out, "resolve"):
        out = out.resolve()
      return out
    if rung.layer == "device" and policy.breaker is not None:
      policy.breaker.record_success()
    return val


# ---------------------------------------------------------------------------
# content-addressed checkpoint journal
# ---------------------------------------------------------------------------

JOURNAL_VERSION = 1


def _sha(parts: Iterable[str]) -> str:
  h = hashlib.sha256()
  for p in parts:
    h.update(p.encode("utf-8"))
    h.update(b"\x00")
  return h.hexdigest()


def space_fingerprint(space) -> str:
  """Content hash of a DesignSpace's sampling identity: PE types, axis
  names/values, and the constraint count.  (Constraint *bodies* are
  opaque callables; swapping one while keeping the count is on the
  caller, exactly like swapping the evaluate hook of a search.)"""
  parts = ["space", ",".join(space.pe_types)]
  for axis in space.axes:
    parts.append(axis.name + "=" + ",".join(repr(v) for v in axis.values))
  parts.append(f"n_constraints={len(space.constraints)}")
  return _sha(parts)


def reducers_fingerprint(reducers: Dict[str, object]) -> str:
  """Content hash of a reducer plan: names plus each reducer's own
  ``fingerprint()`` (class + the parameters that shape its state)."""
  return _sha(f"{name}={reducers[name].fingerprint()}"
              for name in sorted(reducers))


def arch_accs_fingerprint(archs: Sequence[object],
                          accs: Sequence[float]) -> str:
  """Content hash of a co-exploration's (architecture, accuracy) input."""
  parts = ["arch-accs"]
  parts.extend(repr(a) for a in archs)
  parts.extend(repr(float(x)) for x in accs)
  return _sha(parts)


def sweep_key(kind: str, space_fp: str, reducers_fp: str,
              params: Dict[str, object]) -> str:
  """The journal key: (design-space hash, oracle version, reducer plan,
  sweep parameters).  Backend identity is deliberately excluded — the
  parity contract makes checkpoints portable across the numpy and
  device paths."""
  parts = [f"journal-v{JOURNAL_VERSION}", kind, space_fp,
           f"oracle-v{oracle.ORACLE_VERSION}", reducers_fp]
  parts.extend(f"{k}={params[k]!r}" for k in sorted(params))
  return _sha(parts)


class SweepJournal:
  """Durable checkpoint store for resumable sweeps: one pickle file per
  journal key under ``dir_path``, written atomically (tmp +
  ``os.replace``) so a kill mid-write leaves the previous durable
  record intact.  ``load`` returns None — a fresh start, never an
  error — on missing, corrupt, or key/version-mismatched records.

  This journal is the foundation the ROADMAP's exploration-as-a-service
  sweep-cache builds on: the key is content-addressed, so a *finished*
  sweep's record doubles as a cache hit for an identical future sweep.
  """

  def __init__(self, dir_path):
    self.dir = str(dir_path)
    os.makedirs(self.dir, exist_ok=True)

  def path(self, key: str) -> str:
    return os.path.join(self.dir, f"sweep-{key[:32]}.pkl")

  def record(self, key: str, state: Dict[str, object]) -> None:
    payload = {"version": JOURNAL_VERSION, "key": key, "state": state}
    tmp = self.path(key) + ".tmp"
    with open(tmp, "wb") as f:
      pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
      f.flush()
      os.fsync(f.fileno())
    os.replace(tmp, self.path(key))

  def load(self, key: str) -> Optional[Dict[str, object]]:
    try:
      with open(self.path(key), "rb") as f:
        payload = pickle.load(f)
    except FileNotFoundError:
      return None
    except Exception:  # truncated/corrupt record -> fresh start
      return None
    if (payload.get("version") != JOURNAL_VERSION
        or payload.get("key") != key):
      return None
    return payload.get("state")

  # -- append-log records ---------------------------------------------------
  #
  # ``record``/``load`` replace the whole snapshot atomically — safe, but
  # one fsync'd rewrite of the entire reducer state per checkpoint.  The
  # exploration service checkpoints many interleaved sessions, so it uses
  # an append-only log instead: each entry is a complete snapshot framed
  # as ``magic | u64 length | sha256(payload) | payload``, appended and
  # fsync'd.  A kill mid-append leaves at most one partial trailing frame;
  # ``replay`` detects it (short frame, bad digest, or bad magic),
  # truncates the file back to the last valid record, and returns the
  # surviving entries — recovery, never an exception.

  _LOG_MAGIC = b"SWPJ"
  _LOG_HEADER = len(_LOG_MAGIC) + 8 + 32  # magic + length + sha256 digest

  def log_path(self, key: str) -> str:
    return os.path.join(self.dir, f"sweep-{key[:32]}.log")

  def append(self, key: str, state: Dict[str, object]) -> None:
    payload = pickle.dumps(
        {"version": JOURNAL_VERSION, "key": key, "state": state},
        protocol=pickle.HIGHEST_PROTOCOL)
    frame = (self._LOG_MAGIC + struct.pack("<Q", len(payload))
             + hashlib.sha256(payload).digest() + payload)
    with open(self.log_path(key), "ab") as f:
      f.write(frame)
      f.flush()
      os.fsync(f.fileno())

  def replay(self, key: str) -> List[Dict[str, object]]:
    """All valid states in append order, truncating trailing garbage."""
    try:
      with open(self.log_path(key), "rb") as f:
        data = f.read()
    except FileNotFoundError:
      return []
    states: List[Dict[str, object]] = []
    off = 0
    good_end = 0
    n_magic = len(self._LOG_MAGIC)
    while off < len(data):
      header = data[off:off + self._LOG_HEADER]
      if len(header) < self._LOG_HEADER or header[:n_magic] != self._LOG_MAGIC:
        break
      (length,) = struct.unpack("<Q", header[n_magic:n_magic + 8])
      digest = header[n_magic + 8:self._LOG_HEADER]
      payload = data[off + self._LOG_HEADER:off + self._LOG_HEADER + length]
      if (len(payload) < length
          or hashlib.sha256(payload).digest() != digest):
        break
      try:
        rec = pickle.loads(payload)
      except Exception:
        break
      if rec.get("version") != JOURNAL_VERSION or rec.get("key") != key:
        break
      states.append(rec["state"])
      off += self._LOG_HEADER + length
      good_end = off
    if good_end < len(data):
      with open(self.log_path(key), "r+b") as f:
        f.truncate(good_end)
    return states

  def rewrite(self, key: str, states: List[Dict[str, object]]) -> None:
    """Atomically replace ``key``'s append log with ``states`` (in
    order) — the compaction primitive: callers replay, drop superseded
    entries, and rewrite.  Atomic tmp + ``os.replace`` like ``record``,
    so a kill mid-compaction leaves the previous log intact."""
    tmp = self.log_path(key) + ".tmp"
    with open(tmp, "wb") as f:
      for state in states:
        payload = pickle.dumps(
            {"version": JOURNAL_VERSION, "key": key, "state": state},
            protocol=pickle.HIGHEST_PROTOCOL)
        f.write(self._LOG_MAGIC + struct.pack("<Q", len(payload))
                + hashlib.sha256(payload).digest() + payload)
      f.flush()
      os.fsync(f.fileno())
    os.replace(tmp, self.log_path(key))

  def load_last(self, key: str) -> Optional[Dict[str, object]]:
    """Latest valid append-log state for ``key`` (None if none)."""
    states = self.replay(key)
    return states[-1] if states else None

  def load_state(self, key: str) -> Optional[Dict[str, object]]:
    """Best available checkpoint across both storage styles: the atomic
    snapshot (``record``) and the append log (``append``).  When both
    exist — e.g. a sweep started under ``run_stream`` and continued in
    the service — the one with more folded chunks wins."""
    candidates = [s for s in (self.load(key), self.load_last(key))
                  if s is not None]
    if not candidates:
      return None
    return max(candidates, key=lambda s: len(s.get("done", ())))
