"""Fault tolerance for production-scale exploration: retry, degradation,
checkpoint/resume, and deterministic fault injection.

QUIDAM's pre-characterized PPA models only pay off if a sweep actually
*finishes* — a 10M-pair streamed co-exploration or a long guided-search
run must survive the transient failures any long-lived service sees:
flaky jit compiles, device OOMs, hung dispatches, worker exceptions,
whole-process kills.  Everything here leans on one structural fact: a
chunk is a pure function of ``(space, chunk_index, seed)``, so
re-evaluating it — on any rung of the ladder, in any later process — is
bit-identical.  That turns fault tolerance into bookkeeping:

  retry        :class:`RetryPolicy` — seeded, bounded exponential
               backoff around each rung dispatch, built on the single
               retry primitive :func:`repro.train.fault_tolerance.
               retrying` (injectable ``sleep`` — tests never wall-wait)
  degradation  :class:`ResiliencePolicy` — per-chunk fallback ladder
               ``fused-device -> unfused-device -> numpy`` (each rung a
               :class:`Rung` inside a :class:`ChunkTask`); exhausted
               retries or a watchdogged/hung resolution demote to the
               next rung, and the numpy rung has no device failure
               modes left.  Every demotion is counted and surfaced in
               ``StreamResult.meta``.
  resume       reducer ``snapshot()/restore()`` state serialized by a
               :class:`SweepJournal` — a content-addressed checkpoint
               store keyed by (design-space hash, oracle version,
               reducer plan, sweep params).  ``run_stream`` /
               ``stream_explore`` / ``stream_co_explore`` /
               ``guided_search`` accept ``resume_from=`` and skip
               chunks already folded; chunk-order invariance of the
               reducers makes the resumed final fronts bit-identical to
               an uninterrupted run.
  injection    :class:`FaultPlan` — seeded schedules of raise / hang /
               kill-at-chunk-k faults installable at the task, device,
               and backend layers; the tests and the resilience
               benchmark drive every path above through it
               deterministically.

The journal is deliberately backend-agnostic: the exact-codegen parity
contract (``parity_max_rel_err == 0.0``) means a sweep checkpointed from
the device path can resume on the numpy path and vice versa.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import oracle
from repro.core.seeding import derive_seed
from repro.train.fault_tolerance import StepFailure, retrying


# ---------------------------------------------------------------------------
# failure taxonomy
# ---------------------------------------------------------------------------

class FaultInjected(RuntimeError):
  """A :class:`FaultPlan`-injected transient fault.  Subclasses
  RuntimeError so the default retry policy treats it exactly like a real
  transient device error."""


class SweepKilled(Exception):
  """A :class:`FaultPlan`-injected process death.  Deliberately NOT a
  RuntimeError: no retry policy or ladder rung may absorb it — it must
  abort the run the way a real kill would, leaving only the journal."""


class ChunkTimeout(RuntimeError):
  """A pending chunk resolution exceeded the watchdog timeout."""


class InjectedHang(ChunkTimeout):
  """Deterministic stand-in for a hung resolution: raised at the
  resolve point *instead of* blocking, so tests exercise the demotion
  path without consuming the watchdog's wall-clock budget."""


class ChunkError(RuntimeError):
  """A chunk failed fatally.  Carries the chunk's global index so a
  caller (or operator) knows exactly where the sweep stopped."""

  def __init__(self, chunk_index: int, message: str = ""):
    self.chunk_index = int(chunk_index)
    detail = f": {message}" if message else ""
    super().__init__(f"chunk {self.chunk_index} failed{detail}")


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------

FAULT_KINDS = ("raise", "hang", "kill")
FAULT_LAYERS = ("task", "device", "backend")


@dataclasses.dataclass(frozen=True)
class Fault:
  """One scheduled fault: ``kind`` fires at chunk ``chunk`` when the
  ladder touches ``layer``, at most ``times`` times (a transient with
  ``times <= max_retries`` is healed by retry alone; a larger budget
  forces a demotion)."""
  kind: str
  chunk: int
  layer: str = "task"
  times: int = 1

  def __post_init__(self):
    if self.kind not in FAULT_KINDS:
      raise ValueError(f"unknown fault kind {self.kind!r}")
    if self.layer not in FAULT_LAYERS:
      raise ValueError(f"unknown fault layer {self.layer!r}")
    if self.times <= 0:
      raise ValueError(f"times must be positive, got {self.times}")


class FaultPlan:
  """A deterministic schedule of injected faults.

  Installed on a :class:`ResiliencePolicy`; the policy consults the plan
  at each rung dispatch (``check``) and each pending resolution
  (``check_resolve``).  Thread-safe — the streaming engine dispatches
  chunks from a pool — and exactly reproducible: the same plan against
  the same sweep fires the same faults at the same chunks.
  """

  def __init__(self, faults: Iterable[Fault] = ()):
    self.faults: Tuple[Fault, ...] = tuple(faults)
    self._remaining = [f.times for f in self.faults]
    self.n_fired = 0
    self._lock = threading.Lock()

  @classmethod
  def seeded(cls, seed: int, n_chunks: int, p_raise: float = 0.25,
             p_hang: float = 0.0, p_kill: float = 0.0,
             layer: str = "device", times: int = 1) -> "FaultPlan":
    """Random-but-reproducible schedule: per chunk, independent draws
    decide whether a raise / hang / kill fault is planted (hangs always
    target the device layer — that is where resolutions block)."""
    rng = np.random.RandomState(derive_seed("fault-plan", seed))
    faults: List[Fault] = []
    for chunk in range(int(n_chunks)):
      u = rng.random_sample(3)
      if u[0] < p_raise:
        faults.append(Fault("raise", chunk, layer, times))
      if u[1] < p_hang:
        faults.append(Fault("hang", chunk, "device", times))
      if u[2] < p_kill:
        faults.append(Fault("kill", chunk, layer, times))
    return cls(faults)

  def _fire(self, layer: str, chunk: int,
            kinds: Tuple[str, ...]) -> Optional[str]:
    with self._lock:
      for i, f in enumerate(self.faults):
        if (f.chunk == chunk and f.layer == layer and f.kind in kinds
            and self._remaining[i] > 0):
          self._remaining[i] -= 1
          self.n_fired += 1
          return f.kind
    return None

  def check(self, layer: str, chunk: int) -> None:
    """Dispatch-point hook: raises the scheduled fault, if any."""
    kind = self._fire(layer, chunk, ("kill", "raise"))
    if kind == "kill":
      raise SweepKilled(f"injected kill at {layer} layer, chunk {chunk}")
    if kind == "raise":
      raise FaultInjected(f"injected fault at {layer} layer, chunk {chunk}")

  def check_resolve(self, layer: str, chunk: int) -> None:
    """Resolution-point hook: a scheduled hang raises
    :class:`InjectedHang` instead of blocking."""
    if self._fire(layer, chunk, ("hang",)):
      raise InjectedHang(f"injected hang at {layer} layer, chunk {chunk}")


# ---------------------------------------------------------------------------
# retry policy (thin, injectable wrapper over train.fault_tolerance)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RetryPolicy:
  """Bounded exponential-backoff retry for one rung dispatch.

  Delegates to :func:`repro.train.fault_tolerance.retrying` — the same
  primitive that guards trainer steps — so there is exactly one retry
  semantics in the stack.  ``sleep`` is injectable; tests pass a no-op
  and never wall-wait."""
  max_retries: int = 2
  base_delay: float = 0.01
  backoff: float = 2.0
  sleep: Callable[[float], None] = time.sleep
  retry_exceptions: Tuple = (RuntimeError,)

  def call(self, fn: Callable[[], object],
           on_retry: Optional[Callable[[int, Exception], None]] = None):
    """Run ``fn`` with retries; raises
    :class:`~repro.train.fault_tolerance.StepFailure` on exhaustion.
    ``on_retry(attempt, exc)`` fires only for failures that will
    actually be retried, so it counts re-executions exactly."""
    def note(attempt: int, exc: Exception) -> None:
      if on_retry is not None and attempt < self.max_retries:
        on_retry(attempt, exc)
    return retrying(fn, max_retries=self.max_retries, on_failure=note,
                    retry_exceptions=self.retry_exceptions,
                    sleep=self.sleep, base_delay=self.base_delay,
                    backoff=self.backoff)()


# ---------------------------------------------------------------------------
# the degradation ladder
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Rung:
  """One way to evaluate a chunk.  ``fn`` returns either the plain
  ``(frame, indices)`` pair or a pending handle with ``resolve()``;
  ``layer`` is the :class:`FaultPlan` layer this rung dispatches
  through."""
  name: str
  fn: Callable[[], object]
  layer: str = "backend"


@dataclasses.dataclass(frozen=True)
class ChunkTask:
  """A chunk plus its fallback ladder, best rung first.  Calling the
  task directly (no policy installed) runs the best rung only — the
  zero-overhead healthy path the engine used before resilience."""
  index: int
  rungs: Tuple[Rung, ...]

  def __call__(self):
    return self.rungs[0].fn()


class ResiliencePolicy:
  """Executes :class:`ChunkTask` ladders with retry, demotion, and an
  optional resolution watchdog.

  Per rung: dispatch under :class:`RetryPolicy`; if retries exhaust (or
  a pending resolution later fails/hangs), demote to the next rung —
  the terminal numpy rung has no device failure modes, so a sweep
  completes unless the host itself is gone.  Demotion preserves
  bit-identity: whichever rung computes a chunk, the exact-codegen
  parity contract makes the folded rows identical.  ``n_retries`` /
  ``n_demotions`` are totalled here and surfaced in
  ``StreamResult.meta``.  :class:`SweepKilled` is never absorbed.
  """

  def __init__(self, retry: Optional[RetryPolicy] = None,
               fault_plan: Optional[FaultPlan] = None,
               resolve_timeout: Optional[float] = None):
    self.retry = RetryPolicy() if retry is None else retry
    self.fault_plan = fault_plan
    self.resolve_timeout = resolve_timeout
    self.n_retries = 0
    self.n_demotions = 0
    self.demotions: List[Tuple[int, str, str]] = []  # (chunk, rung, why)
    self._lock = threading.Lock()

  # -- accounting -----------------------------------------------------------

  def _note_retry(self) -> None:
    with self._lock:
      self.n_retries += 1

  def _note_demotion(self, chunk: int, rung: str, why: str) -> None:
    with self._lock:
      self.n_demotions += 1
      self.demotions.append((chunk, rung, why))

  # -- execution ------------------------------------------------------------

  def execute(self, task):
    """Run a task through its ladder.  Plain callables (no ladder) pass
    straight through so legacy task iterables keep working."""
    if not isinstance(task, ChunkTask):
      return task()
    return self._run_ladder(task, 0)

  def _attempt(self, task: ChunkTask, rung: Rung) -> Callable[[], object]:
    def attempt():
      if self.fault_plan is not None:
        self.fault_plan.check("task", task.index)
        if rung.layer != "task":
          self.fault_plan.check(rung.layer, task.index)
      return rung.fn()
    return attempt

  def _run_ladder(self, task: ChunkTask, start: int):
    last: Optional[Exception] = None
    for r in range(start, len(task.rungs)):
      rung = task.rungs[r]
      try:
        out = self.retry.call(self._attempt(task, rung),
                              on_retry=lambda a, e: self._note_retry())
      except StepFailure as e:
        if r + 1 < len(task.rungs):
          self._note_demotion(task.index, rung.name, "dispatch")
          last = e
          continue
        raise
      if hasattr(out, "resolve") and r + 1 < len(task.rungs):
        return _GuardedPending(self, task, r, out)
      return out
    raise StepFailure(f"chunk {task.index}: every ladder rung "
                      "exhausted") from last  # pragma: no cover

  def _timed_resolve(self, handle):
    """Resolve a pending handle under the watchdog: the resolution runs
    on a daemon helper thread and a bounded join decides whether it hung
    (the abandoned thread keeps draining the device queue harmlessly —
    its result is discarded and the chunk recomputed on a lower rung)."""
    if self.resolve_timeout is None:
      return handle.resolve()
    box: List[Tuple[str, object]] = []

    def run():
      try:
        box.append(("ok", handle.resolve()))
      except BaseException as e:  # relayed to the watchdog thread below
        box.append(("err", e))

    t = threading.Thread(target=run, daemon=True)
    t.start()
    t.join(self.resolve_timeout)
    if not box:
      raise ChunkTimeout(
          f"resolution exceeded the {self.resolve_timeout}s watchdog")
    tag, val = box[0]
    if tag == "err":
      raise val
    return val


class _GuardedPending:
  """Wraps a device pending handle issued by a non-terminal rung: the
  resolution goes through the fault plan and the watchdog, and any
  transient failure demotes to the remaining rungs synchronously."""

  def __init__(self, policy: ResiliencePolicy, task: ChunkTask,
               rung_pos: int, handle):
    self._policy = policy
    self._task = task
    self._pos = rung_pos
    self._handle = handle

  def resolve(self):
    policy, task = self._policy, self._task
    rung = task.rungs[self._pos]
    demotable = (ChunkTimeout, StepFailure) + policy.retry.retry_exceptions
    try:
      if policy.fault_plan is not None:
        policy.fault_plan.check_resolve(rung.layer, task.index)
      return policy._timed_resolve(self._handle)
    except SweepKilled:
      raise
    except demotable:
      # hung or failed resolution: recompute on the remaining rungs —
      # the chunk is a pure function of its index, so whichever rung
      # finishes it, the folded rows are bit-identical
      policy._note_demotion(task.index, rung.name, "resolve")
      out = policy._run_ladder(task, self._pos + 1)
      if hasattr(out, "resolve"):
        out = out.resolve()
      return out


# ---------------------------------------------------------------------------
# content-addressed checkpoint journal
# ---------------------------------------------------------------------------

JOURNAL_VERSION = 1


def _sha(parts: Iterable[str]) -> str:
  h = hashlib.sha256()
  for p in parts:
    h.update(p.encode("utf-8"))
    h.update(b"\x00")
  return h.hexdigest()


def space_fingerprint(space) -> str:
  """Content hash of a DesignSpace's sampling identity: PE types, axis
  names/values, and the constraint count.  (Constraint *bodies* are
  opaque callables; swapping one while keeping the count is on the
  caller, exactly like swapping the evaluate hook of a search.)"""
  parts = ["space", ",".join(space.pe_types)]
  for axis in space.axes:
    parts.append(axis.name + "=" + ",".join(repr(v) for v in axis.values))
  parts.append(f"n_constraints={len(space.constraints)}")
  return _sha(parts)


def reducers_fingerprint(reducers: Dict[str, object]) -> str:
  """Content hash of a reducer plan: names plus each reducer's own
  ``fingerprint()`` (class + the parameters that shape its state)."""
  return _sha(f"{name}={reducers[name].fingerprint()}"
              for name in sorted(reducers))


def arch_accs_fingerprint(archs: Sequence[object],
                          accs: Sequence[float]) -> str:
  """Content hash of a co-exploration's (architecture, accuracy) input."""
  parts = ["arch-accs"]
  parts.extend(repr(a) for a in archs)
  parts.extend(repr(float(x)) for x in accs)
  return _sha(parts)


def sweep_key(kind: str, space_fp: str, reducers_fp: str,
              params: Dict[str, object]) -> str:
  """The journal key: (design-space hash, oracle version, reducer plan,
  sweep parameters).  Backend identity is deliberately excluded — the
  parity contract makes checkpoints portable across the numpy and
  device paths."""
  parts = [f"journal-v{JOURNAL_VERSION}", kind, space_fp,
           f"oracle-v{oracle.ORACLE_VERSION}", reducers_fp]
  parts.extend(f"{k}={params[k]!r}" for k in sorted(params))
  return _sha(parts)


class SweepJournal:
  """Durable checkpoint store for resumable sweeps: one pickle file per
  journal key under ``dir_path``, written atomically (tmp +
  ``os.replace``) so a kill mid-write leaves the previous durable
  record intact.  ``load`` returns None — a fresh start, never an
  error — on missing, corrupt, or key/version-mismatched records.

  This journal is the foundation the ROADMAP's exploration-as-a-service
  sweep-cache builds on: the key is content-addressed, so a *finished*
  sweep's record doubles as a cache hit for an identical future sweep.
  """

  def __init__(self, dir_path):
    self.dir = str(dir_path)
    os.makedirs(self.dir, exist_ok=True)

  def path(self, key: str) -> str:
    return os.path.join(self.dir, f"sweep-{key[:32]}.pkl")

  def record(self, key: str, state: Dict[str, object]) -> None:
    payload = {"version": JOURNAL_VERSION, "key": key, "state": state}
    tmp = self.path(key) + ".tmp"
    with open(tmp, "wb") as f:
      pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
      f.flush()
      os.fsync(f.fileno())
    os.replace(tmp, self.path(key))

  def load(self, key: str) -> Optional[Dict[str, object]]:
    try:
      with open(self.path(key), "rb") as f:
        payload = pickle.load(f)
    except FileNotFoundError:
      return None
    except Exception:  # truncated/corrupt record -> fresh start
      return None
    if (payload.get("version") != JOURNAL_VERSION
        or payload.get("key") != key):
      return None
    return payload.get("state")
