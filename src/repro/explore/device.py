"""Fused device programs for the streaming sweep engine.

The host streaming path (repro.explore.streaming) evaluates a chunk,
copies full latency/power/area arrays device->host (or allocates them on
host), and reduces in numpy.  This module moves the whole
evaluate -> derive-columns -> reduce pipeline into one jitted x64 program
per chunk so that only O(survivors) floats cross the device boundary:

  pareto    an exact-superset non-dominated prefilter on device (grouped
            2-D staircase elimination when the objectives allow it, the
            block-decomposed dominance port from
            ``repro.kernels.pareto_front`` otherwise), survivors
            compacted with a sized ``nonzero`` and gathered
  top-k     ``jax.lax.top_k`` on the key column (ties resolve to the
            lowest index == the lowest global row id, exactly like
            ``stable_topk_indices``)
  stats     one (count, mean, M2, min, max) Welford partial per chunk
  histogram fixed-edge bin counts (identical binning to ``np.histogram``)

The host-side accumulators stay the cross-chunk merge (see
``Reducer.fold_payload``), so chunk-order invariance and the
pareto/top-k bit-identity guarantees carry over unchanged: survivor
*values* come from the exact x64 device path, survivor *sets* are exact
supersets (pareto) or exact stable selections (top-k), and the
accumulators re-run the same selection logic they apply to host chunks.

Fallback is per chunk and lazy: every program also returns the full
metric arrays as (unfetched) device buffers; only when a pareto survivor
count overflows ``DevicePlan.cap`` does the host fetch them and fold that
chunk through the ordinary full-frame path.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Callable, Dict, Optional, Tuple

import numpy as np


def ensure_exact_cpu_codegen() -> None:
  """Make XLA:CPU arithmetic bit-compatible with numpy.

  Two default XLA rewrites are each 1 ulp away from numpy's
  separate-op IEEE arithmetic and must be off for the exact device path
  (the transcendental log2/pow divergences are already handled by
  host-precomputing those columns, see
  :func:`repro.core.oracle.batch_inputs`):

    * LLVM contracts ``a*b + c`` chains into FMA instructions — capping
      codegen at AVX (a pre-FMA ISA) disables that;
    * the HLO algebraic simplifier rewrites ``x / const`` into
      ``x * (1/const)`` and reassociates constant multiplies.

  XLA latches its flags at the process's first compilation, so this runs
  at this module's import and from ``VectorOracleBackend(jit=True)``
  construction — both precede our program builds.  If your process
  compiles other jax code first, set ``XLA_FLAGS="--xla_cpu_max_isa=AVX
  --xla_disable_hlo_passes=algsimp"`` in the environment yourself
  (``tests/conftest.py`` and ``benchmarks/run.py`` do exactly that).
  """
  flags = os.environ.get("XLA_FLAGS", "")
  if "xla_cpu_max_isa" not in flags:
    flags = (flags + " --xla_cpu_max_isa=AVX").strip()
  if "xla_disable_hlo_passes" not in flags:
    flags = (flags + " --xla_disable_hlo_passes=algsimp").strip()
  os.environ["XLA_FLAGS"] = flags


# NOTE: deliberately NOT invoked at import — the float32 fast mode (and
# unrelated jax workloads sharing the process) should keep full codegen.
# The x64 entry points call it: VectorOracleBackend(jit=True,
# precision="x64").__init__, tests/conftest.py, benchmarks/run.py.

_EXACT_PROBE: Optional[bool] = None
_EXACT_WARNED = False

# ISAs without fused multiply-add: capping codegen at any of these keeps
# XLA's a*b+c bit-identical to numpy's two-op sequence.  AVX2 and up fuse.
_FMA_FREE_ISAS = frozenset({"SSE2", "SSE4_1", "SSE4_2", "AVX"})


def check_exact_codegen_env() -> Optional[str]:
  """Static pre-flight check of the exact-codegen environment.

  Unlike :func:`exact_codegen_active` this never compiles (so it cannot
  itself latch the wrong flags); it inspects ``XLA_FLAGS`` and the jax
  import state and returns a human-readable problem description, or
  ``None`` when the environment can deliver bit-parity.  Callers that
  need the contract (``tests/conftest.py``) should fail fast on a
  non-None return instead of discovering a ~1 ulp drift in a parity
  assertion minutes later.
  """
  import sys
  flags = os.environ.get("XLA_FLAGS", "")
  isas = re.findall(r"--xla_cpu_max_isa=(\S+)", flags)
  passes = re.findall(r"--xla_disable_hlo_passes=(\S+)", flags)
  if not isas or not passes:
    return ("XLA_FLAGS is missing the exact-codegen flags "
            "(--xla_cpu_max_isa / --xla_disable_hlo_passes); call "
            "ensure_exact_cpu_codegen() before jax compiles anything")
  if isas[-1].upper() not in _FMA_FREE_ISAS:
    return (f"XLA_FLAGS pins --xla_cpu_max_isa={isas[-1]}, an ISA with "
            "FMA contraction — a*b+c fuses to 1-ulp-different results; "
            "use AVX (or another of "
            f"{sorted(_FMA_FREE_ISAS)})")
  if not any("algsimp" in p.split(",") for p in passes):
    return (f"XLA_FLAGS disables HLO passes ({passes[-1]}) without "
            "including algsimp — the algebraic simplifier rewrites "
            "x/const into x*(1/const) and breaks bit-parity")
  if "jax" in sys.modules:
    # flags latch at the first backend initialization, not at import —
    # an already-initialized backend means they were read without ours
    xb = sys.modules.get("jax._src.xla_bridge")
    if xb is not None and getattr(xb, "_backends", None):
      return ("a jax backend was initialized before the exact-codegen "
              "flags were set; XLA latched its flags (and the x64 "
              "default) at that first compilation — set XLA_FLAGS in "
              "the environment before the process starts")
  return None


def exact_codegen_active() -> bool:
  """Probe whether XLA is actually compiling numpy-bit-exact arithmetic.

  :func:`ensure_exact_cpu_codegen` cannot guarantee exactness: the user
  may carry conflicting XLA_FLAGS (e.g. ``--xla_cpu_max_isa=AVX512``),
  or another jax program may have compiled before the flags were set
  (XLA latches flags at the first compilation).  This compiles two
  sentinel expressions covering the known divergences (FMA contraction,
  divide-by-constant rewrite, constant reassociation) and compares
  against numpy.  Cached after the first call.
  """
  global _EXACT_PROBE
  if _EXACT_PROBE is None:
    import jax
    from jax.experimental import enable_x64
    x = np.linspace(0.5, 1e6, 4096)
    y = x[::-1].copy()
    with enable_x64():
      got = jax.jit(lambda a, b: (0.028 * a + 0.006 * b,
                                  a / 3.0, a * 0.3 * 0.7))(x, y)
      got = tuple(np.asarray(v) for v in got)
    want = (0.028 * x + 0.006 * y, x / 3.0, x * 0.3 * 0.7)
    _EXACT_PROBE = all(np.array_equal(g, w) for g, w in zip(got, want))
  return _EXACT_PROBE


def warn_if_inexact_codegen() -> None:
  """One-time warning when the exact x64 path cannot deliver bit-parity
  in this process (conflicting XLA_FLAGS / flags latched too late) —
  the backend still runs, but ``parity_max_rel_err == 0.0`` will not
  hold (expect ~1 ulp)."""
  global _EXACT_WARNED
  if _EXACT_WARNED or exact_codegen_active():
    return
  _EXACT_WARNED = True
  import warnings
  warnings.warn(
      "VectorOracleBackend(jit=True, precision='x64') cannot be "
      "bit-identical to numpy in this process: XLA compiled with FMA "
      "contraction or algebraic simplification enabled (conflicting "
      "XLA_FLAGS, or another jax program compiled before "
      "ensure_exact_cpu_codegen ran).  Set XLA_FLAGS="
      "\"--xla_cpu_max_isa=AVX --xla_disable_hlo_passes=algsimp\" before "
      "the process's first jax compilation to restore exactness.",
      RuntimeWarning, stacklevel=3)

from repro.core import oracle
from repro.core.dataflow import ConvLayer
from repro.core.table import ConfigTable
from repro.explore.frame import BASE_COLUMNS, DERIVED_COLUMNS, ResultFrame

# columns the device programs can materialize (frame.column equivalents);
# top1/top1_err additionally need the joint path's per-arch accuracies
DEVICE_COLUMNS = BASE_COLUMNS + DERIVED_COLUMNS
JOINT_COLUMNS = DEVICE_COLUMNS + ("top1", "top1_err")

# columns constant along the HW axis of a joint block (functions of the
# architecture only) — the grouped prefilter may project them out
ARCH_CONSTANT_COLUMNS = frozenset({"top1", "top1_err"})

# default survivor capacity per pareto reducer per chunk; counts above it
# trigger the lazy full-frame fallback for that chunk
DEFAULT_SURVIVOR_CAP = 4096

# staircase elimination rounds: each round removes everything dominated by
# one more front point, so supersets tighten with every round and
# typical per-group fronts (~ln n points) converge well before 32
STAIRCASE_ROUNDS = 32

# block size for the generic (>=3 variable objectives) dominance prefilter
PREFILTER_BLOCK = 128


# ---------------------------------------------------------------------------
# plans: what the reducers need from the device
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParetoSpec:
  cols: Tuple[str, ...]
  maximize: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class TopKSpec:
  col: str
  k: int
  maximize: bool


@dataclasses.dataclass(frozen=True)
class StatsSpec:
  col: str


@dataclasses.dataclass(frozen=True)
class HistSpec:
  col: str
  lo: float
  hi: float
  bins: int


@dataclasses.dataclass(frozen=True)
class DevicePlan:
  """Per-reducer device requests, hashable (part of the jit cache key)."""
  specs: Tuple[Tuple[str, object], ...]  # (reducer name, spec)
  cap: int = DEFAULT_SURVIVOR_CAP

  def __iter__(self):
    return iter(self.specs)


def build_plan(reducers: Dict[str, object], joint: bool,
               cap: int = DEFAULT_SURVIVOR_CAP) -> Optional[DevicePlan]:
  """A DevicePlan covering every reducer, or None when any reducer (or
  any referenced column) is not device-fusable — callers then fall back
  to the plain per-chunk evaluation path."""
  allowed = set(JOINT_COLUMNS if joint else DEVICE_COLUMNS)
  specs = []
  for name, r in reducers.items():
    spec = getattr(r, "device_spec", lambda: None)()
    if spec is None:
      return None
    cols = spec.cols if isinstance(spec, ParetoSpec) else (spec.col,)
    if not set(cols) <= allowed:
      return None
    specs.append((name, spec))
  return DevicePlan(specs=tuple(specs), cap=int(cap))


# ---------------------------------------------------------------------------
# device-side column + prefilter machinery (everything below traces)
# ---------------------------------------------------------------------------

def _derive_columns(lat, pwr, area, jnp, accs=None):
  """The frame.column formulas, op for op (keeps survivor values
  bit-identical to the host frame's derived columns).  All grids are
  (G, M): one group per arch for joint blocks, a single group otherwise.
  """
  cols = {"latency_s": lat, "power_mw": pwr, "area_mm2": area}
  perf = 1.0 / jnp.maximum(lat, 1e-12)
  cols["perf"] = perf
  cols["perf_per_area"] = perf / jnp.maximum(area, 1e-12)
  cols["energy_mj"] = pwr * lat
  if accs is not None:
    top1 = jnp.broadcast_to(accs[:, None], lat.shape)
    cols["top1"] = top1
    cols["top1_err"] = 1.0 - top1
  return cols


def _staircase_mask(x, y, jnp, jax, rounds: Optional[int] = None):
  """(G, M) bool superset of each group's 2-D front (minimize x then y).

  Champion elimination: every round picks the lowest-x not-yet-processed
  survivor per group (i.e. walks the front in x order) and removes
  everything it dominates.  Only truly dominated points are ever removed
  (and champions dominate nobody they tie with), so the result is a
  front superset after ANY number of rounds; rounds only control how
  tight it is — after ``rounds >= front size`` the mask is the union of
  the exact front and points dominated by nothing processed, i.e. the
  exact front plus x-ties.
  """
  if rounds is None:
    rounds = STAIRCASE_ROUNDS
  g = x.shape[0]
  row = jnp.arange(g)

  def body(_, state):
    alive, processed = state
    key = jnp.where(alive & ~processed, x, jnp.inf)
    i = jnp.argmin(key, axis=1)
    cx = jnp.take_along_axis(x, i[:, None], axis=1)
    cy = jnp.take_along_axis(y, i[:, None], axis=1)
    dom = (cx <= x) & (cy <= y) & ((cx < x) | (cy < y))
    return alive & ~dom, processed.at[row, i].set(True)

  alive = jnp.ones(x.shape, bool)
  processed = jnp.zeros(x.shape, bool)
  alive, _ = jax.lax.fori_loop(0, rounds, body, (alive, processed))
  return alive


def _pareto_prefilter(cols, spec: ParetoSpec, grouped: bool, jnp, jax):
  """(G, M) bool exact-superset mask of the chunk front for ``spec``.

  Grouped blocks may project out arch-constant objectives (rows of one
  group tie on them, so within-group dominance on the remaining axes is
  full dominance); cross-group comparisons are only attempted by the
  generic block filter, which keeps every axis.
  """
  mx = set(spec.maximize)
  objs = {c: (-cols[c] if c in mx else cols[c]) for c in spec.cols}
  var = [objs[c] for c in spec.cols
         if not (grouped and c in ARCH_CONSTANT_COLUMNS)]
  if len(var) == 0:  # all objectives tie within every group
    return jnp.ones(next(iter(objs.values())).shape, bool)
  if len(var) == 1:
    v = var[0]
    return v == v.min(axis=1, keepdims=True)
  if len(var) == 2:
    return _staircase_mask(var[0], var[1], jnp, jax)
  from repro.kernels.pareto_front import ops as pf_ops
  obj = jnp.stack([o.reshape(-1) for o in objs.values()], axis=1)
  return pf_ops.block_prefilter_mask(obj, block=PREFILTER_BLOCK).reshape(
      var[0].shape)


def _histogram_counts(v, lo: float, hi: float, bins: int, jnp):
  """np.histogram-identical fixed-edge binning (half-open bins, last
  closed; values pre-clipped into range like HistogramAccumulator)."""
  # host np on purpose: lo/hi/bins are trace constants from the HistSpec,
  # and host-built edges keep binning bit-identical to np.histogram
  edges = np.linspace(float(lo), float(hi), int(bins) + 1)  # repro: ignore[JIT003]
  v = jnp.clip(v.reshape(-1), edges[0], edges[-1])
  idx = jnp.clip(jnp.searchsorted(jnp.asarray(edges), v, side="right") - 1,
                 0, bins - 1)
  return jnp.zeros(bins, jnp.int64 if v.dtype == jnp.float64
                   else jnp.int32).at[idx].add(1)


def _reduce_outputs(cols, plan: DevicePlan, grouped: bool, jnp, jax):
  """The per-reducer output pytree of a fused program."""
  n = cols["latency_s"].size
  base = tuple(cols[c].reshape(-1) for c in ("latency_s", "power_mw",
                                             "area_mm2"))
  out = {}
  for name, spec in plan:
    if isinstance(spec, ParetoSpec):
      mask = _pareto_prefilter(cols, spec, grouped, jnp, jax).reshape(-1)
      idx = jnp.nonzero(mask, size=plan.cap, fill_value=n)[0]
      out[name] = {
          "count": mask.sum(),  # repro: ignore[EXA003] — bool count: integer-exact under any order
          "idx": idx,
          "rows": tuple(jnp.take(b, idx, mode="fill", fill_value=0.0)
                        for b in base),
      }
    elif isinstance(spec, TopKSpec):
      key = cols[spec.col].reshape(-1)
      key = -key if not spec.maximize else key
      k = min(spec.k, n)
      _, idx = jax.lax.top_k(key, k)  # ties -> lowest index == lowest row id
      out[name] = {
          "idx": idx,
          "rows": tuple(jnp.take(b, idx) for b in base),
      }
    elif isinstance(spec, StatsSpec):
      v = cols[spec.col].reshape(-1)
      # Welford partials are outside the bit-identity contract (stats are
      # merge-order-dependent on the host path too); reassociation here
      # moves mean/m2 by ulps, never the survivor sets
      mean = v.mean()  # repro: ignore[EXA003]
      # n is a static trace constant: a single-row chunk has zero spread
      # by definition, and computing (v - mean)**2 for it would turn a
      # non-finite value into a NaN M2 partial (mirrors
      # StatsAccumulator.fold's n == 1 short-circuit)
      m2 = jnp.zeros(()) if n == 1 else ((v - mean) ** 2).sum()  # repro: ignore[EXA003]
      out[name] = {"n": n, "mean": mean, "m2": m2,
                   "min": v.min(), "max": v.max()}
    elif isinstance(spec, HistSpec):
      out[name] = {"counts": _histogram_counts(cols[spec.col], spec.lo,
                                               spec.hi, spec.bins, jnp)}
    else:  # pragma: no cover - build_plan only emits the specs above
      raise TypeError(f"unknown device spec {spec!r}")
  return out


# ---------------------------------------------------------------------------
# program builders (returned callables are pure: backend jits them)
# ---------------------------------------------------------------------------

def make_eval_fn(layers: Tuple[ConvLayer, ...],
                 plan: Optional[DevicePlan]) -> Callable:
  """Plain-sweep program: inputs bundle -> (lat, pwr, area)[, reductions].

  With a plan the full metric arrays still come back as device outputs —
  they are the lazy overflow/Collect fallback and cost only their device
  materialization, never a transfer unless fetched.
  """
  import jax
  import jax.numpy as jnp

  def run(inputs):
    ch = oracle.characterize_batch(None, layers, xp=jnp, inputs=inputs)
    full = (ch.latency_s, ch.power_mw, ch.area_mm2)
    if plan is None:
      return full
    cols = _derive_columns(ch.latency_s[None, :], ch.power_mw[None, :],
                           ch.area_mm2[None, :], jnp)
    return full, _reduce_outputs(cols, plan, grouped=False, jnp=jnp, jax=jax)

  return run


def make_joint_fn(plan: Optional[DevicePlan]) -> Callable:
  """Joint-sweep program over the distinct-layer factorization:
  (inputs, unique_cols, slot_ids, valid, accs) ->
  (lat (A, H), pwr (H,), area (H,))[, reductions].

  Stack data enters as arrays (not trace constants), so ONE jitted
  callable serves every arch block of a streaming sweep — jax re-traces
  per shape, not per block.  ``accs`` is consumed only by fused plans;
  plan-less callers pass an empty array.
  """
  import jax
  import jax.numpy as jnp

  def run(inputs, unique_cols, slot_ids, valid, accs):
    ch = oracle.characterize_joint_dedup(None, unique_cols, slot_ids, valid,
                                         xp=jnp, inputs=inputs)
    full = (ch.latency_s, ch.power_mw, ch.area_mm2)
    if plan is None:
      return full
    lat = ch.latency_s
    cols = _derive_columns(
        lat, jnp.broadcast_to(ch.power_mw[None, :], lat.shape),
        jnp.broadcast_to(ch.area_mm2[None, :], lat.shape), jnp, accs=accs)
    return full, _reduce_outputs(cols, plan, grouped=True, jnp=jnp, jax=jax)

  return run


def joint_chunk_frame(lat: np.ndarray, pwr: np.ndarray, area: np.ndarray,
                      hw: ConfigTable, network: str, arch_lo: int,
                      accs: np.ndarray,
                      arch_lookup: Tuple[object, ...]) -> ResultFrame:
  """The ordinary full joint chunk frame (what
  ``co_evaluate_table`` + the streaming driver's arch postprocessing
  produce), built from raw (A, H)/(H,) metric arrays — shared by the
  non-fused pending path and the fused overflow fallback."""
  n_archs = lat.shape[0]
  joint = hw.cross(n_archs)
  ids = joint.arch_ids()
  return ResultFrame(
      lat.reshape(-1), np.tile(pwr, n_archs), np.tile(area, n_archs),
      joint.pe_type_strings(), (), network, table=joint,
      extra={"arch_id": ids + arch_lo,
             "top1": np.asarray(accs, np.float64)[ids]},
      arch_lookup=arch_lookup)


# ---------------------------------------------------------------------------
# pending chunks: async dispatch handles the host folds later
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FusedChunk:
  """Resolved fused-chunk result: one payload per reducer (see
  ``Reducer.fold_payload``) plus row counts for engine accounting —
  ``n_transferred`` is how many evaluated rows actually crossed the
  device boundary (the O(survivors), not O(chunk_size), evidence);
  ``n_overflows`` counts pareto reducers whose survivor count blew the
  plan cap and fell back to the full chunk frame — the first rung of
  the graceful-degradation story (see repro.explore.resilience)."""
  payloads: Dict[str, tuple]
  n_rows: int
  n_transferred: int = 0
  n_overflows: int = 0


class _PendingBase:
  """A dispatched device chunk.  Construction dispatches the program
  (jax async); ``resolve()`` blocks on / fetches only what the reducers
  need.  The streaming engine keeps a small window of these in flight so
  host chunk materialization overlaps device execution."""

  _buffers = None  # output pytree backing is_ready, when tracked

  def resolve(self):
    raise NotImplementedError

  def is_ready(self) -> bool:
    """Non-blocking readiness: True once every tracked device output
    buffer has been computed (jax async dispatch exposes ``is_ready`` on
    arrays) — the fleet layer's straggler polling.  Handles without
    tracked buffers report False (unknown)."""
    if self._buffers is None:
      return False
    import jax
    return all(leaf.is_ready()
               for leaf in jax.tree_util.tree_leaves(self._buffers)
               if hasattr(leaf, "is_ready"))


class PendingFrame(_PendingBase):
  """Non-fused device chunk: resolves to the ordinary (frame, idx)."""

  def __init__(self, finalize: Callable[[], Tuple[ResultFrame, np.ndarray]],
               buffers=None):
    self._finalize = finalize
    self._buffers = buffers

  def resolve(self) -> Tuple[ResultFrame, np.ndarray]:
    return self._finalize()


class PendingFused(_PendingBase):
  """Fused device chunk: resolves to a :class:`FusedChunk`.

  ``full_frame`` builds the chunk's ordinary full frame from the device
  metric arrays — used by overflowing pareto reducers only.
  """

  def __init__(self, outputs, plan: DevicePlan, table: ConfigTable,
               indices: np.ndarray, network: str,
               n_hw: Optional[int] = None, arch_lo: int = 0,
               accs: Optional[np.ndarray] = None,
               arch_lookup: Tuple[object, ...] = ()):
    self._full, self._reduced = outputs
    self._buffers = outputs
    self.plan = plan
    self.table = table
    self.indices = np.asarray(indices, np.int64)
    self.network = network
    self.n_hw = len(table) if n_hw is None else int(n_hw)
    self.arch_lo = int(arch_lo)
    self.accs = accs
    self.arch_lookup = tuple(arch_lookup)
    self._joint = accs is not None

  # -- frame builders -------------------------------------------------------

  def _extras(self, local: np.ndarray):
    if not self._joint:
      return {}
    arch_local = local // self.n_hw
    return {"arch_id": arch_local + self.arch_lo,
            "top1": np.asarray(self.accs, np.float64)[arch_local]}

  def _mini_frame(self, local: np.ndarray, rows) -> ResultFrame:
    lat, pwr, area = (np.asarray(r, np.float64) for r in rows)
    hw_local = local % self.n_hw if self._joint else local
    sub = self.table.select(hw_local)
    return ResultFrame(lat, pwr, area, sub.pe_type_strings(), (),
                       self.network, extra=self._extras(local), table=sub,
                       arch_lookup=self.arch_lookup)

  def full_frame(self) -> Tuple[ResultFrame, np.ndarray]:
    """The chunk's ordinary full frame (lazy device->host fetch)."""
    lat, pwr, area = (np.asarray(a, np.float64) for a in self._full)
    if not self._joint:
      return (ResultFrame(lat, pwr, area, self.table.pe_type_strings(), (),
                          self.network, table=self.table), self.indices)
    return joint_chunk_frame(lat, pwr, area, self.table, self.network,
                             self.arch_lo, self.accs,
                             self.arch_lookup), self.indices

  # -- resolution -----------------------------------------------------------

  def resolve(self) -> FusedChunk:
    payloads: Dict[str, tuple] = {}
    full = None
    transferred = 0
    overflows = 0
    for name, spec in self.plan:
      out = self._reduced[name]
      if isinstance(spec, ParetoSpec):
        count = int(out["count"])
        if count > self.plan.cap:  # rare: fetch the full chunk instead
          overflows += 1
          if full is None:
            full = self.full_frame()
            transferred += len(self.indices)
          payloads[name] = ("rows",) + full
          continue
        local = np.asarray(out["idx"][:count], np.int64)
        transferred += count
        payloads[name] = ("rows", self._mini_frame(local, [
            r[:count] for r in out["rows"]]), self.indices[local])
      elif isinstance(spec, TopKSpec):
        local = np.asarray(out["idx"], np.int64)
        transferred += local.size
        payloads[name] = ("rows", self._mini_frame(local, out["rows"]),
                          self.indices[local])
      elif isinstance(spec, StatsSpec):
        payloads[name] = ("stats", {k: float(out[k]) if k != "n" else
                                    int(out[k]) for k in out})
      else:
        payloads[name] = ("hist", np.asarray(out["counts"], np.int64))
    return FusedChunk(payloads=payloads, n_rows=len(self.indices),
                      n_transferred=transferred, n_overflows=overflows)
