"""Guided multi-objective search over the streaming evaluator.

QUIDAM's joint space (110k+ architectures x an unbounded HW grid) is too
large to enumerate; the exhaustive sweeps of :mod:`repro.explore.streaming`
spend their budget uniformly.  This module adds the search layer the
paper's co-exploration workflow implies (and the "software-defined DSE"
line of work makes precedent for): an NSGA-II-style evolutionary
optimizer whose unit of work is *one generation == one chunk* of the
existing evaluate pipeline, plus a surrogate mode that fits
:func:`repro.core.ppa.fit_poly`-style models online and screens
proposals by expected hypervolume gain.

Design for determinism and exactness — the repo's standing contracts:

  * every random draw routes through a ``np.random.RandomState`` seeded
    by :func:`repro.core.seeding.derive_seed` (one labelled stream per
    generation; enforced statically by analysis rule CON005), so
    same-seed reruns are bit-identical;
  * populations are materialized as :class:`~repro.core.table.ConfigTable`
    columns via the :class:`~repro.explore.space.DesignSpace` axes —
    mutation and crossover operate on per-axis *value indices*, so
    children always lie on the discrete grid, and constraint predicates
    re-apply through ``DesignSpace.table_mask`` after every variation;
  * each generation evaluates as a single chunk through the caller's
    ``evaluate`` hook (the session wires this to
    ``VectorOracleBackend.eval_pending`` on a ``jit=True`` backend: the
    whole generation is one device-resident program dispatch, and only
    the three base metric columns cross the device boundary);
  * evaluated generations fold into the chunk-order-invariant
    :class:`~repro.explore.streaming.ParetoAccumulator` with global row
    ids in evaluation order, so the reported front is *exact* — re-folding
    the same generations in any order reproduces it bit for bit — and the
    result type is the same :class:`~repro.explore.streaming.StreamResult`
    the streaming engine returns.

Selection reuses the repo's front kernels: non-dominated ranks peel
successive :func:`~repro.explore.frame.pareto_mask` fronts (the
block-decomposed ``_pareto_mask_nd`` underneath for 3+ objectives), and
survivor truncation is the standard (rank asc, crowding desc) order.

Entry point: :meth:`repro.explore.ExplorationSession.optimize`, or
:func:`guided_search` directly with a custom ``evaluate`` hook (the
property-test harness maps analytic ZDT-style problems onto a
DesignSpace this way).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.seeding import derive_seed
from repro.core.table import ConfigTable
from repro.explore.frame import _MAXIMIZE_COLUMNS, ResultFrame, pareto_mask
from repro.explore.resilience import (ChunkError, ChunkTask,
                                      ResiliencePolicy, Rung, SweepJournal,
                                      reducers_fingerprint,
                                      space_fingerprint, sweep_key)
from repro.explore.space import DesignSpace
from repro.explore.streaming import (ParetoAccumulator, Reducer,
                                     StreamResult)

__all__ = [
    "crowding_distance", "guided_search", "hypervolume",
    "nondominated_ranks", "objective_matrix",
]

# surrogate screening thins the archive front to this many points before
# the per-candidate hypervolume-gain loop (a proposal heuristic only —
# the reported front/hypervolume always use the full archive)
_SCREEN_FRONT_CAP = 64

# variation-repair retries before a generation accepts fewer candidates
# (the constrained-or-exhausted-space escape hatch)
_REPAIR_TRIES = 64


# ---------------------------------------------------------------------------
# front quality: exact hypervolume (minimization convention)
# ---------------------------------------------------------------------------

def hypervolume(points: np.ndarray, ref: Sequence[float]) -> float:
  """Exact dominated hypervolume of ``points`` against reference ``ref``.

  All objectives are MINIMIZED (the :func:`pareto_mask` convention);
  only points strictly below ``ref`` in every coordinate contribute.
  Dimension-sweep ("slicing") algorithm: exact in any dimension,
  O(n log n) in 2-D, O(n^2 log n)-ish per extra dimension — intended
  for front-sized inputs, not million-row sweeps.
  """
  pts = np.asarray(points, np.float64)
  if pts.ndim != 2:
    raise ValueError(f"points must be 2-D, got shape {pts.shape}")
  r = np.asarray(ref, np.float64).reshape(-1)
  if r.shape[0] != pts.shape[1]:
    raise ValueError(f"ref has {r.shape[0]} coords for "
                     f"{pts.shape[1]}-objective points")
  if pts.shape[0] == 0:
    return 0.0
  pts = pts[np.all(pts < r, axis=1)]
  if pts.shape[0] == 0:
    return 0.0
  front = np.unique(pts[pareto_mask(pts)], axis=0)
  return float(_hv(front, r))


def _hv(front: np.ndarray, ref: np.ndarray) -> float:
  """Recursive slicing on a deduplicated non-dominated set."""
  d = front.shape[1]
  if d == 1:
    return float(ref[0] - front[:, 0].min())
  if d == 2:
    # ascending x => strictly descending y on a strict 2-D front
    order = np.argsort(front[:, 0], kind="stable")
    x = front[order, 0]
    y = front[order, 1]
    prev_y = np.concatenate([[ref[1]], y[:-1]])
    return float(np.sum((ref[0] - x) * (prev_y - y)))
  order = np.argsort(front[:, -1], kind="stable")
  z = front[order, -1]
  total = 0.0
  for i in range(z.shape[0]):
    z_hi = z[i + 1] if i + 1 < z.shape[0] else ref[-1]
    if z_hi <= z[i]:
      continue  # zero-thickness slab: merged into the next slice
    sub = front[order[: i + 1], :-1]
    if sub.shape[0] > 1:
      sub = np.unique(sub[pareto_mask(sub)], axis=0)
    total += (z_hi - z[i]) * _hv(sub, ref[:-1])
  return total


def objective_matrix(frame: ResultFrame, cols: Sequence[str],
                     maximize: Optional[Sequence[str]] = None) -> np.ndarray:
  """(n, d) minimized objective matrix — identical column signs to
  :class:`~repro.explore.streaming.ParetoAccumulator` (columns in
  ``maximize``, default the frame's perf/perf_per_area/top1 set, are
  negated)."""
  mx = _MAXIMIZE_COLUMNS if maximize is None else frozenset(maximize)
  return np.stack([-frame.column(c) if c in mx else frame.column(c)
                   for c in cols], axis=1).astype(np.float64)


# ---------------------------------------------------------------------------
# NSGA-II machinery: ranks, crowding, selection, variation
# ---------------------------------------------------------------------------

def nondominated_ranks(obj: np.ndarray) -> np.ndarray:
  """Rank 0 = the Pareto front, rank 1 = the front of the rest, ... —
  successive :func:`pareto_mask` peels (the block-decomposed N-D kernel
  underneath), so million-row rank sorts stay vectorized."""
  obj = np.asarray(obj, np.float64)
  n = obj.shape[0]
  ranks = np.zeros(n, np.int64)
  alive = np.arange(n)
  r = 0
  while alive.size:
    m = pareto_mask(obj[alive])
    if not m.any():  # pragma: no cover - only reachable on NaN objectives
      ranks[alive] = r
      break
    ranks[alive[m]] = r
    alive = alive[~m]
    r += 1
  return ranks


def crowding_distance(obj: np.ndarray, ranks: np.ndarray) -> np.ndarray:
  """Per-front crowding distance (inf at each front's per-objective
  boundaries; interior points sum normalized neighbour gaps).  Sorts are
  stable, so equal-objective ties resolve by row index — deterministic."""
  obj = np.asarray(obj, np.float64)
  ranks = np.asarray(ranks, np.int64)
  crowd = np.zeros(obj.shape[0], np.float64)
  for r in np.unique(ranks):
    rows = np.flatnonzero(ranks == r)
    if rows.size <= 2:
      crowd[rows] = np.inf
      continue
    for j in range(obj.shape[1]):
      v = obj[rows, j]
      order = np.argsort(v, kind="stable")
      crowd[rows[order[0]]] = np.inf
      crowd[rows[order[-1]]] = np.inf
      span = float(v[order[-1]] - v[order[0]])
      if span > 0.0:
        crowd[rows[order[1:-1]]] += (v[order[2:]] - v[order[:-2]]) / span
  return crowd


def _tournament(rank: np.ndarray, crowd: np.ndarray,
                rng: np.random.RandomState, n_picks: int) -> np.ndarray:
  """Binary tournament on (rank asc, crowding desc); ties keep the first
  contestant, so the draw sequence alone fixes the outcome."""
  pick = rng.randint(0, rank.shape[0], size=(n_picks, 2))
  a, b = pick[:, 0], pick[:, 1]
  b_wins = (rank[b] < rank[a]) | ((rank[b] == rank[a])
                                  & (crowd[b] > crowd[a]))
  return np.where(b_wins, b, a)


def _draw(rng: np.random.RandomState, n: int,
          card: np.ndarray) -> np.ndarray:
  """n uniform genomes: one value-index per gene, per-gene cardinalities
  ``card`` (vectorized across genes of different cardinality)."""
  u = rng.rand(n, card.shape[0])
  return np.minimum((u * card[None, :]).astype(np.int64), card - 1)


def _vary(genome: np.ndarray, rank: np.ndarray, crowd: np.ndarray,
          rng: np.random.RandomState, card: np.ndarray, n_out: int,
          crossover_rate: float, mutation_rate: float) -> np.ndarray:
  """Tournament parents -> uniform crossover -> per-gene reset mutation.
  Every gene stays a valid value index of its axis by construction."""
  picks = _tournament(rank, crowd, rng, 2 * n_out)
  pa = genome[picks[:n_out]]
  pb = genome[picks[n_out:]]
  crossed = rng.rand(n_out) < crossover_rate
  take_b = (rng.rand(n_out, card.shape[0]) < 0.5) & crossed[:, None]
  child = np.where(take_b, pb, pa)
  mutate = rng.rand(n_out, card.shape[0]) < mutation_rate
  return np.where(mutate, _draw(rng, n_out, card), child)


# ---------------------------------------------------------------------------
# genome <-> ConfigTable
# ---------------------------------------------------------------------------

def _cardinalities(space: DesignSpace, n_archs: Optional[int]) -> np.ndarray:
  card = [len(space.pe_types)] + [len(a.values) for a in space.axes]
  if n_archs is not None:
    card.append(n_archs)
  return np.asarray(card, np.int64)


def _decode_table(space: DesignSpace, genome: np.ndarray) -> ConfigTable:
  """Genome rows -> ConfigTable (gene 0 = PE type index, genes 1..7 =
  per-axis value indices; a trailing arch gene, when present, is not the
  table's concern)."""
  names = np.asarray(space.pe_types)[genome[:, 0]]
  cols = {a.name: np.asarray(a.values)[genome[:, 1 + i]]
          for i, a in enumerate(space.axes)}
  return ConfigTable.from_columns(names, cols)


def _genome_keys(genome: np.ndarray) -> list:
  """Per-row identity keys (bytes of the int64 gene vector) for the
  evaluated-points archive — exact, vocabulary-independent."""
  g = np.ascontiguousarray(genome, np.int64)
  return [g[i].tobytes() for i in range(g.shape[0])]


def _repair(space: DesignSpace, genome: np.ndarray,
            rng: np.random.RandomState, seen, card: np.ndarray
            ) -> np.ndarray:
  """Make every row constraint-valid and never-evaluated (archive +
  within-batch dedup) by redrawing offending rows; rows still bad after
  ``_REPAIR_TRIES`` redraws are dropped — the optimizer then runs a
  smaller generation rather than re-spending budget on known points."""
  genome = np.ascontiguousarray(genome, np.int64)
  good = np.zeros(len(genome), np.bool_)
  for attempt in range(_REPAIR_TRIES + 1):
    ok = space.table_mask(_decode_table(space, genome))
    keys = _genome_keys(genome)
    fresh = np.ones(len(genome), np.bool_)
    batch = set()
    for i in range(len(keys)):
      if keys[i] in seen or keys[i] in batch:
        fresh[i] = False
      else:
        batch.add(keys[i])
    good = ok & fresh
    bad = np.flatnonzero(~good)
    if not bad.size or attempt == _REPAIR_TRIES:
      break
    genome = genome.copy()
    genome[bad] = _draw(rng, bad.size, card)
  return genome[good]


# ---------------------------------------------------------------------------
# surrogate mode: online polynomial models + hypervolume-gain screening
# ---------------------------------------------------------------------------

def default_features(table: ConfigTable,
                     arch: Optional[np.ndarray]) -> np.ndarray:
  """Surrogate feature matrix: the same all-float64 knob + PE-constant
  bundle the batch formulas consume (``ConfigTable.numeric_columns``
  order), plus the raw arch gene when searching the joint space."""
  cols = table.numeric_columns()
  feats = [cols[k] for k in sorted(cols)]
  if arch is not None:
    feats.append(np.asarray(arch, np.float64))
  return np.stack(feats, axis=1)


def _fit_surrogates(x: np.ndarray, y: np.ndarray):
  """One :func:`repro.core.ppa.fit_poly` model per objective (degree-2,
  max 2 variables per monomial — the QAPPA power/area basis shape; ridge
  keeps early small-sample fits well-posed)."""
  from repro.core.ppa import fit_poly
  return [fit_poly(x, y[:, j], degree=2, max_vars=2)
          for j in range(y.shape[1])]


def _screen_front(archive_obj: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
  """(thinned archive front, hypervolume reference point) for proposal
  screening.  The reference sits 10% beyond the archive's per-objective
  worst, so every evaluated point contributes volume."""
  lo = archive_obj.min(axis=0)
  hi = archive_obj.max(axis=0)
  ref = hi + 0.1 * np.maximum(hi - lo, 1e-12)
  front = np.unique(archive_obj[pareto_mask(archive_obj)], axis=0)
  if front.shape[0] > _SCREEN_FRONT_CAP:
    sel = np.linspace(0, front.shape[0] - 1, _SCREEN_FRONT_CAP)
    front = front[sel.astype(np.int64)]
  return front, ref


def _hv_gain_screen(pred: np.ndarray, front: np.ndarray, ref: np.ndarray,
                    k: int) -> np.ndarray:
  """Indices of the ``k`` candidates with the largest expected
  hypervolume gain (predicted objectives vs. the archive front); ties
  break by candidate order — deterministic."""
  base = hypervolume(front, ref)
  gains = np.empty(pred.shape[0], np.float64)
  for i in range(pred.shape[0]):
    gains[i] = hypervolume(np.concatenate([front, pred[i:i + 1]]),
                           ref) - base
  return np.argsort(-gains, kind="stable")[:k]


# ---------------------------------------------------------------------------
# the optimizer
# ---------------------------------------------------------------------------

def guided_search(space: DesignSpace,
                  evaluate: Callable,
                  objectives: Sequence[str],
                  *,
                  maximize: Optional[Sequence[str]] = None,
                  population: int = 32,
                  generations: int = 12,
                  seed: int = 17,
                  surrogate: bool = False,
                  surrogate_pool: int = 4,
                  features: Callable = default_features,
                  crossover_rate: float = 0.9,
                  mutation_rate: Optional[float] = None,
                  n_archs: Optional[int] = None,
                  reducers: Optional[Dict[str, Reducer]] = None,
                  policy: Optional[ResiliencePolicy] = None,
                  resume_from=None,
                  checkpoint_every: int = 1
                  ) -> StreamResult:
  """NSGA-II-style search over a DesignSpace, one generation per chunk.

  ``evaluate(table, idx, arch)`` scores one generation: ``table`` is the
  generation's ConfigTable, ``idx`` its global row ids (evaluation
  order), ``arch`` the per-row architecture gene (``None`` unless
  ``n_archs`` is set).  It returns ``(ResultFrame, idx)`` or an
  asynchronous handle with ``.resolve()`` (the device path's
  PendingFrame), exactly like a streaming-engine task.

  Every generation folds into ``reducers`` (default: one
  :class:`ParetoAccumulator` over ``objectives``) before selection, so
  the returned front is chunk-order invariant and in global row order —
  the same exactness story as the streaming engine.  ``surrogate=True``
  additionally fits per-objective polynomial models on all evaluated
  points and screens a ``surrogate_pool x population`` proposal pool by
  expected hypervolume gain before spending evaluation budget.

  Returns a :class:`StreamResult`; ``meta`` carries evaluations /
  generations / hypervolume (+ its reference point) alongside the usual
  run stats.  Same seed, same inputs -> bit-identical result.

  A generation is the search's chunk: ``policy`` retries a failing
  ``evaluate`` under the resilience ladder, and ``resume_from`` (a
  :class:`SweepJournal` or its directory) checkpoints the complete loop
  state — archive, surrogate training set, population, reducers — after
  every generation, restoring it on re-entry.  Each generation's RNG is
  ``derive_seed("search-gen", seed, g)``, a pure function of ``(seed,
  g)``, so the resumed trajectory (and final front) is bit-identical to
  an uninterrupted run.  ``generations`` is deliberately *not* part of
  the journal key: resuming with a larger budget extends a finished run
  from its last durable generation.
  """
  objectives = tuple(objectives)
  if not objectives:
    raise ValueError("need at least one objective column")
  if population < 2:
    raise ValueError(f"population must be >= 2, got {population}")
  if generations < 1:
    raise ValueError(f"generations must be >= 1, got {generations}")
  if surrogate_pool < 2:
    raise ValueError(f"surrogate_pool must be >= 2, got {surrogate_pool}")
  if n_archs is not None and n_archs < 1:
    raise ValueError(f"n_archs must be >= 1, got {n_archs}")
  card = _cardinalities(space, n_archs)
  if mutation_rate is None:
    mutation_rate = 1.0 / card.shape[0]
  if reducers is None:
    reducers = {"pareto": ParetoAccumulator(objectives, maximize)}

  t0 = time.perf_counter()
  seen = set()  # evaluated-genome archive (membership only; never iterated)
  xs, ys = [], []
  models = None
  pop_genome = None
  pop_obj = None
  offset = 0
  gens_run = 0
  g_start = 0
  n_resumed = 0
  base_retries = 0
  base_demotions = 0
  journal = None
  jkey = ""
  if resume_from is not None:
    journal = resume_from if isinstance(resume_from, SweepJournal) \
        else SweepJournal(resume_from)
    jkey = sweep_key(
        "guided-search", space_fingerprint(space),
        reducers_fingerprint(reducers),
        {"objectives": objectives,
         "maximize": None if maximize is None else tuple(maximize),
         "population": population, "seed": seed, "surrogate": surrogate,
         "surrogate_pool": surrogate_pool,
         "crossover_rate": crossover_rate, "mutation_rate": mutation_rate,
         "n_archs": n_archs})
    state = journal.load(jkey)
    if state is not None:
      g_start = state["g_next"]
      seen = set(state["seen"])
      xs = list(state["xs"])
      ys = list(state["ys"])
      pop_genome = state["pop_genome"]
      pop_obj = state["pop_obj"]
      offset = state["offset"]
      gens_run = state["gens_run"]
      base_retries = state.get("n_retries", 0)
      base_demotions = state.get("n_demotions", 0)
      n_resumed = gens_run
      for name, r in reducers.items():
        if name in state["reducers"]:
          r.restore(state["reducers"][name])
      if surrogate and xs:
        # surrogate models refit deterministically from the journaled
        # training set — no fitted state needs serializing
        models = _fit_surrogates(np.concatenate(xs), np.concatenate(ys))
  since_ckpt = 0

  def checkpoint(g_next: int, force: bool = False) -> None:
    nonlocal since_ckpt
    if journal is None:
      return
    since_ckpt += 1
    if not force and since_ckpt < max(int(checkpoint_every), 1):
      return
    extra_r = policy.n_retries if policy is not None else 0
    extra_d = policy.n_demotions if policy is not None else 0
    journal.record(jkey, {
        "g_next": g_next, "seen": set(seen), "xs": list(xs),
        "ys": list(ys), "pop_genome": pop_genome, "pop_obj": pop_obj,
        "offset": offset, "gens_run": gens_run,
        "n_retries": base_retries + extra_r,
        "n_demotions": base_demotions + extra_d,
        "reducers": {name: r.snapshot() for name, r in reducers.items()}})
    since_ckpt = 0

  for g in range(g_start, generations):
    rng = np.random.RandomState(derive_seed("search-gen", seed, g))
    screening = surrogate and models is not None
    if pop_genome is None:
      cand = _draw(rng, population, card)
    else:
      rank = nondominated_ranks(pop_obj)
      crowd = crowding_distance(pop_obj, rank)
      n_out = population * (surrogate_pool if screening else 1)
      cand = _vary(pop_genome, rank, crowd, rng, card, n_out,
                   crossover_rate, mutation_rate)
    cand = _repair(space, cand, rng, seen, card)
    if not len(cand):
      break  # constrained/deduplicated space exhausted: stop early
    if screening and len(cand) > population:
      table = _decode_table(space, cand)
      arch = cand[:, -1] if n_archs is not None else None
      x = features(table, arch)
      pred = np.stack([m.predict(x) for m in models], axis=1)
      front, ref = _screen_front(np.concatenate(ys))
      cand = cand[_hv_gain_screen(pred, front, ref, population)]
    elif len(cand) > population:
      cand = cand[:population]

    table = _decode_table(space, cand)
    arch = cand[:, -1].copy() if n_archs is not None else None
    idx = np.arange(offset, offset + len(cand), dtype=np.int64)
    try:
      if policy is not None:
        out = policy.execute(ChunkTask(index=g, rungs=(
            Rung("evaluate", lambda: evaluate(table, idx, arch),
                 layer="backend"),)))
      else:
        out = evaluate(table, idx, arch)
      if hasattr(out, "resolve"):
        out = out.resolve()
    except Exception as e:
      # surface the failing generation; the journal already holds every
      # completed generation, so a re-run with resume_from continues here
      checkpoint(g, force=True)
      if isinstance(e, ChunkError):
        raise
      raise ChunkError(g, f"{type(e).__name__}: {e}") from e
    frame, idx = out
    offset += len(frame)
    for r in reducers.values():
      r.fold(frame, idx)
    obj = objective_matrix(frame, objectives, maximize)
    for key in _genome_keys(cand):
      seen.add(key)
    ys.append(obj)
    if surrogate:
      xs.append(features(table, arch))
      models = _fit_surrogates(np.concatenate(xs), np.concatenate(ys))
    if pop_genome is None:
      pop_genome, pop_obj = cand, obj
    else:
      allg = np.concatenate([pop_genome, cand])
      allo = np.concatenate([pop_obj, obj])
      rank = nondominated_ranks(allo)
      crowd = crowding_distance(allo, rank)
      order = np.lexsort((np.arange(allo.shape[0]), -crowd, rank))
      keep = np.sort(order[:population])
      pop_genome, pop_obj = allg[keep], allo[keep]
    gens_run += 1
    checkpoint(g + 1)

  checkpoint(generations, force=True)
  seconds = time.perf_counter() - t0
  n_retries = base_retries + (policy.n_retries if policy is not None else 0)
  n_demotions = base_demotions \
      + (policy.n_demotions if policy is not None else 0)
  all_obj = np.concatenate(ys) if ys else np.zeros((0, len(objectives)))
  meta = {"seconds": seconds, "workers": 1.0,
          "n_chunks": float(gens_run),
          "rows_transferred": float(offset),
          "rows_per_sec": offset / max(seconds, 1e-12),
          "evaluations": float(offset),
          "generations": float(gens_run),
          "population": float(population),
          "surrogate": float(bool(surrogate)),
          "n_retries": float(n_retries),
          "n_demotions": float(n_demotions),
          "n_resumed_chunks": float(n_resumed)}
  if all_obj.shape[0]:
    front, ref = _screen_front(all_obj)
    meta["hypervolume"] = hypervolume(
        all_obj[pareto_mask(all_obj)], ref)
    for j, col in enumerate(objectives):
      meta[f"hv_ref_{col}"] = float(ref[j])
  return StreamResult(
      results={name: r.result() for name, r in reducers.items()},
      n_rows=offset, seconds=seconds, meta=meta)
