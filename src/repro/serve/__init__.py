"""Serving substrate: batched engine with quantized KV caches."""
