"""Batched serving engine: prefill + decode with a fixed-slot scheduler.

A deliberately production-shaped (if compact) continuous-batching engine:
  * fixed decode slot pool (the compiled decode_step shape never changes)
  * per-request state (prompt, generated, remaining budget)
  * prompt prefill runs right-padded at a fixed bucket length
  * KV caches optionally int8-quantized (cfg.kv_quant) — QUIDAM's
    precision axis applied to the decode memory roofline.
  * per-request deadlines (the exploration service's
    :class:`~repro.explore.service.Deadline` type): expired queued
    requests are evicted before prefill, expired active requests release
    their slot mid-decode — an overloaded engine sheds late work instead
    of serving answers nobody is waiting for.

The engine is single-host here; the mesh-parallel path shards the slot
batch over ("pod","data") and heads over "model" exactly like training.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.explore.service import Deadline
from repro.models.model import Model


@dataclasses.dataclass
class Request:
  uid: int
  prompt: np.ndarray            # (len,) int32
  max_new_tokens: int
  generated: List[int] = dataclasses.field(default_factory=list)
  done: bool = False
  submitted_at: float = 0.0
  finished_at: float = 0.0
  deadline: Optional[Deadline] = None
  expired: bool = False


@dataclasses.dataclass
class EngineConfig:
  batch_slots: int = 8
  max_len: int = 512
  prompt_bucket: int = 128
  greedy: bool = True


class ServeEngine:
  """Synchronous continuous-batching engine over a Model."""

  def __init__(self, model: Model, params, ecfg: EngineConfig):
    self.model = model
    self.params = params
    self.ecfg = ecfg
    self.queue: List[Request] = []
    self.active: List[Optional[Request]] = [None] * ecfg.batch_slots
    self.caches: List[Any] = [None] * ecfg.batch_slots
    self._decode = jax.jit(model.decode_step)
    self._prefill = jax.jit(
        lambda p, b: model.prefill(p, b, ecfg.max_len))
    self._uid = 0
    self.n_evicted = 0

  # -- client API ---------------------------------------------------------
  def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
             deadline: Optional[Union[Deadline, float]] = None) -> int:
    """Enqueue a request; ``deadline`` (a Deadline, or seconds from now)
    bounds its total queue + decode time."""
    if deadline is not None and not isinstance(deadline, Deadline):
      deadline = Deadline(float(deadline))
    self._uid += 1
    self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                              max_new_tokens, submitted_at=time.time(),
                              deadline=deadline))
    return self._uid

  def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
    """Generated tokens per finished uid; evicted requests appear with
    whatever partial generation they had (``request.expired`` marks
    them — an eviction is an answer, not a hang)."""
    out: Dict[int, List[int]] = {}
    for _ in range(max_steps):
      if not self.queue and all(r is None for r in self.active):
        break
      finished = self._admit() + self._step()
      for r in finished:
        out[r.uid] = list(r.generated)
    return out

  # -- internals ----------------------------------------------------------
  def _evict(self, req: Request) -> Request:
    req.done = True
    req.expired = True
    req.finished_at = time.time()
    self.n_evicted += 1
    return req

  def _admit(self) -> List[Request]:
    evicted = []
    for slot in range(self.ecfg.batch_slots):
      if self.active[slot] is not None or not self.queue:
        continue
      req = self.queue.pop(0)
      if req.deadline is not None and req.deadline.expired():
        # expired while queued: never spend prefill on it
        evicted.append(self._evict(req))
        continue
      bucket = self.ecfg.prompt_bucket
      prompt = req.prompt[-bucket:]
      pad = bucket - len(prompt)
      # left-pad with the first token (prefill consumes the full bucket;
      # positions are absolute so generation continues at bucket length)
      padded = np.concatenate(
          [np.full(pad, prompt[0] if len(prompt) else 0, np.int32), prompt])
      batch = {"tokens": jnp.asarray(padded[None])}
      logits, cache = self._prefill(self.params, batch)
      first = int(jnp.argmax(logits[0]))
      req.generated.append(first)
      self.active[slot] = req
      self.caches[slot] = cache
    return evicted

  def _step(self) -> List[Request]:
    finished = []
    for slot, req in enumerate(self.active):
      if req is None:
        continue
      if req.deadline is not None and req.deadline.expired():
        # mid-decode expiry: release the slot, keep the partial output
        finished.append(self._evict(req))
        self.active[slot] = None
        self.caches[slot] = None
        continue
      tok = jnp.asarray([req.generated[-1]], jnp.int32)
      logits, cache = self._decode(self.params, tok, self.caches[slot])
      self.caches[slot] = cache
      nxt = int(jnp.argmax(logits[0]))
      req.generated.append(nxt)
      if len(req.generated) >= req.max_new_tokens:
        req.done = True
        req.finished_at = time.time()
        finished.append(req)
        self.active[slot] = None
        self.caches[slot] = None
    return finished
