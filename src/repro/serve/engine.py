"""Batched serving engine: prefill + decode with a fixed-slot scheduler.

A deliberately production-shaped (if compact) continuous-batching engine:
  * fixed decode slot pool (the compiled decode_step shape never changes)
  * per-request state (prompt, generated, remaining budget)
  * prompt prefill runs right-padded at a fixed bucket length
  * KV caches optionally int8-quantized (cfg.kv_quant) — QUIDAM's
    precision axis applied to the decode memory roofline.

The engine is single-host here; the mesh-parallel path shards the slot
batch over ("pod","data") and heads over "model" exactly like training.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
  uid: int
  prompt: np.ndarray            # (len,) int32
  max_new_tokens: int
  generated: List[int] = dataclasses.field(default_factory=list)
  done: bool = False
  submitted_at: float = 0.0
  finished_at: float = 0.0


@dataclasses.dataclass
class EngineConfig:
  batch_slots: int = 8
  max_len: int = 512
  prompt_bucket: int = 128
  greedy: bool = True


class ServeEngine:
  """Synchronous continuous-batching engine over a Model."""

  def __init__(self, model: Model, params, ecfg: EngineConfig):
    self.model = model
    self.params = params
    self.ecfg = ecfg
    self.queue: List[Request] = []
    self.active: List[Optional[Request]] = [None] * ecfg.batch_slots
    self.caches: List[Any] = [None] * ecfg.batch_slots
    self._decode = jax.jit(model.decode_step)
    self._prefill = jax.jit(
        lambda p, b: model.prefill(p, b, ecfg.max_len))
    self._uid = 0

  # -- client API ---------------------------------------------------------
  def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
    self._uid += 1
    self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                              max_new_tokens, submitted_at=time.time()))
    return self._uid

  def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
    out: Dict[int, List[int]] = {}
    for _ in range(max_steps):
      if not self.queue and all(r is None for r in self.active):
        break
      self._admit()
      finished = self._step()
      for r in finished:
        out[r.uid] = list(r.generated)
    return out

  # -- internals ----------------------------------------------------------
  def _admit(self):
    for slot in range(self.ecfg.batch_slots):
      if self.active[slot] is not None or not self.queue:
        continue
      req = self.queue.pop(0)
      bucket = self.ecfg.prompt_bucket
      prompt = req.prompt[-bucket:]
      pad = bucket - len(prompt)
      # left-pad with the first token (prefill consumes the full bucket;
      # positions are absolute so generation continues at bucket length)
      padded = np.concatenate(
          [np.full(pad, prompt[0] if len(prompt) else 0, np.int32), prompt])
      batch = {"tokens": jnp.asarray(padded[None])}
      logits, cache = self._prefill(self.params, batch)
      first = int(jnp.argmax(logits[0]))
      req.generated.append(first)
      self.active[slot] = req
      self.caches[slot] = cache

  def _step(self) -> List[Request]:
    finished = []
    for slot, req in enumerate(self.active):
      if req is None:
        continue
      tok = jnp.asarray([req.generated[-1]], jnp.int32)
      logits, cache = self._decode(self.params, tok, self.caches[slot])
      self.caches[slot] = cache
      nxt = int(jnp.argmax(logits[0]))
      req.generated.append(nxt)
      if len(req.generated) >= req.max_new_tokens:
        req.done = True
        req.finished_at = time.time()
        finished.append(req)
        self.active[slot] = None
        self.caches[slot] = None
    return finished
