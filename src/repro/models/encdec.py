"""Encoder-decoder transformer (Whisper backbone).

The conv audio frontend is a STUB per the assignment: inputs are
precomputed frame embeddings (B, T_frames, d) from ``input_specs``.
Encoder = bidirectional attention blocks; decoder = causal self-attn +
cross-attn + MLP blocks, both scanned.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (cross_attention, decode_attention,
                                    flash_attention)
from repro.models.common import (apply_norm, dense_init, embed_init,
                                 make_norm_params, model_dtype,
                                 sinusoidal_positions)
from repro.models.ffn import apply_mlp, init_mlp
from repro.models.transformer import (_cache_write_token, _project_qkv,
                                      chunked_xent, init_attn,
                                      init_attn_cache, lm_head_weight,
                                      prefill_attn_cache)
from repro.parallel.sharding import constrain

Params = Dict[str, Any]


def init_params(cfg: ModelConfig, key) -> Params:
  ks = jax.random.split(key, 6)

  def enc_block(bkey):
    k1, k2 = jax.random.split(bkey)
    return {"attn_norm": make_norm_params(cfg), "attn": init_attn(k1, cfg),
            "ffn_norm": make_norm_params(cfg),
            "ffn": init_mlp(k2, cfg, cfg.d_ff)}

  def dec_block(bkey):
    k1, k2, k3 = jax.random.split(bkey, 3)
    return {"self_norm": make_norm_params(cfg), "self": init_attn(k1, cfg),
            "cross_norm": make_norm_params(cfg), "cross": init_attn(k2, cfg),
            "ffn_norm": make_norm_params(cfg),
            "ffn": init_mlp(k3, cfg, cfg.d_ff)}

  return {
      "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model),
      "pos_embed": embed_init(ks[1], cfg.max_position, cfg.d_model),
      "enc_blocks": jax.vmap(enc_block)(
          jax.random.split(ks[2], cfg.n_encoder_layers)),
      "enc_norm": make_norm_params(cfg),
      "dec_blocks": jax.vmap(dec_block)(
          jax.random.split(ks[3], cfg.n_layers)),
      "final_norm": make_norm_params(cfg),
      "lm_head": dense_init(ks[4], cfg.d_model, cfg.padded_vocab),
  }


def encode(params: Params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
  """frames (B, T, d) -> encoder states (B, T, d)."""
  x = frames.astype(model_dtype(cfg))
  pe = sinusoidal_positions(x.shape[1], cfg.d_model)
  x = x + pe.astype(x.dtype)
  x = constrain(x, "dp", None, None)

  def body(x, p):
    h = apply_norm(p["attn_norm"], x, cfg)
    q, k, v = _project_qkv(p["attn"], h, cfg)
    out = flash_attention(q, k, v, causal=False,
                          chunk_q=cfg.attn_chunk, chunk_k=cfg.attn_chunk)
    out = out.reshape(*h.shape[:-1], -1)
    x = x + jnp.einsum("...e,ed->...d", out, p["attn"]["wo"].astype(x.dtype))
    h = apply_norm(p["ffn_norm"], x, cfg)
    x = x + apply_mlp(p["ffn"], h, cfg)
    return x, None

  x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_blocks"])
  return apply_norm(params["enc_norm"], x, cfg)


def _decoder(params: Params, tokens: jax.Array, enc: jax.Array,
             cfg: ModelConfig) -> jax.Array:
  b, s = tokens.shape
  x = jnp.take(params["embed"], tokens, axis=0).astype(model_dtype(cfg))
  x = x + jnp.take(params["pos_embed"], jnp.arange(s), axis=0
                   ).astype(x.dtype)
  x = constrain(x, "dp", None, None)

  def body(x, p):
    h = apply_norm(p["self_norm"], x, cfg)
    q, k, v = _project_qkv(p["self"], h, cfg)
    out = flash_attention(q, k, v, causal=True,
                          chunk_q=cfg.attn_chunk, chunk_k=cfg.attn_chunk)
    out = out.reshape(*h.shape[:-1], -1)
    x = x + jnp.einsum("...e,ed->...d", out, p["self"]["wo"].astype(x.dtype))
    h = apply_norm(p["cross_norm"], x, cfg)
    q, _, _ = _project_qkv(p["cross"], h, cfg)
    _, ek, ev = _project_qkv(p["cross"], enc, cfg)
    out = cross_attention(q, ek, ev, chunk_q=cfg.attn_chunk,
                          chunk_k=cfg.attn_chunk)
    out = out.reshape(*h.shape[:-1], -1)
    x = x + jnp.einsum("...e,ed->...d", out,
                       p["cross"]["wo"].astype(x.dtype))
    h = apply_norm(p["ffn_norm"], x, cfg)
    x = x + apply_mlp(p["ffn"], h, cfg)
    return x, None

  x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_blocks"])
  return apply_norm(params["final_norm"], x, cfg)


def train_loss(params: Params, batch: Dict[str, jax.Array],
               cfg: ModelConfig, remat: bool = True):
  enc = encode(params, batch["enc_frames"], cfg)
  x = _decoder(params, batch["tokens"], enc, cfg)
  mask = jnp.ones_like(batch["labels"], jnp.float32)
  loss, denom = chunked_xent(params, x, batch["labels"], mask, cfg)
  return loss, {"xent": loss, "aux": jnp.zeros(()), "tokens": denom}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
  def one(_):
    return {
        "self": init_attn_cache(cfg, batch, max_len),
        # cross K/V computed at prefill; stored dense (encoder length)
        "cross_k": jnp.zeros((batch, cfg.n_kv_heads, cfg.encoder_seq,
                              cfg.head_dim), model_dtype(cfg)),
        "cross_v": jnp.zeros((batch, cfg.n_kv_heads, cfg.encoder_seq,
                              cfg.head_dim), model_dtype(cfg)),
    }
  layers = jax.vmap(one)(jnp.arange(cfg.n_layers))
  return {"layers": layers, "length": jnp.zeros((), jnp.int32)}


def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            max_len: int):
  """Encode audio + consume the prompt tokens; build decoder caches."""
  enc = encode(params, batch["enc_frames"], cfg)
  tokens = batch["tokens"]
  b, s = tokens.shape
  x = jnp.take(params["embed"], tokens, axis=0).astype(model_dtype(cfg))
  x = x + jnp.take(params["pos_embed"], jnp.arange(s), axis=0
                   ).astype(x.dtype)

  def body(x, p):
    cache = {}
    h = apply_norm(p["self_norm"], x, cfg)
    q, k, v = _project_qkv(p["self"], h, cfg)
    out = flash_attention(q, k, v, causal=True, chunk_q=cfg.attn_chunk,
                          chunk_k=cfg.attn_chunk).reshape(b, s, -1)
    x = x + jnp.einsum("...e,ed->...d", out, p["self"]["wo"].astype(x.dtype))
    cache["self"] = prefill_attn_cache(cfg, k, v, max_len)
    h = apply_norm(p["cross_norm"], x, cfg)
    q, _, _ = _project_qkv(p["cross"], h, cfg)
    _, ek, ev = _project_qkv(p["cross"], enc, cfg)
    out = cross_attention(q, ek, ev, chunk_q=cfg.attn_chunk,
                          chunk_k=cfg.attn_chunk).reshape(b, s, -1)
    x = x + jnp.einsum("...e,ed->...d", out,
                       p["cross"]["wo"].astype(x.dtype))
    cache["cross_k"] = jnp.moveaxis(ek, 2, 1).astype(model_dtype(cfg))
    cache["cross_v"] = jnp.moveaxis(ev, 2, 1).astype(model_dtype(cfg))
    h = apply_norm(p["ffn_norm"], x, cfg)
    x = x + apply_mlp(p["ffn"], h, cfg)
    return x, cache

  x, layer_caches = jax.lax.scan(body, x, params["dec_blocks"])
  x = apply_norm(params["final_norm"], x, cfg)
  logits = jnp.einsum("bd,dv->bv", x[:, -1],
                      lm_head_weight(params, cfg).astype(x.dtype))
  return logits[:, :cfg.vocab_size], {"layers": layer_caches,
                                      "length": jnp.asarray(s, jnp.int32)}


def decode_step(params: Params, tokens: jax.Array, cache: Params,
                cfg: ModelConfig):
  """tokens (B,) against a self-attn cache + fixed cross K/V."""
  from repro.models.transformer import apply_attn_decode
  length = cache["length"]
  b = tokens.shape[0]
  x = jnp.take(params["embed"], tokens, axis=0).astype(model_dtype(cfg))
  x = x + params["pos_embed"][length].astype(x.dtype)[None]
  enc_len = jnp.full((b,), cfg.encoder_seq, jnp.int32)

  def body(x, inp):
    p, c = inp
    h = apply_norm(p["self_norm"], x, cfg)
    out, self_c = apply_attn_decode(p["self"], h, c["self"], length, cfg)
    x = x + out
    h = apply_norm(p["cross_norm"], x, cfg)
    q, _, _ = _project_qkv(p["cross"], h, cfg)
    out = decode_attention(q, c["cross_k"], c["cross_v"], enc_len)
    out = out.reshape(b, -1)
    x = x + jnp.einsum("be,ed->bd", out, p["cross"]["wo"].astype(x.dtype))
    h = apply_norm(p["ffn_norm"], x, cfg)
    x = x + apply_mlp(p["ffn"], h, cfg)
    return x, {"self": self_c, "cross_k": c["cross_k"],
               "cross_v": c["cross_v"]}

  x, new_layers = jax.lax.scan(body, x, (params["dec_blocks"],
                                         cache["layers"]))
  x = apply_norm(params["final_norm"], x, cfg)
  logits = jnp.einsum("bd,dv->bv", x,
                      lm_head_weight(params, cfg).astype(x.dtype))
  return logits[:, :cfg.vocab_size], {"layers": new_layers,
                                      "length": length + 1}
