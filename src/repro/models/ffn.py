"""Feed-forward blocks: dense MLP variants and capacity-based MoE.

MoE uses the GShard/Switch capacity dispatch: tokens are grouped, each
group routes top-k with a capacity factor, and dispatch/combine are
one-hot einsums — fully differentiable, SPMD-friendly (dispatch happens
within each data shard; expert weights are TP-sharded on d_ff).  The
dispatch-einsum overhead is visible in the roofline's useful-flops ratio
and is a documented hillclimb axis (scatter-based dispatch, see
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, mlp_act


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int) -> Dict:
  d = cfg.d_model
  ks = jax.random.split(key, 3)
  if cfg.mlp_variant == "swiglu":
    return {"wi": dense_init(ks[0], d, d_ff),
            "wg": dense_init(ks[1], d, d_ff),
            "wo": dense_init(ks[2], d_ff, d, scale=0.5)}
  return {"wi": dense_init(ks[0], d, d_ff),
          "wo": dense_init(ks[2], d_ff, d, scale=0.5)}


def apply_mlp(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
  dt = x.dtype
  h = jnp.einsum("...d,df->...f", x, params["wi"].astype(dt))
  if cfg.mlp_variant == "swiglu":
    g = jnp.einsum("...d,df->...f", x, params["wg"].astype(dt))
    h = jax.nn.silu(g) * h
  else:
    h = mlp_act(h, cfg.mlp_variant)
  return jnp.einsum("...f,fd->...d", h, params["wo"].astype(dt))


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig) -> Dict:
  d, e, ff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
  ks = jax.random.split(key, 5)
  gated = cfg.mlp_variant == "swiglu"
  p = {
      "router": dense_init(ks[0], d, e, scale=0.1),
      "wi": jax.vmap(lambda k: dense_init(k, d, ff))(
          jax.random.split(ks[1], e)),
      "wo": jax.vmap(lambda k: dense_init(k, ff, d, scale=0.5))(
          jax.random.split(ks[2], e)),
  }
  if gated:
    p["wg"] = jax.vmap(lambda k: dense_init(k, d, ff))(
        jax.random.split(ks[3], e))
  if cfg.n_shared_experts:
    p["shared"] = init_mlp(ks[4], cfg, cfg.d_ff_shared)
  return p


def _capacity(group: int, k: int, e: int, factor: float) -> int:
  return max(int(group * k * factor / e), 1)


def route_topk(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
  """(g, E) router logits -> (gates (g, E) with only top-k nonzero,
  topk idx (g, k)).  Gates renormalized over the selected experts."""
  probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
  top_vals, top_idx = jax.lax.top_k(probs, k)
  top_vals = top_vals / jnp.maximum(
      jnp.sum(top_vals, axis=-1, keepdims=True), 1e-9)
  gates = jnp.zeros_like(probs)
  gates = jax.vmap(lambda g, i, v: g.at[i].set(v))(gates, top_idx, top_vals)
  return gates, top_idx


def _dispatch_combine(gates: jax.Array, top_idx: jax.Array, e: int,
                      cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
  """GShard position assignment within one group.

  gates: (g, E); top_idx: (g, k). Returns (dispatch (g, E, cap) bool-ish,
  combine (g, E, cap) f32, load (E,) fraction routed per expert).
  """
  g, _ = gates.shape
  k = top_idx.shape[1]
  dispatch = jnp.zeros((g, e, cap), jnp.float32)
  combine = jnp.zeros((g, e, cap), jnp.float32)
  counts = jnp.zeros((e,), jnp.int32)
  for rank in range(k):
    idx = top_idx[:, rank]                       # (g,)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)          # (g, E)
    pos = jnp.cumsum(onehot, axis=0) - 1 + counts[None, :]    # (g, E)
    counts = counts + jnp.sum(onehot, axis=0)
    my_pos = jnp.sum(pos * onehot, axis=1)                    # (g,)
    keep = my_pos < cap
    dis = (jax.nn.one_hot(idx, e, dtype=jnp.float32)
           * keep[:, None])[..., None] \
        * jax.nn.one_hot(my_pos, cap, dtype=jnp.float32)[:, None, :]
    dispatch = dispatch + dis
    gate_r = jnp.take_along_axis(gates, idx[:, None], axis=1)[:, 0]
    combine = combine + dis * gate_r[:, None, None]
  load = jnp.mean(jnp.sum(dispatch, axis=(0, 2)) / max(g, 1))
  return dispatch, combine, load


def apply_moe_dense(params: Dict, x: jax.Array, cfg: ModelConfig
                    ) -> Tuple[jax.Array, jax.Array]:
  """Exact (capacity-free) MoE for single-token decode: compute every
  expert, combine with renormalized top-k gates.  Decode is weight-
  streaming-bound, so the extra FLOPs are roofline-negligible while the
  result matches the router exactly."""
  b, d = x.shape[0], x.shape[-1]
  dt = x.dtype
  flat = x.reshape(-1, d)
  logits = jnp.einsum("td,de->te", flat, params["router"].astype(dt))
  gates, _ = route_topk(logits, cfg.n_experts_active)      # (t, E)
  h = jnp.einsum("td,edf->tef", flat, params["wi"].astype(dt))
  if cfg.mlp_variant == "swiglu":
    g = jnp.einsum("td,edf->tef", flat, params["wg"].astype(dt))
    h = jax.nn.silu(g) * h
  else:
    h = mlp_act(h, cfg.mlp_variant)
  eo = jnp.einsum("tef,efd->ted", h, params["wo"].astype(dt))
  out = jnp.einsum("te,ted->td", gates.astype(dt), eo).reshape(x.shape)
  if cfg.n_shared_experts:
    out = out + apply_mlp(params["shared"], x, cfg)
  return out, jnp.zeros((), jnp.float32)


def apply_moe(params: Dict, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
  """x: (B, S, d) -> (out, aux_loss). Capacity-grouped top-k MoE."""
  if x.ndim == 3 and x.shape[1] == 1:
    return apply_moe_dense(params, x, cfg)
  b, s, d = x.shape
  dt = x.dtype
  tokens = x.reshape(b * s, d)
  gsz = min(cfg.moe_group_size, b * s)
  n_groups = (b * s) // gsz
  assert n_groups * gsz == b * s, (b, s, gsz)
  xg = tokens.reshape(n_groups, gsz, d)

  logits = jnp.einsum("gtd,de->gte", xg, params["router"].astype(dt))
  gates, top_idx = jax.vmap(lambda lg: route_topk(lg, cfg.n_experts_active)
                            )(logits)
  cap = _capacity(gsz, cfg.n_experts_active, cfg.n_experts,
                  cfg.capacity_factor)
  dispatch, combine, _ = jax.vmap(
      lambda gt, ti: _dispatch_combine(gt, ti, cfg.n_experts, cap)
  )(gates, top_idx)

  # aux load-balancing loss (Switch): E * sum_e f_e * p_e
  me = jnp.mean(gates, axis=(0, 1))                       # (E,)
  ce = jnp.mean(jnp.sum(dispatch, axis=-1), axis=(0, 1))  # (E,)
  aux = cfg.n_experts * jnp.sum(me * ce)

  expert_in = jnp.einsum("gtec,gtd->gecd", dispatch.astype(dt), xg)
  h = jnp.einsum("gecd,edf->gecf", expert_in, params["wi"].astype(dt))
  if cfg.mlp_variant == "swiglu":
    gate = jnp.einsum("gecd,edf->gecf", expert_in, params["wg"].astype(dt))
    h = jax.nn.silu(gate) * h
  else:
    h = mlp_act(h, cfg.mlp_variant)
  expert_out = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(dt))
  out = jnp.einsum("gtec,gecd->gtd", combine.astype(dt), expert_out)
  out = out.reshape(b, s, d)

  if cfg.n_shared_experts:
    out = out + apply_mlp(params["shared"], x, cfg)
  return out, aux
