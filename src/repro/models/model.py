"""Unified model facade: build_model(cfg) -> Model with a stable API.

  init(key)                      -> params
  train_loss(params, batch)      -> (loss, metrics)
  init_cache(batch, max_len)     -> decode cache
  prefill(params, batch, max_len)-> (last logits, cache)
  decode_step(params, tok, cache)-> (logits, cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer


@dataclasses.dataclass(frozen=True)
class Model:
  cfg: ModelConfig
  init: Callable
  train_loss: Callable
  init_cache: Callable
  prefill: Callable
  decode_step: Callable


def build_model(cfg: ModelConfig) -> Model:
  if cfg.family == "encdec":
    return Model(
        cfg=cfg,
        init=lambda key: encdec.init_params(cfg, key),
        train_loss=lambda params, batch, remat=True: encdec.train_loss(
            params, batch, cfg, remat=remat),
        init_cache=lambda batch, max_len: encdec.init_cache(
            cfg, batch, max_len),
        prefill=lambda params, batch, max_len: encdec.prefill(
            params, batch, cfg, max_len),
        decode_step=lambda params, tok, cache: encdec.decode_step(
            params, tok, cache, cfg),
    )

  def _prefill(params, batch, max_len):
    tokens = batch["tokens"] if isinstance(batch, dict) else batch
    return transformer.prefill(params, tokens, cfg, max_len)

  return Model(
      cfg=cfg,
      init=lambda key: transformer.init_params(cfg, key),
      train_loss=lambda params, batch, remat=True: transformer.train_loss(
          params, batch, cfg, remat=remat),
      init_cache=lambda batch, max_len: transformer.init_cache(
          cfg, batch, max_len),
      prefill=_prefill,
      decode_step=lambda params, tok, cache: transformer.decode_step(
          params, tok, cache, cfg),
  )
