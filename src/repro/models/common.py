"""Shared model components: norms, positions, initializers, projections."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def model_dtype(cfg: ModelConfig):
  return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# initializers (params always stored fp32; cast at use)
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, scale: float = 1.0) -> jax.Array:
  std = scale / math.sqrt(d_in)
  return jax.random.normal(key, (d_in, d_out), jnp.float32) * std


def embed_init(key, vocab: int, d: int) -> jax.Array:
  return jax.random.normal(key, (vocab, d), jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def make_norm_params(cfg: ModelConfig, d: Optional[int] = None):
  d = d or cfg.d_model
  if cfg.norm == "rmsnorm":
    return {"scale": jnp.ones((d,), jnp.float32)}
  if cfg.norm == "layernorm":
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}
  if cfg.norm == "layernorm_np":  # olmo: non-parametric LN
    return {}
  raise ValueError(cfg.norm)


def apply_norm(params, x: jax.Array, cfg: ModelConfig,
               eps: float = 1e-5) -> jax.Array:
  xf = x.astype(jnp.float32)
  if cfg.norm == "rmsnorm":
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
  else:
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if cfg.norm == "layernorm":
      y = y * params["scale"] + params["bias"]
  return y.astype(x.dtype)


def rms_head_norm(x: jax.Array, scale: jax.Array,
                  eps: float = 1e-6) -> jax.Array:
  """RMSNorm over the head dim (qwen3 qk-norm; rwkv wkv-out norm)."""
  xf = x.astype(jnp.float32)
  var = jnp.mean(xf * xf, axis=-1, keepdims=True)
  return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
  """x: (..., S, H, D) or (..., H, D) with matching positions (..., S)/(...)."""
  d = x.shape[-1]
  half = d // 2
  freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
  ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
  cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
  sin = jnp.sin(ang)[..., None, :]
  x1, x2 = x[..., :half], x[..., half:]
  out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
  return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
  pos = jnp.arange(n, dtype=jnp.float32)[:, None]
  div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                * (-math.log(10000.0) / d))
  pe = jnp.zeros((n, d), jnp.float32)
  pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
  pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
  return pe


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def mlp_act(x: jax.Array, variant: str) -> jax.Array:
  if variant == "gelu":
    return jax.nn.gelu(x)
  if variant == "relu2":
    r = jax.nn.relu(x)
    return r * r
  if variant == "swiglu":  # applied to the gate half only; see ffn.py
    return jax.nn.silu(x)
  raise ValueError(variant)
