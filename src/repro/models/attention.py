"""Attention: GQA with chunked (flash-style) pure-JAX training path and a
cache-based decode path (optionally int8-quantized KV, matching the
quant_decode_attn Pallas kernel's math).

The training/prefill path never materializes the (S, S) score matrix: an
outer scan over query chunks and an inner scan over key chunks carries
online-softmax statistics; a `lax.cond` skips fully-masked key chunks, so
causal attention does ~half the work and sliding-window attention only
touches the window diagonal band.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

NEG_INF = -1e30


def _attend_block(q, k, v, m, l, acc, mask):
  """One (q_chunk x k_chunk) online-softmax update.

  q: (B, H, Cq, D); k/v: (B, H, Ck, D); m/l: (B, H, Cq, 1);
  acc: (B, H, Cq, D); mask: (Cq, Ck) bool (True = attend) or None.
  """
  s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                 preferred_element_type=jnp.float32)
  if mask is not None:
    s = jnp.where(mask[None, None], s, NEG_INF)
  m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
  p = jnp.exp(s - m_new)
  alpha = jnp.exp(m - m_new)
  l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
  acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd",
                                 p.astype(v.dtype), v,
                                 preferred_element_type=jnp.float32)
  return m_new, l, acc


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    chunk_q: int = 512, chunk_k: int = 512,
                    sm_scale: Optional[float] = None) -> jax.Array:
  """q (B, Sq, H, D); k/v (B, Sk, Hkv, D) -> (B, Sq, H, D).

  GQA: H % Hkv == 0, kv heads repeated. Sliding window (Mistral-style):
  token i attends to [i - window + 1, i].
  """
  b, sq, h, d = q.shape
  _, sk, hkv, _ = k.shape
  assert h % hkv == 0
  if sm_scale is None:
    sm_scale = 1.0 / (d ** 0.5)
  g = h // hkv
  if g > 1:
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)

  # pad sequences to chunk multiples
  cq = min(chunk_q, sq)
  ck = min(chunk_k, sk)
  pad_q = (-sq) % cq
  pad_k = (-sk) % ck
  qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
  kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
  vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
  nq, nk = qp.shape[1] // cq, kp.shape[1] // ck

  # (B, H, nq, Cq, D) etc.
  qb = jnp.moveaxis(qp.reshape(b, nq, cq, h, d), 3, 1) * sm_scale
  kb = jnp.moveaxis(kp.reshape(b, nk, ck, h, d), 3, 1)
  vb = jnp.moveaxis(vp.reshape(b, nk, ck, h, d), 3, 1)

  q_pos = jnp.arange(nq * cq).reshape(nq, cq)
  k_pos = jnp.arange(nk * ck).reshape(nk, ck)

  def process_q_chunk(qi, q_chunk):
    # q_chunk: (B, H, Cq, D)
    m0 = jnp.full((b, h, cq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, cq, 1), jnp.float32)
    a0 = jnp.zeros((b, h, cq, d), jnp.float32)

    # checkpoint the block update: without this, the scan VJP saves the
    # (B, H, Cq, Ck) probability tensors of EVERY (q, k) block pair for the
    # backward pass — the dominant term of the dry-run's temp_bytes
    # (see EXPERIMENTS.md §Perf, jamba train_4k iteration 1)
    @jax.checkpoint
    def kv_step(carry, inp):
      m, l, acc = carry
      ki, k_chunk, v_chunk = inp
      qpos = q_pos[qi]                       # (Cq,)
      kpos = k_pos[ki]                       # (Ck,)
      mask = jnp.ones((cq, ck), bool)
      if causal:
        mask &= qpos[:, None] >= kpos[None, :]
      if window:
        mask &= kpos[None, :] > qpos[:, None] - window
      mask &= (kpos < sk)[None, :]           # padding
      mask &= (qpos < sq)[:, None]

      def do(_):
        return _attend_block(q_chunk, k_chunk, v_chunk, m, l, acc, mask)

      def skip(_):
        return m, l, acc

      any_live = jnp.any(mask)
      m2, l2, a2 = jax.lax.cond(any_live, do, skip, None)
      return (m2, l2, a2), None

    (m, l, acc), _ = jax.lax.scan(
        kv_step, (m0, l0, a0),
        (jnp.arange(nk), jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0)))
    return acc / jnp.maximum(l, 1e-30)

  # keep the batch dim sharded in the stacked map operand (the chunk-index
  # dim must stay replicated or SPMD re-gathers per iteration)
  q_stacked = constrain(jnp.moveaxis(qb, 2, 0), None, "dp", None, None, None)
  outs = jax.lax.map(lambda args: process_q_chunk(*args),
                     (jnp.arange(nq), q_stacked))
  # outs: (nq, B, H, Cq, D) -> (B, Sq, H, D)
  out = jnp.moveaxis(outs, 0, 2).reshape(b, h, nq * cq, d)
  out = jnp.moveaxis(out, 1, 2)[:, :sq]
  return out.astype(q.dtype)


def cross_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    chunk_q: int = 512, chunk_k: int = 512) -> jax.Array:
  return flash_attention(q, k, v, causal=False, window=0,
                         chunk_q=chunk_q, chunk_k=chunk_k)


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array,
                     k_scale: Optional[jax.Array] = None,
                     v_scale: Optional[jax.Array] = None,
                     ring: bool = False) -> jax.Array:
  """Single-token attention over a cache.

  q: (B, H, D); caches: (B, Hkv, S, D) (int8 codes when scales given,
  with per-(B, Hkv, S) scales — the quant_decode_attn kernel's layout).
  length: (B,) int32 tokens written so far. ring=True means the cache is a
  sliding-window ring buffer (all slots valid once length >= S).
  """
  b, h, d = q.shape
  _, hkv, s, _ = k_cache.shape
  g = h // hkv
  k = k_cache
  v = v_cache
  if k_scale is not None:
    k = k.astype(jnp.float32) * k_scale[..., None]
    v = v.astype(jnp.float32) * v_scale[..., None]
  qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
  scores = jnp.einsum("bhgd,bhsd->bhgs", qg, k.astype(jnp.float32))
  scores *= 1.0 / (d ** 0.5)
  pos = jnp.arange(s)[None, None, None, :]
  if ring:
    valid = pos < jnp.minimum(length, s)[:, None, None, None]
  else:
    valid = pos < length[:, None, None, None]
  scores = jnp.where(valid, scores, NEG_INF)
  p = jax.nn.softmax(scores, axis=-1)
  out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
  return out.reshape(b, h, d).astype(q.dtype)
