"""State-space blocks: Mamba (Jamba's selective SSM) and RWKV-6.

Both use chunked formulations so the sequence dimension is processed in
MXU-friendly blocks with a small carried state — the TPU-native adaptation
of the CUDA selective-scan kernels (see DESIGN.md):

  Mamba: outer lax.scan over chunks; within a chunk an associative scan
  solves the diagonal linear recurrence (log-depth, bounded memory).
  RWKV6: the same stable log-decay chunk math as kernels/rwkv6_scan (the
  Pallas kernel is the TPU compute path; this pure-jnp version is the
  SPMD-partitionable model path and doubles as its oracle).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init


# ---------------------------------------------------------------------------
# Mamba (selective SSM, Jamba flavour)
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig) -> Dict:
  d, di, ds = cfg.d_model, cfg.d_inner, cfg.mamba_d_state
  dt_rank = max(d // 16, 1)
  ks = jax.random.split(key, 8)
  return {
      "in_proj": dense_init(ks[0], d, 2 * di),
      "conv_w": jax.random.normal(ks[1], (cfg.mamba_d_conv, di),
                                  jnp.float32) * 0.2,
      "conv_b": jnp.zeros((di,), jnp.float32),
      "x_proj": dense_init(ks[2], di, dt_rank + 2 * ds),
      "dt_proj": dense_init(ks[3], dt_rank, di),
      "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of U(1e-3, 1e-1)
          jax.random.uniform(ks[4], (di,), jnp.float32, 1e-3, 1e-1))),
      "a_log": jnp.log(jnp.broadcast_to(
          jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, ds)) + 0.0),
      "d_skip": jnp.ones((di,), jnp.float32),
      "out_proj": dense_init(ks[5], di, d, scale=0.5),
      "norm": jnp.ones((di,), jnp.float32),  # jamba: RMSNorm before out_proj
  }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array,
                           b: jax.Array) -> jax.Array:
  """x (B, L, C), w (K, C): causal depthwise conv along L."""
  k = w.shape[0]
  xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
  out = jnp.zeros_like(x)
  for i in range(k):
    out = out + xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
  return out + b[None, None, :]


def _ssm_chunk_scan(u, dt, bmat, cmat, a, chunk: int):
  """Diagonal selective-SSM over (B, L, di) with state (B, di, N).

  Outer scan over L/chunk chunks carrying h; within a chunk an associative
  scan solves h_t = dA_t * h_{t-1} + dBu_t (elementwise in (di, N)).
  Returns y (B, L, di).
  """
  b, l, di = u.shape
  n = bmat.shape[-1]
  nchunks = l // chunk
  uc = u.reshape(b, nchunks, chunk, di)
  dtc = dt.reshape(b, nchunks, chunk, di)
  bc = bmat.reshape(b, nchunks, chunk, n)
  cc = cmat.reshape(b, nchunks, chunk, n)

  # checkpoint: the per-chunk (B, C, di, N) discretization tensors would
  # otherwise be saved for backward for EVERY chunk of EVERY layer in a
  # rematted block (§Perf jamba iteration 3: ~400 GB of temp); with the
  # checkpoint only the (B, di, N) chunk carries survive.
  @jax.checkpoint
  def per_chunk(h, inp):
    u_, dt_, b_, c_ = inp                     # (B, C, di) / (B, C, N)
    da = jnp.exp(dt_[..., None] * a[None, None])          # (B, C, di, N)
    dbu = (dt_ * u_)[..., None] * b_[:, :, None, :]       # (B, C, di, N)
    # prepend the carried state as a virtual step: h_0 = 1 * h + 0
    da_full = jnp.concatenate(
        [jnp.ones((b, 1, di, n), da.dtype), da], axis=1)
    dbu_full = jnp.concatenate([h[:, None], dbu], axis=1)

    def combine(x, y):
      a1, b1 = x
      a2, b2 = y
      return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (da_full, dbu_full), axis=1)
    hs = hs[:, 1:]                                        # (B, C, di, N)
    y = jnp.einsum("bcdn,bcn->bcd", hs, c_)
    return hs[:, -1], y

  xs = (jnp.moveaxis(uc, 1, 0), jnp.moveaxis(dtc, 1, 0),
        jnp.moveaxis(bc, 1, 0), jnp.moveaxis(cc, 1, 0))
  h0 = jnp.zeros((b, di, n), jnp.float32)
  _, ys = jax.lax.scan(per_chunk, h0, xs)
  return jnp.moveaxis(ys, 0, 1).reshape(b, l, di)


def apply_mamba(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
  """x (B, L, d) -> (B, L, d). Training/prefill path."""
  b, l, d = x.shape
  dt_rank = max(d // 16, 1)
  di, ds = cfg.d_inner, cfg.mamba_d_state
  dtt = x.dtype
  xz = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(dtt))
  xs, z = jnp.split(xz, 2, axis=-1)
  xs = _causal_depthwise_conv(xs, params["conv_w"].astype(dtt),
                              params["conv_b"].astype(dtt))
  xs = jax.nn.silu(xs)
  proj = jnp.einsum("bld,de->ble", xs, params["x_proj"].astype(dtt))
  dt_in, bmat, cmat = jnp.split(
      proj, [dt_rank, dt_rank + ds], axis=-1)
  dt = jax.nn.softplus(
      jnp.einsum("blr,rd->bld", dt_in, params["dt_proj"].astype(dtt))
      .astype(jnp.float32) + params["dt_bias"][None, None])
  a = -jnp.exp(params["a_log"])
  pad = (-l) % cfg.ssm_chunk
  if pad:
    xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
    dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    b_p = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
    c_p = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
  else:
    xs_p, dt_p, b_p, c_p = xs, dt, bmat, cmat
  y = _ssm_chunk_scan(xs_p.astype(jnp.float32), dt_p,
                      b_p.astype(jnp.float32), c_p.astype(jnp.float32),
                      a, cfg.ssm_chunk)[:, :l]
  y = y + xs.astype(jnp.float32) * params["d_skip"][None, None]
  # jamba: RMSNorm on the ssm output before gating/out projection
  var = jnp.mean(y * y, axis=-1, keepdims=True)
  y = y * jax.lax.rsqrt(var + 1e-6) * params["norm"][None, None]
  y = y.astype(dtt) * jax.nn.silu(z)
  return jnp.einsum("ble,ed->bld", y, params["out_proj"].astype(dtt))


def mamba_decode_step(params: Dict, x: jax.Array, cache: Dict,
                      cfg: ModelConfig) -> Tuple[jax.Array, Dict]:
  """x (B, d) single token; cache: {"h": (B, di, N), "conv": (B, K-1, di)}."""
  b, d = x.shape
  dt_rank = max(d // 16, 1)
  ds = cfg.mamba_d_state
  dtt = x.dtype
  xz = jnp.einsum("bd,de->be", x, params["in_proj"].astype(dtt))
  xs, z = jnp.split(xz, 2, axis=-1)
  # conv over the cached window
  conv_in = jnp.concatenate([cache["conv"], xs[:, None, :]], axis=1)
  w = params["conv_w"].astype(dtt)
  xs = jnp.sum(conv_in * w[None], axis=1) + params["conv_b"].astype(dtt)
  xs = jax.nn.silu(xs)
  proj = jnp.einsum("be,ef->bf", xs, params["x_proj"].astype(dtt))
  dt_in, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
  dt = jax.nn.softplus(
      jnp.einsum("br,rd->bd", dt_in, params["dt_proj"].astype(dtt))
      .astype(jnp.float32) + params["dt_bias"][None])
  a = -jnp.exp(params["a_log"])
  da = jnp.exp(dt[..., None] * a[None])                  # (B, di, N)
  dbu = (dt * xs.astype(jnp.float32))[..., None] * \
      bmat.astype(jnp.float32)[:, None, :]
  h = da * cache["h"] + dbu
  y = jnp.einsum("bdn,bn->bd", h, cmat.astype(jnp.float32))
  y = y + xs.astype(jnp.float32) * params["d_skip"][None]
  var = jnp.mean(y * y, axis=-1, keepdims=True)
  y = y * jax.lax.rsqrt(var + 1e-6) * params["norm"][None]
  y = y.astype(dtt) * jax.nn.silu(z)
  out = jnp.einsum("be,ed->bd", y, params["out_proj"].astype(dtt))
  new_cache = {"h": h, "conv": conv_in[:, 1:, :]}
  return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int) -> Dict:
  return {
      "h": jnp.zeros((batch, cfg.d_inner, cfg.mamba_d_state), jnp.float32),
      "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.d_inner),
                        jnp.bfloat16 if cfg.dtype == "bfloat16"
                        else jnp.float32),
  }


# ---------------------------------------------------------------------------
# RWKV-6 (Finch)
# ---------------------------------------------------------------------------

def init_rwkv(key, cfg: ModelConfig) -> Dict:
  d, dff = cfg.d_model, cfg.d_ff
  h, hd = cfg.n_heads, cfg.head_dim
  dt_rank = max(d // 16, 1)
  ks = jax.random.split(key, 12)
  return {
      # time mix
      "mix": 0.5 * jnp.ones((5, d), jnp.float32),   # r, k, v, g, w lerps
      "wr": dense_init(ks[0], d, h * hd),
      "wk": dense_init(ks[1], d, h * hd),
      "wv": dense_init(ks[2], d, h * hd),
      "wg": dense_init(ks[3], d, h * hd),
      "wo": dense_init(ks[4], h * hd, d, scale=0.5),
      "w0": -6.0 + jax.random.normal(ks[5], (h * hd,), jnp.float32) * 0.3,
      "w_lora_a": dense_init(ks[6], d, dt_rank),
      "w_lora_b": dense_init(ks[7], dt_rank, h * hd, scale=0.1),
      "u": jax.random.normal(ks[8], (h, hd), jnp.float32) * 0.3,
      "ln_x": jnp.ones((h, hd), jnp.float32),       # per-head group norm
      # channel mix
      "cmix": 0.5 * jnp.ones((2, d), jnp.float32),  # r, k lerps
      "cm_wr": dense_init(ks[9], d, d),
      "cm_wk": dense_init(ks[10], d, dff),
      "cm_wv": dense_init(ks[11], dff, d, scale=0.5),
  }


def _token_shift(x: jax.Array, prev: jax.Array = None) -> jax.Array:
  """x (B, L, d) -> previous token per position (zeros / `prev` at t=0)."""
  shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
  if prev is not None:
    shifted = shifted.at[:, 0].set(prev)
  return shifted


def wkv6_chunked(r, k, v, w, u, s0, chunk: int):
  """Stable chunked WKV6 (same math as kernels/rwkv6_scan, pure jnp).

  r/k/v/w: (B, H, T, D); u: (H, D); s0: (B, H, D, D).
  Returns (out (B, H, T, D) f32, s_final).
  """
  b, h, t, dd = r.shape
  pad = (-t) % chunk
  if pad:
    z = jnp.zeros((b, h, pad, dd), r.dtype)
    r = jnp.concatenate([r, z], axis=2)
    k = jnp.concatenate([k, z], axis=2)
    v = jnp.concatenate([v, z], axis=2)
    w = jnp.concatenate([w, jnp.ones((b, h, pad, dd), w.dtype)], axis=2)
  tt = t + pad
  nc = tt // chunk
  mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])

  def to_chunks(x):  # (B, H, T, D) -> (nc, B, H, C, D) for scan
    return jnp.moveaxis(x.reshape(b, h, nc, chunk, dd).astype(jnp.float32),
                        2, 0)

  def chunk_step(s, inp):
    rc, kc, vc, wc = inp                                  # (B, H, C, D)
    logw = jnp.log(jnp.maximum(wc, 1e-30))
    la = jnp.cumsum(logw, axis=2)                         # inclusive
    la_prev = la - logw
    la_last = la[:, :, -1:, :]
    # carried-state term
    rq = rc * jnp.exp(la_prev)
    o = jnp.einsum("bhtd,bhde->bhte", rq, s)
    # intra-chunk pairwise term (exponents are <= 0: stable)
    decay = jnp.exp(la_prev[:, :, :, None, :] - la[:, :, None, :, :])
    scores = jnp.einsum("bhtd,bhjd,bhtjd->bhtj", rc, kc, decay)
    scores = jnp.where(mask[None, None], scores, 0.0)
    o = o + jnp.einsum("bhtj,bhjd->bhtd", scores, vc)
    rd = jnp.sum(rc * u[None, :, None, :] * kc, axis=-1, keepdims=True)
    o = o + rd * vc
    # state update
    kd = kc * jnp.exp(la_last - la)
    s = jnp.exp(la_last[:, :, 0, :])[..., None] * s + \
        jnp.einsum("bhtd,bhte->bhde", kd, vc)
    return s, o

  s_final, outs = jax.lax.scan(
      chunk_step, s0.astype(jnp.float32),
      (to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(w)))
  out = jnp.moveaxis(outs, 0, 2).reshape(b, h, tt, dd)[:, :, :t]
  return out, s_final


def _rwkv_wkv_inputs(params, x, x_prev, cfg: ModelConfig):
  """Shared mixing/projection for train + decode paths."""
  dtt = x.dtype
  mix = params["mix"].astype(dtt)
  xr = x + (x_prev - x) * mix[0]
  xk = x + (x_prev - x) * mix[1]
  xv = x + (x_prev - x) * mix[2]
  xg = x + (x_prev - x) * mix[3]
  xw = x + (x_prev - x) * mix[4]
  r = jnp.einsum("...d,de->...e", xr, params["wr"].astype(dtt))
  k = jnp.einsum("...d,de->...e", xk, params["wk"].astype(dtt))
  v = jnp.einsum("...d,de->...e", xv, params["wv"].astype(dtt))
  g = jax.nn.silu(jnp.einsum("...d,de->...e", xg, params["wg"].astype(dtt)))
  # data-dependent decay (the v6 "Finch" feature)
  lora = jnp.einsum("...r,re->...e",
                    jnp.tanh(jnp.einsum("...d,dr->...r", xw,
                                        params["w_lora_a"].astype(dtt))),
                    params["w_lora_b"].astype(dtt))
  w = jnp.exp(-jnp.exp(params["w0"].astype(jnp.float32)
                       + lora.astype(jnp.float32)))
  return r, k, v, g, w


def apply_rwkv_time_mix(params: Dict, x: jax.Array, cfg: ModelConfig,
                        state: Dict = None) -> jax.Array:
  """x (B, L, d) -> (B, L, d)."""
  b, l, d = x.shape
  h, hd = cfg.n_heads, cfg.head_dim
  dtt = x.dtype
  x_prev = _token_shift(x)
  r, k, v, g, w = _rwkv_wkv_inputs(params, x, x_prev, cfg)

  def heads(t):  # (B, L, h*hd) -> (B, H, L, hd)
    return jnp.moveaxis(t.reshape(b, l, h, hd), 2, 1)

  s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
  out, _ = wkv6_chunked(heads(r), heads(k), heads(v), heads(w),
                        params["u"], s0, cfg.ssm_chunk)
  # per-head group norm, then gate + out proj
  var = jnp.mean(out * out, axis=-1, keepdims=True)
  out = out * jax.lax.rsqrt(var + 1e-6) * \
      params["ln_x"][None, :, None, :]
  out = jnp.moveaxis(out, 1, 2).reshape(b, l, h * hd).astype(dtt) * g
  return jnp.einsum("ble,ed->bld", out, params["wo"].astype(dtt))


def apply_rwkv_channel_mix(params: Dict, x: jax.Array,
                           cfg: ModelConfig) -> jax.Array:
  dtt = x.dtype
  x_prev = _token_shift(x)
  cmix = params["cmix"].astype(dtt)
  xr = x + (x_prev - x) * cmix[0]
  xk = x + (x_prev - x) * cmix[1]
  r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", xr,
                                params["cm_wr"].astype(dtt)))
  k = jnp.einsum("...d,df->...f", xk, params["cm_wk"].astype(dtt))
  k = jnp.square(jax.nn.relu(k))
  return r * jnp.einsum("...f,fd->...d", k, params["cm_wv"].astype(dtt))


def rwkv_decode_step(params: Dict, x: jax.Array, cache: Dict,
                     cfg: ModelConfig) -> Tuple[jax.Array, Dict]:
  """Single token x (B, d); cache {"s": (B,H,D,D), "tm_prev": (B, d),
  "cm_prev": (B, d)} per layer. Applies time mix ONLY (channel mix handled
  by the caller with cm_prev)."""
  b, d = x.shape
  h, hd = cfg.n_heads, cfg.head_dim
  from repro.kernels.rwkv6_scan.ops import wkv6_decode_step
  r, k, v, g, w = _rwkv_wkv_inputs(params, x, cache["tm_prev"], cfg)

  def heads(t):
    return t.reshape(b, h, hd)

  o, s_new = wkv6_decode_step(heads(r).astype(jnp.float32),
                              heads(k).astype(jnp.float32),
                              heads(v).astype(jnp.float32),
                              heads(w).astype(jnp.float32),
                              params["u"], cache["s"])
  var = jnp.mean(o * o, axis=-1, keepdims=True)
  o = o * jax.lax.rsqrt(var + 1e-6) * params["ln_x"][None]
  o = o.reshape(b, h * hd).astype(x.dtype) * g
  out = jnp.einsum("be,ed->bd", o, params["wo"].astype(x.dtype))
  return out, {**cache, "s": s_new, "tm_prev": x}


def rwkv_channel_decode(params: Dict, x: jax.Array, prev: jax.Array,
                        cfg: ModelConfig) -> jax.Array:
  dtt = x.dtype
  cmix = params["cmix"].astype(dtt)
  xr = x + (prev - x) * cmix[0]
  xk = x + (prev - x) * cmix[1]
  r = jax.nn.sigmoid(jnp.einsum("bd,de->be", xr, params["cm_wr"].astype(dtt)))
  k = jnp.square(jax.nn.relu(
      jnp.einsum("bd,df->bf", xk, params["cm_wk"].astype(dtt))))
  return r * jnp.einsum("bf,fd->bd", k, params["cm_wv"].astype(dtt))


def init_rwkv_cache(cfg: ModelConfig, batch: int) -> Dict:
  dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
  return {
      "s": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.head_dim),
                     jnp.float32),
      "tm_prev": jnp.zeros((batch, cfg.d_model), dt),
      "cm_prev": jnp.zeros((batch, cfg.d_model), dt),
  }
