"""Decoder-only transformer LM assembled from the zoo's block kinds.

Covers dense / MoE / hybrid (Mamba+attn) / SSM (RWKV6) / VLM-backbone
families with scan-over-blocks (compile time O(1) in depth), chunked
flash attention, chunked vocab loss, and a cache-based decode path with
optional int8 KV quantization (QUIDAM's precision axis applied to serving).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm
from repro.models.attention import decode_attention, flash_attention
from repro.models.common import (apply_norm, dense_init, embed_init,
                                 make_norm_params, model_dtype, rms_head_norm,
                                 rope, sinusoidal_positions)
from repro.models.ffn import apply_mlp, apply_moe, init_mlp, init_moe
from repro.parallel.sharding import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# attention sub-layer
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig) -> Params:
  d = cfg.d_model
  ks = jax.random.split(key, 4)
  p = {
      "wq": dense_init(ks[0], d, cfg.n_heads * cfg.head_dim),
      "wkv": dense_init(ks[1], d, 2 * cfg.n_kv_heads * cfg.head_dim),
      "wo": dense_init(ks[2], cfg.n_heads * cfg.head_dim, d, scale=0.5),
  }
  if cfg.qk_norm:
    p["q_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
    p["k_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
  return p


def _project_qkv(p: Params, x: jax.Array, cfg: ModelConfig):
  dt = x.dtype
  lead = x.shape[:-1]
  q = jnp.einsum("...d,de->...e", x, p["wq"].astype(dt))
  kv = jnp.einsum("...d,de->...e", x, p["wkv"].astype(dt))
  q = q.reshape(*lead, cfg.n_heads, cfg.head_dim)
  kv = kv.reshape(*lead, 2, cfg.n_kv_heads, cfg.head_dim)
  k, v = kv[..., 0, :, :], kv[..., 1, :, :]
  if cfg.qk_norm:
    q = rms_head_norm(q, p["q_norm"])
    k = rms_head_norm(k, p["k_norm"])
  return q, k, v


def apply_attn_train(p: Params, x: jax.Array, cfg: ModelConfig,
                     positions: jax.Array) -> jax.Array:
  """Full-sequence causal attention. x: (B, S, d)."""
  b, s, d = x.shape
  q, k, v = _project_qkv(p, x, cfg)
  if cfg.pos_embed == "rope":
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
  q = constrain(q, "dp", None, "model", None)
  k = constrain(k, "dp", None, "model" if cfg.n_kv_heads > 1 else None, None)
  out = flash_attention(q, k, v, causal=True, window=cfg.sliding_window,
                        chunk_q=cfg.attn_chunk, chunk_k=cfg.attn_chunk)
  out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
  return jnp.einsum("...e,ed->...d", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------

def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
  s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
  dt = model_dtype(cfg)
  if cfg.kv_quant == "int8":
    return {
        "k_codes": jnp.zeros((batch, cfg.n_kv_heads, s, cfg.head_dim),
                             jnp.int8),
        "v_codes": jnp.zeros((batch, cfg.n_kv_heads, s, cfg.head_dim),
                             jnp.int8),
        "k_scale": jnp.zeros((batch, cfg.n_kv_heads, s), jnp.float32),
        "v_scale": jnp.zeros((batch, cfg.n_kv_heads, s), jnp.float32),
    }
  return {
      "k": jnp.zeros((batch, cfg.n_kv_heads, s, cfg.head_dim), dt),
      "v": jnp.zeros((batch, cfg.n_kv_heads, s, cfg.head_dim), dt),
  }


def _quant_kv_token(k: jax.Array, v: jax.Array):
  """(B, Hkv, D) -> int8 codes + scales (per b, h)."""
  def q(x):
    absmax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-12)
    scale = absmax / 127.0
    codes = jnp.clip(jnp.round(x / scale[..., None]), -128, 127)
    return codes.astype(jnp.int8), scale.astype(jnp.float32)
  kc, ks = q(k.astype(jnp.float32))
  vc, vs = q(v.astype(jnp.float32))
  return kc, ks, vc, vs


def _cache_write_token(cache: Params, k: jax.Array, v: jax.Array,
                       pos: jax.Array, cfg: ModelConfig) -> Params:
  """Write one token's (B, Hkv, D) K/V at pos (scalar int32)."""
  s = (cache["k_codes"] if cfg.kv_quant == "int8" else cache["k"]).shape[2]
  slot = pos % s if cfg.sliding_window else jnp.minimum(pos, s - 1)
  if cfg.kv_quant == "int8":
    kc, ks, vc, vs = _quant_kv_token(k, v)
    return {
        "k_codes": jax.lax.dynamic_update_slice_in_dim(
            cache["k_codes"], kc[:, :, None], slot, axis=2),
        "v_codes": jax.lax.dynamic_update_slice_in_dim(
            cache["v_codes"], vc[:, :, None], slot, axis=2),
        "k_scale": jax.lax.dynamic_update_slice_in_dim(
            cache["k_scale"], ks[:, :, None], slot, axis=2),
        "v_scale": jax.lax.dynamic_update_slice_in_dim(
            cache["v_scale"], vs[:, :, None], slot, axis=2),
    }
  dt = cache["k"].dtype
  return {
      "k": jax.lax.dynamic_update_slice_in_dim(
          cache["k"], k.astype(dt)[:, :, None], slot, axis=2),
      "v": jax.lax.dynamic_update_slice_in_dim(
          cache["v"], v.astype(dt)[:, :, None], slot, axis=2),
  }


def apply_attn_decode(p: Params, x: jax.Array, cache: Params,
                      length: jax.Array, cfg: ModelConfig
                      ) -> Tuple[jax.Array, Params]:
  """x: (B, d) single token; length: scalar int32 tokens so far."""
  b, d = x.shape
  q, k, v = _project_qkv(p, x, cfg)            # (B, H/Hkv, hd)
  if cfg.pos_embed == "rope":
    pos = jnp.full((b,), length, jnp.int32)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
  # heads axes: q (B, H, hd), k/v (B, Hkv, hd)
  cache = _cache_write_token(cache, k, v, length, cfg)
  lens = jnp.full((b,), length + 1, jnp.int32)
  ring = bool(cfg.sliding_window)
  if cfg.kv_quant == "int8":
    out = decode_attention(q, cache["k_codes"], cache["v_codes"], lens,
                           cache["k_scale"], cache["v_scale"], ring=ring)
  else:
    out = decode_attention(q, cache["k"], cache["v"], lens, ring=ring)
  out = out.reshape(b, cfg.n_heads * cfg.head_dim)
  return jnp.einsum("be,ed->bd", out, p["wo"].astype(x.dtype)), cache


def prefill_attn_cache(cfg: ModelConfig, k: jax.Array, v: jax.Array,
                       max_len: int) -> Params:
  """Bulk-build a cache from full-seq K/V (B, S, Hkv, D) after prefill."""
  b, s, hkv, hd = k.shape
  cap = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
  kh = jnp.moveaxis(k, 2, 1)   # (B, Hkv, S, D)
  vh = jnp.moveaxis(v, 2, 1)
  if cfg.sliding_window and s > cap:
    # keep the last `window` positions; ring alignment: slot = pos % cap
    kh = kh[:, :, -cap:]
    vh = vh[:, :, -cap:]
    shift = s % cap
    kh = jnp.roll(kh, shift, axis=2)
    vh = jnp.roll(vh, shift, axis=2)
    s_eff = cap
  else:
    s_eff = s
  pad = cap - kh.shape[2]
  if pad:
    kh = jnp.pad(kh, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vh = jnp.pad(vh, ((0, 0), (0, 0), (0, pad), (0, 0)))
  if cfg.kv_quant == "int8":
    def q(x):
      absmax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-12)
      scale = absmax / 127.0
      return (jnp.clip(jnp.round(x / scale[..., None]), -128, 127)
              .astype(jnp.int8), scale.astype(jnp.float32))
    kc, ks = q(kh.astype(jnp.float32))
    vc, vs = q(vh.astype(jnp.float32))
    return {"k_codes": kc, "v_codes": vc, "k_scale": ks, "v_scale": vs}
  dt = model_dtype(cfg)
  return {"k": kh.astype(dt), "v": vh.astype(dt)}


# ---------------------------------------------------------------------------
# one layer = token mixer + ffn (pre-norm)
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, kind: str, is_moe: bool) -> Params:
  ks = jax.random.split(key, 4)
  p: Params = {"mix_norm": make_norm_params(cfg)}
  if kind == "attn":
    p["mix"] = init_attn(ks[0], cfg)
  elif kind == "mamba":
    p["mix"] = ssm.init_mamba(ks[0], cfg)
  elif kind == "rwkv":
    p["mix"] = ssm.init_rwkv(ks[0], cfg)
  else:
    raise ValueError(kind)
  p["ffn_norm"] = make_norm_params(cfg)
  if kind == "rwkv":
    pass  # rwkv channel mix lives inside mix params (cm_*)
  elif is_moe:
    p["ffn"] = init_moe(ks[1], cfg)
  else:
    p["ffn"] = init_mlp(ks[1], cfg, cfg.d_ff)
  return p


def apply_layer_train(p: Params, x: jax.Array, cfg: ModelConfig, kind: str,
                      is_moe: bool, positions: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
  aux = jnp.zeros((), jnp.float32)
  h = apply_norm(p["mix_norm"], x, cfg)
  if kind == "attn":
    x = x + apply_attn_train(p["mix"], h, cfg, positions)
  elif kind == "mamba":
    x = x + ssm.apply_mamba(p["mix"], h, cfg)
  else:  # rwkv time mix
    x = x + ssm.apply_rwkv_time_mix(p["mix"], h, cfg)
  h = apply_norm(p["ffn_norm"], x, cfg)
  if kind == "rwkv":
    x = x + ssm.apply_rwkv_channel_mix(p["mix"], h, cfg)
  elif is_moe:
    out, aux = apply_moe(p["ffn"], h, cfg)
    x = x + out
  else:
    x = x + apply_mlp(p["ffn"], h, cfg)
  x = constrain(x, "dp", None, None)
  return x, aux


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Params:
  ks = jax.random.split(key, 4)
  pattern = cfg.block_pattern()

  def init_block(bkey):
    sub_keys = jax.random.split(bkey, len(pattern))
    return {f"sub{i}": init_layer(sub_keys[i], cfg, kind, is_moe)
            for i, (kind, is_moe) in enumerate(pattern)}

  params: Params = {
      "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model),
      "final_norm": make_norm_params(cfg),
      "blocks": jax.vmap(init_block)(jax.random.split(ks[1], cfg.n_blocks)),
  }
  if not cfg.tie_embeddings:
    params["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.padded_vocab)
  if cfg.pos_embed == "learned":
    params["pos_embed"] = embed_init(ks[3], cfg.max_position, cfg.d_model)
  return params


def _embed_tokens(params: Params, tokens: jax.Array,
                  cfg: ModelConfig) -> jax.Array:
  return jnp.take(params["embed"], tokens, axis=0).astype(model_dtype(cfg))


def _add_positions(params: Params, x: jax.Array, positions: jax.Array,
                   cfg: ModelConfig) -> jax.Array:
  if cfg.pos_embed == "learned":
    x = x + jnp.take(params["pos_embed"], positions, axis=0
                     ).astype(x.dtype)
  elif cfg.pos_embed == "sinusoidal":
    pe = sinusoidal_positions(int(positions.shape[-1]), cfg.d_model)
    x = x + pe.astype(x.dtype)
  return x


def backbone(params: Params, x: jax.Array, cfg: ModelConfig,
             positions: jax.Array, remat: bool = True
             ) -> Tuple[jax.Array, jax.Array]:
  """Embedded inputs -> final hidden states; returns (x, aux_loss)."""
  pattern = cfg.block_pattern()

  def block_body(carry, block_params):
    h, aux = carry
    for i, (kind, is_moe) in enumerate(pattern):
      h, a = apply_layer_train(block_params[f"sub{i}"], h, cfg, kind,
                               is_moe, positions)
      aux = aux + a
    return (h, aux), None

  body = jax.checkpoint(block_body) if remat else block_body
  (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             params["blocks"])
  x = apply_norm(params["final_norm"], x, cfg)
  return x, aux


def lm_head_weight(params: Params, cfg: ModelConfig) -> jax.Array:
  if cfg.tie_embeddings:
    return params["embed"].T
  return params["lm_head"]


def chunked_xent(params: Params, x: jax.Array, labels: jax.Array,
                 mask: jax.Array, cfg: ModelConfig
                 ) -> Tuple[jax.Array, jax.Array]:
  """Chunked softmax cross-entropy over the (padded) vocab.

  x: (B, S, d); labels/mask: (B, S). Never materializes the full
  (B, S, V) logits — scans over token chunks.
  """
  b, s, d = x.shape
  w = lm_head_weight(params, cfg)
  n = b * s
  chunk = min(cfg.loss_chunk_tokens, n)
  pad = (-n) % chunk
  xf = x.reshape(n, d)
  lf = labels.reshape(n)
  mf = mask.reshape(n).astype(jnp.float32)
  if pad:
    xf = jnp.pad(xf, ((0, pad), (0, 0)))
    lf = jnp.pad(lf, (0, pad))
    mf = jnp.pad(mf, (0, pad))
  nc = xf.shape[0] // chunk
  # mask out the padded vocab columns
  vocab_bias = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                         0.0, -1e30).astype(jnp.float32)

  def chunk_loss(args):
    xc, lc, mc = args
    logits = (jnp.einsum("td,dv->tv", xc, w.astype(xc.dtype))
              .astype(jnp.float32) + vocab_bias)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lc[:, None], axis=1)[:, 0]
    return jnp.sum((logz - gold) * mc), jnp.sum(mc)

  # keep the token dim of each chunk sharded over the dp axes: without this
  # the SPMD partitioner shards the chunk-INDEX dim of the stacked map
  # operand and re-gathers the full activations every loop iteration
  # (§Perf granite iteration 3: a 12 GB/step gather)
  xs = constrain(xf.reshape(nc, chunk, d), None, "dp", None)
  losses, counts = jax.lax.map(
      chunk_loss, (xs, lf.reshape(nc, chunk), mf.reshape(nc, chunk)))
  total = jnp.sum(losses)
  denom = jnp.maximum(jnp.sum(counts), 1.0)
  return total / denom, denom


def train_loss(params: Params, batch: Dict[str, jax.Array],
               cfg: ModelConfig, remat: bool = True
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
  """batch: tokens (B, S), labels (B, S) [, img_embeds (B, I, d)]."""
  tokens = batch["tokens"]
  labels = batch["labels"]
  x = _embed_tokens(params, tokens, cfg)
  mask = jnp.ones_like(labels, jnp.float32)
  if cfg.family == "vlm" and "img_embeds" in batch:
    img = batch["img_embeds"].astype(x.dtype)
    x = jnp.concatenate([img, x], axis=1)
    labels = jnp.concatenate(
        [jnp.zeros((x.shape[0], img.shape[1]), labels.dtype), labels],
        axis=1)
    mask = jnp.concatenate(
        [jnp.zeros((x.shape[0], img.shape[1]), jnp.float32), mask], axis=1)
  positions = jnp.arange(x.shape[1])
  x = _add_positions(params, x, positions, cfg)
  x = constrain(x, "dp", None, None)
  x, aux = backbone(params, x, cfg, positions, remat=remat)
  loss, denom = chunked_xent(params, x, labels, mask, cfg)
  total = loss + 0.01 * aux
  return total, {"xent": loss, "aux": aux, "tokens": denom}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
  pattern = cfg.block_pattern()

  def one_block(_):
    out = {}
    for i, (kind, _) in enumerate(pattern):
      if kind == "attn":
        out[f"sub{i}"] = init_attn_cache(cfg, batch, max_len)
      elif kind == "mamba":
        out[f"sub{i}"] = ssm.init_mamba_cache(cfg, batch)
      else:
        out[f"sub{i}"] = ssm.init_rwkv_cache(cfg, batch)
    return out

  caches = jax.vmap(one_block)(jnp.arange(cfg.n_blocks))
  return {"layers": caches, "length": jnp.zeros((), jnp.int32)}


def decode_step(params: Params, tokens: jax.Array, cache: Params,
                cfg: ModelConfig) -> Tuple[jax.Array, Params]:
  """tokens (B,) -> (logits (B, V), new cache). One token for the batch."""
  pattern = cfg.block_pattern()
  length = cache["length"]
  x = jnp.take(params["embed"], tokens, axis=0).astype(model_dtype(cfg))
  if cfg.pos_embed == "learned":
    x = x + params["pos_embed"][length].astype(x.dtype)[None]

  def block_body(x, inp):
    block_params, block_cache = inp
    new_cache = {}
    for i, (kind, _) in enumerate(pattern):
      p = block_params[f"sub{i}"]
      c = block_cache[f"sub{i}"]
      h = apply_norm(p["mix_norm"], x, cfg)
      if kind == "attn":
        out, c = apply_attn_decode(p["mix"], h, c, length, cfg)
        x = x + out
      elif kind == "mamba":
        out, c = ssm.mamba_decode_step(p["mix"], h, c, cfg)
        x = x + out
      else:
        out, c = ssm.rwkv_decode_step(p["mix"], h, c, cfg)
        x = x + out
      h2 = apply_norm(p["ffn_norm"], x, cfg)
      if kind == "rwkv":
        x = x + ssm.rwkv_channel_decode(p["mix"], h2, c["cm_prev"], cfg)
        c = {**c, "cm_prev": h2}
      elif "ffn" in p:
        if "router" in p["ffn"]:
          out, _ = apply_moe(p["ffn"], h2[:, None, :], cfg)
          x = x + out[:, 0, :]
        else:
          x = x + apply_mlp(p["ffn"], h2, cfg)
      new_cache[f"sub{i}"] = c
    return x, new_cache

  x, new_layer_caches = jax.lax.scan(
      block_body, x, (params["blocks"], cache["layers"]))
  x = apply_norm(params["final_norm"], x, cfg)
  logits = jnp.einsum("bd,dv->bv", x, lm_head_weight(params, cfg)
                      .astype(x.dtype))
  new_cache = {"layers": new_layer_caches, "length": length + 1}
  return logits[:, :cfg.vocab_size], new_cache


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
            max_len: int) -> Tuple[jax.Array, Params]:
  """Run the full prompt, build the cache; returns (last logits, cache)."""
  pattern = cfg.block_pattern()
  b, s = tokens.shape
  x = _embed_tokens(params, tokens, cfg)
  positions = jnp.arange(s)
  x = _add_positions(params, x, positions, cfg)

  def block_body(x, block_params):
    new_cache = {}
    for i, (kind, _) in enumerate(pattern):
      p = block_params[f"sub{i}"]
      h = apply_norm(p["mix_norm"], x, cfg)
      if kind == "attn":
        q, k, v = _project_qkv(p["mix"], h, cfg)
        if cfg.pos_embed == "rope":
          q = rope(q, positions, cfg.rope_theta)
          k = rope(k, positions, cfg.rope_theta)
        out = flash_attention(q, k, v, causal=True,
                              window=cfg.sliding_window,
                              chunk_q=cfg.attn_chunk, chunk_k=cfg.attn_chunk)
        out = out.reshape(b, s, -1)
        x = x + jnp.einsum("...e,ed->...d", out,
                           p["mix"]["wo"].astype(x.dtype))
        new_cache[f"sub{i}"] = prefill_attn_cache(cfg, k, v, max_len)
      elif kind == "mamba":
        # run the train path; rebuild the state by one extra decode pass is
        # avoided: recompute final state from the chunk scan
        out, c = _mamba_prefill(p["mix"], h, cfg)
        x = x + out
        new_cache[f"sub{i}"] = c
      else:
        out, c = _rwkv_prefill(p["mix"], h, cfg)
        x = x + out
        new_cache[f"sub{i}"] = c
      h2 = apply_norm(p["ffn_norm"], x, cfg)
      if kind == "rwkv":
        x = x + ssm.apply_rwkv_channel_mix(p["mix"], h2, cfg)
        new_cache[f"sub{i}"]["cm_prev"] = h2[:, -1, :]
      elif "ffn" in p:
        if "router" in p["ffn"]:
          out, _ = apply_moe(p["ffn"], h2, cfg)
          x = x + out
        else:
          x = x + apply_mlp(p["ffn"], h2, cfg)
    return x, new_cache

  x, layer_caches = jax.lax.scan(block_body, x, params["blocks"])
  x = apply_norm(params["final_norm"], x, cfg)
  last = x[:, -1, :]
  logits = jnp.einsum("bd,dv->bv", last,
                      lm_head_weight(params, cfg).astype(last.dtype))
  cache = {"layers": layer_caches, "length": jnp.asarray(s, jnp.int32)}
  return logits[:, :cfg.vocab_size], cache


def _mamba_prefill(p, h, cfg):
  """Train-path output + final (h_state, conv window) for the cache."""
  out = ssm.apply_mamba(p, h, cfg)
  # final ssm state: recompute cheaply by replaying the last chunk is
  # complex; instead run decode steps over the last d_conv window for conv
  # state and take the full-scan final state via a dedicated call.
  state = _mamba_final_state(p, h, cfg)
  return out, state


def _mamba_final_state(p, x, cfg):
  b, l, d = x.shape
  dt_rank = max(d // 16, 1)
  ds = cfg.mamba_d_state
  dtt = x.dtype
  xz = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(dtt))
  xs, _ = jnp.split(xz, 2, axis=-1)
  conv_tail = xs[:, -(cfg.mamba_d_conv - 1):, :]
  pad = cfg.mamba_d_conv - 1 - conv_tail.shape[1]
  if pad > 0:
    conv_tail = jnp.pad(conv_tail, ((0, 0), (pad, 0), (0, 0)))
  xs = ssm._causal_depthwise_conv(xs, p["conv_w"].astype(dtt),
                                  p["conv_b"].astype(dtt))
  xs = jax.nn.silu(xs)
  proj = jnp.einsum("bld,de->ble", xs, p["x_proj"].astype(dtt))
  dt_in, bmat, _ = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
  dt = jax.nn.softplus(
      jnp.einsum("blr,rd->bld", dt_in, p["dt_proj"].astype(dtt))
      .astype(jnp.float32) + p["dt_bias"][None, None])
  a = -jnp.exp(p["a_log"])

  def step(hs, inp):
    u_, dt_, b_ = inp
    da = jnp.exp(dt_[..., None] * a[None])
    dbu = (dt_ * u_.astype(jnp.float32))[..., None] * \
        b_.astype(jnp.float32)[:, None, :]
    return da * hs + dbu, None

  h0 = jnp.zeros((b, cfg.d_inner, ds), jnp.float32)
  hs, _ = jax.lax.scan(step, h0, (jnp.moveaxis(xs, 1, 0),
                                  jnp.moveaxis(dt, 1, 0),
                                  jnp.moveaxis(bmat, 1, 0)))
  return {"h": hs, "conv": conv_tail.astype(model_dtype(cfg))}


def _rwkv_prefill(p, h, cfg):
  b, l, d = h.shape
  nh, hd = cfg.n_heads, cfg.head_dim
  x_prev = ssm._token_shift(h)
  r, k, v, g, w = ssm._rwkv_wkv_inputs(p, h, x_prev, cfg)

  def heads(t):
    return jnp.moveaxis(t.reshape(b, l, nh, hd), 2, 1)

  s0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
  out, s_final = ssm.wkv6_chunked(heads(r), heads(k), heads(v), heads(w),
                                  p["u"], s0, cfg.ssm_chunk)
  var = jnp.mean(out * out, axis=-1, keepdims=True)
  out = out * jax.lax.rsqrt(var + 1e-6) * p["ln_x"][None, :, None, :]
  out = jnp.moveaxis(out, 1, 2).reshape(b, l, nh * hd).astype(h.dtype) * g
  out = jnp.einsum("ble,ed->bld", out, p["wo"].astype(h.dtype))
  cache = {"s": s_final, "tm_prev": h[:, -1, :],
           "cm_prev": jnp.zeros((b, d), h.dtype)}
  return out, cache
