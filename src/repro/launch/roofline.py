"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch x shape x mesh), TPU v5e constants:

  compute    = FLOPs_dev / 197e12          [s]
  memory     = HBM_bytes_dev / 819e9       [s]
  collective = coll_bytes_dev / 50e9       [s]

METHODOLOGY NOTE (documented deviation): XLA's CPU `cost_analysis()`
counts while-loop bodies ONCE, so raw `flops` under-reports scanned-layer
models by ~n_blocks.  We therefore compute the terms from an ANALYTIC
model of our own compiled program (we control every einsum; formulas
below) and CROSS-CHECK the per-block values against cost_analysis (the
dry-run records carry both; agreement is reported per cell).  Collective
bytes likewise: the HLO inventory (per loop depth, from op_name metadata)
is reconstructed as depth0 + depth1 x n_blocks and compared against the
analytic per-step collective model.

Analytic model (per device, per step):
  train:  matmul FLOPs = (8 Nblk + 6 Nemb) * tokens / n_chips
          (fwd 2 + bwd 4 + full-remat recompute 2 on scanned blocks)
          + attention 4 * (2 * S_eff * d_attn) * tokens * n_attn_layers
          + MoE dispatch/combine einsum overhead (capacity form)
  decode: FLOPs = 2 Nactive * batch / n_chips + cache attention reads
  HBM:    weights traffic (3 reads bf16 at train; 1 at decode) + optimizer
          state read/write (fp32 or int8) + activations/caches
  coll:   TP all-reduces (2/layer fwd + 2 bwd on activation shards)
          + FSDP per-layer param all-gathers + DP gradient all-reduce,
          ring factor 2(n-1)/n on the payload.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
from typing import Any, Dict, List, Optional

from repro.configs import get_config, shape_supported
from repro.configs.shapes import SHAPES

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


def _mesh_info(mesh: str) -> Dict[str, int]:
  if mesh == "16x16":
    return {"chips": 256, "dp": 16, "tp": 16, "pods": 1}
  return {"chips": 512, "dp": 32, "tp": 16, "pods": 2}


def _block_params(cfg, active_only=True) -> int:
  """Matmul params inside the scanned blocks (excludes embeddings/head)."""
  total = cfg.param_count(active_only=active_only)
  emb = cfg.padded_vocab * cfg.d_model
  emb_all = emb if cfg.tie_embeddings else 2 * emb
  if cfg.pos_embed == "learned":
    emb_all += cfg.max_position * cfg.d_model
  return max(total - emb_all, 0)


def analytic_terms(arch: str, shape: str, mesh: str,
                   kv_quant: str = "none",
                   profile: str = "2d") -> Dict[str, float]:
  import dataclasses as _dc
  cfg = get_config(arch)
  if kv_quant and kv_quant != "none":
    cfg = _dc.replace(cfg, kv_quant=kv_quant)
  spec = SHAPES[shape]
  mi = _mesh_info(mesh)
  chips, dp, tp = mi["chips"], mi["dp"] * mi["pods"], mi["tp"]
  if profile == "fsdp":
    dp, tp = chips, 1
  d_attn = cfg.n_heads * cfg.head_dim
  n_attn_layers = sum(1 for k, _ in cfg.block_pattern()
                      for _ in [0] if k == "attn") * cfg.n_blocks
  nblk = _block_params(cfg)
  nemb = cfg.param_count() - _block_params(cfg, active_only=False)
  nact = cfg.param_count(active_only=True)
  nblk_total = _block_params(cfg, active_only=False)

  if spec.mode == "train":
    tokens = spec.global_batch * spec.seq_len
    s_eff = min(spec.seq_len, cfg.sliding_window or spec.seq_len)
    # matmuls: fwd 2N + bwd 4N + remat 2N on blocks; 6N on embed/loss
    mm = (8 * nblk + 6 * nemb) * tokens
    # attention: fwd 2*2*S_eff/2(causal)*d_attn per token per attn layer
    attn = 4 * (2 * s_eff * d_attn) * tokens * n_attn_layers
    moe = 0.0
    if cfg.n_experts:
      cap_tokens = cfg.n_experts_active * cfg.capacity_factor * tokens
      n_moe = sum(1 for _, m in cfg.block_pattern() if m) * cfg.n_blocks
      # dispatch + combine einsums, fwd(2 ops) x4 for bwd+remat
      moe = 4 * 2 * 2 * cap_tokens * cfg.d_model * n_moe
    flops_dev = (mm + attn + moe) / chips
    # HBM: 3 weight reads bf16 + grads f32 w + opt m/v f32 rw + param rw
    n_total = cfg.param_count()
    opt_bytes = 2 if n_total > 50e9 else 8  # int8 m/v vs f32 m/v
    wbytes = (3 * 2 + 4 + 2 * 2 * opt_bytes + 2 * 4) * n_total / chips
    act_bytes = 20 * cfg.d_model * tokens / chips * \
        (cfg.n_layers / max(cfg.n_blocks, 1))  # saved block boundaries+use
    hbm_dev = wbytes + act_bytes
    # collectives: TP activation all-reduces 4/layer (2 fwd + 2 bwd),
    # FSDP all-gathers 2x params, DP grad all-reduce of the TP shard
    ring_tp = 2 * (tp - 1) / tp
    ring_dp = 2 * (dp - 1) / dp
    tok_dev = tokens / dp
    if profile == "fsdp":
      # pure FSDP: 3 bf16 weight gathers (fwd, bwd-remat, bwd) + f32 grad
      # reduce-scatter; no token-scaled TP all-reduces.  Matches the
      # HLO-measured 348 GB/step on granite (§Perf 4.1 iter 3).
      coll_dev = (3 * 2 * nblk_total + 4 * n_total) * (dp - 1) / dp
    else:
      tp_ar = 4 * cfg.n_layers * tok_dev * cfg.d_model * 2 * ring_tp
      fsdp_ag = 2 * (2 * nblk_total / tp) * ring_dp
      dp_ar = 4 * n_total / tp * ring_dp
      coll_dev = tp_ar + fsdp_ag + dp_ar
  elif spec.mode == "prefill":
    tokens = spec.global_batch * spec.seq_len
    s_eff = min(spec.seq_len, cfg.sliding_window or spec.seq_len)
    mm = 2 * (nblk + nemb / 3) * tokens
    attn = (2 * s_eff * d_attn) * tokens * n_attn_layers
    flops_dev = (mm + attn) / chips
    hbm_dev = (2 * cfg.param_count() + 2 * _kv_cache_bytes(cfg, spec)
               + 8 * cfg.d_model * tokens) / chips
    ring_tp = 2 * (tp - 1) / tp
    tok_dev = tokens / dp
    coll_dev = (2 * cfg.n_layers * tok_dev * cfg.d_model * 2 * ring_tp
                + 2 * (2 * nblk_total / tp) * 2 * (dp - 1) / dp)
  else:  # decode: one token against the cache
    b = spec.global_batch
    mm = 2 * nact * b
    cache_bytes = _kv_cache_bytes(cfg, spec)
    flops_dev = (mm + 2 * cache_bytes / 2 * 2) / chips  # scores+pv reads
    hbm_dev = (2 * cfg.param_count(active_only=False) * _w_frac_decode(cfg)
               + cache_bytes) / chips
    ring_tp = 2 * (tp - 1) / tp
    coll_dev = 2 * cfg.n_layers * b / max(dp, 1) * cfg.d_model * 2 * ring_tp
  return {
      "flops_dev": flops_dev, "hbm_dev": hbm_dev, "coll_dev": coll_dev,
      "compute_s": flops_dev / PEAK_FLOPS,
      "memory_s": hbm_dev / HBM_BW,
      "collective_s": coll_dev / ICI_BW,
      "model_flops": (
          6 * nact * spec.global_batch * spec.seq_len
          if spec.mode == "train" else
          2 * nact * spec.global_batch * spec.seq_len
          if spec.mode == "prefill" else
          2 * nact * spec.global_batch),
  }


def _w_frac_decode(cfg) -> float:
  """Fraction of weights actually streamed at decode (MoE: active experts
  + shared; the engine still streams every expert's rows used by the
  batch — with batch >> experts all weights stream, so use 1.0 for MoE
  with big batches, active/total for batch 1)."""
  return 1.0


def _kv_cache_bytes(cfg, spec) -> float:
  b = spec.global_batch
  s = spec.seq_len
  kv_bytes = 1 if cfg.kv_quant == "int8" else 2
  total = 0.0
  for kind, _ in cfg.block_pattern():
    if kind == "attn":
      s_eff = min(s, cfg.sliding_window or s)
      total += (2 * b * cfg.n_kv_heads * s_eff * cfg.head_dim * kv_bytes)
    elif kind == "mamba":
      total += b * cfg.d_inner * cfg.mamba_d_state * 4
    else:  # rwkv
      total += b * cfg.n_heads * cfg.head_dim ** 2 * 4
  return total * cfg.n_blocks


def dominant(terms: Dict[str, float]) -> str:
  vals = {k: terms[k] for k in ("compute_s", "memory_s", "collective_s")}
  return max(vals, key=vals.get).replace("_s", "")


def reconstruct_hlo(record: Dict[str, Any], cfg) -> Dict[str, float]:
  """Reconstruct per-step totals from the body-once cost_analysis values."""
  out: Dict[str, float] = {}
  cost = record.get("cost") or {}
  nb = cfg.n_blocks
  # flops: entry + body(once). body dominates; reconstruction bound:
  out["hlo_flops_body_once"] = cost.get("flops", 0.0)
  out["hlo_flops_reconstructed"] = cost.get("flops", 0.0) * nb
  colls = record.get("collectives") or {}
  d0 = sum(v["bytes"] for v in
           (colls.get("by_loop_depth", {}).get("0", {}) or {}).values())
  d1 = sum(v["bytes"] for v in
           (colls.get("by_loop_depth", {}).get("1", {}) or {}).values())
  out["hlo_coll_bytes_reconstructed"] = d0 + d1 * nb
  return out


def analyse(dryrun_dir: str, out_path: Optional[str] = None
            ) -> List[Dict[str, Any]]:
  rows = []
  for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
    rec = json.load(open(path))
    if rec["status"] != "ok":
      if rec["status"] == "skipped":
        rows.append({"arch": rec["arch"], "shape": rec["shape"],
                     "mesh": rec["mesh"], "status": "skipped",
                     "reason": rec["reason"]})
      continue
    cfg = get_config(rec["arch"])
    variant = []
    if rec.get("profile", "2d") != "2d":
      variant.append(rec["profile"])
    if rec.get("param_dtype", "float32") != "float32":
      variant.append("pbf16")
    if rec.get("kv_quant", "none") != "none":
      variant.append("kv" + rec["kv_quant"])
    if "__mb" in path:
      variant.append(path.split("__mb")[1].split(".")[0] + "mb")
    terms = analytic_terms(rec["arch"], rec["shape"], rec["mesh"],
                           kv_quant=rec.get("kv_quant", "none"),
                           profile=rec.get("profile", "2d"))
    hlo = reconstruct_hlo(rec, cfg)
    chips = _mesh_info(rec["mesh"])["chips"]
    useful = terms["model_flops"] / max(terms["flops_dev"] * chips, 1.0)
    row = {
        "arch": rec["arch"] + ("+" + "+".join(variant) if variant else ""),
        "shape": rec["shape"], "mesh": rec["mesh"],
        "status": "ok", "mode": rec["mode"],
        "compute_s": terms["compute_s"], "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "dominant": dominant(terms),
        "model_flops": terms["model_flops"],
        "useful_flops_ratio": min(useful, 1.0),
        "roofline_fraction": max(terms["compute_s"], 1e-30) / max(
            terms["compute_s"], terms["memory_s"], terms["collective_s"]),
        "hlo_flops_body_once": hlo["hlo_flops_body_once"],
        "hlo_flops_reconstructed": hlo["hlo_flops_reconstructed"],
        "analytic_flops_dev": terms["flops_dev"],
        "hlo_coll_bytes_reconstructed": hlo["hlo_coll_bytes_reconstructed"],
        "analytic_coll_bytes_dev": terms["coll_dev"],
        "temp_bytes_dev": (rec.get("memory") or {}).get("temp_bytes"),
        "arg_bytes_dev": (rec.get("memory") or {}).get("argument_bytes"),
        "compile_s": rec.get("compile_s"),
    }
    rows.append(row)
  if out_path:
    with open(out_path, "w") as f:
      json.dump(rows, f, indent=1)
  return rows


def to_markdown(rows: List[Dict[str, Any]], mesh: str = "16x16") -> str:
  lines = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | roofline frac | useful FLOPs | HBM args+temp (GB/dev) |",
           "|---|---|---|---|---|---|---|---|---|"]
  for r in rows:
    if r.get("mesh") != mesh:
      continue
    if r["status"] == "skipped":
      lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | "
                   f"— | — | {r['reason'][:48]}… |")
      continue
    mem_gb = ((r["arg_bytes_dev"] or 0) + (r["temp_bytes_dev"] or 0)) / 2**30
    lines.append(
        f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
        f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
        f"{r['roofline_fraction']:.2f} | {r['useful_flops_ratio']:.2f} | "
        f"{mem_gb:.1f} |")
  return "\n".join(lines)


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--dryrun-dir", default="results/dryrun")
  ap.add_argument("--out", default="results/roofline.json")
  ap.add_argument("--markdown", default="results/roofline.md")
  args = ap.parse_args()
  rows = analyse(args.dryrun_dir, args.out)
  md = "## Single-pod (16x16)\n" + to_markdown(rows, "16x16") + \
       "\n\n## Multi-pod (2x16x16)\n" + to_markdown(rows, "2x16x16")
  with open(args.markdown, "w") as f:
    f.write(md)
  print(md)


if __name__ == "__main__":
  main()
