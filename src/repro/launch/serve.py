"""Mesh-parallel serving launcher: continuous batching with an optionally
int8-quantized KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --requests 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.parallel import sharding as sh
from repro.serve.engine import EngineConfig, ServeEngine


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default="qwen3-0.6b")
  ap.add_argument("--requests", type=int, default=8)
  ap.add_argument("--new-tokens", type=int, default=16)
  ap.add_argument("--kv-quant", default="int8", choices=["none", "int8"])
  ap.add_argument("--smoke", action="store_true", default=True)
  args = ap.parse_args()

  cfg = get_config(args.arch)
  if args.smoke:
    cfg = reduce_for_smoke(cfg, d_model=128, n_layers=4, vocab_size=2048)
  cfg = dataclasses.replace(cfg, kv_quant=args.kv_quant)
  mesh = make_host_mesh()
  model = build_model(cfg)
  with sh.MeshContext(mesh):
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, EngineConfig(
        batch_slots=4, max_len=256, prompt_bucket=32))
    rng = np.random.RandomState(0)
    t0 = time.time()
    for i in range(args.requests):
      engine.submit(rng.randint(0, cfg.vocab_size, size=10 + i),
                    max_new_tokens=args.new_tokens)
    results = engine.run_until_drained()
  dt = time.time() - t0
  total = sum(len(v) for v in results.values())
  print(f"served {len(results)} requests / {total} tokens in {dt:.1f}s "
        f"(kv_quant={args.kv_quant}, mesh={dict(mesh.shape)})")


if __name__ == "__main__":
  main()
