"""Production mesh factory.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis extends
data parallelism across pods (gradient all-reduce crosses the pod axis
once per step over DCN/optical links).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run driver sets
--xla_force_host_platform_device_count before any jax import).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

from repro.explore.fleet import visible_devices


def make_production_mesh(*, multi_pod: bool = False):
  shape = (2, 16, 16) if multi_pod else (16, 16)
  axes = ("pod", "data", "model") if multi_pod else ("data", "model")
  n = 1
  for s in shape:
    n *= s
  devices = visible_devices()[:n]
  if len(devices) < n:
    raise RuntimeError(
        f"mesh {shape} needs {n} devices, found {len(devices)}; the dry-run "
        "driver must set XLA_FLAGS=--xla_force_host_platform_device_count "
        "before importing jax")
  return jax.make_mesh(shape, axes,
                       axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
                       devices=devices)


def make_host_mesh(model_parallel: int = 1):
  """Whatever this host actually has (tests / examples): (data, model)."""
  devs = visible_devices()
  mp = model_parallel
  dp = max(len(devs) // mp, 1)
  return jax.make_mesh((dp, mp), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2,
                       devices=devs[: dp * mp])


def make_elastic_mesh(data: int, model: int, pods: int = 1):
  """Mesh for a degraded device count (fault-tolerance re-mesh plan)."""
  shape = (pods, data, model) if pods > 1 else (data, model)
  axes = ("pod", "data", "model") if pods > 1 else ("data", "model")
  n = 1
  for s in shape:
    n *= s
  return jax.make_mesh(shape, axes,
                       axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
                       devices=visible_devices()[:n])
