"""Mesh-parallel training launcher.

Runs the Trainer against whatever mesh the host can build (on a real TPU
slice: the production 16x16 / 2x16x16 meshes; on this CPU container: a
1x1 mesh), with the same sharding rules the dry-run verifies at 256/512
chips.  `--smoke` shrinks the config so the driver runs anywhere.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 200
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, reduce_for_smoke
from repro.data.synthetic import (DataCursor, MarkovTokenStream,
                                  TokenStreamConfig, token_batches)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import build_model
from repro.parallel import sharding as sh
from repro.quant.policy import QuantPolicy
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib
from repro.train.trainer import Trainer, TrainerConfig


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default="olmo-1b")
  ap.add_argument("--steps", type=int, default=200)
  ap.add_argument("--batch", type=int, default=8)
  ap.add_argument("--seq", type=int, default=128)
  ap.add_argument("--pe-type", default="FP32")
  ap.add_argument("--smoke", action="store_true")
  ap.add_argument("--production-mesh", action="store_true",
                  help="build the 16x16 mesh (needs 256 devices)")
  ap.add_argument("--model-parallel", type=int, default=1)
  ap.add_argument("--profile", default="2d", choices=["2d", "fsdp"])
  ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
  args = ap.parse_args()

  sh.set_profile(args.profile)
  cfg = get_config(args.arch)
  if args.smoke:
    cfg = reduce_for_smoke(cfg, d_model=128, n_layers=4, d_ff=256,
                           vocab_size=2048)
  mesh = make_production_mesh() if args.production_mesh else \
      make_host_mesh(args.model_parallel)
  model = build_model(cfg)
  tcfg = ts_lib.TrainConfig(
      optimizer=opt_lib.AdamWConfig(lr=3e-3, warmup_steps=20,
                                    total_steps=args.steps),
      quant=QuantPolicy(pe_type=args.pe_type))
  stream = MarkovTokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                               branching=6))
  cursor = DataCursor()
  with sh.MeshContext(mesh):
    trainer = Trainer(model, tcfg,
                      TrainerConfig(total_steps=args.steps, log_every=20,
                                    ckpt_every=100,
                                    ckpt_dir=args.ckpt_dir),
                      token_batches(stream, args.batch, args.seq, cursor),
                      cursor=cursor, key=jax.random.PRNGKey(0))
    trainer.maybe_restore()
    hist = trainer.run(args.steps - trainer.step)
  if hist:
    print(f"final loss {hist[-1]['loss']:.4f} after {trainer.step} steps "
          f"on mesh {dict(mesh.shape)}")


if __name__ == "__main__":
  main()
