import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs ShapeDtypeStruct stand-ins for params / optimizer state /
     decode caches / inputs (NO device allocation),
  3. jit-lowers the step (train_step for train shapes, prefill for
     prefill shapes, decode_step for decode shapes) with full in/out
     shardings and compiles it,
  4. records memory_analysis / cost_analysis / the collective-op
     inventory parsed from the optimized HLO into a JSON record that
     EXPERIMENTS.md §Dry-run and the roofline analysis read.

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system, not in the driver.

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import functools
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config, input_specs, shape_supported
from repro.configs.shapes import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.parallel import sharding as sh
from repro.quant.policy import QuantPolicy
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
                "s16": 2, "u16": 2}


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
  """Inventory of collective ops in the optimized HLO.

  Uses the `op_name` metadata to attribute each collective to its loop
  nesting depth (".../while/body/..." markers): depth-0 collectives run
  once per step, depth-1 run once per scanned layer (or loss chunk), etc.
  The roofline analysis scales depth>=1 bytes by the scan trip counts
  (recorded here from XLA's known_trip_count annotations).
  """
  coll_re = re.compile(
      r"= (\(?[\w\[\],{}0-9 ]+?\)?) "
      r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
      r"(-start|-done)?\(")
  shape_re = re.compile(r"(\w+)\[([0-9,]*)\]")
  name_re = re.compile(r'op_name="([^"]+)"')
  trip_re = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
  inventory: Dict[str, Dict[str, float]] = {}
  by_depth: Dict[str, Dict[str, float]] = {}
  trip_counts = [int(m) for m in trip_re.findall(hlo_text)]
  for line in hlo_text.splitlines():
    cm = coll_re.search(line)
    if not cm:
      continue
    if cm.group(3) == "-done":
      continue  # count start/done pairs once
    kind = cm.group(2)
    nbytes = 0
    for dtype, dims in shape_re.findall(cm.group(1)):
      n = 1
      for d in dims.split(","):
        if d:
          n *= int(d)
      nbytes += n * _DTYPE_BYTES.get(dtype, 4)
    nm = name_re.search(line)
    depth = nm.group(1).count("/while/") if nm else 0
    slot = inventory.setdefault(kind, {"count": 0, "bytes": 0.0})
    slot["count"] += 1
    slot["bytes"] += nbytes
    d_slot = by_depth.setdefault(str(depth), {})
    k_slot = d_slot.setdefault(kind, {"count": 0, "bytes": 0.0})
    k_slot["count"] += 1
    k_slot["bytes"] += nbytes
  return {"static": inventory, "by_loop_depth": by_depth,
          "known_trip_counts": sorted(set(trip_counts))}


def _struct_tree(tree):
  return jax.tree_util.tree_map(
      lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def dryrun_cell(arch: str, shape: str, multi_pod: bool,
                quant_opt: Optional[bool] = None,
                kv_quant: Optional[str] = None,
                profile: str = "2d",
                param_dtype: str = "float32",
                microbatches: int = 1,
                collect_hlo: bool = True) -> Dict[str, Any]:
  """Lower + compile one cell; returns the JSON-able record."""
  import dataclasses
  sh.set_profile(profile)
  t_start = time.time()
  cfg = get_config(arch)
  if kv_quant:
    cfg = dataclasses.replace(cfg, kv_quant=kv_quant)
  spec = SHAPES[shape]
  skip = shape_supported(cfg, shape)
  record: Dict[str, Any] = {
      "arch": arch, "shape": shape,
      "mesh": "2x16x16" if multi_pod else "16x16",
      "mode": spec.mode,
      "params_total": cfg.param_count(),
      "params_active": cfg.param_count(active_only=True),
      "quant_opt": bool(quant_opt), "kv_quant": cfg.kv_quant,
      "profile": profile, "param_dtype": param_dtype,
  }
  if skip:
    record.update(status="skipped", reason=skip)
    return record

  mesh = make_production_mesh(multi_pod=multi_pod)
  model = build_model(cfg)
  specs = input_specs(cfg, shape)
  key = jax.random.PRNGKey(0)

  # default: int8 optimizer state for the >100B archs (it is the difference
  # between fitting 16 GB/chip and not; see EXPERIMENTS.md)
  if quant_opt is None:
    quant_opt = cfg.param_count() > 50e9

  try:
    with sh.MeshContext(mesh):
      if spec.mode == "train":
        tcfg = ts_lib.TrainConfig(
            optimizer=opt_lib.AdamWConfig(quantize_state=quant_opt),
            param_dtype=param_dtype, microbatches=microbatches)
        state_shapes = jax.eval_shape(
            functools.partial(ts_lib.make_train_state, model, tcfg), key)
        state_specs = sh.train_state_specs(state_shapes, mesh, quant_opt)
        batch_specs = {k: sh.batch_spec(mesh, len(v.shape))
                       for k, v in specs.items()}
        fn = functools.partial(ts_lib.train_step, model, tcfg)
        jitted = jax.jit(fn, in_shardings=(
            sh.to_shardings(state_specs, mesh),
            sh.to_shardings(batch_specs, mesh)), donate_argnums=(0,))
        lowered = jitted.lower(state_shapes, specs)
      elif spec.mode == "prefill":
        params_shapes = jax.eval_shape(model.init, key)
        pspecs = sh.param_specs(params_shapes, mesh)
        batch_specs = {k: sh.batch_spec(mesh, len(v.shape))
                       for k, v in specs.items()}
        fn = lambda p, b: model.prefill(p, b, spec.seq_len)  # noqa: E731
        jitted = jax.jit(fn, in_shardings=(
            sh.to_shardings(pspecs, mesh),
            sh.to_shardings(batch_specs, mesh)))
        lowered = jitted.lower(params_shapes, specs)
      else:  # decode
        params_shapes = jax.eval_shape(model.init, key)
        pspecs = sh.param_specs(params_shapes, mesh)
        b = spec.global_batch
        cache_shapes = jax.eval_shape(
            functools.partial(model.init_cache, b, spec.seq_len))
        cspecs = sh.cache_specs(cache_shapes, mesh, b)
        tok_spec = sh.batch_spec(mesh, 1) if b > 1 else \
            jax.sharding.PartitionSpec(None)
        extra = {}
        if cfg.family == "encdec":
          # decode against encoder K/V already in the cache
          pass
        jitted = jax.jit(model.decode_step, in_shardings=(
            sh.to_shardings(pspecs, mesh),
            jax.sharding.NamedSharding(mesh, tok_spec),
            sh.to_shardings(cspecs, mesh)), donate_argnums=(2,))
        lowered = jitted.lower(params_shapes, specs["tokens"], cache_shapes)

      t_lower = time.time()
      compiled = lowered.compile()
      t_compile = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    record.update(
        status="ok",
        lower_s=round(t_lower - t_start, 1),
        compile_s=round(t_compile - t_lower, 1),
        memory={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        cost={k: v for k, v in (cost or {}).items()
              if "flops" in k or "bytes accessed" in k.lower()
              or k in ("transcendentals",)},
    )
    if collect_hlo:
      txt = compiled.as_text()
      record["collectives"] = parse_collectives(txt)
      record["hlo_bytes"] = len(txt)
  except Exception as e:  # noqa: BLE001
    record.update(status="failed", error=f"{type(e).__name__}: {e}",
                  traceback=traceback.format_exc()[-4000:])
  return record


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default=None)
  ap.add_argument("--shape", default=None)
  ap.add_argument("--mesh", choices=["pod1", "pod2", "both"],
                  default="pod1")
  ap.add_argument("--all", action="store_true")
  ap.add_argument("--out", default="results/dryrun")
  ap.add_argument("--kv-quant", default=None)
  ap.add_argument("--profile", default="2d", choices=["2d", "fsdp"])
  ap.add_argument("--param-dtype", default="float32",
                  choices=["float32", "bfloat16"])
  ap.add_argument("--microbatches", type=int, default=1)
  ap.add_argument("--quant-opt", default=None,
                  choices=[None, "true", "false"])
  args = ap.parse_args()

  archs = ALL_ARCHS if args.all or not args.arch else [args.arch]
  shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
  meshes = {"pod1": [False], "pod2": [True],
            "both": [False, True]}[args.mesh]
  quant_opt = None if args.quant_opt is None else args.quant_opt == "true"

  os.makedirs(args.out, exist_ok=True)
  for arch in archs:
    for shape in shapes:
      for mp in meshes:
        tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
        if args.kv_quant:
          tag += f"__kv{args.kv_quant}"
        if args.profile != "2d":
          tag += f"__{args.profile}"
        if args.param_dtype != "float32":
          tag += "__pbf16"
        if args.microbatches > 1:
          tag += f"__mb{args.microbatches}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
          print(f"[skip cached] {tag}")
          continue
        print(f"[dryrun] {tag} ...", flush=True)
        rec = dryrun_cell(arch, shape, mp, quant_opt=quant_opt,
                          kv_quant=args.kv_quant, profile=args.profile,
                          param_dtype=args.param_dtype,
                          microbatches=args.microbatches)
        with open(path, "w") as f:
          json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = rec.get("reason") or rec.get("error") or \
            f"compile {rec.get('compile_s')}s flops/dev " \
            f"{rec.get('cost', {}).get('flops')}"
        print(f"[{status}] {tag}: {extra}", flush=True)


if __name__ == "__main__":
  main()
