"""Data substrate: deterministic synthetic token + image pipelines."""
from repro.data.synthetic import (CifarLike, CifarLikeConfig, DataCursor,
                                  MarkovTokenStream, TokenStreamConfig,
                                  token_batches)
