"""Deterministic synthetic data.

Token streams: a seeded order-1 Markov chain over the vocab with Zipfian
marginals — structured enough that a language model's loss genuinely
decreases (tests/examples assert it), fully reproducible, and resumable
from a (seed, step) cursor.

Image classes: procedural class-conditional Gabor textures standing in for
CIFAR-10/100 in the paper's accuracy experiments (offline container; see
DESIGN.md hardware-adaptation table).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.seeding import derive_seed


# ---------------------------------------------------------------------------
# token stream
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TokenStreamConfig:
  vocab_size: int = 4096
  branching: int = 8          # successors per state (lower = easier)
  seed: int = 0


class MarkovTokenStream:
  """Order-1 Markov chain with Zipf marginals; O(vocab * branching) table."""

  def __init__(self, cfg: TokenStreamConfig):
    self.cfg = cfg
    rng = np.random.RandomState(cfg.seed)
    v, b = cfg.vocab_size, cfg.branching
    self.successors = rng.randint(0, v, size=(v, b)).astype(np.int32)
    # Zipf-ish successor weights shared across states
    w = 1.0 / np.arange(1, b + 1) ** 1.1
    self.weights = (w / w.sum()).astype(np.float64)

  def sample_batch(self, batch: int, seq_len: int, step: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic (tokens, labels) for a global step."""
    rng = np.random.RandomState(derive_seed("markov-step", self.cfg.seed,
                                            step))
    v, b = self.cfg.vocab_size, self.cfg.branching
    toks = np.empty((batch, seq_len + 1), np.int32)
    toks[:, 0] = rng.randint(0, v, size=batch)
    choices = rng.choice(b, size=(batch, seq_len), p=self.weights)
    for t in range(seq_len):
      toks[:, t + 1] = self.successors[toks[:, t], choices[:, t]]
    return toks[:, :-1], toks[:, 1:]


@dataclasses.dataclass
class DataCursor:
  """Resumable pipeline position (checkpointed with the train state)."""
  step: int = 0
  shard: int = 0
  n_shards: int = 1


def token_batches(stream: MarkovTokenStream, batch: int, seq_len: int,
                  cursor: DataCursor) -> Iterator[Dict[str, np.ndarray]]:
  """Host-sharded batch iterator: host `shard` of `n_shards` yields its
  slice of the global batch; the cursor advances for resumability."""
  per_host = batch // cursor.n_shards
  lo = cursor.shard * per_host
  while True:
    toks, labels = stream.sample_batch(batch, seq_len, cursor.step)
    cursor.step += 1   # cursor now names the NEXT batch (resume-correct)
    yield {"tokens": toks[lo: lo + per_host],
           "labels": labels[lo: lo + per_host]}


# ---------------------------------------------------------------------------
# procedural image classes (cifar_like)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CifarLikeConfig:
  n_classes: int = 10
  image_size: int = 32
  noise: float = 0.35
  seed: int = 0


class CifarLike:
  """Class-conditional Gabor textures + color tint + noise.

  Each class has a characteristic (orientation, frequency, phase, tint);
  samples add jitter and pixel noise.  Linear classifiers reach ~50-70%,
  small convnets >90% — enough headroom for the paper's relative-accuracy
  comparisons (FP32 vs INT16 vs LightPE QAT).
  """

  def __init__(self, cfg: CifarLikeConfig):
    self.cfg = cfg
    rng = np.random.RandomState(derive_seed("cifar-classes", cfg.seed))
    c = cfg.n_classes
    self.theta = rng.uniform(0, np.pi, c)
    self.freq = rng.uniform(2.0, 8.0, c)
    self.phase = rng.uniform(0, 2 * np.pi, c)
    self.tint = rng.uniform(0.3, 1.0, (c, 3))

  def sample(self, n: int, split_seed: int
             ) -> Tuple[np.ndarray, np.ndarray]:
    cfg = self.cfg
    rng = np.random.RandomState(derive_seed("cifar-split", cfg.seed,
                                            split_seed))
    labels = rng.randint(0, cfg.n_classes, n)
    s = cfg.image_size
    yy, xx = np.meshgrid(np.linspace(-1, 1, s), np.linspace(-1, 1, s),
                         indexing="ij")
    imgs = np.empty((n, s, s, 3), np.float32)
    for i, c in enumerate(labels):
      th = self.theta[c] + rng.normal(0, 0.08)
      fq = self.freq[c] * (1 + rng.normal(0, 0.05))
      ph = self.phase[c] + rng.normal(0, 0.3)
      u = xx * np.cos(th) + yy * np.sin(th)
      pattern = np.sin(fq * np.pi * u + ph) * \
          np.exp(-(xx ** 2 + yy ** 2))
      img = pattern[..., None] * self.tint[c][None, None, :]
      img += rng.normal(0, cfg.noise, img.shape)
      imgs[i] = img
    return imgs.astype(np.float32), labels.astype(np.int32)
