"""Compressed collectives: QUIDAM's precision axis applied to the wire.

`compressed_psum_int8` performs an int8-quantized all-reduce (per-block
scales) inside `shard_map` over the data-parallel axes: each shard
quantizes its local gradient shard, the int8 codes are summed (as int32)
across the axis, and the result is dequantized — 4x fewer bytes on the DP
all-reduce at a quantization error bounded by the block absmax.

`ErrorFeedback` carries the per-step quantization residual so the
compression bias vanishes over time (EF-SGD).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

BLOCK = 256


def _quantize_block(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
  n = x.size
  pad = (-n) % BLOCK
  xf = jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, BLOCK)
  scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=1, keepdims=True),
                      1e-12) / 127.0
  codes = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
  return codes, scale[:, 0]


def _dequantize_block(codes: jax.Array, scale: jax.Array,
                      shape, size: int) -> jax.Array:
  x = codes.astype(jnp.float32) * scale[:, None]
  return x.reshape(-1)[:size].reshape(shape)


def quantize_dequantize(x: jax.Array) -> jax.Array:
  c, s = _quantize_block(x)
  return _dequantize_block(c, s, x.shape, x.size)


def compressed_psum_int8(x: jax.Array, axis_name: str) -> jax.Array:
  """Inside shard_map/pmap: int8-compressed psum over `axis_name`.

  Bytes on the wire: 1 per element + 4/BLOCK scale overhead (vs 4 fp32),
  with the sum done in int32 after a max-scale exchange (so all shards
  quantize against the same scale and the integer sum is exact).
  """
  n = x.size
  pad = (-n) % BLOCK
  xf = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)) \
      .reshape(-1, BLOCK)
  local_absmax = jnp.max(jnp.abs(xf), axis=1, keepdims=True)
  # small fp32 exchange of block scales (BLOCK x fewer elements)
  global_absmax = jax.lax.pmax(local_absmax, axis_name)
  scale = jnp.maximum(global_absmax, 1e-12) / 127.0
  codes = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
  summed = jax.lax.psum(codes.astype(jnp.int32), axis_name)
  out = summed.astype(jnp.float32) * scale
  return out.reshape(-1)[:n].reshape(x.shape)


class ErrorFeedback:
  """EF-compression wrapper: residual = x - Q(x) is re-injected next step."""

  @staticmethod
  def init(tree):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), tree)

  @staticmethod
  def apply(grads, residuals):
    """Returns (compressed grads (QdQ), new residuals)."""
    def one(g, r):
      corrected = g.astype(jnp.float32) + r
      q = quantize_dequantize(corrected)
      return q, corrected - q
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def dp_compressed_grads(loss_fn, params, batch, mesh: Mesh,
                        axis_name: str = "data"):
  """Pure-DP demonstration path: per-shard grads + int8 all-reduce via
  shard_map (params replicated, batch sharded on `axis_name`)."""
  from jax.experimental.shard_map import shard_map

  def shard_fn(params, batch):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    grads = jax.tree_util.tree_map(
        lambda g: compressed_psum_int8(g, axis_name) /
        jax.lax.psum(1, axis_name), grads)
    loss = jax.lax.pmean(loss, axis_name)
    return loss, grads

  pspec = jax.tree_util.tree_map(lambda _: P(), params)
  bspec = jax.tree_util.tree_map(lambda _: P(axis_name), batch)
  return shard_map(shard_fn, mesh=mesh, in_specs=(pspec, bspec),
                   out_specs=(P(), pspec), check_rep=False)(params, batch)
