"""Sharding rules: params + activations over the (pod, data, model) mesh.

Strategy (MaxText-style 2-D sharding):
  * batch dims            -> ("pod", "data") combined ("dp" axes)
  * attention heads / d_ff / experts' ff / vocab -> "model" (TP)
  * optimizer state       -> additionally sharded over "data" when the
    param's TP-complement dim divides (ZeRO-1); see train/optimizer.py
  * adaptive divisibility: a dim shards on an axis only when divisible —
    otherwise it falls through to replication (e.g. MQA's kv_heads=1,
    whisper's 8 heads on a 16-way model axis).

`constrain` is the activation-annotation hook models call; it is a no-op
unless a mesh context is installed (launchers install one), so models and
tests run unmodified on a single device.
"""
from __future__ import annotations

import re
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

# sharding profile: "2d" = FSDP("data") x TP("model") [default];
# "fsdp" = pure FSDP over every mesh axis (no tensor parallelism; the
# "model" axis becomes extra data/param parallelism).  The §Perf hillclimb
# for collective-bound training cells switches profiles.
_PROFILE = "2d"


def set_profile(profile: str) -> None:
  global _PROFILE
  assert profile in ("2d", "fsdp"), profile
  _PROFILE = profile


def get_profile() -> str:
  return _PROFILE


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
  """The data-parallel axes present in the mesh ('pod' extends DP)."""
  if _PROFILE == "fsdp":
    return tuple(a for a in ("pod", "data", "model")
                 if a in mesh.axis_names)
  return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


class MeshContext:
  """Installs a mesh so `constrain` becomes active inside jit traces."""

  def __init__(self, mesh: Optional[Mesh]):
    self.mesh = mesh

  def __enter__(self):
    _STATE.mesh = self.mesh
    if self.mesh is not None:
      self._mgr = self.mesh
      self._mgr.__enter__()
    return self

  def __exit__(self, *exc):
    if self.mesh is not None:
      self._mgr.__exit__(*exc)
    _STATE.mesh = None
    return False


def active_mesh() -> Optional[Mesh]:
  return getattr(_STATE, "mesh", None)


def constrain(x: jax.Array, *spec) -> jax.Array:
  """with_sharding_constraint if a mesh is active, identity otherwise.

  spec entries: None, an axis name, a tuple of axis names, or the sentinel
  "dp" which expands to the mesh's data-parallel axes.
  """
  mesh = active_mesh()
  if mesh is None:
    return x
  resolved = []
  for s in spec:
    if s == "dp":
      axes = dp_axes(mesh)
      resolved.append(axes if len(axes) > 1 else
                      (axes[0] if axes else None))
    elif _PROFILE == "fsdp" and s == "model":
      resolved.append(None)  # no TP under the pure-FSDP profile
    else:
      resolved.append(s)
  # drop axes that would not divide
  fixed = []
  for dim, s in zip(x.shape, resolved):
    size = _axes_size(mesh, s)
    fixed.append(s if size and dim % size == 0 else None)
  return jax.lax.with_sharding_constraint(x, P(*fixed))


def _axes_size(mesh: Mesh, s) -> int:
  if s is None:
    return 1
  if isinstance(s, str):
    return mesh.shape[s]
  size = 1
  for a in s:
    size *= mesh.shape[a]
  return size


# ---------------------------------------------------------------------------
# parameter partition specs (path-pattern rules)
# ---------------------------------------------------------------------------

# rule table: (path regex, spec builder taking ndim) — first match wins.
# Paths look like "blocks/sub0/mix/wq", "embed", "blocks/sub1/ffn/wi", ...
# Stacked block params have a leading layer axis -> spec gets None prepended.

def _spec_for(path: str, shape: Tuple[int, ...],
              stacked: bool) -> Tuple[Optional[Any], ...]:
  """2-D (FSDP x TP) rules; the leading stacked-layer axis never shards.

  Matmul weights shard the TP-natural dim on "model" and the other dim on
  "data" (ZeRO-3 / FSDP: XLA all-gathers the "data" shard per layer inside
  the scan).  Without this, jamba-1.5-large's 398B params (797 GB bf16)
  cannot fit 16 GB/chip at TP=16; 2-D sharding gives 3.1 GB/chip.
  """
  body_shape = shape[1:] if stacked else shape

  def out(*tail):
    tail = list(tail) + [None] * (len(body_shape) - len(tail))
    return (None, *tail) if stacked else tuple(tail)

  name = path.split("/")[-1]
  if path in ("embed", "lm_head_t"):
    return out("model", "data")              # (V, d)
  if name == "lm_head":
    return out("data", "model")              # (d, V)
  if name == "pos_embed":
    return out(None, "data")
  # attention projections
  if name in ("wq", "wkv"):                  # (d, H*hd) / (d, 2*Hkv*hd)
    return out("data", "model")
  if name == "wo" and "mix" in path:         # (H*hd, d)
    return out("model", "data")
  # mlp
  if name in ("wi", "wg"):                   # (d, ff) or (E, d, ff)
    if len(body_shape) == 3:
      return out(None, "data", "model")
    return out("data", "model")
  if name == "wo" and len(body_shape) == 3:  # experts (E, ff, d)
    return out(None, "model", "data")
  if name == "wo":                           # (ff, d)
    return out("model", "data")
  # mamba
  if name == "in_proj":                      # (d, 2*di)
    return out("data", "model")
  if name == "out_proj":                     # (di, d)
    return out("model", "data")
  if name in ("conv_w",):                    # (K, di)
    return out(None, "model")
  if name in ("conv_b", "dt_bias", "d_skip", "norm") and "mix" in path:
    return out("model")
  if name == "x_proj":                       # (di, dt_rank + 2N)
    return out("model", "data")
  if name == "dt_proj":                      # (dt_rank, di)
    return out("data", "model")
  if name == "a_log":                        # (di, N)
    return out("model", None)
  # rwkv
  if name in ("wr", "wk", "wv", "wg") and "mix" in path:
    return out("data", "model")
  if name == "w_lora_a":
    return out("data", None)
  if name == "w_lora_b":
    return out(None, "model")
  if name in ("w0",):
    return out("model")
  if name in ("u", "ln_x"):                  # (H, hd)
    return out("model", None)
  if name == "cm_wr":
    return out("data", "model")
  if name == "cm_wk":
    return out("data", "model")
  if name == "cm_wv":
    return out("model", "data")
  if name == "router":
    return out("data", None)
  return out()


def _check_divisibility(spec, shape, mesh: Mesh):
  fixed = []
  for dim, s in zip(shape, spec):
    if s is None:
      fixed.append(None)
      continue
    size = _axes_size(mesh, s)
    fixed.append(s if dim % size == 0 else None)
  return tuple(fixed)


def param_specs(params, mesh: Mesh, stacked_prefixes=("blocks",)
                ) -> Any:
  """PartitionSpec tree matching a params pytree (adaptive divisibility)."""
  def spec_one(path_parts, leaf):
    path = "/".join(str(p) for p in path_parts)
    stacked = any(path.startswith(pref) for pref in stacked_prefixes)
    raw = _spec_for(path, leaf.shape, stacked)
    raw = raw[: len(leaf.shape)]
    return P(*_check_divisibility(raw, leaf.shape, mesh))

  def walk(node, path):
    if isinstance(node, dict):
      return {k: walk(v, path + (k,)) for k, v in node.items()}
    return spec_one(path, node)

  return walk(params, ())


def shardings_for(params, mesh: Mesh):
  specs = param_specs(params, mesh)
  return jax.tree_util.tree_map(
      lambda s: NamedSharding(mesh, s), specs,
      is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, ndim: int) -> P:
  axes = dp_axes(mesh)
  lead = axes if len(axes) > 1 else (axes[0] if axes else None)
  return P(lead, *([None] * (ndim - 1)))


def replicated(mesh: Mesh) -> NamedSharding:
  return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# optimizer-state and decode-cache specs
# ---------------------------------------------------------------------------

def opt_state_specs(params, mesh: Mesh, quantized: bool):
  """Specs for AdamW state. Non-quantized m/v mirror the param specs;
  int8 state is blocked along the LAST axis (shape-preserving), so codes
  reuse the param's spec verbatim and scales reuse it minus the last dim
  — the optimizer update stays collective-free (see optimizer._q8)."""
  pspecs = param_specs(params, mesh)
  if not quantized:
    return {"step": P(), "m": pspecs, "v": pspecs}

  flat_p, tdef = jax.tree_util.tree_flatten(params)
  flat_s = tdef.flatten_up_to(pspecs)

  def q_spec(p, spec):
    parts = tuple(spec)
    parts = parts + (None,) * (len(p.shape) - len(parts))
    code_spec = _check_divisibility(parts, p.shape, mesh)
    scale_spec = code_spec[:-1] + (None,) if code_spec else ()
    return {"codes": P(*code_spec), "scale": P(*scale_spec)}

  qtree = tdef.unflatten([q_spec(p, s) for p, s in zip(flat_p, flat_s)])
  return {"step": P(), "m": qtree, "v": qtree}


def train_state_specs(state_shapes, mesh: Mesh, quantized_opt: bool = False):
  """Spec tree for {"params", "opt"} train state."""
  return {
      "params": param_specs(state_shapes["params"], mesh),
      "opt": opt_state_specs(state_shapes["params"], mesh, quantized_opt),
  }


def cache_specs(cache_shapes, mesh: Mesh, batch: int):
  """Spec tree for a decode cache pytree (stacked leading layer axis).

  Batch shards on the dp axes when divisible; for batch=1 (long-context
  decode) attention caches shard their SEQUENCE dim on "data" instead
  (sequence-parallel cache).  Heads shard on "model" when divisible, else
  head_dim (the contraction all-reduces over "model").
  """
  dp = dp_axes(mesh)
  dp_size = 1
  for a in dp:
    dp_size *= mesh.shape[a]
  dp_lead = dp if len(dp) > 1 else (dp[0] if dp else None)
  batch_ok = batch % dp_size == 0
  mdl = mesh.shape.get("model", 1)
  data = mesh.shape.get("data", 1)

  def spec_one(path_parts, leaf):
    name = str(path_parts[-1])
    shape = leaf.shape
    if len(shape) == 0:
      return P()
    sp = [None] * len(shape)
    # layout: (L, B, ...) for stacked layer caches
    bdim = 1 if str(path_parts[0]) == "layers" else 0
    if len(shape) > bdim and batch_ok and shape[bdim] == batch:
      sp[bdim] = dp_lead
    if name in ("k", "v", "k_codes", "v_codes", "cross_k", "cross_v"):
      hdim, sdim, ddim = bdim + 1, bdim + 2, bdim + 3
      if shape[hdim] % mdl == 0:
        sp[hdim] = "model"
      elif shape[ddim] % mdl == 0:
        sp[ddim] = "model"
      if not batch_ok and shape[sdim] % data == 0:
        sp[sdim] = "data"
    elif name in ("k_scale", "v_scale"):
      hdim, sdim = bdim + 1, bdim + 2
      if shape[hdim] % mdl == 0:
        sp[hdim] = "model"
      if not batch_ok and shape[sdim] % data == 0:
        sp[sdim] = "data"
    elif name == "h":                     # mamba (L, B, di, N)
      if shape[bdim + 1] % mdl == 0:
        sp[bdim + 1] = "model"
    elif name == "conv":                  # (L, B, K-1, di)
      if shape[bdim + 2] % mdl == 0:
        sp[bdim + 2] = "model"
    elif name == "s":                     # rwkv (L, B, H, D, D)
      if shape[bdim + 1] % mdl == 0:
        sp[bdim + 1] = "model"
    return P(*sp)

  def walk(node, path):
    if isinstance(node, dict):
      return {k: walk(v, path + (k,)) for k, v in node.items()}
    return spec_one(path, node)

  return walk(cache_shapes, ())


def to_shardings(spec_tree, mesh: Mesh):
  return jax.tree_util.tree_map(
      lambda s: NamedSharding(mesh, s), spec_tree,
      is_leaf=lambda x: isinstance(x, P))
