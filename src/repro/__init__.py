"""repro: QUIDAM on TPU — quantization-aware accelerator/model co-exploration
as a first-class feature of a multi-pod JAX training/serving framework.

Subpackages:
  core      the paper's contribution (PE types, PPA models, DSE, supernet)
  quant     framework-level quantization policies (QAT + deploy codecs)
  models    architecture zoo (dense / MoE / hybrid / SSM / enc-dec / VLM)
  configs   assigned architectures x input shapes
  parallel  sharding rules, mesh logic, compressed collectives
  train     optimizer, train step, checkpointing, fault tolerance
  serve     batched serving engine with quantized KV caches
  data      synthetic token + image pipelines
  kernels   Pallas TPU kernels (pow2/int8 matmul, quant decode attn, rwkv6)
  launch    mesh / dryrun / train / serve / roofline drivers
"""
__version__ = "1.0.0"
