"""ConfigTable: struct-of-arrays design points for the vectorized path.

QUIDAM's headline speedup comes from evaluating *many* design points
cheaply.  A list of per-point :class:`~repro.core.dataflow.AcceleratorConfig`
dataclasses caps that at Python-object speed; a :class:`ConfigTable` holds
the same design points as parallel numpy columns so the batch oracle
(:mod:`repro.core.oracle` ``*_batch``), the batch RS-dataflow model
(:mod:`repro.core.dataflow` ``*_batch``), and the vector backends
(:class:`repro.explore.VectorOracleBackend`) stay array-at-a-time from
sampling to :class:`~repro.explore.ResultFrame`.

PE types are stored as small integer codes into a per-table name vocabulary
(``pe_type_names``); per-PE constants (bit widths, gate counts, energies)
expand to per-row arrays via :meth:`pe_const` lookups.

Conversion is lossless both ways: ``ConfigTable.from_configs(cfgs)`` and
``table.to_configs()`` round-trip exactly, and ``table.config_at(i)``
materializes a single row on demand (the only place a dataclass is built).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import pe as pe_lib
from repro.core.dataflow import AcceleratorConfig

# column order mirrors AcceleratorConfig's field order (minus pe_type)
INT_COLUMNS = ("pe_rows", "pe_cols", "sp_if", "sp_fw", "sp_ps", "gbuf_kb")
FLOAT_COLUMNS = ("bandwidth_gbps",)
COLUMNS = INT_COLUMNS + FLOAT_COLUMNS


@dataclasses.dataclass(eq=False)
class ConfigTable:
  """N design points as parallel columns (one row == one AcceleratorConfig).

  ``pe_code[i]`` indexes ``pe_type_names``; integer knobs are int64 columns
  and ``bandwidth_gbps`` is float64.
  """
  pe_code: np.ndarray
  pe_type_names: Tuple[str, ...]
  pe_rows: np.ndarray
  pe_cols: np.ndarray
  sp_if: np.ndarray
  sp_fw: np.ndarray
  sp_ps: np.ndarray
  gbuf_kb: np.ndarray
  bandwidth_gbps: np.ndarray

  def __post_init__(self):
    self.pe_code = np.asarray(self.pe_code, np.int64)
    for name in INT_COLUMNS:
      setattr(self, name, np.asarray(getattr(self, name), np.int64))
    self.bandwidth_gbps = np.asarray(self.bandwidth_gbps, np.float64)
    self.pe_type_names = tuple(self.pe_type_names)
    for name in self.pe_type_names:
      pe_lib.pe_type(name)  # validate the vocabulary eagerly
    n = self.pe_code.shape[0]
    for name in COLUMNS:
      col = getattr(self, name)
      if col.shape != (n,):
        raise ValueError(f"column {name!r} has shape {col.shape}, "
                         f"expected ({n},)")
    if n and (self.pe_code.min() < 0
              or self.pe_code.max() >= len(self.pe_type_names)):
      raise ValueError("pe_code out of range for pe_type_names")

  def __len__(self) -> int:
    return int(self.pe_code.shape[0])

  # -- derived columns -----------------------------------------------------

  @property
  def n_pe(self) -> np.ndarray:
    return self.pe_rows * self.pe_cols

  def pe_type_strings(self) -> np.ndarray:
    """Per-row PE type names (the ResultFrame ``pe_type`` column)."""
    return np.asarray(self.pe_type_names)[self.pe_code]

  def pe_const(self, field: str) -> np.ndarray:
    """Per-row PEType constant (e.g. ``act_bits``, ``critical_path_ns``)
    expanded from the type vocabulary by code lookup."""
    vocab = np.asarray(
        [float(getattr(pe_lib.pe_type(t), field)) for t in self.pe_type_names],
        np.float64)
    return vocab[self.pe_code]

  # per-row PEType constants the batch oracle/dataflow formulas consume
  PE_CONST_FIELDS = ("act_bits", "weight_bits", "psum_bits", "arith_gates",
                     "mac_energy_pj", "critical_path_ns")

  def numeric_columns(self) -> Dict[str, np.ndarray]:
    """All-float64 column dict (knobs + ``n_pe`` + per-row PE constants).

    This is the array bundle every ``*_batch`` formula consumes; it is a
    plain dict so the optional ``jax.jit`` device path can trace straight
    through it (a traced ConfigTable would drag numpy-only lookups into
    the jaxpr).
    """
    cols = {name: getattr(self, name).astype(np.float64) for name in COLUMNS}
    cols["n_pe"] = self.n_pe.astype(np.float64)
    for field in self.PE_CONST_FIELDS:
      cols[field] = self.pe_const(field)
    return cols

  def hw_features(self) -> np.ndarray:
    """(N, 4) power/area feature matrix: SP_if, SP_ps, SP_fw, #PE."""
    return np.stack([
        self.sp_if.astype(np.float64), self.sp_ps.astype(np.float64),
        self.sp_fw.astype(np.float64), self.n_pe.astype(np.float64)], axis=1)

  def latency_hw_features(self) -> np.ndarray:
    """(N, 6) latency hardware features: SP_if, SP_ps, SP_fw, rows, cols,
    GBS."""
    return np.stack([
        self.sp_if.astype(np.float64), self.sp_ps.astype(np.float64),
        self.sp_fw.astype(np.float64), self.pe_rows.astype(np.float64),
        self.pe_cols.astype(np.float64), self.gbuf_kb.astype(np.float64)],
        axis=1)

  # -- construction / conversion -------------------------------------------

  @classmethod
  def from_columns(cls, pe_type: Sequence[str],
                   columns: Mapping[str, np.ndarray]) -> "ConfigTable":
    """Build from a per-row PE-type name sequence + named value columns."""
    missing = set(COLUMNS) - set(columns)
    if missing:
      raise ValueError(f"missing columns {sorted(missing)}")
    names = np.asarray(pe_type)
    vocab, codes = np.unique(names, return_inverse=True)
    return cls(pe_code=codes, pe_type_names=tuple(str(t) for t in vocab),
               **{name: np.asarray(columns[name]) for name in COLUMNS})

  @classmethod
  def from_configs(cls, cfgs: Sequence[AcceleratorConfig]) -> "ConfigTable":
    cfgs = list(cfgs)
    return cls.from_columns(
        [c.pe_type for c in cfgs],
        {name: np.asarray([getattr(c, name) for c in cfgs])
         for name in COLUMNS})

  @classmethod
  def full(cls, pe_type: str, n: int, columns: Mapping[str, np.ndarray]
           ) -> "ConfigTable":
    """Single-PE-type table (the common per-type sampling case)."""
    return cls(pe_code=np.zeros(n, np.int64), pe_type_names=(pe_type,),
               **{name: np.asarray(columns[name]) for name in COLUMNS})

  def config_at(self, i: int) -> AcceleratorConfig:
    """Materialize one row as a dataclass (the only scalar escape hatch)."""
    return AcceleratorConfig(
        pe_type=self.pe_type_names[int(self.pe_code[i])],
        **{name: int(getattr(self, name)[i]) for name in INT_COLUMNS},
        bandwidth_gbps=float(self.bandwidth_gbps[i]))

  def to_configs(self) -> List[AcceleratorConfig]:
    return [self.config_at(i) for i in range(len(self))]

  def __iter__(self) -> Iterator[AcceleratorConfig]:
    return (self.config_at(i) for i in range(len(self)))

  # -- slicing / combination -----------------------------------------------

  def select(self, index) -> "ConfigTable":
    """Sub-table by boolean mask, slice, or integer index array."""
    idx = index if isinstance(index, slice) else np.asarray(index)
    return ConfigTable(
        pe_code=self.pe_code[idx], pe_type_names=self.pe_type_names,
        **{name: getattr(self, name)[idx] for name in COLUMNS})

  def chunks(self, chunk_size: int) -> Iterator["ConfigTable"]:
    """Bounded-memory iteration: successive row slices of <= chunk_size."""
    if chunk_size <= 0:
      raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    for lo in range(0, len(self), chunk_size):
      yield self.select(slice(lo, lo + chunk_size))

  @classmethod
  def concat(cls, tables: Sequence["ConfigTable"]) -> "ConfigTable":
    tables = list(tables)
    if not tables:
      raise ValueError("cannot concat zero tables")
    vocab = sorted({t for tbl in tables for t in tbl.pe_type_names})
    code_of = {t: i for i, t in enumerate(vocab)}
    codes = np.concatenate([
        np.asarray([code_of[t] for t in tbl.pe_type_names],
                   np.int64)[tbl.pe_code]
        for tbl in tables])
    return cls(pe_code=codes, pe_type_names=tuple(vocab),
               **{name: np.concatenate([getattr(t, name) for t in tables])
                  for name in COLUMNS})

  def groups_by_type(self) -> Iterator[Tuple[str, np.ndarray]]:
    """Yield (pe_type_name, row-index array) for each type present."""
    for code, name in enumerate(self.pe_type_names):
      idx = np.flatnonzero(self.pe_code == code)
      if idx.size:
        yield name, idx

  def __repr__(self) -> str:
    return (f"ConfigTable({len(self)} rows, "
            f"pe_types={list(self.pe_type_names)})")
