"""ConfigTable: struct-of-arrays design points for the vectorized path.

QUIDAM's headline speedup comes from evaluating *many* design points
cheaply.  A list of per-point :class:`~repro.core.dataflow.AcceleratorConfig`
dataclasses caps that at Python-object speed; a :class:`ConfigTable` holds
the same design points as parallel numpy columns so the batch oracle
(:mod:`repro.core.oracle` ``*_batch``), the batch RS-dataflow model
(:mod:`repro.core.dataflow` ``*_batch``), and the vector backends
(:class:`repro.explore.VectorOracleBackend`) stay array-at-a-time from
sampling to :class:`~repro.explore.ResultFrame`.

PE types are stored as small integer codes into a per-table name vocabulary
(``pe_type_names``); per-PE constants (bit widths, gate counts, energies)
expand to per-row arrays via :meth:`pe_const` lookups.

Conversion is lossless both ways: ``ConfigTable.from_configs(cfgs)`` and
``table.to_configs()`` round-trip exactly, and ``table.config_at(i)``
materializes a single row on demand (the only place a dataclass is built).

For HW x NN co-exploration the cross product of a ConfigTable with N
integer-coded architectures is represented by :class:`JointTable`
(``table.cross(n_archs)``): joint rows exist only as (arch_id, hw_index)
index arithmetic — a million-pair sweep never materializes per-pair
Python objects, and the HW columns are stored once, not ``n_archs``
times.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import pe as pe_lib
from repro.core.dataflow import AcceleratorConfig

# column order mirrors AcceleratorConfig's field order (minus pe_type)
INT_COLUMNS = ("pe_rows", "pe_cols", "sp_if", "sp_fw", "sp_ps", "gbuf_kb")
FLOAT_COLUMNS = ("bandwidth_gbps",)
COLUMNS = INT_COLUMNS + FLOAT_COLUMNS


def scratch_buf(scratch: Optional[Dict], key: str, n: int,
                dtype) -> Optional[np.ndarray]:
  """Reusable per-caller buffer: ``scratch[key]`` when shape/dtype still
  match, else a fresh allocation registered back into ``scratch``.  The
  shared protocol behind chunked-sweep temporary reuse (consumed here by
  :meth:`ConfigTable.numeric_columns` and by
  :func:`repro.core.oracle.batch_inputs`); returns None when ``scratch``
  is None so callers can fall back to plain allocation."""
  if scratch is None:
    return None
  buf = scratch.get(key)
  if buf is None or buf.shape != (n,) or buf.dtype != dtype:
    buf = np.empty(n, dtype)
    scratch[key] = buf
  return buf


@dataclasses.dataclass(eq=False)
class ConfigTable:
  """N design points as parallel columns (one row == one AcceleratorConfig).

  ``pe_code[i]`` indexes ``pe_type_names``; integer knobs are int64 columns
  and ``bandwidth_gbps`` is float64.
  """
  pe_code: np.ndarray
  pe_type_names: Tuple[str, ...]
  pe_rows: np.ndarray
  pe_cols: np.ndarray
  sp_if: np.ndarray
  sp_fw: np.ndarray
  sp_ps: np.ndarray
  gbuf_kb: np.ndarray
  bandwidth_gbps: np.ndarray

  def __post_init__(self):
    self.pe_code = np.asarray(self.pe_code, np.int64)
    for name in INT_COLUMNS:
      setattr(self, name, np.asarray(getattr(self, name), np.int64))
    self.bandwidth_gbps = np.asarray(self.bandwidth_gbps, np.float64)
    self.pe_type_names = tuple(self.pe_type_names)
    for name in self.pe_type_names:
      pe_lib.pe_type(name)  # validate the vocabulary eagerly
    n = self.pe_code.shape[0]
    for name in COLUMNS:
      col = getattr(self, name)
      if col.shape != (n,):
        raise ValueError(f"column {name!r} has shape {col.shape}, "
                         f"expected ({n},)")
    if n and (self.pe_code.min() < 0
              or self.pe_code.max() >= len(self.pe_type_names)):
      raise ValueError("pe_code out of range for pe_type_names")

  def __len__(self) -> int:
    return int(self.pe_code.shape[0])

  # -- derived columns -----------------------------------------------------

  @property
  def n_pe(self) -> np.ndarray:
    return self.pe_rows * self.pe_cols

  def pe_type_strings(self) -> np.ndarray:
    """Per-row PE type names (the ResultFrame ``pe_type`` column)."""
    return np.asarray(self.pe_type_names)[self.pe_code]

  def _pe_const_vocab(self, field: str) -> np.ndarray:
    """Per-type constant vocabulary for one PEType field."""
    return np.asarray(
        [float(getattr(pe_lib.pe_type(t), field)) for t in self.pe_type_names],
        np.float64)

  def pe_const(self, field: str) -> np.ndarray:
    """Per-row PEType constant (e.g. ``act_bits``, ``critical_path_ns``)
    expanded from the type vocabulary by code lookup."""
    return self._pe_const_vocab(field)[self.pe_code]

  # per-row PEType constants the batch oracle/dataflow formulas consume
  PE_CONST_FIELDS = ("act_bits", "weight_bits", "psum_bits", "arith_gates",
                     "mac_energy_pj", "critical_path_ns")

  def numeric_columns(self, scratch: Optional[Dict[str, np.ndarray]] = None
                      ) -> Dict[str, np.ndarray]:
    """All-float64 column dict (knobs + ``n_pe`` + per-row PE constants).

    This is the array bundle every ``*_batch`` formula consumes; it is a
    plain dict so the optional ``jax.jit`` device path can trace straight
    through it (a traced ConfigTable would drag numpy-only lookups into
    the jaxpr).

    ``scratch`` (a caller-owned dict, one per worker thread) lets chunked
    sweeps reuse the per-chunk float64 buffers instead of allocating a
    fresh set per call; the returned dict then aliases the scratch
    buffers, so consume it before the next call with the same scratch.
    """
    n = len(self)

    def fill(key: str, src: np.ndarray) -> np.ndarray:
      b = scratch_buf(scratch, key, n, np.float64)
      if b is None:
        return src.astype(np.float64)
      b[...] = src
      return b

    cols = {name: fill(name, getattr(self, name)) for name in COLUMNS}
    cols["n_pe"] = fill("n_pe", self.n_pe)
    for field in self.PE_CONST_FIELDS:
      vocab = self._pe_const_vocab(field)
      b = scratch_buf(scratch, field, n, np.float64)
      if b is None:
        cols[field] = vocab[self.pe_code]
      else:
        cols[field] = np.take(vocab, self.pe_code, out=b)
    return cols

  def hw_features(self) -> np.ndarray:
    """(N, 4) power/area feature matrix: SP_if, SP_ps, SP_fw, #PE."""
    return np.stack([
        self.sp_if.astype(np.float64), self.sp_ps.astype(np.float64),
        self.sp_fw.astype(np.float64), self.n_pe.astype(np.float64)], axis=1)

  def latency_hw_features(self) -> np.ndarray:
    """(N, 6) latency hardware features: SP_if, SP_ps, SP_fw, rows, cols,
    GBS."""
    return np.stack([
        self.sp_if.astype(np.float64), self.sp_ps.astype(np.float64),
        self.sp_fw.astype(np.float64), self.pe_rows.astype(np.float64),
        self.pe_cols.astype(np.float64), self.gbuf_kb.astype(np.float64)],
        axis=1)

  # -- construction / conversion -------------------------------------------

  @classmethod
  def from_columns(cls, pe_type: Sequence[str],
                   columns: Mapping[str, np.ndarray]) -> "ConfigTable":
    """Build from a per-row PE-type name sequence + named value columns."""
    missing = set(COLUMNS) - set(columns)
    if missing:
      raise ValueError(f"missing columns {sorted(missing)}")
    names = np.asarray(pe_type)
    vocab, codes = np.unique(names, return_inverse=True)
    return cls(pe_code=codes, pe_type_names=tuple(str(t) for t in vocab),
               **{name: np.asarray(columns[name]) for name in COLUMNS})

  @classmethod
  def from_configs(cls, cfgs: Sequence[AcceleratorConfig]) -> "ConfigTable":
    cfgs = list(cfgs)
    return cls.from_columns(
        [c.pe_type for c in cfgs],
        {name: np.asarray([getattr(c, name) for c in cfgs])
         for name in COLUMNS})

  @classmethod
  def full(cls, pe_type: str, n: int, columns: Mapping[str, np.ndarray]
           ) -> "ConfigTable":
    """Single-PE-type table (the common per-type sampling case)."""
    return cls(pe_code=np.zeros(n, np.int64), pe_type_names=(pe_type,),
               **{name: np.asarray(columns[name]) for name in COLUMNS})

  def config_at(self, i: int) -> AcceleratorConfig:
    """Materialize one row as a dataclass (the only scalar escape hatch)."""
    return AcceleratorConfig(
        pe_type=self.pe_type_names[int(self.pe_code[i])],
        **{name: int(getattr(self, name)[i]) for name in INT_COLUMNS},
        bandwidth_gbps=float(self.bandwidth_gbps[i]))

  def to_configs(self) -> List[AcceleratorConfig]:
    return [self.config_at(i) for i in range(len(self))]

  def __iter__(self) -> Iterator[AcceleratorConfig]:
    return (self.config_at(i) for i in range(len(self)))

  # -- slicing / combination -----------------------------------------------

  def select(self, index) -> "ConfigTable":
    """Sub-table by boolean mask, slice, or integer index array."""
    idx = index if isinstance(index, slice) else np.asarray(index)
    return ConfigTable(
        pe_code=self.pe_code[idx], pe_type_names=self.pe_type_names,
        **{name: getattr(self, name)[idx] for name in COLUMNS})

  def chunks(self, chunk_size: int) -> Iterator["ConfigTable"]:
    """Bounded-memory iteration: successive row slices of <= chunk_size."""
    if chunk_size <= 0:
      raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    for lo in range(0, len(self), chunk_size):
      yield self.select(slice(lo, lo + chunk_size))

  @classmethod
  def concat(cls, tables: Sequence["ConfigTable"]) -> "ConfigTable":
    tables = list(tables)
    if not tables:
      raise ValueError("cannot concat zero tables")
    vocab = sorted({t for tbl in tables for t in tbl.pe_type_names})
    code_of = {t: i for i, t in enumerate(vocab)}
    codes = np.concatenate([
        np.asarray([code_of[t] for t in tbl.pe_type_names],
                   np.int64)[tbl.pe_code]
        for tbl in tables])
    return cls(pe_code=codes, pe_type_names=tuple(vocab),
               **{name: np.concatenate([getattr(t, name) for t in tables])
                  for name in COLUMNS})

  def groups_by_type(self) -> Iterator[Tuple[str, np.ndarray]]:
    """Yield (pe_type_name, row-index array) for each type present."""
    for code, name in enumerate(self.pe_type_names):
      idx = np.flatnonzero(self.pe_code == code)
      if idx.size:
        yield name, idx

  def cross(self, n_archs: int) -> "JointTable":
    """Cross product with ``n_archs`` integer-coded architectures."""
    return JointTable(hw=self, n_archs=n_archs)

  def row_keys(self) -> List[bytes]:
    """Per-row identity keys: equal keys iff equal design points (PE type
    name + every knob value), independent of each table's ``pe_code``
    vocabulary — so keys compare across tables built by different
    samplers.  O(n) Python-level keys, intended for population-scale
    dedup (the guided-search evaluated-points archive, shim regression
    pins), not million-row sweeps."""
    vals = np.ascontiguousarray(np.stack(
        [getattr(self, name).astype(np.float64) for name in COLUMNS],
        axis=1))
    names = self.pe_type_strings()
    return [str(names[i]).encode() + b"|" + vals[i].tobytes()
            for i in range(len(self))]

  def __repr__(self) -> str:
    return (f"ConfigTable({len(self)} rows, "
            f"pe_types={list(self.pe_type_names)})")


# ---------------------------------------------------------------------------
# joint HW x NN cross product
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class JointTable:
  """The cross product of ``n_archs`` architectures x a HW ConfigTable.

  Joint rows are ordered arch-major — row ``i`` pairs architecture
  ``i // len(hw)`` with HW design point ``i % len(hw)`` — matching the
  scalar ``co_explore`` loop order (per PE type: for arch, for hw).  The
  HW columns are stored once; ``arch_ids()`` / ``hw_indices()`` are pure
  index arithmetic and :meth:`materialize` tiles the columns only when a
  caller genuinely needs a flat ``n_archs * n_hw``-row ConfigTable.
  Architectures live outside the table as integer codes (the
  ResultFrame's ``arch_lookup`` maps them back to objects).
  """
  hw: ConfigTable
  n_archs: int

  def __post_init__(self):
    self.n_archs = int(self.n_archs)
    if self.n_archs < 0:
      raise ValueError(f"n_archs must be >= 0, got {self.n_archs}")

  def __len__(self) -> int:
    return self.n_archs * len(self.hw)

  @property
  def n_hw(self) -> int:
    return len(self.hw)

  @property
  def pe_type_names(self) -> Tuple[str, ...]:
    return self.hw.pe_type_names

  def arch_ids(self) -> np.ndarray:
    """Per-joint-row architecture code (arch-major repeat)."""
    return np.repeat(np.arange(self.n_archs, dtype=np.int64), self.n_hw)

  def hw_indices(self) -> np.ndarray:
    """Per-joint-row index into the underlying HW table."""
    return np.tile(np.arange(self.n_hw, dtype=np.int64), self.n_archs)

  def pe_type_strings(self) -> np.ndarray:
    return np.tile(self.hw.pe_type_strings(), self.n_archs)

  def pair_at(self, i: int) -> Tuple[int, AcceleratorConfig]:
    """(arch_id, hw config) of joint row ``i``."""
    i = int(i)
    if not 0 <= i < len(self):
      raise IndexError(f"joint row {i} out of range for {len(self)} rows")
    return i // self.n_hw, self.hw.config_at(i % self.n_hw)

  def config_at(self, i: int) -> AcceleratorConfig:
    """HW half of joint row ``i`` (ResultFrame design-point protocol)."""
    return self.pair_at(i)[1]

  def select(self, index) -> ConfigTable:
    """HW columns of the selected joint rows as a flat ConfigTable (used
    by ResultFrame.select; arch codes ride along in the frame's
    ``arch_id`` column, so only the HW half is gathered here)."""
    if isinstance(index, slice):
      index = np.arange(len(self))[index]
    idx = np.asarray(index)
    if idx.dtype == np.bool_:
      idx = np.flatnonzero(idx)
    return self.hw.select(idx % max(self.n_hw, 1))

  def block_slices(self, chunk_size: int
                   ) -> Iterator[Tuple[slice, slice]]:
    """Tile the arch x HW cross product into (arch_slice, hw_slice)
    blocks of <= chunk_size joint rows — the streaming engine's unit of
    work.  HW chunks span as many rows as fit; the arch axis splits into
    blocks of ``chunk_size // hw_chunk`` so a 100M-pair sweep is visited
    as a few hundred bounded blocks, never materialized."""
    if chunk_size <= 0:
      raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    n_hw = self.n_hw
    if not n_hw or not self.n_archs:
      return
    hw_chunk = min(n_hw, chunk_size)
    arch_block = max(1, chunk_size // hw_chunk)
    for a_lo in range(0, self.n_archs, arch_block):
      a_sl = slice(a_lo, min(a_lo + arch_block, self.n_archs))
      for h_lo in range(0, n_hw, hw_chunk):
        yield a_sl, slice(h_lo, min(h_lo + hw_chunk, n_hw))

  def block_indices(self, arch_slice: slice, hw_slice: slice) -> np.ndarray:
    """Joint row ids of one block, flattened arch-major — i.e. in the
    exact row order :meth:`~repro.explore.backend.VectorOracleBackend.\
co_evaluate_table` emits for the block's sub-table/sub-stack."""
    a = np.arange(arch_slice.start, arch_slice.stop, dtype=np.int64)
    h = np.arange(hw_slice.start, hw_slice.stop, dtype=np.int64)
    return (a[:, None] * self.n_hw + h[None, :]).reshape(-1)

  def materialize(self) -> ConfigTable:
    """Flat ``n_archs * n_hw``-row ConfigTable (numpy tiling, no Python
    per-pair objects) — the escape hatch for consumers of plain tables."""
    return self.hw.select(self.hw_indices())

  def to_configs(self) -> List[AcceleratorConfig]:
    """Per-joint-row HW configs (the all-Python escape hatch; completes
    the ConfigTable protocol ResultFrame.to_points relies on)."""
    return self.hw.to_configs() * self.n_archs

  def __repr__(self) -> str:
    return (f"JointTable({self.n_archs} archs x {self.n_hw} hw rows = "
            f"{len(self)} pairs, pe_types={list(self.hw.pe_type_names)})")
