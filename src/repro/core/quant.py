"""Quantization schemes used by QUIDAM processing elements.

Implements the paper's Eq. (1) family: LightNN-style *sum of powers of two*
weight quantization (Ding et al., GLSVLSI'17 / TRETS'18), plus conventional
symmetric integer quantization (INT4/8/16) and FP32 passthrough.

All quantizers share the same contract:

    q = quantize(w)          # codes (+ scale), pytree of arrays
    w_hat = dequantize(q)    # exact float reconstruction of the code
    w_fake = fake_quant(w)   # dequantize(quantize(w)) with a straight-
                             # through estimator, for QAT

Power-of-two codes
------------------
LightPE-1 stores ``w = s * (+/- 2^-m)``, m in [0, 7]  -> 4-bit code
  (1 sign bit + 3 exponent bits), plus a per-channel fp scale ``s``.
LightPE-2 stores ``w = s * (+/- (2^-m1 + 2^-m2))``    -> 7-bit code in 8 bits
  (1 sign + 3 + 3), m1 <= m2.

Because 2^-m and 2^-m1 + 2^-m2 are exactly representable in bf16/fp32, the
TPU-side "shift-add" is realized by decoding codes to exact floats and using
the MXU; no precision is lost relative to an ASIC shifter implementation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

# Exponent range of the paper: m in {0, 1, ..., 7}.
POW2_M_MAX = 7


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _channel_absmax(w: jax.Array, axis: Optional[int]) -> jax.Array:
  """Per-channel (or per-tensor when axis is None) absmax scale, >= tiny."""
  if axis is None:
    s = jnp.max(jnp.abs(w))
  else:
    red = tuple(i for i in range(w.ndim) if i != axis % w.ndim)
    s = jnp.max(jnp.abs(w), axis=red, keepdims=True)
  return jnp.maximum(s, jnp.finfo(jnp.float32).tiny)


def _ste(real: jax.Array, quant: jax.Array) -> jax.Array:
  """Straight-through estimator: forward=quant, backward=identity."""
  return real + jax.lax.stop_gradient(quant - real)


# ---------------------------------------------------------------------------
# sum-of-powers-of-two (LightPE) codes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Pow2Quantized:
  """Packed power-of-two code.

  codes: uint8 array, same shape as w.
    k=1: bit3 = sign, bits2..0 = m           (valid range 0..15)
    k=2: bit6 = sign, bits5..3 = m1, 2..0 = m2 (m1 <= m2)
  scale: broadcastable float32 scale (per channel or scalar).
  k: number of power-of-two terms (1 or 2).
  """
  codes: jax.Array
  scale: jax.Array
  k: int

  def tree_flatten(self):
    return (self.codes, self.scale), self.k

  @classmethod
  def tree_unflatten(cls, k, leaves):
    return cls(leaves[0], leaves[1], k)


jax.tree_util.register_pytree_node(
    Pow2Quantized, Pow2Quantized.tree_flatten, Pow2Quantized.tree_unflatten)


def pow2_codebook(k: int) -> jnp.ndarray:
  """All positive codebook values for k terms, and their (m1, m2) codes.

  k=1: 8 values 2^-m.  k=2: 36 values 2^-m1 + 2^-m2 with m1 <= m2 (duplicate
  exponents encode single powers exactly: 2^-(m+1) + 2^-(m+1) == 2^-m).
  Returns (values[f32], code_low_bits[uint8]) sorted by value.
  """
  import numpy as _np
  if k == 1:
    ms = _np.arange(POW2_M_MAX + 1)
    return (jnp.asarray(2.0 ** (-ms), jnp.float32),
            jnp.asarray(ms, jnp.uint8))
  m1, m2 = _np.meshgrid(_np.arange(POW2_M_MAX + 1),
                        _np.arange(POW2_M_MAX + 1), indexing="ij")
  keep = (m1 <= m2).reshape(-1)
  m1 = m1.reshape(-1)[keep]
  m2 = m2.reshape(-1)[keep]
  vals = 2.0 ** (-m1.astype(_np.float64)) + 2.0 ** (-m2.astype(_np.float64))
  return (jnp.asarray(vals, jnp.float32),
          jnp.asarray(m1 * 8 + m2, jnp.uint8))


def pow2_quantize(w: jax.Array, k: int = 1, channel_axis: Optional[int] = 0,
                  scale: Optional[jax.Array] = None) -> Pow2Quantized:
  """Quantize weights to s * (+/- sum_{i<k} 2^-m_i), exact codebook argmin."""
  assert k in (1, 2), "paper defines LightPE-1 (k=1) and LightPE-2 (k=2)"
  w = w.astype(jnp.float32)
  if scale is None:
    scale = _channel_absmax(w, channel_axis)
  a = w / scale
  sign_neg = a < 0
  mag = jnp.abs(a)
  vals, low_codes = pow2_codebook(k)
  # argmin over the (8 or 36)-entry codebook, vectorized on a trailing axis.
  err = jnp.abs(mag[..., None] - vals)
  best = jnp.argmin(err, axis=-1)
  low = low_codes[best]
  sign_bit = 8 if k == 1 else 64
  codes = (jnp.where(sign_neg, sign_bit, 0) + low).astype(jnp.uint8)
  return Pow2Quantized(codes, scale, k)


def pow2_decode_codes(codes: jax.Array, k: int) -> jax.Array:
  """Decode uint8 codes to exact float32 in [-2, 2] (pre-scale values)."""
  c = codes.astype(jnp.int32)
  if k == 1:
    sign = jnp.where((c & 8) != 0, -1.0, 1.0)
    m = (c & 7).astype(jnp.float32)
    return sign * 2.0 ** (-m)
  sign = jnp.where((c & 64) != 0, -1.0, 1.0)
  m1 = ((c >> 3) & 7).astype(jnp.float32)
  m2 = (c & 7).astype(jnp.float32)
  return sign * (2.0 ** (-m1) + 2.0 ** (-m2))


def pow2_dequantize(q: Pow2Quantized) -> jax.Array:
  return pow2_decode_codes(q.codes, q.k) * q.scale


def pow2_fake_quant(w: jax.Array, k: int = 1,
                    channel_axis: Optional[int] = 0) -> jax.Array:
  """QAT forward: dequant(quant(w)) with straight-through gradients."""
  q = pow2_quantize(jax.lax.stop_gradient(w), k=k, channel_axis=channel_axis)
  return _ste(w, pow2_dequantize(q).astype(w.dtype))


# ---------------------------------------------------------------------------
# symmetric integer codes (INT4 / INT8 / INT16)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IntQuantized:
  codes: jax.Array      # int8 or int16 (int4 stored unpacked in int8)
  scale: jax.Array      # float32, broadcastable
  bits: int

  def tree_flatten(self):
    return (self.codes, self.scale), self.bits

  @classmethod
  def tree_unflatten(cls, bits, leaves):
    return cls(leaves[0], leaves[1], bits)


jax.tree_util.register_pytree_node(
    IntQuantized, IntQuantized.tree_flatten, IntQuantized.tree_unflatten)


def int_quantize(w: jax.Array, bits: int = 8,
                 channel_axis: Optional[int] = 0,
                 scale: Optional[jax.Array] = None) -> IntQuantized:
  assert bits in (4, 8, 16)
  w = w.astype(jnp.float32)
  qmax = 2 ** (bits - 1) - 1
  if scale is None:
    scale = _channel_absmax(w, channel_axis) / qmax
  codes = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax)
  dtype = jnp.int8 if bits <= 8 else jnp.int16
  return IntQuantized(codes.astype(dtype), scale, bits)


def int_dequantize(q: IntQuantized) -> jax.Array:
  return q.codes.astype(jnp.float32) * q.scale


def int_fake_quant(w: jax.Array, bits: int = 8,
                   channel_axis: Optional[int] = 0) -> jax.Array:
  q = int_quantize(jax.lax.stop_gradient(w), bits=bits,
                   channel_axis=channel_axis)
  return _ste(w, int_dequantize(q).astype(w.dtype))


# ---------------------------------------------------------------------------
# activation quantization (8-bit for LightPEs per the paper)
# ---------------------------------------------------------------------------

def act_fake_quant(x: jax.Array, bits: int = 8) -> jax.Array:
  """Dynamic per-tensor symmetric activation fake-quant (QAT)."""
  qmax = 2 ** (bits - 1) - 1
  s = jnp.maximum(jnp.max(jnp.abs(jax.lax.stop_gradient(x))),
                  jnp.finfo(jnp.float32).tiny) / qmax
  q = jnp.clip(jnp.round(x / s), -qmax - 1, qmax) * s
  return _ste(x, q.astype(x.dtype))


# ---------------------------------------------------------------------------
# packing (storage formats; kernels consume these)
# ---------------------------------------------------------------------------

def pack_nibbles(codes: jax.Array) -> jax.Array:
  """Pack pairs of 4-bit codes (uint8 each, <16) along the last axis."""
  assert codes.shape[-1] % 2 == 0
  lo = codes[..., 0::2].astype(jnp.uint8)
  hi = codes[..., 1::2].astype(jnp.uint8)
  return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(packed: jax.Array) -> jax.Array:
  lo = packed & 0xF
  hi = (packed >> 4) & 0xF
  return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1],
                                              packed.shape[-1] * 2)


def pack_int4(codes: jax.Array) -> jax.Array:
  """Pack int4 values (int8 in [-8, 7]) into uint8 pairs."""
  u = (codes.astype(jnp.int32) & 0xF).astype(jnp.uint8)
  return pack_nibbles(u)


def unpack_int4(packed: jax.Array) -> jax.Array:
  u = unpack_nibbles(packed).astype(jnp.int32)
  return jnp.where(u >= 8, u - 16, u).astype(jnp.int8)


# ---------------------------------------------------------------------------
# unified dispatch keyed by PE type name (see core.pe)
# ---------------------------------------------------------------------------

def fake_quant_for_pe(w: jax.Array, pe_type: str,
                      channel_axis: Optional[int] = 0) -> jax.Array:
  """Weight fake-quant matching a QUIDAM PE type's numerics."""
  if pe_type == "FP32":
    return w
  if pe_type == "INT16":
    return int_fake_quant(w, 16, channel_axis)
  if pe_type == "INT8":
    return int_fake_quant(w, 8, channel_axis)
  if pe_type == "INT4":
    return int_fake_quant(w, 4, channel_axis)
  if pe_type == "LightPE-1":
    return pow2_fake_quant(w, 1, channel_axis)
  if pe_type == "LightPE-2":
    return pow2_fake_quant(w, 2, channel_axis)
  raise ValueError(f"unknown PE type {pe_type!r}")


def act_fake_quant_for_pe(x: jax.Array, pe_type: str) -> jax.Array:
  """Activation fake-quant matching a PE type (paper: 8b acts on LightPEs)."""
  if pe_type == "FP32":
    return x
  if pe_type == "INT16":
    return act_fake_quant(x, 16)
  return act_fake_quant(x, 8)
