"""One documented seed-derivation helper for the whole repo.

Ad-hoc child-seed arithmetic (``seed * 7 + split``, ``seed + 999``,
``seed * 1_000_003 + step``) has two failure modes the analysis pass
(rule DET005) exists to catch:

  * **collisions** — linear maps intersect: ``seed*7 + split`` gives the
    same RNG stream for ``(seed=0, split=7)`` and ``(seed=1, split=0)``,
    so two "independent" datasets silently share every sample;
  * **overflow/clipping** — ``% 2**31`` folds distinct (seed, step)
    pairs onto each other in structured ways, and unreduced products
    overflow numpy's int64 seed range for large steps.

:func:`derive_seed` replaces all of it: a labelled splitmix64 chain over
the components.  The label keeps unrelated consumers (e.g. the Markov
stream vs the image sampler) on disjoint streams even for identical
numeric components; splitmix64's avalanche makes structurally related
inputs (seed, seed+1) statistically unrelated outputs.  Deterministic
across platforms and Python versions (string labels hash via SHA-256,
never ``hash()``).
"""
from __future__ import annotations

import hashlib
import struct
from typing import Union

_MASK64 = (1 << 64) - 1
_GOLDEN = 0x9E3779B97F4A7C15  # splitmix64 stream increment

Component = Union[int, float, str, bool]


def _mix64(z: int) -> int:
  """splitmix64 finalizer (mod 2^64): full avalanche on every input bit."""
  z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
  z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
  return z ^ (z >> 31)


def _component64(part: Component) -> int:
  if isinstance(part, bool):
    return int(part)
  if isinstance(part, int):
    return part & _MASK64
  if isinstance(part, float):
    return int.from_bytes(struct.pack("<d", part), "little")
  if isinstance(part, str):
    return int.from_bytes(hashlib.sha256(part.encode()).digest()[:8],
                          "little")
  raise TypeError(f"derive_seed components must be int/float/str/bool, "
                  f"got {type(part).__name__}: {part!r}")


def derive_seed(label: str, *parts: Component, bits: int = 31) -> int:
  """A child seed in ``[0, 2**bits)`` from a label and components.

  ``label`` names the consumer (e.g. ``"markov-step"``) and keeps its
  stream disjoint from every other consumer's even when the numeric
  components coincide.  Components may be ints (any sign/size), floats
  (hashed by bit pattern), bools or strings.  Order matters:
  ``derive_seed(l, a, b) != derive_seed(l, b, a)`` in general.

  ``bits`` defaults to 31 — safe for ``np.random.RandomState``,
  ``jax.random.PRNGKey`` and C ``int`` seed APIs alike; raise it (max
  63) for consumers that accept wider seeds.
  """
  if not isinstance(label, str) or not label:
    raise ValueError("derive_seed needs a non-empty string label naming "
                     "the consumer")
  if not 1 <= bits <= 63:
    raise ValueError(f"bits must be in [1, 63], got {bits}")
  h = _component64(label)
  for part in parts:
    h = _mix64(((h + _GOLDEN) & _MASK64) ^ _component64(part))
  return h >> (64 - bits)
