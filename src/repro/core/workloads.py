"""DNN workload definitions for QUIDAM's DSE.

Provides the paper's evaluation networks — VGG-16, ResNet-20/34/50/56 on
CIFAR (32x32) and ImageNet (224x224) — as row-stationary workload layer
lists, plus a *bridge* that lowers any transformer architecture from the
assigned zoo (``repro.configs``) into the same workload IR (matmuls as
1x1 convolutions), so the paper's PPA models co-explore LM architectures
as well (beyond-paper extension, see README.md "LM workloads bridge").
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence

from repro.core.dataflow import ConvLayer


# ---------------------------------------------------------------------------
# VGG-16
# ---------------------------------------------------------------------------

_VGG16_PLAN = [  # (channels, repeats) per stage; maxpool between stages
    (64, 2), (128, 2), (256, 3), (512, 3), (512, 3),
]


def vgg16(input_dim: int = 32, in_ch: int = 3,
          plan: Sequence = _VGG16_PLAN) -> List[ConvLayer]:
  layers: List[ConvLayer] = []
  a, c = input_dim, in_ch
  for stage, (f, reps) in enumerate(plan):
    for r in range(reps):
      layers.append(ConvLayer(f"conv{stage + 1}_{r + 1}", A=a, C=c, F=f,
                              K=3, S=1, P=1))
      c = f
    a = max(a // 2, 1)  # maxpool 2x2
  return layers


# ---------------------------------------------------------------------------
# ResNets
# ---------------------------------------------------------------------------

def resnet_cifar(depth: int, input_dim: int = 32) -> List[ConvLayer]:
  """CIFAR ResNet-(6n+2): 3 stages of n basic blocks, widths 16/32/64."""
  assert (depth - 2) % 6 == 0, "CIFAR ResNet depth must be 6n+2"
  n = (depth - 2) // 6
  layers = [ConvLayer("conv1", A=input_dim, C=3, F=16, K=3, S=1, P=1)]
  a, c = input_dim, 16
  for stage, f in enumerate((16, 32, 64)):
    for b in range(n):
      s = 2 if (stage > 0 and b == 0) else 1
      ds = 1 if (stage > 0 and b == 0) else 0
      layers.append(ConvLayer(f"s{stage}b{b}c1", A=a, C=c, F=f, K=3, S=s,
                              P=1, rs=1 - ds, ds=ds))
      a_out = (a + 2 - 3) // s + 1
      layers.append(ConvLayer(f"s{stage}b{b}c2", A=a_out, C=f, F=f, K=3,
                              S=1, P=1, rs=1, ds=0))
      if ds:
        layers.append(ConvLayer(f"s{stage}b{b}proj", A=a, C=c, F=f, K=1,
                                S=s, P=0, rs=0, ds=1))
      a, c = a_out, f
  return layers


def resnet34(input_dim: int = 224) -> List[ConvLayer]:
  """ImageNet ResNet-34: basic blocks, widths 64/128/256/512, [3,4,6,3]."""
  layers = [ConvLayer("conv1", A=input_dim, C=3, F=64, K=7, S=2, P=3)]
  a = (input_dim + 6 - 7) // 2 + 1
  a = (a + 2 - 3) // 2 + 1  # maxpool 3x3 /2
  c = 64
  for stage, (f, reps) in enumerate(((64, 3), (128, 4), (256, 6), (512, 3))):
    for b in range(reps):
      s = 2 if (stage > 0 and b == 0) else 1
      ds = 1 if (stage > 0 and b == 0) else 0
      layers.append(ConvLayer(f"s{stage}b{b}c1", A=a, C=c, F=f, K=3, S=s,
                              P=1, rs=1 - ds, ds=ds))
      a_out = (a + 2 - 3) // s + 1
      layers.append(ConvLayer(f"s{stage}b{b}c2", A=a_out, C=f, F=f, K=3,
                              S=1, P=1, rs=1))
      if ds:
        layers.append(ConvLayer(f"s{stage}b{b}proj", A=a, C=c, F=f, K=1,
                                S=s, P=0, ds=1))
      a, c = a_out, f
  return layers


def resnet50(input_dim: int = 224) -> List[ConvLayer]:
  """ImageNet ResNet-50: bottleneck blocks [3,4,6,3]."""
  layers = [ConvLayer("conv1", A=input_dim, C=3, F=64, K=7, S=2, P=3)]
  a = (input_dim + 6 - 7) // 2 + 1
  a = (a + 2 - 3) // 2 + 1
  c = 64
  for stage, (f, reps) in enumerate(((64, 3), (128, 4), (256, 6), (512, 3))):
    for b in range(reps):
      s = 2 if (stage > 0 and b == 0) else 1
      ds = 1 if b == 0 else 0
      layers.append(ConvLayer(f"s{stage}b{b}r", A=a, C=c, F=f, K=1, S=1,
                              P=0, rs=1 - ds, ds=ds))
      layers.append(ConvLayer(f"s{stage}b{b}c", A=a, C=f, F=f, K=3, S=s,
                              P=1, rs=1 - ds, ds=ds))
      a_out = (a + 2 - 3) // s + 1
      layers.append(ConvLayer(f"s{stage}b{b}e", A=a_out, C=f, F=4 * f, K=1,
                              S=1, P=0, rs=1 - ds, ds=ds))
      if ds:
        layers.append(ConvLayer(f"s{stage}b{b}proj", A=a, C=c, F=4 * f,
                                K=1, S=s, P=0, ds=1))
      a, c = a_out, 4 * f
  return layers


def resnet20(input_dim: int = 32) -> List[ConvLayer]:
  return resnet_cifar(20, input_dim)


def resnet56(input_dim: int = 32) -> List[ConvLayer]:
  return resnet_cifar(56, input_dim)


# ---------------------------------------------------------------------------
# transformer bridge: matmul -> 1x1 conv workload
# ---------------------------------------------------------------------------

def matmul_layer(name: str, tokens: int, d_in: int, d_out: int) -> ConvLayer:
  """A (tokens, d_in) @ (d_in, d_out) GEMM as a 1x1 conv over sqrt(tokens)^2
  positions (RS dataflow treats output positions uniformly)."""
  a = max(int(math.ceil(math.sqrt(tokens))), 1)
  return ConvLayer(name, A=a, C=d_in, F=d_out, K=1, S=1, P=0)


def lm_block_workload(name: str, tokens: int, d_model: int, n_heads: int,
                      n_kv: int, head_dim: int, d_ff: int,
                      gated: bool = True, n_experts_active: int = 1
                      ) -> List[ConvLayer]:
  """One transformer block's GEMMs as workload layers (per token batch)."""
  layers = [
      matmul_layer(f"{name}.q", tokens, d_model, n_heads * head_dim),
      matmul_layer(f"{name}.kv", tokens, d_model, 2 * n_kv * head_dim),
      matmul_layer(f"{name}.o", tokens, n_heads * head_dim, d_model),
  ]
  ff_mats = 3 if gated else 2
  for i in range(ff_mats):
    d_in = d_model if i < ff_mats - 1 else d_ff
    d_out = d_ff if i < ff_mats - 1 else d_model
    layers.append(matmul_layer(f"{name}.ffn{i}",
                               tokens * n_experts_active, d_in, d_out))
  return layers


# ---------------------------------------------------------------------------
# registry (paper networks; model-zoo bridging lives in repro.configs)
# ---------------------------------------------------------------------------

PAPER_NETWORKS: Dict[str, Sequence[ConvLayer]] = {}


def get_network(name: str) -> List[ConvLayer]:
  """Paper workloads: vgg16/resnet20/resnet56 (CIFAR), vgg16_imagenet,
  resnet34/resnet50 (ImageNet)."""
  table = {
      "vgg16": lambda: vgg16(32),
      "vgg16_imagenet": lambda: vgg16(224),
      "resnet20": lambda: resnet20(32),
      "resnet56": lambda: resnet56(32),
      "resnet34": lambda: resnet34(224),
      "resnet50": lambda: resnet50(224),
  }
  if name not in table:
    raise ValueError(f"unknown network {name!r}; known: {sorted(table)}")
  return table[name]()


# the paper's workload suites (Sec. 4.2)
CIFAR_SUITE = ("vgg16", "resnet20", "resnet56")
IMAGENET_SUITE = ("vgg16_imagenet", "resnet34", "resnet50")
