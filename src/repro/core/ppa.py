"""Quantization-aware polynomial PPA models (paper Sec. 3.3, Eq. 2, Fig. 5).

A K-degree multivariate polynomial  F(x) = sum_j c_j prod_i x_i^{q_ij},
sum_i q_ij <= K, fit per PE type:

  power  : x = (SP_if, SP_ps, SP_fw, #PE)                      [4-dim]
  area   : x = (SP_if, SP_ps, SP_fw, #PE)                      [4-dim]
  latency: x = (SP_if, SP_ps, SP_fw, PE_rows, PE_cols, GBS,
                A, C, F, K, S, P [, RS, DS])                    [12(+2)-dim]

Degree is selected with k-fold cross validation comparing MAPE and RMSPE
jointly (Fig. 5; the paper selects degree 5).  Fitting uses relative-error-
weighted ridge regression in float64 (numpy) — the fit itself is offline;
evaluation is a single feature-matrix product and is what accelerates the
DSE by orders of magnitude vs. re-characterization.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import oracle
from repro.core.dataflow import AcceleratorConfig, ConvLayer


# ---------------------------------------------------------------------------
# polynomial feature expansion
# ---------------------------------------------------------------------------

def monomial_exponents(n_features: int, degree: int,
                       max_vars: Optional[int] = None) -> np.ndarray:
  """All exponent vectors q with sum(q) <= degree (incl. the constant term).

  max_vars caps the number of distinct variables per monomial — used for the
  12/14-feature latency model where the full degree-5 basis (6k+ monomials)
  is statistically and numerically untenable; the paper does not specify its
  basis pruning, we document ours.
  """
  rows: List[Tuple[int, ...]] = []
  for total in range(degree + 1):
    for combo in itertools.combinations_with_replacement(
        range(n_features), total):
      q = [0] * n_features
      for i in combo:
        q[i] += 1
      if max_vars is not None and sum(1 for v in q if v > 0) > max_vars:
        continue
      rows.append(tuple(q))
  uniq = sorted(set(rows))
  return np.asarray(uniq, dtype=np.int32)


def poly_features(x: np.ndarray, exponents: np.ndarray,
                  col_scale: np.ndarray) -> np.ndarray:
  """Feature matrix Phi[n, m] = prod_i (x[n, i]/s_i)^{q[m, i]} (vectorized)."""
  xs = x / col_scale
  n, d = xs.shape
  m = exponents.shape[0]
  # precompute powers[p, :, i] then gather per monomial column
  max_deg = int(exponents.max()) if exponents.size else 0
  powers = np.ones((max_deg + 1, n, d), dtype=np.float64)
  for p in range(1, max_deg + 1):
    powers[p] = powers[p - 1] * xs
  out = np.ones((n, m), dtype=np.float64)
  for i in range(d):
    qi = exponents[:, i]
    active = qi > 0
    if np.any(active):
      out[:, active] *= powers[qi[active], :, i].T
  return out


# ---------------------------------------------------------------------------
# metrics (paper's model-selection criteria)
# ---------------------------------------------------------------------------

def mape(y_true: np.ndarray, y_pred: np.ndarray) -> float:
  denom = np.maximum(np.abs(y_true), 1e-30)
  return float(np.mean(np.abs((y_pred - y_true) / denom)) * 100.0)


def rmspe(y_true: np.ndarray, y_pred: np.ndarray) -> float:
  denom = np.maximum(np.abs(y_true), 1e-30)
  return float(np.sqrt(np.mean(((y_pred - y_true) / denom) ** 2)) * 100.0)


def r2(y_true: np.ndarray, y_pred: np.ndarray) -> float:
  ss_res = float(np.sum((y_true - y_pred) ** 2))
  ss_tot = float(np.sum((y_true - np.mean(y_true)) ** 2))
  return 1.0 - ss_res / max(ss_tot, 1e-30)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PolyModel:
  degree: int
  exponents: np.ndarray
  col_scale: np.ndarray
  coef: np.ndarray
  y_scale: float
  log_target: bool = False

  def predict(self, x: np.ndarray) -> np.ndarray:
    phi = poly_features(np.asarray(x, np.float64), self.exponents,
                        self.col_scale)
    raw = phi @ self.coef
    if self.log_target:
      return np.exp(np.clip(raw, -60.0, 60.0)) * self.y_scale
    return raw * self.y_scale


def fit_poly(x: np.ndarray, y: np.ndarray, degree: int,
             max_vars: Optional[int] = None,
             ridge: float = 1e-8, log_target: bool = False) -> PolyModel:
  """Ridge fit of a degree-K polynomial.

  log_target=True fits log(y) (used for latency whose dynamic range spans
  4+ orders of magnitude across layers — a documented deviation from the
  paper's raw-target fit; raw fits are numerically untenable there).
  Raw fits are relative-error weighted so MAPE/RMSPE are the effective
  training criteria.
  """
  x = np.asarray(x, np.float64)
  y = np.asarray(y, np.float64)
  col_scale = np.maximum(np.max(np.abs(x), axis=0), 1e-12)
  exps = monomial_exponents(x.shape[1], degree, max_vars)
  phi = poly_features(x, exps, col_scale)
  if log_target:
    y_scale = float(np.maximum(np.exp(np.mean(np.log(np.maximum(y, 1e-30)))),
                               1e-30))
    t = np.log(np.maximum(y, 1e-30) / y_scale)
    w = np.ones_like(t)
  else:
    y_scale = float(np.maximum(np.mean(np.abs(y)), 1e-30))
    t = y / y_scale
    # minimize sum_n w_n (phi_n c - t_n)^2 with w ~ 1/t (relative error)
    w = 1.0 / np.maximum(np.abs(t), 1e-3)
  tw = t * w
  phiw = phi * w[:, None]
  gram = phiw.T @ phiw
  gram[np.diag_indices_from(gram)] += ridge * np.trace(gram) / gram.shape[0]
  coef = np.linalg.solve(gram, phiw.T @ tw)
  return PolyModel(degree, exps, col_scale, coef, y_scale, log_target)


def kfold_cv(x: np.ndarray, y: np.ndarray, degree: int, k: int = 5,
             max_vars: Optional[int] = None, seed: int = 0,
             log_target: bool = False) -> Tuple[float, float]:
  """k-fold CV -> (MAPE, RMSPE), the joint criteria of Fig. 5."""
  rng = np.random.RandomState(seed)
  n = x.shape[0]
  idx = rng.permutation(n)
  folds = np.array_split(idx, k)
  mapes, rmspes = [], []
  for f in range(k):
    test = folds[f]
    train = np.concatenate([folds[g] for g in range(k) if g != f])
    model = fit_poly(x[train], y[train], degree, max_vars,
                     log_target=log_target)
    pred = model.predict(x[test])
    mapes.append(mape(y[test], pred))
    rmspes.append(rmspe(y[test], pred))
  return float(np.mean(mapes)), float(np.mean(rmspes))


def select_degree(x: np.ndarray, y: np.ndarray,
                  degrees: Sequence[int] = tuple(range(1, 9)),
                  k: int = 5, max_vars: Optional[int] = None,
                  seed: int = 0, log_target: bool = False
                  ) -> Tuple[int, Dict[int, Tuple[float, float]]]:
  """Sweep degrees, return (best_degree, {degree: (MAPE, RMSPE)})."""
  scores: Dict[int, Tuple[float, float]] = {}
  for d in degrees:
    scores[d] = kfold_cv(x, y, d, k=k, max_vars=max_vars, seed=seed,
                         log_target=log_target)
  # joint criterion: both metrics low -> minimize MAPE + RMSPE
  best = min(scores, key=lambda d: scores[d][0] + scores[d][1])
  return best, scores


# ---------------------------------------------------------------------------
# dataset builders (characterize designs with the synthesis oracle)
# ---------------------------------------------------------------------------

# DSE sampling ranges (Sec. 3.3: "vary global buffer size, #PE per row and
# column, bit precision, PE type, and individual scratchpad sizes").
HW_RANGES = {
    "pe_rows": (8, 10, 12, 14, 16, 20, 24, 28, 32),
    "pe_cols": (8, 10, 12, 14, 16, 20, 24, 28, 32),
    "sp_if": (6, 8, 12, 16, 24, 32, 48, 64),
    "sp_fw": (64, 96, 128, 160, 224, 288, 352, 448),
    "sp_ps": (8, 12, 16, 24, 32, 48, 64),
    "gbuf_kb": (64, 96, 128, 192, 256, 384, 512),
    "bandwidth_gbps": (6.4, 12.8, 25.6),
}


def sample_configs(pe_type: str, n: int, seed: int = 0
                   ) -> List[AcceleratorConfig]:
  rng = np.random.RandomState(seed)
  cfgs = []
  for _ in range(n):
    cfgs.append(AcceleratorConfig(
        pe_type=pe_type,
        pe_rows=int(rng.choice(HW_RANGES["pe_rows"])),
        pe_cols=int(rng.choice(HW_RANGES["pe_cols"])),
        sp_if=int(rng.choice(HW_RANGES["sp_if"])),
        sp_fw=int(rng.choice(HW_RANGES["sp_fw"])),
        sp_ps=int(rng.choice(HW_RANGES["sp_ps"])),
        gbuf_kb=int(rng.choice(HW_RANGES["gbuf_kb"])),
        bandwidth_gbps=float(rng.choice(HW_RANGES["bandwidth_gbps"])),
    ))
  return cfgs


def hw_feature_matrix(cfgs) -> np.ndarray:
  """(N, 4) power/area features from a config sequence or a ConfigTable
  (the table path never touches per-point Python objects)."""
  if hasattr(cfgs, "hw_features"):  # ConfigTable
    return cfgs.hw_features()
  return np.asarray([c.hw_features() for c in cfgs], np.float64)


def power_area_dataset(cfgs: Sequence[AcceleratorConfig]
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
  """(X[4], array power mW, array area mm2) via the synthesis oracle.

  Targets are the PE-array subsystem: the paper's 4-feature power/area
  vector cannot see GBS, so the global buffer composes separately as a
  closed-form SRAM macro (oracle.gbuf_power_mw / gbuf_area_mm2)."""
  x = hw_feature_matrix(cfgs)
  p = np.asarray([oracle.array_power_mw(c) for c in cfgs])
  a = np.asarray([oracle.array_area_mm2(c) for c in cfgs])
  return x, p, a


def latency_feature_row(cfg: AcceleratorConfig, layer: ConvLayer
                        ) -> Tuple[float, ...]:
  return cfg.latency_hw_features() + layer.features()


def latency_dataset(cfgs: Sequence[AcceleratorConfig],
                    layers: Sequence[ConvLayer]
                    ) -> Tuple[np.ndarray, np.ndarray]:
  """Layer-level (X[14], latency_s) pairs — the paper's training granularity."""
  rows, ys = [], []
  for cfg in cfgs:
    clk = oracle.clock_mhz(cfg)
    for layer in layers:
      from repro.core.dataflow import simulate_layer
      st = simulate_layer(cfg, layer, clk)
      rows.append(latency_feature_row(cfg, layer))
      ys.append(st.cycles / (clk * 1e6))
  return np.asarray(rows, np.float64), np.asarray(ys, np.float64)


# ---------------------------------------------------------------------------
# per-PE-type PPA model bundle
# ---------------------------------------------------------------------------

LATENCY_MAX_VARS = 2   # basis pruning for the 14-feature latency model
LATENCY_DEGREE = 4     # CV-selected on held-out layers (deg-4/mv-2 minimizes
                       # MAPE+RMSPE; latency is the hardest target, cf. Fig 7)


@dataclasses.dataclass
class PPAModels:
  """Power/area/latency polynomial models for one PE type (paper: one model
  set per PE type; Sec. 3.3)."""
  pe_type: str
  degree: int
  power: PolyModel
  area: PolyModel
  latency: PolyModel

  def predict_power_mw(self, cfgs) -> np.ndarray:
    """Configs sequence or ConfigTable -> array-PE-subsystem power (mW)."""
    return self.power.predict(hw_feature_matrix(cfgs))

  def predict_area_mm2(self, cfgs) -> np.ndarray:
    """Configs sequence or ConfigTable -> array-PE-subsystem area (mm^2)."""
    return self.area.predict(hw_feature_matrix(cfgs))

  def predict_network_latency_s(self, cfgs,
                                layers: Sequence[ConvLayer]) -> np.ndarray:
    """Sum of per-layer latency predictions (layer-level strategy).
    Vectorized: hw features tiled against cached layer features; accepts a
    config sequence or a ConfigTable."""
    if hasattr(cfgs, "latency_hw_features"):  # ConfigTable
      hw = cfgs.latency_hw_features()
    else:
      cfgs = list(cfgs)
      hw = np.asarray([c.latency_hw_features() for c in cfgs], np.float64)
    lf = np.asarray([l.features() for l in layers], np.float64)
    return self.predict_network_latency_feats(hw, lf)

  def predict_network_latency_feats(self, hw: np.ndarray, lf: np.ndarray
                                    ) -> np.ndarray:
    """Network latency from precomputed feature matrices: ``hw`` is
    (n_cfgs, 6) latency hardware features, ``lf`` is (n_layers, 8) layer
    features.  The joint co-exploration path calls this directly with
    LayerStack rows, bypassing per-point objects; ops (and therefore the
    float64 bits) match :meth:`predict_network_latency_s` exactly."""
    n_c, n_l = hw.shape[0], lf.shape[0]
    rows = np.concatenate(
        [np.repeat(hw, n_l, axis=0), np.tile(lf, (n_c, 1))], axis=1)
    pred = np.maximum(self.latency.predict(rows), 1e-12)
    return pred.reshape(n_c, n_l).sum(axis=1)


def fit_ppa_models(pe_type: str, degree: int = 5, n_train: int = 300,
                   layers: Optional[Sequence[ConvLayer]] = None,
                   seed: int = 0) -> PPAModels:
  """Characterize n_train sampled designs with the oracle and fit models."""
  cfgs = sample_configs(pe_type, n_train, seed=seed)
  x, p, a = power_area_dataset(cfgs)
  power = fit_poly(x, p, degree)
  area = fit_poly(x, a, degree)
  if layers is None:
    from repro.core.workloads import get_network
    layers = get_network("resnet20") + get_network("vgg16")
  # fewer configs for the (config x layer) latency dataset
  lat_cfgs = cfgs[: max(150, n_train // 2)]
  lx, ly = latency_dataset(lat_cfgs, layers)
  latency = fit_poly(lx, ly, min(degree, LATENCY_DEGREE),
                     max_vars=LATENCY_MAX_VARS, log_target=True)
  return PPAModels(pe_type, degree, power, area, latency)
