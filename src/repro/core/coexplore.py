"""HW x NN co-exploration — COMPATIBILITY SHIM over ``repro.explore``.

The joint exploration of paper Sec. 4.5 / Fig. 12 now runs through
:meth:`repro.explore.ExplorationSession.co_explore`, which shares the
evaluation backends (and their memoized global-buffer composition) with
plain DSE.  This module keeps the old list-of-CoPoint API working; new
code should use the session + ResultFrame directly.  Internally frames
use the coded-architecture representation (integer ``arch_id`` column +
``arch_lookup``, see :mod:`repro.explore.frame`) — the CoPoint list is
materialized from it bit-compatibly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import ppa as ppa_lib
from repro.core.cnn import ArchChoice
from repro.core.dataflow import AcceleratorConfig
from repro.core.pe import PAPER_PE_TYPES
from repro.explore.backend import PolynomialBackend
from repro.explore.frame import ResultFrame, pareto_mask
from repro.explore.session import ExplorationSession
from repro.explore.space import DesignSpace

__all__ = ["CoPoint", "co_explore", "normalize_and_front"]


@dataclasses.dataclass
class CoPoint:
  """One (hardware, architecture) pair in the joint space."""
  cfg: AcceleratorConfig
  arch: ArchChoice
  top1: float
  latency_s: float
  power_mw: float
  area_mm2: float

  @property
  def energy_mj(self) -> float:
    return self.power_mw * self.latency_s

  @property
  def top1_err(self) -> float:
    return 1.0 - self.top1


def _to_frame(points: Sequence[CoPoint]) -> ResultFrame:
  """CoPoint list -> coded-arch ResultFrame (integer ``arch_id`` column +
  shared ``arch_lookup``; no object-dtype columns)."""
  pts = list(points)
  lookup: List[ArchChoice] = []
  index: Dict[ArchChoice, int] = {}
  ids = np.empty(len(pts), np.int64)
  for i, p in enumerate(pts):
    if p.arch not in index:
      index[p.arch] = len(lookup)
      lookup.append(p.arch)
    ids[i] = index[p.arch]
  return ResultFrame(
      latency_s=np.asarray([p.latency_s for p in pts]),
      power_mw=np.asarray([p.power_mw for p in pts]),
      area_mm2=np.asarray([p.area_mm2 for p in pts]),
      pe_type=np.asarray([p.cfg.pe_type for p in pts]),
      cfgs=tuple(p.cfg for p in pts), network="coexplore",
      extra={"top1": np.asarray([p.top1 for p in pts], np.float64),
             "arch_id": ids},
      arch_lookup=tuple(lookup))


def co_explore(models: Dict[str, ppa_lib.PPAModels],
               arch_accs: Sequence[Tuple[ArchChoice, float]],
               n_hw_per_type: int = 20, seed: int = 3,
               image_size: int = 32,
               pe_types: Sequence[str] = PAPER_PE_TYPES) -> List[CoPoint]:
  """Random HW samples x supernet-evaluated archs -> joint design points."""
  session = ExplorationSession(PolynomialBackend(models),
                               DesignSpace(pe_types=tuple(pe_types)))
  frame = session.co_explore(arch_accs, n_hw_per_type=n_hw_per_type,
                             seed=seed, image_size=image_size,
                             vectorized=False)
  lookup = frame.arch_lookup
  return [CoPoint(cfg, lookup[int(aid)], float(t1), float(l), float(p),
                  float(a))
          for cfg, aid, t1, l, p, a in zip(
              frame.cfgs, frame.extra["arch_id"], frame.extra["top1"],
              frame.latency_s, frame.power_mw, frame.area_mm2)]


def normalize_and_front(points: Sequence[CoPoint]
                        ) -> Dict[str, np.ndarray]:
  """Fig. 12 processing: normalize energy/area to the min-energy/min-area
  INT16 pair; Pareto front on (top1_err, energy) and (top1_err, area)."""
  frame = _to_frame(points)
  e_ref = float(frame.energy_mj[frame.reference_index("energy")])
  a_ref = float(frame.area_mm2[frame.reference_index("area")])
  err = frame.column("top1_err")
  energy = frame.energy_mj / e_ref
  area = frame.area_mm2 / a_ref
  front_e = pareto_mask(np.stack([err, energy], axis=1))
  front_a = pareto_mask(np.stack([err, area], axis=1))
  return {"err": err, "energy": energy, "area": area,
          "types": frame.pe_type, "front_energy": front_e,
          "front_area": front_a}
