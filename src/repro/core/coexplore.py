"""DNN accelerator + model co-exploration (paper Sec. 4.5, Fig. 12).

Pairs randomly sampled hardware configurations with supernet-evaluated
candidate architectures: each (HW, NN) pair gets accuracy (weight-sharing
proxy), energy (power x latency from the PPA models) and area; pairs are
normalized against the minimum-energy / minimum-area INT16 pair and the
joint Pareto front is extracted.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dse, ppa as ppa_lib
from repro.core.cnn import ArchChoice
from repro.core.dataflow import AcceleratorConfig
from repro.core.pe import PAPER_PE_TYPES
from repro.core.supernet import Supernet, arch_to_layers


@dataclasses.dataclass
class CoPoint:
  """One (hardware, architecture) pair in the joint space."""
  cfg: AcceleratorConfig
  arch: ArchChoice
  top1: float
  latency_s: float
  power_mw: float
  area_mm2: float

  @property
  def energy_mj(self) -> float:
    return self.power_mw * self.latency_s

  @property
  def top1_err(self) -> float:
    return 1.0 - self.top1


def co_explore(models: Dict[str, ppa_lib.PPAModels],
               arch_accs: Sequence[Tuple[ArchChoice, float]],
               n_hw_per_type: int = 20, seed: int = 3,
               image_size: int = 32,
               pe_types: Sequence[str] = PAPER_PE_TYPES) -> List[CoPoint]:
  """Random HW samples x supernet-evaluated archs -> joint design points."""
  points: List[CoPoint] = []
  for ti, pe_type in enumerate(pe_types):
    cfgs = ppa_lib.sample_configs(pe_type, n_hw_per_type,
                                  seed=seed + 17 * ti)
    m = models[pe_type]
    for arch, acc in arch_accs:
      layers = arch_to_layers(arch, image_size=image_size)
      lat = float(np.maximum(
          m.predict_network_latency_s(cfgs, layers), 1e-9).mean())
      # evaluate each cfg separately for the scatter
      lats = np.maximum(m.predict_network_latency_s(cfgs, layers), 1e-9)
      pwrs = np.maximum(m.predict_power_mw(cfgs), 1e-3)
      areas = np.maximum(m.predict_area_mm2(cfgs), 1e-6)
      from repro.core import oracle
      pwrs = pwrs + np.asarray([oracle.gbuf_power_mw(c) for c in cfgs])
      areas = areas + np.asarray([oracle.gbuf_area_mm2(c) for c in cfgs])
      for c, l, p, a in zip(cfgs, lats, pwrs, areas):
        points.append(CoPoint(c, arch, acc, float(l), float(p), float(a)))
  return points


def normalize_and_front(points: Sequence[CoPoint]
                        ) -> Dict[str, np.ndarray]:
  """Fig. 12 processing: normalize energy/area to the min-energy/min-area
  INT16 pair; Pareto front on (top1_err, energy) and (top1_err, area)."""
  int16 = [p for p in points if p.cfg.pe_type == "INT16"]
  if not int16:
    raise ValueError("need INT16 pairs for normalization")
  e_ref = min(p.energy_mj for p in int16)
  a_ref = min(p.area_mm2 for p in int16)
  err = np.asarray([p.top1_err for p in points])
  energy = np.asarray([p.energy_mj for p in points]) / e_ref
  area = np.asarray([p.area_mm2 for p in points]) / a_ref
  types = np.asarray([p.cfg.pe_type for p in points])
  front_e = dse.pareto_front(np.stack([err, energy], axis=1))
  front_a = dse.pareto_front(np.stack([err, area], axis=1))
  return {"err": err, "energy": energy, "area": area, "types": types,
          "front_energy": front_e, "front_area": front_a}
