"""Synthesis oracle: the stand-in for Synopsys DC + VCS @ FreePDK45.

The paper characterizes every design point with commercial synthesis
(power/area/clock) plus RTL simulation (latency).  Neither tool can run in
this environment, so this module provides an *analytical gate/SRAM-level
model* with documented 45 nm constants (see :mod:`repro.core.pe`), plus a
deterministic, config-hashed "layout variation" term so the downstream
polynomial regression faces realistically noisy targets.

Calibration anchors (paper, Table 3 + Figs 6/8 orderings):
  clock:  FP32 275 MHz | INT16 285 MHz | LightPE-2 435 MHz | LightPE-1 455 MHz
  area/power: FP32 > INT16 >> LightPE-2 > LightPE-1 per PE.

Everything is per *design point* (AcceleratorConfig); latency additionally
takes workload layers and delegates to the RS dataflow model.  Every
target also has a vectorized ``*_batch`` sibling that evaluates a whole
:class:`repro.core.table.ConfigTable` at once (bit-identical to the
scalar path on numpy; optional jax device path) — the engine behind
:class:`repro.explore.VectorOracleBackend`'s million-point sweeps.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import math
import struct
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import pe as pe_lib
from repro.core.dataflow import (AcceleratorConfig, ConvLayer, LayerStats,
                                 simulate_network)
from repro.core.table import scratch_buf as _scratch_buf

# Characterization-model version: bump whenever oracle outputs change for
# the same config (invalidates on-disk polynomial-model caches fitted
# against older outputs).  v2: column-hashed _variation (splitmix64 chain
# over key columns) replaced the per-point string SHA-256.
ORACLE_VERSION = 2

# FIFO depth per the Eyeriss-style template (4 FIFOs per PE, Fig. 3).
FIFO_DEPTH = 4
FLOP_BIT_UM2 = 2.0          # latch-based FIFO storage cell
NOC_GATES_PER_PE = 300      # X-bus router slice + links at 21-bit mean width
PSUM_AMORTIZE = 3.0         # psum spad is touched once per K MACs (a local
                            # accumulator register holds the running sum;
                            # K=3 kernels dominate the workloads)
ARRAY_CTRL_GATES = 12_000   # top-level controller, address generators


# Layout variation hashes the design point's KEY COLUMNS (not a formatted
# key string): salt and PE-type names are folded in as one-time SHA-256
# constants, then each knob column is chained through a splitmix64-style
# finalizer.  The same mixer runs per-row on Python ints (scalar path) and
# on uint64 numpy columns (:func:`_variation_batch`), so the vectorized
# million-point path is bit-identical to the scalar oracle by construction.
_MASK64 = (1 << 64) - 1


@functools.lru_cache(maxsize=None)
def _name_const(name: str) -> int:
  """Stable 64-bit constant for a salt / PE-type name (one-time hash)."""
  return int.from_bytes(hashlib.sha256(name.encode()).digest()[:8], "little")


def _mix64(z: int) -> int:
  """splitmix64 finalizer on a Python int (mod 2^64)."""
  z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
  z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
  return z ^ (z >> 31)


def _variation_key_ints(cfg: AcceleratorConfig) -> Tuple[int, ...]:
  return (_name_const(cfg.pe_type), cfg.pe_rows, cfg.pe_cols, cfg.sp_if,
          cfg.sp_fw, cfg.sp_ps, cfg.gbuf_kb,
          int.from_bytes(struct.pack("<d", float(cfg.bandwidth_gbps)),
                         "little"))


def _variation(cfg: AcceleratorConfig, salt: str, pct: float) -> float:
  """Deterministic pseudo-random multiplier in [1-pct, 1+pct]."""
  h = _name_const(salt)
  for v in _variation_key_ints(cfg):
    h = _mix64(h ^ v)
  u = (h / 2**64) * 2.0 - 1.0
  return 1.0 + pct * u


def _sram_area_um2(bits: float, words: float = 64.0) -> float:
  """CACTI-flavoured small-SRAM area: cells + sqrt-periphery + decoder
  steps (ceil(log2 words) levels) + fixed."""
  if bits <= 0:
    return 0.0
  decoder = 6.0 * pe_lib.decoder_levels(words) * math.sqrt(max(bits, 1.0)) \
      / 8.0
  return bits * pe_lib.SRAM_BIT_UM2 + 3.0 * math.sqrt(bits) + decoder + 15.0


# ---------------------------------------------------------------------------
# clock
# ---------------------------------------------------------------------------

def clock_mhz(cfg: AcceleratorConfig) -> float:
  """Post-synthesis clock estimate.

  period = arithmetic critical path + control/wire term that grows with the
  array size and scratchpad address depth.  Calibrated so the nominal
  16x16 / (12,224,24) / 128 KiB design reproduces the paper's Table 3.
  """
  pe = cfg.pe
  ctrl_ns = 0.028 * math.log2(max(cfg.n_pe, 2)) \
      + 0.006 * math.log2(max(cfg.sp_fw + cfg.sp_if + cfg.sp_ps, 2))
  period_ns = pe.critical_path_ns + ctrl_ns
  period_ns *= _variation(cfg, "clk", 0.004)
  return 1000.0 / period_ns


# ---------------------------------------------------------------------------
# area
# ---------------------------------------------------------------------------

def pe_area_um2(cfg: AcceleratorConfig) -> float:
  """One PE: arithmetic + 3 scratchpads + 4 FIFOs + local control."""
  pe = cfg.pe
  arith = pe.arith_gates * pe_lib.GATE_AREA_UM2
  spad = (_sram_area_um2(cfg.sp_if * pe.act_bits, cfg.sp_if)
          + _sram_area_um2(cfg.sp_fw * pe.weight_bits, cfg.sp_fw)
          + _sram_area_um2(cfg.sp_ps * pe.psum_bits, cfg.sp_ps))
  fifo_bits = FIFO_DEPTH * (2 * pe.act_bits + pe.weight_bits + pe.psum_bits)
  fifo = fifo_bits * FLOP_BIT_UM2
  ctrl = 0.04 * (arith + spad) + 220 * pe_lib.GATE_AREA_UM2
  return arith + spad + fifo + ctrl


def array_area_mm2(cfg: AcceleratorConfig) -> float:
  """PE-array subsystem (array + NoC + control, EXCLUDING global buffer).

  This is the polynomial area model's target: the paper's 4-feature vector
  (SP_if, SP_ps, SP_fw, #PE) cannot see GBS, so the global buffer is
  composed separately as a pre-characterized SRAM macro (datasheet-style),
  see :func:`gbuf_area_mm2`.
  """
  pe = cfg.pe
  pe_area = pe_area_um2(cfg) * cfg.n_pe
  word = (pe.act_bits + pe.weight_bits + pe.psum_bits) / 3.0
  noc = NOC_GATES_PER_PE * (word / 21.0) * cfg.n_pe * pe_lib.GATE_AREA_UM2
  top = ARRAY_CTRL_GATES * pe_lib.GATE_AREA_UM2
  # routing congestion: utilization degrades as the array grows, the placer
  # needs slack area ~ 1/(1 - congestion) — a rational factor polynomials
  # only approximate gradually (this is what pushes the CV-optimal degree up)
  congestion = 0.30 * (cfg.n_pe / 1024.0) ** 0.7
  route = 1.0 / (1.0 - min(congestion, 0.45))
  um2 = (pe_area + noc + top) * route * _variation(cfg, "area", 0.005)
  return um2 * 1e-6


def gbuf_area_mm2(cfg: AcceleratorConfig) -> float:
  """Global-buffer SRAM macro area (closed form, banking overhead incl.)."""
  return _sram_area_um2(cfg.gbuf_kb * 1024 * 8, cfg.gbuf_kb * 512) \
      * 1.15 * 1e-6


def area_mm2(cfg: AcceleratorConfig) -> float:
  """Full accelerator: PE array subsystem + global buffer macro."""
  return array_area_mm2(cfg) + gbuf_area_mm2(cfg)


# ---------------------------------------------------------------------------
# power
# ---------------------------------------------------------------------------

def leakage_mw(cfg: AcceleratorConfig) -> float:
  """Array static power ~ gate-area equivalent (gbuf leakage lives in
  :func:`gbuf_power_mw`)."""
  pe = cfg.pe
  word = (pe.act_bits + pe.weight_bits + pe.psum_bits) / 3.0
  logic_um2 = (pe.arith_gates + NOC_GATES_PER_PE * word / 21.0) \
      * pe_lib.GATE_AREA_UM2 * cfg.n_pe \
      + ARRAY_CTRL_GATES * pe_lib.GATE_AREA_UM2
  sram_bits = cfg.n_pe * (cfg.sp_if * pe.act_bits + cfg.sp_fw * pe.weight_bits
                          + cfg.sp_ps * pe.psum_bits)
  leak = (logic_um2 / pe_lib.GATE_AREA_UM2) * pe_lib.GATE_LEAKAGE_UW \
      + sram_bits * 0.00035
  return leak * 1e-3  # uW -> mW


def array_power_mw(cfg: AcceleratorConfig) -> float:
  """PE-array characterization power (DC default activity), EXCL. gbuf.

  Activity model: every cycle each PE performs one MAC, reads act+weight
  from its scratchpads and read-modify-writes one psum.  Per-bit scratchpad
  access energy grows with scratchpad depth (bitline capacitance ~ sqrt of
  cell count) — genuinely nonlinear in the DSE axes.
  """
  pe = cfg.pe
  f_hz = clock_mhz(cfg) * 1e6
  e = pe_lib.ENERGY_PJ
  spad_pj = e["spad_access_per_bit"] * (
      pe.act_bits * pe_lib.sram_access_scale(cfg.sp_if)
      + pe.weight_bits * pe_lib.sram_access_scale(cfg.sp_fw)
      + (2.0 / PSUM_AMORTIZE) * pe.psum_bits
      * pe_lib.sram_access_scale(cfg.sp_ps))
  per_pe_pj = (pe.mac_energy_pj + spad_pj
               + FIFO_DEPTH * 0.25 * e["fifo_access_per_bit"])
  activity = 0.62  # DC default toggling assumption
  dyn_pe_mw = cfg.n_pe * per_pe_pj * activity * f_hz * 1e-9
  gbuf_word_bits = (pe.act_bits + pe.weight_bits + pe.psum_bits) / 3.0
  noc_mw = cfg.n_pe * 0.004 * (f_hz * 1e-9) * gbuf_word_bits
  dyn = dyn_pe_mw + noc_mw
  # self-heating feedback: leakage rises with power density (saturating
  # rational in the features -> hard for low-degree polynomials)
  density = dyn / max(array_area_mm2(cfg), 1e-6)  # mW / mm^2
  leak = leakage_mw(cfg) * (1.0 + 0.9 * density / (density + 40.0))
  return dyn * _variation(cfg, "pwr", 0.005) + leak


def gbuf_power_mw(cfg: AcceleratorConfig) -> float:
  """Global-buffer macro power: ports scale with the array edge
  (~sqrt(#PE)); per-bit energy scales with capacity; plus SRAM leakage."""
  pe = cfg.pe
  f_hz = clock_mhz(cfg) * 1e6
  e = pe_lib.ENERGY_PJ
  gbuf_word_bits = (pe.act_bits + pe.weight_bits + pe.psum_bits) / 3.0
  gbuf_pj_bit = e["gbuf_access_per_bit"] * pe_lib.sram_access_scale(
      cfg.gbuf_kb * 16.0)
  dyn = math.sqrt(cfg.n_pe) * gbuf_word_bits * gbuf_pj_bit * 0.62 \
      * f_hz * 1e-9
  leak = cfg.gbuf_kb * 8192 * 0.00035 * 1e-3
  return dyn + leak


def power_mw(cfg: AcceleratorConfig) -> float:
  """Full accelerator characterization power."""
  return array_power_mw(cfg) + gbuf_power_mw(cfg)


# ---------------------------------------------------------------------------
# full characterization (the expensive call QUIDAM's models replace)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Characterization:
  """Everything the paper extracts from DC + VCS for one design point."""
  clock_mhz: float
  area_mm2: float
  power_mw: float
  latency_s: float
  energy_mj: float
  per_layer_cycles: List[float]
  per_layer_energy_mj: List[float]
  utilization: float


def characterize(cfg: AcceleratorConfig,
                 layers: Sequence[ConvLayer]) -> Characterization:
  """Synthesize + simulate one (hardware, network) pair.

  This is the slow path (a Python-level per-layer dataflow walk standing in
  for hours of synthesis + RTL simulation); QUIDAM's polynomial models are
  trained on its outputs and replace it during DSE.
  """
  clk = clock_mhz(cfg)
  leak = leakage_mw(cfg)
  latency_s, energy_mj, stats = simulate_network(cfg, layers, clk, leak)
  per_cyc = [s.cycles for s in stats]
  from repro.core.dataflow import layer_energy_pj  # local to avoid cycle
  per_e = [layer_energy_pj(cfg, l, s, clk, leak) * 1e-9
           for l, s in zip(layers, stats)]
  util = (sum(s.utilization * s.cycles for s in stats)
          / max(sum(per_cyc), 1e-12))
  return Characterization(
      clock_mhz=clk, area_mm2=area_mm2(cfg), power_mw=power_mw(cfg),
      latency_s=latency_s, energy_mj=energy_mj,
      per_layer_cycles=per_cyc, per_layer_energy_mj=per_e,
      utilization=util)


def characterize_layer_latency(cfg: AcceleratorConfig, layer: ConvLayer
                               ) -> float:
  """Ground-truth single-layer latency in seconds (latency-model target)."""
  from repro.core.dataflow import simulate_layer
  clk = clock_mhz(cfg)
  st = simulate_layer(cfg, layer, clk)
  return st.cycles / (clk * 1e6)


# ---------------------------------------------------------------------------
# vectorized siblings: whole ConfigTables at once
# ---------------------------------------------------------------------------
# Every scalar formula above has a ``*_batch`` twin that evaluates a
# :class:`repro.core.table.ConfigTable` column-at-a-time.  The formulas are
# written against an array module ``xp`` (numpy by default; jax.numpy for
# the optional device path) and mirror the scalar expressions op for op, so
# the numpy path is bit-identical to looping the scalar oracle.  The
# variation term is precomputed with numpy uint64 arithmetic either way
# (jax traces treat it as an input), because the mixer needs uint64.


def _mix64_batch(z: np.ndarray, out: Optional[np.ndarray] = None
                 ) -> np.ndarray:
  """splitmix64 finalizer across a uint64 column (wraps mod 2^64)."""
  z = np.multiply(z ^ (z >> np.uint64(30)), np.uint64(0xBF58476D1CE4E5B9),
                  out=out)
  z = np.multiply(z ^ (z >> np.uint64(27)), np.uint64(0x94D049BB133111EB),
                  out=out)
  return np.bitwise_xor(z, z >> np.uint64(31), out=out)


def _variation_batch(table, salt: str, pct: float,
                     scratch: Optional[Dict] = None) -> np.ndarray:
  """Vectorized :func:`_variation`: one multiplier per table row."""
  n = len(table)
  type64 = np.asarray([_name_const(t) for t in table.pe_type_names],
                      np.uint64)[table.pe_code]
  h = _scratch_buf(scratch, f"var64_{salt}", n, np.uint64)
  if h is None:
    h = np.empty(n, np.uint64)
  h[...] = _name_const(salt)
  cols = (type64,
          table.pe_rows.astype(np.uint64), table.pe_cols.astype(np.uint64),
          table.sp_if.astype(np.uint64), table.sp_fw.astype(np.uint64),
          table.sp_ps.astype(np.uint64), table.gbuf_kb.astype(np.uint64),
          table.bandwidth_gbps.astype(np.float64).view(np.uint64))
  for v in cols:
    np.bitwise_xor(h, v, out=h)
    _mix64_batch(h, out=h)
  u = _scratch_buf(scratch, f"var_{salt}", n, np.float64)
  if u is None:
    u = np.empty(n, np.float64)
  # same IEEE op sequence as the expression form: /2^64, *2, -1, *pct, +1
  np.true_divide(h, 2.0**64, out=u)
  np.multiply(u, 2.0, out=u)
  np.subtract(u, 1.0, out=u)
  np.multiply(u, pct, out=u)
  np.add(u, 1.0, out=u)
  return u


def batch_inputs(table, scratch: Optional[Dict] = None
                 ) -> Dict[str, np.ndarray]:
  """The array bundle all batch formulas consume: numeric columns +
  per-row PE constants + the three precomputed variation columns + the
  transcendental terms (log2 / pow) of the area/clock formulas.

  The transcendentals are precomputed with host numpy for the same reason
  the variation columns are: they are pure functions of the config
  columns, and libm (numpy) and XLA disagree by 1 ulp on ``log2``/``pow``
  — precomputing them makes the ``jax.jit`` x64 device path bit-identical
  to the numpy path by construction (basic arithmetic, ``sqrt``, ``ceil``
  and floor-division are IEEE-exact in both).

  ``scratch`` (a plain dict owned by the caller, one per worker thread)
  lets repeated chunked calls reuse the feature temporaries instead of
  allocating ~20 fresh arrays per chunk; the returned dict then aliases
  the scratch buffers, so the caller must consume it before the next
  call with the same scratch.
  """
  cols = table.numeric_columns(scratch=scratch)
  cols["var_clk"] = _variation_batch(table, "clk", 0.004, scratch)
  cols["var_area"] = _variation_batch(table, "area", 0.005, scratch)
  cols["var_pwr"] = _variation_batch(table, "pwr", 0.005, scratch)
  n = len(table)
  l2pe = _scratch_buf(scratch, "log2_n_pe", n, np.float64)
  cols["log2_n_pe"] = np.log2(np.maximum(cols["n_pe"], 2.0), out=l2pe)
  sp = cols["sp_fw"] + cols["sp_if"] + cols["sp_ps"]
  l2sp = _scratch_buf(scratch, "log2_sp_words", n, np.float64)
  cols["log2_sp_words"] = np.log2(np.maximum(sp, 2.0, out=sp), out=l2sp)
  cg = _scratch_buf(scratch, "congestion", n, np.float64)
  cols["congestion"] = np.multiply(
      0.30, np.power(cols["n_pe"] / 1024.0, 0.7, out=cg), out=cg)
  return cols


def _decoder_levels_arr(words, xp):
  # ceil absorbs log2's 1-ulp XLA/libm divergence everywhere except at
  # exact powers of two, where IEEE log2 is exact in both — bit-safe
  return xp.maximum(xp.ceil(xp.log2(xp.maximum(words, 2.0))), 1.0)  # repro: ignore[EXA002]


def _sram_access_scale_arr(words, xp):
  return (0.47 + 0.45 * xp.sqrt(xp.maximum(words, 1.0) / 64.0)
          + 0.022 * _decoder_levels_arr(words, xp))


def _sram_area_um2_arr(bits, words, xp):
  decoder = 6.0 * _decoder_levels_arr(words, xp) \
      * xp.sqrt(xp.maximum(bits, 1.0)) / 8.0
  area = bits * pe_lib.SRAM_BIT_UM2 + 3.0 * xp.sqrt(xp.maximum(bits, 0.0)) \
      + decoder + 15.0
  return xp.where(bits <= 0, 0.0, area)


def _clock_cols(c, xp):
  # log2 terms come precomputed from batch_inputs when available (host
  # numpy: keeps the jitted x64 path bit-identical — XLA's log2 is 1 ulp
  # off libm); bare numeric_columns() dicts compute them inline
  # fallbacks below only run for bare numeric_columns() dicts, which are
  # host numpy by construction — batch_inputs precomputes for the device
  l2_pe = c["log2_n_pe"] if "log2_n_pe" in c \
      else xp.log2(xp.maximum(c["n_pe"], 2.0))  # repro: ignore[EXA002]
  l2_sp = c["log2_sp_words"] if "log2_sp_words" in c \
      else xp.log2(xp.maximum(c["sp_fw"] + c["sp_if"] + c["sp_ps"], 2.0))  # repro: ignore[EXA002]
  ctrl_ns = 0.028 * l2_pe + 0.006 * l2_sp
  period_ns = (c["critical_path_ns"] + ctrl_ns) * c["var_clk"]
  return 1000.0 / period_ns


def _pe_area_cols(c, xp):
  arith = c["arith_gates"] * pe_lib.GATE_AREA_UM2
  spad = (_sram_area_um2_arr(c["sp_if"] * c["act_bits"], c["sp_if"], xp)
          + _sram_area_um2_arr(c["sp_fw"] * c["weight_bits"], c["sp_fw"], xp)
          + _sram_area_um2_arr(c["sp_ps"] * c["psum_bits"], c["sp_ps"], xp))
  fifo_bits = FIFO_DEPTH * (2 * c["act_bits"] + c["weight_bits"]
                            + c["psum_bits"])
  fifo = fifo_bits * FLOP_BIT_UM2
  ctrl = 0.04 * (arith + spad) + 220 * pe_lib.GATE_AREA_UM2
  return arith + spad + fifo + ctrl


def _array_area_cols(c, xp):
  pe_area = _pe_area_cols(c, xp) * c["n_pe"]
  word = (c["act_bits"] + c["weight_bits"] + c["psum_bits"]) / 3.0
  noc = NOC_GATES_PER_PE * (word / 21.0) * c["n_pe"] * pe_lib.GATE_AREA_UM2
  top = ARRAY_CTRL_GATES * pe_lib.GATE_AREA_UM2
  # pow is precomputed on host like the log2 terms (see _clock_cols);
  # the fallback only runs for host-numpy numeric_columns() dicts
  congestion = c["congestion"] if "congestion" in c \
      else 0.30 * (c["n_pe"] / 1024.0) ** 0.7  # repro: ignore[EXA002]
  route = 1.0 / (1.0 - xp.minimum(congestion, 0.45))
  um2 = (pe_area + noc + top) * route * c["var_area"]
  return um2 * 1e-6


def _gbuf_area_cols(c, xp):
  return _sram_area_um2_arr(c["gbuf_kb"] * 1024 * 8, c["gbuf_kb"] * 512, xp) \
      * 1.15 * 1e-6


def _leakage_cols(c, xp):
  word = (c["act_bits"] + c["weight_bits"] + c["psum_bits"]) / 3.0
  logic_um2 = (c["arith_gates"] + NOC_GATES_PER_PE * word / 21.0) \
      * pe_lib.GATE_AREA_UM2 * c["n_pe"] \
      + ARRAY_CTRL_GATES * pe_lib.GATE_AREA_UM2
  sram_bits = c["n_pe"] * (c["sp_if"] * c["act_bits"]
                           + c["sp_fw"] * c["weight_bits"]
                           + c["sp_ps"] * c["psum_bits"])
  leak = (logic_um2 / pe_lib.GATE_AREA_UM2) * pe_lib.GATE_LEAKAGE_UW \
      + sram_bits * 0.00035
  return leak * 1e-3


def _array_power_cols(c, xp, clock=None, array_area=None):
  if clock is None:
    clock = _clock_cols(c, xp)
  if array_area is None:
    array_area = _array_area_cols(c, xp)
  f_hz = clock * 1e6
  e = pe_lib.ENERGY_PJ
  spad_pj = e["spad_access_per_bit"] * (
      c["act_bits"] * _sram_access_scale_arr(c["sp_if"], xp)
      + c["weight_bits"] * _sram_access_scale_arr(c["sp_fw"], xp)
      + (2.0 / PSUM_AMORTIZE) * c["psum_bits"]
      * _sram_access_scale_arr(c["sp_ps"], xp))
  per_pe_pj = (c["mac_energy_pj"] + spad_pj
               + FIFO_DEPTH * 0.25 * e["fifo_access_per_bit"])
  activity = 0.62
  dyn_pe_mw = c["n_pe"] * per_pe_pj * activity * f_hz * 1e-9
  gbuf_word_bits = (c["act_bits"] + c["weight_bits"] + c["psum_bits"]) / 3.0
  noc_mw = c["n_pe"] * 0.004 * (f_hz * 1e-9) * gbuf_word_bits
  dyn = dyn_pe_mw + noc_mw
  density = dyn / xp.maximum(array_area, 1e-6)
  leak = _leakage_cols(c, xp) * (1.0 + 0.9 * density / (density + 40.0))
  return dyn * c["var_pwr"] + leak


def _gbuf_power_cols(c, xp, clock=None):
  if clock is None:
    clock = _clock_cols(c, xp)
  f_hz = clock * 1e6
  e = pe_lib.ENERGY_PJ
  gbuf_word_bits = (c["act_bits"] + c["weight_bits"] + c["psum_bits"]) / 3.0
  gbuf_pj_bit = e["gbuf_access_per_bit"] * _sram_access_scale_arr(
      c["gbuf_kb"] * 16.0, xp)
  dyn = xp.sqrt(c["n_pe"]) * gbuf_word_bits * gbuf_pj_bit * 0.62 \
      * f_hz * 1e-9
  leak = c["gbuf_kb"] * 8192 * 0.00035 * 1e-3
  return dyn + leak


# -- public batch API (each takes a ConfigTable, like the scalar siblings
# take an AcceleratorConfig) -------------------------------------------------

def clock_mhz_batch(table, xp=np, inputs: Optional[Dict] = None) -> np.ndarray:
  """Vectorized :func:`clock_mhz` over a ConfigTable."""
  return _clock_cols(inputs if inputs is not None else batch_inputs(table), xp)


def pe_area_um2_batch(table, xp=np, inputs: Optional[Dict] = None
                      ) -> np.ndarray:
  """Vectorized :func:`pe_area_um2`."""
  return _pe_area_cols(
      inputs if inputs is not None else batch_inputs(table), xp)


def array_area_mm2_batch(table, xp=np, inputs: Optional[Dict] = None
                         ) -> np.ndarray:
  """Vectorized :func:`array_area_mm2`."""
  return _array_area_cols(
      inputs if inputs is not None else batch_inputs(table), xp)


def gbuf_area_mm2_batch(table, xp=np, inputs: Optional[Dict] = None
                        ) -> np.ndarray:
  """Vectorized :func:`gbuf_area_mm2`."""
  return _gbuf_area_cols(
      inputs if inputs is not None else batch_inputs(table), xp)


def area_mm2_batch(table, xp=np, inputs: Optional[Dict] = None) -> np.ndarray:
  """Vectorized :func:`area_mm2`."""
  c = inputs if inputs is not None else batch_inputs(table)
  return _array_area_cols(c, xp) + _gbuf_area_cols(c, xp)


def leakage_mw_batch(table, xp=np, inputs: Optional[Dict] = None
                     ) -> np.ndarray:
  """Vectorized :func:`leakage_mw`."""
  return _leakage_cols(
      inputs if inputs is not None else batch_inputs(table), xp)


def array_power_mw_batch(table, xp=np, inputs: Optional[Dict] = None
                         ) -> np.ndarray:
  """Vectorized :func:`array_power_mw`."""
  return _array_power_cols(
      inputs if inputs is not None else batch_inputs(table), xp)


def gbuf_power_mw_batch(table, xp=np, inputs: Optional[Dict] = None
                        ) -> np.ndarray:
  """Vectorized :func:`gbuf_power_mw`."""
  return _gbuf_power_cols(
      inputs if inputs is not None else batch_inputs(table), xp)


def power_mw_batch(table, xp=np, inputs: Optional[Dict] = None) -> np.ndarray:
  """Vectorized :func:`power_mw`."""
  c = inputs if inputs is not None else batch_inputs(table)
  clock = _clock_cols(c, xp)
  return _array_power_cols(c, xp, clock=clock) \
      + _gbuf_power_cols(c, xp, clock=clock)


def power_area_batch(table, xp=np, inputs: Optional[Dict] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
  """(power_mw, area_mm2) per row, sharing the clock / array-area
  intermediates both targets need — the hot pair of every DSE sweep."""
  c = inputs if inputs is not None else batch_inputs(table)
  clock = _clock_cols(c, xp)
  array_area = _array_area_cols(c, xp)
  gbuf_area = _gbuf_area_cols(c, xp)
  power = _array_power_cols(c, xp, clock=clock, array_area=array_area) \
      + _gbuf_power_cols(c, xp, clock=clock)
  return power, array_area + gbuf_area


@dataclasses.dataclass
class BatchCharacterization:
  """Column form of :class:`Characterization` for N design points."""
  clock_mhz: np.ndarray
  area_mm2: np.ndarray
  power_mw: np.ndarray
  latency_s: np.ndarray
  energy_mj: np.ndarray
  utilization: np.ndarray

  def __len__(self) -> int:
    return int(self.clock_mhz.shape[0])


def hw_batch_targets(c, xp=np) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                        np.ndarray]:
  """(clock_mhz, power_mw, area_mm2, leakage_mw) from an inputs bundle —
  the shared workload-independent half of :func:`characterize_batch` /
  :func:`characterize_joint` (and of the fused device programs)."""
  clock = _clock_cols(c, xp)
  array_area = _array_area_cols(c, xp)
  area = array_area + _gbuf_area_cols(c, xp)
  power = _array_power_cols(c, xp, clock=clock, array_area=array_area) \
      + _gbuf_power_cols(c, xp, clock=clock)
  leak = _leakage_cols(c, xp)
  return clock, power, area, leak


def characterize_batch(table, layers: Sequence[ConvLayer], xp=np,
                       inputs: Optional[Dict] = None
                       ) -> BatchCharacterization:
  """Vectorized :func:`characterize`: one synthesis-oracle characterization
  per table row, sharing clock/area/variation intermediates across targets.
  """
  from repro.core.dataflow import simulate_network_batch
  c = inputs if inputs is not None else batch_inputs(table)
  clock, power, area, leak = hw_batch_targets(c, xp)
  latency_s, energy_mj, utilization = simulate_network_batch(
      c, layers, clock, leak, xp=xp)
  return BatchCharacterization(
      clock_mhz=clock, area_mm2=area, power_mw=power,
      latency_s=latency_s, energy_mj=energy_mj, utilization=utilization)


def characterize_layer_latency_batch(table, layer: ConvLayer, xp=np,
                                     inputs: Optional[Dict] = None
                                     ) -> np.ndarray:
  """Vectorized :func:`characterize_layer_latency` (seconds per row)."""
  from repro.core.dataflow import simulate_layer_batch
  c = inputs if inputs is not None else batch_inputs(table)
  clk = _clock_cols(c, xp)
  st = simulate_layer_batch(c, layer, clk, xp=xp)
  return st.cycles / (clk * 1e6)


# ---------------------------------------------------------------------------
# joint HW x NN characterization: every architecture x every design point
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JointCharacterization:
  """Characterization of ``n_archs x n_hw`` (architecture, HW) pairs.

  Clock / power / area depend only on the hardware and are ``(n_hw,)``;
  the workload-dependent targets are ``(n_archs, n_hw)`` (arch-major,
  matching :class:`repro.core.table.JointTable` row order when
  flattened)."""
  clock_mhz: np.ndarray
  area_mm2: np.ndarray
  power_mw: np.ndarray
  latency_s: np.ndarray
  energy_mj: np.ndarray
  utilization: np.ndarray

  @property
  def n_archs(self) -> int:
    return int(self.latency_s.shape[0])

  @property
  def n_hw(self) -> int:
    return int(self.latency_s.shape[1])


def characterize_joint(table, stack, xp=np, inputs: Optional[Dict] = None
                       ) -> JointCharacterization:
  """Joint :func:`characterize_batch`: one characterization per
  (architecture, design point) pair, computing the HW-only targets
  (clock/area/power) once per design point instead of once per pair.

  ``stack`` is a :class:`repro.core.dataflow.LayerStack`; on the numpy
  path row ``a`` of the workload targets is bit-identical to
  ``characterize_batch(table, stack.layers_of(a))``.
  """
  from repro.core.dataflow import simulate_network_stack
  c = inputs if inputs is not None else batch_inputs(table)
  clock, power, area, leak = hw_batch_targets(c, xp)
  latency_s, energy_mj, utilization = simulate_network_stack(
      c, stack, clock, leak, xp=xp)
  return JointCharacterization(
      clock_mhz=clock, area_mm2=area, power_mw=power,
      latency_s=latency_s, energy_mj=energy_mj, utilization=utilization)


def characterize_joint_dedup(table, unique_cols, slot_ids, valid, xp=np,
                             inputs: Optional[Dict] = None
                             ) -> JointCharacterization:
  """Distinct-layer twin of :func:`characterize_joint` — same outputs,
  bit-identical on the numpy path, with the dataflow formulas evaluated
  once per distinct layer shape instead of once per (arch, slot) (see
  :func:`repro.core.dataflow.simulate_network_stack_dedup`).  This is the
  form the exact ``jax.jit`` device path compiles: stack data enters as
  arrays, so one executable serves every arch block of a streaming sweep.
  """
  from repro.core.dataflow import simulate_network_stack_dedup
  c = inputs if inputs is not None else batch_inputs(table)
  clock, power, area, leak = hw_batch_targets(c, xp)
  latency_s, energy_mj, utilization = simulate_network_stack_dedup(
      c, unique_cols, slot_ids, valid, clock, leak, xp=xp)
  return JointCharacterization(
      clock_mhz=clock, area_mm2=area, power_mw=power,
      latency_s=latency_s, energy_mj=energy_mj, utilization=utilization)
