"""Design-space exploration — COMPATIBILITY SHIM over ``repro.explore``.

The exploration surface moved to the unified :mod:`repro.explore` package
(declarative DesignSpace, pluggable OracleBackend/PolynomialBackend,
columnar ResultFrame, ExplorationSession).  This module keeps the old
names working as thin delegations:

  DesignPoint             -> repro.explore.DesignPoint (re-export)
  evaluate_with_oracle    -> OracleBackend().evaluate(...).to_points()
  evaluate_with_models    -> PolynomialBackend(models).evaluate(...)
  pareto_front            -> repro.explore.pareto_mask (vectorized)
  best_int16_reference    -> ResultFrame.reference_index
  normalized_metrics      -> ResultFrame.normalize
  distribution_stats      -> repro.explore.summary_stats
  DesignSpaceExplorer     -> ExplorationSession + PolynomialBackend.fit

New code should import from :mod:`repro.explore` directly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import ppa as ppa_lib
from repro.core.dataflow import AcceleratorConfig, ConvLayer
from repro.core.pe import PAPER_PE_TYPES
from repro.explore.backend import OracleBackend, PolynomialBackend
from repro.explore.frame import (DesignPoint, ResultFrame, pareto_mask,
                                 summary_stats)
from repro.explore.session import ExplorationSession
from repro.explore.space import DesignSpace

__all__ = [
    "DesignPoint", "DesignSpaceExplorer", "ExplorationResult",
    "best_int16_reference", "distribution_stats", "evaluate_with_models",
    "evaluate_with_oracle", "normalized_metrics", "pareto_front",
]


def evaluate_with_oracle(cfgs: Sequence[AcceleratorConfig],
                         layers: Sequence[ConvLayer],
                         network: str) -> List[DesignPoint]:
  """Slow path: full characterization per design (synthesis stand-in)."""
  return OracleBackend().evaluate(cfgs, layers, network).to_points()


def evaluate_with_models(models: Dict[str, ppa_lib.PPAModels],
                         cfgs: Sequence[AcceleratorConfig],
                         layers: Sequence[ConvLayer],
                         network: str) -> List[DesignPoint]:
  """Fast path: pre-characterized polynomial PPA models (batched)."""
  return PolynomialBackend(models).evaluate(cfgs, layers, network).to_points()


def pareto_front(objectives: np.ndarray) -> np.ndarray:
  """Boolean mask of non-dominated rows; all objectives are MINIMIZED."""
  return pareto_mask(objectives)


def best_int16_reference(points: Sequence[DesignPoint],
                         metric: str = "perf_per_area") -> DesignPoint:
  """The paper's normalization anchor: best INT16 config under `metric`."""
  points = list(points)
  frame = ResultFrame.from_points(points)
  return points[frame.reference_index(metric)]


def normalized_metrics(points: Sequence[DesignPoint],
                       ref: Optional[DesignPoint] = None
                       ) -> Tuple[np.ndarray, np.ndarray]:
  """(normalized perf/area, normalized energy) vs best-INT16-perf/area."""
  frame = ResultFrame.from_points(points)
  if ref is None:
    norm = frame.normalize(ref="best-int16")
  else:
    norm = frame.normalize(ref=(ref.perf_per_area, ref.energy_mj))
  return norm.perf_per_area, norm.energy


def distribution_stats(values: np.ndarray) -> Dict[str, float]:
  """Fig. 9 violin summary: min / q1 / median / q3 / max / mean."""
  return summary_stats(values)


# ---------------------------------------------------------------------------
# the explorer (legacy facade)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExplorationResult:
  points: List[DesignPoint]
  seconds_model: float
  seconds_oracle_per_design: float

  @property
  def speedup(self) -> float:
    n = max(len(self.points), 1)
    per_model = self.seconds_model / n
    return self.seconds_oracle_per_design / max(per_model, 1e-12)


class DesignSpaceExplorer:
  """Fit-once / evaluate-many QUIDAM DSE driver (legacy facade over
  ExplorationSession; fits share the process-wide PolynomialBackend cache)."""

  def __init__(self, pe_types: Sequence[str] = PAPER_PE_TYPES,
               degree: int = 5, n_train: int = 240, seed: int = 0,
               layers: Optional[Sequence[ConvLayer]] = None):
    self.pe_types = tuple(pe_types)
    self.backend = PolynomialBackend.fit(self.pe_types, degree=degree,
                                         n_train=n_train, layers=layers,
                                         seed=seed)
    self.session = ExplorationSession(self.backend,
                                      DesignSpace(pe_types=self.pe_types))

  @property
  def models(self) -> Dict[str, ppa_lib.PPAModels]:
    return self.backend.models

  def explore(self, layers: Sequence[ConvLayer], network: str,
              n_per_type: int = 200, seed: int = 17,
              measure_oracle: int = 3) -> ExplorationResult:
    frame = self.session.explore(layers, network, n_per_type=n_per_type,
                                 seed=seed, measure_oracle=measure_oracle)
    return ExplorationResult(
        frame.to_points(), frame.meta["eval_seconds"],
        frame.meta.get("oracle_seconds_per_design", 0.0))
