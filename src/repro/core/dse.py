"""Design-space exploration and Pareto analysis (paper Secs. 3.3-4.5).

Evaluates accelerator design points — via the fast polynomial PPA models or
the slow synthesis oracle — over DNN workloads, producing the paper's
metrics:

  performance            = 1 / latency            (Sec. 3.3)
  performance per area   = perf / area
  energy                 = power * latency        (per inference)

with normalization against the *best INT16 configuration* (highest
perf/area, resp. lowest energy), Pareto-front extraction, and distribution
statistics (Fig. 9's violins).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import oracle
from repro.core import ppa as ppa_lib
from repro.core.dataflow import AcceleratorConfig, ConvLayer
from repro.core.pe import PAPER_PE_TYPES


@dataclasses.dataclass
class DesignPoint:
  """One evaluated (hardware config, network) pair."""
  cfg: AcceleratorConfig
  network: str
  latency_s: float
  power_mw: float
  area_mm2: float

  @property
  def perf(self) -> float:
    return 1.0 / max(self.latency_s, 1e-12)

  @property
  def perf_per_area(self) -> float:
    return self.perf / max(self.area_mm2, 1e-12)

  @property
  def energy_mj(self) -> float:
    return self.power_mw * self.latency_s  # mW * s = mJ


def evaluate_with_oracle(cfgs: Sequence[AcceleratorConfig],
                         layers: Sequence[ConvLayer],
                         network: str) -> List[DesignPoint]:
  """Slow path: full characterization per design (synthesis stand-in)."""
  out = []
  for cfg in cfgs:
    ch = oracle.characterize(cfg, layers)
    out.append(DesignPoint(cfg, network, ch.latency_s, ch.power_mw,
                           ch.area_mm2))
  return out


import functools


@functools.lru_cache(maxsize=65536)
def _gbuf_power_cached(cfg: AcceleratorConfig) -> float:
  return oracle.gbuf_power_mw(cfg)


@functools.lru_cache(maxsize=65536)
def _gbuf_area_cached(cfg: AcceleratorConfig) -> float:
  return oracle.gbuf_area_mm2(cfg)


def evaluate_with_models(models: Dict[str, ppa_lib.PPAModels],
                         cfgs: Sequence[AcceleratorConfig],
                         layers: Sequence[ConvLayer],
                         network: str) -> List[DesignPoint]:
  """Fast path: pre-characterized polynomial PPA models (batched)."""
  by_type: Dict[str, List[int]] = {}
  for i, c in enumerate(cfgs):
    by_type.setdefault(c.pe_type, []).append(i)
  lat = np.zeros(len(cfgs))
  pwr = np.zeros(len(cfgs))
  area = np.zeros(len(cfgs))
  for pe_type, idxs in by_type.items():
    sub = [cfgs[i] for i in idxs]
    m = models[pe_type]
    lat[idxs] = np.maximum(m.predict_network_latency_s(sub, layers), 1e-9)
    # polynomial model covers the PE array; the global buffer composes as a
    # pre-characterized SRAM macro (closed form, memoized per unique config)
    gb_p = np.asarray([_gbuf_power_cached(c) for c in sub])
    gb_a = np.asarray([_gbuf_area_cached(c) for c in sub])
    pwr[idxs] = np.maximum(m.predict_power_mw(sub), 1e-3) + gb_p
    area[idxs] = np.maximum(m.predict_area_mm2(sub), 1e-6) + gb_a
  return [DesignPoint(c, network, float(lat[i]), float(pwr[i]),
                      float(area[i])) for i, c in enumerate(cfgs)]


# ---------------------------------------------------------------------------
# Pareto machinery
# ---------------------------------------------------------------------------

def pareto_front(objectives: np.ndarray) -> np.ndarray:
  """Boolean mask of non-dominated rows; all objectives are MINIMIZED."""
  obj = np.asarray(objectives, np.float64)
  n = obj.shape[0]
  mask = np.ones(n, dtype=bool)
  for i in range(n):
    if not mask[i]:
      continue
    # points strictly dominated by i die
    dominated_by_i = (np.all(obj >= obj[i], axis=1)
                      & np.any(obj > obj[i], axis=1))
    mask[dominated_by_i] = False
    # i dies if anyone dominates it
    dominators = (np.all(obj <= obj[i], axis=1)
                  & np.any(obj < obj[i], axis=1))
    if np.any(dominators):
      mask[i] = False
  return mask


def best_int16_reference(points: Sequence[DesignPoint],
                         metric: str = "perf_per_area") -> DesignPoint:
  """The paper's normalization anchor: best INT16 config under `metric`."""
  int16 = [p for p in points if p.cfg.pe_type == "INT16"]
  if not int16:
    raise ValueError("design space contains no INT16 points to normalize by")
  if metric == "perf_per_area":
    return max(int16, key=lambda p: p.perf_per_area)
  if metric == "energy":
    return min(int16, key=lambda p: p.energy_mj)
  if metric == "area":
    return min(int16, key=lambda p: p.area_mm2)
  raise ValueError(f"unknown reference metric {metric!r}")


def normalized_metrics(points: Sequence[DesignPoint],
                       ref: Optional[DesignPoint] = None
                       ) -> Tuple[np.ndarray, np.ndarray]:
  """(normalized perf/area, normalized energy) vs best-INT16-perf/area."""
  if ref is None:
    ref = best_int16_reference(points, "perf_per_area")
  ppa = np.asarray([p.perf_per_area for p in points]) / ref.perf_per_area
  en = np.asarray([p.energy_mj for p in points]) / ref.energy_mj
  return ppa, en


def distribution_stats(values: np.ndarray) -> Dict[str, float]:
  """Fig. 9 violin summary: min / q1 / median / q3 / max / mean."""
  v = np.asarray(values, np.float64)
  return {
      "min": float(v.min()), "q1": float(np.percentile(v, 25)),
      "median": float(np.median(v)), "q3": float(np.percentile(v, 75)),
      "max": float(v.max()), "mean": float(v.mean()),
  }


# ---------------------------------------------------------------------------
# the explorer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExplorationResult:
  points: List[DesignPoint]
  seconds_model: float
  seconds_oracle_per_design: float

  @property
  def speedup(self) -> float:
    n = max(len(self.points), 1)
    per_model = self.seconds_model / n
    return self.seconds_oracle_per_design / max(per_model, 1e-12)


class DesignSpaceExplorer:
  """Fit-once / evaluate-many QUIDAM DSE driver."""

  def __init__(self, pe_types: Sequence[str] = PAPER_PE_TYPES,
               degree: int = 5, n_train: int = 240, seed: int = 0,
               layers: Optional[Sequence[ConvLayer]] = None):
    self.pe_types = tuple(pe_types)
    self.models: Dict[str, ppa_lib.PPAModels] = {}
    for i, t in enumerate(self.pe_types):
      self.models[t] = ppa_lib.fit_ppa_models(
          t, degree=degree, n_train=n_train, layers=layers, seed=seed + i)

  def explore(self, layers: Sequence[ConvLayer], network: str,
              n_per_type: int = 200, seed: int = 17,
              measure_oracle: int = 3) -> ExplorationResult:
    cfgs: List[AcceleratorConfig] = []
    for i, t in enumerate(self.pe_types):
      cfgs.extend(ppa_lib.sample_configs(t, n_per_type, seed=seed + 100 * i))
    t0 = time.perf_counter()
    points = evaluate_with_models(self.models, cfgs, layers, network)
    t_model = time.perf_counter() - t0
    t_oracle = 0.0
    if measure_oracle:
      t1 = time.perf_counter()
      evaluate_with_oracle(cfgs[:measure_oracle], layers, network)
      t_oracle = (time.perf_counter() - t1) / measure_oracle
    return ExplorationResult(points, t_model, t_oracle)
