"""CNN substrate for the paper's accuracy experiments (pure JAX).

Conv-BN-ReLU stacks (VGG plans, channel/repeat-sliceable for the weight-
sharing supernet) and CIFAR-style basic-block ResNets, trained with the
paper's SGD recipe on the procedural `cifar_like` dataset, under any
QUIDAM PE-type fake-quant policy (FP32 / INT16 / LightPE-1 / LightPE-2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant as quant_lib

Params = Any


def conv_init(key, k: int, c_in: int, c_out: int) -> jax.Array:
  fan_in = k * k * c_in
  return jax.random.normal(key, (k, k, c_in, c_out), jnp.float32) \
      * (2.0 / fan_in) ** 0.5


def conv2d(x: jax.Array, w: jax.Array, stride: int = 1,
           padding: str = "SAME") -> jax.Array:
  return jax.lax.conv_general_dilated(
      x, w, (stride, stride), padding,
      dimension_numbers=("NHWC", "HWIO", "NHWC"))


def batch_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
  mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
  var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
  return (x - mean) * jax.lax.rsqrt(var + eps) * scale + bias


def maxpool(x: jax.Array) -> jax.Array:
  return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                               (1, 2, 2, 1), "VALID")


def _maybe_fq(w: jax.Array, pe_type: str) -> jax.Array:
  if pe_type == "FP32":
    return w
  # per-output-channel (last axis) weight fake quant
  return quant_lib.fake_quant_for_pe(w, pe_type, channel_axis=-1)


def _maybe_fq_act(x: jax.Array, pe_type: str) -> jax.Array:
  if pe_type == "FP32":
    return x
  return quant_lib.act_fake_quant_for_pe(x, pe_type)


# ---------------------------------------------------------------------------
# VGG (plan-parameterized; supernet-sliceable)
# ---------------------------------------------------------------------------

# Table 4 search space: (repeat choices, channel choices) per stage.
SEARCH_SPACE: Tuple[Tuple[Tuple[int, ...], Tuple[int, ...]], ...] = (
    ((1, 2), (40, 48, 56, 64)),
    ((1, 2), (80, 96, 112, 128)),
    ((1, 2, 3), (160, 192, 224, 256)),
    ((1, 2, 3), (320, 384, 448, 512)),
    ((1, 2, 3), (320, 384, 448, 512)),
)

MAX_PLAN = tuple((max(reps), max(chs)) for reps, chs in SEARCH_SPACE)
SPACE_SIZE = 1
for _reps, _chs in SEARCH_SPACE:
  SPACE_SIZE *= (len(_reps) * len(_chs)) ** 1  # per stage: reps x channels
SPACE_SIZE = 1
for _reps, _chs in SEARCH_SPACE:
  SPACE_SIZE *= len(_reps) * len(_chs)         # = 110,592


@dataclasses.dataclass(frozen=True)
class ArchChoice:
  """One point of the Table-4 space: per-stage (repeats, channels)."""
  stages: Tuple[Tuple[int, int], ...]

  def as_plan(self) -> List[Tuple[int, int]]:
    return [(c, r) for (r, c) in self.stages]


def sample_arch(key) -> ArchChoice:
  ks = jax.random.split(key, len(SEARCH_SPACE))
  stages = []
  for (reps, chs), k in zip(SEARCH_SPACE, ks):
    kr, kc = jax.random.split(k)
    r = reps[int(jax.random.randint(kr, (), 0, len(reps)))]
    c = chs[int(jax.random.randint(kc, (), 0, len(chs)))]
    stages.append((r, c))
  return ArchChoice(tuple(stages))


def max_arch() -> ArchChoice:
  return ArchChoice(MAX_PLAN)


def init_vgg_supernet(key, n_classes: int = 10, in_ch: int = 3) -> Params:
  """Weights for the LARGEST config; subnets slice channels/repeats."""
  params: Dict[str, Any] = {"stages": []}
  c_prev = in_ch
  for si, (reps, c_out) in enumerate(MAX_PLAN):
    stage = []
    for r in range(reps):
      key, k1 = jax.random.split(key)
      stage.append({
          "w": conv_init(k1, 3, c_prev, c_out),
          "scale": jnp.ones((c_out,), jnp.float32),
          "bias": jnp.zeros((c_out,), jnp.float32),
      })
      c_prev = c_out
    params["stages"].append(stage)
  key, k1 = jax.random.split(key)
  params["head"] = jax.random.normal(
      k1, (MAX_PLAN[-1][1], n_classes), jnp.float32) * 0.01
  return params


def arch_masks(arch: ArchChoice):
  """Dynamic (r_use, c_use) arrays so ONE compiled graph serves the whole
  110,592-point space (channel masking is mathematically identical to
  channel slicing: masked inputs contribute zero to every conv)."""
  r = jnp.asarray([r for (r, _) in arch.stages], jnp.int32)
  c = jnp.asarray([c for (_, c) in arch.stages], jnp.int32)
  return r, c


def apply_vgg(params: Params, images: jax.Array,
              arch: Optional[ArchChoice] = None,
              pe_type: str = "FP32",
              r_use: Optional[jax.Array] = None,
              c_use: Optional[jax.Array] = None) -> jax.Array:
  """images (B, H, W, 3) -> logits; masks the supernet per `arch`."""
  if arch is not None:
    r_use, c_use = arch_masks(arch)
  x = images
  for si, stage in enumerate(params["stages"]):
    c_max = stage[0]["w"].shape[-1]
    cmask = (jnp.arange(c_max) < c_use[si]).astype(x.dtype)
    for r, blk in enumerate(stage):
      y = conv2d(_maybe_fq_act(x, pe_type), _maybe_fq(blk["w"], pe_type))
      y = batch_norm(y, blk["scale"], blk["bias"])
      y = jax.nn.relu(y) * cmask[None, None, None, :]
      if r == 0:
        x = y  # first conv changes the channel count: always applied
      else:
        keep = (r < r_use[si]).astype(x.dtype)
        x = keep * y + (1.0 - keep) * x
    if x.shape[1] > 1:
      x = maxpool(x)
  x = jnp.mean(x, axis=(1, 2))                     # global average pool
  return jnp.einsum("bc,cn->bn", x, _maybe_fq(params["head"], pe_type))


# ---------------------------------------------------------------------------
# CIFAR ResNets (reduced-width variants for the QAT accuracy studies)
# ---------------------------------------------------------------------------

def init_resnet(key, depth: int, n_classes: int = 10, width: int = 16,
                in_ch: int = 3) -> Params:
  assert (depth - 2) % 6 == 0
  n = (depth - 2) // 6
  params: Dict[str, Any] = {}
  key, k = jax.random.split(key)
  params["stem"] = {"w": conv_init(k, 3, in_ch, width),
                    "scale": jnp.ones((width,)), "bias": jnp.zeros((width,))}
  blocks = []
  c_prev = width
  for stage, mult in enumerate((1, 2, 4)):
    c = width * mult
    for b in range(n):
      key, k1, k2, k3 = jax.random.split(key, 4)
      blk = {
          "w1": conv_init(k1, 3, c_prev, c),
          "s1": jnp.ones((c,)), "b1": jnp.zeros((c,)),
          "w2": conv_init(k2, 3, c, c),
          "s2": jnp.ones((c,)), "b2": jnp.zeros((c,)),
      }
      if c_prev != c:
        blk["proj"] = conv_init(k3, 1, c_prev, c)
      blocks.append(blk)
      c_prev = c
    params[f"stage{stage}"] = None  # layout marker
  params["blocks"] = blocks
  key, k = jax.random.split(key)
  params["head"] = jax.random.normal(k, (c_prev, n_classes)) * 0.01
  return params


def apply_resnet(params: Params, images: jax.Array, depth: int,
                 pe_type: str = "FP32") -> jax.Array:
  n = (depth - 2) // 6
  x = conv2d(images, _maybe_fq(params["stem"]["w"], pe_type))
  x = jax.nn.relu(batch_norm(x, params["stem"]["scale"],
                             params["stem"]["bias"]))
  bi = 0
  for stage in range(3):
    for b in range(n):
      blk = params["blocks"][bi]
      bi += 1
      stride = 2 if (stage > 0 and b == 0) else 1
      h = conv2d(_maybe_fq_act(x, pe_type), _maybe_fq(blk["w1"], pe_type),
                 stride=stride)
      h = jax.nn.relu(batch_norm(h, blk["s1"], blk["b1"]))
      h = conv2d(_maybe_fq_act(h, pe_type), _maybe_fq(blk["w2"], pe_type))
      h = batch_norm(h, blk["s2"], blk["b2"])
      if "proj" in blk:
        x = conv2d(x, _maybe_fq(blk["proj"], pe_type), stride=stride)
      x = jax.nn.relu(x + h)
  x = jnp.mean(x, axis=(1, 2))
  return jnp.einsum("bc,cn->bn", x, _maybe_fq(params["head"], pe_type))


# ---------------------------------------------------------------------------
# loss/accuracy helpers
# ---------------------------------------------------------------------------

def xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
  logz = jax.nn.logsumexp(logits, axis=-1)
  gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
  return jnp.mean(logz - gold)


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
  return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
