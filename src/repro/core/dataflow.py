"""Row-stationary (RS) dataflow model for the QUIDAM accelerator template.

This is the cycle-approximate analytical model of the Eyeriss-style spatial
array the paper synthesizes (Sec. 3.1): a ``rows x cols`` PE grid running
row-stationary dataflow, per-PE scratchpads (ifmap/filter/psum), a global
buffer, and DRAM behind a finite-bandwidth link.

It provides the *ground-truth* latency / utilization / memory-access counts
that the paper obtains from Synopsys VCS testbenches; the polynomial PPA
models of :mod:`repro.core.ppa` are trained against it (together with the
area/power numbers from :mod:`repro.core.oracle`).

Mapping summary (Chen et al., ISCA'16):
  * a logical PE set of ``K`` rows x ``E`` cols computes one 2-D conv plane;
    PE(i, j) convolves filter row ``i`` against ifmap row ``i + j`` and
    produces psums of output row ``j``.
  * the logical set is folded onto the physical array: ``E`` folds over the
    columns, ``K`` folds over the rows; leftover rows replicate additional
    channel/filter tiles.
  * scratchpads bound the per-pass tile sizes:
      - psum spad       -> F_tile accumulators held per PE
      - filter spad     -> K * C_tile * F_tile weights held per PE
      - ifmap spad      -> sliding window of C_tile * K activations
  * passes iterate over ceil(C / C_tile) * ceil(F / F_tile) tiles; psums
    spill to the global buffer between channel tiles.

Each simulation entry point has a vectorized ``*_batch`` sibling
(:func:`simulate_layer_batch`, :func:`simulate_network_batch`) that
evaluates a whole :class:`repro.core.table.ConfigTable` column-at-a-time,
bit-identically to the scalar model on the numpy path.

For joint HW x NN co-exploration the per-network layer loop additionally
batches over *architectures*: :class:`LayerStack` pre-packs every
architecture's layer features into padded ``(n_archs, max_layers)``
tensors once, and :func:`simulate_network_stack` evaluates all
``n_archs x n_hw`` pairs with one ``(n_archs, n_hw)``-shaped pass per
layer slot — the same formulas as :func:`simulate_layer_batch`, with the
layer-side constants promoted from Python floats to broadcast arrays, so
the numpy path stays bit-identical to the scalar nested loop.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import pe as pe_lib


@dataclasses.dataclass(frozen=True)
class ConvLayer:
  """One conv (or 1x1-conv-as-matmul) workload layer.

  A: input feature-map spatial dim (assumed square A x A)
  C: input channels;  F: output channels (filter count)
  K: kernel size;     S: stride;     P: padding
  rs/ds: ResNet regular / dotted (projection) skip-connection indicators,
  the two binary extra features of the paper's latency model.
  """
  name: str
  A: int
  C: int
  F: int
  K: int = 1
  S: int = 1
  P: int = 0
  rs: int = 0
  ds: int = 0

  @property
  def out_dim(self) -> int:
    return (self.A + 2 * self.P - self.K) // self.S + 1

  @property
  def macs(self) -> int:
    e = self.out_dim
    return e * e * self.K * self.K * self.C * self.F

  @property
  def weight_count(self) -> int:
    return self.K * self.K * self.C * self.F

  @property
  def ifmap_count(self) -> int:
    return self.A * self.A * self.C

  @property
  def ofmap_count(self) -> int:
    e = self.out_dim
    return e * e * self.F

  def features(self) -> Tuple[float, ...]:
    """The layer-side features of the paper's 12-dim latency vector."""
    return (float(self.A), float(self.C), float(self.F), float(self.K),
            float(self.S), float(self.P), float(self.rs), float(self.ds))


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
  """The hardware half of QUIDAM's input space (Fig. 2)."""
  pe_type: str = "INT16"
  pe_rows: int = 16
  pe_cols: int = 16
  sp_if: int = 12      # ifmap scratchpad entries (words)
  sp_fw: int = 224     # filter scratchpad entries
  sp_ps: int = 24      # psum scratchpad entries
  gbuf_kb: int = 128   # global buffer (KiB)
  bandwidth_gbps: float = 12.8  # DRAM link bandwidth

  @property
  def n_pe(self) -> int:
    return self.pe_rows * self.pe_cols

  @property
  def pe(self) -> pe_lib.PEType:
    return pe_lib.pe_type(self.pe_type)

  def hw_features(self) -> Tuple[float, ...]:
    return (float(self.sp_if), float(self.sp_ps), float(self.sp_fw),
            float(self.n_pe))

  def latency_hw_features(self) -> Tuple[float, ...]:
    return (float(self.sp_if), float(self.sp_ps), float(self.sp_fw),
            float(self.pe_rows), float(self.pe_cols), float(self.gbuf_kb))


@dataclasses.dataclass
class LayerStats:
  """Per-layer dataflow simulation output."""
  cycles: float
  compute_cycles: float
  dram_stall_cycles: float
  utilization: float
  macs: int
  # access counts (words) per memory level
  spad_reads: float
  spad_writes: float
  gbuf_reads: float
  gbuf_writes: float
  dram_reads: float
  dram_writes: float


def simulate_layer(cfg: AcceleratorConfig, layer: ConvLayer,
                   clock_mhz: float) -> LayerStats:
  """Cycle-approximate RS dataflow simulation of one layer."""
  pe = cfg.pe
  E = max(layer.out_dim, 1)
  K, C, F = layer.K, layer.C, layer.F

  # ---- spatial mapping -------------------------------------------------
  # columns host output rows (E), rows host filter rows (K)
  col_folds = math.ceil(E / cfg.pe_cols)
  cols_used = min(E, cfg.pe_cols)
  k_rows = min(K, cfg.pe_rows)
  row_folds = math.ceil(K / cfg.pe_rows)
  # leftover row capacity replicates additional (channel, filter) tiles
  sets_per_col = max(cfg.pe_rows // k_rows, 1) if row_folds == 1 else 1
  spatial_util = (k_rows * sets_per_col * cols_used) / cfg.n_pe
  if row_folds > 1:
    spatial_util = (cfg.pe_rows * cols_used) / cfg.n_pe

  # ---- scratchpad-bounded tiling ----------------------------------------
  f_tile = max(1, min(F, cfg.sp_ps))
  # filter spad holds K * C_tile * F_tile weights (one filter row per pass)
  c_tile = max(1, min(C, cfg.sp_fw // max(K * f_tile, 1)))
  # ifmap spad needs a K-deep sliding window per channel in flight
  c_tile = max(1, min(c_tile, max(cfg.sp_if // max(K, 1), 1) * sets_per_col))
  n_c_passes = math.ceil(C / c_tile)
  n_f_passes = math.ceil(F / f_tile)
  # replication across spare row capacity processes extra channel tiles in
  # parallel
  n_c_passes_eff = math.ceil(n_c_passes / sets_per_col)
  passes = n_c_passes_eff * n_f_passes * col_folds * row_folds

  # ---- compute cycles ----------------------------------------------------
  # per pass, each active PE performs E (out width) * K (kernel width) *
  # c_tile * f_tile MACs, 1 MAC/cycle; pipeline fill ~ K + cols_used.
  per_pass = E * K * c_tile * f_tile + (K + cols_used)
  compute_cycles = passes * per_pass
  ideal_cycles = layer.macs / cfg.n_pe
  compute_cycles = max(compute_cycles, ideal_cycles)
  utilization = min(1.0, ideal_cycles / max(compute_cycles, 1.0)) \
      * min(1.0, spatial_util + 1e-9)

  # ---- access counts -----------------------------------------------------
  macs = layer.macs
  # every MAC reads act + weight from its spads; the running psum lives in
  # an accumulator register and spills to the psum spad once per K MACs
  spad_reads = (2.0 + 1.0 / max(K, 1)) * macs
  spad_writes = macs / max(K, 1)
  # ifmap: DRAM -> gbuf once if it fits, else per filter-pass; gbuf -> array
  # once per filter pass (row-stationary reuses within a pass)
  ifmap_words = layer.ifmap_count
  gbuf_bits = cfg.gbuf_kb * 1024 * 8
  ifmap_fits = ifmap_words * pe.act_bits <= 0.5 * gbuf_bits
  dram_if = ifmap_words * (1 if ifmap_fits else n_f_passes)
  gbuf_if_reads = ifmap_words * n_f_passes * row_folds
  # weights: streamed from DRAM once per E-fold when they do not fit
  weight_words = layer.weight_count
  weights_fit = weight_words * pe.weight_bits <= 0.25 * gbuf_bits
  dram_w = weight_words * (1 if weights_fit else col_folds)
  gbuf_w_reads = weight_words * col_folds
  # psums: spill/refill between channel tiles
  of_words = layer.ofmap_count
  psum_spills = max(n_c_passes_eff - 1, 0)
  gbuf_ps = of_words * (2.0 * psum_spills + 1.0)
  dram_of = of_words  # final writeback
  gbuf_reads = gbuf_if_reads + gbuf_w_reads + of_words * psum_spills
  gbuf_writes = of_words * (psum_spills + 1.0)
  dram_reads = dram_if + dram_w
  dram_writes = float(dram_of)

  # ---- bandwidth bound ---------------------------------------------------
  cycle_s = 1e-6 / clock_mhz
  dram_bits = (dram_if * pe.act_bits + dram_w * pe.weight_bits
               + dram_of * pe.psum_bits)
  dram_time_s = dram_bits / 8.0 / (cfg.bandwidth_gbps * 1e9)
  dram_cycles = dram_time_s / cycle_s
  # compute/communication overlap: stalls only for the non-overlapped excess
  dram_stall = max(0.0, dram_cycles - 0.85 * compute_cycles)
  cycles = compute_cycles + dram_stall

  return LayerStats(
      cycles=cycles, compute_cycles=compute_cycles,
      dram_stall_cycles=dram_stall, utilization=utilization, macs=macs,
      spad_reads=spad_reads, spad_writes=spad_writes,
      gbuf_reads=gbuf_reads, gbuf_writes=gbuf_writes,
      dram_reads=float(dram_reads), dram_writes=dram_writes)


def layer_energy_pj(cfg: AcceleratorConfig, layer: ConvLayer,
                    stats: LayerStats, clock_mhz: float,
                    leakage_mw: float) -> float:
  """Eyeriss-style hierarchical energy model (pJ) for one layer."""
  pe = cfg.pe
  e = pe_lib.ENERGY_PJ
  mac_e = stats.macs * pe.mac_energy_pj
  # scratchpad word widths differ per operand; use the mean of act/weight/
  # psum widths for reads (2 operand reads + 1 psum read) and psum for writes
  k = max(layer.K, 1)
  spad_read_bits = stats.macs * (pe.act_bits + pe.weight_bits
                                 + pe.psum_bits / k)
  spad_write_bits = stats.spad_writes * pe.psum_bits
  spad_e = (spad_read_bits + spad_write_bits) * e["spad_access_per_bit"]
  gbuf_bits = (stats.gbuf_reads + stats.gbuf_writes) * (
      (pe.act_bits + pe.weight_bits + pe.psum_bits) / 3.0)
  gbuf_e = gbuf_bits * e["gbuf_access_per_bit"]
  dram_bits = (stats.dram_reads * (pe.act_bits + pe.weight_bits) / 2.0
               + stats.dram_writes * pe.psum_bits)
  dram_e = dram_bits * e["dram_access_per_bit"]
  time_s = stats.cycles / (clock_mhz * 1e6)
  leak_e = leakage_mw * 1e-3 * time_s * 1e12  # mW * s -> pJ
  return mac_e + spad_e + gbuf_e + dram_e + leak_e


def simulate_network(cfg: AcceleratorConfig, layers: Sequence[ConvLayer],
                     clock_mhz: float, leakage_mw: float
                     ) -> Tuple[float, float, List[LayerStats]]:
  """Returns (total_latency_s, total_energy_mj, per-layer stats)."""
  total_cycles = 0.0
  total_energy_pj = 0.0
  all_stats: List[LayerStats] = []
  for layer in layers:
    st = simulate_layer(cfg, layer, clock_mhz)
    total_cycles += st.cycles
    total_energy_pj += layer_energy_pj(cfg, layer, st, clock_mhz, leakage_mw)
    all_stats.append(st)
  latency_s = total_cycles / (clock_mhz * 1e6)
  return latency_s, total_energy_pj * 1e-9, all_stats  # pJ -> mJ


# ---------------------------------------------------------------------------
# vectorized siblings: N design points x one layer at a time
# ---------------------------------------------------------------------------
# The batch functions evaluate a whole ConfigTable (or its
# ``numeric_columns()`` dict) against one layer per call, mirroring the
# scalar control flow with xp.where / xp.minimum so the numpy path matches
# :func:`simulate_layer` bit for bit.  ``xp`` may be jax.numpy for the
# optional device path (approximate there: jax defaults to float32).


def _cols_of(table_or_cols) -> Dict[str, "np.ndarray"]:
  if hasattr(table_or_cols, "numeric_columns"):
    return table_or_cols.numeric_columns()
  return table_or_cols


@dataclasses.dataclass
class LayerStatsBatch:
  """Column form of :class:`LayerStats` for N design points.

  ``macs`` is an int for the one-layer path and an ``(n_archs, 1)`` array
  on the joint (LayerStack) path, where every stat column broadcasts to
  ``(n_archs, n_hw)``."""
  cycles: "np.ndarray"
  compute_cycles: "np.ndarray"
  dram_stall_cycles: "np.ndarray"
  utilization: "np.ndarray"
  macs: "int | np.ndarray"
  spad_reads: "np.ndarray"
  spad_writes: "np.ndarray"
  gbuf_reads: "np.ndarray"
  gbuf_writes: "np.ndarray"
  dram_reads: "np.ndarray"
  dram_writes: "np.ndarray"

  def row(self, i: int) -> LayerStats:
    """One design point's stats as the scalar dataclass."""
    return LayerStats(
        cycles=float(self.cycles[i]),
        compute_cycles=float(self.compute_cycles[i]),
        dram_stall_cycles=float(self.dram_stall_cycles[i]),
        utilization=float(self.utilization[i]), macs=self.macs,
        spad_reads=float(self.spad_reads[i]),
        spad_writes=float(self.spad_writes[i]),
        gbuf_reads=float(self.gbuf_reads[i]),
        gbuf_writes=float(self.gbuf_writes[i]),
        dram_reads=float(self.dram_reads[i]),
        dram_writes=float(self.dram_writes[i]))


def _layer_feats(layer: ConvLayer) -> Dict[str, float]:
  """The layer-side constants the batch formulas consume, as Python
  floats (one ConvLayer) — :class:`LayerStack` supplies the same keys as
  broadcastable ``(n_archs, 1)`` arrays."""
  return {
      "E": float(max(layer.out_dim, 1)),
      "K": float(layer.K), "C": float(layer.C), "F": float(layer.F),
      "macs": float(layer.macs),
      "ifmap_words": float(layer.ifmap_count),
      "weight_words": float(layer.weight_count),
      "of_words": float(layer.ofmap_count),
  }


def _simulate_layer_feats(c, f, clock_mhz, xp) -> LayerStatsBatch:
  """The batch RS-dataflow formulas over HW columns ``c`` x layer
  features ``f``.  ``f`` values are floats (one layer) or ``(n_archs, 1)``
  arrays (a LayerStack slot, broadcasting against ``(n_hw,)`` columns to
  ``(n_archs, n_hw)``); the elementwise op sequence is identical either
  way, so the numpy path matches the scalar model bit for bit."""
  pe_rows, pe_cols, n_pe = c["pe_rows"], c["pe_cols"], c["n_pe"]
  E, K, C, F = f["E"], f["K"], f["C"], f["F"]
  k_safe = xp.maximum(K, 1.0)

  # ---- spatial mapping -------------------------------------------------
  col_folds = xp.ceil(E / pe_cols)
  cols_used = xp.minimum(E, pe_cols)
  k_rows = xp.minimum(K, pe_rows)
  row_folds = xp.ceil(K / pe_rows)
  one_fold = row_folds == 1
  sets_per_col = xp.where(one_fold, xp.maximum(pe_rows // k_rows, 1.0), 1.0)
  spatial_util = xp.where(
      one_fold, (k_rows * sets_per_col * cols_used) / n_pe,
      (pe_rows * cols_used) / n_pe)

  # ---- scratchpad-bounded tiling ----------------------------------------
  f_tile = xp.maximum(1.0, xp.minimum(F, c["sp_ps"]))
  c_tile = xp.maximum(1.0, xp.minimum(
      C, c["sp_fw"] // xp.maximum(K * f_tile, 1.0)))
  c_tile = xp.maximum(1.0, xp.minimum(
      c_tile, xp.maximum(c["sp_if"] // k_safe, 1.0) * sets_per_col))
  n_c_passes = xp.ceil(C / c_tile)
  n_f_passes = xp.ceil(F / f_tile)
  n_c_passes_eff = xp.ceil(n_c_passes / sets_per_col)
  passes = n_c_passes_eff * n_f_passes * col_folds * row_folds

  # ---- compute cycles ----------------------------------------------------
  per_pass = E * K * c_tile * f_tile + (K + cols_used)
  compute_cycles = passes * per_pass
  ideal_cycles = f["macs"] / n_pe
  compute_cycles = xp.maximum(compute_cycles, ideal_cycles)
  utilization = xp.minimum(1.0, ideal_cycles / xp.maximum(compute_cycles, 1.0)
                           ) * xp.minimum(1.0, spatial_util + 1e-9)

  # ---- access counts -----------------------------------------------------
  macs = f["macs"]
  spad_reads = (2.0 + 1.0 / k_safe) * macs + xp.zeros_like(n_pe)
  spad_writes = macs / k_safe + xp.zeros_like(n_pe)
  ifmap_words = f["ifmap_words"]
  gbuf_bits = c["gbuf_kb"] * 1024 * 8
  ifmap_fits = ifmap_words * c["act_bits"] <= 0.5 * gbuf_bits
  dram_if = ifmap_words * xp.where(ifmap_fits, 1.0, n_f_passes)
  gbuf_if_reads = ifmap_words * n_f_passes * row_folds
  weight_words = f["weight_words"]
  weights_fit = weight_words * c["weight_bits"] <= 0.25 * gbuf_bits
  dram_w = weight_words * xp.where(weights_fit, 1.0, col_folds)
  gbuf_w_reads = weight_words * col_folds
  of_words = f["of_words"]
  psum_spills = xp.maximum(n_c_passes_eff - 1.0, 0.0)
  dram_of = of_words
  gbuf_reads = gbuf_if_reads + gbuf_w_reads + of_words * psum_spills
  gbuf_writes = of_words * (psum_spills + 1.0)
  dram_reads = dram_if + dram_w
  dram_writes = dram_of + xp.zeros_like(n_pe)

  # ---- bandwidth bound ---------------------------------------------------
  cycle_s = 1e-6 / clock_mhz
  dram_bits = (dram_if * c["act_bits"] + dram_w * c["weight_bits"]
               + dram_of * c["psum_bits"])
  dram_time_s = dram_bits / 8.0 / (c["bandwidth_gbps"] * 1e9)
  dram_cycles = dram_time_s / cycle_s
  dram_stall = xp.maximum(0.0, dram_cycles - 0.85 * compute_cycles)
  cycles = compute_cycles + dram_stall

  return LayerStatsBatch(
      cycles=cycles, compute_cycles=compute_cycles,
      dram_stall_cycles=dram_stall, utilization=utilization, macs=macs,
      spad_reads=spad_reads, spad_writes=spad_writes,
      gbuf_reads=gbuf_reads, gbuf_writes=gbuf_writes,
      dram_reads=dram_reads, dram_writes=dram_writes)


def simulate_layer_batch(table, layer: ConvLayer, clock_mhz, xp=np
                         ) -> LayerStatsBatch:
  """Vectorized :func:`simulate_layer`: all table rows against one layer.

  ``clock_mhz`` is a per-row array (or scalar, broadcast).  Every branch of
  the scalar model becomes a masked select; integer tiling uses the same
  float ceil/floor expressions the scalar path evaluates, so results agree
  exactly on the numpy path.
  """
  st = _simulate_layer_feats(_cols_of(table), _layer_feats(layer),
                             clock_mhz, xp)
  st.macs = layer.macs  # exact int for LayerStatsBatch.row()
  return st


def _layer_energy_feats(c, f, stats: LayerStatsBatch, clock_mhz,
                        leakage_mw, xp):
  """Hierarchical energy formulas over HW columns x layer features (pJ),
  broadcasting like :func:`_simulate_layer_feats`."""
  e = pe_lib.ENERGY_PJ
  mac_e = stats.macs * c["mac_energy_pj"]
  k = xp.maximum(f["K"], 1.0)
  spad_read_bits = stats.macs * (c["act_bits"] + c["weight_bits"]
                                 + c["psum_bits"] / k)
  spad_write_bits = stats.spad_writes * c["psum_bits"]
  spad_e = (spad_read_bits + spad_write_bits) * e["spad_access_per_bit"]
  gbuf_bits = (stats.gbuf_reads + stats.gbuf_writes) * (
      (c["act_bits"] + c["weight_bits"] + c["psum_bits"]) / 3.0)
  gbuf_e = gbuf_bits * e["gbuf_access_per_bit"]
  dram_bits = (stats.dram_reads * (c["act_bits"] + c["weight_bits"]) / 2.0
               + stats.dram_writes * c["psum_bits"])
  dram_e = dram_bits * e["dram_access_per_bit"]
  time_s = stats.cycles / (clock_mhz * 1e6)
  leak_e = leakage_mw * 1e-3 * time_s * 1e12  # mW * s -> pJ
  return mac_e + spad_e + gbuf_e + dram_e + leak_e


def layer_energy_pj_batch(table, layer: ConvLayer, stats: LayerStatsBatch,
                          clock_mhz, leakage_mw, xp=np):
  """Vectorized :func:`layer_energy_pj` (pJ per design point)."""
  return _layer_energy_feats(_cols_of(table), _layer_feats(layer), stats,
                             clock_mhz, leakage_mw, xp)


def simulate_network_batch(table, layers: Sequence[ConvLayer],
                           clock_mhz, leakage_mw, xp=np):
  """Vectorized :func:`simulate_network` over a ConfigTable.

  Returns ``(latency_s, energy_mj, utilization)`` arrays, where
  utilization is the cycle-weighted mean the scalar
  :func:`repro.core.oracle.characterize` computes from per-layer stats.
  """
  c = _cols_of(table)
  total_cycles = 0.0
  total_energy_pj = 0.0
  util_weighted = 0.0
  for layer in layers:
    st = simulate_layer_batch(c, layer, clock_mhz, xp=xp)
    total_cycles = total_cycles + st.cycles
    total_energy_pj = total_energy_pj + layer_energy_pj_batch(
        c, layer, st, clock_mhz, leakage_mw, xp=xp)
    util_weighted = util_weighted + st.utilization * st.cycles
  latency_s = total_cycles / (clock_mhz * 1e6)
  utilization = util_weighted / xp.maximum(total_cycles, 1e-12)
  return latency_s, total_energy_pj * 1e-9, utilization  # pJ -> mJ


# ---------------------------------------------------------------------------
# joint HW x NN batching: all architectures x all design points at once
# ---------------------------------------------------------------------------

# Padded layer slots use a benign 1x1x1 layer so every formula stays
# finite; the validity mask zeroes their contribution before accumulation
# (x + 0.0 == x exactly, so padding never perturbs the numpy-path bits).
_PAD_LAYER = ConvLayer("pad", A=1, C=1, F=1, K=1, S=1, P=0)

# ConvLayer int fields packed into the stack, in feature order
_STACK_FIELDS = ("A", "C", "F", "K", "S", "P", "rs", "ds")


@dataclasses.dataclass(eq=False)
class LayerStack:
  """Padded per-architecture layer features: ``(n_archs, max_layers)``
  int64 tensors per ConvLayer field plus a validity mask.

  Built once per co-exploration sweep (``from_layer_lists``); the derived
  quantities every dataflow formula needs (out_dim, MAC count, tensor
  word counts) are precomputed as float64 tensors so the per-layer-slot
  inner loop is pure array arithmetic.
  """
  A: np.ndarray
  C: np.ndarray
  F: np.ndarray
  K: np.ndarray
  S: np.ndarray
  P: np.ndarray
  rs: np.ndarray
  ds: np.ndarray
  valid: np.ndarray

  def __post_init__(self):
    for name in _STACK_FIELDS:
      setattr(self, name, np.asarray(getattr(self, name), np.int64))
    self.valid = np.asarray(self.valid, np.bool_)
    shape = self.A.shape
    if len(shape) != 2:
      raise ValueError(f"LayerStack fields must be 2-D, got shape {shape}")
    for name in _STACK_FIELDS + ("valid",):
      if getattr(self, name).shape != shape:
        raise ValueError(f"field {name!r} has shape "
                         f"{getattr(self, name).shape}, expected {shape}")
    # derived float64 tensors (all integer-valued, exact in float64)
    a, c, f, k = (x.astype(np.float64) for x in (self.A, self.C, self.F,
                                                 self.K))
    s, p = self.S.astype(np.float64), self.P.astype(np.float64)
    out = np.floor((a + 2.0 * p - k) / np.maximum(s, 1.0)) + 1.0
    self._E = np.maximum(out, 1.0)
    self._macs = out * out * k * k * c * f
    self._ifmap_words = a * a * c
    self._weight_words = k * k * c * f
    self._of_words = out * out * f

  @property
  def n_archs(self) -> int:
    return int(self.A.shape[0])

  @property
  def max_layers(self) -> int:
    return int(self.A.shape[1])

  def n_layers(self) -> np.ndarray:
    """Per-architecture true layer count."""
    return self.valid.sum(axis=1)

  @classmethod
  def from_layer_lists(cls, layer_lists: Sequence[Sequence[ConvLayer]]
                       ) -> "LayerStack":
    """Pack one ConvLayer list per architecture, right-padded to the
    longest network."""
    lists = [list(ls) for ls in layer_lists]
    n_max = max((len(ls) for ls in lists), default=0) or 1
    padded = [ls + [_PAD_LAYER] * (n_max - len(ls)) for ls in lists]
    cols = {name: np.asarray([[getattr(l, name) for l in ls]
                              for ls in padded], np.int64)
            for name in _STACK_FIELDS}
    valid = np.asarray([[True] * len(ls) + [False] * (n_max - len(ls))
                        for ls in lists], np.bool_)
    return cls(valid=valid, **cols)

  def slice_archs(self, lo: int, hi: int) -> "LayerStack":
    """Arch-range sub-stack (the streaming engine's unit of work).

    Row ``a`` of the slice is bit-identical to row ``lo + a`` of the full
    stack — padding columns are preserved, so per-slot accumulation order
    (and therefore every latency/energy sum) is unchanged.
    """
    sl = slice(lo, hi)
    return LayerStack(valid=self.valid[sl],
                      **{name: getattr(self, name)[sl]
                         for name in _STACK_FIELDS})

  def layers_of(self, arch_id: int) -> List[ConvLayer]:
    """Materialize one architecture's ConvLayer list (scalar escape)."""
    out = []
    for li in range(self.max_layers):
      if not self.valid[arch_id, li]:
        break
      out.append(ConvLayer(
          f"a{arch_id}l{li}",
          **{name: int(getattr(self, name)[arch_id, li])
             for name in _STACK_FIELDS}))
    return out

  def features(self) -> np.ndarray:
    """(n_archs, max_layers, 8) float64 layer-feature tensor in the
    paper's latency-model order (== ConvLayer.features())."""
    return np.stack([getattr(self, name).astype(np.float64)
                     for name in _STACK_FIELDS], axis=2)

  def feats_at(self, li: int) -> Dict[str, np.ndarray]:
    """Layer slot ``li`` as ``(n_archs, 1)`` broadcastable feature
    columns (the array twin of :func:`_layer_feats`)."""
    sl = slice(li, li + 1)
    return {
        "E": self._E[:, sl], "K": self.K[:, sl].astype(np.float64),
        "C": self.C[:, sl].astype(np.float64),
        "F": self.F[:, sl].astype(np.float64),
        "macs": self._macs[:, sl],
        "ifmap_words": self._ifmap_words[:, sl],
        "weight_words": self._weight_words[:, sl],
        "of_words": self._of_words[:, sl],
    }

  def fingerprint(self) -> str:
    """Content hash (jit-cache key for the device path)."""
    import hashlib
    h = hashlib.sha256()
    for name in _STACK_FIELDS + ("valid",):
      h.update(np.ascontiguousarray(getattr(self, name)).tobytes())
    return h.hexdigest()[:16]

  def dedup_slots(self) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """Distinct-layer factorization: ``(unique_cols, slot_ids)``.

    Architectures drawn from one search space share most of their layers,
    so the ``n_archs x max_layers`` slot grid typically references only a
    few dozen *distinct* layer shapes.  ``unique_cols`` holds one
    ``(n_distinct, 1)`` float64 column per ConvLayer field (broadcastable
    against ``(n_hw,)`` HW columns exactly like :meth:`feats_at` rows);
    ``slot_ids[a, li]`` maps each slot to its distinct row.  The device
    path simulates each distinct layer once per HW chunk and *gathers*
    per slot — per-slot accumulation order is unchanged, so results stay
    bit-identical to the slot-by-slot evaluation (see
    :func:`simulate_network_stack_dedup`).
    """
    feats = np.stack([getattr(self, n).reshape(-1) for n in _STACK_FIELDS],
                     axis=1)
    uniq, inv = np.unique(feats, axis=0, return_inverse=True)
    slot_ids = inv.reshape(self.A.shape).astype(np.int32)
    cols = {n: uniq[:, i:i + 1].astype(np.float64)
            for i, n in enumerate(_STACK_FIELDS)}
    return cols, slot_ids

  def __repr__(self) -> str:
    return (f"LayerStack({self.n_archs} archs x <= {self.max_layers} "
            f"layers)")


def unique_layer_feats(cols: Dict[str, "np.ndarray"], xp=np
                       ) -> Dict[str, "np.ndarray"]:
  """Derived feature columns for :meth:`LayerStack.dedup_slots` rows —
  the same expressions LayerStack precomputes in ``__post_init__`` (and
  therefore bit-identical to :meth:`LayerStack.feats_at` values), written
  against ``xp`` so the device path can trace through them."""
  a, c, f, k = cols["A"], cols["C"], cols["F"], cols["K"]
  s, p = cols["S"], cols["P"]
  out = xp.floor((a + 2.0 * p - k) / xp.maximum(s, 1.0)) + 1.0
  return {"E": xp.maximum(out, 1.0), "K": k, "C": c, "F": f,
          "macs": out * out * k * k * c * f,
          "ifmap_words": a * a * c,
          "weight_words": k * k * c * f,
          "of_words": out * out * f}


def simulate_network_stack_dedup(table, unique_cols, slot_ids, valid,
                                 clock_mhz, leakage_mw, xp=np):
  """Distinct-layer twin of :func:`simulate_network_stack`.

  Evaluates the dataflow/energy formulas once per *distinct* layer
  (``(n_distinct, n_hw)`` grids) and accumulates per ``(arch, slot)`` by
  gathering the distinct rows — the hot restructure behind the exact
  device path: formula work drops from ``n_archs * max_layers`` slot
  evaluations to ``n_distinct`` (often 10-50x fewer), while the per-slot
  accumulation order (and thus every latency/energy/utilization bit on
  the numpy path) is exactly that of :func:`simulate_network_stack`'s
  masked branch — gathering reorders no additions.

  ``unique_cols``/``slot_ids`` come from :meth:`LayerStack.dedup_slots`;
  ``valid`` is the stack's validity mask.  Returns
  ``(latency_s, energy_mj, utilization)`` shaped ``(n_archs, n_hw)``.
  """
  c = _cols_of(table)
  f = unique_layer_feats(unique_cols, xp)
  st = _simulate_layer_feats(c, f, clock_mhz, xp)
  e_pj = _layer_energy_feats(c, f, st, clock_mhz, leakage_mw, xp)
  cyc = st.cycles
  util_cyc = st.utilization * cyc
  take = (lambda arr, ids: arr[ids]) if xp is np \
      else (lambda arr, ids: xp.take(arr, ids, axis=0))
  total_cycles = 0.0
  total_energy_pj = 0.0
  util_weighted = 0.0
  for li in range(slot_ids.shape[1]):
    ids = slot_ids[:, li]
    v = valid[:, li:li + 1]
    total_cycles = total_cycles + xp.where(v, take(cyc, ids), 0.0)
    total_energy_pj = total_energy_pj + xp.where(v, take(e_pj, ids), 0.0)
    util_weighted = util_weighted + xp.where(v, take(util_cyc, ids), 0.0)
  latency_s = total_cycles / (clock_mhz * 1e6)
  utilization = util_weighted / xp.maximum(total_cycles, 1e-12)
  return latency_s, total_energy_pj * 1e-9, utilization  # pJ -> mJ


def simulate_network_stack(table, stack: LayerStack, clock_mhz, leakage_mw,
                           xp=np):
  """Joint :func:`simulate_network_batch`: every architecture in ``stack``
  x every design point in ``table`` in one batched pass per layer slot.

  Returns ``(latency_s, energy_mj, utilization)`` shaped
  ``(n_archs, n_hw)``.  Row ``a`` is bit-identical (numpy path) to
  ``simulate_network_batch(table, stack.layers_of(a), ...)``: padded
  slots contribute exactly 0.0 and the per-slot accumulation order
  matches the scalar per-layer loop.
  """
  c = _cols_of(table)
  total_cycles = 0.0
  total_energy_pj = 0.0
  util_weighted = 0.0
  for li in range(stack.max_layers):
    f = stack.feats_at(li)
    st = _simulate_layer_feats(c, f, clock_mhz, xp)
    e_pj = _layer_energy_feats(c, f, st, clock_mhz, leakage_mw, xp)
    v = stack.valid[:, li:li + 1]
    if bool(np.all(v)):  # common fast path: no masking needed
      total_cycles = total_cycles + st.cycles
      total_energy_pj = total_energy_pj + e_pj
      util_weighted = util_weighted + st.utilization * st.cycles
    else:
      total_cycles = total_cycles + xp.where(v, st.cycles, 0.0)
      total_energy_pj = total_energy_pj + xp.where(v, e_pj, 0.0)
      util_weighted = util_weighted + xp.where(
          v, st.utilization * st.cycles, 0.0)
  latency_s = total_cycles / (clock_mhz * 1e6)
  utilization = util_weighted / xp.maximum(total_cycles, 1e-12)
  return latency_s, total_energy_pj * 1e-9, utilization  # pJ -> mJ
