"""Row-stationary (RS) dataflow model for the QUIDAM accelerator template.

This is the cycle-approximate analytical model of the Eyeriss-style spatial
array the paper synthesizes (Sec. 3.1): a ``rows x cols`` PE grid running
row-stationary dataflow, per-PE scratchpads (ifmap/filter/psum), a global
buffer, and DRAM behind a finite-bandwidth link.

It provides the *ground-truth* latency / utilization / memory-access counts
that the paper obtains from Synopsys VCS testbenches; the polynomial PPA
models of :mod:`repro.core.ppa` are trained against it (together with the
area/power numbers from :mod:`repro.core.oracle`).

Mapping summary (Chen et al., ISCA'16):
  * a logical PE set of ``K`` rows x ``E`` cols computes one 2-D conv plane;
    PE(i, j) convolves filter row ``i`` against ifmap row ``i + j`` and
    produces psums of output row ``j``.
  * the logical set is folded onto the physical array: ``E`` folds over the
    columns, ``K`` folds over the rows; leftover rows replicate additional
    channel/filter tiles.
  * scratchpads bound the per-pass tile sizes:
      - psum spad       -> F_tile accumulators held per PE
      - filter spad     -> K * C_tile * F_tile weights held per PE
      - ifmap spad      -> sliding window of C_tile * K activations
  * passes iterate over ceil(C / C_tile) * ceil(F / F_tile) tiles; psums
    spill to the global buffer between channel tiles.

Each simulation entry point has a vectorized ``*_batch`` sibling
(:func:`simulate_layer_batch`, :func:`simulate_network_batch`) that
evaluates a whole :class:`repro.core.table.ConfigTable` column-at-a-time,
bit-identically to the scalar model on the numpy path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import pe as pe_lib


@dataclasses.dataclass(frozen=True)
class ConvLayer:
  """One conv (or 1x1-conv-as-matmul) workload layer.

  A: input feature-map spatial dim (assumed square A x A)
  C: input channels;  F: output channels (filter count)
  K: kernel size;     S: stride;     P: padding
  rs/ds: ResNet regular / dotted (projection) skip-connection indicators,
  the two binary extra features of the paper's latency model.
  """
  name: str
  A: int
  C: int
  F: int
  K: int = 1
  S: int = 1
  P: int = 0
  rs: int = 0
  ds: int = 0

  @property
  def out_dim(self) -> int:
    return (self.A + 2 * self.P - self.K) // self.S + 1

  @property
  def macs(self) -> int:
    e = self.out_dim
    return e * e * self.K * self.K * self.C * self.F

  @property
  def weight_count(self) -> int:
    return self.K * self.K * self.C * self.F

  @property
  def ifmap_count(self) -> int:
    return self.A * self.A * self.C

  @property
  def ofmap_count(self) -> int:
    e = self.out_dim
    return e * e * self.F

  def features(self) -> Tuple[float, ...]:
    """The layer-side features of the paper's 12-dim latency vector."""
    return (float(self.A), float(self.C), float(self.F), float(self.K),
            float(self.S), float(self.P), float(self.rs), float(self.ds))


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
  """The hardware half of QUIDAM's input space (Fig. 2)."""
  pe_type: str = "INT16"
  pe_rows: int = 16
  pe_cols: int = 16
  sp_if: int = 12      # ifmap scratchpad entries (words)
  sp_fw: int = 224     # filter scratchpad entries
  sp_ps: int = 24      # psum scratchpad entries
  gbuf_kb: int = 128   # global buffer (KiB)
  bandwidth_gbps: float = 12.8  # DRAM link bandwidth

  @property
  def n_pe(self) -> int:
    return self.pe_rows * self.pe_cols

  @property
  def pe(self) -> pe_lib.PEType:
    return pe_lib.pe_type(self.pe_type)

  def hw_features(self) -> Tuple[float, ...]:
    return (float(self.sp_if), float(self.sp_ps), float(self.sp_fw),
            float(self.n_pe))

  def latency_hw_features(self) -> Tuple[float, ...]:
    return (float(self.sp_if), float(self.sp_ps), float(self.sp_fw),
            float(self.pe_rows), float(self.pe_cols), float(self.gbuf_kb))


@dataclasses.dataclass
class LayerStats:
  """Per-layer dataflow simulation output."""
  cycles: float
  compute_cycles: float
  dram_stall_cycles: float
  utilization: float
  macs: int
  # access counts (words) per memory level
  spad_reads: float
  spad_writes: float
  gbuf_reads: float
  gbuf_writes: float
  dram_reads: float
  dram_writes: float


def simulate_layer(cfg: AcceleratorConfig, layer: ConvLayer,
                   clock_mhz: float) -> LayerStats:
  """Cycle-approximate RS dataflow simulation of one layer."""
  pe = cfg.pe
  E = max(layer.out_dim, 1)
  K, C, F = layer.K, layer.C, layer.F

  # ---- spatial mapping -------------------------------------------------
  # columns host output rows (E), rows host filter rows (K)
  col_folds = math.ceil(E / cfg.pe_cols)
  cols_used = min(E, cfg.pe_cols)
  k_rows = min(K, cfg.pe_rows)
  row_folds = math.ceil(K / cfg.pe_rows)
  # leftover row capacity replicates additional (channel, filter) tiles
  sets_per_col = max(cfg.pe_rows // k_rows, 1) if row_folds == 1 else 1
  spatial_util = (k_rows * sets_per_col * cols_used) / cfg.n_pe
  if row_folds > 1:
    spatial_util = (cfg.pe_rows * cols_used) / cfg.n_pe

  # ---- scratchpad-bounded tiling ----------------------------------------
  f_tile = max(1, min(F, cfg.sp_ps))
  # filter spad holds K * C_tile * F_tile weights (one filter row per pass)
  c_tile = max(1, min(C, cfg.sp_fw // max(K * f_tile, 1)))
  # ifmap spad needs a K-deep sliding window per channel in flight
  c_tile = max(1, min(c_tile, max(cfg.sp_if // max(K, 1), 1) * sets_per_col))
  n_c_passes = math.ceil(C / c_tile)
  n_f_passes = math.ceil(F / f_tile)
  # replication across spare row capacity processes extra channel tiles in
  # parallel
  n_c_passes_eff = math.ceil(n_c_passes / sets_per_col)
  passes = n_c_passes_eff * n_f_passes * col_folds * row_folds

  # ---- compute cycles ----------------------------------------------------
  # per pass, each active PE performs E (out width) * K (kernel width) *
  # c_tile * f_tile MACs, 1 MAC/cycle; pipeline fill ~ K + cols_used.
  per_pass = E * K * c_tile * f_tile + (K + cols_used)
  compute_cycles = passes * per_pass
  ideal_cycles = layer.macs / cfg.n_pe
  compute_cycles = max(compute_cycles, ideal_cycles)
  utilization = min(1.0, ideal_cycles / max(compute_cycles, 1.0)) \
      * min(1.0, spatial_util + 1e-9)

  # ---- access counts -----------------------------------------------------
  macs = layer.macs
  # every MAC reads act + weight from its spads; the running psum lives in
  # an accumulator register and spills to the psum spad once per K MACs
  spad_reads = (2.0 + 1.0 / max(K, 1)) * macs
  spad_writes = macs / max(K, 1)
  # ifmap: DRAM -> gbuf once if it fits, else per filter-pass; gbuf -> array
  # once per filter pass (row-stationary reuses within a pass)
  ifmap_words = layer.ifmap_count
  gbuf_bits = cfg.gbuf_kb * 1024 * 8
  ifmap_fits = ifmap_words * pe.act_bits <= 0.5 * gbuf_bits
  dram_if = ifmap_words * (1 if ifmap_fits else n_f_passes)
  gbuf_if_reads = ifmap_words * n_f_passes * row_folds
  # weights: streamed from DRAM once per E-fold when they do not fit
  weight_words = layer.weight_count
  weights_fit = weight_words * pe.weight_bits <= 0.25 * gbuf_bits
  dram_w = weight_words * (1 if weights_fit else col_folds)
  gbuf_w_reads = weight_words * col_folds
  # psums: spill/refill between channel tiles
  of_words = layer.ofmap_count
  psum_spills = max(n_c_passes_eff - 1, 0)
  gbuf_ps = of_words * (2.0 * psum_spills + 1.0)
  dram_of = of_words  # final writeback
  gbuf_reads = gbuf_if_reads + gbuf_w_reads + of_words * psum_spills
  gbuf_writes = of_words * (psum_spills + 1.0)
  dram_reads = dram_if + dram_w
  dram_writes = float(dram_of)

  # ---- bandwidth bound ---------------------------------------------------
  cycle_s = 1e-6 / clock_mhz
  dram_bits = (dram_if * pe.act_bits + dram_w * pe.weight_bits
               + dram_of * pe.psum_bits)
  dram_time_s = dram_bits / 8.0 / (cfg.bandwidth_gbps * 1e9)
  dram_cycles = dram_time_s / cycle_s
  # compute/communication overlap: stalls only for the non-overlapped excess
  dram_stall = max(0.0, dram_cycles - 0.85 * compute_cycles)
  cycles = compute_cycles + dram_stall

  return LayerStats(
      cycles=cycles, compute_cycles=compute_cycles,
      dram_stall_cycles=dram_stall, utilization=utilization, macs=macs,
      spad_reads=spad_reads, spad_writes=spad_writes,
      gbuf_reads=gbuf_reads, gbuf_writes=gbuf_writes,
      dram_reads=float(dram_reads), dram_writes=dram_writes)


def layer_energy_pj(cfg: AcceleratorConfig, layer: ConvLayer,
                    stats: LayerStats, clock_mhz: float,
                    leakage_mw: float) -> float:
  """Eyeriss-style hierarchical energy model (pJ) for one layer."""
  pe = cfg.pe
  e = pe_lib.ENERGY_PJ
  mac_e = stats.macs * pe.mac_energy_pj
  # scratchpad word widths differ per operand; use the mean of act/weight/
  # psum widths for reads (2 operand reads + 1 psum read) and psum for writes
  k = max(layer.K, 1)
  spad_read_bits = stats.macs * (pe.act_bits + pe.weight_bits
                                 + pe.psum_bits / k)
  spad_write_bits = stats.spad_writes * pe.psum_bits
  spad_e = (spad_read_bits + spad_write_bits) * e["spad_access_per_bit"]
  gbuf_bits = (stats.gbuf_reads + stats.gbuf_writes) * (
      (pe.act_bits + pe.weight_bits + pe.psum_bits) / 3.0)
  gbuf_e = gbuf_bits * e["gbuf_access_per_bit"]
  dram_bits = (stats.dram_reads * (pe.act_bits + pe.weight_bits) / 2.0
               + stats.dram_writes * pe.psum_bits)
  dram_e = dram_bits * e["dram_access_per_bit"]
  time_s = stats.cycles / (clock_mhz * 1e6)
  leak_e = leakage_mw * 1e-3 * time_s * 1e12  # mW * s -> pJ
  return mac_e + spad_e + gbuf_e + dram_e + leak_e


def simulate_network(cfg: AcceleratorConfig, layers: Sequence[ConvLayer],
                     clock_mhz: float, leakage_mw: float
                     ) -> Tuple[float, float, List[LayerStats]]:
  """Returns (total_latency_s, total_energy_mj, per-layer stats)."""
  total_cycles = 0.0
  total_energy_pj = 0.0
  all_stats: List[LayerStats] = []
  for layer in layers:
    st = simulate_layer(cfg, layer, clock_mhz)
    total_cycles += st.cycles
    total_energy_pj += layer_energy_pj(cfg, layer, st, clock_mhz, leakage_mw)
    all_stats.append(st)
  latency_s = total_cycles / (clock_mhz * 1e6)
  return latency_s, total_energy_pj * 1e-9, all_stats  # pJ -> mJ


# ---------------------------------------------------------------------------
# vectorized siblings: N design points x one layer at a time
# ---------------------------------------------------------------------------
# The batch functions evaluate a whole ConfigTable (or its
# ``numeric_columns()`` dict) against one layer per call, mirroring the
# scalar control flow with xp.where / xp.minimum so the numpy path matches
# :func:`simulate_layer` bit for bit.  ``xp`` may be jax.numpy for the
# optional device path (approximate there: jax defaults to float32).


def _cols_of(table_or_cols) -> Dict[str, "np.ndarray"]:
  if hasattr(table_or_cols, "numeric_columns"):
    return table_or_cols.numeric_columns()
  return table_or_cols


@dataclasses.dataclass
class LayerStatsBatch:
  """Column form of :class:`LayerStats` for N design points."""
  cycles: "np.ndarray"
  compute_cycles: "np.ndarray"
  dram_stall_cycles: "np.ndarray"
  utilization: "np.ndarray"
  macs: int
  spad_reads: "np.ndarray"
  spad_writes: "np.ndarray"
  gbuf_reads: "np.ndarray"
  gbuf_writes: "np.ndarray"
  dram_reads: "np.ndarray"
  dram_writes: "np.ndarray"

  def row(self, i: int) -> LayerStats:
    """One design point's stats as the scalar dataclass."""
    return LayerStats(
        cycles=float(self.cycles[i]),
        compute_cycles=float(self.compute_cycles[i]),
        dram_stall_cycles=float(self.dram_stall_cycles[i]),
        utilization=float(self.utilization[i]), macs=self.macs,
        spad_reads=float(self.spad_reads[i]),
        spad_writes=float(self.spad_writes[i]),
        gbuf_reads=float(self.gbuf_reads[i]),
        gbuf_writes=float(self.gbuf_writes[i]),
        dram_reads=float(self.dram_reads[i]),
        dram_writes=float(self.dram_writes[i]))


def simulate_layer_batch(table, layer: ConvLayer, clock_mhz, xp=np
                         ) -> LayerStatsBatch:
  """Vectorized :func:`simulate_layer`: all table rows against one layer.

  ``clock_mhz`` is a per-row array (or scalar, broadcast).  Every branch of
  the scalar model becomes a masked select; integer tiling uses the same
  float ceil/floor expressions the scalar path evaluates, so results agree
  exactly on the numpy path.
  """
  c = _cols_of(table)
  pe_rows, pe_cols, n_pe = c["pe_rows"], c["pe_cols"], c["n_pe"]
  E = float(max(layer.out_dim, 1))
  K, C, F = float(layer.K), float(layer.C), float(layer.F)

  # ---- spatial mapping -------------------------------------------------
  col_folds = xp.ceil(E / pe_cols)
  cols_used = xp.minimum(E, pe_cols)
  k_rows = xp.minimum(K, pe_rows)
  row_folds = xp.ceil(K / pe_rows)
  one_fold = row_folds == 1
  sets_per_col = xp.where(one_fold, xp.maximum(pe_rows // k_rows, 1.0), 1.0)
  spatial_util = xp.where(
      one_fold, (k_rows * sets_per_col * cols_used) / n_pe,
      (pe_rows * cols_used) / n_pe)

  # ---- scratchpad-bounded tiling ----------------------------------------
  f_tile = xp.maximum(1.0, xp.minimum(F, c["sp_ps"]))
  c_tile = xp.maximum(1.0, xp.minimum(
      C, c["sp_fw"] // xp.maximum(K * f_tile, 1.0)))
  c_tile = xp.maximum(1.0, xp.minimum(
      c_tile, xp.maximum(c["sp_if"] // max(K, 1.0), 1.0) * sets_per_col))
  n_c_passes = xp.ceil(C / c_tile)
  n_f_passes = xp.ceil(F / f_tile)
  n_c_passes_eff = xp.ceil(n_c_passes / sets_per_col)
  passes = n_c_passes_eff * n_f_passes * col_folds * row_folds

  # ---- compute cycles ----------------------------------------------------
  per_pass = E * K * c_tile * f_tile + (K + cols_used)
  compute_cycles = passes * per_pass
  ideal_cycles = layer.macs / n_pe
  compute_cycles = xp.maximum(compute_cycles, ideal_cycles)
  utilization = xp.minimum(1.0, ideal_cycles / xp.maximum(compute_cycles, 1.0)
                           ) * xp.minimum(1.0, spatial_util + 1e-9)

  # ---- access counts -----------------------------------------------------
  macs = layer.macs
  spad_reads = (2.0 + 1.0 / max(K, 1.0)) * macs + xp.zeros_like(n_pe)
  spad_writes = macs / max(K, 1.0) + xp.zeros_like(n_pe)
  ifmap_words = float(layer.ifmap_count)
  gbuf_bits = c["gbuf_kb"] * 1024 * 8
  ifmap_fits = ifmap_words * c["act_bits"] <= 0.5 * gbuf_bits
  dram_if = ifmap_words * xp.where(ifmap_fits, 1.0, n_f_passes)
  gbuf_if_reads = ifmap_words * n_f_passes * row_folds
  weight_words = float(layer.weight_count)
  weights_fit = weight_words * c["weight_bits"] <= 0.25 * gbuf_bits
  dram_w = weight_words * xp.where(weights_fit, 1.0, col_folds)
  gbuf_w_reads = weight_words * col_folds
  of_words = float(layer.ofmap_count)
  psum_spills = xp.maximum(n_c_passes_eff - 1.0, 0.0)
  dram_of = of_words
  gbuf_reads = gbuf_if_reads + gbuf_w_reads + of_words * psum_spills
  gbuf_writes = of_words * (psum_spills + 1.0)
  dram_reads = dram_if + dram_w
  dram_writes = dram_of + xp.zeros_like(n_pe)

  # ---- bandwidth bound ---------------------------------------------------
  cycle_s = 1e-6 / clock_mhz
  dram_bits = (dram_if * c["act_bits"] + dram_w * c["weight_bits"]
               + dram_of * c["psum_bits"])
  dram_time_s = dram_bits / 8.0 / (c["bandwidth_gbps"] * 1e9)
  dram_cycles = dram_time_s / cycle_s
  dram_stall = xp.maximum(0.0, dram_cycles - 0.85 * compute_cycles)
  cycles = compute_cycles + dram_stall

  return LayerStatsBatch(
      cycles=cycles, compute_cycles=compute_cycles,
      dram_stall_cycles=dram_stall, utilization=utilization, macs=macs,
      spad_reads=spad_reads, spad_writes=spad_writes,
      gbuf_reads=gbuf_reads, gbuf_writes=gbuf_writes,
      dram_reads=dram_reads, dram_writes=dram_writes)


def layer_energy_pj_batch(table, layer: ConvLayer, stats: LayerStatsBatch,
                          clock_mhz, leakage_mw, xp=np):
  """Vectorized :func:`layer_energy_pj` (pJ per design point)."""
  c = _cols_of(table)
  e = pe_lib.ENERGY_PJ
  mac_e = stats.macs * c["mac_energy_pj"]
  k = max(layer.K, 1)
  spad_read_bits = stats.macs * (c["act_bits"] + c["weight_bits"]
                                 + c["psum_bits"] / k)
  spad_write_bits = stats.spad_writes * c["psum_bits"]
  spad_e = (spad_read_bits + spad_write_bits) * e["spad_access_per_bit"]
  gbuf_bits = (stats.gbuf_reads + stats.gbuf_writes) * (
      (c["act_bits"] + c["weight_bits"] + c["psum_bits"]) / 3.0)
  gbuf_e = gbuf_bits * e["gbuf_access_per_bit"]
  dram_bits = (stats.dram_reads * (c["act_bits"] + c["weight_bits"]) / 2.0
               + stats.dram_writes * c["psum_bits"])
  dram_e = dram_bits * e["dram_access_per_bit"]
  time_s = stats.cycles / (clock_mhz * 1e6)
  leak_e = leakage_mw * 1e-3 * time_s * 1e12  # mW * s -> pJ
  return mac_e + spad_e + gbuf_e + dram_e + leak_e


def simulate_network_batch(table, layers: Sequence[ConvLayer],
                           clock_mhz, leakage_mw, xp=np):
  """Vectorized :func:`simulate_network` over a ConfigTable.

  Returns ``(latency_s, energy_mj, utilization)`` arrays, where
  utilization is the cycle-weighted mean the scalar
  :func:`repro.core.oracle.characterize` computes from per-layer stats.
  """
  c = _cols_of(table)
  total_cycles = 0.0
  total_energy_pj = 0.0
  util_weighted = 0.0
  for layer in layers:
    st = simulate_layer_batch(c, layer, clock_mhz, xp=xp)
    total_cycles = total_cycles + st.cycles
    total_energy_pj = total_energy_pj + layer_energy_pj_batch(
        c, layer, st, clock_mhz, leakage_mw, xp=xp)
    util_weighted = util_weighted + st.utilization * st.cycles
  latency_s = total_cycles / (clock_mhz * 1e6)
  utilization = util_weighted / xp.maximum(total_cycles, 1e-12)
  return latency_s, total_energy_pj * 1e-9, utilization  # pJ -> mJ
