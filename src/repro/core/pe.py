"""QUIDAM processing-element (PE) types and hardware unit inventory.

Mirrors Fig. 3 of the paper: each PE has four FIFOs (ifmap, filter, input
psum, output psum), three scratchpads (ifmap / filter / psum), and an
arithmetic unit that differs per PE type:

  FP32       32b float multiplier + 32b float adder
  INT16      16b integer multiplier + 32b integer adder
  LightPE-1  8b activations x 4b pow2 weights: one shifter  + 24b adder
  LightPE-2  8b activations x 8b (7 used) codes: two shifters + 2 adders

The numbers here parameterize :mod:`repro.core.oracle` (the stand-in for
Synopsys DC + VCS @ FreePDK45).  Gate counts follow standard textbook
estimates (array multiplier ~ n^2 full adders; FP32 mult ~ 24x24 mantissa
array + normalization; barrel shifter ~ n log n muxes); per-op energies are
anchored to Horowitz, "Computing's energy problem" (ISSCC 2014), scaled to
45 nm.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

# NAND2-equivalent gate-area at 45nm (FreePDK45 NAND2X1 ~ 0.798 um^2).
GATE_AREA_UM2 = 0.798
# 6T SRAM bit cell at 45nm, with periphery overhead folded into the
# sqrt-term of the CACTI-like model below.
SRAM_BIT_UM2 = 0.57
# Leakage per NAND2-equivalent gate (uW) at 45nm, 25C.
GATE_LEAKAGE_UW = 0.0025
# Dynamic switching energy per gate-toggle (pJ) at 1.0V 45nm, activity ~0.15.
GATE_DYN_PJ = 0.0009

def decoder_levels(words: float) -> int:
  """Address-decoder depth = ceil(log2(words)) — a *step* function of the
  scratchpad size; synthesis area/power/latency jump at power-of-two
  boundaries, which is what makes real PPA surfaces polynomial-hostile."""
  import math
  return max(int(math.ceil(math.log2(max(words, 2.0)))), 1)


def sram_access_scale(words: float) -> float:
  """Per-bit access-energy scale factor vs array depth.

  Bitline/wordline capacitance grows with the array edge (~sqrt of the cell
  count) and each decoder level adds a step; normalized to ~1.0 at 64 words.
  """
  import math
  return (0.47 + 0.45 * math.sqrt(max(words, 1.0) / 64.0)
          + 0.022 * decoder_levels(words))


# Horowitz ISSCC'14 per-op energies (pJ), 45nm:
ENERGY_PJ: Dict[str, float] = {
    "add_int8": 0.03,
    "add_int16": 0.05,
    "add_int24": 0.08,
    "add_int32": 0.1,
    "add_fp32": 0.9,
    "mul_int8": 0.2,
    "mul_int16": 0.8,   # ~quadratic in width between int8 (0.2) and int32 (3.1)
    "mul_fp32": 3.7,
    "shift_8": 0.024,   # 8b barrel shifter ~ comparable to int8 add
    # memory, per 16-bit word unless noted:
    "spad_access_per_bit": 0.006,   # register-file-like small spad
    "gbuf_access_per_bit": 0.025,   # 100KB-class SRAM
    "dram_access_per_bit": 1.3,     # LPDDR
    "fifo_access_per_bit": 0.004,
}


@dataclasses.dataclass(frozen=True)
class PEType:
  """Static description of one QUIDAM PE variant."""
  name: str
  act_bits: int
  weight_bits: int          # storage bits per weight (code width)
  psum_bits: int
  # arithmetic unit inventory -> NAND2-equivalent gates
  arith_gates: int
  # energy per MAC-equivalent (pJ): multiply/shift + accumulate add
  mac_energy_pj: float
  # critical path of the arithmetic unit (ns) -> bounds the clock
  critical_path_ns: float
  # number of power-of-two terms when weights are pow2 codes (0 = integer/fp)
  pow2_terms: int = 0

  @property
  def is_light(self) -> bool:
    return self.pow2_terms > 0


def _mult_gates(n: int) -> int:
  """Array multiplier with partial-product reduction: ~10 NAND2-eq gates
  per bit^2 (n^2 AND + ~n^2 FA at 6 gates + reduction tree wiring)."""
  return 10 * n * n


def _adder_gates(n: int) -> int:
  return 7 * n  # ripple-ish CLA mix, ~7 gates/bit


def _shifter_gates(width: int, stages: int) -> int:
  return 3 * width * stages  # barrel shifter: width muxes per log-stage


def _fp32_mult_gates() -> int:
  # 24x24 mantissa array + exponent add + rounding/normalize
  return _mult_gates(24) + _adder_gates(10) + 900


def _fp32_add_gates() -> int:
  # align shifter + 27b add + LZD + normalize shifter
  return _shifter_gates(27, 5) * 2 + _adder_gates(27) + 700


# --- the four paper PE types (plus INT8/INT4 companions used by the wider
# framework; the paper's Table 1 lists INT4/8/16/FP32 support) -------------

FP32 = PEType(
    name="FP32", act_bits=32, weight_bits=32, psum_bits=32,
    arith_gates=_fp32_mult_gates() + _fp32_add_gates(),
    mac_energy_pj=ENERGY_PJ["mul_fp32"] + ENERGY_PJ["add_fp32"],
    critical_path_ns=3.364,  # calibrated: Table 3 -> 275 MHz nominal
)

INT16 = PEType(
    name="INT16", act_bits=16, weight_bits=16, psum_bits=32,
    arith_gates=_mult_gates(16) + _adder_gates(32),
    mac_energy_pj=ENERGY_PJ["mul_int16"] + ENERGY_PJ["add_int32"],
    critical_path_ns=3.237,  # Table 3 -> 285 MHz
)

INT8 = PEType(
    name="INT8", act_bits=8, weight_bits=8, psum_bits=24,
    arith_gates=_mult_gates(8) + _adder_gates(24),
    mac_energy_pj=ENERGY_PJ["mul_int8"] + ENERGY_PJ["add_int24"],
    critical_path_ns=2.60,
)

INT4 = PEType(
    name="INT4", act_bits=8, weight_bits=4, psum_bits=20,
    arith_gates=_mult_gates(4) + _adder_gates(20),
    mac_energy_pj=0.08 + ENERGY_PJ["add_int24"],
    critical_path_ns=2.40,
)

LIGHTPE1 = PEType(
    name="LightPE-1", act_bits=8, weight_bits=4, psum_bits=24,
    arith_gates=_shifter_gates(16, 3) + _adder_gates(24),
    mac_energy_pj=ENERGY_PJ["shift_8"] + ENERGY_PJ["add_int24"],
    critical_path_ns=1.926,  # shift + accumulate; Table 3 -> 455 MHz
    pow2_terms=1,
)

LIGHTPE2 = PEType(
    name="LightPE-2", act_bits=8, weight_bits=8, psum_bits=24,
    arith_gates=2 * _shifter_gates(16, 3) + 2 * _adder_gates(24),
    mac_energy_pj=2 * ENERGY_PJ["shift_8"] + ENERGY_PJ["add_int24"]
                  + ENERGY_PJ["add_int16"],
    critical_path_ns=2.027,  # two shifts + adder tree; Table 3 -> 435 MHz
    pow2_terms=2,
)

PE_TYPES: Dict[str, PEType] = {
    p.name: p for p in (FP32, INT16, INT8, INT4, LIGHTPE1, LIGHTPE2)
}

# The four the paper's figures sweep:
PAPER_PE_TYPES: Tuple[str, ...] = ("FP32", "INT16", "LightPE-1", "LightPE-2")


def pe_type(name: str) -> PEType:
  try:
    return PE_TYPES[name]
  except KeyError as e:
    raise ValueError(
        f"unknown PE type {name!r}; known: {sorted(PE_TYPES)}") from e
