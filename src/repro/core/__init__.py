"""QUIDAM core: the paper's contribution.

Quantization-aware DNN accelerator + model co-exploration:
  quant      power-of-two (LightNN) and integer quantizers, QAT STE
  pe         processing-element types (FP32/INT16/INT8/INT4/LightPE-1/2)
  dataflow   row-stationary spatial-array dataflow model
  oracle     synthesis stand-in (Synopsys DC + VCS @ FreePDK45)
  ppa        polynomial PPA regression models + k-fold CV degree selection
  dse        design-space exploration (compat shim over repro.explore)
  workloads  VGG/ResNet workloads + transformer-as-workload bridge
  supernet   weight-sharing VGG supernet accuracy proxy (Table 4 space)
  coexplore  joint HW x NN co-exploration (compat shim over repro.explore)

Exploration itself lives in :mod:`repro.explore` (DesignSpace,
Oracle/Polynomial backends, columnar ResultFrame, ExplorationSession).
"""
from repro.core.dataflow import AcceleratorConfig, ConvLayer
from repro.core.pe import PAPER_PE_TYPES, PE_TYPES, pe_type

__all__ = [
    "AcceleratorConfig", "ConvLayer", "PAPER_PE_TYPES", "PE_TYPES",
    "pe_type",
]
