"""QUIDAM core: the paper's contribution.

Quantization-aware DNN accelerator + model co-exploration:
  quant      power-of-two (LightNN) and integer quantizers, QAT STE
  pe         processing-element types (FP32/INT16/INT8/INT4/LightPE-1/2)
  dataflow   row-stationary spatial-array dataflow model (scalar + batch)
  oracle     synthesis stand-in (Synopsys DC + VCS @ FreePDK45), with
             vectorized ``*_batch`` siblings over ConfigTables
  table      ConfigTable: struct-of-arrays design points for the
             vectorized million-point evaluation path
  ppa        polynomial PPA regression models + k-fold CV degree selection
  dse        design-space exploration (compat shim over repro.explore)
  workloads  VGG/ResNet workloads + transformer-as-workload bridge
  supernet   weight-sharing VGG supernet accuracy proxy (Table 4 space)
  coexplore  joint HW x NN co-exploration (compat shim over repro.explore)

Exploration itself lives in :mod:`repro.explore` (DesignSpace,
Oracle/Vector/Polynomial backends, columnar ResultFrame,
ExplorationSession).
"""
from repro.core.dataflow import AcceleratorConfig, ConvLayer
from repro.core.pe import PAPER_PE_TYPES, PE_TYPES, pe_type
from repro.core.table import ConfigTable

__all__ = [
    "AcceleratorConfig", "ConfigTable", "ConvLayer", "PAPER_PE_TYPES",
    "PE_TYPES", "pe_type",
]
