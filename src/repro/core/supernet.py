"""Weight-sharing VGG supernet over the Table-4 search space (Sec. 4.5).

Single-path one-shot training [Guo et al. 2020; Li & Talwalkar 2020]: each
batch trains one uniformly-sampled sub-architecture with weights shared
with the largest network; after training, candidate architectures are
evaluated directly on a validation set — the paper's accuracy proxy for
co-exploration (110,592-point space, 1,000 sampled evaluations).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cnn
from repro.core.cnn import (SEARCH_SPACE, SPACE_SIZE, ArchChoice, accuracy,
                            apply_vgg, init_vgg_supernet, max_arch,
                            sample_arch, xent)
from repro.core.dataflow import ConvLayer
from repro.core.seeding import derive_seed
from repro.data.synthetic import CifarLike, CifarLikeConfig
from repro.train import optimizer as opt_lib


@dataclasses.dataclass
class SupernetConfig:
  n_classes: int = 10
  image_size: int = 16      # reduced from 32 for the CPU container; the
  # SEARCH SPACE (repeats/channels, Table 4) is unchanged
  batch: int = 64
  steps: int = 300
  lr: float = 0.015
  seed: int = 0


class Supernet:
  def __init__(self, cfg: SupernetConfig):
    self.cfg = cfg
    self.data = CifarLike(CifarLikeConfig(
        n_classes=cfg.n_classes, image_size=cfg.image_size, seed=cfg.seed))
    key = jax.random.PRNGKey(cfg.seed)
    self.params = init_vgg_supernet(key, cfg.n_classes)
    self.opt_cfg = opt_lib.SGDConfig(lr=cfg.lr, steps_per_epoch=50,
                                     drops=(3, 5), drop_factor=0.2)
    self.opt = opt_lib.sgd_init(self.params)
    self._grad = jax.jit(jax.value_and_grad(self._loss))

  def _loss(self, params, images, labels, r_use, c_use):
    logits = apply_vgg(params, images, r_use=r_use, c_use=c_use)
    return xent(logits, labels)

  def train(self, steps: Optional[int] = None,
            log_every: int = 50) -> List[float]:
    steps = steps or self.cfg.steps
    losses = []
    rng = np.random.RandomState(self.cfg.seed)
    for step in range(steps):
      imgs, labels = self.data.sample(self.cfg.batch, split_seed=step)
      arch = sample_arch(jax.random.PRNGKey(rng.randint(2 ** 31)))
      from repro.core.cnn import arch_masks
      r_use, c_use = arch_masks(arch)
      loss, grads = self._grad(self.params, jnp.asarray(imgs),
                               jnp.asarray(labels), r_use, c_use)
      self.params, self.opt, _ = opt_lib.sgd_update(
          self.opt_cfg, self.params, grads, self.opt)
      losses.append(float(loss))
      if log_every and (step + 1) % log_every == 0:
        print(f"supernet step {step + 1}: loss {np.mean(losses[-50:]):.3f}",
              flush=True)
    return losses

  def evaluate(self, arch: ArchChoice, n_val: int = 512,
               val_seed: int = 10_000_019) -> float:
    """Validation top-1 for one sub-architecture (weight sharing)."""
    imgs, labels = self.data.sample(n_val, split_seed=val_seed)
    from repro.core.cnn import arch_masks
    if not hasattr(self, "_eval_fn"):
      self._eval_fn = jax.jit(
          lambda p, x, r, c: apply_vgg(p, x, r_use=r, c_use=c))
    r_use, c_use = arch_masks(arch)
    logits = self._eval_fn(self.params, jnp.asarray(imgs), r_use, c_use)
    return float(accuracy(logits, jnp.asarray(labels)))

  def sample_and_evaluate(self, n_archs: int = 100, n_val: int = 512,
                          seed: int = 1) -> List[Tuple[ArchChoice, float]]:
    """The paper's predictor: sample architectures, evaluate directly."""
    out = []
    for i in range(n_archs):
      arch = sample_arch(jax.random.PRNGKey(
          derive_seed("supernet-eval", seed, i)))
      out.append((arch, self.evaluate(arch, n_val)))
    return out


# ---------------------------------------------------------------------------
# arch -> accelerator workload bridge (for the co-exploration HW cost)
# ---------------------------------------------------------------------------

def arch_to_layers(arch: ArchChoice, image_size: int = 32,
                   in_ch: int = 3) -> List[ConvLayer]:
  layers: List[ConvLayer] = []
  a, c = image_size, in_ch
  for si, (reps, ch) in enumerate(arch.stages):
    for r in range(reps):
      layers.append(ConvLayer(f"s{si}r{r}", A=a, C=c, F=ch, K=3, S=1, P=1))
      c = ch
    a = max(a // 2, 1)
  return layers


def space_size() -> int:
  return SPACE_SIZE
