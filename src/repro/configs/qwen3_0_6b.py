"""qwen3-0.6b [dense]: 28L d1024 16H (GQA kv=8) ff3072 vocab151936.

QK-RMSNorm inside attention, SwiGLU, RoPE (theta 1e6), tied embeddings,
head_dim 128 decoupled from d_model.  [hf:Qwen/Qwen3-0.6B (family per
hf:Qwen/Qwen3-8B card)]
"""
from repro.configs.base import ModelConfig, register


@register("qwen3-0.6b")
def qwen3_0_6b() -> ModelConfig:
  return ModelConfig(
      name="qwen3-0.6b", family="dense",
      n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
      d_ff=3072, vocab_size=151936,
      mlp_variant="swiglu", norm="rmsnorm", qk_norm=True,
      pos_embed="rope", rope_theta=1e6, tie_embeddings=True,
      source="hf:Qwen/Qwen3-8B",
  )
