"""Assigned input shapes x dry-run input specs.

Four shapes per architecture (LM-family grid):
  train_4k     seq 4,096  x global batch 256   (training step)
  prefill_32k  seq 32,768 x global batch 32    (inference prefill)
  decode_32k   seq 32,768 x global batch 128   (one token, 32k KV cache)
  long_500k    seq 524,288 x global batch 1    (one token, 500k context)

``decode_*`` / ``long_*`` lower ``serve_step`` (single new token against a
KV/state cache of the given length), NOT ``train_step``.  ``long_500k``
requires sub-quadratic attention: it runs for mixtral (SWA), jamba
(hybrid) and rwkv6 (attention-free) and is a documented skip for the pure
full-attention architectures (see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
  name: str
  seq_len: int
  global_batch: int
  mode: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_supported(cfg: ModelConfig, shape: str) -> Optional[str]:
  """None if the (arch, shape) cell runs; else the documented skip reason."""
  spec = SHAPES[shape]
  if spec.name == "long_500k" and not cfg.supports_long_context:
    return ("full quadratic attention with unbounded KV: long_500k requires "
            "sub-quadratic attention (SWA / SSM / hybrid)")
  if cfg.family == "encdec" and spec.name == "long_500k":
    return "enc-dec full attention (448-token decoder design)"
  return None


def input_specs(cfg: ModelConfig, shape: str,
                batch_override: Optional[int] = None,
                seq_override: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
  """ShapeDtypeStruct stand-ins for every model input (no allocation).

  Modality frontends are STUBS per the assignment: whisper gets precomputed
  frame embeddings, pixtral gets precomputed patch embeddings.
  """
  spec = SHAPES[shape]
  b = batch_override or spec.global_batch
  s = seq_override or spec.seq_len
  f32 = jnp.float32
  i32 = jnp.int32
  d = cfg.d_model

  out: Dict[str, jax.ShapeDtypeStruct] = {}
  if spec.mode == "train":
    out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
  elif spec.mode == "prefill":
    out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
  else:  # decode: one new token against an s-deep cache
    out["tokens"] = jax.ShapeDtypeStruct((b,), i32)

  if cfg.family == "encdec":
    # conv frontend stub: precomputed log-mel frame embeddings
    enc_len = min(cfg.encoder_seq, s)
    out["enc_frames"] = jax.ShapeDtypeStruct((b, enc_len, d), f32)
    if spec.mode == "train":
      # decoder consumes seq/4 tokens (audio>text token ratio)
      dec = max(s // 4, 8)
      out["tokens"] = jax.ShapeDtypeStruct((b, dec), i32)
      out["labels"] = jax.ShapeDtypeStruct((b, dec), i32)
    elif spec.mode == "prefill":
      out["tokens"] = jax.ShapeDtypeStruct((b, max(s // 4, 8)), i32)
  if cfg.family == "vlm" and spec.mode != "decode":
    # ViT frontend stub: precomputed patch embeddings
    out["img_embeds"] = jax.ShapeDtypeStruct((b, cfg.n_image_tokens, d), f32)
  return out


def reduce_for_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
  """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
  period = len(cfg.layer_kinds())
  base = dict(
      n_layers=2 * period,
      d_model=64,
      n_heads=4 if cfg.n_heads else 0,
      n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_heads else 0,
      head_dim=16,
      d_ff=128,
      vocab_size=512,
      attn_chunk=64,
      loss_chunk_tokens=256,
      moe_group_size=64,
      ssm_chunk=16,
      dtype="float32",
  )
  if cfg.family == "ssm":
    base.update(n_heads=4, head_dim=16)  # wkv heads
  if cfg.n_experts:
    base.update(n_experts=4, n_experts_active=min(cfg.n_experts_active, 2),
                d_ff_expert=128,
                d_ff_shared=128 if cfg.n_shared_experts else 0)
  if cfg.family == "encdec":
    base.update(n_encoder_layers=2, encoder_seq=32)
  if cfg.family == "vlm":
    base.update(n_image_tokens=8)
  if cfg.sliding_window:
    base.update(sliding_window=32)
  base.update(overrides)
  return dataclasses.replace(cfg, **base)
