"""jamba-1.5-large-398b [hybrid]: 72L d8192 64H (GQA kv=8) ff24576,
Mamba:attention 7:1 interleave, MoE 16 experts top-2 on every other layer.

No positional embeddings (Mamba carries position); SwiGLU experts.
long_500k RUNS: 63/72 layers are O(1)-state Mamba, the 9 attention layers
keep full KV (sharded over the mesh).  [arXiv:2403.19887 + Jamba-1.5
arXiv:2408.12570; hf:ai21labs/AI21-Jamba-1.5-Large]
"""
from repro.configs.base import ModelConfig, register


@register("jamba-1.5-large")
def jamba_1_5_large() -> ModelConfig:
  return ModelConfig(
      name="jamba-1.5-large", family="hybrid",
      n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
      d_ff=24576, vocab_size=65536,
      mlp_variant="swiglu", norm="rmsnorm", pos_embed="none",
      n_experts=16, n_experts_active=2, d_ff_expert=24576,
      moe_period=2, moe_offset=1,
      attn_period=8, mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
      source="arXiv:2403.19887",
  )
