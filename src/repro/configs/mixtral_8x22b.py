"""mixtral-8x22b [moe]: 56L d6144 48H (GQA kv=8) ff16384, 8 experts top-2.

SwiGLU experts, RoPE (theta 1e6), sliding-window attention (4096) per the
assignment note — SWA bounds the KV cache, so long_500k RUNS for this arch.
[arXiv:2401.04088; hf:mistralai/Mixtral-8x22B-v0.1]
"""
from repro.configs.base import ModelConfig, register


@register("mixtral-8x22b")
def mixtral_8x22b() -> ModelConfig:
  return ModelConfig(
      name="mixtral-8x22b", family="moe",
      n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
      d_ff=16384, vocab_size=32768,
      mlp_variant="swiglu", norm="rmsnorm", pos_embed="rope",
      rope_theta=1e6, sliding_window=4096,
      n_experts=8, n_experts_active=2, d_ff_expert=16384,
      moe_period=1, moe_offset=0,
      source="arXiv:2401.04088",
  )
