"""minitron-4b [dense]: 32L d3072 24H (GQA kv=8) ff9216 vocab256000.

Pruned Nemotron: squared-ReLU MLP, RoPE, untied 256k embedding.
[arXiv:2407.14679; hf:nvidia/Minitron-4B-Base]
"""
from repro.configs.base import ModelConfig, register


@register("minitron-4b")
def minitron_4b() -> ModelConfig:
  return ModelConfig(
      name="minitron-4b", family="dense",
      n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
      d_ff=9216, vocab_size=256000,
      mlp_variant="relu2", norm="layernorm", pos_embed="rope",
      source="arXiv:2407.14679",
  )
