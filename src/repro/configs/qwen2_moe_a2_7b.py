"""qwen2-moe-a2.7b [moe]: 24L d2048 16H (kv=16) 60 routed experts top-4
+ 4 shared experts (shared intermediate 5632 = 4 x 1408), ff_expert 1408,
vocab 151936.  [hf:Qwen/Qwen1.5-MoE-A2.7B]
"""
from repro.configs.base import ModelConfig, register


@register("qwen2-moe-a2.7b")
def qwen2_moe_a2_7b() -> ModelConfig:
  return ModelConfig(
      name="qwen2-moe-a2.7b", family="moe",
      n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
      d_ff=1408, vocab_size=151936,
      mlp_variant="swiglu", norm="rmsnorm", pos_embed="rope",
      n_experts=60, n_experts_active=4, n_shared_experts=4,
      d_ff_expert=1408, d_ff_shared=5632,
      moe_period=1, moe_offset=0,
      source="hf:Qwen/Qwen1.5-MoE-A2.7B",
  )
