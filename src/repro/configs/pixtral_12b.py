"""pixtral-12b [vlm]: 40L d5120 32H (GQA kv=8) ff14336 vocab131072.

Mistral-Nemo decoder backbone; the Pixtral-ViT frontend is a STUB —
input_specs() provides precomputed patch embeddings prepended to the text
sequence.  [hf:mistralai/Pixtral-12B-2409]
"""
from repro.configs.base import ModelConfig, register


@register("pixtral-12b")
def pixtral_12b() -> ModelConfig:
  return ModelConfig(
      name="pixtral-12b", family="vlm",
      n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
      d_ff=14336, vocab_size=131072,
      mlp_variant="swiglu", norm="rmsnorm", pos_embed="rope",
      rope_theta=1e6, n_image_tokens=256,
      source="hf:mistralai/Pixtral-12B-2409",
  )
