"""granite-34b [dense]: 88L d6144 48H (MQA kv=1) ff24576 vocab49152.

GPTBigCode/llama-arch code model: MQA, GELU MLP, learned positions.
[arXiv:2405.04324; hf:ibm-granite/granite-34b-code-base]
"""
from repro.configs.base import ModelConfig, register


@register("granite-34b")
def granite_34b() -> ModelConfig:
  return ModelConfig(
      name="granite-34b", family="dense",
      n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
      d_ff=24576, vocab_size=49152,
      mlp_variant="gelu", norm="layernorm", pos_embed="learned",
      max_position=65536,  # table extended beyond the 8k training ctx so
                            # the 32k assigned shapes lower structurally
      source="arXiv:2405.04324",
  )
