"""olmo-1b [dense]: 16L d2048 16H (GQA kv=16) ff8192 vocab50304.

Non-parametric LayerNorm, SwiGLU, RoPE, tied embeddings.
[arXiv:2402.00838; hf:allenai/OLMo-1B]
"""
from repro.configs.base import ModelConfig, register


@register("olmo-1b")
def olmo_1b() -> ModelConfig:
  return ModelConfig(
      name="olmo-1b", family="dense",
      n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
      d_ff=8192, vocab_size=50304,
      mlp_variant="swiglu", norm="layernorm_np", pos_embed="rope",
      tie_embeddings=True,
      source="arXiv:2402.00838",
  )
