"""Architecture registry: importing this package registers all configs."""
from repro.configs import (granite_34b, jamba_1_5_large, minitron_4b,  # noqa
                           mixtral_8x22b, olmo_1b, pixtral_12b,
                           qwen2_moe_a2_7b, qwen3_0_6b, rwkv6_1_6b,
                           whisper_base)
from repro.configs.base import ModelConfig, get_config, list_archs  # noqa
from repro.configs.shapes import (SHAPES, input_specs,  # noqa
                                  reduce_for_smoke, shape_supported)

ALL_ARCHS = (
    "olmo-1b", "granite-34b", "qwen3-0.6b", "minitron-4b", "mixtral-8x22b",
    "qwen2-moe-a2.7b", "jamba-1.5-large", "whisper-base", "rwkv6-1.6b",
    "pixtral-12b",
)
