"""rwkv6-1.6b "Finch" [ssm]: 24L d2048 (attention-free) ff7168 vocab65536.

Data-dependent per-channel decay (WKV6), 32 heads of 64; time-mix +
channel-mix per layer.  long_500k RUNS (O(1) recurrent state).
[arXiv:2404.05892; hf:RWKV/rwkv-6-world-1b6]
"""
from repro.configs.base import ModelConfig, register


@register("rwkv6-1.6b")
def rwkv6_1_6b() -> ModelConfig:
  return ModelConfig(
      name="rwkv6-1.6b", family="ssm",
      n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
      d_ff=7168, vocab_size=65536,
      mlp_variant="gelu", norm="layernorm", pos_embed="none",
      ssm_chunk=64,
      source="arXiv:2404.05892",
  )
