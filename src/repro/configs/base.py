"""Model configuration schema + registry for the assigned architectures."""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

VOCAB_PAD_MULTIPLE = 256  # Megatron-style padding so vocab shards on TP axes


@dataclasses.dataclass(frozen=True)
class ModelConfig:
  """One architecture. All fields are public-literature values (see the
  per-arch modules for sources)."""
  name: str
  family: str                 # dense | moe | hybrid | ssm | encdec | vlm
  n_layers: int
  d_model: int
  n_heads: int                # 0 => attention-free architecture
  n_kv_heads: int
  head_dim: int
  d_ff: int
  vocab_size: int

  # block variations
  mlp_variant: str = "swiglu"          # swiglu | gelu | relu2
  norm: str = "rmsnorm"                # rmsnorm | layernorm | layernorm_np
  qk_norm: bool = False
  pos_embed: str = "rope"              # rope | learned | sinusoidal | none
  rope_theta: float = 10_000.0
  tie_embeddings: bool = False
  sliding_window: int = 0              # 0 = full attention
  max_position: int = 1 << 20

  # MoE
  n_experts: int = 0
  n_experts_active: int = 0
  n_shared_experts: int = 0
  d_ff_expert: int = 0
  d_ff_shared: int = 0
  moe_period: int = 1                  # MoE on layers where i % period ...
  moe_offset: int = 0                  # ... == offset (when n_experts > 0)
  capacity_factor: float = 1.25
  moe_group_size: int = 512

  # hybrid / ssm
  attn_period: int = 0                 # jamba: 1 attn per this many layers
  mamba_d_state: int = 16
  mamba_d_conv: int = 4
  mamba_expand: int = 2
  ssm_chunk: int = 128

  # encoder-decoder (audio) / vlm frontends (STUBS: input_specs() provides
  # precomputed frame / patch embeddings)
  n_encoder_layers: int = 0
  encoder_seq: int = 1500
  n_image_tokens: int = 0

  # numerics
  kv_quant: str = "none"               # none | int8 (serving KV cache)
  dtype: str = "bfloat16"
  attn_chunk: int = 512                # pure-JAX flash chunking
  loss_chunk_tokens: int = 8192

  # notes for DESIGN.md / roofline
  source: str = ""

  # ---------------------------------------------------------------------
  @property
  def padded_vocab(self) -> int:
    m = VOCAB_PAD_MULTIPLE
    return -(-self.vocab_size // m) * m

  @property
  def d_inner(self) -> int:
    return self.mamba_expand * self.d_model

  @property
  def is_attention_free(self) -> bool:
    return self.family == "ssm"

  @property
  def supports_long_context(self) -> bool:
    """long_500k runnable: sub-quadratic attention (SWA / SSM / hybrid)."""
    return (self.family in ("ssm", "hybrid")
            or self.sliding_window > 0)

  @property
  def has_decoder(self) -> bool:
    return True  # all assigned archs decode (whisper via its decoder)

  def layer_kinds(self) -> List[str]:
    """Per-layer kind within one scan block (the repeating pattern)."""
    if self.family == "ssm":
      return ["rwkv"]
    if self.family == "hybrid" and self.attn_period > 1:
      return ["attn"] + ["mamba"] * (self.attn_period - 1)
    return ["attn"]

  def block_pattern(self) -> List[Tuple[str, bool]]:
    """[(kind, is_moe)] for one scanned block; model = scan over
    n_layers/len(pattern) stacked blocks."""
    kinds = self.layer_kinds()
    period = len(kinds)
    assert self.n_layers % period == 0, (self.name, self.n_layers, period)
    out = []
    for i, kind in enumerate(kinds):
      is_moe = (self.n_experts > 0
                and i % self.moe_period == self.moe_offset)
      out.append((kind, is_moe))
    return out

  @property
  def n_blocks(self) -> int:
    return self.n_layers // len(self.layer_kinds())

  # ---- parameter / FLOP accounting (roofline) ---------------------------
  def param_count(self, active_only: bool = False) -> int:
    """Analytic parameter count; active_only counts top-k experts only."""
    d, dff = self.d_model, self.d_ff
    n = 0
    emb = self.padded_vocab * d
    n += emb if self.tie_embeddings else 2 * emb
    if self.pos_embed == "learned":
      n += self.max_position * d
    dt_rank = max(d // 16, 1)
    for kind, is_moe in self.block_pattern():
      per = 0
      if kind == "attn":
        per += d * self.n_heads * self.head_dim          # q
        per += 2 * d * self.n_kv_heads * self.head_dim   # kv
        per += self.n_heads * self.head_dim * d          # o
      elif kind == "mamba":
        di = self.d_inner
        per += d * 2 * di                                # in_proj (x, z)
        per += di * self.mamba_d_conv                    # depthwise conv
        per += di * (dt_rank + 2 * self.mamba_d_state)   # x_proj
        per += dt_rank * di                              # dt_proj
        per += di * self.mamba_d_state                   # A_log
        per += di * d                                    # out_proj
      elif kind == "rwkv":
        per += 5 * d * d                  # r, k, v, gate, out (time mix)
        per += 2 * d * dt_rank            # data-dependent decay lora
      ff_mats = 3 if self.mlp_variant == "swiglu" else 2
      if is_moe:
        e = self.n_experts if not active_only else self.n_experts_active
        per += e * ff_mats * d * self.d_ff_expert
        if self.n_shared_experts:
          per += ff_mats * d * self.d_ff_shared
        per += d * self.n_experts         # router
      elif kind == "rwkv":
        per += 2 * d * dff + d * d        # channel mix: k, v + receptance
      else:
        per += ff_mats * d * dff
      per *= self.n_blocks
      n += per
    if self.family == "encdec":
      # encoder blocks (self-attn + mlp) and decoder cross-attention
      enc = self.n_encoder_layers * (
          4 * d * self.n_heads * self.head_dim
          + (3 if self.mlp_variant == "swiglu" else 2) * d * dff)
      cross = self.n_layers * 4 * d * self.n_heads * self.head_dim
      n += enc + cross
    return n

  def train_flops_per_token(self) -> float:
    """MODEL_FLOPS = 6 * N(active) per token (fwd+bwd)."""
    return 6.0 * self.param_count(active_only=True)


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
  def deco(fn):
    _REGISTRY[name] = fn
    return fn
  return deco


def get_config(name: str) -> ModelConfig:
  if name not in _REGISTRY:
    # import side-effect registration
    import repro.configs  # noqa
  if name not in _REGISTRY:
    raise ValueError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
  return _REGISTRY[name]()


def list_archs() -> List[str]:
  import repro.configs  # noqa
  return sorted(_REGISTRY)
