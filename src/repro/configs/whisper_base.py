"""whisper-base [audio]: 6L enc + 6L dec, d512 8H ff2048 vocab51865.

Encoder-decoder; the conv1d audio frontend is a STUB — input_specs()
provides precomputed frame embeddings (B, 1500, d).  GELU MLP, learned
decoder positions, sinusoidal encoder positions.
[arXiv:2212.04356; hf:openai/whisper-base]
"""
from repro.configs.base import ModelConfig, register


@register("whisper-base")
def whisper_base() -> ModelConfig:
  return ModelConfig(
      name="whisper-base", family="encdec",
      n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
      d_ff=2048, vocab_size=51865,
      mlp_variant="gelu", norm="layernorm", pos_embed="learned",
      n_encoder_layers=6, encoder_seq=1500, max_position=65536,
      source="arXiv:2212.04356",
  )
