"""Elastic fleet perf record: multi-device scaling + chaos bit-identity.

Measures sweep throughput (design pairs/s) on 1 vs 8 forced XLA host
devices — each point in its own child process, since the device count is
fixed at jax start — and runs the acceptance chaos scenario (1 straggler
+ 1 device lost mid-sweep + 1 silently-corrupting chunk with the SDC
sentinel on) asserting its Pareto front is bit-identical to the solo
numpy baseline.  Results land in ``results/BENCH_fleet.json``;
``FLEET_BENCH_SCALE=smoke`` (CI) shrinks the sweep while still
exercising every phase.

The >= 4x scaling gate is enforced only at full scale on hosts with
>= 8 cores: forced host devices share physical cores, so on a smaller
box the 8-device point measures dispatch overhead, not parallel
capacity.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_worker(n_devices: int, mode: str, n_per_type: int,
                chunk_size: int) -> dict:
  env = dict(os.environ)
  env.pop("XLA_FLAGS", None)  # the child builds its own device topology
  env["PYTHONPATH"] = os.pathsep.join(
      [os.path.join(_REPO, "src"),
       env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
  proc = subprocess.run(
      [sys.executable, "-m", "benchmarks.fleet_worker", str(n_devices),
       mode, str(n_per_type), str(chunk_size)],
      capture_output=True, text=True, env=env, cwd=_REPO, timeout=3600)
  if proc.returncode != 0:
    raise RuntimeError(
        f"fleet worker ({n_devices} dev, {mode}) failed:\n"
        + proc.stderr[-4000:])
  return json.loads(proc.stdout.splitlines()[-1])


def fleet_perf() -> None:
  from benchmarks.common import emit, write_bench_json

  smoke = os.environ.get("FLEET_BENCH_SCALE") == "smoke"
  if smoke:
    n_per_type, chunk_size = 200, 100        # 800 rows, 8 chunks
  else:
    n_per_type, chunk_size = 25000, 6250     # 100k rows, 16 chunks

  solo = _run_worker(1, "solo", n_per_type, chunk_size)
  one = _run_worker(1, "fleet", n_per_type, chunk_size)
  eight = _run_worker(8, "fleet", n_per_type, chunk_size)
  chaos = _run_worker(8, "chaos", n_per_type, chunk_size)

  # bit-identity: the healthy 8-device front and the chaos front must
  # both reproduce the solo numpy front exactly (JSON doubles round-trip)
  for name, run in (("fleet8", eight), ("chaos", chaos)):
    for part in ("front", "top"):
      assert run[part] == solo[part], f"{name} {part} != solo"
  meta = chaos["meta"]
  assert meta["n_device_losses"] == 1.0, meta
  assert meta["n_corruptions_detected"] == 1.0, meta
  assert meta["n_corruption_checks"] >= 1.0, meta
  assert meta["n_resharded"] >= 1.0, meta
  assert meta["n_leaked_watchdogs"] == 0.0, meta

  scaling = eight["pairs_per_sec"] / one["pairs_per_sec"]
  if not smoke and (os.cpu_count() or 1) >= 8:
    assert scaling >= 4.0, (
        f"8-device scaling {scaling:.2f}x < 4x at full scale")

  emit("fleet_pairs_per_sec_1dev", 1e6 / one["pairs_per_sec"],
       f"pairs/s={one['pairs_per_sec']:.0f}")
  emit("fleet_pairs_per_sec_8dev", 1e6 / eight["pairs_per_sec"],
       f"pairs/s={eight['pairs_per_sec']:.0f} scaling={scaling:.2f}x")
  emit("fleet_chaos_sweep", chaos["wall_s"] * 1e6,
       f"bit-identical lost={int(meta['n_device_losses'])} "
       f"sdc={int(meta['n_corruptions_detected'])} "
       f"resharded={int(meta['n_resharded'])}")

  write_bench_json("fleet", {
      "scale": "smoke" if smoke else "full",
      "n_rows": solo["n_rows"],
      "pairs_per_sec_solo_numpy": solo["pairs_per_sec"],
      "pairs_per_sec_1dev": one["pairs_per_sec"],
      "pairs_per_sec_8dev": eight["pairs_per_sec"],
      "scaling_1_to_8": scaling,
      "scaling_gate_enforced": bool(not smoke
                                    and (os.cpu_count() or 1) >= 8),
      "chaos": {
          "bit_identical_to_solo": True,
          "wall_s": chaos["wall_s"],
          "n_device_losses": meta["n_device_losses"],
          "n_corruption_checks": meta["n_corruption_checks"],
          "n_corruptions_detected": meta["n_corruptions_detected"],
          "n_resharded": meta["n_resharded"],
          "n_speculative": meta["n_speculative"],
          "n_leaked_watchdogs": meta["n_leaked_watchdogs"],
      },
      "device_topology_8dev": eight["topology"],
  })


ALL = [fleet_perf]
