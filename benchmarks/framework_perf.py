"""Framework-side benchmarks: kernel codecs, train step, serve step."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call


def kernel_codecs() -> None:
  """HBM bytes per weight for each deploy codec + interpret-mode check."""
  from repro.kernels.pow2_matmul import ops as pow2_ops
  from repro.kernels.int8_matmul import ops as i8_ops
  key = jax.random.PRNGKey(0)
  k, n = 512, 512
  w = jax.random.normal(key, (k, n)) * 0.05
  x = jax.random.normal(key, (64, k))
  rows = []
  for kt in (1, 2):
    pw = pow2_ops.quantize_weights(w, k_terms=kt)
    t0 = time.perf_counter()
    out = pow2_ops.pow2_matmul(x, pw, interpret=True)
    out.block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    ref = pow2_ops.pow2_matmul_reference(x, pw)
    err = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
    rows.append(f"pow2_k{kt}:bytes/weight={pw.hbm_bytes / (k * n):.3f},"
                f"err={err:.1e}")
  i8 = i8_ops.quantize_weights(w)
  rows.append(f"int8:bytes/weight={i8.hbm_bytes / (k * n):.3f}")
  rows.append("bf16_dense:bytes/weight=2.0")
  emit("kernel_codecs", 0.0, ";".join(rows))


def train_step_small_lm() -> None:
  """Micro end-to-end: one optimizer step of a tiny zoo model."""
  from repro.configs import get_config, reduce_for_smoke
  from repro.models.model import build_model
  from repro.train import train_step as ts_lib
  cfg = reduce_for_smoke(get_config("olmo-1b"))
  model = build_model(cfg)
  tcfg = ts_lib.TrainConfig()
  state = ts_lib.make_train_state(model, tcfg, jax.random.PRNGKey(0))
  step = ts_lib.jit_train_step(model, tcfg, donate=False)
  key = jax.random.PRNGKey(1)
  batch = {"tokens": jax.random.randint(key, (4, 128), 0, cfg.vocab_size),
           "labels": jax.random.randint(key, (4, 128), 0, cfg.vocab_size)}
  state, m = step(state, batch)  # compile
  t0 = time.perf_counter()
  for _ in range(3):
    state, m = step(state, batch)
  jax.block_until_ready(state)
  us = (time.perf_counter() - t0) / 3 * 1e6
  emit("train_step_small_lm", us,
       f"loss={float(m['loss']):.3f};tokens/step=512")


def serve_engine_throughput() -> None:
  """Batched serving engine throughput on a tiny model."""
  from repro.configs import get_config, reduce_for_smoke
  from repro.models.model import build_model
  from repro.serve.engine import EngineConfig, ServeEngine
  import dataclasses
  cfg = reduce_for_smoke(get_config("qwen3-0.6b"))
  cfg = dataclasses.replace(cfg, kv_quant="int8")
  model = build_model(cfg)
  params = model.init(jax.random.PRNGKey(0))
  eng = ServeEngine(model, params, EngineConfig(
      batch_slots=4, max_len=128, prompt_bucket=32))
  rng = np.random.RandomState(0)
  for _ in range(6):
    eng.submit(rng.randint(0, cfg.vocab_size, size=12), max_new_tokens=8)
  t0 = time.perf_counter()
  out = eng.run_until_drained()
  dt = time.perf_counter() - t0
  total_tokens = sum(len(v) for v in out.values())
  emit("serve_engine_throughput", dt / max(total_tokens, 1) * 1e6,
       f"requests={len(out)};tokens={total_tokens};kv_quant=int8")


def explore_api_perf() -> None:
  """repro.explore hot paths: vectorized Pareto at 50k points, backend
  save/load round trip, and columnar evaluation throughput."""
  import os
  import tempfile

  from repro.core.workloads import get_network
  from repro.explore import DesignSpace, PolynomialBackend, pareto_mask

  # 50k-point front extraction (front-heavy worst case for the old loop)
  rng = np.random.RandomState(0)
  theta = rng.uniform(0.0, np.pi / 2, 2000)
  arc = np.stack([np.cos(theta), np.sin(theta)], axis=1)
  fill = arc[rng.randint(0, len(arc), 48_000)] + rng.uniform(
      0.01, 1.0, size=(48_000, 2))
  pts = np.concatenate([arc, fill])[rng.permutation(50_000)]
  t0 = time.perf_counter()
  mask = pareto_mask(pts)
  pareto_us = (time.perf_counter() - t0) * 1e6

  # fit-once + save/load + batched evaluation
  layers = get_network("resnet20")[:4]
  backend = PolynomialBackend.fit(pe_types=("INT16",), degree=3, n_train=80,
                                  layers=layers, seed=0)
  cfgs = DesignSpace(pe_types=("INT16",)).sample_type("INT16", 5000, seed=1)
  with tempfile.TemporaryDirectory() as d:
    path = os.path.join(d, "models.npz")
    t0 = time.perf_counter()
    backend.save(path)
    loaded = PolynomialBackend.load(path)
    roundtrip_us = (time.perf_counter() - t0) * 1e6
  t0 = time.perf_counter()
  frame = loaded.evaluate(cfgs, layers, "resnet20-head")
  eval_us = (time.perf_counter() - t0) * 1e6
  orig = backend.evaluate(cfgs, layers, "x")
  exact = bool(np.array_equal(frame.latency_s, orig.latency_s)
               and np.array_equal(frame.power_mw, orig.power_mw)
               and np.array_equal(frame.area_mm2, orig.area_mm2))
  emit("explore_api_perf", pareto_us,
       f"pareto_50k_us={pareto_us:.0f};front_size={int(mask.sum())};"
       f"save_load_us={roundtrip_us:.0f};roundtrip_bit_identical={exact};"
       f"eval_us_per_design={eval_us / len(frame):.1f}")


def explore_vector_perf() -> None:
  """The tentpole claim: vectorized oracle sweep throughput vs the scalar
  per-design loop on a 100k-point power/area sweep, plus the Pareto time
  over the resulting frame.  Records results/BENCH_explore.json so the
  perf trajectory is tracked across PRs."""
  from benchmarks.common import write_bench_json
  from repro.core import oracle
  from repro.explore import DesignSpace

  n_total = 100_000
  space = DesignSpace()
  t0 = time.perf_counter()
  table = space.sample_table(n_total // len(space.pe_types), seed=0)
  sample_s = time.perf_counter() - t0

  # vector sweep: full characterization-free power/area pass over the table
  t0 = time.perf_counter()
  pwr, area = oracle.power_area_batch(table)
  vec_s = time.perf_counter() - t0
  vec_pts_per_s = len(table) / vec_s

  # scalar baseline on a subsample (extrapolating the loop to 100k would
  # dominate the whole benchmark suite), plus a parity check on it
  n_scalar = 2000
  sub = table.select(slice(0, n_scalar))
  cfgs = sub.to_configs()
  t0 = time.perf_counter()
  s_pwr = np.asarray([oracle.power_mw(c) for c in cfgs])
  s_area = np.asarray([oracle.area_mm2(c) for c in cfgs])
  scalar_s = time.perf_counter() - t0
  scalar_pts_per_s = n_scalar / scalar_s
  parity = float(max(np.max(np.abs(pwr[:n_scalar] / s_pwr - 1.0)),
                     np.max(np.abs(area[:n_scalar] / s_area - 1.0))))

  # Pareto on the paper's axes: (perf_per_area, energy).  Raw (power,
  # area) are near-perfectly correlated across this space, which
  # degenerates the front to ~1 point — perf/area vs energy needs the
  # latency sweep too, so characterize against a small workload head.
  from repro.core.workloads import get_network
  from repro.explore import VectorOracleBackend
  layers = get_network("resnet20")[:8]
  t0 = time.perf_counter()
  frame = VectorOracleBackend(chunk_size=65536).evaluate_table(
      table, layers, "resnet20-head")
  latency_s = time.perf_counter() - t0
  t0 = time.perf_counter()
  front = frame.pareto(cols=("perf_per_area", "energy_mj"))
  pareto_s = time.perf_counter() - t0
  # per-type fronts (Fig. 11-style): each PE type's own non-dominated set
  front_by_type = {
      t: int(frame.select(frame.by_type(t))
             .pareto(cols=("perf_per_area", "energy_mj")).sum())
      for t in space.pe_types}

  speedup = vec_pts_per_s / scalar_pts_per_s
  record = {
      "n_points": int(len(table)),
      "sample_table_seconds": round(sample_s, 4),
      "vector_seconds": round(vec_s, 4),
      "vector_points_per_sec": round(vec_pts_per_s, 1),
      "scalar_points_per_sec": round(scalar_pts_per_s, 1),
      "scalar_sample_points": n_scalar,
      "speedup": round(speedup, 1),
      "parity_max_rel_err": parity,
      "latency_sweep_seconds": round(latency_s, 4),
      "pareto_axes": ["perf_per_area", "energy_mj"],
      "pareto_100k_seconds": round(pareto_s, 4),
      "pareto_front_size": int(front.sum()),
      "pareto_front_size_by_type": front_by_type,
  }
  path = write_bench_json("explore", record)
  emit("explore_vector_perf", vec_s / len(table) * 1e6,
       f"points={len(table)};vector_pts_per_s={vec_pts_per_s:.0f};"
       f"scalar_pts_per_s={scalar_pts_per_s:.0f};speedup={speedup:.0f}x;"
       f"parity_max_rel={parity:.1e};pareto_s={pareto_s:.3f};"
       f"front={int(front.sum())};json={path}")


def coexplore_vector_perf() -> None:
  """The joint-sweep tentpole claim: vectorized HW x NN co-exploration
  (JointTable + LayerStack + characterize_joint) vs the scalar nested
  per-(arch, hw) oracle loop, on a 1M-pair sweep (1k archs x 1k HW
  configs).  Records scalar/vector throughput, exact-parity max-rel-err,
  and the 3-objective joint Pareto front size into
  results/BENCH_coexplore.json."""
  from benchmarks.common import write_bench_json
  from repro.core.cnn import SEARCH_SPACE, ArchChoice
  from repro.core.supernet import arch_to_layers
  from repro.explore import (DesignSpace, ExplorationSession, OracleBackend,
                             VectorOracleBackend)

  n_archs, n_hw_per_type, image_size = 1000, 250, 16
  rng = np.random.RandomState(0)
  archs = [ArchChoice(tuple((int(rng.choice(reps)), int(rng.choice(chs)))
                            for reps, chs in SEARCH_SPACE))
           for _ in range(n_archs)]
  # pseudo-accuracies: the throughput/front shape does not need a trained
  # supernet (examples/coexplore_cnn.py demos the real accuracy loop)
  accs = rng.uniform(0.5, 0.95, size=n_archs)
  arch_accs = list(zip(archs, accs))

  space = DesignSpace()
  session = ExplorationSession(VectorOracleBackend(chunk_size=262144), space)
  t0 = time.perf_counter()
  frame = session.co_explore(arch_accs, n_hw_per_type=n_hw_per_type,
                             seed=3, image_size=image_size)  # auto -> joint
  vec_s = time.perf_counter() - t0
  n_pairs = len(frame)
  vec_pairs_per_s = n_pairs / vec_s

  # scalar baseline: the pre-vectorization nested loop (per-point oracle
  # characterization per (arch, hw) pair) on a subsample, plus exact
  # parity against the matching joint-frame rows.  Type-0 block rows are
  # arch-major: row(a, h) = a * n_hw_per_type + h.
  k_archs, k_hw = 2, 50
  hw0 = space.sample_type_table(space.pe_types[0], n_hw_per_type, seed=3)
  sub_cfgs = hw0.select(slice(0, k_hw)).to_configs()
  ob = OracleBackend()
  parity = 0.0
  t0 = time.perf_counter()
  for a in range(k_archs):
    fs = ob.evaluate(sub_cfgs, arch_to_layers(archs[a], image_size),
                     "coexplore")
    rows = slice(a * n_hw_per_type, a * n_hw_per_type + k_hw)
    for col in ("latency_s", "power_mw", "area_mm2"):
      rel = np.abs(getattr(frame, col)[rows] / getattr(fs, col) - 1.0)
      parity = max(parity, float(rel.max()))
  scalar_s = time.perf_counter() - t0
  scalar_pairs_per_s = k_archs * k_hw / scalar_s

  t0 = time.perf_counter()
  front3 = frame.pareto(cols=("top1_err", "energy_mj", "area_mm2"))
  front3_s = time.perf_counter() - t0

  speedup = vec_pairs_per_s / scalar_pairs_per_s
  record = {
      "n_pairs": int(n_pairs),
      "n_archs": n_archs,
      "n_hw": n_hw_per_type * len(space.pe_types),
      "vector_seconds": round(vec_s, 4),
      "vector_pairs_per_sec": round(vec_pairs_per_s, 1),
      "scalar_pairs_per_sec": round(scalar_pairs_per_s, 1),
      "scalar_sample_pairs": k_archs * k_hw,
      "speedup": round(speedup, 1),
      "parity_max_rel_err": parity,
      "pareto3d_axes": ["top1_err", "energy_mj", "area_mm2"],
      "pareto3d_seconds": round(front3_s, 4),
      "pareto3d_front_size": int(front3.sum()),
  }
  path = write_bench_json("coexplore", record)
  emit("coexplore_vector_perf", vec_s / n_pairs * 1e6,
       f"pairs={n_pairs};vector_pairs_per_s={vec_pairs_per_s:.0f};"
       f"scalar_pairs_per_s={scalar_pairs_per_s:.0f};speedup={speedup:.0f}x;"
       f"parity_max_rel={parity:.1e};front3d={int(front3.sum())};"
       f"json={path}")


def streaming_perf() -> None:
  """The streaming tentpole claim: a 10M-pair co-exploration (1k archs x
  10k HW configs) evaluated in constant memory through the streaming
  engine — online Pareto/top-k reducers keep only survivors, peak RSS
  stays bounded (one-shot materialization would need the full 10M-row
  JointTable + ResultFrame) — plus the device-resident fused pipeline
  (exact x64 ``jax.jit`` evaluation + on-device reduction, O(survivors)
  device->host transfer, bit-identical survivors), parallel-vs-serial
  numpy chunk throughput, streaming <-> one-shot bit-identity on a
  smaller sweep, and the block-decomposed N-D pareto_mask kernel time.
  Records results/BENCH_streaming.json.  Set STREAMING_BENCH_SCALE=smoke
  (CI) to shrink every phase while still exercising both paths.

  Comparability note: the phase-1/1b stream rates are single-shot runs of
  the full sweep (cold pages, Pareto+top-k reducers), while the phase-2
  serial/parallel rates are best-of-3 on a Pareto-only sub-sweep — the
  two pairs are each internally comparable, but not with one another."""
  import os
  import resource

  from benchmarks.common import write_bench_json
  from repro.core.cnn import SEARCH_SPACE, ArchChoice
  from repro.explore import (DesignSpace, ExplorationSession,
                             ParetoAccumulator, TopKAccumulator,
                             VectorOracleBackend, pareto_mask)

  smoke = os.environ.get("STREAMING_BENCH_SCALE") == "smoke"
  n_archs = 40 if smoke else 1000
  n_hw_per_type = 25 if smoke else 2500
  chunk_size = 8192 if smoke else 262144
  cols = ("top1_err", "energy_mj", "area_mm2")

  rng = np.random.RandomState(0)
  archs = [ArchChoice(tuple((int(rng.choice(reps)), int(rng.choice(chs)))
                            for reps, chs in SEARCH_SPACE))
           for _ in range(n_archs)]
  accs = rng.uniform(0.5, 0.95, size=n_archs)
  arch_accs = list(zip(archs, accs))

  def rss_mb() -> float:
    # ru_maxrss is KiB on Linux, bytes on macOS; it is also the *process
    # lifetime* high-water mark — rss_peak_mb only bounds the streaming
    # sweep when this benchmark runs standalone (--suite streaming, as the
    # CI step and the canonical BENCH_streaming.json record do), not after
    # the frame-materializing benchmarks of --suite framework/all.
    import sys
    val = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return val / (1024.0 * 1024.0) if sys.platform == "darwin" \
        else val / 1024.0

  space = DesignSpace()
  session = ExplorationSession(VectorOracleBackend(chunk_size=chunk_size),
                               space)
  rss_before = rss_mb()

  # phase 1: the big constant-memory sweep (survivors only, parallel)
  reducers = {"pareto": ParetoAccumulator(cols),
              "top": TopKAccumulator(100, by="energy_mj")}
  t0 = time.perf_counter()
  res = session.co_explore(arch_accs, n_hw_per_type=n_hw_per_type, seed=3,
                           image_size=16, stream=True, reducers=reducers,
                           chunk_size=chunk_size)
  stream_s = time.perf_counter() - t0
  rss_peak = rss_mb()  # sampled right after: the sweep's own high-water mark
  n_pairs = res.n_rows
  front = res["pareto"]
  top = res["top"]

  # phase 1b: the device-resident fused pipeline on the same sweep —
  # exact x64 jit evaluation fused with on-device Pareto/top-k reduction;
  # survivors must be bit-identical to the numpy streaming run above
  dev_session = ExplorationSession(
      VectorOracleBackend(chunk_size=chunk_size, jit=True), space)
  dev_reducers = {"pareto": ParetoAccumulator(cols),
                  "top": TopKAccumulator(100, by="energy_mj")}
  t0 = time.perf_counter()
  dres = dev_session.co_explore(arch_accs, n_hw_per_type=n_hw_per_type,
                                seed=3, image_size=16, stream=True,
                                reducers=dev_reducers,
                                chunk_size=chunk_size)
  device_s = time.perf_counter() - t0
  metric_cols = ("latency_s", "power_mw", "area_mm2")
  dev_identical = all(
      np.array_equal(getattr(dres["pareto"], c), getattr(front, c))
      and np.array_equal(getattr(dres["top"], c), getattr(top, c))
      for c in metric_cols)
  transfer_rows = int(dres.meta["rows_transferred"])

  # device parity on a one-shot sub-block: exact x64 means identically 0
  sub_hw = space.sample_type_table(space.pe_types[0],
                                   min(n_hw_per_type, 200), seed=3)
  from repro.core.dataflow import LayerStack
  from repro.core.supernet import arch_to_layers
  par_stack = LayerStack.from_layer_lists(
      [arch_to_layers(a, image_size=16) for a in archs[:8]])
  f_np = VectorOracleBackend().co_evaluate_table(sub_hw, par_stack)
  f_dev = VectorOracleBackend(chunk_size=chunk_size,
                              jit=True).co_evaluate_table(sub_hw, par_stack)
  parity = max(float(np.max(np.abs(getattr(f_dev, c) / getattr(f_np, c)
                                   - 1.0))) for c in metric_cols)

  # phase 2: parallel vs serial chunk loop on a sub-sweep (best of 3
  # interleaved runs per mode — this box's wall clock is noisy; speedup
  # scales with cores up to the default min(8, cpu_count) pool width)
  sub = arch_accs[:max(n_archs // 10, 4)]
  sub_chunk = min(chunk_size, 65536)

  def timed_sub(w):
    t0 = time.perf_counter()
    r = session.co_explore(sub, n_hw_per_type=n_hw_per_type, seed=3,
                           image_size=16, stream=True,
                           reducers={"pareto": ParetoAccumulator(cols)},
                           chunk_size=sub_chunk, workers=w)
    return time.perf_counter() - t0, r

  ser_runs, par_runs = [], []
  for _ in range(3):  # interleaved so both modes see the same machine state
    ser_runs.append(timed_sub(1))
    par_runs.append(timed_sub(None))
  serial_s, r_ser = min(ser_runs, key=lambda t_r: t_r[0])
  par_s, r_par = min(par_runs, key=lambda t_r: t_r[0])
  workers = int(r_par.meta["workers"])

  # phase 3: streaming <-> one-shot bit-identity on a one-shot-sized sweep
  eq_accs = arch_accs[:min(n_archs, 40)]
  eq_hw = min(n_hw_per_type, 50)
  frame = session.co_explore(eq_accs, n_hw_per_type=eq_hw, seed=3,
                             image_size=16)
  r_eq = session.co_explore(eq_accs, n_hw_per_type=eq_hw, seed=3,
                            image_size=16, stream=True,
                            reducers={"pareto": ParetoAccumulator(cols),
                                      "top": TopKAccumulator(
                                          100, by="energy_mj")},
                            chunk_size=977)
  want_front = frame.select(frame.pareto(cols))
  want_top = frame.top_k(100, by="energy_mj")
  metric_cols = ("latency_s", "power_mw", "area_mm2")
  front_ok = all(np.array_equal(getattr(r_eq["pareto"], c),
                                getattr(want_front, c)) for c in metric_cols)
  top_ok = all(np.array_equal(getattr(r_eq["top"], c), getattr(want_top, c))
               for c in metric_cols)

  # phase 4: the block-decomposed N-D front kernel on synthetic 3-D data
  n_nd = 100_000 if smoke else 1_000_000
  obj = np.random.RandomState(1).uniform(size=(n_nd, 3))
  t0 = time.perf_counter()
  nd_mask = pareto_mask(obj)
  nd_s = time.perf_counter() - t0

  record = {
      "n_pairs": int(n_pairs),
      "n_archs": n_archs,
      "n_hw": n_hw_per_type * len(space.pe_types),
      "chunk_size": chunk_size,
      "workers": workers,
      "cpu_count": int(os.cpu_count() or 1),
      "stream_seconds": round(stream_s, 4),
      "stream_pairs_per_sec": round(n_pairs / stream_s, 1),
      "device_stream_seconds": round(device_s, 4),
      "device_stream_pairs_per_sec": round(n_pairs / device_s, 1),
      "device_speedup_vs_numpy_stream": round(stream_s / device_s, 2),
      "device_precision": "x64",
      "device_parity_max_rel_err": parity,
      "device_survivors_bit_identical": bool(dev_identical),
      "device_transfer_rows": transfer_rows,
      "device_transfer_fraction": round(transfer_rows / max(n_pairs, 1), 6),
      "rss_before_mb": round(rss_before, 1),
      "rss_peak_mb": round(rss_peak, 1),
      "pareto_axes": list(cols),
      "pareto_front_size": int(len(front)),
      "top_k": 100,
      "serial_sub_pairs": int(r_ser.n_rows),
      "serial_pairs_per_sec": round(r_ser.n_rows / serial_s, 1),
      "parallel_pairs_per_sec": round(r_par.n_rows / par_s, 1),
      "parallel_speedup": round(serial_s / par_s, 2),
      "equivalence_pairs": int(len(frame)),
      "pareto_bit_identical": bool(front_ok),
      "topk_bit_identical": bool(top_ok),
      "pareto3d_points": n_nd,
      "pareto3d_seconds": round(nd_s, 4),
      "pareto3d_front_size": int(nd_mask.sum()),
      # failure accounting (explore/resilience.py): a healthy canonical
      # run records zeros; nonzero values mean the rates above include
      # retried/demoted/resumed chunks and are not comparable
      "n_retries": int(res.meta["n_retries"]),
      "n_demotions": int(res.meta["n_demotions"]),
      "n_resumed_chunks": int(res.meta["n_resumed_chunks"]),
      "n_overflows": int(res.meta["n_overflows"]),
  }
  # smoke runs land in their own record so reproducing the CI command
  # locally never clobbers the canonical full-scale tentpole evidence
  path = write_bench_json("streaming_smoke" if smoke else "streaming",
                          record)
  emit("streaming_perf", stream_s / max(n_pairs, 1) * 1e6,
       f"pairs={n_pairs};stream_pairs_per_s={n_pairs / stream_s:.0f};"
       f"device_pairs_per_s={n_pairs / device_s:.0f};"
       f"device_speedup={stream_s / device_s:.2f}x;"
       f"device_parity={parity:.1e};"
       f"device_transfer_frac={transfer_rows / max(n_pairs, 1):.5f};"
       f"rss_peak_mb={rss_peak:.0f};parallel_speedup="
       f"{serial_s / par_s:.2f}x;front={len(front)};top_identical={top_ok};"
       f"front_identical={front_ok};pareto3d_s={nd_s:.3f};json={path}")
  if not (front_ok and top_ok):
    raise AssertionError("streaming survivors diverged from one-shot path")
  if not dev_identical:
    raise AssertionError("device fused survivors diverged from numpy "
                         "streaming path")
  if parity != 0.0:
    raise AssertionError(f"x64 device parity broken: {parity}")


def resilience_perf() -> None:
  """The fault-tolerance claims, measured: (a) kill-and-resume — a
  streamed co-exploration killed mid-sweep by an injected FaultPlan
  resumes from its journal, skipping the already-folded chunks, with
  bit-identical survivors; (b) graceful degradation — seeded transient
  device faults heal by retry/ladder with unchanged results; both with
  retry/demotion/resume accounting and overheads recorded to
  results/BENCH_resilience.json.  RESILIENCE_BENCH_SCALE=smoke (CI)
  shrinks the sweep while still exercising every path."""
  import os
  import tempfile

  from benchmarks.common import write_bench_json
  from repro.core.cnn import SEARCH_SPACE, ArchChoice
  from repro.explore import (ChunkError, DesignSpace, ExplorationSession,
                             Fault, FaultPlan, ParetoAccumulator,
                             ResiliencePolicy, RetryPolicy,
                             TopKAccumulator, VectorOracleBackend)

  smoke = os.environ.get("RESILIENCE_BENCH_SCALE") == "smoke"
  n_archs = 16 if smoke else 200
  n_hw_per_type = 20 if smoke else 500
  chunk_size = 4096 if smoke else 65536
  cols = ("top1_err", "energy_mj", "area_mm2")
  metric_cols = ("latency_s", "power_mw", "area_mm2")

  rng = np.random.RandomState(0)
  archs = [ArchChoice(tuple((int(rng.choice(reps)), int(rng.choice(chs)))
                            for reps, chs in SEARCH_SPACE))
           for _ in range(n_archs)]
  arch_accs = list(zip(archs, rng.uniform(0.5, 0.95, size=n_archs)))
  space = DesignSpace()
  session = ExplorationSession(VectorOracleBackend(chunk_size=chunk_size),
                               space)

  def sweep(**kw):
    return session.co_explore(
        arch_accs, n_hw_per_type=n_hw_per_type, seed=3, image_size=16,
        stream=True, chunk_size=chunk_size,
        reducers={"pareto": ParetoAccumulator(cols),
                  "top": TopKAccumulator(50, by="energy_mj")}, **kw)

  t0 = time.perf_counter()
  ref = sweep()
  healthy_s = time.perf_counter() - t0
  n_chunks = int(ref.meta["n_chunks"])

  def identical(res) -> bool:
    return all(
        np.array_equal(getattr(res["pareto"], c), getattr(ref["pareto"], c))
        and np.array_equal(getattr(res["top"], c), getattr(ref["top"], c))
        for c in metric_cols)

  # (a) kill mid-sweep, resume from the journal
  kill_at = n_chunks // 2
  with tempfile.TemporaryDirectory() as jdir:
    pol = ResiliencePolicy(
        retry=RetryPolicy(sleep=lambda s: None),
        fault_plan=FaultPlan([Fault("kill", kill_at, "task")]))
    killed_index = -1
    try:
      sweep(policy=pol, resume_from=jdir)
    except ChunkError as e:
      killed_index = e.chunk_index
    t0 = time.perf_counter()
    resumed = sweep(resume_from=jdir)
    resume_s = time.perf_counter() - t0
  resume_identical = identical(resumed)
  n_resumed = int(resumed.meta["n_resumed_chunks"])

  # (b) seeded transient faults healed by retry (no wall-waiting)
  plan = FaultPlan.seeded(7, n_chunks, p_raise=0.5, layer="task")
  pol = ResiliencePolicy(retry=RetryPolicy(sleep=lambda s: None),
                         fault_plan=plan)
  t0 = time.perf_counter()
  healed = sweep(policy=pol)
  faulty_s = time.perf_counter() - t0
  healed_identical = identical(healed)

  record = {
      "n_pairs": int(ref.n_rows),
      "n_chunks": n_chunks,
      "healthy_seconds": round(healthy_s, 4),
      "kill_at_chunk": kill_at,
      "killed_chunk_index": killed_index,
      "n_resumed_chunks": n_resumed,
      "resume_seconds": round(resume_s, 4),
      "resume_fraction_of_healthy": round(resume_s / max(healthy_s, 1e-9),
                                          3),
      "resume_bit_identical": bool(resume_identical),
      "injected_faults": len(plan.faults),
      "faults_fired": int(plan.n_fired),
      "n_retries": int(healed.meta["n_retries"]),
      "n_demotions": int(healed.meta["n_demotions"]),
      "faulty_seconds": round(faulty_s, 4),
      "retry_overhead": round(faulty_s / max(healthy_s, 1e-9), 3),
      "healed_bit_identical": bool(healed_identical),
  }
  path = write_bench_json("resilience_smoke" if smoke else "resilience",
                          record)
  emit("resilience_perf", healthy_s / max(ref.n_rows, 1) * 1e6,
       f"chunks={n_chunks};killed_at={killed_index};resumed={n_resumed};"
       f"resume_identical={resume_identical};"
       f"retries={record['n_retries']};"
       f"healed_identical={healed_identical};json={path}")
  if killed_index != kill_at:
    raise AssertionError(
        f"injected kill surfaced chunk {killed_index}, wanted {kill_at}")
  if not resume_identical:
    raise AssertionError("resumed survivors diverged from healthy run")
  if not healed_identical:
    raise AssertionError("retry-healed survivors diverged from healthy run")


ALL = [kernel_codecs, train_step_small_lm, serve_engine_throughput,
       explore_api_perf, explore_vector_perf, coexplore_vector_perf,
       streaming_perf, resilience_perf]
