"""Benchmark harness utilities: timing, the name,us_per_call,derived CSV,
and JSON perf records under ``results/`` (BENCH_*.json) so the performance
trajectory is tracked across PRs."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Optional

# where write_bench_json lands records; benchmarks.run --json-dir overrides
JSON_DIR = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                        "results")


def time_call(fn: Callable, n: int = 3, warmup: int = 1) -> float:
  """Mean wall-time per call in microseconds."""
  for _ in range(warmup):
    fn()
  t0 = time.perf_counter()
  for _ in range(n):
    fn()
  return (time.perf_counter() - t0) / n * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
  print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def bench_provenance() -> Dict[str, object]:
  """Reproducibility stamp shared by every BENCH_*.json record: commit,
  UTC timestamp, library versions, core count, and the jax device kind
  the numbers were measured on."""
  import datetime
  import subprocess

  import numpy as np
  try:
    commit = subprocess.run(
        ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
        text=True, timeout=10,
        cwd=os.path.dirname(os.path.abspath(__file__))).stdout.strip()
  except Exception:  # noqa: BLE001 - provenance must never fail a bench
    commit = ""
  prov: Dict[str, object] = {
      "git_commit": commit or "unknown",
      "timestamp_utc": datetime.datetime.now(datetime.timezone.utc)
      .strftime("%Y-%m-%dT%H:%M:%SZ"),
      "numpy_version": np.__version__,
      "cpu_count": int(os.cpu_count() or 1),
  }
  try:
    import jax

    from repro.explore.fleet import device_topology
    prov["jax_version"] = jax.__version__
    topo = device_topology()
    prov["jax_device_kind"] = (topo["device_kinds"] or ["none"])[0]
    prov["device_topology"] = topo
  except Exception:  # noqa: BLE001 - jax is optional for numpy-only runs
    prov["jax_version"] = "unavailable"
    prov["jax_device_kind"] = "none"
    prov["device_topology"] = {"platform": "none", "n_devices": 0,
                               "device_kinds": []}
  return prov


def write_bench_json(name: str, record: Dict) -> str:
  """Write ``results/BENCH_<name>.json`` (pretty, stable key order),
  stamped with :func:`bench_provenance`."""
  os.makedirs(JSON_DIR, exist_ok=True)
  path = os.path.join(JSON_DIR, f"BENCH_{name}.json")
  record = dict(record)
  record.setdefault("provenance", bench_provenance())
  with open(path, "w") as f:
    json.dump(record, f, indent=2, sort_keys=True)
    f.write("\n")
  return path
