"""Benchmark harness utilities: timing, the name,us_per_call,derived CSV,
and JSON perf records under ``results/`` (BENCH_*.json) so the performance
trajectory is tracked across PRs."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Optional

# where write_bench_json lands records; benchmarks.run --json-dir overrides
JSON_DIR = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                        "results")


def time_call(fn: Callable, n: int = 3, warmup: int = 1) -> float:
  """Mean wall-time per call in microseconds."""
  for _ in range(warmup):
    fn()
  t0 = time.perf_counter()
  for _ in range(n):
    fn()
  return (time.perf_counter() - t0) / n * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
  print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def write_bench_json(name: str, record: Dict) -> str:
  """Write ``results/BENCH_<name>.json`` (pretty, stable key order)."""
  os.makedirs(JSON_DIR, exist_ok=True)
  path = os.path.join(JSON_DIR, f"BENCH_{name}.json")
  with open(path, "w") as f:
    json.dump(record, f, indent=2, sort_keys=True)
    f.write("\n")
  return path
