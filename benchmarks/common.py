"""Benchmark harness utilities: timing + the name,us_per_call,derived CSV."""
from __future__ import annotations

import time
from typing import Callable, Optional


def time_call(fn: Callable, n: int = 3, warmup: int = 1) -> float:
  """Mean wall-time per call in microseconds."""
  for _ in range(warmup):
    fn()
  t0 = time.perf_counter()
  for _ in range(n):
    fn()
  return (time.perf_counter() - t0) / n * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
  print(f"{name},{us_per_call:.1f},{derived}", flush=True)
