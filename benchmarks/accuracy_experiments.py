"""Accuracy-side benchmarks: Table 2 / Figs 10-12 (QAT + co-exploration).

These train small CNNs on the procedural cifar_like dataset (CPU budget);
scale is reduced vs the paper (documented in EXPERIMENTS.md) but the
comparisons are like-for-like across PE types, which is what the paper's
claims are about.  Budgets are kept small so `python -m benchmarks.run`
finishes; examples/coexplore_cnn.py runs the bigger version.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import cnn
from repro.core.pe import PAPER_PE_TYPES
from repro.explore import pareto_mask
from repro.data.synthetic import CifarLike, CifarLikeConfig
from repro.train import optimizer as opt_lib

_STEPS = 120
_BATCH = 64
_IMG = 16


def _train_qat(model_kind: str, pe_type: str, seed: int = 0,
               steps: int = _STEPS) -> float:
  data = CifarLike(CifarLikeConfig(n_classes=10, image_size=_IMG,
                                   seed=seed))
  key = jax.random.PRNGKey(seed)
  if model_kind == "vgg":
    params = cnn.init_vgg_supernet(key, 10)
    r_use, c_use = cnn.arch_masks(cnn.max_arch())
    fwd = functools.partial(cnn.apply_vgg, pe_type=pe_type,
                            r_use=r_use, c_use=c_use)
  else:
    depth = int(model_kind.replace("resnet", ""))
    params = cnn.init_resnet(key, depth, 10, width=8)
    fwd = functools.partial(cnn.apply_resnet, depth=depth, pe_type=pe_type)

  def loss_fn(p, x, y):
    return cnn.xent(fwd(p, x), y)

  grad = jax.jit(jax.value_and_grad(loss_fn))
  ocfg = opt_lib.SGDConfig(lr=0.05, steps_per_epoch=40, drops=(2, 3))
  opt = opt_lib.sgd_init(params)
  for step in range(steps):
    x, y = data.sample(_BATCH, split_seed=step)
    _, g = grad(params, jnp.asarray(x), jnp.asarray(y))
    params, opt, _ = opt_lib.sgd_update(ocfg, params, g, opt)
  xv, yv = data.sample(512, split_seed=10_000_019)
  logits = jax.jit(fwd)(params, jnp.asarray(xv))
  return float(cnn.accuracy(logits, jnp.asarray(yv)))


def table2_accuracy() -> None:
  """Table 2 (accuracy columns): QAT top-1 per PE type per network."""
  rows = []
  t0 = time.perf_counter()
  for model_kind in ("resnet20",):
    for pe_type in PAPER_PE_TYPES:
      acc = _train_qat(model_kind, pe_type)
      rows.append(f"{model_kind}/{pe_type}={acc:.3f}")
  us = (time.perf_counter() - t0) * 1e6
  emit("table2_accuracy", us,
       ";".join(rows) + ";paper_claim=on_par_across_types")


def fig10_11_pareto_fronts() -> None:
  """Figs 10-11: accuracy vs perf-per-area / energy Pareto fronts."""
  from benchmarks.paper_figures import _session
  from repro.core.workloads import get_network
  t0 = time.perf_counter()
  accs = {t: _train_qat("resnet20", t, steps=_STEPS)
          for t in PAPER_PE_TYPES}
  sess = _session()
  layers = get_network("resnet20")
  frame = sess.explore(layers, "resnet20", n_per_type=150)
  ppa_n, en_n = frame.normalize(ref="best-int16")
  pts = []
  for t in PAPER_PE_TYPES:
    m = frame.by_type(t)
    pts.append((t, accs[t], float(ppa_n[m].max()), float(en_n[m].min())))
  err = np.asarray([1 - a for (_, a, _, _) in pts])
  inv_ppa = np.asarray([1.0 / p for (_, _, p, _) in pts])
  en = np.asarray([e for (_, _, _, e) in pts])
  front_ppa = pareto_mask(np.stack([err, inv_ppa], 1))
  front_en = pareto_mask(np.stack([err, en], 1))
  on_front_ppa = [pts[i][0] for i in range(len(pts)) if front_ppa[i]]
  on_front_en = [pts[i][0] for i in range(len(pts)) if front_en[i]]
  us = (time.perf_counter() - t0) * 1e6
  emit("fig10_11_pareto_fronts", us,
       ";".join(f"{t}:acc={a:.3f},ppa={p:.2f}x,energy={e:.3f}x"
                for (t, a, p, e) in pts)
       + f";front_ppa={'/'.join(on_front_ppa)}"
       + f";front_energy={'/'.join(on_front_en)}"
       + ";paper_claim=LightPEs_on_front")


def fig12_coexploration() -> None:
  """Fig 12: joint HW x NN co-exploration fronts (supernet proxy)."""
  from benchmarks.paper_figures import _session
  from repro.core.supernet import Supernet, SupernetConfig
  t0 = time.perf_counter()
  sn = Supernet(SupernetConfig(steps=80, batch=32, image_size=_IMG))
  sn.train(log_every=0)
  arch_accs = sn.sample_and_evaluate(n_archs=12, n_val=256)
  sess = _session()
  frame = sess.co_explore(arch_accs, n_hw_per_type=8)
  front = frame.pareto(cols=("top1_err", "energy_mj"))
  on_front = set(str(t) for t in frame.pe_type[front])
  us = (time.perf_counter() - t0) * 1e6
  emit("fig12_coexploration", us,
       f"pairs={len(frame)};front_energy_types={'/'.join(sorted(on_front))};"
       f"acc_range={min(a for _, a in arch_accs):.3f}-"
       f"{max(a for _, a in arch_accs):.3f};"
       f"paper_claim=LightPEs_on_joint_front")


ALL = [table2_accuracy, fig10_11_pareto_fronts, fig12_coexploration]


def lm_qat_ablation() -> None:
  """Beyond-paper: QUIDAM's PE-type axis on a LANGUAGE model.

  Trains the same reduced olmo-family LM under each PE type's QAT policy
  on the Markov stream and reports final train loss — the LM analogue of
  Table 2's on-par-accuracy claim.
  """
  from repro.configs import get_config, reduce_for_smoke
  from repro.data.synthetic import MarkovTokenStream, TokenStreamConfig
  from repro.models.model import build_model
  from repro.quant.policy import QuantPolicy
  from repro.train import optimizer as opt_lib
  from repro.train import train_step as ts_lib

  t0 = time.perf_counter()
  cfg = reduce_for_smoke(get_config("olmo-1b"), d_model=128, n_layers=4,
                         d_ff=256, vocab_size=2048)
  stream = MarkovTokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                               branching=4))
  model = build_model(cfg)
  rows = []
  for pe_type in PAPER_PE_TYPES:
    tcfg = ts_lib.TrainConfig(
        optimizer=opt_lib.AdamWConfig(lr=3e-3, warmup_steps=0,
                                      schedule="constant",
                                      weight_decay=0.0),
        quant=QuantPolicy(pe_type=pe_type))
    state = ts_lib.make_train_state(model, tcfg, jax.random.PRNGKey(0))
    step = ts_lib.jit_train_step(model, tcfg, donate=False)
    losses = []
    for i in range(60):
      toks, labels = stream.sample_batch(8, 64, i)
      state, m = step(state, {"tokens": jnp.asarray(toks),
                              "labels": jnp.asarray(labels)})
      losses.append(float(m["loss"]))
    rows.append(f"{pe_type}={np.mean(losses[-10:]):.3f}")
  us = (time.perf_counter() - t0) * 1e6
  emit("lm_qat_ablation", us,
       "final_loss:" + ";".join(rows)
       + ";extension=paper_claim_generalizes_to_LMs")


ALL = ALL + [lm_qat_ablation]
