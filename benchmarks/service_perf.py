"""Exploration-service perf record: store-hit and delta-sweep
amortization on an overlapping-query workload, plus a concurrent-session
chaos check.

The service's value proposition is that overlapping exploration requests
stop paying for evaluation: an identical resubmission is a store hit
(restore, no evaluation), and a one-axis-edited full-grid sweep is a
delta-sweep (only the new subgrid runs).  This benchmark measures both
against full recomputation on a ~1M-pair grid workload and asserts the
amortized paths stay bit-identical and >= 5x faster.  Results land in
``results/BENCH_service.json``; SERVICE_BENCH_SCALE=smoke (CI) shrinks
the grid while still exercising every phase.
"""
from __future__ import annotations

import time

import numpy as np


def service_perf() -> None:
  import os
  import tempfile

  from benchmarks.common import emit, write_bench_json
  from repro.core.workloads import get_network
  from repro.explore import (CircuitBreaker, DesignSpace,
                             ExplorationService, FaultPlan,
                             ParetoAccumulator, RetryPolicy,
                             TopKAccumulator, VectorOracleBackend,
                             stream_explore)
  from repro.explore.space import AXIS_ORDER, HW_RANGES

  smoke = os.environ.get("SERVICE_BENCH_SCALE") == "smoke"
  # grid sized so the edited space is ~1M design points at full scale
  # (the "overlapping queries over a 1M-pair workload" claim); pe_rows
  # is the edited axis — base takes n-1 of its values, the edit adds
  # the last one, so the delta subgrid is ~1/8 of the base grid
  if smoke:
    take = {"pe_rows": 3, "pe_cols": 3, "sp_if": 2, "sp_fw": 2,
            "sp_ps": 2, "gbuf_kb": 1, "bandwidth_gbps": 1}
  else:
    take = {"pe_rows": 8, "pe_cols": 9, "sp_if": 8, "sp_fw": 8,
            "sp_ps": 7, "gbuf_kb": 7, "bandwidth_gbps": 1}
  axes = {name: HW_RANGES[name][:take[name]] for name in AXIS_ORDER}
  base_space = DesignSpace(axes=axes)
  edited_axes = dict(axes)
  edited_axes["pe_rows"] = HW_RANGES["pe_rows"][:take["pe_rows"] + 1]
  edited_space = DesignSpace(axes=edited_axes)

  chunk_size = 512 if smoke else 65536
  layers = get_network("resnet20")[:4]
  metric_cols = ("latency_s", "power_mw", "area_mm2")

  def reducers():
    return {"pareto": ParetoAccumulator(("latency_s", "power_mw")),
            "top": TopKAccumulator(50, by="power_mw")}

  def identical(got, want) -> bool:
    return all(
        np.array_equal(getattr(got["pareto"], c), getattr(want["pareto"], c))
        and np.array_equal(getattr(got["top"], c), getattr(want["top"], c))
        for c in metric_cols)

  def backend():
    return VectorOracleBackend(chunk_size=chunk_size)

  def grid_submit(svc, space):
    return svc.submit_explore(space, layers, "resnet20",
                              n_per_type=space.per_type_grid_size(),
                              method="grid", chunk_size=chunk_size,
                              reducers=reducers())

  with tempfile.TemporaryDirectory() as sdir:
    svc = ExplorationService(backend(), slots=2, store=sdir)

    # phase 1: cold full-grid sweep (populates the store)
    t0 = time.perf_counter()
    h_cold = grid_submit(svc, base_space)
    svc.drain()
    cold = h_cold.result()
    cold_s = time.perf_counter() - t0

    # phase 2: identical resubmission -> store hit, no evaluation
    t0 = time.perf_counter()
    h_hit = grid_submit(svc, base_space)
    hit = h_hit.result()
    hit_s = time.perf_counter() - t0
    hit_identical = identical(hit, cold)
    store_hit = hit.meta.get("store_hit") == 1.0

    # phase 3: one-axis edit -> delta-sweep over just the new subgrid
    t0 = time.perf_counter()
    h_delta = grid_submit(svc, edited_space)
    svc.drain()
    delta = h_delta.result()
    delta_s = time.perf_counter() - t0
    delta_ran = delta.meta.get("delta_sweep") == 1.0

    # the honest baseline: the same edited space from scratch
    t0 = time.perf_counter()
    scratch = stream_explore(backend(), edited_space, layers,
                             network="resnet20",
                             n_per_type=edited_space.per_type_grid_size(),
                             method="grid", reducers=reducers(),
                             chunk_size=chunk_size)
    scratch_s = time.perf_counter() - t0
    delta_identical = identical(delta, scratch) \
        and delta.n_rows == scratch.n_rows
    service_stats = svc.service_meta()

  # phase 4: chaos mini-run — concurrent sessions under injected faults
  # (and a sick-device breaker) still match solo healthy runs
  space = DesignSpace()
  n_rand = 500 if smoke else 5000
  refs = {s: stream_explore(backend(), space, layers, network="resnet20",
                            n_per_type=n_rand, seed=s,
                            reducers=reducers(), chunk_size=chunk_size)
          for s in (1, 2)}
  plan = FaultPlan.seeded(seed=5, n_chunks=16, p_raise=0.5, layer="task",
                          times=2)
  chaos = ExplorationService(backend(), slots=2,
                             retry=RetryPolicy(sleep=lambda s: None),
                             fault_plan=plan,
                             breaker=CircuitBreaker(threshold=2))
  t0 = time.perf_counter()
  handles = {s: chaos.submit_explore(space, layers, "resnet20",
                                     n_per_type=n_rand, seed=s,
                                     chunk_size=chunk_size,
                                     reducers=reducers())
             for s in (1, 2)}
  chaos.drain()
  chaos_s = time.perf_counter() - t0
  chaos_identical = all(identical(handles[s].result(), refs[s])
                        for s in (1, 2))

  hit_speedup = cold_s / max(hit_s, 1e-9)
  delta_speedup = scratch_s / max(delta_s, 1e-9)
  record = {
      "n_pairs": int(scratch.n_rows),
      "base_rows": int(cold.n_rows),
      "delta_rows": int(delta.meta.get("n_delta_rows", 0)),
      "cold_seconds": round(cold_s, 4),
      "store_hit_seconds": round(hit_s, 4),
      "store_hit_speedup": round(hit_speedup, 2),
      "store_hit_taken": bool(store_hit),
      "store_hit_bit_identical": bool(hit_identical),
      "delta_seconds": round(delta_s, 4),
      "scratch_seconds": round(scratch_s, 4),
      "delta_speedup": round(delta_speedup, 2),
      "delta_sweep_taken": bool(delta_ran),
      "delta_bit_identical": bool(delta_identical),
      "chaos_sessions": 2,
      "chaos_seconds": round(chaos_s, 4),
      "chaos_faults_fired": int(plan.n_fired),
      "chaos_bit_identical": bool(chaos_identical),
      "service": {k: v for k, v in service_stats.items()
                  if isinstance(v, (int, float))},
  }
  path = write_bench_json("service_smoke" if smoke else "service", record)
  emit("service_perf", cold_s / max(cold.n_rows, 1) * 1e6,
       f"pairs={record['n_pairs']};hit_x={record['store_hit_speedup']};"
       f"delta_x={record['delta_speedup']};"
       f"delta_identical={delta_identical};"
       f"chaos_identical={chaos_identical};json={path}")
  if not (store_hit and hit_identical):
    raise AssertionError("store hit missing or diverged from cold sweep")
  if not (delta_ran and delta_identical):
    raise AssertionError("delta-sweep missing or diverged from scratch")
  if not chaos_identical:
    raise AssertionError("chaos sessions diverged from solo healthy runs")
  if not smoke and (hit_speedup < 5.0 or delta_speedup < 5.0):
    raise AssertionError(
        f"amortization regressed: hit {hit_speedup:.1f}x, "
        f"delta {delta_speedup:.1f}x (need >= 5x)")


ALL = [service_perf]
