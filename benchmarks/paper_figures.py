"""Benchmarks reproducing every QUIDAM table/figure (one function each).

Each function prints `name,us_per_call,derived` rows (benchmarks.common)
where `derived` carries the quantities the paper reports, so
EXPERIMENTS.md can cite them directly.

All exploration runs through ``repro.explore``: one ExplorationSession
over a PolynomialBackend whose fit is cached on disk (fit-once across
benchmark runs, never refit unless the fit spec changes).
"""
from __future__ import annotations

import os
import time
from typing import Optional

import numpy as np

from benchmarks.common import emit
from repro.core import oracle, ppa
from repro.core.dataflow import AcceleratorConfig
from repro.core.pe import PAPER_PE_TYPES
from repro.core.workloads import get_network
from repro.explore import (DesignSpace, ExplorationSession, OracleBackend,
                           PolynomialBackend, summary_stats)

_CACHE_PATH = os.environ.get(
    "QUIDAM_PPA_CACHE", os.path.join("results", "cache", "ppa_models.npz"))
_SESSION: Optional[ExplorationSession] = None


def _session() -> ExplorationSession:
  """Shared session: degree-5 models trained across workload families so
  DSE never extrapolates; fitted once, persisted to _CACHE_PATH."""
  global _SESSION
  if _SESSION is None:
    layers = get_network("resnet20") + get_network("vgg16")
    t0 = time.perf_counter()
    backend = PolynomialBackend.fit_or_load(
        _CACHE_PATH, degree=5, n_train=240, layers=layers)
    cache = "hit" if backend.loaded_from else "miss"
    emit("fit_ppa_models[all]", (time.perf_counter() - t0) * 1e6,
         f"degree=5;n_train=240;per_pe_type=4;cache={cache}")
    _SESSION = ExplorationSession(backend, DesignSpace())
  return _SESSION


def fig4_dse_scatter() -> None:
  """Fig 4: perf/area vs energy spread across PE types/configs."""
  sess = _session()
  layers = get_network("resnet20")
  t0 = time.perf_counter()
  frame = sess.explore(layers, "resnet20", n_per_type=250)
  us = (time.perf_counter() - t0) * 1e6
  ppa_n, en_n = frame.normalize(ref="best-int16")
  emit("fig4_dse_scatter", us,
       f"n={len(frame)};perf_area_spread={ppa_n.max()/ppa_n.min():.1f}x;"
       f"energy_spread={en_n.max()/en_n.min():.1f}x;"
       f"paper=5x_and_35x_plus")


def fig5_degree_selection() -> None:
  """Fig 5: k-fold-CV MAPE/RMSPE vs polynomial degree (power+area)."""
  space = DesignSpace(pe_types=("INT16",))
  cfgs = space.sample_type("INT16", 400, seed=0)
  x, p, a = ppa.power_area_dataset(cfgs)
  t0 = time.perf_counter()
  best_p, scores_p = ppa.select_degree(x, p, degrees=range(1, 9))
  best_a, scores_a = ppa.select_degree(x, a, degrees=range(1, 9))
  us = (time.perf_counter() - t0) * 1e6
  curve = ";".join(f"d{d}={scores_p[d][0]:.2f}/{scores_p[d][1]:.2f}"
                   for d in sorted(scores_p))
  emit("fig5_degree_selection", us,
       f"best_power_degree={best_p};best_area_degree={best_a};"
       f"paper_degree=5;power_mape/rmspe_curve:{curve}")


def fig6_8_ppa_accuracy() -> None:
  """Figs 6-8: model-vs-oracle accuracy per PE type (held-out configs)."""
  layers = get_network("resnet20")
  space = DesignSpace()
  for pe_type in PAPER_PE_TYPES:
    backend = PolynomialBackend.fit(pe_types=(pe_type,), degree=5,
                                    n_train=240, layers=layers, seed=7)
    models = backend.models[pe_type]
    test = space.sample_type(pe_type, 120, seed=991)
    xt, pt, at = ppa.power_area_dataset(test)
    t0 = time.perf_counter()
    p_hat = models.power.predict(xt)
    a_hat = models.area.predict(xt)
    lat_hat = models.predict_network_latency_s(test, layers)
    us = (time.perf_counter() - t0) * 1e6
    lat_true = np.asarray(
        [oracle.characterize(c, layers).latency_s for c in test])
    emit(f"fig6_8_ppa_accuracy[{pe_type}]", us,
         f"power_mape={ppa.mape(pt, p_hat):.2f}%;"
         f"area_mape={ppa.mape(at, a_hat):.2f}%;"
         f"latency_mape={ppa.mape(lat_true, lat_hat):.2f}%;"
         f"power_r2={ppa.r2(pt, p_hat):.4f};"
         f"latency_r2={ppa.r2(np.log(lat_true), np.log(np.maximum(lat_hat, 1e-12))):.4f}")


def fig9_pe_distributions() -> None:
  """Fig 9: normalized perf/area + energy distributions per PE type."""
  sess = _session()
  nets = ("vgg16", "resnet20", "resnet56")
  rows = []
  t0 = time.perf_counter()
  for net in nets:
    layers = get_network(net)
    frame = sess.explore(layers, net, n_per_type=150)
    ppa_n, en_n = frame.normalize(ref="best-int16")
    for t in PAPER_PE_TYPES:
      m = frame.by_type(t)
      s1 = summary_stats(ppa_n[m])
      s2 = summary_stats(en_n[m])
      rows.append(f"{net}/{t}:ppa_med={s1['median']:.2f},max={s1['max']:.2f}"
                  f",energy_med={s2['median']:.3f},min={s2['min']:.3f}")
  us = (time.perf_counter() - t0) * 1e6
  emit("fig9_pe_distributions", us, ";".join(rows))


def table3_clock() -> None:
  """Table 3: clock per PE type (paper: 275/285/435/455 MHz)."""
  t0 = time.perf_counter()
  clocks = {t: oracle.clock_mhz(AcceleratorConfig(pe_type=t))
            for t in PAPER_PE_TYPES}
  us = (time.perf_counter() - t0) * 1e6
  emit("table3_clock", us,
       ";".join(f"{t}={clocks[t]:.0f}MHz" for t in PAPER_PE_TYPES)
       + ";paper=275/285/455/435")


def table2_pareto_hw() -> None:
  """Table 2 (hardware columns): best perf/area + energy per PE type."""
  sess = _session()
  rows = []
  t0 = time.perf_counter()
  for net in ("vgg16", "resnet20", "resnet56"):
    layers = get_network(net)
    frame = sess.explore(layers, net, n_per_type=250)
    ppa_n, en_n = frame.normalize(ref="best-int16")
    for t in PAPER_PE_TYPES:
      m = frame.by_type(t)
      rows.append(f"{net}/{t}:ppa={ppa_n[m].max():.2f}x,"
                  f"energy={en_n[m].min():.3f}x")
  us = (time.perf_counter() - t0) * 1e6
  emit("table2_pareto_hw", us, ";".join(rows)
       + ";paper_vgg16=5.7x/0.18x_LP1,4.9x/0.20x_LP2")


def speedup_dse() -> None:
  """Sec 4.1: characterization-replacement speedup at DSE scale.

  The paper's baseline is SYNTHESIS (hours-days per design); our ground
  truth is already a fast analytical simulator, so we report all three
  timings with clear semantics: model µs/design, simulator µs/design, and
  the model-vs-synthesis ratio under a documented 4 h/design assumption
  (conservative: DC + VCS on these designs is typically longer).
  """
  sess = _session()
  layers = get_network("resnet20")
  cfgs = []
  for i, t in enumerate(PAPER_PE_TYPES):
    cfgs += sess.space.sample_type(t, 500, seed=31 + i)
  t0 = time.perf_counter()
  sess.evaluate(cfgs, layers, "resnet20")
  t_model = time.perf_counter() - t0
  t1 = time.perf_counter()
  OracleBackend().evaluate(cfgs[:20], layers, "resnet20")
  t_oracle = (time.perf_counter() - t1) / 20
  synth_hours = 4.0
  vs_synth = synth_hours * 3600 / (t_model / len(cfgs))
  emit("speedup_dse", t_model / len(cfgs) * 1e6,
       f"model_us_per_design={t_model / len(cfgs) * 1e6:.0f};"
       f"analytic_simulator_us_per_design={t_oracle * 1e6:.0f};"
       f"model_vs_synthesis@{synth_hours}h/design={vs_synth:.1e}x;"
       f"paper_claim=3-4_orders_vs_synthesis")


ALL = [fig4_dse_scatter, fig5_degree_selection, fig6_8_ppa_accuracy,
       fig9_pe_distributions, table2_pareto_hw, table3_clock, speedup_dse]
