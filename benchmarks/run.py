"""Benchmark runner: one function per paper table/figure + framework perf.

Prints ``name,us_per_call,derived`` CSV rows; benchmarks that track the
perf trajectory additionally write ``BENCH_*.json`` records (default
under ``results/``, see --json-dir), each stamped with provenance
(git commit, UTC timestamp, numpy/jax versions, cpu count, jax device
kind — see ``benchmarks.common.bench_provenance``) — e.g.
``BENCH_explore.json`` with scalar-vs-vector sweep points/sec and the
Pareto-front time.
Usage: PYTHONPATH=src python -m benchmarks.run [--suite name]
       [--only substr] [--json-dir DIR]
"""
from __future__ import annotations

import argparse
import sys
import traceback

# suites that run streaming_perf's device-resident phase and therefore
# need the XLA exactness flags set before this process's first jax
# compilation (see repro.explore.device.ensure_exact_cpu_codegen); the
# flags pessimize unrelated jax codegen slightly, so suites without a
# device phase are left untouched to keep their perf records comparable
_DEVICE_SUITES = ("streaming", "framework", "all")


def main() -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--suite", default="all",
                  choices=("paper", "accuracy", "framework", "coexplore",
                           "streaming", "search", "resilience", "service",
                           "fleet", "all"),
                  help="benchmark module to run (default: all); "
                       "'coexplore' runs just the joint-sweep perf record, "
                       "'streaming' the constant-memory sweep-engine record "
                       "(STREAMING_BENCH_SCALE=smoke shrinks it for CI), "
                       "'search' the guided-search front-quality record "
                       "(SEARCH_BENCH_SCALE=smoke shrinks it for CI), "
                       "'resilience' the kill-and-resume / fault-healing "
                       "record (RESILIENCE_BENCH_SCALE=smoke for CI), "
                       "'service' the store-hit / delta-sweep amortization "
                       "record (SERVICE_BENCH_SCALE=smoke for CI), "
                       "'fleet' the multi-device scaling + chaos "
                       "bit-identity record (FLEET_BENCH_SCALE=smoke for "
                       "CI; each point is a child process with its own "
                       "forced XLA host-device count)")
  ap.add_argument("--only", default=None,
                  help="run only benchmarks whose name contains this")
  ap.add_argument("--json-dir", default=None,
                  help="directory for BENCH_*.json perf records "
                       "(default: results/)")
  args = ap.parse_args()
  if args.suite in _DEVICE_SUITES:
    from repro.explore.device import ensure_exact_cpu_codegen
    ensure_exact_cpu_codegen()
  if args.json_dir:
    from benchmarks import common
    common.JSON_DIR = args.json_dir

  from benchmarks import (accuracy_experiments, fleet_perf, framework_perf,
                          paper_figures, search_perf, service_perf)
  suites = {
      "paper": paper_figures.ALL,
      "accuracy": accuracy_experiments.ALL,
      "framework": framework_perf.ALL,
      "coexplore": [framework_perf.coexplore_vector_perf],
      "streaming": [framework_perf.streaming_perf],
      "search": search_perf.ALL,
      "resilience": [framework_perf.resilience_perf],
      "service": service_perf.ALL,
      "fleet": fleet_perf.ALL,
  }
  benches = suites.get(args.suite) or (paper_figures.ALL
                                       + accuracy_experiments.ALL
                                       + framework_perf.ALL
                                       + search_perf.ALL
                                       + service_perf.ALL
                                       + fleet_perf.ALL)
  print("name,us_per_call,derived")
  failures = 0
  for fn in benches:
    if args.only and args.only not in fn.__name__:
      continue
    try:
      fn()
    except Exception as e:  # noqa: BLE001
      failures += 1
      print(f"{fn.__name__},nan,FAILED:{type(e).__name__}:{e}",
            flush=True)
      traceback.print_exc(file=sys.stderr)
  if failures:
    sys.exit(1)


if __name__ == "__main__":
  main()
