"""Guided-search benchmark: front hypervolume vs random sampling.

The PR-7 tentpole claim: NSGA-II-style guided search through the
streaming evaluator finds a strictly better Pareto front than uniform
random sampling at the SAME evaluation budget — measured as exact
hypervolume (minimization, shared reference point from the union of
both fronts) on the QUIDAM joint arch x HW space.  The random baseline
is ``optimize(..., generations=1, population=budget)``: generation 0 of
the optimizer IS uniform constraint-respecting sampling, so both arms
share one code path, one dedup policy, and one seeding discipline.

Also records the surrogate-screened arm and re-runs the guided arm at
the same seed to pin the bit-identity contract in the perf record.
Records results/BENCH_search.json (SEARCH_BENCH_SCALE=smoke shrinks it
for CI into its own BENCH_search_smoke.json record).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, write_bench_json


def _front_matrix(front, objectives):
  from repro.explore.search import objective_matrix
  return objective_matrix(front, objectives)


def search_perf() -> None:
  import os

  from repro.core.cnn import SEARCH_SPACE, ArchChoice
  from repro.explore import (DesignSpace, ExplorationSession,
                             VectorOracleBackend)
  from repro.explore.search import hypervolume

  smoke = os.environ.get("SEARCH_BENCH_SCALE") == "smoke"
  n_archs = 8 if smoke else 24
  population = 16 if smoke else 48
  generations = 6 if smoke else 24
  seed = 7
  objectives = ("top1_err", "energy_mj", "area_mm2")

  rng = np.random.RandomState(0)
  archs = [ArchChoice(tuple((int(rng.choice(reps)), int(rng.choice(chs)))
                            for reps, chs in SEARCH_SPACE))
           for _ in range(n_archs)]
  accs = rng.uniform(0.5, 0.95, size=n_archs)
  arch_accs = list(zip(archs, accs))

  space = DesignSpace()
  session = ExplorationSession(VectorOracleBackend(), space)

  def guided(**kw):
    t0 = time.perf_counter()
    res = session.optimize(arch_accs=arch_accs, objectives=objectives,
                           population=population, generations=generations,
                           seed=seed, **kw)
    return res, time.perf_counter() - t0

  res, guided_s = guided()
  budget = int(res.meta["evaluations"])
  sur, sur_s = guided(surrogate=True)

  # random arm: one generation whose population is the guided arm's
  # realized budget — generation 0 is plain uniform sampling
  t0 = time.perf_counter()
  rand = session.optimize(arch_accs=arch_accs, objectives=objectives,
                          population=budget, generations=1, seed=seed + 1)
  rand_s = time.perf_counter() - t0

  # exact hypervolume under one shared reference: the per-objective max
  # over the union of all fronts, pushed out 10% so boundary points
  # contribute volume in every arm
  mats = {name: _front_matrix(r["pareto"], objectives)
          for name, r in (("guided", res), ("surrogate", sur),
                          ("random", rand))}
  union = np.concatenate(list(mats.values()), axis=0)
  lo, hi = union.min(axis=0), union.max(axis=0)
  ref = hi + 0.1 * np.maximum(hi - lo, 1e-12)
  hv = {name: hypervolume(m, ref) for name, m in mats.items()}
  ratio = hv["guided"] / max(hv["random"], 1e-300)
  sur_ratio = hv["surrogate"] / max(hv["random"], 1e-300)

  # same-seed bit-identity: the whole trajectory replays exactly
  res2, _ = guided()
  front, front2 = res["pareto"], res2["pareto"]
  identical = len(front) == len(front2) and all(
      np.array_equal(front.column(c), front2.column(c))
      for c in objectives + ("latency_s", "power_mw"))

  record = {
      "scale": "smoke" if smoke else "full",
      "space": "quidam-joint",
      "n_archs": n_archs,
      "hw_axes": len(space.axes) + 1,  # + pe_type
      "objectives": list(objectives),
      "population": population,
      "generations": generations,
      "evaluations": budget,
      "random_evaluations": int(rand.meta["evaluations"]),
      "guided_seconds": round(guided_s, 4),
      "surrogate_seconds": round(sur_s, 4),
      "random_seconds": round(rand_s, 4),
      "guided_evals_per_sec": round(budget / guided_s, 1),
      "front_size_guided": int(len(front)),
      "front_size_surrogate": int(len(sur["pareto"])),
      "front_size_random": int(len(rand["pareto"])),
      "hv_ref": [float(r) for r in ref],
      "hv_guided": hv["guided"],
      "hv_surrogate": hv["surrogate"],
      "hv_random": hv["random"],
      "hv_ratio_guided_vs_random": round(ratio, 3),
      "hv_ratio_surrogate_vs_random": round(sur_ratio, 3),
      "same_seed_bit_identical": bool(identical),
  }
  path = write_bench_json("search_smoke" if smoke else "search", record)
  emit("search_perf", guided_s / max(budget, 1) * 1e6,
       f"evals={budget};front={len(front)};hv_ratio={ratio:.2f}x;"
       f"surrogate_hv_ratio={sur_ratio:.2f}x;"
       f"bit_identical={identical};json={path}")
  if not identical:
    raise AssertionError("same-seed optimize() reruns diverged")
  # the acceptance bar (>= 2x) is asserted at full scale; the smoke run
  # only has a generation or two of headroom, so it just has to win
  floor = 1.0 if smoke else 2.0
  if ratio < floor:
    raise AssertionError(
        f"guided-search hypervolume ratio {ratio:.3f} below {floor}x "
        "vs equal-budget random sampling")


ALL = [search_perf]
