"""Child process for the fleet scaling/chaos benchmark.

The XLA host-device count is fixed at process start, so every
measurement point (1 device, 8 devices, chaos) runs in its own child
with ``--xla_force_host_platform_device_count=N`` set *before* jax
loads.  Importing ``repro.explore.device`` then appends the exact-codegen
flags, so the parity contract (device results bit-identical to numpy)
holds inside every child exactly as it does in the tests.

Usage: python -m benchmarks.fleet_worker N_DEVICES MODE N_PER_TYPE CHUNK
  MODE: solo  — numpy baseline (no pool), the bit-identity reference
        fleet — healthy fleet sweep over all N visible devices
        chaos — fleet sweep with 1 straggler + 1 device lost mid-sweep
                + 1 silently-corrupting chunk, SDC sentinel on

Prints one JSON record on stdout: pairs/s over a timed post-warmup run,
the Pareto front columns (JSON floats round-trip doubles exactly, so
the parent compares them bit-for-bit), and the fleet meta counters.
"""
from __future__ import annotations

import json
import os
import sys
import time


def main() -> None:
  n_devices, mode = int(sys.argv[1]), sys.argv[2]
  n_per_type, chunk_size = int(sys.argv[3]), int(sys.argv[4])
  os.environ["XLA_FLAGS"] = (
      os.environ.get("XLA_FLAGS", "")
      + f" --xla_force_host_platform_device_count={n_devices}").strip()
  # XLA latches its flags when the client initializes, and
  # visible_devices() below initializes it before the first backend is
  # built — so the exact-codegen flags must be in place *now*, not left
  # to VectorOracleBackend(jit=True).__init__
  from repro.explore.device import ensure_exact_cpu_codegen
  ensure_exact_cpu_codegen()
  from repro.core.workloads import get_network
  from repro.explore import (DesignSpace, DevicePool, Fault, FaultPlan,
                             ParetoAccumulator, ResiliencePolicy,
                             RetryPolicy, TopKAccumulator,
                             VectorOracleBackend, stream_explore,
                             visible_devices)
  from repro.explore.fleet import device_topology

  layers = get_network("resnet20")[:4]
  space = DesignSpace()
  n_chunks = -(-4 * n_per_type // chunk_size)  # 4 PE types

  def reducers():
    return {"pareto": ParetoAccumulator(),
            "top": TopKAccumulator(20, by="power_mw")}

  def sweep():
    kw = dict(network="resnet20", n_per_type=n_per_type, seed=17,
              chunk_size=chunk_size, reducers=reducers())
    if mode == "solo":
      return stream_explore(VectorOracleBackend(), space, layers,
                            workers=1, **kw)
    chaos = mode == "chaos"
    pool = DevicePool(sdc_check_every=4 if chaos else 0)
    policy = None
    if chaos:
      assert n_chunks >= 5, f"chaos needs >= 5 chunks, got {n_chunks}"
      policy = ResiliencePolicy(
          retry=RetryPolicy(sleep=lambda s: None),
          fault_plan=FaultPlan([
              Fault("device-lost", 1, "fleet"),
              Fault("corrupt", 2, "fleet"),
              Fault("slow", n_chunks - 1, "fleet"),
          ]))
    return stream_explore(VectorOracleBackend(jit=True), space, layers,
                          pool=pool, policy=policy, **kw)

  assert len(visible_devices()) == n_devices
  sweep()                                     # warmup: compile + caches
  t0 = time.perf_counter()
  res = sweep()
  dt = time.perf_counter() - t0

  front = res.results["pareto"]
  meta = {k: v for k, v in res.meta.items()
          if isinstance(v, (int, float, str))}
  print(json.dumps({
      "mode": mode,
      "n_devices": n_devices,
      "n_rows": int(res.n_rows),
      "pairs_per_sec": res.n_rows / dt,
      "wall_s": dt,
      "front": {col: getattr(front, col).tolist()
                for col in ("latency_s", "power_mw", "area_mm2")},
      "top": {col: getattr(res.results["top"], col).tolist()
              for col in ("latency_s", "power_mw", "area_mm2")},
      "meta": meta,
      "topology": device_topology(),
  }))


if __name__ == "__main__":
  main()
