"""Scalar <-> vector parity for the joint HW x NN co-exploration path.

Covers the LayerStack packing, the JointTable cross-product
representation, `characterize_joint`, backend `co_evaluate_table`
(VectorOracleBackend exact / PolynomialBackend within float tolerance),
chunk-size invariance, session-level `co_explore(vectorized=...)`
routing, coded-arch ResultFrame mechanics (arch_id + arch_lookup,
mixed-lookup concat remapping), and a property test pinning the
3-objective `pareto_mask` to a brute-force O(n^2) reference on random
joint frames.
"""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import oracle
from repro.core.cnn import SEARCH_SPACE, ArchChoice
from repro.core.dataflow import ConvLayer, LayerStack
from repro.core.pe import PE_TYPES
from repro.core.table import ConfigTable, JointTable
from repro.explore import (DesignSpace, ExplorationSession, OracleBackend,
                           PolynomialBackend, ResultFrame,
                           VectorOracleBackend, pareto_mask)

ALL_TYPES = tuple(PE_TYPES)


def make_archs(n, seed=0):
  """Deterministic Table-4 architectures without a jax dependency."""
  rng = np.random.RandomState(seed)
  return [ArchChoice(tuple((int(rng.choice(reps)), int(rng.choice(chs)))
                           for reps, chs in SEARCH_SPACE))
          for _ in range(n)]


def arch_layer_lists(archs, image_size=16):
  from repro.core.supernet import arch_to_layers
  return [arch_to_layers(a, image_size=image_size) for a in archs]


@pytest.fixture(scope="module")
def archs():
  return make_archs(3, seed=7)


@pytest.fixture(scope="module")
def layer_lists(archs):
  return arch_layer_lists(archs)


@pytest.fixture(scope="module")
def stack(layer_lists):
  return LayerStack.from_layer_lists(layer_lists)


class TestLayerStack:
  def test_round_trip(self, layer_lists, stack):
    assert stack.n_archs == len(layer_lists)
    assert stack.max_layers == max(len(ls) for ls in layer_lists)
    assert stack.n_layers().tolist() == [len(ls) for ls in layer_lists]
    for a, ls in enumerate(layer_lists):
      got = stack.layers_of(a)
      # names differ (stack synthesizes them); compare the feature fields
      assert [l.features() for l in got] == [l.features() for l in ls]

  def test_features_tensor(self, layer_lists, stack):
    f = stack.features()
    assert f.shape == (stack.n_archs, stack.max_layers, 8)
    for a, ls in enumerate(layer_lists):
      want = np.asarray([l.features() for l in ls])
      assert np.array_equal(f[a, :len(ls)], want)

  def test_derived_match_convlayer(self, layer_lists, stack):
    for a, ls in enumerate(layer_lists):
      for li, l in enumerate(ls):
        feats = stack.feats_at(li)
        assert feats["macs"][a, 0] == float(l.macs)
        assert feats["E"][a, 0] == float(max(l.out_dim, 1))
        assert feats["of_words"][a, 0] == float(l.ofmap_count)
        assert feats["ifmap_words"][a, 0] == float(l.ifmap_count)
        assert feats["weight_words"][a, 0] == float(l.weight_count)

  def test_validation_and_fingerprint(self, stack):
    with pytest.raises(ValueError, match="2-D"):
      LayerStack(*[np.zeros(3)] * 8, valid=np.ones(3, bool))
    other = LayerStack.from_layer_lists(
        [[ConvLayer("x", A=8, C=3, F=16, K=3)]])
    assert stack.fingerprint() != other.fingerprint()
    assert stack.fingerprint() == stack.fingerprint()


class TestJointTable:
  def test_index_arithmetic(self):
    hw = DesignSpace().sample_table(5, seed=1)  # 20 rows, 4 types
    joint = hw.cross(3)
    assert isinstance(joint, JointTable)
    assert len(joint) == 3 * 20 and joint.n_hw == 20
    assert joint.arch_ids().tolist() == [a for a in range(3)
                                         for _ in range(20)]
    assert joint.hw_indices().tolist() == list(range(20)) * 3
    assert list(joint.pe_type_strings()) == \
        list(hw.pe_type_strings()) * 3
    aid, cfg = joint.pair_at(2 * 20 + 7)
    assert aid == 2 and cfg == hw.config_at(7)
    assert joint.config_at(41) == hw.config_at(1)
    with pytest.raises(IndexError):
      joint.pair_at(len(joint))

  def test_select_and_materialize(self):
    hw = DesignSpace().sample_type_table("INT16", 6, seed=2)
    joint = hw.cross(2)
    flat = joint.materialize()
    assert isinstance(flat, ConfigTable) and len(flat) == 12
    assert flat.to_configs() == hw.to_configs() * 2
    idx = np.asarray([0, 6, 11])
    sel = joint.select(idx)
    assert sel.to_configs() == [joint.config_at(i) for i in idx]
    mask = np.zeros(12, bool)
    mask[[1, 7]] = True
    assert joint.select(mask).to_configs() == \
        [joint.config_at(1), joint.config_at(7)]
    assert joint.select(slice(5, 8)).to_configs() == \
        [joint.config_at(i) for i in (5, 6, 7)]


class TestJointOracleParity:
  @pytest.mark.parametrize("pe_type", ALL_TYPES)
  def test_characterize_joint_per_type(self, pe_type, layer_lists, stack):
    hw = DesignSpace(pe_types=(pe_type,)).sample_type_table(
        pe_type, 8, seed=hash(pe_type) % 1000)
    ch = oracle.characterize_joint(hw, stack)
    for a, ls in enumerate(layer_lists):
      for h in range(len(hw)):
        sc = oracle.characterize(hw.config_at(h), ls)
        assert ch.latency_s[a, h] == sc.latency_s
        assert ch.energy_mj[a, h] == sc.energy_mj
        assert ch.utilization[a, h] == sc.utilization
        assert ch.power_mw[h] == sc.power_mw
        assert ch.area_mm2[h] == sc.area_mm2

  def test_joint_row_matches_network_batch(self, layer_lists, stack):
    """Row a of the stack path == characterize_batch with arch a's
    layers (mixed-PE-type table)."""
    hw = DesignSpace().sample_table(4, seed=9)
    ch = oracle.characterize_joint(hw, stack)
    for a, ls in enumerate(layer_lists):
      cb = oracle.characterize_batch(hw, ls)
      assert np.array_equal(ch.latency_s[a], cb.latency_s)
      assert np.array_equal(ch.energy_mj[a], cb.energy_mj)
      assert np.array_equal(ch.utilization[a], cb.utilization)


class TestVectorCoEvaluate:
  def test_exact_vs_scalar_loop(self, archs, layer_lists):
    hw = DesignSpace().sample_table(5, seed=4)  # 20 mixed-type rows
    stack = LayerStack.from_layer_lists(layer_lists)
    fj = VectorOracleBackend(chunk_size=32).co_evaluate_table(hw, stack)
    assert len(fj) == len(archs) * len(hw)
    ob = OracleBackend()
    n_hw = len(hw)
    for a, ls in enumerate(layer_lists):
      fs = ob.evaluate(hw.to_configs(), ls, "coexplore")
      rows = slice(a * n_hw, (a + 1) * n_hw)
      for col in ("latency_s", "power_mw", "area_mm2"):
        assert np.array_equal(getattr(fj, col)[rows],
                              getattr(fs, col)), col
      assert list(fj.pe_type[rows]) == list(fs.pe_type)

  def test_chunk_size_invariance(self, stack):
    hw = DesignSpace().sample_table(7, seed=5)
    frames = [VectorOracleBackend(chunk_size=cs).co_evaluate_table(hw, stack)
              for cs in (1, 2, 17, 100, 10_000_000)]
    for f in frames[1:]:
      for col in ("latency_s", "power_mw", "area_mm2"):
        assert np.array_equal(getattr(f, col),
                              getattr(frames[0], col)), col

  def test_frame_carries_joint_table_and_arch_ids(self, stack):
    hw = DesignSpace().sample_type_table("INT16", 4, seed=0)
    f = VectorOracleBackend().co_evaluate_table(hw, stack)
    assert isinstance(f.table, JointTable)
    assert f.extra["arch_id"].dtype == np.int64
    assert f.config_at(5) == hw.config_at(1)
    top = f.top_k(3, by="perf_per_area")  # select() gathers a flat table
    assert isinstance(top.table, ConfigTable) and len(top.table) == 3
    pts = f.to_points()  # design-point protocol holds on joint frames
    assert len(pts) == len(f)
    assert pts[5].cfg == hw.config_at(1)
    assert pts[-1].latency_s == f.latency_s[-1]

  def test_jit_path_exact(self, stack):
    """The default x64 joint device path is bit-identical to numpy."""
    pytest.importorskip("jax")
    hw = DesignSpace().sample_table(3, seed=1)
    base = VectorOracleBackend().co_evaluate_table(hw, stack)
    jit = VectorOracleBackend(chunk_size=64, jit=True).co_evaluate_table(
        hw, stack)
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(getattr(jit, col), getattr(base, col)), col


class TestPolynomialCoEvaluate:
  @pytest.fixture(scope="class")
  def backend(self, layer_lists):
    return PolynomialBackend.fit(pe_types=("INT16", "LightPE-1"), degree=3,
                                 n_train=80, layers=layer_lists[0][:4],
                                 seed=0)

  def test_matches_scalar_loop(self, backend, archs, layer_lists):
    space = DesignSpace(pe_types=("INT16", "LightPE-1"))
    hw = space.sample_table(6, seed=3)
    stack = LayerStack.from_layer_lists(layer_lists)
    fj = backend.co_evaluate_table(hw, stack)
    n_hw = len(hw)
    for a, ls in enumerate(layer_lists):
      fs = backend.evaluate(hw.to_configs(), ls, "coexplore")
      rows = slice(a * n_hw, (a + 1) * n_hw)
      for col in ("latency_s", "power_mw", "area_mm2"):
        np.testing.assert_allclose(getattr(fj, col)[rows],
                                   getattr(fs, col), rtol=1e-12,
                                   err_msg=col)

  def test_missing_type_raises(self, backend, stack):
    hw = DesignSpace().sample_type_table("FP32", 2, seed=0)
    with pytest.raises(KeyError, match="FP32"):
      backend.co_evaluate_table(hw, stack)


class TestSessionCoExplore:
  @pytest.fixture(scope="class")
  def arch_accs(self):
    return [(a, 0.9 - 0.1 * i) for i, a in enumerate(make_archs(3, seed=7))]

  def test_vectorized_matches_scalar_path(self, arch_accs):
    """Stratified sampling enumerates the same HW sequence on both
    paths, so the joint frames must agree bit for bit."""
    space = DesignSpace(pe_types=("INT16", "LightPE-2"))
    sess = ExplorationSession(VectorOracleBackend(chunk_size=16), space)
    kw = dict(n_hw_per_type=5, image_size=16, method="stratified")
    fv = sess.co_explore(arch_accs, vectorized=True, **kw)
    fs = sess.co_explore(arch_accs, vectorized=False, **kw)
    assert len(fv) == len(fs) == 2 * 3 * 5
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(getattr(fv, col), getattr(fs, col)), col
    assert np.array_equal(fv.extra["top1"], fs.extra["top1"])
    assert np.array_equal(fv.extra["arch_id"], fs.extra["arch_id"])
    assert list(fv.pe_type) == list(fs.pe_type)
    assert fv.arch_lookup == fs.arch_lookup
    assert fv.arch_at(0) == arch_accs[0][0]

  def test_auto_routes_by_backend(self, arch_accs):
    space = DesignSpace(pe_types=("INT16",))
    joint = ExplorationSession(VectorOracleBackend(), space).co_explore(
        arch_accs, n_hw_per_type=3, image_size=16)
    assert joint.extra["arch_id"].dtype == np.int64
    with pytest.raises(ValueError, match="co_evaluate_table"):
      ExplorationSession(OracleBackend(), space).co_explore(
          arch_accs, n_hw_per_type=2, image_size=16, vectorized=True)

  def test_three_objective_front(self, arch_accs):
    space = DesignSpace(pe_types=("INT16", "LightPE-1"))
    sess = ExplorationSession(VectorOracleBackend(), space)
    frame = sess.co_explore(arch_accs, n_hw_per_type=6, image_size=16)
    front = frame.pareto(("top1_err", "energy_mj", "area_mm2"))
    obj = np.stack([frame.column("top1_err"), frame.energy_mj,
                    frame.area_mm2], axis=1)
    ref = np.ones(len(frame), bool)
    for i in range(len(frame)):
      dom = np.all(obj <= obj[i], axis=1) & np.any(obj < obj[i], axis=1)
      ref[i] = not dom.any()
    assert np.array_equal(front, ref)
    assert front.any()


class TestCodedArchFrame:
  def test_lookup_requires_arch_id(self):
    with pytest.raises(ValueError, match="arch_id"):
      ResultFrame(np.ones(2), np.ones(2), np.ones(2),
                  np.asarray(["INT16"] * 2), arch_lookup=("a",))

  def test_arch_id_out_of_range(self):
    with pytest.raises(ValueError, match="out of range"):
      ResultFrame(np.ones(2), np.ones(2), np.ones(2),
                  np.asarray(["INT16"] * 2),
                  extra={"arch_id": np.asarray([0, 5])},
                  arch_lookup=("a",))

  def test_mixed_lookup_concat_remaps(self):
    def frame(lookup, ids):
      n = len(ids)
      return ResultFrame(np.ones(n), np.ones(n), np.ones(n),
                         np.asarray(["INT16"] * n), network="coexplore",
                         extra={"arch_id": np.asarray(ids, np.int64)},
                         arch_lookup=lookup)
    archs = make_archs(3, seed=1)
    f1 = frame((archs[0], archs[1]), [0, 1, 1])
    f2 = frame((archs[1], archs[2]), [0, 1])
    both = ResultFrame.concat([f1, f2])
    assert both.arch_lookup == (archs[0], archs[1], archs[2])
    assert both.extra["arch_id"].tolist() == [0, 1, 1, 1, 2]
    assert both.arch_at(3) == archs[1]
    # identical lookups short-circuit without remapping
    same = ResultFrame.concat([f1, f1])
    assert same.arch_lookup == f1.arch_lookup
    assert same.extra["arch_id"].tolist() == [0, 1, 1, 0, 1, 1]

  def test_concat_rejects_uncoded_arch_frames(self):
    archs = make_archs(1, seed=2)
    coded = ResultFrame(np.ones(1), np.ones(1), np.ones(1),
                        np.asarray(["INT16"]), network="coexplore",
                        extra={"arch_id": np.zeros(1, np.int64)},
                        arch_lookup=(archs[0],))
    uncoded = ResultFrame(np.ones(1), np.ones(1), np.ones(1),
                          np.asarray(["INT16"]), network="coexplore",
                          extra={"arch_id": np.zeros(1, np.int64)})
    with pytest.raises(ValueError, match="arch_lookup"):
      ResultFrame.concat([coded, uncoded])

  def test_select_preserves_lookup(self):
    archs = make_archs(2, seed=3)
    f = ResultFrame(np.arange(4.0), np.ones(4), np.ones(4),
                    np.asarray(["INT16"] * 4), network="coexplore",
                    extra={"arch_id": np.asarray([0, 0, 1, 1])},
                    arch_lookup=tuple(archs))
    sub = f.select(np.asarray([2, 3]))
    assert sub.arch_lookup == tuple(archs)
    assert sub.arch_at(0) == archs[1]


class TestShimCompat:
  def test_copoint_list_bit_compatible(self):
    """The rerouted _to_frame keeps the CoPoint API unchanged."""
    from repro.core import coexplore
    from repro.core.workloads import get_network
    layers = get_network("resnet20")[:4]
    backend = PolynomialBackend.fit(pe_types=("INT16",), degree=3,
                                    n_train=80, layers=layers, seed=0)
    arch_accs = [(a, 0.8 - 0.1 * i)
                 for i, a in enumerate(make_archs(2, seed=5))]
    pts = coexplore.co_explore(backend.models, arch_accs, n_hw_per_type=4,
                               image_size=16, pe_types=("INT16",))
    assert len(pts) == 2 * 4
    assert [p.arch for p in pts[:4]] == [arch_accs[0][0]] * 4
    assert [p.arch for p in pts[4:]] == [arch_accs[1][0]] * 4
    res = coexplore.normalize_and_front(pts)
    assert res["front_energy"].shape == (8,)
    assert res["err"].tolist() == [1.0 - p.top1 for p in pts]


# ---------------------------------------------------------------------------
# property tests (hypothesis optional — skip cleanly without it)
# ---------------------------------------------------------------------------

def brute_force_front(obj: np.ndarray) -> np.ndarray:
  obj = np.asarray(obj, np.float64)
  n = obj.shape[0]
  mask = np.ones(n, bool)
  for i in range(n):
    dom = np.all(obj <= obj[i], axis=1) & np.any(obj < obj[i], axis=1)
    mask[i] = not dom.any()
  return mask


class TestProperties:
  @given(st.integers(0, 10_000), st.integers(1, 120), st.integers(1, 6))
  @settings(max_examples=25, deadline=None)
  def test_3d_pareto_matches_brute_force_on_joint_frames(self, seed, n,
                                                         n_archs):
    """Random joint frames (duplicated objective rows included, as real
    arch-major frames produce) — the n-d sweep must equal the O(n^2)
    dominance reference on (top1_err, energy, area)."""
    rng = np.random.RandomState(seed)
    err = rng.uniform(0.05, 0.6, size=n_archs)[
        rng.randint(0, n_archs, size=n)]
    energy = rng.lognormal(0.0, 1.0, size=n)
    area = rng.lognormal(0.0, 0.5, size=n)
    # inject exact duplicates (tied pairs across archs)
    if n >= 4:
      energy[: n // 4] = energy[n // 4: 2 * (n // 4)]
      area[: n // 4] = area[n // 4: 2 * (n // 4)]
    obj = np.stack([err, energy, area], axis=1)
    assert np.array_equal(pareto_mask(obj), brute_force_front(obj))

  @given(st.integers(0, 10_000), st.integers(1, 4), st.integers(1, 10))
  @settings(max_examples=10, deadline=None)
  def test_joint_parity_random(self, seed, n_archs, n_hw):
    rng = np.random.RandomState(seed)
    archs = make_archs(n_archs, seed=seed)
    lists = arch_layer_lists(archs, image_size=8)
    stack = LayerStack.from_layer_lists(lists)
    hw = DesignSpace().sample_table(max(n_hw // 4, 1), seed=seed)
    ch = oracle.characterize_joint(hw, stack)
    a = seed % n_archs
    cb = oracle.characterize_batch(hw, lists[a])
    assert np.array_equal(ch.latency_s[a], cb.latency_s)
    assert np.array_equal(ch.energy_mj[a], cb.energy_mj)
