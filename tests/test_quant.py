"""Unit + property tests for the quantization core (paper Eq. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import quant


class TestPow2:
  @pytest.mark.parametrize("k", [1, 2])
  def test_roundtrip_idempotent(self, k):
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 64)) * 0.1
    q = quant.pow2_quantize(w, k=k)
    wh = quant.pow2_dequantize(q)
    q2 = quant.pow2_quantize(wh, k=k, scale=q.scale)
    assert jnp.allclose(quant.pow2_dequantize(q2), wh)

  @pytest.mark.parametrize("k", [1, 2])
  def test_codebook_values_exact(self, k):
    vals, codes = quant.pow2_codebook(k)
    # every codebook value must be representable exactly (sum of 2^-m)
    vals = np.asarray(vals)
    assert vals.min() >= 2.0 ** -quant.POW2_M_MAX
    assert vals.max() <= 2.0
    # k=1: 8 values; k=2: 36 values (m1 <= m2)
    assert len(vals) == (8 if k == 1 else 36)

  def test_k2_better_than_k1(self):
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    e1 = jnp.mean(jnp.abs(quant.pow2_dequantize(
        quant.pow2_quantize(w, 1)) - w))
    e2 = jnp.mean(jnp.abs(quant.pow2_dequantize(
        quant.pow2_quantize(w, 2)) - w))
    assert e2 < e1

  @pytest.mark.parametrize("k", [1, 2])
  def test_quantize_is_nearest_codebook_point(self, k):
    """Property: the chosen code minimizes |w/s - v| over the codebook."""
    w = jax.random.normal(jax.random.PRNGKey(2), (128,))
    q = quant.pow2_quantize(w, k=k, channel_axis=None)
    vals, _ = quant.pow2_codebook(k)
    a = np.asarray(w / q.scale).reshape(-1)
    got = np.asarray(quant.pow2_decode_codes(q.codes, k)).reshape(-1)
    vals = np.asarray(vals)
    best = np.array([vals[np.argmin(np.abs(np.abs(x) - vals))]
                     * np.sign(x) for x in a])
    np.testing.assert_allclose(got, best, rtol=0, atol=0)

  def test_ste_gradient_identity(self):
    w = jax.random.normal(jax.random.PRNGKey(3), (8, 8))
    g = jax.grad(lambda w: jnp.sum(quant.pow2_fake_quant(w, 1) * 2.0))(w)
    np.testing.assert_allclose(np.asarray(g), 2.0)


class TestIntQuant:
  @pytest.mark.parametrize("bits", [4, 8, 16])
  def test_error_bound(self, bits):
    w = jax.random.normal(jax.random.PRNGKey(4), (32, 32))
    q = quant.int_quantize(w, bits)
    wh = quant.int_dequantize(q)
    # error bounded by scale/2 per element
    bound = np.asarray(jnp.broadcast_to(q.scale / 2, w.shape))
    assert np.all(np.abs(np.asarray(wh - w)) <= bound + 1e-7)

  def test_bits_ordering(self):
    w = jax.random.normal(jax.random.PRNGKey(5), (64, 64))
    errs = [float(jnp.mean(jnp.abs(quant.int_dequantize(
        quant.int_quantize(w, b)) - w))) for b in (4, 8, 16)]
    assert errs[0] > errs[1] > errs[2]


class TestPacking:
  @given(st.integers(0, 2 ** 31 - 1))
  @settings(max_examples=20, deadline=None)
  def test_nibble_roundtrip(self, seed):
    codes = jax.random.randint(jax.random.PRNGKey(seed), (4, 16), 0, 16
                               ).astype(jnp.uint8)
    assert jnp.all(quant.unpack_nibbles(quant.pack_nibbles(codes)) == codes)

  @given(st.integers(0, 2 ** 31 - 1))
  @settings(max_examples=20, deadline=None)
  def test_int4_roundtrip(self, seed):
    codes = jax.random.randint(jax.random.PRNGKey(seed), (4, 16), -8, 8
                               ).astype(jnp.int8)
    assert jnp.all(quant.unpack_int4(quant.pack_int4(codes)) == codes)


class TestPolicy:
  def test_fake_quant_tree_only_matmuls(self):
    from repro.quant.policy import QuantPolicy, fake_quant_params
    params = {"blocks": {"sub0": {"mix": {"wq": jnp.ones((4, 4))},
                                  "mix_norm": {"scale": jnp.ones(4)}}}}
    out = fake_quant_params(params, QuantPolicy(pe_type="LightPE-1"))
    # norm untouched, wq quantized to pow2 grid
    assert jnp.all(out["blocks"]["sub0"]["mix_norm"]["scale"] == 1.0)
    wq = out["blocks"]["sub0"]["mix"]["wq"]
    assert jnp.allclose(wq, 1.0)  # 1.0 = 2^0 exactly representable
