"""Streaming <-> one-shot equivalence for the constant-memory sweep engine.

Covers lazy chunked sampling (`DesignSpace.iter_tables` concatenation
bit-identical to `sample_table` for every method and chunk size), the
online reducers (ParetoAccumulator / TopKAccumulator folds over shuffled
chunk partitions equal the single-shot `pareto_mask` / `top_k`, including
empty-chunk and single-chunk edge cases; streaming stats/histograms),
the block-decomposed `_pareto_mask_nd` kernel, `stable_topk_indices`
(the argpartition `top_k` satellite), NaN-safe empty `summary_stats`,
JointTable block slicing, LayerStack arch slicing, and session-level
`stream=True` / auto-threshold routing on both vector backends.
"""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.cnn import SEARCH_SPACE, ArchChoice
from repro.core.table import ConfigTable
from repro.core.workloads import get_network
from repro.explore import (CollectAccumulator, DesignSpace,
                           ExplorationSession, HistogramAccumulator,
                           ParetoAccumulator, PolynomialBackend, ResultFrame,
                           StatsAccumulator, TopKAccumulator,
                           VectorOracleBackend, pareto_mask,
                           stable_topk_indices, summary_stats,
                           vector_constraint)
from repro.explore import frame as frame_mod


def random_frame(rng: np.random.RandomState, n: int,
                 with_top1: bool = False) -> ResultFrame:
  """Synthetic ResultFrame with deliberate ties (quantized values)."""
  extra = {}
  if with_top1:
    extra["top1"] = rng.randint(50, 96, n) / 100.0
    extra["arch_id"] = rng.randint(0, 5, n).astype(np.int64)
  return ResultFrame(
      latency_s=rng.randint(1, 30, n) * 1e-3,
      power_mw=rng.randint(1, 20, n) * 10.0,
      area_mm2=rng.randint(1, 15, n) * 0.5,
      pe_type=np.asarray(["INT16", "FP32"])[rng.randint(0, 2, n)],
      extra=extra)


def fold_partition(reducer, frame: ResultFrame, rng: np.random.RandomState,
                   n_chunks: int):
  """Fold `frame` into `reducer` as a shuffled partition of row chunks."""
  n = len(frame)
  perm = rng.permutation(n)
  bounds = np.sort(rng.randint(0, n + 1, size=max(n_chunks - 1, 0)))
  parts = np.split(perm, bounds)
  rng.shuffle(parts)
  for idx in parts:
    reducer.fold(frame.select(idx), idx)
  return reducer


# ---------------------------------------------------------------------------
# lazy chunked sampling
# ---------------------------------------------------------------------------

class TestIterTables:
  @pytest.mark.parametrize("method", ["random", "grid", "stratified"])
  @pytest.mark.parametrize("chunk_size", [1, 13, 100_000])
  def test_concat_equals_sample_table(self, method, chunk_size):
    space = DesignSpace()
    one = space.sample_table(83, seed=11, method=method)
    parts = list(space.iter_tables(83, seed=11, method=method,
                                   chunk_size=chunk_size))
    assert all(len(p) <= chunk_size for p in parts)
    assert ConfigTable.concat(parts).to_configs() == one.to_configs()

  def test_grid_subsampled_and_list_parity(self):
    """n < total grid: lazy linspace+dedup == one-shot np.unique(linspace),
    and the list path still enumerates the same sequence."""
    space = DesignSpace()
    one = space.sample_type_table("INT16", 60, method="grid")
    parts = list(space.iter_type_tables("INT16", 60, method="grid",
                                        chunk_size=7))
    assert ConfigTable.concat(parts).to_configs() == one.to_configs()
    assert space.sample_type("INT16", 60, method="grid") == one.to_configs()

  def test_constraints_filter_chunks(self):
    space = DesignSpace(constraints=[
        vector_constraint(lambda c: c.n_pe <= 256, lambda t: t.n_pe <= 256)])
    one = space.sample_type_table("INT16", 150, seed=2)
    parts = list(space.iter_type_tables("INT16", 150, seed=2, chunk_size=32))
    cat = ConfigTable.concat(parts)
    assert len(cat) == 150 and int(cat.n_pe.max()) <= 256
    assert cat.to_configs() == one.to_configs()

  def test_zero_and_bad_args(self):
    space = DesignSpace()
    assert list(space.iter_type_tables("INT16", 0, seed=0)) == []
    with pytest.raises(ValueError, match="chunk_size"):
      list(space.iter_type_tables("INT16", 5, chunk_size=0))
    with pytest.raises(ValueError, match="not in this space"):
      list(space.iter_type_tables("NOPE", 5))

  def test_impossible_constraint_raises(self):
    space = DesignSpace(constraints=[
        vector_constraint(lambda c: False,
                          lambda t: np.zeros(len(t), bool))])
    with pytest.raises(ValueError, match="constraints rejected"):
      list(space.iter_type_tables("INT16", 2, seed=0, chunk_size=64))


# ---------------------------------------------------------------------------
# online reducers vs one-shot
# ---------------------------------------------------------------------------

class TestParetoAccumulator:
  COLS = ("perf_per_area", "energy_mj")

  def one_shot(self, frame):
    return frame.select(frame.pareto(self.COLS))

  def check_equal(self, got, want):
    assert len(got) == len(want)
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(getattr(got, col), getattr(want, col)), col
    assert list(got.pe_type) == list(want.pe_type)

  def test_single_chunk(self):
    frame = random_frame(np.random.RandomState(0), 200)
    acc = ParetoAccumulator(self.COLS)
    acc.fold(frame, np.arange(len(frame)))
    self.check_equal(acc.result(), self.one_shot(frame))

  def test_empty_chunks_are_noops(self):
    frame = random_frame(np.random.RandomState(1), 120)
    acc = ParetoAccumulator(self.COLS)
    empty = frame.select(np.zeros(0, np.int64))
    acc.fold(empty, np.zeros(0, np.int64))
    acc.fold(frame, np.arange(len(frame)))
    acc.fold(empty, np.zeros(0, np.int64))
    self.check_equal(acc.result(), self.one_shot(frame))

  def test_no_folds_gives_empty_frame(self):
    assert len(ParetoAccumulator(self.COLS).result()) == 0
    assert len(TopKAccumulator(3).result()) == 0

  @given(st.integers(0, 10_000), st.integers(1, 200), st.integers(1, 8))
  @settings(max_examples=25, deadline=None)
  def test_shuffled_partitions_match_one_shot(self, seed, n, n_chunks):
    rng = np.random.RandomState(seed)
    frame = random_frame(rng, n)
    acc = fold_partition(ParetoAccumulator(self.COLS), frame, rng, n_chunks)
    want = self.one_shot(frame)
    self.check_equal(acc.result(), want)
    assert np.array_equal(
        acc.indices, np.flatnonzero(frame.pareto(self.COLS)))

  @given(st.integers(0, 10_000), st.integers(1, 150), st.integers(1, 6))
  @settings(max_examples=15, deadline=None)
  def test_3d_joint_front_partitions(self, seed, n, n_chunks):
    rng = np.random.RandomState(seed)
    frame = random_frame(rng, n, with_top1=True)
    cols = ("top1_err", "energy_mj", "area_mm2")
    acc = fold_partition(ParetoAccumulator(cols), frame, rng, n_chunks)
    want = frame.select(frame.pareto(cols))
    got = acc.result()
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(getattr(got, col), getattr(want, col)), col
    assert np.array_equal(got.extra["top1"], want.extra["top1"])


class TestTopKAccumulator:
  @given(st.integers(0, 10_000), st.integers(1, 200), st.integers(1, 8),
         st.integers(1, 30))
  @settings(max_examples=25, deadline=None)
  def test_shuffled_partitions_match_one_shot(self, seed, n, n_chunks, k):
    rng = np.random.RandomState(seed)
    frame = random_frame(rng, n)
    by = ("energy_mj", "perf_per_area")[seed % 2]
    acc = fold_partition(TopKAccumulator(k, by=by), frame, rng, n_chunks)
    want = frame.top_k(k, by=by)
    got = acc.result()
    assert len(got) == len(want) == min(k, n)
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(getattr(got, col), getattr(want, col)), col
    # ties resolve to the lowest global row id, like the stable one-shot
    key = frame.column(by)
    key = -key if by == "perf_per_area" else key
    assert np.array_equal(acc.indices,
                          stable_topk_indices(key, k))

  def test_bad_k(self):
    with pytest.raises(ValueError, match="k must be positive"):
      TopKAccumulator(0)


class TestStatsAndHistogram:
  @given(st.integers(0, 10_000), st.integers(1, 300), st.integers(1, 7))
  @settings(max_examples=15, deadline=None)
  def test_stats_match_numpy(self, seed, n, n_chunks):
    rng = np.random.RandomState(seed)
    frame = random_frame(rng, n)
    acc = fold_partition(StatsAccumulator("energy_mj"), frame, rng, n_chunks)
    v = frame.energy_mj
    got = acc.result()
    assert got["count"] == n
    assert got["min"] == v.min() and got["max"] == v.max()
    np.testing.assert_allclose(got["mean"], v.mean(), rtol=1e-12)
    np.testing.assert_allclose(got["std"], v.std(), rtol=1e-9)

  def test_stats_empty(self):
    out = StatsAccumulator("energy_mj").result()
    assert all(np.isnan(x) for x in out.values())

  def test_stats_single_row_chunks_match_one_shot(self):
    # row-at-a-time folding exercises the n == 1 zero-M2 short-circuit;
    # must agree with the one-shot fold (and numpy) instead of poisoning
    # the Welford merge with NaN partials
    rng = np.random.RandomState(8)
    frame = random_frame(rng, 37)
    acc = StatsAccumulator("energy_mj")
    for i in range(len(frame)):
      acc.fold(frame.select(np.asarray([i])), np.asarray([i]))
    got = acc.result()
    v = frame.energy_mj
    assert got["count"] == len(frame)
    assert got["min"] == v.min() and got["max"] == v.max()
    np.testing.assert_allclose(got["mean"], v.mean(), rtol=1e-12)
    np.testing.assert_allclose(got["std"], v.std(), rtol=1e-9)

  def test_stats_single_nonfinite_row_has_no_nan_partial(self):
    # a 1-row chunk holding inf used to yield m2 = (inf - inf)**2 = NaN,
    # and merging a +-inf mean into the empty state NaN'd the M2 term;
    # both paths must now stay NaN-free for count/min/max
    def one_row(val):
      return ResultFrame(np.asarray([val]), np.asarray([1.0]),
                         np.asarray([1.0]), np.asarray(["INT8"]))

    acc = StatsAccumulator("latency_s")
    acc.fold(one_row(np.inf), np.asarray([0]))
    acc.fold(one_row(2.0), np.asarray([1]))
    acc.fold(one_row(3.0), np.asarray([2]))
    got = acc.result()
    assert got["count"] == 3
    assert got["min"] == 2.0
    assert got["max"] == np.inf

  def test_stats_first_partial_adopted_bit_identically(self):
    # the n == 0 adopt-directly shortcut must be bit-identical to the
    # general Chan merge for finite inputs
    rng = np.random.RandomState(9)
    v = rng.rand(50) * 1e3
    frame = ResultFrame(v, np.ones(50), np.ones(50),
                        np.asarray(["INT8"] * 50))
    acc = StatsAccumulator("latency_s")
    acc.fold(frame, np.arange(50))
    mean_b = float(v.mean())
    m2_b = float(((v - mean_b) ** 2).sum())
    # what the general formula computes from the (0, 0.0, 0.0) state
    assert acc._mean == 0.0 + (mean_b - 0.0) * 50 / 50
    assert acc._m2 == m2_b + (mean_b - 0.0) ** 2 * 0 * 50 / 50
    assert acc.n == 50

  def test_histogram_counts_and_quantiles(self):
    rng = np.random.RandomState(3)
    frame = random_frame(rng, 500)
    v = frame.energy_mj
    acc = HistogramAccumulator("energy_mj", float(v.min()), float(v.max()),
                               bins=32)
    fold_partition(acc, frame, rng, 5)
    out = acc.result()
    assert out["counts"].sum() == 500
    want = np.histogram(v, bins=out["edges"])[0]
    assert np.array_equal(out["counts"], want)
    # approximate median within one bin width of the exact one
    bin_w = out["edges"][1] - out["edges"][0]
    assert abs(acc.quantile(0.5) - np.median(v)) <= bin_w
    with pytest.raises(ValueError, match="hi > lo"):
      HistogramAccumulator("energy_mj", 1.0, 1.0)

  def test_collect_reassembles_global_order(self):
    rng = np.random.RandomState(4)
    frame = random_frame(rng, 100)
    acc = fold_partition(CollectAccumulator(), frame, rng, 6)
    got = acc.result()
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(getattr(got, col), getattr(frame, col)), col


# ---------------------------------------------------------------------------
# frame-level satellites: top_k, empty stats, block-decomposed N-D pareto
# ---------------------------------------------------------------------------

class TestStableTopK:
  @given(st.integers(0, 10_000), st.integers(0, 120), st.integers(0, 140))
  @settings(max_examples=40, deadline=None)
  def test_matches_stable_argsort(self, seed, n, k):
    rng = np.random.RandomState(seed)
    v = rng.randint(0, 12, n).astype(np.float64)  # heavy ties
    assert np.array_equal(stable_topk_indices(v, k),
                          np.argsort(v, kind="stable")[:k])

  def test_nan_fallback(self):
    v = np.asarray([3.0, np.nan, 1.0, np.nan, 2.0])
    assert np.array_equal(stable_topk_indices(v, 2),
                          np.argsort(v, kind="stable")[:2])

  def test_frame_top_k_maximize_and_ties(self):
    rng = np.random.RandomState(0)
    frame = random_frame(rng, 300)
    for by, k in (("perf_per_area", 7), ("energy_mj", 25), ("latency_s", 0)):
      got = frame.top_k(k, by=by)
      key = frame.column(by)
      key = -key if by == "perf_per_area" else key
      want = frame.select(np.argsort(key, kind="stable")[:k])
      assert np.array_equal(got.latency_s, want.latency_s), by


class TestSummaryStatsEmpty:
  def test_empty_returns_nans(self):
    out = summary_stats(np.zeros(0))
    assert set(out) == {"min", "q1", "median", "q3", "max", "mean"}
    assert all(np.isnan(x) for x in out.values())

  def test_frame_stats_zero_row_mask(self):
    frame = random_frame(np.random.RandomState(0), 10)
    out = frame.stats("energy_mj", mask=np.zeros(10, np.bool_))
    assert all(np.isnan(x) for x in out.values())


def brute_force_front(obj: np.ndarray) -> np.ndarray:
  n = len(obj)
  return np.asarray(
      [not any(np.all(obj[j] <= obj[i]) and np.any(obj[j] < obj[i])
               for j in range(n)) for i in range(n)])


class TestBlockDecomposedParetoND:
  @given(st.integers(0, 10_000), st.integers(1, 120), st.integers(3, 4))
  @settings(max_examples=20, deadline=None)
  def test_blocked_matches_brute_force(self, seed, n, d, monkeypatch=None):
    rng = np.random.RandomState(seed)
    obj = rng.randint(0, 6, size=(n, d)).astype(np.float64)  # many dups
    assert np.array_equal(pareto_mask(obj), brute_force_front(obj))

  def test_multi_block_recursion(self, monkeypatch):
    monkeypatch.setattr(frame_mod, "_ND_BLOCK", 16)
    rng = np.random.RandomState(1)
    obj = rng.uniform(size=(400, 3))
    obj[37] = obj[11]  # duplicate straddling blocks
    assert np.array_equal(frame_mod._pareto_mask_nd(obj),
                          brute_force_front(obj))

  def test_all_front_degenerate(self, monkeypatch):
    monkeypatch.setattr(frame_mod, "_ND_BLOCK", 8)
    # anti-correlated: every point non-dominated -> blocks make no progress
    t = np.linspace(0.0, 1.0, 40)
    obj = np.stack([t, 1.0 - t, np.ones_like(t)], axis=1)
    mask = frame_mod._pareto_mask_nd(obj)
    assert mask.all()


# ---------------------------------------------------------------------------
# table / stack block machinery
# ---------------------------------------------------------------------------

class TestJointBlocks:
  def test_block_slices_cover_exactly_once(self):
    hw = DesignSpace().sample_type_table("INT16", 23, seed=0)
    joint = hw.cross(9)
    seen = np.concatenate([joint.block_indices(a, h)
                           for a, h in joint.block_slices(50)])
    assert np.array_equal(np.sort(seen), np.arange(len(joint)))
    for a_sl, h_sl in joint.block_slices(50):
      n_rows = (a_sl.stop - a_sl.start) * (h_sl.stop - h_sl.start)
      assert n_rows <= 50
    assert list(hw.cross(0).block_slices(10)) == []
    with pytest.raises(ValueError, match="chunk_size"):
      list(joint.block_slices(0))

  def test_block_indices_are_arch_major(self):
    hw = DesignSpace().sample_type_table("INT16", 4, seed=0)
    joint = hw.cross(3)
    idx = joint.block_indices(slice(1, 3), slice(2, 4))
    assert idx.tolist() == [1 * 4 + 2, 1 * 4 + 3, 2 * 4 + 2, 2 * 4 + 3]


class TestLayerStackSlice:
  def test_slice_rows_bit_identical(self):
    from repro.core.dataflow import LayerStack
    from repro.core.supernet import arch_to_layers
    rng = np.random.RandomState(2)
    archs = [ArchChoice(tuple((int(rng.choice(r)), int(rng.choice(c)))
                              for r, c in SEARCH_SPACE)) for _ in range(5)]
    stack = LayerStack.from_layer_lists(
        [arch_to_layers(a, image_size=16) for a in archs])
    sub = stack.slice_archs(1, 4)
    assert sub.n_archs == 3 and sub.max_layers == stack.max_layers
    assert np.array_equal(sub.features(), stack.features()[1:4])
    assert np.array_equal(sub.valid, stack.valid[1:4])


# ---------------------------------------------------------------------------
# session-level streaming (end to end, both backends)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_layers():
  return get_network("resnet20")[:4]


@pytest.fixture(scope="module")
def arch_accs():
  rng = np.random.RandomState(7)
  archs = [ArchChoice(tuple((int(rng.choice(r)), int(rng.choice(c)))
                            for r, c in SEARCH_SPACE)) for _ in range(4)]
  return list(zip(archs, rng.uniform(0.5, 0.95, len(archs))))


class TestSessionStreaming:
  COLS = ("perf_per_area", "energy_mj")

  def test_stream_explore_matches_one_shot(self, small_layers):
    sess = ExplorationSession(VectorOracleBackend(chunk_size=64))
    frame = sess.explore(small_layers, "net", n_per_type=40, seed=4)
    res = sess.explore(
        small_layers, "net", n_per_type=40, seed=4, stream=True,
        reducers={"pareto": ParetoAccumulator(self.COLS),
                  "top": TopKAccumulator(9, by="energy_mj")},
        chunk_size=17, workers=3)
    assert res.n_rows == len(frame)
    want = frame.select(frame.pareto(self.COLS))
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(getattr(res["pareto"], col),
                            getattr(want, col)), col
    want_top = frame.top_k(9, by="energy_mj")
    assert np.array_equal(res["top"].latency_s, want_top.latency_s)
    # default reducer set + serial path
    res2 = sess.explore(small_layers, "net", n_per_type=40, seed=4,
                        stream=True, chunk_size=1000, workers=1)
    assert np.array_equal(res2["pareto"].latency_s, want.latency_s)

  def test_stream_co_explore_matches_one_shot(self, arch_accs):
    cols = ("top1_err", "energy_mj", "area_mm2")
    sess = ExplorationSession(VectorOracleBackend(chunk_size=512))
    frame = sess.co_explore(arch_accs, n_hw_per_type=10, seed=3,
                            image_size=16)
    res = sess.co_explore(
        arch_accs, n_hw_per_type=10, seed=3, image_size=16, stream=True,
        reducers={"pareto": ParetoAccumulator(cols),
                  "top": TopKAccumulator(7, by="energy_mj")},
        chunk_size=37, workers=4)
    assert res.n_rows == len(frame)
    want = frame.select(frame.pareto(cols))
    got = res["pareto"]
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(getattr(got, col), getattr(want, col)), col
    assert np.array_equal(got.extra["arch_id"], want.extra["arch_id"])
    assert got.arch_lookup == want.arch_lookup
    assert got.arch_at(0) is not None
    want_top = frame.top_k(7, by="energy_mj")
    assert np.array_equal(res["top"].latency_s, want_top.latency_s)

  def test_stream_polynomial_backend(self, small_layers, arch_accs):
    backend = PolynomialBackend.fit(pe_types=("INT16", "LightPE-1"),
                                    degree=3, n_train=80,
                                    layers=small_layers, seed=0)
    space = DesignSpace(pe_types=("INT16", "LightPE-1"))
    sess = ExplorationSession(backend, space)
    frame = sess.explore(small_layers, "net", n_per_type=30, seed=4,
                         vectorized=True)
    res = sess.explore(small_layers, "net", n_per_type=30, seed=4,
                       stream=True, chunk_size=13, workers=2)
    want = frame.select(frame.pareto(self.COLS))
    assert np.array_equal(res["pareto"].latency_s, want.latency_s)
    # joint streaming through the fitted models
    co = sess.co_explore(arch_accs, n_hw_per_type=6, seed=3, image_size=16,
                         vectorized=True)
    cols = ("top1_err", "energy_mj", "area_mm2")
    res_co = sess.co_explore(arch_accs, n_hw_per_type=6, seed=3,
                             image_size=16, stream=True, chunk_size=11,
                             workers=2)
    want_co = co.select(co.pareto(cols))
    assert np.array_equal(res_co["pareto"].latency_s, want_co.latency_s)

  def test_auto_threshold_routes_through_engine(self, small_layers,
                                                monkeypatch):
    import repro.explore.session as session_mod
    sess = ExplorationSession(VectorOracleBackend(chunk_size=64))
    base = sess.explore(small_layers, "net", n_per_type=25, seed=4)
    assert "streamed" not in base.meta
    monkeypatch.setattr(session_mod, "STREAM_AUTO_MIN_ROWS", 50)
    auto = sess.explore(small_layers, "net", n_per_type=25, seed=4)
    assert auto.meta["streamed"] == 1.0 and auto.meta["workers"] >= 1
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(getattr(auto, col), getattr(base, col)), col
    assert auto.table is not None
    assert auto.config_at(3) == base.config_at(3)

  def test_auto_threshold_co_explore(self, arch_accs, monkeypatch):
    import repro.explore.session as session_mod
    sess = ExplorationSession(VectorOracleBackend(chunk_size=512))
    base = sess.co_explore(arch_accs, n_hw_per_type=8, seed=3, image_size=16)
    monkeypatch.setattr(session_mod, "STREAM_AUTO_MIN_ROWS", 10)
    auto = sess.co_explore(arch_accs, n_hw_per_type=8, seed=3, image_size=16)
    assert auto.meta["streamed"] == 1.0
    assert len(auto) == len(base)
    for col in ("latency_s", "power_mw", "area_mm2"):
      assert np.array_equal(getattr(auto, col), getattr(base, col)), col
    assert np.array_equal(auto.extra["arch_id"], base.extra["arch_id"])

  def test_stream_requires_table_backend(self, small_layers):
    from repro.explore import OracleBackend
    sess = ExplorationSession(OracleBackend())
    with pytest.raises(ValueError, match="evaluate_table"):
      sess.explore(small_layers, "net", n_per_type=2, stream=True)
    with pytest.raises(ValueError, match="co_evaluate_table"):
      sess.co_explore([(object(), 0.9)], n_hw_per_type=2, stream=True)

  def test_reducers_require_stream(self, small_layers):
    sess = ExplorationSession(VectorOracleBackend())
    with pytest.raises(ValueError, match="stream=True"):
      sess.explore(small_layers, "net", n_per_type=2,
                   reducers={"p": ParetoAccumulator()})
    with pytest.raises(ValueError, match="stream=True"):
      sess.co_explore([(object(), 0.9)], n_hw_per_type=2,
                      reducers={"p": ParetoAccumulator()})

  def test_stream_rejects_measure_oracle(self, small_layers):
    sess = ExplorationSession(VectorOracleBackend())
    with pytest.raises(ValueError, match="one-shot"):
      sess.explore(small_layers, "net", n_per_type=2, stream=True,
                   measure_oracle=1)


# ---------------------------------------------------------------------------
# failure semantics: chunk-indexed errors, pool cancellation, accounting
# ---------------------------------------------------------------------------

class TestFailureSemantics:

  @staticmethod
  def tasks_with_bomb(n_chunks, bomb_at, rng_seed=0, rows=6):
    from repro.explore import ChunkTask, Rung
    rng = np.random.RandomState(rng_seed)
    frames = [random_frame(rng, rows) for _ in range(n_chunks)]

    def make(ci):
      def run():
        if ci == bomb_at:
          raise ValueError(f"chunk {ci} exploded")
        idx = np.arange(ci * rows, (ci + 1) * rows, dtype=np.int64)
        return frames[ci], idx
      return ChunkTask(index=ci, rungs=(Rung("numpy", run),))
    return [make(ci) for ci in range(n_chunks)]

  def test_serial_error_carries_chunk_index(self):
    from repro.explore import ChunkError
    from repro.explore.streaming import run_stream
    with pytest.raises(ChunkError) as err:
      run_stream(self.tasks_with_bomb(8, bomb_at=5),
                 {"pareto": ParetoAccumulator(("latency_s", "power_mw"))})
    assert err.value.chunk_index == 5
    assert "ValueError" in str(err.value)

  def test_pool_error_carries_chunk_index_and_cancels(self):
    from repro.explore import ChunkError
    from repro.explore.streaming import run_stream
    with pytest.raises(ChunkError) as err:
      run_stream(self.tasks_with_bomb(24, bomb_at=7),
                 {"pareto": ParetoAccumulator(("latency_s", "power_mw"))},
                 workers=3)
    assert err.value.chunk_index == 7

  def test_meta_failure_accounting_keys(self, small_layers):
    sess = ExplorationSession(VectorOracleBackend(chunk_size=64))
    res = sess.explore(small_layers, "net", n_per_type=20, seed=4,
                       stream=True, chunk_size=16)
    for key in ("n_retries", "n_demotions", "n_resumed_chunks",
                "n_overflows"):
      assert res.meta[key] == 0.0, key  # healthy run: all zero, all present
