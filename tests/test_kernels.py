"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.int8_matmul import ops as i8_ops
from repro.kernels.int8_matmul.kernel import int8_matmul_pallas
from repro.kernels.int8_matmul.ref import int8_matmul_ref
from repro.kernels.pow2_matmul import ops as pow2_ops
from repro.kernels.quant_decode_attn import ops as attn_ops
from repro.kernels.rwkv6_scan import ops as wkv_ops


class TestPow2Matmul:
  @pytest.mark.parametrize("k_terms", [1, 2])
  @pytest.mark.parametrize("shape", [(4, 96, 130), (128, 128, 128),
                                     (257, 300, 514), (1, 64, 64)])
  @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
  def test_vs_oracle(self, k_terms, shape, dtype):
    m, k, n = shape
    key = jax.random.PRNGKey(m * n + k_terms)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k), dtype)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.05
    pw = pow2_ops.quantize_weights(w, k_terms=k_terms)
    got = pow2_ops.pow2_matmul(x, pw, interpret=True)
    want = pow2_ops.pow2_matmul_reference(x, pw)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    err = float(jnp.max(jnp.abs(got - want))
                / (jnp.max(jnp.abs(want)) + 1e-9))
    assert err < tol, err

  def test_hbm_bytes_savings(self):
    w = jax.random.normal(jax.random.PRNGKey(0), (512, 512)) * 0.1
    p1 = pow2_ops.quantize_weights(w, 1)
    p2 = pow2_ops.quantize_weights(w, 2)
    dense = 512 * 512 * 2  # bf16
    assert p1.hbm_bytes < dense / 3.5   # ~4x (+ scales)
    assert p2.hbm_bytes < dense / 1.9   # ~2x

  def test_batched_leading_dims(self):
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 96)) * 0.1
    pw = pow2_ops.quantize_weights(w, 1)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 64))
    out = pow2_ops.pow2_matmul(x, pw, interpret=True)
    assert out.shape == (2, 3, 96)


class TestInt8Matmul:
  @pytest.mark.parametrize("shape", [(5, 64, 70), (128, 128, 128),
                                     (200, 384, 250)])
  def test_kernel_exact_vs_ref_on_codes(self, shape):
    """Kernel vs oracle on IDENTICAL quantized inputs: bit-exact."""
    m, k, n = shape
    key = jax.random.PRNGKey(m + n)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n)) * 0.1
    W = i8_ops.quantize_weights(w)
    xq, xs = i8_ops.quantize_activations(x)
    from repro.kernels import common
    xq_p, m0 = common.pad_to(xq, 0, common.BM)
    xq_p, _ = common.pad_to(xq_p, 1, common.BK)
    xs_p, _ = common.pad_to(xs.reshape(-1), 0, common.BM)
    wq, _ = common.pad_to(W.codes, 0, common.BK)
    wq, _ = common.pad_to(wq, 1, common.BN)
    ws, _ = common.pad_to(W.scale, 0, common.BN)
    got = int8_matmul_pallas(xq_p, wq, xs_p, ws, interpret=True)[:m0, :n]
    want = int8_matmul_ref(xq, W.codes, xs.reshape(-1), W.scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=0)

  def test_end_to_end_close_to_float(self):
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (32, 256))
    w = jax.random.normal(jax.random.fold_in(key, 1), (256, 128)) * 0.05
    W = i8_ops.quantize_weights(w)
    got = i8_ops.int8_matmul(x, W, interpret=True)
    ref = x @ w
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert rel < 0.02, rel


class TestQuantDecodeAttn:
  @pytest.mark.parametrize("dims", [(2, 8, 4, 512, 64, 500),
                                    (1, 4, 1, 300, 128, 130),
                                    (3, 6, 6, 1024, 64, 1024),
                                    (2, 4, 2, 64, 64, 1)])
  def test_vs_oracle(self, dims):
    b, h, hkv, s, d, length = dims
    ks = jax.random.split(jax.random.PRNGKey(b * s + h), 3)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    kc, ksc, vc, vsc = attn_ops.quantize_kv(k, v)
    lens = jnp.full((b,), length, jnp.int32)
    got = attn_ops.quant_decode_attn(q, kc, ksc, vc, vsc, lens,
                                     interpret=True)
    want = attn_ops.quant_decode_attn_reference(q, kc, ksc, vc, vsc, lens)
    err = float(jnp.max(jnp.abs(got - want))
                / (jnp.max(jnp.abs(want)) + 1e-9))
    assert err < 2e-5, err

  def test_int8_kv_close_to_fp(self):
    """int8 KV attention stays within ~1% of full-precision attention."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, h, s, d = 2, 4, 256, 64
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    kc, ksc, vc, vsc = attn_ops.quantize_kv(k, v)
    lens = jnp.full((b,), s, jnp.int32)
    got = attn_ops.quant_decode_attn_reference(q, kc, ksc, vc, vsc, lens)
    from repro.models.attention import decode_attention
    ref = decode_attention(q, k, v, lens)
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert rel < 0.01, rel


class TestWkv6:
  @pytest.mark.parametrize("dims", [(2, 4, 128, 64, 64), (1, 2, 100, 32, 32),
                                    (2, 3, 256, 64, 16)])
  def test_vs_sequential_oracle(self, dims):
    b, h, t, d, chunk = dims
    ks = jax.random.split(jax.random.PRNGKey(sum(dims)), 6)
    r = jax.random.normal(ks[0], (b, h, t, d)) * 0.5
    k = jax.random.normal(ks[1], (b, h, t, d)) * 0.5
    v = jax.random.normal(ks[2], (b, h, t, d))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, h, t, d))))
    u = jax.random.normal(ks[4], (h, d)) * 0.3
    s0 = jax.random.normal(ks[5], (b, h, d, d)) * 0.1
    go, gs = wkv_ops.wkv6(r, k, v, w, u, s0, interpret=True, chunk=chunk)
    wo, ws = wkv_ops.wkv6_reference(r, k, v, w, u, s0)
    assert float(jnp.max(jnp.abs(go - wo))
                 / (jnp.max(jnp.abs(wo)) + 1e-9)) < 2e-5
    assert float(jnp.max(jnp.abs(gs - ws))
                 / (jnp.max(jnp.abs(ws)) + 1e-9)) < 2e-5

  @given(st.integers(0, 10_000))
  @settings(max_examples=8, deadline=None)
  def test_property_random_decay(self, seed):
    """Arbitrary decays in (0,1): chunked == sequential."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    b, h, t, d = 1, 2, 48, 16
    r, k, v = (jax.random.normal(ks[i], (b, h, t, d)) for i in range(3))
    w = jax.random.uniform(ks[3], (b, h, t, d), minval=0.05, maxval=0.999)
    u = jax.random.normal(ks[4], (h, d)) * 0.2
    go, gs = wkv_ops.wkv6(r, k, v, w, u, interpret=True, chunk=16)
    wo, ws = wkv_ops.wkv6_reference(r, k, v, w, u)
    assert float(jnp.max(jnp.abs(go - wo))) < 1e-3 * float(
        jnp.max(jnp.abs(wo)) + 1.0)

  def test_decode_step_matches_kernel(self):
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    b, h, t, d = 1, 2, 8, 32
    r, k, v = (jax.random.normal(ks[i], (b, h, t, d)) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, h, t, d))))
    u = jax.random.normal(ks[4], (h, d)) * 0.3
    state = jnp.zeros((b, h, d, d))
    outs = []
    for i in range(t):
      o, state = wkv_ops.wkv6_decode_step(
          r[:, :, i], k[:, :, i], v[:, :, i], w[:, :, i], u, state)
      outs.append(o)
    seq_o = jnp.stack(outs, axis=2)
    ker_o, ker_s = wkv_ops.wkv6(r, k, v, w, u, interpret=True, chunk=8)
    np.testing.assert_allclose(np.asarray(seq_o), np.asarray(ker_o),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(ker_s),
                               rtol=1e-4, atol=1e-4)


class TestFlashAttentionKernel:
  @pytest.mark.parametrize("dims", [(2, 128, 4, 4, 64, True, 0),
                                    (1, 300, 8, 2, 64, True, 0),
                                    (2, 256, 4, 4, 32, False, 0),
                                    (1, 256, 4, 2, 64, True, 64)])
  def test_vs_oracle(self, dims):
    from repro.kernels.flash_attention import ops as fa
    b, s, h, hkv, d, causal, window = dims
    ks = jax.random.split(jax.random.PRNGKey(s + h), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    got = fa.flash_attention(q, k, v, causal=causal, window=window,
                             interpret=True, bq=64, bk=64)
    want = fa.flash_attention_reference(q, k, v, causal=causal,
                                        window=window)
    err = float(jnp.max(jnp.abs(got - want))
                / (jnp.max(jnp.abs(want)) + 1e-9))
    assert err < 2e-5, err

  def test_matches_model_attention_path(self):
    """Kernel == the pure-JAX training attention (same math, two paths)."""
    from repro.kernels.flash_attention import ops as fa
    from repro.models.attention import flash_attention as model_fa
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    b, s, h, d = 1, 96, 4, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    got = fa.flash_attention(q, k, v, interpret=True, bq=32, bk=32)
    want = model_fa(q, k, v, chunk_q=32, chunk_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
