"""End-to-end system tests: the paper's full pipeline + the framework's
train->checkpoint->serve path, plus the dry-run/roofline machinery."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(0)


class TestPaperPipeline:
  """QUIDAM end to end: oracle -> fit -> DSE -> Pareto -> claims."""

  @pytest.fixture(scope="class")
  def explorer(self):
    from repro.core import dse
    from repro.core.workloads import get_network
    return dse.DesignSpaceExplorer(degree=4, n_train=160,
                                   layers=get_network("resnet20"))

  def test_dse_reproduces_orderings(self, explorer):
    from repro.core import dse
    from repro.core.workloads import get_network
    res = explorer.explore(get_network("resnet20"), "resnet20",
                           n_per_type=120, measure_oracle=0)
    ppa_n, en_n = dse.normalized_metrics(res.points)
    types = np.asarray([p.cfg.pe_type for p in res.points])
    best_ppa = {t: ppa_n[types == t].max()
                for t in ("FP32", "INT16", "LightPE-1", "LightPE-2")}
    best_en = {t: en_n[types == t].min()
               for t in ("FP32", "INT16", "LightPE-1", "LightPE-2")}
    # paper's qualitative structure
    assert best_ppa["LightPE-1"] > best_ppa["INT16"] > best_ppa["FP32"]
    assert best_ppa["LightPE-2"] > best_ppa["INT16"]
    assert best_en["LightPE-1"] < best_en["INT16"] < best_en["FP32"]

  def test_lm_bridge_workloads(self, explorer):
    """Beyond-paper: the PPA models evaluate zoo LM architectures too."""
    from repro.core import dse, ppa as ppa_lib
    from repro.core.workloads import lm_block_workload
    from repro.configs import get_config
    cfg = get_config("qwen3-0.6b")
    layers = lm_block_workload("blk", tokens=1024, d_model=cfg.d_model,
                               n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                               head_dim=cfg.head_dim, d_ff=cfg.d_ff)
    cfgs = ppa_lib.sample_configs("LightPE-1", 20, seed=5) + \
        ppa_lib.sample_configs("INT16", 20, seed=6)
    pts = dse.evaluate_with_models(explorer.models, cfgs, layers,
                                   "qwen3-block")
    assert all(p.latency_s > 0 and p.area_mm2 > 0 for p in pts)


class TestTrainServeRoundtrip:
  @pytest.mark.slow
  def test_train_then_serve(self, tmp_path):
    """Train a tiny model until loss drops, checkpoint, serve from the
    restored params — the full production loop at smoke scale."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.data.synthetic import (DataCursor, MarkovTokenStream,
                                      TokenStreamConfig, token_batches)
    from repro.models.model import build_model
    from repro.serve.engine import EngineConfig, ServeEngine
    from repro.train import checkpoint as ckpt_lib
    from repro.train import optimizer as opt_lib
    from repro.train import train_step as ts_lib
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = reduce_for_smoke(get_config("qwen3-0.6b"))
    model = build_model(cfg)
    tcfg = ts_lib.TrainConfig(optimizer=opt_lib.AdamWConfig(
        lr=3e-3, warmup_steps=0, schedule="constant", weight_decay=0.0))
    stream = MarkovTokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                                 branching=4))
    cursor = DataCursor()
    trainer = Trainer(model, tcfg,
                      TrainerConfig(total_steps=20, ckpt_every=20,
                                    log_every=100, ckpt_dir=str(tmp_path)),
                      token_batches(stream, 8, 48, cursor), cursor=cursor,
                      key=KEY)
    hist = trainer.run(20)
    assert hist[-1]["loss"] < hist[0]["loss"]

    _, restored, _ = ckpt_lib.restore_checkpoint(str(tmp_path))
    params = jax.tree_util.tree_map(jnp.asarray, restored["params"])
    engine = ServeEngine(model, params, EngineConfig(
        batch_slots=2, max_len=64, prompt_bucket=16))
    engine.submit(np.arange(10) % cfg.vocab_size, max_new_tokens=4)
    out = engine.run_until_drained()
    assert len(out) == 1 and len(list(out.values())[0]) == 4


class TestDryRunMachinery:
  def test_collective_parser(self):
    from repro.launch.dryrun import parse_collectives
    hlo = """
  %all-reduce.1 = f32[128,256]{1,0} all-reduce(%x), metadata={op_name="jit(f)/while/body/dot"}
  %all-gather-start.2 = bf16[64]{0} all-gather-start(%y), metadata={op_name="jit(f)/gather"}
  %all-gather-done.2 = bf16[64]{0} all-gather-done(%z), metadata={op_name="jit(f)/gather"}
  backend_config={"known_trip_count":{"n":"28"}}
"""
    out = parse_collectives(hlo)
    assert out["static"]["all-reduce"]["bytes"] == 128 * 256 * 4
    assert out["static"]["all-gather"]["count"] == 1  # start/done deduped
    assert out["by_loop_depth"]["1"]["all-reduce"]["count"] == 1
    assert out["by_loop_depth"]["0"]["all-gather"]["count"] == 1
    assert out["known_trip_counts"] == [28]

  def test_roofline_terms_positive(self):
    from repro.launch.roofline import analytic_terms, dominant
    for arch, shape in (("olmo-1b", "train_4k"),
                        ("mixtral-8x22b", "decode_32k"),
                        ("rwkv6-1.6b", "long_500k"),
                        ("whisper-base", "prefill_32k")):
      t = analytic_terms(arch, shape, "16x16")
      assert t["compute_s"] > 0 and t["memory_s"] > 0
      assert dominant(t) in ("compute", "memory", "collective")

  def test_decode_memory_term_halves_with_int8_kv(self):
    import dataclasses
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    from repro.launch.roofline import _kv_cache_bytes
    cfg = get_config("minitron-4b")
    spec = SHAPES["decode_32k"]
    full = _kv_cache_bytes(cfg, spec)
    quant = _kv_cache_bytes(dataclasses.replace(cfg, kv_quant="int8"), spec)
    assert abs(quant / full - 0.5) < 0.01

  def test_dryrun_artifacts_complete(self):
    """If the sweep artifacts exist, assert the deliverable: all 80 cells
    either ok or documented-skip, zero failures."""
    import glob, os
    files = glob.glob("results/dryrun/*__pod*.json")
    base = [f for f in files if "__kv" not in f and "__fsdp" not in f
            and "__pbf16" not in f and "__mb" not in f]
    if len(base) < 80:
      pytest.skip("dry-run sweep artifacts not present")
    statuses = {}
    for f in base:
      d = json.load(open(f))
      statuses.setdefault(d["status"], []).append(os.path.basename(f))
    assert not statuses.get("failed"), statuses.get("failed")
    assert len(statuses.get("ok", [])) == 66
    assert len(statuses.get("skipped", [])) == 14
