"""Distribution tests: sharding rules, compressed collectives, fault
tolerance, serving engine, supernet, co-exploration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, reduce_for_smoke
from repro.models.model import build_model
from repro.parallel import collectives, sharding as sh
from repro.train.fault_tolerance import (ElasticMeshPlanner,
                                         StragglerMonitor, StepFailure,
                                         retrying)

KEY = jax.random.PRNGKey(0)


def _fake_mesh(shape=(2, 2), axes=("data", "model")):
  devs = jax.devices()
  if len(devs) < np.prod(shape):
    # abstract mesh purely for spec computation; signature differs across
    # jax versions: (shape, axes) vs (((name, size), ...),)
    try:
      return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:
      return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))
  return jax.make_mesh(shape, axes,
                       axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
                       devices=devs[: int(np.prod(shape))])


class TestParamSpecs:
  def test_adaptive_divisibility(self):
    mesh = _fake_mesh((2, 2))
    params = {"blocks": {"sub0": {"mix": {
        "wq": jnp.zeros((8, 4, 6)),     # stacked; 6 % 2 == 0 -> model
        "wkv": jnp.zeros((8, 4, 3)),    # 3 % 2 != 0 -> replicate dim
    }}}}
    specs = sh.param_specs(params, mesh)
    wq = specs["blocks"]["sub0"]["mix"]["wq"]
    wkv = specs["blocks"]["sub0"]["mix"]["wkv"]
    assert wq == P(None, "data", "model")
    assert wkv == P(None, "data", None)

  def test_embed_vocab_sharded(self):
    mesh = _fake_mesh((2, 2))
    specs = sh.param_specs({"embed": jnp.zeros((512, 64))}, mesh)
    assert specs["embed"] == P("model", "data")

  def test_every_leaf_gets_spec(self):
    cfg = reduce_for_smoke(get_config("jamba-1.5-large"))
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, KEY)
    mesh = _fake_mesh((2, 2))
    specs = sh.param_specs(shapes, mesh)
    n_params = len(jax.tree_util.tree_leaves(shapes))
    n_specs = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_params == n_specs

  def test_cache_specs_long_context_seq_sharding(self):
    """batch=1 decode: cache seq dim shards on data."""
    mesh = _fake_mesh((4, 2))
    cache = {"layers": {"sub0": {
        "k": jax.ShapeDtypeStruct((3, 1, 2, 64, 8), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((3, 1, 2, 64, 8), jnp.bfloat16)}},
        "length": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = sh.cache_specs(cache, mesh, batch=1)
    assert specs["layers"]["sub0"]["k"] == P(None, None, "model", "data",
                                             None)


class TestCompressedCollectives:
  def test_quantize_dequantize_error_bound(self):
    x = jax.random.normal(KEY, (1000,))
    q = collectives.quantize_dequantize(x)
    # block absmax / 127 error bound
    assert float(jnp.max(jnp.abs(q - x))) <= float(
        jnp.max(jnp.abs(x))) / 127.0 + 1e-6

  def test_error_feedback_reduces_bias(self):
    """EF compression: accumulated compressed sum tracks the true sum."""
    ef = collectives.ErrorFeedback
    g_true = jax.random.normal(KEY, (512,)) * 1e-3
    res = ef.init({"g": g_true})
    acc_c = jnp.zeros_like(g_true)
    for i in range(20):
      comp, res = ef.apply({"g": g_true}, res)
      acc_c = acc_c + comp["g"]
    # relative error of accumulated compressed stream vs true
    rel = float(jnp.linalg.norm(acc_c - 20 * g_true)
                / jnp.linalg.norm(20 * g_true))
    assert rel < 0.02, rel

  @pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >=2 devices")
  def test_compressed_psum_matches_psum(self):
    mesh = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
    from jax.experimental.shard_map import shard_map
    x = jax.random.normal(KEY, (2, 256))

    def f(x):
      return collectives.compressed_psum_int8(x[0], "data")

    got = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P(),
                    check_rep=False)(x)
    want = jnp.sum(x, axis=0)
    assert float(jnp.max(jnp.abs(got - want))) < float(
        jnp.max(jnp.abs(x))) / 40.0


class TestFaultTolerance:
  def test_straggler_detection(self):
    mon = StragglerMonitor(min_samples=5)
    for step in range(10):
      for h in range(8):
        t = 1.0 if h != 3 else 2.5   # host 3 is slow
        mon.record(f"host{h}", t + 0.01 * step)
    assert mon.stragglers() == ["host3"]

  def test_no_false_positives(self):
    mon = StragglerMonitor(min_samples=5)
    rng = np.random.RandomState(0)
    for step in range(20):
      for h in range(8):
        mon.record(f"host{h}", 1.0 + rng.normal(0, 0.02))
    assert mon.stragglers() == []

  def test_elastic_plan_keeps_tp(self):
    planner = ElasticMeshPlanner(model_parallel=16, global_batch=256,
                                 batch_per_dp=16)
    plan = planner.plan(healthy_devices=208)   # lost 3 hosts of 16 devs
    assert plan is not None
    assert plan.model == 16
    assert plan.data <= 13
    assert plan.devices <= 208
    assert 256 % (plan.data * plan.pods) == 0

  def test_elastic_plan_impossible(self):
    planner = ElasticMeshPlanner(model_parallel=16, global_batch=256,
                                 batch_per_dp=16)
    assert planner.plan(healthy_devices=8) is None

  def test_retrying_recovers(self):
    calls = {"n": 0}

    def flaky():
      calls["n"] += 1
      if calls["n"] < 3:
        raise RuntimeError("transient")
      return "ok"

    assert retrying(flaky, max_retries=3)() == "ok"

  def test_retrying_escalates(self):
    def always_fails():
      raise RuntimeError("hard")

    with pytest.raises(StepFailure):
      retrying(always_fails, max_retries=1)()


class TestServeEngine:
  def test_batched_requests_complete(self):
    from repro.serve.engine import EngineConfig, ServeEngine
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    model = build_model(cfg)
    params = model.init(KEY)
    eng = ServeEngine(model, params, EngineConfig(
        batch_slots=2, max_len=64, prompt_bucket=16))
    rng = np.random.RandomState(0)
    uids = [eng.submit(rng.randint(0, cfg.vocab_size, size=8),
                       max_new_tokens=5) for _ in range(4)]
    out = eng.run_until_drained()
    assert set(out) == set(uids)
    assert all(len(v) == 5 for v in out.values())

  def test_deadline_evicts_queued_and_active(self):
    from repro.explore.service import Deadline
    from repro.serve.engine import EngineConfig, ServeEngine
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    model = build_model(cfg)
    params = model.init(KEY)
    eng = ServeEngine(model, params, EngineConfig(
        batch_slots=1, max_len=64, prompt_bucket=16))
    rng = np.random.RandomState(0)
    t = {"now": 0.0}
    # u1 has a deadline that expires while it decodes; u2's expires
    # while it sits behind u1 in the queue; u3 is unconstrained
    u1 = eng.submit(rng.randint(0, cfg.vocab_size, size=8),
                    max_new_tokens=50,
                    deadline=Deadline(1.0, clock=lambda: t["now"]))
    u2 = eng.submit(rng.randint(0, cfg.vocab_size, size=8),
                    max_new_tokens=5,
                    deadline=Deadline(1.0, clock=lambda: t["now"]))
    u3 = eng.submit(rng.randint(0, cfg.vocab_size, size=8),
                    max_new_tokens=5)
    eng._admit()        # u1 takes the slot while the deadline is live
    t["now"] = 2.0      # both deadlines expire
    out = eng.run_until_drained()
    assert set(out) == {u1, u2, u3}
    assert 0 < len(out[u1]) < 50   # partial generation kept
    assert out[u2] == []           # evicted before any prefill
    assert len(out[u3]) == 5       # neighbor unaffected
    assert eng.n_evicted == 2

  def test_seconds_deadline_coerced(self):
    from repro.explore.service import Deadline
    from repro.serve.engine import EngineConfig, ServeEngine
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    model = build_model(cfg)
    params = model.init(KEY)
    eng = ServeEngine(model, params, EngineConfig(
        batch_slots=1, max_len=64, prompt_bucket=16))
    uid = eng.submit(np.arange(8) % cfg.vocab_size, max_new_tokens=3,
                     deadline=30.0)
    assert isinstance(eng.queue[0].deadline, Deadline)
    out = eng.run_until_drained()
    assert len(out[uid]) == 3

  def test_greedy_determinism(self):
    from repro.serve.engine import EngineConfig, ServeEngine
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    model = build_model(cfg)
    params = model.init(KEY)
    prompt = np.arange(8) % cfg.vocab_size
    outs = []
    for _ in range(2):
      eng = ServeEngine(model, params, EngineConfig(
          batch_slots=1, max_len=64, prompt_bucket=16))
      eng.submit(prompt, max_new_tokens=6)
      outs.append(list(eng.run_until_drained().values())[0])
    assert outs[0] == outs[1]


class TestSupernetBridge:
  def test_arch_to_layers(self):
    from repro.core.cnn import max_arch
    from repro.core.supernet import arch_to_layers, space_size
    assert space_size() == 110592
    layers = arch_to_layers(max_arch(), image_size=32)
    assert len(layers) == 13   # VGG-16's conv count
    assert layers[0].C == 3 and layers[-1].F == 512

  @pytest.mark.slow
  def test_mask_equals_slice_semantics(self):
    """Masked supernet == manually sliced subnet (exactness property)."""
    from repro.core import cnn
    params = cnn.init_vgg_supernet(KEY, 10)
    arch = cnn.ArchChoice(((1, 40), (2, 96), (1, 160), (2, 320), (1, 320)))
    imgs = jax.random.normal(KEY, (2, 16, 16, 3))
    got = cnn.apply_vgg(params, imgs, arch)
    # manual slice reference
    x = imgs
    c_prev = 3
    for si, ((r_use, c_use), stage) in enumerate(zip(arch.stages,
                                                     params["stages"])):
      for r in range(r_use):
        w = stage[r]["w"]
        # full-width conv on zero-padded channels == sliced conv
        xw = jnp.pad(x, ((0, 0), (0, 0), (0, 0),
                         (0, w.shape[2] - x.shape[-1])))
        y = cnn.conv2d(xw, w)[..., :c_use]
        y = cnn.batch_norm(y, stage[r]["scale"][:c_use],
                           stage[r]["bias"][:c_use])
        x = jax.nn.relu(y)
      if x.shape[1] > 1:
        x = cnn.maxpool(x)
    feats = jnp.mean(x, axis=(1, 2))
    want = jnp.einsum("bc,cn->bn",
                      jnp.pad(feats, ((0, 0),
                                      (0, params["head"].shape[0]
                                       - feats.shape[-1]))),
                      params["head"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
