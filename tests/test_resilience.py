"""Fault-tolerant exploration: retry, degradation, resume, injection.

Covers the PR-8 acceptance matrix:
  * ``retrying``/``RetryPolicy`` back off through an injectable sleep
    (tests never wall-wait) and raise ``StepFailure`` on exhaustion;
  * ``FaultPlan`` schedules are exactly reproducible and fire each
    fault at most ``times`` times;
  * the ladder demotes past dead/hung rungs, never absorbs
    ``SweepKilled``, and counts every retry/demotion;
  * the ``SweepJournal`` round-trips reducer state atomically and
    treats corrupt/mismatched records as a fresh start;
  * killing a streamed co-exploration at *every* chunk boundary and
    resuming reproduces the uninterrupted reductions bit-identically;
  * on a ``jit=True`` backend, injected device faults degrade chunks to
    the numpy rung with unchanged results (exact-codegen parity).
"""
import pickle
import threading

import numpy as np
import pytest

from repro.core.cnn import SEARCH_SPACE, ArchChoice
from repro.core.workloads import get_network
from repro.explore import (ChunkError, ChunkTask, DesignSpace,
                           ExplorationSession, Fault, FaultInjected,
                           FaultPlan, InjectedHang, ParetoAccumulator,
                           ResiliencePolicy, RetryPolicy, Rung,
                           StatsAccumulator, SweepJournal, SweepKilled,
                           TopKAccumulator, VectorOracleBackend, sweep_key)
from repro.explore.resilience import ChunkTimeout
from repro.train.fault_tolerance import StepFailure, retrying

METRICS = ("latency_s", "power_mw", "area_mm2")
COLS = ("perf_per_area", "energy_mj")


def no_wait() -> RetryPolicy:
  return RetryPolicy(sleep=lambda s: None)


def flaky(n_failures: int, result="ok", exc=RuntimeError):
  """Callable failing the first ``n_failures`` invocations."""
  state = {"calls": 0}

  def fn():
    state["calls"] += 1
    if state["calls"] <= n_failures:
      raise exc(f"transient #{state['calls']}")
    return result

  fn.state = state
  return fn


# ---------------------------------------------------------------------------
# the retry primitive (train.fault_tolerance.retrying + RetryPolicy)
# ---------------------------------------------------------------------------

class TestRetrying:

  def test_injected_sleep_sees_exponential_backoff(self):
    delays = []
    fn = flaky(2)
    out = retrying(fn, max_retries=2, sleep=delays.append,
                   base_delay=0.5, backoff=3.0)()
    assert out == "ok" and fn.state["calls"] == 3
    assert delays == [0.5, 1.5]

  def test_no_sleep_after_final_attempt(self):
    delays = []
    with pytest.raises(StepFailure):
      retrying(flaky(99), max_retries=2, sleep=delays.append)()
    assert len(delays) == 2  # backs off between attempts, not before raising

  def test_non_retryable_propagates_immediately(self):
    delays = []
    fn = flaky(1, exc=ValueError)
    with pytest.raises(ValueError):
      retrying(fn, max_retries=5, sleep=delays.append)()
    assert fn.state["calls"] == 1 and delays == []


class TestRetryPolicy:

  def test_on_retry_counts_reexecutions_exactly(self):
    seen = []
    out = no_wait().call(flaky(2), on_retry=lambda a, e: seen.append(a))
    assert out == "ok" and seen == [0, 1]

  def test_exhaustion_raises_stepfailure(self):
    seen = []
    with pytest.raises(StepFailure):
      no_wait().call(flaky(99), on_retry=lambda a, e: seen.append(a))
    assert seen == [0, 1]  # the terminal failure is not a retry


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------

class TestFaultPlan:

  def test_seeded_schedule_reproducible(self):
    mk = lambda: FaultPlan.seeded(11, 50, p_raise=0.3, p_hang=0.2,
                                  p_kill=0.1)
    a, b = mk(), mk()
    assert a.faults == b.faults and len(a.faults) > 0
    assert FaultPlan.seeded(12, 50, p_raise=0.3).faults != a.faults

  def test_times_budget_exhausts(self):
    plan = FaultPlan([Fault("raise", 3, "device", times=2)])
    for _ in range(2):
      with pytest.raises(FaultInjected):
        plan.check("device", 3)
    plan.check("device", 3)  # budget spent: silent
    assert plan.n_fired == 2

  def test_layer_and_chunk_scoping(self):
    plan = FaultPlan([Fault("raise", 1, "device")])
    plan.check("backend", 1)  # wrong layer
    plan.check("device", 2)  # wrong chunk
    with pytest.raises(FaultInjected):
      plan.check("device", 1)

  def test_kill_and_hang_exception_types(self):
    plan = FaultPlan([Fault("kill", 0, "task"),
                      Fault("hang", 0, "device")])
    with pytest.raises(SweepKilled):
      plan.check("task", 0)
    with pytest.raises(InjectedHang):
      plan.check_resolve("device", 0)
    # SweepKilled must bypass retry-by-RuntimeError semantics entirely
    assert not issubclass(SweepKilled, RuntimeError)
    assert issubclass(FaultInjected, RuntimeError)
    assert issubclass(InjectedHang, ChunkTimeout)

  def test_validation(self):
    with pytest.raises(ValueError):
      Fault("explode", 0)
    with pytest.raises(ValueError):
      Fault("raise", 0, layer="cloud")
    with pytest.raises(ValueError):
      Fault("raise", 0, times=0)


# ---------------------------------------------------------------------------
# the degradation ladder (unit level, fake rungs)
# ---------------------------------------------------------------------------

class _FakePending:
  def __init__(self, fn):
    self._fn = fn

  def resolve(self):
    return self._fn()


def policy_of(**kw) -> ResiliencePolicy:
  kw.setdefault("retry", RetryPolicy(max_retries=1, sleep=lambda s: None))
  return ResiliencePolicy(**kw)


class TestLadder:

  def test_plain_callable_passes_through(self):
    assert policy_of().execute(lambda: 42) == 42

  def test_transient_healed_by_retry_alone(self):
    pol = policy_of()
    task = ChunkTask(0, (Rung("a", flaky(1, "healed")),))
    assert pol.execute(task) == "healed"
    assert pol.n_retries == 1 and pol.n_demotions == 0

  def test_dead_rung_demotes_to_next(self):
    pol = policy_of()
    task = ChunkTask(7, (Rung("device", flaky(99), layer="device"),
                         Rung("numpy", lambda: "fallback")))
    assert pol.execute(task) == "fallback"
    assert pol.n_demotions == 1
    assert pol.demotions == [(7, "device", "dispatch")]

  def test_all_rungs_dead_raises(self):
    pol = policy_of()
    task = ChunkTask(0, (Rung("a", flaky(99)), Rung("b", flaky(99))))
    with pytest.raises(StepFailure):
      pol.execute(task)
    assert pol.n_demotions == 1  # a -> b recorded; b's failure raised

  def test_sweepkilled_never_absorbed(self):
    def die():
      raise SweepKilled("kill -9")
    pol = policy_of()
    task = ChunkTask(0, (Rung("a", die), Rung("b", lambda: "nope")))
    with pytest.raises(SweepKilled):
      pol.execute(task)
    assert pol.n_retries == 0 and pol.n_demotions == 0

  def test_failed_resolution_demotes(self):
    boom = flaky(99)
    task = ChunkTask(4, (Rung("device", lambda: _FakePending(boom),
                              layer="device"),
                         Rung("numpy", lambda: "recomputed")))
    pol = policy_of()
    out = pol.execute(task)
    assert hasattr(out, "resolve")  # pending from a non-terminal rung
    assert out.resolve() == "recomputed"
    assert pol.demotions == [(4, "device", "resolve")]

  def test_injected_hang_demotes_without_waiting(self):
    plan = FaultPlan([Fault("hang", 2, "device")])
    task = ChunkTask(2, (Rung("device",
                              lambda: _FakePending(lambda: "from-device"),
                              layer="device"),
                         Rung("numpy", lambda: "from-host")))
    pol = policy_of(fault_plan=plan)
    assert pol.execute(task).resolve() == "from-host"
    assert pol.n_demotions == 1 and plan.n_fired == 1

  def test_watchdog_times_out_real_hang(self):
    hung = threading.Event()  # never set: resolve blocks forever

    def block():
      hung.wait(30.0)
      return "too-late"

    task = ChunkTask(0, (Rung("device", lambda: _FakePending(block),
                              layer="device"),
                         Rung("numpy", lambda: "rescued")))
    pol = policy_of(resolve_timeout=0.05)
    assert pol.execute(task).resolve() == "rescued"
    assert pol.demotions == [(0, "device", "resolve")]
    hung.set()  # unblock the abandoned daemon thread

  def test_terminal_rung_pending_not_guarded(self):
    # a pending from the LAST rung has nothing to demote to: it is
    # returned as-is (the engine resolves it in the dispatch window)
    pend = _FakePending(lambda: "direct")
    task = ChunkTask(0, (Rung("numpy", lambda: pend),))
    assert policy_of().execute(task) is pend


# ---------------------------------------------------------------------------
# the checkpoint journal
# ---------------------------------------------------------------------------

class TestJournal:

  def test_round_trip(self, tmp_path):
    j = SweepJournal(tmp_path)
    state = {"done": {0, 1}, "counters": {"n_rows": 64}}
    j.record("k" * 64, state)
    assert j.load("k" * 64) == state

  def test_missing_and_corrupt_are_fresh_starts(self, tmp_path):
    j = SweepJournal(tmp_path)
    assert j.load("a" * 64) is None
    j.record("a" * 64, {"done": set()})
    with open(j.path("a" * 64), "wb") as f:
      f.write(b"\x80truncated garbage")
    assert j.load("a" * 64) is None

  def test_key_and_version_mismatch_rejected(self, tmp_path):
    j = SweepJournal(tmp_path)
    key, other = "a" * 64, "b" * 64
    with open(j.path(key), "wb") as f:
      pickle.dump({"version": 1, "key": other, "state": {}}, f)
    assert j.load(key) is None
    with open(j.path(key), "wb") as f:
      pickle.dump({"version": 999, "key": key, "state": {}}, f)
    assert j.load(key) is None

  def test_sweep_key_sensitivity(self):
    base = dict(kind="explore", space_fp="s", reducers_fp="r",
                params={"seed": 3, "chunk_size": 64})
    k0 = sweep_key(**base)
    assert sweep_key(**base) == k0
    assert sweep_key("co-explore", "s", "r", base["params"]) != k0
    assert sweep_key("explore", "s2", "r", base["params"]) != k0
    assert sweep_key("explore", "s", "r", {"seed": 4,
                                           "chunk_size": 64}) != k0


# ---------------------------------------------------------------------------
# end to end: kill at every chunk boundary, resume bit-identically
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def arch_accs():
  rng = np.random.RandomState(7)
  archs = [ArchChoice(tuple((int(rng.choice(r)), int(rng.choice(c)))
                            for r, c in SEARCH_SPACE)) for _ in range(4)]
  return list(zip(archs, rng.uniform(0.5, 0.95, len(archs))))


def co_reducers():
  cols = ("top1_err", "energy_mj", "area_mm2")
  return {"pareto": ParetoAccumulator(cols),
          "top": TopKAccumulator(7, by="energy_mj"),
          "stats": StatsAccumulator("energy_mj")}


def run_co(sess, arch_accs, **kw):
  return sess.co_explore(arch_accs, n_hw_per_type=10, seed=3,
                         image_size=16, stream=True,
                         reducers=co_reducers(), chunk_size=13, **kw)


def assert_same_results(got, want):
  for name in ("pareto", "top"):
    for col in METRICS:
      assert np.array_equal(getattr(got[name], col),
                            getattr(want[name], col)), (name, col)
  assert np.array_equal(got["pareto"].extra["arch_id"],
                        want["pareto"].extra["arch_id"])
  assert got["stats"] == want["stats"]


class TestKillAndResume:

  def test_every_chunk_boundary(self, arch_accs, tmp_path):
    sess = ExplorationSession(VectorOracleBackend(chunk_size=512))
    ref = run_co(sess, arch_accs)
    n_chunks = int(ref.meta["n_chunks"])
    assert n_chunks >= 10  # the acceptance floor: a 10+-chunk sweep
    for k in range(n_chunks):
      jdir = tmp_path / f"kill-{k}"
      pol = ResiliencePolicy(retry=no_wait(),
                             fault_plan=FaultPlan([Fault("kill", k,
                                                         "task")]))
      with pytest.raises(ChunkError) as err:
        run_co(sess, arch_accs, policy=pol, resume_from=jdir)
      assert err.value.chunk_index == k
      res = run_co(sess, arch_accs, resume_from=jdir)
      assert_same_results(res, ref)
      assert res.meta["n_resumed_chunks"] == float(k)
      assert res.meta["n_chunks"] == float(n_chunks)

  def test_finished_journal_resumes_everything(self, arch_accs, tmp_path):
    sess = ExplorationSession(VectorOracleBackend(chunk_size=512))
    ref = run_co(sess, arch_accs, resume_from=tmp_path)
    res = run_co(sess, arch_accs, resume_from=tmp_path)
    assert_same_results(res, ref)
    assert res.meta["n_resumed_chunks"] == ref.meta["n_chunks"]

  def test_corrupt_journal_restarts_cleanly(self, arch_accs, tmp_path):
    sess = ExplorationSession(VectorOracleBackend(chunk_size=512))
    ref = run_co(sess, arch_accs, resume_from=tmp_path)
    for p in tmp_path.glob("sweep-*.pkl"):
      p.write_bytes(b"not a pickle")
    res = run_co(sess, arch_accs, resume_from=tmp_path)
    assert_same_results(res, ref)
    assert res.meta["n_resumed_chunks"] == 0.0

  def test_transient_faults_healed_in_place(self, arch_accs):
    sess = ExplorationSession(VectorOracleBackend(chunk_size=512))
    ref = run_co(sess, arch_accs)
    plan = FaultPlan([Fault("raise", 2, "task"),
                      Fault("raise", 5, "task")])
    pol = ResiliencePolicy(retry=no_wait(), fault_plan=plan)
    res = run_co(sess, arch_accs, policy=pol)
    assert_same_results(res, ref)
    assert res.meta["n_retries"] == 2.0
    assert res.meta["n_demotions"] == 0.0


# ---------------------------------------------------------------------------
# graceful degradation on the device path (jit backend)
# ---------------------------------------------------------------------------

class TestDeviceDegradation:

  def test_device_faults_degrade_to_numpy_bit_identically(self):
    pytest.importorskip("jax")
    layers = get_network("resnet20")[:4]
    sess = ExplorationSession(VectorOracleBackend(chunk_size=64, jit=True))

    def go(policy=None):
      # reducers are stateful accumulators: build fresh ones per run
      return sess.explore(
          layers, "net", n_per_type=40, seed=4, stream=True, chunk_size=32,
          policy=policy,
          reducers={"pareto": ParetoAccumulator(COLS),
                    "top": TopKAccumulator(5, by="energy_mj")})

    ref = go()
    # times=99: every device-layer dispatch for chunk 1 fails, so both
    # the fused and unfused device rungs exhaust and the chunk lands on
    # the numpy rung — whose rows are bit-identical (parity contract)
    plan = FaultPlan([Fault("raise", 1, "device", times=99)])
    pol = ResiliencePolicy(retry=no_wait(), fault_plan=plan)
    res = go(pol)
    assert res.meta["n_demotions"] > 0
    assert pol.demotions == [(1, "fused-device", "dispatch"),
                             (1, "device", "dispatch")]
    for name in ("pareto", "top"):
      for col in METRICS:
        assert np.array_equal(getattr(res[name], col),
                              getattr(ref[name], col)), (name, col)
