"""Per-arch smoke tests (reduced configs) + model-component tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, reduce_for_smoke
from repro.models.attention import decode_attention, flash_attention
from repro.models.model import build_model

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B=2, S=32):
  batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
           "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
  if cfg.family == "encdec":
    batch["enc_frames"] = jax.random.normal(KEY, (B, 16, cfg.d_model))
    batch["tokens"] = batch["tokens"][:, :8]
    batch["labels"] = batch["labels"][:, :8]
  if cfg.family == "vlm":
    batch["img_embeds"] = jax.random.normal(
        KEY, (B, cfg.n_image_tokens, cfg.d_model))
  return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
class TestSmoke:
  """One reduced-config forward/train step per assigned architecture."""

  def test_train_step_shapes_and_finite(self, arch):
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    loss, metrics = jax.jit(lambda p, b: model.train_loss(p, b))(
        params, _batch_for(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    assert float(metrics["tokens"]) > 0

  @pytest.mark.slow
  def test_gradients_finite(self, arch):
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    g = jax.jit(jax.grad(lambda p, b: model.train_loss(p, b)[0]))(
        params, _batch_for(cfg))
    leaves = jax.tree_util.tree_leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)
    # at least one nonzero gradient
    assert any(float(jnp.max(jnp.abs(l))) > 0 for l in leaves)


@pytest.mark.parametrize("arch", ["olmo-1b", "granite-34b", "qwen3-0.6b",
                                  "minitron-4b", "whisper-base",
                                  "rwkv6-1.6b", "pixtral-12b"])
def test_decode_matches_prefill_exact(arch):
  """Non-MoE archs: decode continuation == full-prefill logits."""
  cfg = reduce_for_smoke(get_config(arch))
  model = build_model(cfg)
  params = model.init(KEY)
  B, S, MAX = 2, 24, 48
  toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
  batch = {"tokens": toks}
  if cfg.family == "encdec":
    batch["enc_frames"] = jax.random.normal(KEY, (B, 16, cfg.d_model))
  logits0, cache = jax.jit(lambda p, b: model.prefill(p, b, MAX))(
      params, batch)
  nxt = jnp.argmax(logits0, -1).astype(jnp.int32)
  logits1, _ = jax.jit(model.decode_step)(params, nxt, cache)
  batch2 = dict(batch)
  batch2["tokens"] = jnp.concatenate([toks, nxt[:, None]], 1)
  logits_ref, _ = jax.jit(lambda p, b: model.prefill(p, b, MAX))(
      params, batch2)
  err = float(jnp.max(jnp.abs(logits1 - logits_ref))
              / (jnp.max(jnp.abs(logits_ref)) + 1e-9))
  assert err < 1e-4, err


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "qwen2-moe-a2.7b",
                                  "jamba-1.5-large"])
@pytest.mark.slow
def test_decode_matches_prefill_moe_no_drops(arch):
  """MoE archs match exactly when capacity dropping is disabled."""
  cfg = dataclasses.replace(reduce_for_smoke(get_config(arch)),
                            capacity_factor=8.0)
  model = build_model(cfg)
  params = model.init(KEY)
  B, S, MAX = 2, 24, 48
  toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
  logits0, cache = jax.jit(lambda p, b: model.prefill(p, b, MAX))(
      params, {"tokens": toks})
  nxt = jnp.argmax(logits0, -1).astype(jnp.int32)
  logits1, _ = jax.jit(model.decode_step)(params, nxt, cache)
  logits_ref, _ = jax.jit(lambda p, b: model.prefill(p, b, MAX))(
      params, {"tokens": jnp.concatenate([toks, nxt[:, None]], 1)})
  err = float(jnp.max(jnp.abs(logits1 - logits_ref))
              / (jnp.max(jnp.abs(logits_ref)) + 1e-9))
  assert err < 1e-4, err


@pytest.mark.slow
def test_quantized_kv_decode_close():
  """int8 KV cache decode stays close to the fp cache decode."""
  cfg = reduce_for_smoke(get_config("qwen3-0.6b"))
  cfg8 = dataclasses.replace(cfg, kv_quant="int8")
  m0, m8 = build_model(cfg), build_model(cfg8)
  params = m0.init(KEY)
  toks = jax.random.randint(KEY, (2, 24), 0, cfg.vocab_size)
  l0, c0 = jax.jit(lambda p, b: m0.prefill(p, b, 48))(params,
                                                      {"tokens": toks})
  l8, c8 = jax.jit(lambda p, b: m8.prefill(p, b, 48))(params,
                                                      {"tokens": toks})
  nxt = jnp.argmax(l0, -1).astype(jnp.int32)
  d0, _ = jax.jit(m0.decode_step)(params, nxt, c0)
  d8, _ = jax.jit(m8.decode_step)(params, nxt, c8)
  rel = float(jnp.linalg.norm(d8 - d0) / (jnp.linalg.norm(d0) + 1e-9))
  assert rel < 0.05, rel
  # and the argmax token usually agrees
  agree = float(jnp.mean((jnp.argmax(d0, -1) == jnp.argmax(d8, -1))
                         .astype(jnp.float32)))
  assert agree >= 0.5


class TestFlashAttention:
  @pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                             (True, 16)])
  def test_vs_dense_reference(self, causal, window):
    b, s, h, d = 2, 48, 4, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    got = flash_attention(q, k, v, causal=causal, window=window,
                          chunk_q=16, chunk_k=16)
    # dense reference
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (d ** 0.5)
    mask = jnp.ones((s, s), bool)
    if causal:
      mask &= jnp.tril(jnp.ones((s, s), bool))
    if window:
      qi = jnp.arange(s)[:, None]
      ki = jnp.arange(s)[None, :]
      mask &= ki > qi - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)

  def test_gqa_grouping(self):
    b, s, h, hkv, d = 1, 32, 8, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    out = flash_attention(q, k, v, chunk_q=16, chunk_k=16)
    assert out.shape == (b, s, h, d)
    # kv heads repeat: groups of 4 query heads see the same k/v
    kr = jnp.repeat(k, 4, axis=2)
    vr = jnp.repeat(v, 4, axis=2)
    want = flash_attention(q, kr, vr, chunk_q=16, chunk_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_param_counts_match_published():
  expected = {"olmo-1b": 1.18e9, "granite-34b": 34.4e9, "qwen3-0.6b": 0.6e9,
              "minitron-4b": 4.19e9, "mixtral-8x22b": 140.6e9,
              "qwen2-moe-a2.7b": 14.3e9, "jamba-1.5-large": 398e9,
              "rwkv6-1.6b": 1.6e9, "pixtral-12b": 12.2e9}
  for arch, n in expected.items():
    got = get_config(arch).param_count()
    assert abs(got - n) / n < 0.05, (arch, got, n)


def test_active_params_moe():
  assert abs(get_config("mixtral-8x22b").param_count(active_only=True)
             - 39e9) / 39e9 < 0.05
  assert abs(get_config("jamba-1.5-large").param_count(active_only=True)
             - 94e9) / 94e9 < 0.05
